"""L2 network definitions (paper Table III architectures).

Parameters are *flat lists of f32 arrays* in a documented order — the rust
coordinator owns initialization, storage (master weights) and marshaling,
so the convention must be dead simple:

    MLP:      [W0, b0, W1, b1, ...]          W: (din, dout), b: (dout,)
    ConvNet:  [K0, b0, K1, b1, ..., Wfc, bfc, ...]
              K: (kh, kw, cin, cout) HWIO, b: (cout,)

Dense layers run through the L1 Pallas mixed-precision matmul; conv layers
use lax.conv (XLA) with the same operand-rounding emulation (conv *is* an
MM node in the paper's taxonomy — im2col GEMM — and the analytic hw model
profiles it as such; see DESIGN.md).

Every forward takes a per-layer precision assignment (compile.precision),
so one code path serves the fp32 control and the mixed AP-DRL artifacts.
"""

import math

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import matmul, quantize


def _dense(x, w, b, prec):
    """One dense layer on component ``prec.component``: operands rounded to
    the component format, f32 accumulate, bias add in f32."""
    y = matmul(x, w, prec.fmt)
    return y + quantize(b, prec.fmt)


def mlp_forward(params, x, assignment, hidden_act=jnp.tanh, final_act=None):
    """3-or-more-layer MLP forward.  ``assignment`` has one LayerPrecision
    per weight matrix."""
    n_layers = len(params) // 2
    assert len(assignment) == n_layers, (len(assignment), n_layers)
    h = x
    for i in range(n_layers):
        w, b = params[2 * i], params[2 * i + 1]
        h = _dense(h, w, b, assignment[i])
        if i < n_layers - 1:
            h = hidden_act(h)
    if final_act is not None:
        h = final_act(h)
    return h


def mlp_param_shapes(sizes):
    """[(din,dout), (dout,), ...] for rust-side init/marshaling."""
    shapes = []
    for din, dout in zip(sizes[:-1], sizes[1:]):
        shapes.append((din, dout))
        shapes.append((dout,))
    return shapes


# ---------------------------------------------------------------------------
# Conv net (Table III Breakout/MsPacman: Conv(8,4)-Conv(4,2)-Conv(3,1)-FC-FC)
# ---------------------------------------------------------------------------


def _conv(x, k, b, stride, prec):
    """NHWC conv, HWIO kernel, VALID padding (the Nature-DQN trunk uses no
    padding).  Operands rounded to the component format like the GEMM."""
    xq = quantize(x, prec.fmt)
    kq = quantize(k, prec.fmt)
    y = lax.conv_general_dilated(
        xq,
        kq,
        window_strides=(stride, stride),
        padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + quantize(b, prec.fmt)


def conv_net_spec(in_hw, in_ch, conv_layers, fc_sizes):
    """Compute the flattened-dim + per-layer per-row FLOPs of a conv trunk.

    conv_layers: [(cout, ksize, stride), ...];  fc_sizes: [h1, ..., out].
    Returns (param_shapes, flat_dim, per_layer_flops).
    """
    h = w = in_hw
    c = in_ch
    shapes = []
    flops = []
    for cout, k, s in conv_layers:
        oh = (h - k) // s + 1
        ow = (w - k) // s + 1
        shapes.append((k, k, c, cout))
        shapes.append((cout,))
        flops.append(2 * oh * ow * k * k * c * cout)
        h, w, c = oh, ow, cout
    flat = h * w * c
    sizes = [flat] + list(fc_sizes)
    for din, dout in zip(sizes[:-1], sizes[1:]):
        shapes.append((din, dout))
        shapes.append((dout,))
        flops.append(2 * din * dout)
    return shapes, flat, flops


def conv_forward(params, x, conv_layers, assignment, hidden_act=jax.nn.relu):
    """Conv trunk + FC head.  ``assignment`` covers conv layers then FC
    layers, in order."""
    n_conv = len(conv_layers)
    h = x
    for i, (cout, k, s) in enumerate(conv_layers):
        kk, b = params[2 * i], params[2 * i + 1]
        h = hidden_act(_conv(h, kk, b, s, assignment[i]))
    h = h.reshape(h.shape[0], -1)
    n_fc = (len(params) - 2 * n_conv) // 2
    for j in range(n_fc):
        w, b = params[2 * (n_conv + j)], params[2 * (n_conv + j) + 1]
        h = _dense(h, w, b, assignment[n_conv + j])
        if j < n_fc - 1:
            h = hidden_act(h)
    return h


def init_scale(shape):
    """He-uniform bound used by the rust initializer (documented here so
    python tests and rust agree): U(-lim, lim), lim = sqrt(6 / fan_in)."""
    fan_in = shape[0] if len(shape) == 2 else shape[0] * shape[1] * shape[2]
    return math.sqrt(6.0 / fan_in)
