"""Adam + loss-scaled gradient machinery (L2 side of paper Alg. 1 / Fig 9).

The *policy* of dynamic loss scaling (grow/backoff/skip) is L3 coordination
(rust `quant::LossScaler`); this module implements the per-step mechanics
that must live inside the lowered artifact:

  * the loss is multiplied by the ``loss_scale`` input before backprop,
  * gradients are unscaled by 1/scale,
  * ``found_inf`` (f32 0/1) reports any non-finite gradient,
  * the Adam update is *skipped* (params and moments passed through) when
    found_inf is set — Fig 9's "conditional update skipping",
  * AIE-assigned (bf16) layers have their updated weights re-rounded to
    bf16: the paper keeps no master copy for AIE nodes, so the stored
    value must be bf16-representable (Table II "Master Weight Backup
    Required? No").

Optimizer state marshaling convention (rust `drl::network` mirrors it):
``opt_state = [m_0..m_{k-1}, v_0..v_{k-1}, t]`` with t a f32 scalar.
"""

import jax
import jax.numpy as jnp

from .kernels import quantize


def init_opt_state(params):
    zeros = [jnp.zeros_like(p) for p in params]
    return zeros + [jnp.zeros_like(p) for p in params] + [jnp.zeros((), jnp.float32)]


def unscale_and_check(grads, loss_scale):
    """Unscale gradients and compute the found-inf flag (f32 0/1)."""
    inv = 1.0 / loss_scale
    unscaled = [g * inv for g in grads]
    finite = jnp.ones((), jnp.bool_)
    for g in grads:  # check the *scaled* grads: that's where fp16 overflows
        finite = jnp.logical_and(finite, jnp.all(jnp.isfinite(g)))
    return unscaled, (1.0 - finite.astype(jnp.float32))


def adam_update(
    params,
    grads,
    opt_state,
    found_inf,
    *,
    lr,
    bf16_mask=None,
    betas=(0.9, 0.999),
    eps=1e-8,
):
    """One Adam step, skipped elementwise-uniformly when found_inf == 1.

    ``bf16_mask`` (optional, one bool per tensor) re-rounds AIE-resident
    tensors to bf16 after the update (weights and their biases alike).
    """
    k = len(params)
    m, v, t = opt_state[:k], opt_state[k : 2 * k], opt_state[2 * k]
    b1, b2 = betas
    keep = found_inf  # 1.0 -> keep old values, 0.0 -> apply update
    t_new = t + (1.0 - keep)
    new_params, new_m, new_v = [], [], []
    # bias correction uses the *post-increment* step count; guard t=0 (all
    # first steps skipped) with a max to avoid 0^0 division surprises.
    t_safe = jnp.maximum(t_new, 1.0)
    c1 = 1.0 - b1**t_safe
    c2 = 1.0 - b2**t_safe
    for i, (p, g, mi, vi) in enumerate(zip(params, grads, m, v)):
        g = jnp.where(keep > 0, jnp.zeros_like(g), g)  # poison-free skip
        mi2 = b1 * mi + (1 - b1) * g
        vi2 = b2 * vi + (1 - b2) * g * g
        step = lr * (mi2 / c1) / (jnp.sqrt(vi2 / c2) + eps)
        p2 = p - step
        if bf16_mask is not None and bf16_mask[i]:
            # AIE node: no master copy — the stored weight is the bf16 value.
            p2 = quantize(p2, "bf16")
        new_params.append(jnp.where(keep > 0, p, p2))
        new_m.append(jnp.where(keep > 0, mi, mi2))
        new_v.append(jnp.where(keep > 0, vi, vi2))
    return new_params, new_m + new_v + [t_new]


def soft_update(target_params, params, tau):
    """Polyak averaging for target networks (DDPG)."""
    return [tau * p + (1.0 - tau) * tp for tp, p in zip(target_params, params)]
