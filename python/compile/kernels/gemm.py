"""L1 hot-spot kernel: tiled mixed-precision GEMM (Pallas).

The paper's bottleneck analysis (Fig 5/6) shows DRL training time is
dominated by the GEMMs of forward/backward propagation, and its hardware
mapping runs them in BF16 on the AIE-ML array (bf16 multiply, fp32
accumulate) or FP16 on the PL DSP slices.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the AIE-ML tile array
maps onto the MXU-style systolic model Pallas exposes —

  * (M, N, K) is tiled into VMEM-resident blocks via BlockSpec (the
    HBM<->VMEM schedule standing in for CHARM's PLIO double-buffering),
  * the grid iterates (M/bm, N/bn, K/bk) with an f32 VMEM accumulator
    (the AIE-ML cascade/accumulator registers),
  * inputs are rounded to the compute format (bf16/fp16) at tile load,
    mirroring the vector-register width of the target component.

interpret=True everywhere: the CPU PJRT client cannot run Mosaic
custom-calls; real-TPU efficiency is estimated from VMEM footprint + MXU
alignment in DESIGN.md/EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quantize import INTERPRET

# Default VMEM tile: MXU-aligned (128 lanes) but clamped to the operand
# shape so the small DRL MLPs (e.g. 4x64) do not pad 100x.  The §Perf pass
# sweeps these (see python/tests/test_kernel.py::test_block_sweep and
# EXPERIMENTS.md §Perf L1).
DEFAULT_BM = 512
DEFAULT_BN = 512
DEFAULT_BK = 512


def _cast(x, fmt):
    if fmt == "fp32":
        return x
    if fmt == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if fmt == "fp16":
        return x.astype(jnp.float16).astype(jnp.float32)
    raise ValueError(f"unknown format {fmt!r}")


def _gemm_kernel(x_ref, w_ref, o_ref, *, fmt):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks.

    The f32 output block doubles as the accumulator (it stays VMEM-resident
    across the K steps because its index map ignores the K grid axis) —
    emulating the AIE-ML cascade/accumulator registers without a scratch
    buffer.
    """

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # Round tile operands to the component's compute format at load —
    # this is where the bf16 multiply / f32 accumulate datapath of the
    # AIE-ML (or the fp16 DSP path on the PL) is emulated.
    x = _cast(x_ref[...], fmt)
    w = _cast(w_ref[...], fmt)
    o_ref[...] += jnp.dot(x, w, preferred_element_type=jnp.float32)


def _pad_to(x, m, axis):
    pad = (-x.shape[axis]) % m
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def gemm(x, w, *, fmt="fp32", bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """``x @ w`` with operands rounded to ``fmt`` and f32 accumulation.

    x: (M, K) f32, w: (K, N) f32 -> (M, N) f32.
    Shapes are padded up to the tile grid and the result sliced back, so
    arbitrary DRL layer shapes are accepted.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"gemm shape mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    n = w.shape[1]
    bm = min(bm, max(m, 1))
    bn = min(bn, max(n, 1))
    bk = min(bk, max(k, 1))
    xp = _pad_to(_pad_to(x, bm, 0), bk, 1)
    wp = _pad_to(_pad_to(w, bk, 0), bn, 1)
    mp, kp = xp.shape
    np_ = wp.shape[1]
    nk = kp // bk
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, fmt=fmt),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=INTERPRET,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def matmul(x, w, fmt="fp32"):
    """Differentiable mixed-precision matmul used by every dense layer.

    Forward and both backward GEMMs (dx = g @ w.T, dw = x.T @ g) run the
    Pallas kernel in the same compute format — the whole layer lives on one
    component under AP-DRL's per-layer partitioning, so its backward pass
    shares that component's precision (paper Alg. 1: "Execute current node
    in BF16" covers fwd, bwd and update).
    """
    return gemm(x, w, fmt=fmt)


def _matmul_fwd(x, w, fmt):
    return gemm(x, w, fmt=fmt), (x, w)


def _matmul_bwd(fmt, res, g):
    x, w = res
    dx = gemm(g, w.T, fmt=fmt)
    dw = gemm(x.T, g, fmt=fmt)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm, bn, bk, fmt="bf16"):
    """Estimated VMEM bytes for one grid step: x tile + w tile + f32 acc.

    Used by the §Perf L1 analysis (and `figures`'s kernel report) to bound
    tile sizes against the ~16 MiB VMEM of a TPU core — the stand-in for
    the AIE-ML tile-local memory budget CHARM enforces.
    """
    in_bytes = 2 if fmt in ("bf16", "fp16") else 4
    return bm * bk * in_bytes + bk * bn * in_bytes + bm * bn * 4


def mxu_alignment(bm, bn, bk):
    """Fraction of the (128, 128) MXU tile each block dimension fills —
    the utilisation *estimate* reported in §Perf (interpret=True gives no
    hardware timing)."""
    def frac(d):
        return min(d, 128) / 128.0 if d % 128 else 1.0
    return min(frac(bm), frac(bn), frac(bk))
