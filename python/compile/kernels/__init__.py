"""L1: Pallas kernels for AP-DRL's compute hot-spot (mixed-precision GEMM)
plus the precision-emulation casts, with pure-jnp oracles in ref.py."""

from .gemm import gemm, matmul, vmem_footprint_bytes, mxu_alignment  # noqa: F401
from .quantize import quantize, quantize_bf16, quantize_fp16, FORMATS  # noqa: F401
