"""Pure-jnp oracles for the L1 kernels.

These are the correctness ground truth: pytest (python/tests/) asserts the
Pallas kernels match them with `assert_allclose`, and hypothesis sweeps
shapes/formats.  Nothing here is ever lowered into an artifact.
"""

import jax.numpy as jnp
import numpy as np


def round_format(x, fmt):
    """Reference RNE round-trip through ``fmt`` (f32 storage)."""
    x = jnp.asarray(x, jnp.float32)
    if fmt == "fp32":
        return x
    if fmt == "bf16":
        return x.astype(jnp.bfloat16).astype(jnp.float32)
    if fmt == "fp16":
        return x.astype(jnp.float16).astype(jnp.float32)
    raise ValueError(fmt)


def round_bf16_bits(x):
    """Manual bit-twiddling RNE f32 -> bf16 -> f32, independent of any
    dtype-cast implementation.  Guards the astype semantics the kernels
    rely on (paper Fig 3: bf16 = top 16 bits of f32 with round-to-nearest-
    even on bit 16)."""
    x = np.asarray(x, np.float32)
    bits = x.view(np.uint32)
    nan = np.isnan(x)
    rounding_bias = ((bits >> 16) & 1).astype(np.uint32) + np.uint32(0x7FFF)
    rounded = ((bits + rounding_bias) & np.uint32(0xFFFF0000)).view(np.float32)
    out = np.where(nan, x, rounded)
    return jnp.asarray(out)


def gemm(x, w, fmt="fp32"):
    """Reference mixed-precision GEMM: round operands, multiply-accumulate
    in f32 (highest-precision accumulation, like the MXU / AIE-ML MAC)."""
    xq = round_format(x, fmt)
    wq = round_format(w, fmt)
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)


def matmul_grads(x, w, g, fmt="fp32"):
    """Reference VJP of the mixed-precision matmul (both backward GEMMs in
    the same component format — see kernels/gemm.py::matmul)."""
    dx = gemm(g, w.T, fmt=fmt)
    dw = gemm(x.T, g, fmt=fmt)
    return dx, dw
