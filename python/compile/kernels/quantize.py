"""L1 precision-emulation kernels.

AP-DRL coordinates three numeric formats across the Versal ACAP components
(paper Table II / Fig 3):

  * FP32 on the PS (Cortex-A72),
  * FP16 on the PL/DSP (requires master weights + dynamic loss scaling),
  * BF16 on the AIE-ML (same exponent range as FP32 -> no scaling needed).

On this testbed the "hardware" formats are emulated in software with
bit-exact round-to-nearest-even casts.  The casts are wrapped as Pallas
kernels (interpret=True) so the rounding lives at L1 next to the GEMM, and a
pure-jnp oracle in ref.py checks them (plus a manual bit-twiddling RNE
implementation in the tests to guard against astype semantics drifting).

Everything here is build-time only: the kernels lower into the train-step
HLO emitted by aot.py and execute under the rust PJRT client.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Pallas kernels must be lowered with interpret=True: the CPU PJRT plugin
# cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
INTERPRET = True

#: Formats AP-DRL coordinates.  "fp32" is the identity (PS native format).
FORMATS = ("fp32", "fp16", "bf16")


def _round_kernel(x_ref, o_ref, *, dtype):
    """Elementwise round-trip through ``dtype`` (RNE, like the hardware MAC
    input registers on the PL DSP slices / AIE-ML vector lanes)."""
    o_ref[...] = x_ref[...].astype(dtype).astype(x_ref.dtype)


def _round_via_pallas(x, dtype):
    if x.ndim == 0:  # pallas wants >=1D blocks; scalars are cheap anyway
        return x.astype(dtype).astype(x.dtype)
    return pl.pallas_call(
        functools.partial(_round_kernel, dtype=dtype),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=INTERPRET,
    )(x)


def _make_quantizer(dtype, name):
    """Differentiable precision round-trip.

    VJP: the cotangent is itself rounded to the component format (the
    backward pass of a layer runs on the same component as its forward
    under per-layer partitioning — paper Alg. 1), composed with a
    straight-through identity for the rounding nonlinearity.
    """

    @jax.custom_vjp
    def q(x):
        return _round_via_pallas(x, dtype)

    def q_fwd(x):
        return q(x), None

    def q_bwd(_, g):
        return (_round_via_pallas(g, dtype),)

    q.defvjp(q_fwd, q_bwd)
    q.__name__ = name
    return q


#: Round f32 -> bf16 -> f32 (AIE-ML compute format, RNE).
quantize_bf16 = _make_quantizer(jnp.bfloat16, "quantize_bf16")

#: Round f32 -> fp16 -> f32 (PL/DSP compute format, RNE).  Out-of-range
#: magnitudes saturate to +/-inf exactly like an IEEE-754 binary16 cast;
#: AP-DRL's dynamic loss scaling (L3 ``quant::LossScaler``) keeps scaled
#: gradients inside the representable range.
quantize_fp16 = _make_quantizer(jnp.float16, "quantize_fp16")


def quantize(x, fmt):
    """Round ``x`` into compute format ``fmt`` (and back to f32 storage)."""
    if fmt == "fp32":
        return x
    if fmt == "bf16":
        return quantize_bf16(x)
    if fmt == "fp16":
        return quantize_fp16(x)
    raise ValueError(f"unknown format {fmt!r}; expected one of {FORMATS}")
