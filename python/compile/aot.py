"""AOT lowering: every (combo, kind, mode) train/act step -> HLO text.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the rust `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Also emits ``artifacts/manifest.json`` describing each artifact's
positional I/O layout for the rust marshaling layer, and skips lowering
when sources are unchanged (content hash) so `make artifacts` is a no-op
on a built tree.

Usage: cd python && python -m compile.aot [--out-dir ../artifacts]
       [--only NAME_SUBSTR] [--force]
"""

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import combos, trainstep
from .kernels.gemm import gemm as gemm_kernel


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _source_hash():
    """Hash of every compile/ source file — the artifact invalidation key."""
    h = hashlib.sha256()
    root = os.path.dirname(__file__)
    for dirpath, _, files in sorted(os.walk(root)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(dirpath, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def _spec_list(args):
    """Flatten example args into [(shape, dtype), ...] in pytree order —
    the positional convention the rust executor follows."""
    flat, _ = jax.tree_util.tree_flatten(args)
    return [
        {"shape": list(a.shape), "dtype": jnp.dtype(a.dtype).name} for a in flat
    ]


def _gemm_artifact(n, fmt):
    """Square-GEMM artifact for §Perf L1 wallclock (Fig 6's ladder)."""

    def fn(x, w):
        return (gemm_kernel(x, w, fmt=fmt),)

    spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return fn, (spec, spec), dict(kind="gemm", n=n, fmt=fmt)


def artifact_list():
    """Yield (name, fn, args, meta) for everything we lower."""
    for combo_name, cfg in combos.COMBOS.items():
        for mode in combos.MODES:
            for kind in ("train", "act"):
                # bf16 act == same graph as bf16 train's forward; still
                # lowered (cheap) so any mode is runnable end-to-end.
                name = f"{combo_name}_{mode}_{kind}"
                fn, args, meta = trainstep.build(cfg, kind, mode)
                meta = dict(meta, combo=combo_name, env=cfg["env"])
                yield name, fn, args, meta
    for n in combos.GEMM_SIZES:
        for fmt in combos.GEMM_FMTS:
            name = f"gemm_{n}_{fmt}"
            fn, args, meta = _gemm_artifact(n, fmt)
            yield name, fn, args, meta


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="substring filter on artifact names")
    ap.add_argument("--force", action="store_true")
    ns = ap.parse_args()
    os.makedirs(ns.out_dir, exist_ok=True)
    manifest_path = os.path.join(ns.out_dir, "manifest.json")
    src_hash = _source_hash()

    old = {}
    if os.path.exists(manifest_path) and not ns.force:
        with open(manifest_path) as f:
            old = json.load(f)
        if old.get("source_hash") == src_hash and ns.only is None:
            print("artifacts up to date (source hash match); nothing to do")
            return 0

    entries = dict(old.get("artifacts", {})) if ns.only else {}
    t_all = time.time()
    for name, fn, args, meta in artifact_list():
        if ns.only and ns.only not in name:
            continue
        t0 = time.time()
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(ns.out_dir, fname), "w") as f:
            f.write(text)
        entries[name] = {
            "file": fname,
            "inputs": _spec_list(args),
            "outputs": _spec_list(jax.eval_shape(fn, *args)),
            "meta": meta,
        }
        print(f"  {name}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s")

    with open(manifest_path, "w") as f:
        json.dump({"source_hash": src_hash, "artifacts": entries}, f, indent=1)
    print(f"wrote {len(entries)} artifacts in {time.time() - t_all:.1f}s -> {ns.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
