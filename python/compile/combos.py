"""Experiment combinations (paper Table III), shared by aot.py and tests.

Two families:

  * full-shape configs — exactly Table III; used for the *timing* figures
    (profiled analytically by the rust hw model, so no artifact needed at
    84x84 Atari scale), and for the MLP combos' convergence artifacts;
  * ``*_mini`` Atari configs — scaled-down pixel environments
    (DESIGN.md §Substitutions) whose artifacts are actually trained to
    convergence on this 1-core testbed.

``batch`` is baked into each train artifact (XLA shapes are static); the
rust coordinator requests the artifact matching its configured batch size.
"""

# (cout, ksize, stride) triples of the Nature-DQN trunk (Table III).
ATARI_CONV = [(32, 8, 4), (64, 4, 2), (64, 3, 1)]
# Scaled-down trunk for the mini pixel envs (12x12x4 observations).
MINI_CONV = [(8, 4, 2), (16, 3, 1)]

COMBOS = {
    # --- MLP combos: trained end-to-end through PJRT ---
    "dqn_cartpole": dict(
        algo="dqn",
        env="cartpole",
        obs_dim=4,
        act_dim=2,  # |A| (discrete)
        sizes=[4, 64, 64, 2],
        batch=64,
        gamma=0.99,
        lr=1e-3,
    ),
    "a2c_invpend": dict(
        algo="a2c",
        env="invpendulum",
        obs_dim=4,
        act_dim=1,  # continuous
        sizes=[4, 64, 64, 1],
        batch=64,  # rollout length
        gamma=0.99,
        lr=7e-4,
    ),
    "ddpg_lunar": dict(
        algo="ddpg",
        env="lunarcont",
        obs_dim=8,
        act_dim=2,
        sizes=[8, 400, 300, 2],  # actor; critic gets obs+act inputs
        batch=64,
        gamma=0.99,
        lr=1e-3,
        tau=0.005,
    ),
    "ddpg_mntncar": dict(
        algo="ddpg",
        env="mntncarcont",
        obs_dim=2,
        act_dim=1,
        sizes=[2, 400, 300, 1],
        batch=64,
        gamma=0.99,
        lr=1e-3,
        tau=0.005,
    ),
    # --- mini pixel combos: conv nets trained end-to-end ---
    "dqn_breakout_mini": dict(
        algo="dqn_conv",
        env="breakout_mini",
        in_hw=12,
        in_ch=4,
        conv=MINI_CONV,
        fc=[128, 4],
        act_dim=4,
        batch=32,
        gamma=0.99,
        lr=5e-4,
    ),
    "ppo_mspacman_mini": dict(
        algo="ppo_conv",
        env="mspacman_mini",
        in_hw=12,
        in_ch=4,
        conv=MINI_CONV,
        fc=[128],  # shared trunk FC; heads: pi (A), v (1)
        act_dim=9,
        batch=64,  # rollout minibatch
        gamma=0.99,
        lr=3e-4,
    ),
}

#: Precision modes lowered for every combo.  "fp32" is the paper's control,
#: "mixed" is AP-DRL's FP32+FP16+BF16 coordination, "bf16" is the all-AIE
#: datapath used by Table IV's BF16 column.
MODES = ("fp32", "mixed", "bf16")

#: Square GEMM artifacts for the §Perf L1 wallclock measurements (Fig 6's
#: synthetic-GEMM ladder, truncated to 1-core-feasible sizes).
GEMM_SIZES = (64, 256, 512)
GEMM_FMTS = ("fp32", "bf16")
