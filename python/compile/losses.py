"""DRL loss functions (paper §II-A / Eq. 1) for the four evaluated
algorithms.  All operate on flat param lists + batch arrays and are pure,
so jax.grad closes over them directly in trainstep.py."""

import jax
import jax.numpy as jnp

LOG_2PI = 1.8378770664093453


def dqn_loss(q_online, q_target_max, a, r, done, gamma):
    """Eq. 1: squared TD error with a decoupled target network.

    q_online: (bs, A) online Q(s, ·); q_target_max: (bs,) max_a' Q_t(s',a');
    a: (bs,) i32 actions; r, done: (bs,) f32.
    """
    bs = q_online.shape[0]
    q_sa = q_online[jnp.arange(bs), a]
    y = r + gamma * (1.0 - done) * q_target_max
    y = jax.lax.stop_gradient(y)
    return jnp.mean((y - q_sa) ** 2)


def ddpg_critic_loss(q, q_target_next, r, done, gamma):
    """MSE TD error for the critic; q, q_target_next, r, done: (bs,)."""
    y = jax.lax.stop_gradient(r + gamma * (1.0 - done) * q_target_next)
    return jnp.mean((y - q) ** 2)


def ddpg_actor_loss(q_of_pi):
    """Deterministic policy gradient: maximize Q(s, pi(s))."""
    return -jnp.mean(q_of_pi)


def gaussian_logp(a, mean, log_std):
    """Diagonal-Gaussian log-density, summed over action dims.
    a, mean: (bs, da); log_std: (da,)."""
    std = jnp.exp(log_std)
    z = (a - mean) / std
    per_dim = -0.5 * z * z - log_std - 0.5 * LOG_2PI
    return jnp.sum(per_dim, axis=-1)


def gaussian_entropy(log_std):
    return jnp.sum(log_std + 0.5 * (LOG_2PI + 1.0))


def a2c_loss(logp, adv, value, ret, entropy, vf_coef=0.5, ent_coef=0.01):
    """Advantage actor-critic: policy gradient + value MSE - entropy bonus."""
    pg = -jnp.mean(logp * jax.lax.stop_gradient(adv))
    vf = jnp.mean((value - ret) ** 2)
    return pg + vf_coef * vf - ent_coef * entropy


def categorical_logp(logits, a):
    """Log pi(a|s) for discrete policies; logits: (bs, A), a: (bs,) i32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    return logits[jnp.arange(logits.shape[0]), a] - logz


def categorical_entropy(logits):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(jnp.exp(logp) * logp, axis=-1))


def ppo_loss(logp, logp_old, adv, value, ret, entropy, clip=0.2, vf_coef=0.5, ent_coef=0.01):
    """Clipped-surrogate PPO objective."""
    adv = jax.lax.stop_gradient(adv)
    ratio = jnp.exp(logp - logp_old)
    surr = jnp.minimum(ratio * adv, jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv)
    pg = -jnp.mean(surr)
    vf = jnp.mean((value - ret) ** 2)
    return pg + vf_coef * vf - ent_coef * entropy
