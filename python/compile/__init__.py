"""Build-time compile path of the AP-DRL reproduction (L1 + L2).

Never imported at runtime: `make artifacts` runs `python -m compile.aot`,
which lowers every (algorithm, environment, precision) train/act step to
HLO text under artifacts/, and the rust coordinator is self-contained from
then on.
"""
