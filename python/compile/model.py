"""L2 entry point (prescribed layout shim).

The actual model code is factored across sibling modules:

  * nets.py       — MLP / conv forward passes calling the L1 kernels
  * losses.py     — DQN / DDPG / A2C / PPO objectives
  * optim.py      — Adam + loss-scaled gradients + bf16 weight storage
  * precision.py  — per-layer precision assignment (AP-DRL partition -> fmt)
  * trainstep.py  — per-artifact jitted train/act step builders
  * aot.py        — lowering to artifacts/*.hlo.txt

This module re-exports the public surface for tests and interactive use.
"""

from .nets import (  # noqa: F401
    conv_forward,
    conv_net_spec,
    init_scale,
    mlp_forward,
    mlp_param_shapes,
)
from .precision import assign_conv, assign_mlp, LayerPrecision  # noqa: F401
from .trainstep import build, BUILDERS  # noqa: F401
