"""L2 train/act step builders — one jitted function per artifact.

Every builder returns ``(fn, example_args, meta)``:

  * ``fn(*args)`` is pure and jit-lowerable; list-valued arguments flatten
    in list order, so the rust marshaling convention is positional;
  * ``example_args`` are ShapeDtypeStructs (or lists thereof);
  * ``meta`` describes the I/O layout for artifacts/manifest.json.

Input layout (train steps)
    [params...] [extra param groups...] [opt state...] [batch arrays...] loss_scale
Output layout
    ([new params...], [new opt...], aux scalars..., loss, found_inf)

Dynamic loss scaling: the scale is an *input* and found_inf an *output*;
the growth/backoff policy lives in rust (`quant::LossScaler`), because it
is stateful across steps — exactly the paper's Fig 9 split between the
per-step MPT dataflow (here) and coordination (L3).
"""

import functools

import jax
import jax.numpy as jnp

from . import losses, nets, optim, precision

F32 = jnp.float32
I32 = jnp.int32


def _spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _mask_from_assignment(assignment, extra_tensors=0):
    """Per-tensor bf16 mask from per-layer assignment ([W,b] per layer,
    then ``extra_tensors`` non-layer tensors like log_std, never bf16)."""
    mask = []
    for prec in assignment:
        mask += [prec.fmt == "bf16"] * 2
    mask += [False] * extra_tensors
    return mask


# ---------------------------------------------------------------------------
# DQN (MLP)
# ---------------------------------------------------------------------------


def build_dqn_train(cfg, mode):
    sizes = cfg["sizes"]
    assign = precision.assign_mlp(sizes, mode)
    bs = cfg["batch"]
    gamma, lr = cfg["gamma"], cfg["lr"]
    mask = _mask_from_assignment(assign)

    def step(params, tparams, opt_state, s, a, r, s2, done, loss_scale):
        def loss_fn(p):
            q = nets.mlp_forward(p, s, assign)
            qt = nets.mlp_forward(tparams, s2, assign)
            q_t_max = jnp.max(qt, axis=-1)
            loss = losses.dqn_loss(q, q_t_max, a, r, done, gamma)
            return loss * loss_scale

        scaled_loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, found_inf = optim.unscale_and_check(grads, loss_scale)
        new_params, new_opt = optim.adam_update(
            params, grads, opt_state, found_inf, lr=lr, bf16_mask=mask
        )
        return new_params, new_opt, scaled_loss / loss_scale, found_inf

    shapes = nets.mlp_param_shapes(sizes)
    params = [_spec(sh) for sh in shapes]
    opt = [_spec(sh) for sh in shapes] * 2 + [_spec(())]
    ds, na = cfg["obs_dim"], cfg["act_dim"]
    args = (
        params,
        params,
        opt,
        _spec((bs, ds)),
        _spec((bs,), I32),
        _spec((bs,)),
        _spec((bs, ds)),
        _spec((bs,)),
        _spec(()),
    )
    meta = dict(
        kind="train",
        algo="dqn",
        mode=mode,
        batch=bs,
        param_shapes=[list(sh) for sh in shapes],
        n_param_groups=2,  # params + target params
        aux_outputs=["loss", "found_inf"],
        scaled=precision.any_scaled(assign),
        assignment=[p.component for p in assign],
    )
    return step, args, meta


def build_dqn_act(cfg, mode):
    sizes = cfg["sizes"]
    assign = precision.assign_mlp(sizes, mode)

    def act(params, s):
        return nets.mlp_forward(params, s, assign)

    shapes = nets.mlp_param_shapes(sizes)
    args = ([_spec(sh) for sh in shapes], _spec((1, cfg["obs_dim"])))
    meta = dict(
        kind="act",
        algo="dqn",
        mode=mode,
        param_shapes=[list(sh) for sh in shapes],
        outputs=["qvalues"],
    )
    return act, args, meta


# ---------------------------------------------------------------------------
# DDPG (MLP actor + critic, target networks, soft updates)
# ---------------------------------------------------------------------------


def _ddpg_shapes(cfg):
    ds, da = cfg["obs_dim"], cfg["act_dim"]
    h1, h2 = cfg["sizes"][1], cfg["sizes"][2]
    actor_sizes = [ds, h1, h2, da]
    critic_sizes = [ds + da, h1, h2, 1]
    return actor_sizes, critic_sizes


def build_ddpg_train(cfg, mode):
    actor_sizes, critic_sizes = _ddpg_shapes(cfg)
    a_assign = precision.assign_mlp(actor_sizes, mode)
    c_assign = precision.assign_mlp(critic_sizes, mode)
    bs = cfg["batch"]
    gamma, lr, tau = cfg["gamma"], cfg["lr"], cfg["tau"]
    a_mask = _mask_from_assignment(a_assign)
    c_mask = _mask_from_assignment(c_assign)

    def actor_fwd(p, s):
        return jnp.tanh(nets.mlp_forward(p, s, a_assign))

    def critic_fwd(p, s, a):
        return nets.mlp_forward(p, jnp.concatenate([s, a], axis=-1), c_assign)[:, 0]

    def step(actor, critic, t_actor, t_critic, opt_a, opt_c, s, a, r, s2, done, loss_scale):
        def c_loss_fn(cp):
            a2 = actor_fwd(t_actor, s2)
            q_next = critic_fwd(t_critic, s2, a2)
            q = critic_fwd(cp, s, a)
            return losses.ddpg_critic_loss(q, q_next, r, done, gamma) * loss_scale

        def a_loss_fn(ap):
            q = critic_fwd(critic, s, actor_fwd(ap, s))
            return losses.ddpg_actor_loss(q) * loss_scale

        closs, c_grads = jax.value_and_grad(c_loss_fn)(critic)
        aloss, a_grads = jax.value_and_grad(a_loss_fn)(actor)
        c_grads, inf_c = optim.unscale_and_check(c_grads, loss_scale)
        a_grads, inf_a = optim.unscale_and_check(a_grads, loss_scale)
        found_inf = jnp.maximum(inf_c, inf_a)
        new_critic, new_opt_c = optim.adam_update(
            critic, c_grads, opt_c, found_inf, lr=lr, bf16_mask=c_mask
        )
        new_actor, new_opt_a = optim.adam_update(
            actor, a_grads, opt_a, found_inf, lr=lr, bf16_mask=a_mask
        )
        # Soft target updates track the (possibly skipped) new params.
        new_t_actor = optim.soft_update(t_actor, new_actor, tau)
        new_t_critic = optim.soft_update(t_critic, new_critic, tau)
        return (
            new_actor,
            new_critic,
            new_t_actor,
            new_t_critic,
            new_opt_a,
            new_opt_c,
            closs / loss_scale,
            aloss / loss_scale,
            found_inf,
        )

    a_shapes = nets.mlp_param_shapes(actor_sizes)
    c_shapes = nets.mlp_param_shapes(critic_sizes)
    pa = [_spec(sh) for sh in a_shapes]
    pc = [_spec(sh) for sh in c_shapes]
    oa = [_spec(sh) for sh in a_shapes] * 2 + [_spec(())]
    oc = [_spec(sh) for sh in c_shapes] * 2 + [_spec(())]
    ds, da = cfg["obs_dim"], cfg["act_dim"]
    args = (
        pa,
        pc,
        pa,
        pc,
        oa,
        oc,
        _spec((bs, ds)),
        _spec((bs, da)),
        _spec((bs,)),
        _spec((bs, ds)),
        _spec((bs,)),
        _spec(()),
    )
    meta = dict(
        kind="train",
        algo="ddpg",
        mode=mode,
        batch=bs,
        actor_shapes=[list(sh) for sh in a_shapes],
        critic_shapes=[list(sh) for sh in c_shapes],
        aux_outputs=["critic_loss", "actor_loss", "found_inf"],
        scaled=precision.any_scaled(a_assign) or precision.any_scaled(c_assign),
        assignment=[p.component for p in a_assign + c_assign],
    )
    return step, args, meta


def build_ddpg_act(cfg, mode):
    actor_sizes, _ = _ddpg_shapes(cfg)
    assign = precision.assign_mlp(actor_sizes, mode)

    def act(actor, s):
        return jnp.tanh(nets.mlp_forward(actor, s, assign))

    shapes = nets.mlp_param_shapes(actor_sizes)
    args = ([_spec(sh) for sh in shapes], _spec((1, cfg["obs_dim"])))
    meta = dict(
        kind="act",
        algo="ddpg",
        mode=mode,
        param_shapes=[list(sh) for sh in shapes],
        outputs=["action"],
    )
    return act, args, meta


# ---------------------------------------------------------------------------
# A2C (Gaussian policy + separate value MLP; continuous control)
# ---------------------------------------------------------------------------


def _a2c_param_shapes(cfg):
    ds, da = cfg["obs_dim"], cfg["act_dim"]
    h1, h2 = cfg["sizes"][1], cfg["sizes"][2]
    pi_shapes = nets.mlp_param_shapes([ds, h1, h2, da])
    v_shapes = nets.mlp_param_shapes([ds, h1, h2, 1])
    return pi_shapes, v_shapes, da


def build_a2c_train(cfg, mode):
    ds, da = cfg["obs_dim"], cfg["act_dim"]
    h1, h2 = cfg["sizes"][1], cfg["sizes"][2]
    pi_sizes = [ds, h1, h2, da]
    v_sizes = [ds, h1, h2, 1]
    pi_assign = precision.assign_mlp(pi_sizes, mode)
    v_assign = precision.assign_mlp(v_sizes, mode)
    bs, lr = cfg["batch"], cfg["lr"]
    # trainables: pi params + [log_std] + v params, one optimizer.
    mask = _mask_from_assignment(pi_assign, extra_tensors=1) + _mask_from_assignment(v_assign)
    n_pi = len(pi_assign) * 2

    def split(train):
        return train[:n_pi], train[n_pi], train[n_pi + 1 :]

    def step(train, opt_state, s, a, ret, adv, loss_scale):
        def loss_fn(tr):
            pi_p, log_std, v_p = split(tr)
            mean = nets.mlp_forward(pi_p, s, pi_assign)
            value = nets.mlp_forward(v_p, s, v_assign)[:, 0]
            logp = losses.gaussian_logp(a, mean, log_std)
            ent = losses.gaussian_entropy(log_std)
            return losses.a2c_loss(logp, adv, value, ret, ent) * loss_scale

        scaled_loss, grads = jax.value_and_grad(loss_fn)(train)
        grads, found_inf = optim.unscale_and_check(grads, loss_scale)
        new_train, new_opt = optim.adam_update(
            train, grads, opt_state, found_inf, lr=lr, bf16_mask=mask
        )
        return new_train, new_opt, scaled_loss / loss_scale, found_inf

    pi_shapes = nets.mlp_param_shapes(pi_sizes)
    v_shapes = nets.mlp_param_shapes(v_sizes)
    all_shapes = pi_shapes + [(da,)] + v_shapes
    train = [_spec(sh) for sh in all_shapes]
    opt = [_spec(sh) for sh in all_shapes] * 2 + [_spec(())]
    args = (
        train,
        opt,
        _spec((bs, ds)),
        _spec((bs, da)),
        _spec((bs,)),
        _spec((bs,)),
        _spec(()),
    )
    meta = dict(
        kind="train",
        algo="a2c",
        mode=mode,
        batch=bs,
        param_shapes=[list(sh) for sh in all_shapes],
        aux_outputs=["loss", "found_inf"],
        scaled=precision.any_scaled(pi_assign) or precision.any_scaled(v_assign),
        assignment=[p.component for p in pi_assign + v_assign],
    )
    return step, args, meta


def build_a2c_act(cfg, mode):
    ds, da = cfg["obs_dim"], cfg["act_dim"]
    h1, h2 = cfg["sizes"][1], cfg["sizes"][2]
    pi_sizes = [ds, h1, h2, da]
    v_sizes = [ds, h1, h2, 1]
    pi_assign = precision.assign_mlp(pi_sizes, mode)
    v_assign = precision.assign_mlp(v_sizes, mode)
    n_pi = len(pi_assign) * 2

    def act(train, s):
        pi_p, log_std, v_p = train[:n_pi], train[n_pi], train[n_pi + 1 :]
        mean = nets.mlp_forward(pi_p, s, pi_assign)
        value = nets.mlp_forward(v_p, s, v_assign)[:, 0]
        return mean, jnp.broadcast_to(log_std, (1, da)), value

    pi_shapes = nets.mlp_param_shapes(pi_sizes)
    v_shapes = nets.mlp_param_shapes(v_sizes)
    all_shapes = pi_shapes + [(da,)] + v_shapes
    args = ([_spec(sh) for sh in all_shapes], _spec((1, ds)))
    meta = dict(
        kind="act",
        algo="a2c",
        mode=mode,
        param_shapes=[list(sh) for sh in all_shapes],
        outputs=["mean", "log_std", "value"],
    )
    return act, args, meta


# ---------------------------------------------------------------------------
# DQN (conv, mini-Breakout)
# ---------------------------------------------------------------------------


def build_dqn_conv_train(cfg, mode):
    shapes, flat, flops = nets.conv_net_spec(cfg["in_hw"], cfg["in_ch"], cfg["conv"], cfg["fc"])
    assign = precision.assign_conv(flops, mode)
    bs, gamma, lr = cfg["batch"], cfg["gamma"], cfg["lr"]
    mask = _mask_from_assignment(assign)
    hw, ch = cfg["in_hw"], cfg["in_ch"]

    def step(params, tparams, opt_state, s, a, r, s2, done, loss_scale):
        def loss_fn(p):
            q = nets.conv_forward(p, s, cfg["conv"], assign)
            qt = nets.conv_forward(tparams, s2, cfg["conv"], assign)
            loss = losses.dqn_loss(q, jnp.max(qt, axis=-1), a, r, done, gamma)
            return loss * loss_scale

        scaled_loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, found_inf = optim.unscale_and_check(grads, loss_scale)
        new_params, new_opt = optim.adam_update(
            params, grads, opt_state, found_inf, lr=lr, bf16_mask=mask
        )
        return new_params, new_opt, scaled_loss / loss_scale, found_inf

    params = [_spec(sh) for sh in shapes]
    opt = [_spec(sh) for sh in shapes] * 2 + [_spec(())]
    args = (
        params,
        params,
        opt,
        _spec((bs, hw, hw, ch)),
        _spec((bs,), I32),
        _spec((bs,)),
        _spec((bs, hw, hw, ch)),
        _spec((bs,)),
        _spec(()),
    )
    meta = dict(
        kind="train",
        algo="dqn_conv",
        mode=mode,
        batch=bs,
        param_shapes=[list(sh) for sh in shapes],
        n_param_groups=2,
        aux_outputs=["loss", "found_inf"],
        scaled=precision.any_scaled(assign),
        assignment=[p.component for p in assign],
    )
    return step, args, meta


def build_dqn_conv_act(cfg, mode):
    shapes, flat, flops = nets.conv_net_spec(cfg["in_hw"], cfg["in_ch"], cfg["conv"], cfg["fc"])
    assign = precision.assign_conv(flops, mode)
    hw, ch = cfg["in_hw"], cfg["in_ch"]

    def act(params, s):
        return nets.conv_forward(params, s, cfg["conv"], assign)

    args = ([_spec(sh) for sh in shapes], _spec((1, hw, hw, ch)))
    meta = dict(
        kind="act",
        algo="dqn_conv",
        mode=mode,
        param_shapes=[list(sh) for sh in shapes],
        outputs=["qvalues"],
    )
    return act, args, meta


# ---------------------------------------------------------------------------
# PPO (conv actor-critic with shared trunk, mini-MsPacman)
# ---------------------------------------------------------------------------


def _ppo_conv_shapes(cfg):
    """Shared trunk (conv + one FC) then pi/v heads."""
    trunk_fc = cfg["fc"][0]
    shapes, flat, flops = nets.conv_net_spec(cfg["in_hw"], cfg["in_ch"], cfg["conv"], [trunk_fc])
    na = cfg["act_dim"]
    head_shapes = [(trunk_fc, na), (na,), (trunk_fc, 1), (1,)]
    head_flops = [2 * trunk_fc * na, 2 * trunk_fc]
    return shapes + head_shapes, flops + head_flops


def build_ppo_conv_train(cfg, mode):
    all_shapes, flops = _ppo_conv_shapes(cfg)
    assign = precision.assign_conv(flops, mode)
    n_trunk_layers = len(cfg["conv"]) + 1
    trunk_assign = assign[:n_trunk_layers]
    pi_assign, v_assign = assign[n_trunk_layers], assign[n_trunk_layers + 1]
    bs, lr = cfg["batch"], cfg["lr"]
    mask = _mask_from_assignment(assign)
    hw, ch, na = cfg["in_hw"], cfg["in_ch"], cfg["act_dim"]
    n_trunk = n_trunk_layers * 2

    def fwd(params, s):
        trunk = params[:n_trunk]
        w_pi, b_pi, w_v, b_v = params[n_trunk : n_trunk + 4]
        h = nets.conv_forward(trunk, s, cfg["conv"], trunk_assign)
        h = jax.nn.relu(h)
        logits = nets._dense(h, w_pi, b_pi, pi_assign)
        value = nets._dense(h, w_v, b_v, v_assign)[:, 0]
        return logits, value

    def step(params, opt_state, s, a, logp_old, ret, adv, loss_scale):
        def loss_fn(p):
            logits, value = fwd(p, s)
            logp = losses.categorical_logp(logits, a)
            ent = losses.categorical_entropy(logits)
            return losses.ppo_loss(logp, logp_old, adv, value, ret, ent) * loss_scale

        scaled_loss, grads = jax.value_and_grad(loss_fn)(params)
        grads, found_inf = optim.unscale_and_check(grads, loss_scale)
        new_params, new_opt = optim.adam_update(
            params, grads, opt_state, found_inf, lr=lr, bf16_mask=mask
        )
        return new_params, new_opt, scaled_loss / loss_scale, found_inf

    params = [_spec(sh) for sh in all_shapes]
    opt = [_spec(sh) for sh in all_shapes] * 2 + [_spec(())]
    args = (
        params,
        opt,
        _spec((bs, hw, hw, ch)),
        _spec((bs,), I32),
        _spec((bs,)),
        _spec((bs,)),
        _spec((bs,)),
        _spec(()),
    )
    meta = dict(
        kind="train",
        algo="ppo_conv",
        mode=mode,
        batch=bs,
        param_shapes=[list(sh) for sh in all_shapes],
        aux_outputs=["loss", "found_inf"],
        scaled=precision.any_scaled(assign),
        assignment=[p.component for p in assign],
    )
    return step, args, meta


def build_ppo_conv_act(cfg, mode):
    all_shapes, flops = _ppo_conv_shapes(cfg)
    assign = precision.assign_conv(flops, mode)
    n_trunk_layers = len(cfg["conv"]) + 1
    trunk_assign = assign[:n_trunk_layers]
    pi_assign, v_assign = assign[n_trunk_layers], assign[n_trunk_layers + 1]
    hw, ch = cfg["in_hw"], cfg["in_ch"]
    n_trunk = n_trunk_layers * 2

    def act(params, s):
        trunk = params[:n_trunk]
        w_pi, b_pi, w_v, b_v = params[n_trunk : n_trunk + 4]
        h = jax.nn.relu(nets.conv_forward(trunk, s, cfg["conv"], trunk_assign))
        logits = nets._dense(h, w_pi, b_pi, pi_assign)
        value = nets._dense(h, w_v, b_v, v_assign)[:, 0]
        return logits, value

    args = ([_spec(sh) for sh in all_shapes], _spec((1, hw, hw, ch)))
    meta = dict(
        kind="act",
        algo="ppo_conv",
        mode=mode,
        param_shapes=[list(sh) for sh in all_shapes],
        outputs=["logits", "value"],
    )
    return act, args, meta


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

BUILDERS = {
    "dqn": (build_dqn_train, build_dqn_act),
    "ddpg": (build_ddpg_train, build_ddpg_act),
    "a2c": (build_a2c_train, build_a2c_act),
    "dqn_conv": (build_dqn_conv_train, build_dqn_conv_act),
    "ppo_conv": (build_ppo_conv_train, build_ppo_conv_act),
}


def build(cfg, kind, mode):
    """Build the (fn, args, meta) triple for one artifact."""
    train_b, act_b = BUILDERS[cfg["algo"]]
    builder = train_b if kind == "train" else act_b
    fn, args, meta = builder(cfg, mode)
    return fn, args, meta
