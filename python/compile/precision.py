"""Per-layer precision assignment (the L2 face of AP-DRL's partitioning).

The rust L3 partitioner assigns every layer node of the training DAG to a
Versal component (PL / AIE, with non-MM layers pinned to PL); each
component implies a compute format (paper Alg. 1):

    AIE  -> bf16    (no master weights, no loss scaling)
    PL   -> fp16    (fp32/bf16 master weights + dynamic loss scaling)
    PS   -> fp32

Artifacts are lowered per *precision mode*:

  * ``fp32``  — everything in fp32 (the paper's non-quantized control),
  * ``mixed`` — each layer rounded to the format of the component the
    default partitioning rule assigns it to.

The default rule mirrors the paper's observed behaviour (§V-C, Fig 15):
high-FLOPs MM layers go to the AIE (bf16), low-FLOPs MM layers and all
non-MM layers go to the PL (fp16).  The rust ILP partitioner implements the
full cost model; this build-time rule only has to pick *formats*, and the
threshold below reproduces the paper's assignments for every Table III
network (cross-checked by rust tests against the ILP output).
"""

from dataclasses import dataclass

#: MM layers with at least this many forward FLOPs (per batch row) are
#: AIE-resident under the default rule.  2 * in * out FLOPs per row; the
#: (400, 300) DDPG trunk lands on AIE, the (64, 64) control MLPs on PL —
#: matching Fig 15 at batch size >= 512 and Fig 4's crossover.
AIE_FLOPS_THRESHOLD = 2 * 64 * 128


@dataclass(frozen=True)
class LayerPrecision:
    """Compute format + loss-scaling participation for one layer."""

    fmt: str  # "fp32" | "fp16" | "bf16"
    component: str  # "PS" | "PL" | "AIE"

    @property
    def scaled(self):
        """FP16/PL layers participate in dynamic loss scaling."""
        return self.fmt == "fp16"


def assign_mlp(sizes, mode):
    """Precision per dense layer of an MLP with ``sizes`` = [d0, d1, ...].

    Returns a list of LayerPrecision, one per weight matrix (d_i x d_{i+1}).
    """
    out = []
    for din, dout in zip(sizes[:-1], sizes[1:]):
        if mode == "fp32":
            out.append(LayerPrecision("fp32", "PS"))
        elif mode == "bf16":
            out.append(LayerPrecision("bf16", "AIE"))
        elif mode == "mixed":
            if 2 * din * dout >= AIE_FLOPS_THRESHOLD:
                out.append(LayerPrecision("bf16", "AIE"))
            else:
                out.append(LayerPrecision("fp16", "PL"))
        else:
            raise ValueError(f"unknown precision mode {mode!r}")
    return out


def assign_conv(channels_flops, mode):
    """Precision per conv/dense layer of a conv net, given each layer's
    per-row forward FLOPs (conv layers are always MM nodes: im2col GEMM)."""
    out = []
    for flops in channels_flops:
        if mode == "fp32":
            out.append(LayerPrecision("fp32", "PS"))
        elif mode == "bf16":
            out.append(LayerPrecision("bf16", "AIE"))
        elif mode == "mixed":
            if flops >= AIE_FLOPS_THRESHOLD:
                out.append(LayerPrecision("bf16", "AIE"))
            else:
                out.append(LayerPrecision("fp16", "PL"))
        else:
            raise ValueError(f"unknown precision mode {mode!r}")
    return out


def any_scaled(assignment):
    """True if any layer runs FP16 => the artifact's loss-scale input is
    live and the L3 LossScaler FSM must drive it."""
    return any(p.scaled for p in assignment)
