"""L2 network forwards: shapes, precision assignment, oracle agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import nets, precision
from compile.kernels import ref


def rand(shape, seed=0, scale=0.3):
    rng = np.random.default_rng(seed)
    return jnp.array((rng.standard_normal(shape) * scale).astype(np.float32))


def mlp_params(sizes, seed=0):
    ps = []
    for i, sh in enumerate(nets.mlp_param_shapes(sizes)):
        ps.append(rand(sh, seed=seed + i))
    return ps


class TestMLP:
    def test_shapes(self):
        sizes = [4, 64, 64, 2]
        ps = mlp_params(sizes)
        assign = precision.assign_mlp(sizes, "fp32")
        out = nets.mlp_forward(ps, rand((7, 4), seed=9), assign)
        assert out.shape == (7, 2)

    def test_fp32_matches_pure_jnp(self):
        sizes = [4, 16, 16, 2]
        ps = mlp_params(sizes)
        x = rand((5, 4), seed=42)
        assign = precision.assign_mlp(sizes, "fp32")
        out = nets.mlp_forward(ps, x, assign)

        h = x
        for i in range(3):
            h = h @ ps[2 * i] + ps[2 * i + 1]
            if i < 2:
                h = jnp.tanh(h)
        np.testing.assert_allclose(np.array(out), np.array(h), rtol=2e-5, atol=2e-5)

    def test_mixed_matches_reference_rounding(self):
        """Mixed forward == manually rounding operands per layer with the
        ref oracle."""
        sizes = [8, 400, 300, 2]  # DDPG-Lunar actor: PL, AIE, AIE... by rule
        ps = mlp_params(sizes, seed=3)
        x = rand((4, 8), seed=5)
        assign = precision.assign_mlp(sizes, "mixed")
        out = nets.mlp_forward(ps, x, assign)

        h = x
        for i in range(3):
            fmt = assign[i].fmt
            y = ref.gemm(h, ps[2 * i], fmt=fmt) + ref.round_format(ps[2 * i + 1], fmt)
            h = jnp.tanh(y) if i < 2 else y
        np.testing.assert_allclose(np.array(out), np.array(h), rtol=1e-6, atol=1e-6)

    def test_grads_finite(self):
        sizes = [4, 64, 64, 2]
        ps = mlp_params(sizes, seed=1)
        assign = precision.assign_mlp(sizes, "mixed")

        def loss(p):
            return jnp.sum(nets.mlp_forward(p, rand((6, 4), seed=2), assign) ** 2)

        grads = jax.grad(loss)(ps)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in grads)


class TestAssignment:
    def test_fp32_mode_all_ps(self):
        a = precision.assign_mlp([4, 64, 64, 2], "fp32")
        assert all(p.component == "PS" and p.fmt == "fp32" for p in a)

    def test_mixed_small_mlp_all_pl(self):
        """CartPole's (64,64) MLP is low-FLOPs -> PL/fp16 everywhere
        (Fig 15 / §V-B: low-FLOP nets stay on the PL)."""
        a = precision.assign_mlp([4, 64, 64, 2], "mixed")
        assert all(p.component == "PL" and p.fmt == "fp16" for p in a)

    def test_mixed_large_mlp_uses_aie(self):
        """DDPG's (400,300) trunk crosses the FLOPs threshold -> AIE/bf16
        for the fat layers, PL for the skinny head."""
        a = precision.assign_mlp([8, 400, 300, 2], "mixed")
        # the 400x300 trunk crosses the threshold; the skinny 8x400 input
        # layer and 300x2 head stay on the PL (batch-independent rule)
        assert a[1].component == "AIE" and a[1].fmt == "bf16"
        assert a[2].component == "PL"

    def test_scaled_flag(self):
        a = precision.assign_mlp([4, 64, 64, 2], "mixed")
        assert precision.any_scaled(a)
        b = precision.assign_mlp([4, 64, 64, 2], "bf16")
        assert not precision.any_scaled(b)

    def test_unknown_mode_raises(self):
        with pytest.raises(ValueError):
            precision.assign_mlp([4, 8, 2], "int4")
        with pytest.raises(ValueError):
            precision.assign_conv([100], "int4")


class TestConvNet:
    CONV = [(8, 4, 2), (16, 3, 1)]

    def test_spec_dims(self):
        shapes, flat, flops = nets.conv_net_spec(12, 4, self.CONV, [128, 4])
        # 12x12 -k4s2-> 5x5x8 -k3s1-> 3x3x16 = 144
        assert flat == 144
        assert shapes[0] == (4, 4, 4, 8)
        assert shapes[-2] == (128, 4)
        assert len(flops) == 4

    def test_nature_dqn_spec_matches_table3(self):
        """Full-shape Breakout trunk (Table III): conv dims 84->20->9->7,
        flatten 3136, FC 512 -> 4."""
        shapes, flat, flops = nets.conv_net_spec(
            84, 4, [(32, 8, 4), (64, 4, 2), (64, 3, 1)], [512, 4]
        )
        assert flat == 3136
        assert shapes[-4] == (3136, 512)
        assert shapes[-2] == (512, 4)

    def test_forward_shapes(self):
        shapes, flat, flops = nets.conv_net_spec(12, 4, self.CONV, [128, 4])
        ps = [rand(sh, seed=i) for i, sh in enumerate(shapes)]
        assign = precision.assign_conv(flops, "mixed")
        x = rand((3, 12, 12, 4), seed=100)
        out = nets.conv_forward(ps, x, self.CONV, assign)
        assert out.shape == (3, 4)

    def test_conv_grads_finite(self):
        shapes, flat, flops = nets.conv_net_spec(12, 4, self.CONV, [128, 4])
        ps = [rand(sh, seed=i + 50) for i, sh in enumerate(shapes)]
        assign = precision.assign_conv(flops, "bf16")

        def loss(p):
            x = rand((2, 12, 12, 4), seed=7)
            return jnp.sum(nets.conv_forward(p, x, self.CONV, assign) ** 2)

        grads = jax.grad(loss)(ps)
        assert all(bool(jnp.all(jnp.isfinite(g))) for g in grads)


def test_init_scale():
    assert np.isclose(nets.init_scale((64, 64)), np.sqrt(6 / 64))
    assert np.isclose(nets.init_scale((4, 4, 4, 8)), np.sqrt(6 / 64))
