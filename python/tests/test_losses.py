"""DRL objectives: closed-form checks and gradient-direction sanity."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import losses


def test_dqn_loss_closed_form():
    q = jnp.array([[1.0, 2.0], [3.0, 0.0]], jnp.float32)
    qt_max = jnp.array([1.0, 2.0], jnp.float32)
    a = jnp.array([1, 0], jnp.int32)
    r = jnp.array([0.5, 1.0], jnp.float32)
    done = jnp.array([0.0, 1.0], jnp.float32)
    # y = [0.5 + 0.9*1, 1.0]; q_sa = [2, 3]; mse = ((1.4-2)^2 + (1-3)^2)/2
    out = float(losses.dqn_loss(q, qt_max, a, r, done, gamma=0.9))
    assert np.isclose(out, ((1.4 - 2.0) ** 2 + (1.0 - 3.0) ** 2) / 2, atol=1e-6)


def test_dqn_loss_target_not_differentiated():
    """stop_gradient on y: d loss / d qt_max must be zero."""
    def f(qt_max):
        q = jnp.array([[1.0, 2.0]], jnp.float32)
        return losses.dqn_loss(
            q, qt_max, jnp.array([0], jnp.int32), jnp.ones(1), jnp.zeros(1), 0.9
        )

    g = jax.grad(f)(jnp.array([1.0], jnp.float32))
    np.testing.assert_array_equal(np.array(g), 0.0)


def test_ddpg_losses():
    q = jnp.array([1.0, 2.0], jnp.float32)
    qn = jnp.array([0.5, 0.5], jnp.float32)
    r = jnp.array([1.0, 0.0], jnp.float32)
    done = jnp.array([0.0, 0.0], jnp.float32)
    y = 1.0 + 0.99 * 0.5
    expect = ((y - 1.0) ** 2 + (0.99 * 0.5 - 2.0) ** 2) / 2
    assert np.isclose(float(losses.ddpg_critic_loss(q, qn, r, done, 0.99)), expect, atol=1e-6)
    assert float(losses.ddpg_actor_loss(q)) == -1.5


def test_gaussian_logp_standard_normal():
    a = jnp.zeros((1, 1))
    mean = jnp.zeros((1, 1))
    log_std = jnp.zeros(1)
    out = float(losses.gaussian_logp(a, mean, log_std)[0])
    assert np.isclose(out, -0.5 * losses.LOG_2PI, atol=1e-6)


def test_gaussian_entropy_monotone_in_std():
    lo = float(losses.gaussian_entropy(jnp.array([-1.0])))
    hi = float(losses.gaussian_entropy(jnp.array([1.0])))
    assert hi > lo


def test_categorical_logp_softmax():
    logits = jnp.array([[1.0, 2.0, 3.0]], jnp.float32)
    a = jnp.array([2], jnp.int32)
    p = np.exp(3.0) / np.sum(np.exp([1.0, 2.0, 3.0]))
    assert np.isclose(float(losses.categorical_logp(logits, a)[0]), np.log(p), atol=1e-6)


def test_categorical_entropy_uniform_max():
    uni = float(losses.categorical_entropy(jnp.zeros((1, 4))))
    peaked = float(losses.categorical_entropy(jnp.array([[10.0, 0, 0, 0]])))
    assert np.isclose(uni, np.log(4), atol=1e-5)
    assert peaked < uni


def test_ppo_clip_blocks_large_ratio_gain():
    """With adv>0, pushing logp far above logp_old must stop improving the
    clipped objective."""
    adv = jnp.ones(1)
    v = jnp.zeros(1)
    ret = jnp.zeros(1)

    def surrogate(delta):
        return -float(
            losses.ppo_loss(
                jnp.array([delta]), jnp.zeros(1), adv, v, ret, entropy=0.0, ent_coef=0.0, vf_coef=0.0
            )
        )

    assert np.isclose(surrogate(np.log(1.2)), surrogate(2.0), atol=1e-6)
    assert surrogate(0.1) > surrogate(0.0)


def test_a2c_loss_direction():
    """Increasing logp of positive-advantage actions lowers the loss."""
    adv = jnp.ones(2)
    v = jnp.zeros(2)
    ret = jnp.zeros(2)
    lo = float(losses.a2c_loss(jnp.zeros(2), adv, v, ret, entropy=0.0, ent_coef=0.0))
    hi = float(losses.a2c_loss(jnp.ones(2), adv, v, ret, entropy=0.0, ent_coef=0.0))
    assert hi < lo
