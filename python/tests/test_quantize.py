"""Precision-emulation kernels vs bit-level references (paper Fig 3 /
Table II semantics)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.quantize import quantize, quantize_bf16, quantize_fp16

finite_f32 = st.floats(allow_nan=False, allow_infinity=False, width=32)


@settings(max_examples=60, deadline=None)
@given(st.lists(finite_f32, min_size=1, max_size=64))
def test_bf16_roundtrip_matches_bit_twiddle(vals):
    """astype-based kernel == independent integer RNE implementation."""
    x = np.array(vals, np.float32)
    out = np.array(quantize_bf16(jnp.array(x)))
    expect = np.array(ref.round_bf16_bits(x))
    np.testing.assert_array_equal(out, expect)


def test_bf16_exponent_range_preserved():
    """BF16 keeps FP32's exponent range (Table II): huge/tiny magnitudes
    survive the round-trip finite/nonzero."""
    x = jnp.array([1e38, -1e38, 1e-38, -1e-38], jnp.float32)
    out = np.array(quantize_bf16(x))
    assert np.all(np.isfinite(out))
    assert np.all(out[:2] != 0) and np.all(out[2:] != 0)


def test_fp16_narrow_range():
    """FP16 overflows beyond 65504 and flushes tiny values (paper: why PL
    nodes need loss scaling)."""
    x = jnp.array([1e6, -1e6, 1e-9], jnp.float32)
    out = np.array(quantize_fp16(x))
    assert np.isinf(out[0]) and np.isinf(out[1])
    assert out[2] == 0.0


def test_fp16_representable_exact():
    x = jnp.array([1.0, -2.5, 0.09997558593750001, 65504.0], jnp.float32)
    out = np.array(quantize_fp16(x))
    expect = x.astype(jnp.float16).astype(jnp.float32)
    np.testing.assert_array_equal(out, np.array(expect))


def test_quantize_dispatch_and_identity():
    x = jnp.array([[1.2345678]], jnp.float32)
    assert quantize(x, "fp32") is x
    assert float(quantize(x, "bf16")[0, 0]) != float(x[0, 0])
    with pytest.raises(ValueError):
        quantize(x, "int8")


def test_quantize_scalar_and_nd():
    s = quantize(jnp.float32(1.7), "bf16")
    assert s.shape == ()
    t = quantize(jnp.ones((2, 3, 4), jnp.float32) * 1.1, "fp16")
    assert t.shape == (2, 3, 4)


def test_quantize_grad_is_rounded_cotangent():
    """VJP = cotangent rounded to the same format (backward runs on the
    same component as forward under per-layer partitioning)."""
    x = jnp.array([1.0, 2.0, 3.0], jnp.float32)
    g_in = np.array([1.0001, -2.5, 1e-9], np.float32)

    def f(v):
        return jnp.sum(quantize_fp16(v) * jnp.array(g_in))

    g = np.array(jax.grad(f)(x))
    expect = np.array(jnp.array(g_in).astype(jnp.float16).astype(jnp.float32))
    np.testing.assert_array_equal(g, expect)


def test_nan_propagates():
    x = jnp.array([np.nan], jnp.float32)
    assert np.isnan(np.array(quantize_bf16(x))[0])
    assert np.isnan(np.array(quantize_fp16(x))[0])
    assert np.isnan(np.array(ref.round_bf16_bits(np.array([np.nan], np.float32)))[0])
