"""AOT pipeline: manifest consistency + HLO text parseability probes."""

import json
import os

import jax
import jax.numpy as jnp
import pytest

from compile import aot, combos, trainstep

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

needs_artifacts = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def test_to_hlo_text_produces_hlo_module():
    def fn(x):
        return (x * 2.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((2, 2), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule"), text[:50]
    assert "ENTRY" in text


def test_artifact_list_complete():
    names = [name for name, *_ in aot.artifact_list()]
    assert len(names) == len(set(names))
    expected = len(combos.COMBOS) * len(combos.MODES) * 2 + len(combos.GEMM_SIZES) * len(
        combos.GEMM_FMTS
    )
    assert len(names) == expected
    assert "dqn_cartpole_mixed_train" in names
    assert "gemm_256_bf16" in names


def test_spec_list_flattening_order():
    """Rust relies on pytree flattening == positional list order."""
    args = ([jax.ShapeDtypeStruct((2, 3), jnp.float32), jax.ShapeDtypeStruct((3,), jnp.float32)],
            jax.ShapeDtypeStruct((), jnp.float32))
    specs = aot._spec_list(args)
    assert specs == [
        {"shape": [2, 3], "dtype": "float32"},
        {"shape": [3], "dtype": "float32"},
        {"shape": [], "dtype": "float32"},
    ]


@needs_artifacts
def test_manifest_matches_builders():
    with open(MANIFEST) as f:
        manifest = json.load(f)
    arts = manifest["artifacts"]
    for name, fn, args, meta in aot.artifact_list():
        assert name in arts, f"missing artifact {name}"
        entry = arts[name]
        assert entry["inputs"] == aot._spec_list(args)
        assert os.path.exists(os.path.join(ART_DIR, entry["file"]))


@needs_artifacts
def test_hlo_files_look_like_hlo():
    with open(MANIFEST) as f:
        manifest = json.load(f)
    for name, entry in manifest["artifacts"].items():
        path = os.path.join(ART_DIR, entry["file"])
        with open(path) as f:
            head = f.read(64)
        assert head.startswith("HloModule"), f"{name}: {head!r}"


@needs_artifacts
def test_train_artifact_io_counts():
    """Every train artifact ends with loss_scale input and has found_inf
    as its last output (the rust LossScaler contract)."""
    with open(MANIFEST) as f:
        manifest = json.load(f)
    for name, entry in manifest["artifacts"].items():
        if entry["meta"].get("kind") != "train":
            continue
        assert entry["inputs"][-1]["shape"] == []
        assert entry["outputs"][-1]["shape"] == []
        assert entry["meta"]["aux_outputs"][-1] == "found_inf"
