import os
import sys

# Tests run from python/ (see Makefile); make `import compile` work from
# anywhere.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
