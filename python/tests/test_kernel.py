"""L1 GEMM kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps shapes and formats; every case asserts allclose against
ref.gemm (same operand rounding, f32 accumulate).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm import (
    gemm,
    matmul,
    mxu_alignment,
    vmem_footprint_bytes,
)

FMTS = ["fp32", "bf16", "fp16"]


def rand(shape, seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return (rng.standard_normal(shape) * scale).astype(np.float32)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize(
    "m,k,n",
    [(1, 4, 2), (4, 64, 64), (64, 64, 2), (7, 13, 5), (128, 128, 128), (130, 70, 33)],
)
def test_gemm_matches_ref(fmt, m, k, n):
    x, w = rand((m, k), seed=m * 1000 + k), rand((k, n), seed=n)
    out = gemm(jnp.array(x), jnp.array(w), fmt=fmt)
    expect = ref.gemm(x, w, fmt=fmt)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=1e-6, atol=1e-6)


@settings(max_examples=40, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    fmt=st.sampled_from(FMTS),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_hypothesis_shapes(m, k, n, fmt, seed):
    x, w = rand((m, k), seed=seed), rand((k, n), seed=seed + 1)
    out = gemm(jnp.array(x), jnp.array(w), fmt=fmt)
    expect = ref.gemm(x, w, fmt=fmt)
    np.testing.assert_allclose(np.array(out), np.array(expect), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("fmt", FMTS)
@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (32, 16, 8), (128, 128, 128)])
def test_gemm_block_shape_invariance(fmt, bm, bn, bk):
    """Tiling must never change the numbers (padding is sliced away and
    K-blocking only reorders f32 additions of identical products when the
    pad is zero).  The §Perf L1 sweep relies on this."""
    x, w = rand((48, 40), seed=3), rand((40, 24), seed=4)
    base = gemm(jnp.array(x), jnp.array(w), fmt=fmt)
    tiled = gemm(jnp.array(x), jnp.array(w), fmt=fmt, bm=bm, bn=bn, bk=bk)
    # K-split changes f32 summation order; bound stays tight.
    np.testing.assert_allclose(np.array(base), np.array(tiled), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("fmt", FMTS)
def test_matmul_vjp_matches_ref(fmt):
    x, w = rand((9, 17), seed=10), rand((17, 6), seed=11)
    g = rand((9, 6), seed=12)

    def f(a, b):
        return jnp.sum(matmul(a, b, fmt) * jnp.array(g))

    dx, dw = jax.grad(f, argnums=(0, 1))(jnp.array(x), jnp.array(w))
    rdx, rdw = ref.matmul_grads(x, w, g, fmt=fmt)
    np.testing.assert_allclose(np.array(dx), np.array(rdx), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.array(dw), np.array(rdw), rtol=1e-6, atol=1e-6)


def test_gemm_rejects_bad_shapes():
    with pytest.raises(ValueError):
        gemm(jnp.zeros((2, 3)), jnp.zeros((4, 5)))


def test_bf16_gemm_differs_from_fp32_when_it_should():
    """Sanity: the precision emulation actually loses precision."""
    x, w = rand((32, 32), seed=5), rand((32, 32), seed=6)
    out32 = np.array(gemm(jnp.array(x), jnp.array(w), fmt="fp32"))
    out16 = np.array(gemm(jnp.array(x), jnp.array(w), fmt="bf16"))
    assert not np.allclose(out32, out16, rtol=1e-7, atol=0)
    # ... but only by a bf16-sized relative error.
    np.testing.assert_allclose(out16, out32, rtol=3e-2, atol=3e-2)


def test_fp16_gemm_saturates_to_inf():
    """FP16's narrow exponent range overflows where bf16 does not — the
    very motivation for AP-DRL's format coordination (Table II)."""
    x = np.full((4, 4), 1e6, np.float32)
    w = np.ones((4, 4), dtype=np.float32)
    out16 = np.array(gemm(jnp.array(x), jnp.array(w), fmt="fp16"))
    outbf = np.array(gemm(jnp.array(x), jnp.array(w), fmt="bf16"))
    # 1e6 saturates to +inf in fp16; inf · 1 accumulates to inf.
    assert not np.any(np.isfinite(out16))
    assert np.all(np.isfinite(outbf))


def test_vmem_footprint_and_alignment_helpers():
    assert vmem_footprint_bytes(128, 128, 128, "bf16") == 128 * 128 * 2 * 2 + 128 * 128 * 4
    assert vmem_footprint_bytes(128, 128, 128, "fp32") == 3 * 128 * 128 * 4
    assert mxu_alignment(128, 128, 128) == 1.0
    assert mxu_alignment(64, 128, 128) == 0.5
