"""Executable train-step semantics — the same functions that get lowered
into artifacts, run eagerly on small synthetic batches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import combos, nets, optim, trainstep


def init_params(shapes, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    return [jnp.array((rng.standard_normal(sh) * scale).astype(np.float32)) for sh in shapes]


def shapes_of(args_entry):
    return [tuple(a.shape) for a in args_entry]


def make_inputs(args, seed=0, zero_opt=True):
    """Concrete arrays for a builder's ShapeDtypeStruct example args.

    Optimizer-state arguments (the lists matching ``init_opt_state``'s
    ``2k+1`` layout, i.e. any list argument ending in a scalar) are zeroed —
    a random Adam step-count makes no sense.
    """
    rng = np.random.default_rng(seed)

    def concrete(a):
        if a.dtype == jnp.int32:
            return jnp.array(rng.integers(0, 2, a.shape), jnp.int32)
        return jnp.array((rng.standard_normal(a.shape) * 0.1).astype(np.float32))

    out = []
    for arg in args:
        if (
            zero_opt
            and isinstance(arg, list)
            and len(arg) >= 3
            and arg[-1].shape == ()
            and len(arg) % 2 == 1
        ):
            out.append([jnp.zeros(a.shape, a.dtype) for a in arg])
        else:
            out.append(jax.tree_util.tree_map(concrete, arg))
    return tuple(out)


class TestDQN:
    CFG = combos.COMBOS["dqn_cartpole"]

    @pytest.mark.parametrize("mode", ["fp32", "mixed", "bf16"])
    def test_step_runs_and_updates(self, mode):
        fn, args, meta = trainstep.build(self.CFG, "train", mode)
        params, tparams, opt, s, a, r, s2, done, _ = make_inputs(args, seed=1)
        scale = jnp.float32(1024.0 if meta["scaled"] else 1.0)
        new_params, new_opt, loss, found_inf = fn(
            params, tparams, opt, s, a, r, s2, done, scale
        )
        assert float(found_inf) == 0.0
        assert np.isfinite(float(loss))
        changed = any(
            not np.array_equal(np.array(p0), np.array(p1))
            for p0, p1 in zip(params, new_params)
        )
        assert changed
        assert float(new_opt[-1]) == 1.0

    def test_loss_decreases_over_steps(self):
        """Few steps on a fixed batch must reduce the TD loss (fp32)."""
        fn, args, meta = trainstep.build(self.CFG, "train", "fp32")
        jit_fn = jax.jit(fn)
        params, tparams, opt, s, a, r, s2, done, scale = make_inputs(args, seed=2)
        scale = jnp.float32(1.0)
        first = None
        for i in range(30):
            params, opt, loss, found_inf = jit_fn(
                params, tparams, opt, s, a, r, s2, done, scale
            )
            if first is None:
                first = float(loss)
        assert float(loss) < first

    def test_act_matches_forward(self):
        fn, args, meta = trainstep.build(self.CFG, "act", "fp32")
        params, s = make_inputs(args, seed=3)
        q = fn(params, s)
        assert q.shape == (1, self.CFG["act_dim"])

    def test_scaled_loss_invariance_fp32(self):
        """In fp32 the reported (unscaled) loss must not depend on the
        scale input."""
        fn, args, _ = trainstep.build(self.CFG, "train", "fp32")
        inputs = make_inputs(args, seed=4)
        params, tparams, opt, s, a, r, s2, done, _ = inputs
        _, _, loss1, _ = fn(params, tparams, opt, s, a, r, s2, done, jnp.float32(1.0))
        _, _, loss2, _ = fn(params, tparams, opt, s, a, r, s2, done, jnp.float32(4096.0))
        np.testing.assert_allclose(float(loss1), float(loss2), rtol=1e-4)

    def test_overflow_sets_found_inf_and_skips(self):
        """A scale large enough to overflow f32 must set found_inf and
        leave params untouched."""
        fn, args, _ = trainstep.build(self.CFG, "train", "fp32")
        params, tparams, opt, s, a, r, s2, done, _ = make_inputs(args, seed=5)
        r_huge = r + 1e25
        new_params, new_opt, loss, found_inf = fn(
            params, tparams, opt, s, a, r_huge, s2, done, jnp.float32(1e30)
        )
        assert float(found_inf) == 1.0
        for p0, p1 in zip(params, new_params):
            np.testing.assert_array_equal(np.array(p0), np.array(p1))


class TestDDPG:
    CFG = combos.COMBOS["ddpg_mntncar"]  # smallest DDPG net

    def test_step_runs(self):
        fn, args, meta = trainstep.build(self.CFG, "train", "mixed")
        inputs = make_inputs(args, seed=6)
        out = fn(*inputs[:-1], jnp.float32(256.0))
        (na, nc, nta, ntc, noa, noc, closs, aloss, found_inf) = out
        assert float(found_inf) == 0.0
        assert np.isfinite(float(closs)) and np.isfinite(float(aloss))
        assert len(na) == len(inputs[0])

    def test_soft_update_moves_targets(self):
        fn, args, meta = trainstep.build(self.CFG, "train", "fp32")
        inputs = make_inputs(args, seed=7)
        out = fn(*inputs[:-1], jnp.float32(1.0))
        t_actor_before, t_actor_after = inputs[2], out[2]
        moved = any(
            not np.array_equal(np.array(a), np.array(b))
            for a, b in zip(t_actor_before, t_actor_after)
        )
        assert moved

    def test_act_bounded(self):
        fn, args, _ = trainstep.build(self.CFG, "act", "fp32")
        params, s = make_inputs(args, seed=8)
        a = fn(params, 10.0 * s)
        assert np.all(np.abs(np.array(a)) <= 1.0)


class TestA2C:
    CFG = combos.COMBOS["a2c_invpend"]

    def test_step_runs(self):
        fn, args, meta = trainstep.build(self.CFG, "train", "mixed")
        train, opt, s, a, ret, adv, _ = make_inputs(args, seed=9)
        new_train, new_opt, loss, found_inf = fn(
            train, opt, s, a, ret, adv, jnp.float32(512.0)
        )
        assert float(found_inf) == 0.0
        assert np.isfinite(float(loss))

    def test_act_outputs(self):
        fn, args, _ = trainstep.build(self.CFG, "act", "fp32")
        train, s = make_inputs(args, seed=10)
        mean, log_std, value = fn(train, s)
        assert mean.shape == (1, 1) and log_std.shape == (1, 1) and value.shape == (1,)


class TestConv:
    def test_dqn_conv_step(self):
        cfg = combos.COMBOS["dqn_breakout_mini"]
        fn, args, meta = trainstep.build(cfg, "train", "mixed")
        params, tparams, opt, s, a, r, s2, done, _ = make_inputs(args, seed=11)
        new_params, new_opt, loss, found_inf = fn(
            params, tparams, opt, s, a, r, s2, done, jnp.float32(256.0)
        )
        assert float(found_inf) == 0.0
        assert np.isfinite(float(loss))

    def test_ppo_conv_step_and_act(self):
        cfg = combos.COMBOS["ppo_mspacman_mini"]
        fn, args, meta = trainstep.build(cfg, "train", "fp32")
        params, opt, s, a, logp_old, ret, adv, _ = make_inputs(args, seed=12)
        new_params, new_opt, loss, found_inf = fn(
            params, opt, s, a, logp_old, ret, adv, jnp.float32(1.0)
        )
        assert np.isfinite(float(loss))
        act_fn, act_args, _ = trainstep.build(cfg, "act", "fp32")
        p2, s1 = make_inputs(act_args, seed=13)
        logits, value = act_fn(p2, s1)
        assert logits.shape == (1, cfg["act_dim"]) and value.shape == (1,)


def test_every_combo_builds_every_mode():
    for name, cfg in combos.COMBOS.items():
        for mode in combos.MODES:
            for kind in ("train", "act"):
                fn, args, meta = trainstep.build(cfg, kind, mode)
                jax.eval_shape(fn, *args)  # must trace cleanly
                assert meta["mode"] == mode and meta["kind"] == kind
