"""Adam + loss-scaling mechanics (paper Alg. 1 / Fig 9 per-step dataflow)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim
from compile.kernels import ref


def params_pair():
    w = jnp.array([[1.0, -2.0], [0.5, 3.0]], jnp.float32)
    b = jnp.array([0.1, -0.1], jnp.float32)
    return [w, b]


def test_init_opt_state_layout():
    ps = params_pair()
    st = optim.init_opt_state(ps)
    assert len(st) == 2 * len(ps) + 1
    assert st[-1].shape == ()
    assert all(bool(jnp.all(s == 0)) for s in st[:-1])


def test_unscale_and_check_clean():
    grads = [jnp.ones((2, 2)) * 4.0]
    un, found = optim.unscale_and_check(grads, jnp.float32(4.0))
    np.testing.assert_allclose(np.array(un[0]), 1.0)
    assert float(found) == 0.0


@pytest.mark.parametrize("bad", [np.inf, -np.inf, np.nan])
def test_unscale_and_check_flags_nonfinite(bad):
    grads = [jnp.ones(3), jnp.array([1.0, bad, 2.0], jnp.float32)]
    _, found = optim.unscale_and_check(grads, jnp.float32(2.0))
    assert float(found) == 1.0


def reference_adam(params, grads, m, v, t, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = t + 1
    out_p, out_m, out_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        out_p.append(p - lr * mhat / (np.sqrt(vhat) + eps))
        out_m.append(mi)
        out_v.append(vi)
    return out_p, out_m, out_v, t


def test_adam_matches_reference_over_steps():
    ps = [np.array([[1.0, -2.0]], np.float32), np.array([0.5], np.float32)]
    m = [np.zeros_like(p) for p in ps]
    v = [np.zeros_like(p) for p in ps]
    t = 0
    jps = [jnp.array(p) for p in ps]
    jst = optim.init_opt_state(jps)
    for step in range(5):
        grads = [np.full_like(p, 0.1 * (step + 1)) for p in ps]
        ps, m, v, t = reference_adam(ps, grads, m, v, t, lr=1e-2)
        jps, jst = optim.adam_update(
            jps, [jnp.array(g) for g in grads], jst, jnp.float32(0.0), lr=1e-2
        )
    for a, b in zip(jps, ps):
        np.testing.assert_allclose(np.array(a), b, rtol=1e-5, atol=1e-7)
    assert float(jst[-1]) == 5.0


def test_adam_skip_on_found_inf():
    """found_inf=1 must pass params, moments AND step count through
    unchanged (Fig 9 'conditional update skipping')."""
    ps = params_pair()
    st = optim.init_opt_state(ps)
    grads = [jnp.full_like(p, 1e9) for p in ps]
    new_ps, new_st = optim.adam_update(ps, grads, st, jnp.float32(1.0), lr=1e-3)
    for a, b in zip(new_ps, ps):
        np.testing.assert_array_equal(np.array(a), np.array(b))
    for a, b in zip(new_st, st):
        np.testing.assert_array_equal(np.array(a), np.array(b))


def test_adam_bf16_mask_stores_bf16_weights():
    """AIE tensors carry no master copy: stored value must be
    bf16-representable after the update (Table II)."""
    ps = params_pair()
    st = optim.init_opt_state(ps)
    grads = [jnp.full_like(p, 0.333333) for p in ps]
    new_ps, _ = optim.adam_update(
        ps, grads, st, jnp.float32(0.0), lr=1e-3, bf16_mask=[True, False]
    )
    w = np.array(new_ps[0])
    np.testing.assert_array_equal(w, np.array(ref.round_bf16_bits(w)))
    # the un-masked tensor is NOT bf16-rounded
    b = np.array(new_ps[1])
    assert not np.array_equal(b, np.array(ref.round_bf16_bits(b)))


def test_soft_update():
    tp = [jnp.zeros(3)]
    p = [jnp.ones(3)]
    out = optim.soft_update(tp, p, tau=0.1)
    np.testing.assert_allclose(np.array(out[0]), 0.1)
