//! Remote planning sweep: drive a Table III batch-ladder grid through a
//! long-lived `apdrl serve` daemon instead of the in-process planner,
//! then read the daemon's telemetry (`stats` verb).
//!
//! Point it at a running server:
//!
//! ```bash
//! cargo run --release -- serve --addr 127.0.0.1:7040 &
//! APDRL_SERVER=127.0.0.1:7040 cargo run --release --example remote_sweep
//! ```
//!
//! Without `APDRL_SERVER` the example is self-contained: it boots a
//! daemon on an ephemeral loopback port in a background thread, sweeps
//! against it, and shuts it down — the full client/server round trip in
//! one process.

use anyhow::Result;

use apdrl::server::{RemotePlanner, Server, ENV_ADDR};
use apdrl::util::json::Json;

fn main() -> Result<()> {
    // A server from the environment, or a self-booted ephemeral one.
    let (addr, local_daemon) = match std::env::var(ENV_ADDR) {
        Ok(addr) if !addr.is_empty() => (addr, None),
        _ => {
            let server = Server::bind("127.0.0.1:0", 2)?;
            let addr = server.local_addr()?.to_string();
            println!("(no {ENV_ADDR} set — booted an ephemeral daemon on {addr})\n");
            (addr, Some(std::thread::spawn(move || server.run())))
        }
    };

    let combos: Vec<String> =
        ["dqn_cartpole", "a2c_invpend", "ddpg_lunar", "ddpg_mntncar"]
            .iter()
            .map(|s| s.to_string())
            .collect();
    let batches = [64usize, 256, 1024];

    let mut client = RemotePlanner::connect(&addr)?;
    let t0 = std::time::Instant::now();
    let plans = client.sweep(&combos, &batches, true)?;
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;

    println!("remote sweep of {} points via {addr} ({cold_ms:.0} ms):\n", plans.len());
    println!(
        "{:>14} | {:>5} | {:>12} | {:>7} | {:>8} | origin",
        "combo", "batch", "makespan µs", "AIE MM", "steps/s"
    );
    for p in &plans {
        println!(
            "{:>14} | {:>5} | {:>12.1} | {:>3} of {:>2} | {:>8.0} | {}",
            p.combo,
            p.batch,
            p.makespan_us,
            p.aie_mm_nodes,
            p.mm_nodes,
            p.throughput(),
            if p.cache_hit { "cache".to_string() } else { format!("{} explored", p.explored) },
        );
    }

    // The same grid again: every point is now a shared-cache hit.
    let t1 = std::time::Instant::now();
    let replans = client.sweep(&combos, &batches, true)?;
    let warm_ms = t1.elapsed().as_secs_f64() * 1e3;
    println!(
        "\nre-sweep: {:.1} ms ({}/{} cache hits — every client shares the daemon's cache)",
        warm_ms,
        replans.iter().filter(|p| p.cache_hit).count(),
        replans.len()
    );

    let stats = client.stats()?;
    let pick = |path: &[&str]| -> f64 {
        let mut v = Some(&stats);
        for k in path {
            v = v.and_then(|j| j.get(k));
        }
        v.and_then(Json::as_f64).unwrap_or(0.0)
    };
    println!(
        "daemon stats: {} requests, {} plans served ({} from cache), cache hit rate {:.0}%",
        pick(&["requests"]),
        pick(&["plans_served"]),
        pick(&["plans_from_cache"]),
        pick(&["cache", "hit_rate"]) * 100.0
    );

    if let Some(handle) = local_daemon {
        client.shutdown()?;
        handle.join().expect("daemon thread")?;
        println!("ephemeral daemon stopped.");
    }
    Ok(())
}
