//! Remote + federated planning sweep: drive a Table III batch-ladder
//! grid through long-lived `apdrl serve` daemons via the one `Planner`
//! API, watch the plan-key sharding spread the grid across hosts, then
//! kill a daemon and watch fail-over finish the sweep on the survivor.
//!
//! Point it at running servers (one, or a comma-separated federation):
//!
//! ```bash
//! cargo run --release -- serve --addr 127.0.0.1:7040 &
//! cargo run --release -- serve --addr 127.0.0.1:7041 &
//! APDRL_SERVER=127.0.0.1:7040,127.0.0.1:7041 cargo run --release --example remote_sweep
//! ```
//!
//! Without `APDRL_SERVER` the example is self-contained: it boots two
//! daemons on ephemeral loopback ports in background threads, sweeps a
//! federation of both, shuts one down mid-demo to exercise the fail-over
//! path, and stops the survivor — the full multi-daemon round trip in
//! one process.

use anyhow::Result;

use apdrl::coordinator::{PlanOutcome, PlanRequest, Planner, Provenance};
use apdrl::server::{
    parse_host_list, FederatedPlanner, RemotePlanner, Server, ENV_ADDR,
};
use apdrl::util::json::Json;

fn print_plans(plans: &[PlanOutcome]) {
    println!(
        "{:>14} | {:>5} | {:>12} | {:>7} | {:>8} | origin",
        "combo", "batch", "makespan µs", "AIE MM", "steps/s"
    );
    for p in plans {
        println!(
            "{:>14} | {:>5} | {:>12.1} | {:>3} of {:>2} | {:>8.0} | {}{}",
            p.combo,
            p.batch,
            p.makespan_us,
            p.aie_mm_nodes,
            p.mm_nodes,
            p.throughput(),
            p.provenance,
            if p.cache_hit { " (cache)" } else { "" },
        );
    }
}

fn shard_histogram(plans: &[PlanOutcome], hosts: usize) -> Vec<usize> {
    let mut counts = vec![0usize; hosts];
    for p in plans {
        if let Provenance::Federated { shard } = p.provenance {
            counts[shard] += 1;
        }
    }
    counts
}

fn main() -> Result<()> {
    // Servers from the environment, or two self-booted ephemeral ones.
    let mut daemons = Vec::new();
    let hosts: Vec<String> = match std::env::var(ENV_ADDR) {
        Ok(spec) if !spec.is_empty() => parse_host_list(&spec),
        _ => {
            let mut hosts = Vec::new();
            for _ in 0..2 {
                let server = Server::bind("127.0.0.1:0", 2)?;
                hosts.push(server.local_addr()?.to_string());
                daemons.push(std::thread::spawn(move || server.run()));
            }
            println!(
                "(no {ENV_ADDR} set — booted ephemeral daemons on {})\n",
                hosts.join(" and ")
            );
            hosts
        }
    };

    let planner = FederatedPlanner::connect(&hosts)?;
    let combos = ["dqn_cartpole", "a2c_invpend", "ddpg_lunar", "ddpg_mntncar"];
    let batches = [64usize, 256, 1024];
    let requests: Vec<PlanRequest> = combos
        .iter()
        .flat_map(|name| {
            batches
                .iter()
                .map(move |&bs| PlanRequest::named(name).expect("registry combo").with_batch(bs))
        })
        .collect();

    let t0 = std::time::Instant::now();
    let plans = planner.plan_many(&requests)?;
    let cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    println!(
        "federated sweep of {} points [{}] in {cold_ms:.0} ms:\n",
        plans.len(),
        planner.describe()
    );
    print_plans(&plans);
    let counts = shard_histogram(&plans, planner.hosts().len());
    println!(
        "\nplan-key sharding: {}",
        counts
            .iter()
            .enumerate()
            .map(|(i, n)| format!("host {i} served {n}"))
            .collect::<Vec<_>>()
            .join(", ")
    );

    // The same grid again: every point is a shared-cache hit on its
    // shard's daemon (same key → same shard → warm cache).
    let t1 = std::time::Instant::now();
    let replans = planner.plan_many(&requests)?;
    println!(
        "re-sweep: {:.1} ms ({}/{} daemon-cache hits — sharding is cache-affine)",
        t1.elapsed().as_secs_f64() * 1e3,
        replans.iter().filter(|p| p.cache_hit).count(),
        replans.len()
    );

    // Per-daemon telemetry via the stats verb.
    for (i, host) in planner.hosts().iter().enumerate() {
        if let Ok(stats) = RemotePlanner::connect(host).and_then(|c| c.stats()) {
            let served = stats.get("plans_served").and_then(Json::as_f64).unwrap_or(0.0);
            let hits = stats.get("plans_from_cache").and_then(Json::as_f64).unwrap_or(0.0);
            println!("host {i} ({host}): {served} plans served, {hits} from cache");
        }
    }

    if daemons.len() == 2 {
        // Fail-over demo: stop host 0, then sweep again — the shards that
        // lived there retry on host 1 and the sweep still completes.
        println!("\nstopping host 0 to exercise fail-over...");
        RemotePlanner::connect(&planner.hosts()[0])?.shutdown()?;
        daemons.remove(0).join().expect("daemon thread")?;
        let t2 = std::time::Instant::now();
        let failover = planner.plan_many(&requests)?;
        let survivors = shard_histogram(&failover, planner.hosts().len());
        println!(
            "fail-over sweep: {} points in {:.1} ms, all served by host 1 \
             (shard counts: {survivors:?})",
            failover.len(),
            t2.elapsed().as_secs_f64() * 1e3
        );
        assert!(
            failover
                .iter()
                .zip(&plans)
                .all(|(a, b)| a.makespan_us.to_bits() == b.makespan_us.to_bits()),
            "fail-over plans must be bit-identical to the federated ones"
        );

        RemotePlanner::connect(&planner.hosts()[1])?.shutdown()?;
        daemons.remove(0).join().expect("daemon thread")?;
        println!("ephemeral daemons stopped.");
    }
    Ok(())
}
