//! End-to-end validation driver (DESIGN.md §3): train DQN-CartPole
//! through the full three-layer stack — rust env + replay + exploration
//! (L3) driving the AOT-compiled JAX/Pallas train step (L2/L1) over PJRT
//! — in both FP32 and AP-DRL mixed precision, and report the reward
//! curves + reward error.  Results are recorded in EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example train_cartpole -- [--steps 20000] [--seeds 2]
//! ```

use anyhow::Result;

use apdrl::coordinator::metrics::reward_error_pct;
use apdrl::coordinator::report::write_tsv;
use apdrl::coordinator::{combo, train_combo, TrainLimits};
use apdrl::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |name: &str, default: usize| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let steps = get("--steps", 20_000) as u64;
    let seeds = get("--seeds", 2) as u64;

    let dir = std::env::var("APDRL_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")));
    let mut runtime = Runtime::new(dir)?;
    println!("PJRT platform: {}", runtime.platform());

    let c = combo("dqn_cartpole");
    let limits = TrainLimits { max_env_steps: steps, max_episodes: 10_000 };
    let mut fp32 = Vec::new();
    let mut mixed = Vec::new();
    for seed in 1..=seeds {
        for mode in ["fp32", "mixed"] {
            let t0 = std::time::Instant::now();
            let mut backend = apdrl::exec::PjrtBackend::new(&mut runtime, mode);
            let r = train_combo(&mut backend, &c, seed, limits, true)?;
            let conv = r.metrics.converged_reward(50);
            println!(
                "[{mode} seed {seed}] {} episodes | converged reward {conv:.1} | {} train steps | {} overflows | {:.1}s ({:.0} env steps/s)",
                r.metrics.episode_rewards.len(),
                r.metrics.train_steps,
                r.metrics.overflows,
                t0.elapsed().as_secs_f64(),
                r.metrics.env_steps as f64 / t0.elapsed().as_secs_f64()
            );
            // dump the smoothed curve
            let rows: Vec<Vec<String>> = r
                .metrics
                .smoothed_rewards()
                .iter()
                .enumerate()
                .map(|(i, v)| vec![i.to_string(), format!("{v:.2}")])
                .collect();
            write_tsv(
                format!(
                    "{}/reports/train_cartpole_{mode}_s{seed}.tsv",
                    env!("CARGO_MANIFEST_DIR")
                ),
                &["episode", "reward_ma100"],
                &rows,
            )?;
            if mode == "fp32" {
                fp32.push(conv);
            } else {
                mixed.push(conv);
            }
        }
    }
    let err = reward_error_pct(&fp32, &mixed);
    println!("\n== end-to-end result ==");
    println!(
        "FP32 converged {:.1} | AP-DRL mixed converged {:.1} | reward error {err:.2}% (paper Table III: 1.60%)",
        apdrl::util::stats::mean(&fp32),
        apdrl::util::stats::mean(&mixed)
    );
    Ok(())
}
