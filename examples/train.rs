//! Quickstart for the dynamic phase: plan → precision policy → train,
//! entirely on the pure-Rust CPU executor (no PJRT, no artifacts).
//!
//! ```bash
//! cargo run --release --example train -- [--steps 4000] [--seed 1]
//! ```
//!
//! Plans DQN-CartPole through the one `Planner` API, folds the solved
//! schedule into an `ExecPolicy` (the quantized CartPole plan is all-PL,
//! so every layer runs FP16 with FP32 masters and the loss-scaling FSM
//! armed), then trains both quantized and FP32 on the same seed and
//! reports the reward error.

use anyhow::Result;

use apdrl::coordinator::metrics::reward_error_pct;
use apdrl::coordinator::{combo, train_combo, LocalPlanner, PlanRequest, Planner, TrainLimits};
use apdrl::exec::CpuBackend;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |name: &str, default: usize| {
        args.iter()
            .position(|a| a == name)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    };
    let steps = get("--steps", 4_000) as u64;
    let seed = get("--seed", 1) as u64;
    let c = combo("dqn_cartpole");
    let limits = TrainLimits { max_env_steps: steps, max_episodes: 200 };

    let mut converged = Vec::new();
    for quantized in [true, false] {
        // 1. Static phase: the partition plan decides the layer formats.
        let plan = LocalPlanner.plan(&PlanRequest::new(c.clone(), c.batch, quantized))?;
        // 2. Dynamic phase: the CPU executor runs the plan's routing.
        let mut backend = CpuBackend::from_outcome(&plan)?.with_train_every(2);
        println!(
            "[{}] {} MM nodes on AIE of {}, loss scaling {}",
            backend.describe(),
            plan.aie_mm_nodes,
            plan.mm_nodes,
            if backend.policy().needs_loss_scaling { "armed" } else { "off" }
        );
        let r = train_combo(&mut backend, &c, seed, limits, false)?;
        let conv = r.metrics.converged_reward(25);
        println!(
            "[{}] {} episodes, {} train steps, {} overflows, {} scale transitions, converged reward {conv:.1}",
            backend.describe(),
            r.metrics.episode_rewards.len(),
            r.metrics.train_steps,
            r.metrics.overflows,
            r.metrics.scale_transitions.len(),
        );
        converged.push(conv);
    }
    println!(
        "quantized {:.1} vs fp32 {:.1} -> reward error {:.2}% (paper Table III: {:.2}%)",
        converged[0],
        converged[1],
        reward_error_pct(&[converged[1]], &[converged[0]]),
        c.paper_reward_error_pct
    );
    Ok(())
}
