//! Partitioning-behaviour sweep (paper Fig 15 + §V-C analysis): how the
//! ILP's PL/AIE split of DDPG-LunarCont evolves with batch size, and how
//! the ILP compares against the greedy and HEFT baselines (the ablation
//! DESIGN.md calls out).
//!
//! The whole batch ladder is planned in one `Planner::plan_many` call —
//! in-process by default, or through whatever backend `APDRL_SERVER`
//! names (a daemon, or a comma-separated federation).  The points are
//! solved concurrently and repeated runs in the same process (or with
//! `APDRL_PLAN_CACHE` set) hit the plan cache instead of re-solving.
//! The heuristic baselines are local-only analyses, so the problem
//! instance is rebuilt in-process (deterministically) for them.
//!
//! ```bash
//! cargo run --release --example partition_sweep
//! ```

use anyhow::Result;

use apdrl::coordinator::{combo, PlanRequest, Planner};
use apdrl::graph::build_train_graph;
use apdrl::hw::vek280;
use apdrl::partition::heuristics::{greedy, heft};
use apdrl::partition::Problem;
use apdrl::profile::profile_dag;
use apdrl::server::select_planner;

fn main() -> Result<()> {
    let c = combo("ddpg_lunar");
    let batches = [64usize, 128, 256, 512, 1024, 2048];
    let requests: Vec<PlanRequest> =
        batches.iter().map(|&bs| PlanRequest::new(c.clone(), bs, true)).collect();

    let planner = select_planner(None)?;
    let t0 = std::time::Instant::now();
    let plans = planner.plan_many(&requests)?;
    println!(
        "DDPG-LunarCont partitioning vs batch size (paper Fig 15) — {} plans in {:.0} ms [{}]\n",
        plans.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        planner.describe()
    );
    println!(
        "{:>6} | {:>10} | {:>12} | {:>12} | {:>12} | ILP gain",
        "batch", "AIE nodes", "ILP µs", "HEFT µs", "greedy µs"
    );
    let platform = vek280();
    for (&bs, plan) in batches.iter().zip(&plans) {
        // Ablation baselines evaluated on the same (deterministically
        // rebuilt) problem instance the backend solved.
        let dag = build_train_graph(&c.train_spec(bs));
        let profiles = profile_dag(&dag, &platform, true);
        let problem = Problem::new(&dag, &profiles, &platform, true);
        let h = heft(&problem);
        let g = greedy(&problem);
        println!(
            "{bs:>6} | {:>4} of {:>2}  | {:>12.1} | {:>12.1} | {:>12.1} | {:.2}x vs greedy",
            plan.aie_mm_nodes,
            plan.mm_nodes,
            plan.makespan_us,
            h.makespan_us,
            g.makespan_us,
            g.makespan_us / plan.makespan_us
        );
    }

    println!("\nAIE-resident layers at bs=1024:");
    let idx = batches.iter().position(|&b| b == 1024).unwrap();
    for step in plans[idx].schedule.iter().filter(|s| s.component == "AIE") {
        println!("  {}", step.name);
    }

    // Re-planning the same ladder is O(1) per point: all cache hits
    // (whichever backend's cache — the outcome says).
    let t1 = std::time::Instant::now();
    let replans = planner.plan_many(&requests)?;
    println!(
        "\nre-plan of the same ladder: {:.2} ms, {}/{} plan-cache hits",
        t1.elapsed().as_secs_f64() * 1e3,
        replans.iter().filter(|p| p.cache_hit).count(),
        replans.len()
    );
    Ok(())
}
