//! Partitioning-behaviour sweep (paper Fig 15 + §V-C analysis): how the
//! ILP's PL/AIE split of DDPG-LunarCont evolves with batch size, and how
//! the ILP compares against the greedy and HEFT baselines (the ablation
//! DESIGN.md calls out).
//!
//! The whole batch ladder is planned in one call through the
//! coordinator's batched planning service (`plan_sweep`): the points are
//! solved concurrently, each solve parallelizes its own branch-and-bound,
//! and repeated runs in the same process (or with `APDRL_PLAN_CACHE`
//! set) hit the plan cache instead of re-solving.
//!
//! ```bash
//! cargo run --release --example partition_sweep
//! ```

use apdrl::coordinator::{combo, plan_sweep, PlanRequest};
use apdrl::partition::heuristics::{greedy, heft};
use apdrl::partition::Problem;

fn main() {
    let c = combo("ddpg_lunar");
    let batches = [64usize, 128, 256, 512, 1024, 2048];
    let requests: Vec<PlanRequest> =
        batches.iter().map(|&bs| PlanRequest::new(c.clone(), bs, true)).collect();

    let t0 = std::time::Instant::now();
    let plans = plan_sweep(&requests);
    println!(
        "DDPG-LunarCont partitioning vs batch size (paper Fig 15) — {} plans in {:.0} ms\n",
        plans.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    println!(
        "{:>6} | {:>10} | {:>12} | {:>12} | {:>12} | ILP gain",
        "batch", "AIE nodes", "ILP µs", "HEFT µs", "greedy µs"
    );
    for (&bs, plan) in batches.iter().zip(&plans) {
        // Ablation baselines evaluated on the exact same problem instance
        // the service solved (dag/profiles/platform travel with the plan).
        let problem = Problem::new(&plan.dag, &plan.profiles, &plan.platform, true);
        let h = heft(&problem);
        let g = greedy(&problem);
        println!(
            "{bs:>6} | {:>4} of {:>2}  | {:>12.1} | {:>12.1} | {:>12.1} | {:.2}x vs greedy",
            plan.solution.aie_nodes(&plan.dag),
            plan.dag.mm_nodes().len(),
            plan.solution.makespan_us,
            h.makespan_us,
            g.makespan_us,
            g.makespan_us / plan.solution.makespan_us
        );
    }

    println!("\nAIE-resident layers at bs=1024:");
    let idx = batches.iter().position(|&b| b == 1024).unwrap();
    let plan_1024 = &plans[idx];
    for (i, p) in plan_1024.solution.assignment.iter().enumerate() {
        if p.component == apdrl::hw::Component::AIE {
            println!("  {}", plan_1024.dag.nodes[i].name);
        }
    }

    // Re-planning the same ladder is O(1) per point: all cache hits.
    let t1 = std::time::Instant::now();
    let replans = plan_sweep(&requests);
    println!(
        "\nre-plan of the same ladder: {:.2} ms, {}/{} plan-cache hits",
        t1.elapsed().as_secs_f64() * 1e3,
        replans.iter().filter(|p| p.cache_hit).count(),
        replans.len()
    );
}
