//! Partitioning-behaviour sweep (paper Fig 15 + §V-C analysis): how the
//! ILP's PL/AIE split of DDPG-LunarCont evolves with batch size, and how
//! the ILP compares against the greedy and HEFT baselines (the ablation
//! DESIGN.md calls out).
//!
//! ```bash
//! cargo run --release --example partition_sweep
//! ```

use apdrl::coordinator::combo;
use apdrl::graph::build_train_graph;
use apdrl::hw::vek280;
use apdrl::partition::heuristics::{greedy, heft};
use apdrl::partition::{solve_ilp, Problem};
use apdrl::profile::profile_dag;

fn main() {
    let c = combo("ddpg_lunar");
    let platform = vek280();
    println!("DDPG-LunarCont partitioning vs batch size (paper Fig 15)\n");
    println!(
        "{:>6} | {:>10} | {:>12} | {:>12} | {:>12} | ILP gain",
        "batch", "AIE nodes", "ILP µs", "HEFT µs", "greedy µs"
    );
    for bs in [64usize, 128, 256, 512, 1024, 2048] {
        let dag = build_train_graph(&c.train_spec(bs));
        let profiles = profile_dag(&dag, &platform, true);
        let problem = Problem::new(&dag, &profiles, &platform, true);
        let ilp = solve_ilp(&problem);
        let h = heft(&problem);
        let g = greedy(&problem);
        println!(
            "{bs:>6} | {:>4} of {:>2}  | {:>12.1} | {:>12.1} | {:>12.1} | {:.2}x vs greedy",
            ilp.aie_nodes(&dag),
            dag.mm_nodes().len(),
            ilp.makespan_us,
            h.makespan_us,
            g.makespan_us,
            g.makespan_us / ilp.makespan_us
        );
    }
    println!("\nAIE-resident layers at bs=1024:");
    let dag = build_train_graph(&c.train_spec(1024));
    let profiles = profile_dag(&dag, &platform, true);
    let problem = Problem::new(&dag, &profiles, &platform, true);
    let ilp = solve_ilp(&problem);
    for (i, p) in ilp.assignment.iter().enumerate() {
        if p.component == apdrl::hw::Component::AIE {
            println!("  {}", dag.nodes[i].name);
        }
    }
}
