//! Quickstart: the whole AP-DRL static phase on one combo in ~20 lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use apdrl::coordinator::{combo, static_phase};
use apdrl::hw::Component;

fn main() {
    // 1. Pick a Table III workload: DDPG on LunarLanderContinuous.
    let c = combo("ddpg_lunar");

    // 2. Run the static phase: build the layer CDFG, DSE-profile every
    //    node on PL and AIE, solve the partitioning ILP, derive the
    //    precision policy (Alg. 1) and pick the PS-PL interface (TAPCA).
    let plan = static_phase(&c, 512, /* quantized = */ true);

    println!("workload: {} (batch 512)", c.name);
    println!("layer nodes: {} ({} MM)", plan.dag.len(), plan.dag.mm_nodes().len());
    println!(
        "partition: {} MM nodes on AIE, rest on PL",
        plan.solution.aie_nodes(&plan.dag)
    );
    for e in &plan.schedule.entries {
        let n = &plan.dag.nodes[e.node];
        if n.kind.is_mm() {
            println!(
                "  {:24} -> {:3} [{}] {:8.1} µs",
                n.name,
                e.component.name(),
                plan.policy.node_format[e.node].name(),
                e.finish_us - e.start_us
            );
        }
    }
    println!(
        "train-step makespan: {:.1} µs ({:.0} steps/s) | comm {:.1} µs | exposed master-weight sync {:.1} µs",
        plan.schedule.makespan_us,
        plan.throughput(),
        plan.schedule.comm_us,
        plan.schedule.sync_us,
    );
    println!(
        "loss scaling armed: {} | PS-PL interface: {:?}",
        plan.policy.needs_loss_scaling, plan.interface
    );

    // 3. Compare with the FP32 control — the quantization benefit.
    let fp32 = static_phase(&c, 512, false);
    println!(
        "FP32 control: {:.1} µs/step -> quantization speedup {:.2}x",
        fp32.schedule.makespan_us,
        fp32.schedule.makespan_us / plan.schedule.makespan_us
    );

    // 4. Where did the AIE win? (the paper's Fig 6 intuition)
    let any_aie = plan
        .schedule
        .entries
        .iter()
        .find(|e| e.component == Component::AIE)
        .map(|e| plan.dag.nodes[e.node].name.clone());
    if let Some(node) = any_aie {
        println!("example AIE-resident layer: {node} (high-FLOPs GEMM, BF16 native)");
    }
}
