//! Quickstart: the whole AP-DRL static phase on one combo in ~20 lines,
//! through the one [`Planner`] API every backend implements.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Swap `LocalPlanner` for `RemotePlanner::connect("host:port")?` or
//! `FederatedPlanner::connect(&hosts)?` and nothing else changes — the
//! `PlanOutcome` (and the printed numbers) is bit-identical.

use anyhow::Result;

use apdrl::coordinator::{LocalPlanner, PlanRequest, Planner};

fn main() -> Result<()> {
    // 1. Pick a Table III workload: DDPG on LunarLanderContinuous, batch
    //    512, AP-DRL mixed precision (the default).
    let req = PlanRequest::named("ddpg_lunar")?.with_batch(512);

    // 2. Run the static phase: build the layer CDFG, DSE-profile every
    //    node on PL and AIE, solve the partitioning ILP, derive the
    //    precision policy (Alg. 1) and pick the PS-PL interface (TAPCA).
    let plan = LocalPlanner.plan(&req)?;

    println!("workload: {} (batch {})", plan.combo, plan.batch);
    println!("layer nodes: {} ({} MM)", plan.schedule.len(), plan.mm_nodes);
    println!("partition: {} MM nodes on AIE, rest on PL", plan.aie_mm_nodes);
    for step in plan.schedule.iter().filter(|s| s.mm) {
        println!(
            "  {:24} -> {:3} [{}] {:8.1} µs",
            step.name,
            step.component,
            step.format,
            step.finish_us - step.start_us
        );
    }
    println!(
        "train-step makespan: {:.1} µs ({:.0} steps/s) | comm {:.1} µs | exposed master-weight sync {:.1} µs",
        plan.makespan_us,
        plan.throughput(),
        plan.comm_us,
        plan.sync_us,
    );
    println!(
        "PS-PL interface: {} ({:.1} µs/step) | planned via {}",
        plan.interface, plan.ps_pl_us, plan.provenance
    );

    // 3. Compare with the FP32 control — the quantization benefit.
    let fp32 = LocalPlanner.plan(&req.clone().fp32())?;
    println!(
        "FP32 control: {:.1} µs/step -> quantization speedup {:.2}x",
        fp32.makespan_us,
        fp32.makespan_us / plan.makespan_us
    );

    // 4. Where did the AIE win? (the paper's Fig 6 intuition)
    if let Some(step) = plan.schedule.iter().find(|s| s.component == "AIE") {
        println!(
            "example AIE-resident layer: {} (high-FLOPs GEMM, BF16 native)",
            step.name
        );
    }
    Ok(())
}
