//! Design-space exploration demo (paper Table I / §IV-B): sweep the HLS
//! pragma space for one GEMM layer on the PL and the tile allocations on
//! the AIE, print the Pareto frontiers, and show what the DSE winner
//! looks like.
//!
//! ```bash
//! cargo run --release --example dse_explore -- [n]
//! ```

use apdrl::graph::LayerKind;
use apdrl::hw::{vek280, Component, Format};
use apdrl::profile::dse::{explore_aie, explore_pl, partition_factors, unroll_factors};

fn main() {
    let n: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(512);
    let platform = vek280();
    let kind = LayerKind::Mm { m: n, k: n, n };

    println!("Table I design space for a {n}x{n}x{n} GEMM:");
    println!("  dataflow: 2, func pipeline: 2, loop pipeline: 2");
    println!("  loop unroll points: {} (log2 progression)", unroll_factors((n * n).min(4096)).len());
    println!("  array partition points (fp16): {}", partition_factors(Format::Fp16).len());

    println!("\nPL Pareto frontier (COMBA-substitute, fp16):");
    let pl = explore_pl(platform.spec(Component::PL), &kind, Format::Fp16, platform.pl_dsp);
    for d in &pl {
        println!(
            "  {:>6} DSP  {:>7.1} kLUT  {:>12.1} µs   (DF={} FP={} LP={} LU={} AP={})",
            d.resource,
            d.kluts,
            d.latency_us,
            d.config.dataflow as u8,
            d.config.func_pipeline as u8,
            d.config.loop_pipeline as u8,
            d.config.unroll,
            d.config.array_partition
        );
    }

    println!("\nAIE Pareto frontier (CHARM-substitute, bf16):");
    let aie = explore_aie(
        platform.spec(Component::AIE),
        &kind,
        Format::Bf16,
        platform.aie_tiles,
        platform.aie_lanes_per_tile,
    );
    for d in &aie {
        println!("  {:>6} tiles {:>12.1} µs", d.resource, d.latency_us);
    }

    let pl_best = pl.last().unwrap();
    let aie_best = aie.last().unwrap();
    println!(
        "\nDSE winners: PL {:.1} µs vs AIE {:.1} µs -> {} wins at n={n}",
        pl_best.latency_us,
        aie_best.latency_us,
        if pl_best.latency_us < aie_best.latency_us { "PL" } else { "AIE" }
    );
    println!("(crossover behaviour is the paper's Fig 6; sweep n to see it move)");

    // The same DSE, driven end-to-end through the one `Planner` API:
    // every Table III convergence combo profiled + partitioned in one
    // batched, cache-aware `plan_many` (the per-node frontiers above are
    // what the ILP consumes as its t_ij candidates).  The backend is
    // whatever `APDRL_SERVER` selects — local, one daemon, or a
    // federation — and the numbers are identical whichever it is.
    use apdrl::coordinator::{PlanRequest, Planner, COMBO_NAMES};
    use apdrl::server::select_planner;
    let requests: Vec<PlanRequest> = COMBO_NAMES
        .iter()
        .filter_map(|name| PlanRequest::named(name).ok())
        .collect();
    let planner = match select_planner(None) {
        Ok(planner) => planner,
        Err(e) => {
            eprintln!("cannot select a planning backend: {e:#}");
            std::process::exit(1);
        }
    };
    let t0 = std::time::Instant::now();
    let plans = match planner.plan_many(&requests) {
        Ok(plans) => plans,
        Err(e) => {
            eprintln!("planning sweep failed: {e:#}");
            std::process::exit(1);
        }
    };
    println!(
        "\nplanning service [{}] over {} combos ({:.0} ms cold):",
        planner.describe(),
        plans.len(),
        t0.elapsed().as_secs_f64() * 1e3
    );
    for plan in &plans {
        println!(
            "  {:20} bs={:<5} {:>10.1} µs/step   AIE {}/{} MM   explored {}{}",
            plan.combo,
            plan.batch,
            plan.makespan_us,
            plan.aie_mm_nodes,
            plan.mm_nodes,
            plan.explored,
            if plan.cache_hit { " (cache hit)" } else { "" }
        );
    }
    let t1 = std::time::Instant::now();
    let warm = planner.plan_many(&requests).expect("warm re-plan");
    println!(
        "re-plan: {:.2} ms, {}/{} cache hits (set APDRL_PLAN_CACHE=<file> to persist across runs)",
        t1.elapsed().as_secs_f64() * 1e3,
        warm.iter().filter(|p| p.cache_hit).count(),
        warm.len()
    );
}
