//! Minimal, API-compatible subset of the `anyhow` crate, vendored in-repo
//! because this build runs fully offline (no crates.io registry).  Only
//! the surface the workspace actually uses is provided:
//!
//! * [`Error`] — an opaque error value carrying a context chain;
//! * [`Result<T>`] — `std::result::Result<T, Error>`;
//! * [`anyhow!`] / [`bail!`] / [`ensure!`] — formatted construction;
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`;
//! * `From<E: std::error::Error>` so `?` converts std errors;
//! * `Display` (`{e}` = outermost message, `{e:#}` = full chain) and a
//!   `Debug` that prints the chain like real anyhow, so `.unwrap()`
//!   diagnostics stay useful.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error` — that is what makes the blanket `From` impl
//! coherent.

use std::fmt;

/// `Result` specialized to [`Error`], matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: an outermost message plus the chain of causes
/// (outermost first).
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The causes, outermost first (mirrors `anyhow::Error::chain`).
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// Root cause = innermost entry of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }

    fn from_std<E: std::error::Error + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain, colon-separated, like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.chain.split_first() {
            None => Ok(()),
            Some((head, rest)) => {
                write!(f, "{head}")?;
                if !rest.is_empty() {
                    write!(f, "\n\nCaused by:")?;
                    for (i, cause) in rest.iter().enumerate() {
                        write!(f, "\n    {i}: {cause}")?;
                    }
                }
                Ok(())
            }
        }
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($fmt:literal $(, $arg:expr)* $(,)?) => {
        $crate::Error::msg(format!($fmt $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !$cond {
            return ::std::result::Result::Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert_eq!(format!("{e}"), "missing file");
    }

    #[test]
    fn context_prepends() {
        let e: Result<()> = Err(io_err());
        let e = e.with_context(|| "reading manifest").unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("empty").unwrap_err();
        assert_eq!(format!("{e}"), "empty");
    }

    #[test]
    fn macros_format() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(format!("{e}"), "bad value 7");
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("unlucky {x}");
            }
            Ok(x)
        }
        assert!(f(2).is_ok());
        assert_eq!(format!("{}", f(3).unwrap_err()), "unlucky 3");
        assert_eq!(format!("{}", f(12).unwrap_err()), "too big: 12");
    }
}
