//! Bench: Fig 6 machinery — DSE sweeps per GEMM size on PL and AIE.

use apdrl::graph::LayerKind;
use apdrl::hw::{vek280, Component, Format};
use apdrl::profile::dse::{explore_aie, explore_pl};
use apdrl::util::bench::{observe, run};

fn main() {
    println!("== bench_gemm_dse: Table-I sweep cost per GEMM size ==");
    let platform = vek280();
    for n in [64usize, 256, 1024] {
        let kind = LayerKind::Mm { m: n, k: n, n };
        run(&format!("explore_pl/{n}"), || {
            observe(explore_pl(
                platform.spec(Component::PL),
                &kind,
                Format::Fp16,
                platform.pl_dsp,
            ));
        });
        run(&format!("explore_aie/{n}"), || {
            observe(explore_aie(
                platform.spec(Component::AIE),
                &kind,
                Format::Bf16,
                platform.aie_tiles,
                platform.aie_lanes_per_tile,
            ));
        });
    }
}
