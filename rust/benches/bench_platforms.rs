//! Bench: Fig 4 regeneration cost + per-component profile evaluation.
//! (`cargo bench` target; custom harness — criterion is not vendored.)

use apdrl::coordinator::{combo, plan_sweep, PlanRequest};
use apdrl::graph::build_train_graph;
use apdrl::hw::vek280;
use apdrl::partition::cache;
use apdrl::profile::profile_dag;
use apdrl::util::bench::{observe, run};

fn main() {
    println!("== bench_platforms: profiling/DSE costs (Fig 4 machinery) ==");
    let platform = vek280();
    let names = ["dqn_cartpole", "ddpg_lunar", "dqn_breakout"];
    for name in names {
        let c = combo(name);
        let dag = build_train_graph(&c.train_spec(c.batch));
        run(&format!("build_train_graph/{name}"), || {
            observe(build_train_graph(&c.train_spec(c.batch)));
        });
        run(&format!("profile_dag/{name}"), || {
            observe(profile_dag(&dag, &platform, true));
        });
    }

    // The planning service over the same combos: cold (parallel solves)
    // vs warm (every point a plan-cache hit).
    let requests: Vec<PlanRequest> = names
        .iter()
        .map(|name| {
            let c = combo(name);
            let bs = c.batch;
            PlanRequest::new(c, bs, true)
        })
        .collect();
    run("plan_sweep_cold/3combos", || {
        cache::global().lock().unwrap().clear();
        observe(plan_sweep(&requests));
    });
    plan_sweep(&requests);
    run("plan_sweep_warm/3combos", || {
        let plans = plan_sweep(&requests);
        assert!(plans.iter().all(|p| p.cache_hit));
        observe(plans);
    });
}
