//! Bench: Fig 4 regeneration cost + per-component profile evaluation.
//! (`cargo bench` target; custom harness — criterion is not vendored.)

use apdrl::coordinator::combo;
use apdrl::graph::build_train_graph;
use apdrl::hw::vek280;
use apdrl::profile::profile_dag;
use apdrl::util::bench::{observe, run};

fn main() {
    println!("== bench_platforms: profiling/DSE costs (Fig 4 machinery) ==");
    let platform = vek280();
    for name in ["dqn_cartpole", "ddpg_lunar", "dqn_breakout"] {
        let c = combo(name);
        let dag = build_train_graph(&c.train_spec(c.batch));
        run(&format!("build_train_graph/{name}"), || {
            observe(build_train_graph(&c.train_spec(c.batch)));
        });
        run(&format!("profile_dag/{name}"), || {
            observe(profile_dag(&dag, &platform, true));
        });
    }
}
