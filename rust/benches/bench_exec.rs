//! Bench: CPU-executor kernels — naive vs blocked vs parallel GEMM over
//! Table-I-style sizes, plus per-train-step cost for one MLP and one
//! conv combo.  Emits machine-readable `BENCH_exec.json` (schema below)
//! to seed the executor's perf trajectory; CI runs `--smoke` so the
//! bench and the JSON path never rot offline.
//!
//! Speedup expectations (release build; refresh the numbers from
//! BENCH_exec.json on your box — CI's smoke run is *not* representative):
//! the blocked/packed kernel holds the MR×NR accumulator tile in
//! registers instead of load/storing the output row every reduction
//! step, which is worth ≥2× over the naive ikj loop at 256³
//! single-threaded (the tracked acceptance line, printed as
//! `speedup blocked/naive @256`), typically more on AVX-capable
//! targets; the parallel kernel adds near-linear row-block scaling on
//! top for GEMMs past the sequential threshold.  Everything here is
//! bit-identical to naive — speed is the only axis (tests/kernels.rs).
//!
//! ```text
//! BENCH_exec.json = {
//!   "bench": "exec", "mode": "full"|"smoke", "threads": N,
//!   "gemm": [ {"m","k","n","kernel","median_ns","mean_ns","p95_ns",
//!              "iters","gflops"} ... ],
//!   "speedups": { "blocked_vs_naive_256"?: x, ... },
//!   "train_step": [ {"combo","net","threads","median_ns",...} ... ],
//!   "actors": [ {"actors","env_steps_per_sec","median_ns",...} ... ],
//!   "micro": [ {"name","median_ns",...} ... ]
//! }
//! ```
//!
//! Perf-regression guard: before overwriting its output, the bench
//! compares fresh medians against `BENCH_exec.baseline.json` (the
//! committed smoke-mode baseline; falls back to the previous run's
//! `BENCH_exec.json`) and prints a `WARN` for any key that regressed
//! more than 2× — it never fails, because shared CI boxes are noisy and
//! the baseline may come from different hardware (keys that don't match,
//! e.g. a different pool width, are simply skipped).

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use apdrl::coordinator::config::{combo, ComboConfig};
use apdrl::drl::compute::DqnCompute;
use apdrl::drl::replay::{ReplayBuffer, StoredAction};
use apdrl::drl::Agent;
use apdrl::envs::{lane_rngs, BatchedEnv, Env};
use apdrl::exec::{Backend, CpuBackend, CpuDqn, ExecPolicy, Pool, Tensor};
use apdrl::graph::{Algo, NetSpec};
use apdrl::util::bench::{bench, fmt_ns, observe, BenchResult};
use apdrl::util::json::Json;
use apdrl::util::Rng;

fn rand_tensor(rng: &mut Rng, rows: usize, cols: usize) -> Tensor {
    Tensor::from_vec(
        (0..rows * cols).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(),
        &[rows, cols],
    )
}

fn result_json(r: &BenchResult, extra: &[(&str, Json)]) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("name".to_string(), Json::Str(r.name.clone()));
    obj.insert("iters".to_string(), Json::Num(r.iters as f64));
    obj.insert("median_ns".to_string(), Json::Num(r.median_ns));
    obj.insert("mean_ns".to_string(), Json::Num(r.mean_ns));
    obj.insert("p95_ns".to_string(), Json::Num(r.p95_ns));
    for (k, v) in extra {
        obj.insert(k.to_string(), v.clone());
    }
    Json::Obj(obj)
}

/// Stable comparison key of one `gemm` entry.
fn gemm_key(r: &Json) -> String {
    let n = |k: &str| r.get(k).and_then(Json::as_f64).unwrap_or(0.0) as usize;
    format!(
        "{}/{}x{}x{}/{}thr",
        r.get("kernel").and_then(Json::as_str).unwrap_or("?"),
        n("m"),
        n("k"),
        n("n"),
        n("threads")
    )
}

/// Stable comparison key of one `train_step` entry.
fn train_key(r: &Json) -> String {
    format!(
        "{}/{}thr",
        r.get("combo").and_then(Json::as_str).unwrap_or("?"),
        r.get("threads").and_then(Json::as_f64).unwrap_or(0.0) as usize
    )
}

/// Stable comparison key of one `micro` entry.
fn micro_key(r: &Json) -> String {
    r.get("name").and_then(Json::as_str).unwrap_or("?").to_string()
}

/// The warn-only perf guard: every fresh median whose key exists in the
/// baseline section is compared; >2x slower prints a WARN.  Returns
/// (medians compared, regressions warned).
fn warn_regressions(
    base: &Json,
    sections: &[(&str, &[Json], fn(&Json) -> String)],
) -> (usize, usize) {
    let mut compared = 0usize;
    let mut warned = 0usize;
    let empty: Vec<Json> = Vec::new();
    for &(name, fresh, key_of) in sections {
        let base_medians: BTreeMap<String, f64> = base
            .get(name)
            .and_then(Json::as_arr)
            .unwrap_or(&empty)
            .iter()
            .filter_map(|r| Some((key_of(r), r.get("median_ns").and_then(Json::as_f64)?)))
            .collect();
        for row in fresh {
            let key = key_of(row);
            let (Some(&base_ns), Some(now_ns)) =
                (base_medians.get(&key), row.get("median_ns").and_then(Json::as_f64))
            else {
                continue;
            };
            compared += 1;
            if now_ns > base_ns * 2.0 {
                warned += 1;
                println!(
                    "WARN perf regression {name}/{key}: median {} vs baseline {} ({:.1}x)",
                    fmt_ns(now_ns),
                    fmt_ns(base_ns),
                    now_ns / base_ns
                );
            }
        }
    }
    (compared, warned)
}

#[allow(clippy::too_many_arguments)]
fn gemm_entry(
    r: &BenchResult,
    m: usize,
    k: usize,
    n: usize,
    kernel: &str,
    threads: usize,
) -> Json {
    let flops = 2.0 * m as f64 * k as f64 * n as f64;
    result_json(
        r,
        &[
            ("m", Json::Num(m as f64)),
            ("k", Json::Num(k as f64)),
            ("n", Json::Num(n as f64)),
            ("kernel", Json::Str(kernel.to_string())),
            ("threads", Json::Num(threads as f64)),
            ("gflops", Json::Num(flops / r.median_ns)),
        ],
    )
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("APDRL_BENCH_SMOKE").ok().is_some_and(|v| !v.is_empty());
    let mode = if smoke { "smoke" } else { "full" };
    let budget =
        if smoke { Duration::from_millis(40) } else { Duration::from_millis(1500) };
    // Table-I-style GEMM sizes; smoke shrinks them so CI proves the
    // path (compile, run, JSON) in seconds, not minutes.
    let sizes: &[(usize, usize, usize)] = if smoke {
        &[(8, 8, 8), (24, 24, 24)]
    } else {
        &[(64, 64, 64), (256, 256, 256), (1024, 1024, 1024)]
    };
    // Naive is O(minutes) at 1024³ — cap it at 256 in full mode; the
    // JSON records which sizes carry a naive baseline.
    let naive_cap = if smoke { usize::MAX } else { 256 };

    let par_pool = Pool::global();
    let seq_pool = Arc::new(Pool::new(1));
    println!(
        "== bench_exec [{mode}]: naive vs blocked vs parallel GEMM ({} threads) ==",
        par_pool.threads()
    );

    let mut rng = Rng::new(0xBE7C);
    let mut gemm_rows = Vec::new();
    let mut speedups = BTreeMap::new();
    for &(m, k, n) in sizes {
        let a = rand_tensor(&mut rng, m, k);
        let b = rand_tensor(&mut rng, k, n);
        let mut naive_median = None;
        if m.max(k).max(n) <= naive_cap {
            let r = bench(&format!("gemm_naive/{m}x{k}x{n}"), budget, || {
                observe(a.matmul_naive(&b));
            });
            r.print();
            naive_median = Some(r.median_ns);
            gemm_rows.push(gemm_entry(&r, m, k, n, "naive", 1));
        }
        let r = bench(&format!("gemm_blocked/{m}x{k}x{n}"), budget, || {
            observe(a.matmul_with(&b, &seq_pool));
        });
        r.print();
        let blocked_median = r.median_ns;
        gemm_rows.push(gemm_entry(&r, m, k, n, "blocked", 1));
        let r = bench(&format!("gemm_parallel/{m}x{k}x{n}"), budget, || {
            observe(a.matmul_with(&b, &par_pool));
        });
        r.print();
        gemm_rows.push(gemm_entry(&r, m, k, n, "parallel", par_pool.threads()));
        if let Some(naive) = naive_median {
            let speedup = naive / blocked_median;
            println!(
                "   -> speedup blocked/naive @{m}: {speedup:.2}x  (naive {} vs blocked {})",
                fmt_ns(naive),
                fmt_ns(blocked_median)
            );
            speedups.insert(format!("blocked_vs_naive_{m}"), Json::Num(speedup));
        }
    }

    // Per-train-step cost: one MLP combo (registry DQN-CartPole net)
    // and one conv combo (the Table III mini pixel net), at 1 thread
    // and at the pool default.
    println!("== bench_exec [{mode}]: per-train-step cost ==");
    let bs = if smoke { 8 } else { 64 };
    let mlp = combo("dqn_cartpole");
    let conv = ComboConfig {
        name: "dqn_pixel_bench",
        algo: Algo::Dqn,
        env: "mspacman_mini",
        net: NetSpec::Conv { in_hw: 12, in_ch: 4, conv: vec![(8, 4, 2)], fc: vec![128, 9] },
        batch: bs,
        obs_dim: 12 * 12 * 4,
        act_dim: 9,
        paper_flops_per_row: 0.0,
        paper_reward_error_pct: 0.0,
    };
    let mut train_rows = Vec::new();
    for c in [&mlp, &conv] {
        let mut fill_rng = Rng::new(0xF111);
        let mut rb = ReplayBuffer::new(bs * 2, c.obs_dim);
        for _ in 0..bs * 2 {
            let o: Vec<f32> =
                (0..c.obs_dim).map(|_| fill_rng.uniform_in(-1.0, 1.0) as f32).collect();
            let o2: Vec<f32> =
                (0..c.obs_dim).map(|_| fill_rng.uniform_in(-1.0, 1.0) as f32).collect();
            rb.push(&o, StoredAction::Discrete(fill_rng.below(c.act_dim) as i32), 1.0, &o2, false);
        }
        let batch = rb.sample(bs, &mut fill_rng);
        let net_kind = match c.net {
            NetSpec::Mlp { .. } => "mlp",
            NetSpec::Conv { .. } => "conv",
        };
        for pool in [&seq_pool, &par_pool] {
            let mut model = CpuDqn::new_pooled(c, &ExecPolicy::fp32(), 11, pool.clone());
            let r = bench(
                &format!("train_step/{net_kind}/{}thr (batch {bs})", pool.threads()),
                budget,
                || {
                    observe(model.train(&batch, 1.0).expect("train step"));
                },
            );
            r.print();
            train_rows.push(result_json(
                &r,
                &[
                    ("combo", Json::Str(c.name.to_string())),
                    ("net", Json::Str(net_kind.to_string())),
                    ("batch", Json::Num(bs as f64)),
                    ("threads", Json::Num(pool.threads() as f64)),
                ],
            ));
        }
    }

    // Batched collection throughput: one DQN-CartPole agent driving a
    // BatchedEnv fleet through the full act → step → observe round, at
    // a lane ladder.  Warmup far beyond the budget keeps training out
    // of the loop, so this isolates what `--actors` exists to buy:
    // amortized inference + pooled env stepping.
    println!("== bench_exec [{mode}]: batched collection (env-steps/sec) ==");
    let lane_counts: &[usize] = if smoke { &[1, 8] } else { &[1, 8, 64] };
    let mut actor_rows = Vec::new();
    for &nlanes in lane_counts {
        let mut backend = CpuBackend::fp32().with_warmup(1_000_000_000);
        let mut agent = backend.make_agent(&mlp, 21).expect("agent");
        let envs = (0..nlanes)
            .map(|_| mlp.try_make_env())
            .collect::<Result<Vec<Box<dyn Env>>, _>>()
            .expect("envs");
        let mut root = Rng::new(21);
        let rngs = lane_rngs(&mut root, 0xE74, nlanes);
        let mut fleet = BatchedEnv::new(envs, rngs, Pool::global()).expect("fleet");
        let mut act_rng = root;
        let mut prev_obs = vec![0.0f32; nlanes * fleet.obs_dim()];
        let mut rew = vec![0.0f32; nlanes];
        let mut stats = Vec::new();
        let r = bench(&format!("collect/{nlanes}lanes"), budget, || {
            prev_obs.copy_from_slice(fleet.obs());
            let actions = agent.act(&prev_obs, nlanes, &mut act_rng).expect("act");
            fleet.step(&actions).expect("step");
            for (x, &raw) in rew.iter_mut().zip(fleet.rewards()) {
                *x = raw as f32;
            }
            stats.clear();
            agent
                .observe(
                    &prev_obs,
                    &actions,
                    &rew,
                    fleet.next_obs(),
                    fleet.dones(),
                    &mut act_rng,
                    &mut stats,
                )
                .expect("observe");
        });
        r.print();
        let steps_per_sec = nlanes as f64 / r.median_ns * 1e9;
        println!("   -> {steps_per_sec:.0} env-steps/s at {nlanes} lanes");
        actor_rows.push(result_json(
            &r,
            &[
                ("actors", Json::Num(nlanes as f64)),
                ("env_steps_per_sec", Json::Num(steps_per_sec)),
            ],
        ));
    }

    // Trace-layer overhead: the disarmed span() fast path (one relaxed
    // atomic load + branch) per call, batched 1k per closure so the
    // harness timer resolution doesn't dominate.  tests/trace_overhead.rs
    // pins the no-allocation contract; this pins the wall cost.
    println!("== bench_exec [{mode}]: trace-layer disarmed overhead ==");
    assert!(
        !apdrl::obs::trace::active(),
        "bench_exec must run with tracing disarmed (unset APDRL_TRACE)"
    );
    let mut micro_rows = Vec::new();
    let r = bench("trace_disarmed_span/1k", budget, || {
        for _ in 0..1_000 {
            observe(apdrl::obs::trace::span(
                apdrl::obs::trace::Kernel::GemmNn,
                [8, 8, 8],
                1,
            ));
        }
    });
    r.print();
    println!("   -> {:.2} ns per disarmed span", r.median_ns / 1_000.0);
    micro_rows.push(result_json(
        &r,
        &[("per_span_ns", Json::Num(r.median_ns / 1_000.0))],
    ));

    // Perf-regression guard: committed baseline first, else the previous
    // run's output.  Warn-only — see the module docs.
    let baseline = ["BENCH_exec.baseline.json", "BENCH_exec.json"].iter().find_map(|p| {
        let base = Json::parse(&std::fs::read_to_string(p).ok()?).ok()?;
        Some((p.to_string(), base))
    });
    match baseline {
        Some((path, base)) if base.get("mode").and_then(Json::as_str) == Some(mode) => {
            let (compared, warned) = warn_regressions(
                &base,
                &[
                    ("gemm", gemm_rows.as_slice(), gemm_key as fn(&Json) -> String),
                    ("train_step", train_rows.as_slice(), train_key),
                    ("micro", micro_rows.as_slice(), micro_key),
                ],
            );
            println!(
                "perf guard vs {path}: {compared} medians compared, {warned} regressed >2x \
                 (warn-only)"
            );
        }
        Some((path, _)) => {
            println!("perf guard: {path} is a different mode than {mode:?} — comparison skipped")
        }
        None => println!("perf guard: no readable baseline in cwd — comparison skipped"),
    }

    let mut top = BTreeMap::new();
    top.insert("bench".to_string(), Json::Str("exec".to_string()));
    top.insert("mode".to_string(), Json::Str(mode.to_string()));
    top.insert("threads".to_string(), Json::Num(par_pool.threads() as f64));
    top.insert("gemm".to_string(), Json::Arr(gemm_rows));
    top.insert("speedups".to_string(), Json::Obj(speedups));
    top.insert("train_step".to_string(), Json::Arr(train_rows));
    top.insert("actors".to_string(), Json::Arr(actor_rows));
    top.insert("micro".to_string(), Json::Arr(micro_rows));
    let line = Json::Obj(top).to_line().expect("bench results serialize");
    std::fs::write("BENCH_exec.json", line + "\n").expect("write BENCH_exec.json");
    println!("wrote BENCH_exec.json");
}
