//! Bench: L3 coordinator hot paths — ILP solve, schedule evaluation,
//! replay sampling, env stepping, RNG, JSON parse.  The §Perf iteration
//! log in EXPERIMENTS.md tracks these.

use apdrl::coordinator::combo;
use apdrl::drl::replay::{ReplayBuffer, StoredAction};
use apdrl::envs::{Action, Env};
use apdrl::graph::build_train_graph;
use apdrl::hw::vek280;
use apdrl::partition::heuristics::heft;
use apdrl::partition::{evaluate, solve_ilp, Problem};
use apdrl::profile::profile_dag;
use apdrl::util::bench::{observe, run};
use apdrl::util::json::Json;
use apdrl::util::Rng;

fn main() {
    println!("== bench_hotpath: L3 coordinator internals ==");
    let platform = vek280();
    let c = combo("ddpg_lunar");
    let dag = build_train_graph(&c.train_spec(512));
    let profiles = profile_dag(&dag, &platform, true);
    let problem = Problem::new(&dag, &profiles, &platform, true);
    let sol = solve_ilp(&problem);

    run("ilp_solve/ddpg_lunar_512", || {
        observe(solve_ilp(&problem));
    });
    run("heft/ddpg_lunar_512", || {
        observe(heft(&problem));
    });
    run("schedule_evaluate/ddpg_lunar_512", || {
        observe(evaluate(&problem, &sol.assignment));
    });

    let mut replay = ReplayBuffer::new(50_000, 8);
    let mut rng = Rng::new(1);
    for i in 0..50_000 {
        replay.push(
            &[i as f32; 8],
            StoredAction::Continuous(vec![0.1, 0.2]),
            1.0,
            &[i as f32; 8],
            false,
        );
    }
    run("replay_sample_256/obs8", || {
        observe(replay.sample(256, &mut rng));
    });

    let mut env = apdrl::envs::LunarLanderCont::new();
    env.reset(&mut rng);
    run("env_step/lunar_lander", || {
        let t = env.step(&Action::Continuous(vec![0.4, -0.2]), &mut rng);
        if t.done {
            env.reset(&mut rng);
        }
        observe(t.reward);
    });

    let mut breakout = apdrl::envs::MiniBreakout::mini();
    breakout.reset(&mut rng);
    run("env_step/mini_breakout(render)", || {
        let t = breakout.step(&Action::Discrete(0), &mut rng);
        if t.done {
            breakout.reset(&mut rng);
        }
        observe(t.reward);
    });

    run("rng_normal/1k", || {
        let mut s = 0.0;
        for _ in 0..1000 {
            s += rng.normal();
        }
        observe(s);
    });

    let manifest_text = std::fs::read_to_string(format!(
        "{}/artifacts/manifest.json",
        env!("CARGO_MANIFEST_DIR")
    ))
    .unwrap_or_else(|_| "{}".into());
    run("json_parse/manifest", || {
        observe(Json::parse(&manifest_text).unwrap());
    });
}
