//! Bench: end-to-end PJRT hot path — act and train step latency per
//! combo (the L3 request-loop cost Fig 12/13's throughput depends on).
//! Skips gracefully if artifacts are absent.

use std::time::Duration;

use apdrl::coordinator::combo;
use apdrl::drl::dqn::DqnConfig;
use apdrl::drl::Agent;
use apdrl::envs::Env;
use apdrl::runtime::Runtime;
use apdrl::util::bench::bench;
use apdrl::util::Rng;

fn main() {
    println!("== bench_endtoend: PJRT act/train latency ==");
    let dir = format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"));
    let Ok(mut rt) = Runtime::new(&dir) else {
        println!("(artifacts missing; run `make artifacts`)");
        return;
    };
    for (name, mode) in
        [("dqn_cartpole", "mixed"), ("dqn_cartpole", "fp32"), ("dqn_breakout_mini", "mixed")]
    {
        let c = combo(name);
        let obs_shape = match &c.net {
            apdrl::graph::NetSpec::Mlp { .. } => vec![c.obs_dim],
            apdrl::graph::NetSpec::Conv { in_hw, in_ch, .. } => vec![*in_hw, *in_hw, *in_ch],
        };
        let cfg = DqnConfig {
            warmup: 64,
            ..DqnConfig::for_combo(c.batch, obs_shape, c.act_dim)
        };
        let mut agent = apdrl::drl::pjrt::dqn_agent(&mut rt, name, mode, cfg, 1).unwrap();
        let mut env = c.make_env();
        let mut rng = Rng::new(1);
        let mut obs = env.reset(&mut rng);
        // warm the replay buffer so observe() trains every step
        let mut stats = Vec::new();
        for _ in 0..80 {
            let a = agent.act(&obs, 1, &mut rng).unwrap();
            let t = env.step(&a[0], &mut rng);
            stats.clear();
            agent
                .observe(&obs, &a, &[t.reward as f32], &t.obs, &[t.done], &mut rng, &mut stats)
                .unwrap();
            obs = if t.done { env.reset(&mut rng) } else { t.obs };
        }
        let r = bench(&format!("act/{name}/{mode}"), Duration::from_secs(2), || {
            let _ = agent.act_greedy(&obs, 1).unwrap();
        });
        r.print();
        let r = bench(&format!("env_act_train_step/{name}/{mode}"), Duration::from_secs(4), || {
            let a = agent.act(&obs, 1, &mut rng).unwrap();
            let t = env.step(&a[0], &mut rng);
            stats.clear();
            agent
                .observe(&obs, &a, &[t.reward as f32], &t.obs, &[t.done], &mut rng, &mut stats)
                .unwrap();
            obs = if t.done { env.reset(&mut rng) } else { t.obs };
        });
        r.print();
    }
}
