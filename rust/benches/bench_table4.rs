//! Bench: Table IV regeneration — the full static phase (profile + ILP +
//! schedule) per network size, FP32 vs quantized.

use apdrl::coordinator::{combo, static_phase};
use apdrl::graph::NetSpec;
use apdrl::util::bench::{observe, run};

fn main() {
    println!("== bench_table4: static phase per Table-IV network ==");
    for (label, sizes) in [
        ("64x64", vec![4usize, 64, 64, 2]),
        ("400x300", vec![4, 400, 300, 2]),
        ("4096x3072", vec![4, 4096, 3072, 2]),
    ] {
        let mut c = combo("dqn_cartpole");
        c.net = NetSpec::Mlp { sizes };
        run(&format!("static_phase_quant/{label}"), || {
            observe(static_phase(&c, 64, true));
        });
        run(&format!("static_phase_fp32/{label}"), || {
            observe(static_phase(&c, 64, false));
        });
    }
}
