//! Bench: Table IV regeneration — the full static phase (profile + ILP +
//! schedule) per network size, FP32 vs quantized, plus the planning
//! service around it: cold solves (cache cleared every iteration), cached
//! re-plans (the O(1) hit path) and the batched `plan_sweep` that plans
//! the whole Table IV grid concurrently.

use apdrl::coordinator::{combo, plan_sweep, static_phase, ComboConfig, PlanRequest};
use apdrl::graph::NetSpec;
use apdrl::partition::cache;
use apdrl::util::bench::{observe, run};

fn table4_combo(sizes: &[usize]) -> ComboConfig {
    let mut c = combo("dqn_cartpole");
    c.net = NetSpec::mlp(sizes);
    c
}

fn main() {
    println!("== bench_table4: static phase per Table-IV network ==");
    let sizes: [(&str, Vec<usize>); 3] = [
        ("64x64", vec![4usize, 64, 64, 2]),
        ("400x300", vec![4, 400, 300, 2]),
        ("4096x3072", vec![4, 4096, 3072, 2]),
    ];
    for (label, sizes_v) in &sizes {
        let c = table4_combo(sizes_v);
        run(&format!("static_phase_quant_cold/{label}"), || {
            cache::global().lock().unwrap().clear();
            observe(static_phase(&c, 64, true));
        });
        run(&format!("static_phase_fp32_cold/{label}"), || {
            cache::global().lock().unwrap().clear();
            observe(static_phase(&c, 64, false));
        });
        // The memoized path: everything after the first solve is a
        // cache hit — this is the steady-state cost of a re-plan.
        static_phase(&c, 64, true);
        run(&format!("static_phase_quant_cached/{label}"), || {
            let plan = static_phase(&c, 64, true);
            assert!(plan.cache_hit, "steady-state re-plan must hit the cache");
            observe(plan);
        });
    }

    // Whole-grid batched planning (cold): 3 networks × 2 precisions.
    let requests: Vec<PlanRequest> = sizes
        .iter()
        .flat_map(|(_, sizes_v)| {
            let c = table4_combo(sizes_v);
            [PlanRequest::new(c.clone(), 64, false), PlanRequest::new(c, 64, true)]
        })
        .collect();
    run("plan_sweep_table4_grid_cold/6pts", || {
        cache::global().lock().unwrap().clear();
        observe(plan_sweep(&requests));
    });
}
