//! Layer-level CDFG of a DRL training step (paper §IV-A/§IV-B).
//!
//! The paper converts the C/C++ training loop through Clang/LLVM into a
//! control-data-flow graph whose nodes are *network layers*; we build the
//! same graph directly from the network + algorithm specification (the
//! information content is identical — layer kinds, shapes and
//! dependencies — without the C-frontend detour, which is not the
//! contribution).  Nodes are classified MM vs non-MM exactly as §IV-A:
//! MM layers may go to PL or AIE, non-MM layers are pinned to PL.

pub mod builder;
pub mod dag;
pub mod flops;
pub mod layer;

pub use builder::{build_train_graph, critic_spec, value_spec, Algo, NetSpec, TrainSpec};
pub use dag::Dag;
pub use layer::{LayerKind, Node, Phase};
