//! Build the training-step CDFG for each algorithm (paper §IV-A: the
//! CDFG the LLVM pass extracts, with layers as nodes).
//!
//! Node emission per algorithm follows the compute structure the paper
//! describes in §IV-B: DQN needs two forward passes (online + target) and
//! one backward pass (Eq. 1); DDPG runs four networks with two backward
//! passes; A2C/PPO run actor-critic forwards plus one joint backward.

use super::dag::Dag;
use super::flops::conv_gemm_dims;
use super::layer::{LayerKind, Node, Phase};

/// Network architecture (Table III).
#[derive(Clone, Debug)]
pub enum NetSpec {
    /// Dense sizes `[d0, d1, ..., dk]`.
    Mlp { sizes: Vec<usize> },
    /// Conv trunk + FC head: input `in_hw`×`in_hw`×`in_ch`,
    /// conv layers `(cout, ksize, stride)`, then dense sizes.
    Conv { in_hw: usize, in_ch: usize, conv: Vec<(usize, usize, usize)>, fc: Vec<usize> },
}

impl NetSpec {
    pub fn mlp(sizes: &[usize]) -> Self {
        NetSpec::Mlp { sizes: sizes.to_vec() }
    }

    /// Weight elements of the whole network (master-weight volume).
    pub fn weight_elems(&self) -> usize {
        match self {
            NetSpec::Mlp { sizes } => sizes
                .windows(2)
                .map(|w| w[0] * w[1] + w[1])
                .sum(),
            NetSpec::Conv { in_hw, in_ch, conv, fc } => {
                let mut total = 0;
                let (mut h, mut c) = (*in_hw, *in_ch);
                for &(cout, k, s) in conv {
                    total += k * k * c * cout + cout;
                    h = (h - k) / s + 1;
                    c = cout;
                }
                let mut din = h * h * c;
                for &dout in fc {
                    total += din * dout + dout;
                    din = dout;
                }
                total
            }
        }
    }
}

/// DRL algorithm shape (which networks + passes the train step runs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algo {
    Dqn,
    Ddpg,
    A2c,
    Ppo,
}

impl Algo {
    pub fn name(self) -> &'static str {
        match self {
            Algo::Dqn => "DQN",
            Algo::Ddpg => "DDPG",
            Algo::A2c => "A2C",
            Algo::Ppo => "PPO",
        }
    }

    /// Whether the algorithm emits discrete actions (DQN/PPO) rather
    /// than continuous vectors (DDPG/A2C) — checked against the env's
    /// action space before training starts.
    pub fn discrete_actions(self) -> bool {
        matches!(self, Algo::Dqn | Algo::Ppo)
    }
}

/// Everything needed to build one training-step graph.
#[derive(Clone, Debug)]
pub struct TrainSpec {
    pub algo: Algo,
    pub net: NetSpec,
    pub batch: usize,
    /// Observation/action dims (critic input sizing for DDPG).
    pub obs_dim: usize,
    pub act_dim: usize,
}

/// Per-layer GEMM dims of a network at batch `bs`:
/// (name, m, k, n, out_elems, weight_elems).
fn layer_dims(net: &NetSpec, bs: usize) -> Vec<(String, usize, usize, usize, usize, usize)> {
    match net {
        NetSpec::Mlp { sizes } => sizes
            .windows(2)
            .enumerate()
            .map(|(i, w)| {
                let (din, dout) = (w[0], w[1]);
                (format!("fc{i}"), bs, din, dout, bs * dout, din * dout + dout)
            })
            .collect(),
        NetSpec::Conv { in_hw, in_ch, conv, fc } => {
            let mut out = Vec::new();
            let (mut h, mut c) = (*in_hw, *in_ch);
            for (i, &(cout, k, s)) in conv.iter().enumerate() {
                let (m, kk, n, oh, _ow) = conv_gemm_dims(bs, h, h, c, cout, k, s);
                out.push((
                    format!("conv{i}"),
                    m,
                    kk,
                    n,
                    m * n,
                    k * k * c * cout + cout,
                ));
                h = oh;
                c = cout;
            }
            let mut din = h * h * c;
            for (j, &dout) in fc.iter().enumerate() {
                out.push((format!("fc{j}"), bs, din, dout, bs * dout, din * dout + dout));
                din = dout;
            }
            out
        }
    }
}

struct Emitter<'a> {
    dag: &'a mut Dag,
}

impl<'a> Emitter<'a> {
    fn mm(&mut self, name: String, phase: Phase, m: usize, k: usize, n: usize, w: usize, deps: &[usize]) -> usize {
        self.dag.add(
            Node {
                id: 0,
                name,
                phase,
                kind: LayerKind::Mm { m, k, n },
                weight_elems: w,
                out_elems: m * n,
            },
            deps,
        )
    }

    fn elem(&mut self, name: String, phase: Phase, elems: usize, deps: &[usize]) -> usize {
        self.dag.add(
            Node {
                id: 0,
                name,
                phase,
                kind: LayerKind::Elementwise { elems },
                weight_elems: 0,
                out_elems: elems,
            },
            deps,
        )
    }

    /// Weight-update node: elementwise over `w` weight elements, and
    /// carries that volume for master-weight sync accounting (Fig 10).
    fn upd(&mut self, name: String, w: usize, deps: &[usize]) -> usize {
        self.dag.add(
            Node {
                id: 0,
                name,
                phase: Phase::Update,
                kind: LayerKind::Elementwise { elems: w },
                weight_elems: w,
                out_elems: w,
            },
            deps,
        )
    }

    fn reduce(&mut self, name: String, elems: usize, deps: &[usize]) -> usize {
        self.dag.add(
            Node {
                id: 0,
                name,
                phase: Phase::Loss,
                kind: LayerKind::Reduce { elems },
                weight_elems: 0,
                out_elems: 1,
            },
            deps,
        )
    }

    /// Forward pass: per layer an MM node + (except last) an activation
    /// node.  Returns (last node id, MM node ids).
    fn forward(
        &mut self,
        tag: &str,
        dims: &[(String, usize, usize, usize, usize, usize)],
        entry_dep: Option<usize>,
    ) -> (usize, Vec<usize>) {
        let mut mm_ids = Vec::new();
        let mut prev: Option<usize> = entry_dep;
        for (i, (lname, m, k, n, out, w)) in dims.iter().enumerate() {
            let deps: Vec<usize> = prev.into_iter().collect();
            let mm =
                self.mm(format!("{tag}/{lname}/fwd"), Phase::Forward, *m, *k, *n, *w, &deps);
            mm_ids.push(mm);
            prev = Some(if i < dims.len() - 1 {
                self.elem(format!("{tag}/{lname}/act"), Phase::Forward, *out, &[mm])
            } else {
                mm
            });
        }
        (prev.unwrap(), mm_ids)
    }

    /// Backward pass over `dims` (reverse order): per layer one MM node
    /// covering dx+dw (the two GEMMs stay on one component — same
    /// argument as §IV-B: splitting a layer costs communication), plus
    /// an update node.  `fwd_mms[i]` is the matching forward node (bwd
    /// needs its saved activations) and `loss` the gradient source.
    fn backward(
        &mut self,
        tag: &str,
        dims: &[(String, usize, usize, usize, usize, usize)],
        fwd_mms: &[usize],
        loss: usize,
    ) -> Vec<usize> {
        let mut updates = Vec::new();
        let mut grad_dep = loss;
        for (i, (lname, m, k, n, _out, w)) in dims.iter().enumerate().rev() {
            // dx (m×n)·(n×k) + dw (k×m)·(m×n): fold to one MM with 2× k
            let bwd = self.mm(
                format!("{tag}/{lname}/bwd"),
                Phase::Backward,
                *m,
                2 * *k,
                *n,
                0,
                &[grad_dep, fwd_mms[i]],
            );
            let upd = self.upd(format!("{tag}/{lname}/update"), *w, &[bwd]);
            updates.push(upd);
            grad_dep = bwd;
        }
        updates
    }
}

/// Build the full training-step DAG for `spec` (paper §IV-C input).
pub fn build_train_graph(spec: &TrainSpec) -> Dag {
    let mut dag = Dag::new();
    let mut e = Emitter { dag: &mut dag };
    let bs = spec.batch;
    match spec.algo {
        Algo::Dqn => {
            let dims = layer_dims(&spec.net, bs);
            let (q_out, q_mms) = e.forward("online", &dims, None);
            let (t_out, _) = e.forward("target", &dims, None);
            let loss = e.reduce("td_loss".into(), bs * spec.act_dim, &[q_out, t_out]);
            e.backward("online", &dims, &q_mms, loss);
        }
        Algo::Ddpg => {
            // Critic target path: a' = t_actor(s'), q' = t_critic(s', a')
            let actor_dims = layer_dims(&spec.net, bs);
            let critic_net = critic_spec(&spec.net, spec.obs_dim, spec.act_dim);
            let critic_dims = layer_dims(&critic_net, bs);
            let (ta_out, _) = e.forward("t_actor", &actor_dims, None);
            let (tc_out, _) = e.forward("t_critic", &critic_dims, Some(ta_out));
            // Critic update: q = critic(s, a); loss; backward.
            let (c_out, c_mms) = e.forward("critic", &critic_dims, None);
            let closs = e.reduce("critic_loss".into(), bs, &[c_out, tc_out]);
            e.backward("critic", &critic_dims, &c_mms, closs);
            // Actor update: a = actor(s); q = critic(s, a); backward.
            let (a_out, a_mms) = e.forward("actor", &actor_dims, None);
            let (cq_out, _) = e.forward("critic_for_actor", &critic_dims, Some(a_out));
            let aloss = e.reduce("actor_loss".into(), bs, &[cq_out]);
            let a_updates = e.backward("actor", &actor_dims, &a_mms, aloss);
            // Soft target updates depend on the new weights.
            let w_a = spec.net.weight_elems();
            let w_c = critic_net.weight_elems();
            e.upd("t_actor/soft_update".into(), w_a, &a_updates.clone());
            e.upd("t_critic/soft_update".into(), w_c, &[closs]);
        }
        Algo::A2c | Algo::Ppo => {
            let pi_dims = layer_dims(&spec.net, bs);
            let v_net = value_spec(&spec.net);
            let v_dims = layer_dims(&v_net, bs);
            let (pi_out, pi_mms) = e.forward("actor", &pi_dims, None);
            let (v_out, v_mms) = e.forward("value", &v_dims, None);
            let loss_elems = bs * (spec.act_dim + 1);
            let name = if spec.algo == Algo::Ppo { "ppo_clip_loss" } else { "a2c_loss" };
            let loss = e.reduce(name.into(), loss_elems, &[pi_out, v_out]);
            e.backward("actor", &pi_dims, &pi_mms, loss);
            e.backward("value", &v_dims, &v_mms, loss);
        }
    }
    dag
}

/// DDPG critic: same hidden sizes, input obs+act, scalar output.  Public
/// because the CPU execution backend instantiates the same network
/// shapes the CDFG describes.
pub fn critic_spec(net: &NetSpec, obs_dim: usize, act_dim: usize) -> NetSpec {
    match net {
        NetSpec::Mlp { sizes } => {
            let mut s = sizes.clone();
            s[0] = obs_dim + act_dim;
            *s.last_mut().unwrap() = 1;
            NetSpec::Mlp { sizes: s }
        }
        NetSpec::Conv { .. } => panic!("conv critic not used by Table III DDPG combos"),
    }
}

/// A2C/PPO value net: same trunk, scalar head.  Public for the same
/// reason as [`critic_spec`].
pub fn value_spec(net: &NetSpec) -> NetSpec {
    match net {
        NetSpec::Mlp { sizes } => {
            let mut s = sizes.clone();
            *s.last_mut().unwrap() = 1;
            NetSpec::Mlp { sizes: s }
        }
        NetSpec::Conv { in_hw, in_ch, conv, fc } => {
            let mut f = fc.clone();
            *f.last_mut().unwrap() = 1;
            NetSpec::Conv { in_hw: *in_hw, in_ch: *in_ch, conv: conv.clone(), fc: f }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::Phase;

    fn cartpole_spec() -> TrainSpec {
        TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(&[4, 64, 64, 2]),
            batch: 64,
            obs_dim: 4,
            act_dim: 2,
        }
    }

    #[test]
    fn dqn_graph_structure() {
        let g = build_train_graph(&cartpole_spec());
        // 2 forwards × (3 MM + 2 act) + loss + 3 bwd + 3 update = 17
        assert_eq!(g.len(), 17);
        assert_eq!(g.mm_nodes().len(), 9); // 3+3 fwd MM + 3 bwd MM
        assert!(!g.sinks().is_empty());
        g.topo_order(); // must not panic
    }

    #[test]
    fn dqn_breakout_has_15_mm_layers() {
        // Paper Fig 8: DQN-Breakout training touches 15 distinct layers
        // (5 per fwd pass × 2 passes + 5 bwd).
        let spec = TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::Conv {
                in_hw: 84,
                in_ch: 4,
                conv: vec![(32, 8, 4), (64, 4, 2), (64, 3, 1)],
                fc: vec![512, 4],
            },
            batch: 32,
            obs_dim: 84 * 84 * 4,
            act_dim: 4,
        };
        let g = build_train_graph(&spec);
        assert_eq!(g.mm_nodes().len(), 15);
    }

    #[test]
    fn ddpg_graph_has_four_networks() {
        let spec = TrainSpec {
            algo: Algo::Ddpg,
            net: NetSpec::mlp(&[8, 400, 300, 2]),
            batch: 256,
            obs_dim: 8,
            act_dim: 2,
        };
        let g = build_train_graph(&spec);
        // 6 forward passes (t_actor, t_critic, critic, actor, critic_for_actor ... )
        let fwd_mm = g
            .nodes
            .iter()
            .filter(|n| n.phase == Phase::Forward && n.kind.is_mm())
            .count();
        assert_eq!(fwd_mm, 5 * 3); // 5 forward passes × 3 layers
        let bwd_mm = g
            .nodes
            .iter()
            .filter(|n| n.phase == Phase::Backward)
            .count();
        assert_eq!(bwd_mm, 6); // critic + actor backward × 3 layers
        g.topo_order();
    }

    #[test]
    fn a2c_and_ppo_share_shape() {
        for algo in [Algo::A2c, Algo::Ppo] {
            let spec = TrainSpec {
                algo,
                net: NetSpec::mlp(&[4, 64, 64, 1]),
                batch: 64,
                obs_dim: 4,
                act_dim: 1,
            };
            let g = build_train_graph(&spec);
            assert_eq!(g.mm_nodes().len(), 12); // 2 fwd × 3 + 2 bwd × 3
        }
    }

    #[test]
    fn flops_scale_with_batch() {
        let mut spec = cartpole_spec();
        let f1 = build_train_graph(&spec).total_flops();
        spec.batch = 128;
        let f2 = build_train_graph(&spec).total_flops();
        assert!(f2 > 1.9 * f1 && f2 < 2.1 * f1);
    }

    #[test]
    fn weight_elems_accounting() {
        let net = NetSpec::mlp(&[4, 64, 64, 2]);
        assert_eq!(net.weight_elems(), 4 * 64 + 64 + 64 * 64 + 64 + 64 * 2 + 2);
        let conv = NetSpec::Conv {
            in_hw: 12,
            in_ch: 4,
            conv: vec![(8, 4, 2), (16, 3, 1)],
            fc: vec![128, 4],
        };
        // conv1: 4*4*4*8+8, 12->5; conv2: 3*3*8*16+16, 5->3; flat=144
        let expect = 4 * 4 * 4 * 8 + 8 + 3 * 3 * 8 * 16 + 16 + 144 * 128 + 128 + 128 * 4 + 4;
        assert_eq!(conv.weight_elems(), expect);
    }
}
