//! FLOP/shape accounting for network layers (paper Fig 8 / Table III).
//!
//! Dense fwd:  2·bs·din·dout
//! Dense bwd:  dx = 2·bs·dout·din, dw = 2·din·bs·dout  (two GEMMs)
//! Conv fwd (im2col GEMM): m = bs·oh·ow, k = kh·kw·cin, n = cout
//! Adam update: ~10 ops per weight element.

/// im2col GEMM dims of a VALID conv: returns (m, k, n, oh, ow).
pub fn conv_gemm_dims(
    bs: usize,
    in_h: usize,
    in_w: usize,
    cin: usize,
    cout: usize,
    ksize: usize,
    stride: usize,
) -> (usize, usize, usize, usize, usize) {
    assert!(in_h >= ksize && in_w >= ksize, "conv kernel larger than input");
    let oh = (in_h - ksize) / stride + 1;
    let ow = (in_w - ksize) / stride + 1;
    (bs * oh * ow, ksize * ksize * cin, cout, oh, ow)
}

/// Forward FLOPs of a dense layer.
pub fn dense_fwd_flops(bs: usize, din: usize, dout: usize) -> f64 {
    2.0 * bs as f64 * din as f64 * dout as f64
}

/// Table III "Train FLOPs (Per Batch Size)" = fwd + bwd per batch element
/// over all passes of the algorithm; helper for one dense layer
/// (fwd + dx + dw = 3 GEMMs ≈ 6·din·dout per row).
pub fn dense_train_flops_per_row(din: usize, dout: usize) -> f64 {
    6.0 * din as f64 * dout as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nature_dqn_conv_dims() {
        // Table III Breakout: 84x84x4 -Conv(32,8,4)-> 20x20x32
        let (m, k, n, oh, ow) = conv_gemm_dims(32, 84, 84, 4, 32, 8, 4);
        assert_eq!((oh, ow), (20, 20));
        assert_eq!(m, 32 * 400);
        assert_eq!(k, 8 * 8 * 4);
        assert_eq!(n, 32);
        // -Conv(64,4,2)-> 9x9x64
        let (_, _, _, oh, ow) = conv_gemm_dims(32, 20, 20, 32, 64, 4, 2);
        assert_eq!((oh, ow), (9, 9));
        // -Conv(64,3,1)-> 7x7x64 -> flatten 3136
        let (_, _, _, oh, ow) = conv_gemm_dims(32, 9, 9, 64, 64, 3, 1);
        assert_eq!((oh, ow), (7, 7));
        assert_eq!(7 * 7 * 64, 3136);
    }

    #[test]
    fn dense_flops() {
        assert_eq!(dense_fwd_flops(64, 4, 64), 2.0 * 64.0 * 4.0 * 64.0);
        assert_eq!(dense_train_flops_per_row(4, 64), 6.0 * 4.0 * 64.0);
    }

    /// Table III sanity: CartPole DQN "Train FLOPs per batch size" is
    /// 28.04K.  DQN does 2 forwards (online + target) + 1 backward
    /// (≈ 2 fwd-equivalents): ≈ 4 × fwd-flops-per-row.
    /// fwd/row = 2·(4·64 + 64·64 + 64·2) = 9.2K → ≈ 4× ≈ 36.9K; the
    /// paper's 28.04K ≈ 3× (counting bwd as ≈1 fwd into the target-less
    /// path).  We assert the same order of magnitude, not the exact
    /// accounting convention.
    #[test]
    fn cartpole_flops_order_of_magnitude() {
        let fwd: f64 = 2.0 * (4.0 * 64.0 + 64.0 * 64.0 + 64.0 * 2.0);
        assert!((2.0 * fwd..5.0 * fwd).contains(&28_040.0));
    }
}
