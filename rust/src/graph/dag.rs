//! DAG container: nodes + dependency edges, topological order, critical
//! path — the `G = (V, E)` of the paper's ILP formulation (§IV-C).

use super::layer::Node;

#[derive(Clone, Debug)]
pub struct Dag {
    pub nodes: Vec<Node>,
    /// preds[i] = Γ⁻(i): nodes that must complete before i starts.
    pub preds: Vec<Vec<usize>>,
    /// succs[i] = Γ⁺(i).
    pub succs: Vec<Vec<usize>>,
}

impl Dag {
    pub fn new() -> Self {
        Dag { nodes: Vec::new(), preds: Vec::new(), succs: Vec::new() }
    }

    /// Append a node depending on `deps`; returns its id.
    pub fn add(&mut self, mut node: Node, deps: &[usize]) -> usize {
        let id = self.nodes.len();
        node.id = id;
        for &d in deps {
            assert!(d < id, "dependency {d} must precede node {id}");
            self.succs[d].push(id);
        }
        self.nodes.push(node);
        self.preds.push(deps.to_vec());
        self.succs.push(Vec::new());
        id
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Sink nodes ({i ∈ V | Γ⁺(i) = ∅} in Eq. 6).
    pub fn sinks(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.succs[i].is_empty()).collect()
    }

    /// Kahn topological order.  Construction guarantees acyclicity
    /// (edges only point forward), so this cannot fail; kept as a checked
    /// API for robustness against future builders.
    pub fn topo_order(&self) -> Vec<usize> {
        let mut indeg: Vec<usize> = self.preds.iter().map(|p| p.len()).collect();
        let mut queue: Vec<usize> = (0..self.len()).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(self.len());
        while let Some(i) = queue.pop() {
            order.push(i);
            for &s in &self.succs[i] {
                indeg[s] -= 1;
                if indeg[s] == 0 {
                    queue.push(s);
                }
            }
        }
        assert_eq!(order.len(), self.len(), "cycle in CDFG");
        order
    }

    /// Longest path through the DAG weighting node i by `cost(i)` —
    /// the makespan lower bound no schedule can beat.
    pub fn critical_path(&self, cost: impl Fn(usize) -> f64) -> f64 {
        let order = self.topo_order();
        let mut finish = vec![0.0f64; self.len()];
        let mut best: f64 = 0.0;
        for &i in &order {
            let start = self.preds[i].iter().map(|&p| finish[p]).fold(0.0, f64::max);
            finish[i] = start + cost(i);
            best = best.max(finish[i]);
        }
        best
    }

    /// Total FLOPs across all nodes.
    pub fn total_flops(&self) -> f64 {
        self.nodes.iter().map(|n| n.flops()).sum()
    }

    /// Ids of MM nodes (the PL/AIE decision variables of the ILP).
    pub fn mm_nodes(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.nodes[i].kind.is_mm()).collect()
    }
}

impl Default for Dag {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::{LayerKind, Node, Phase};

    fn node(name: &str) -> Node {
        Node {
            id: 0,
            name: name.into(),
            phase: Phase::Forward,
            kind: LayerKind::Mm { m: 2, k: 2, n: 2 },
            weight_elems: 0,
            out_elems: 4,
        }
    }

    fn diamond() -> Dag {
        let mut g = Dag::new();
        let a = g.add(node("a"), &[]);
        let b = g.add(node("b"), &[a]);
        let c = g.add(node("c"), &[a]);
        let _d = g.add(node("d"), &[b, c]);
        g
    }

    #[test]
    fn topo_respects_edges() {
        let g = diamond();
        let order = g.topo_order();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (idx, &n) in order.iter().enumerate() {
                p[n] = idx;
            }
            p
        };
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn sinks_found() {
        let g = diamond();
        assert_eq!(g.sinks(), vec![3]);
    }

    #[test]
    fn critical_path_unit_costs() {
        let g = diamond();
        // longest chain a -> b/c -> d = 3 nodes
        assert_eq!(g.critical_path(|_| 1.0), 3.0);
    }

    #[test]
    #[should_panic(expected = "dependency")]
    fn forward_edges_only() {
        let mut g = Dag::new();
        let a = g.add(node("a"), &[]);
        let _ = g.add(node("b"), &[a + 1]); // future dep: must panic
    }
}
