//! Node (layer) types of the training-step CDFG.

/// Which training phase a node belongs to (paper Fig 5 breakdown).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Loss,
    Backward,
    Update,
}

impl Phase {
    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::Loss => "loss",
            Phase::Backward => "backward",
            Phase::Update => "update",
        }
    }
}

/// Computational shape of a layer node.
#[derive(Clone, Debug, PartialEq)]
pub enum LayerKind {
    /// GEMM: (m × k) · (k × n).  Dense layers, and conv layers via their
    /// im2col GEMM shape (a conv *is* an MM node in the paper's taxonomy).
    Mm { m: usize, k: usize, n: usize },
    /// Elementwise non-MM op (activation, weight update, scaling...).
    Elementwise { elems: usize },
    /// Reduction non-MM op (loss, max over actions...).
    Reduce { elems: usize },
}

impl LayerKind {
    /// MM nodes are PL/AIE candidates; non-MM nodes are pinned to PL
    /// (paper §IV-A: "Non-MM layers, being unsuitable for AIE
    /// acceleration…are typically allocated to the PL").
    pub fn is_mm(&self) -> bool {
        matches!(self, LayerKind::Mm { .. })
    }

    /// Total arithmetic operations for this node.
    pub fn flops(&self) -> f64 {
        match *self {
            LayerKind::Mm { m, k, n } => 2.0 * m as f64 * k as f64 * n as f64,
            LayerKind::Elementwise { elems } => elems as f64,
            LayerKind::Reduce { elems } => 2.0 * elems as f64,
        }
    }

    /// Bytes touched assuming 2-byte operands (format multipliers are
    /// applied by the profiling models).
    pub fn bytes(&self, elem_bytes: usize) -> f64 {
        let e = elem_bytes as f64;
        match *self {
            LayerKind::Mm { m, k, n } => {
                (m * k + k * n + m * n) as f64 * e
            }
            LayerKind::Elementwise { elems } => 2.0 * elems as f64 * e,
            LayerKind::Reduce { elems } => elems as f64 * e,
        }
    }
}

/// One node of the training CDFG.
#[derive(Clone, Debug)]
pub struct Node {
    pub id: usize,
    pub name: String,
    pub phase: Phase,
    pub kind: LayerKind,
    /// Number of weight elements updated in place here (update nodes);
    /// drives master-weight sync volume for the quantization overhead.
    pub weight_elems: usize,
    /// Output activation elements (payload of outgoing edges).
    pub out_elems: usize,
}

impl Node {
    pub fn flops(&self) -> f64 {
        self.kind.flops()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm_classification() {
        assert!(LayerKind::Mm { m: 4, k: 4, n: 4 }.is_mm());
        assert!(!LayerKind::Elementwise { elems: 10 }.is_mm());
        assert!(!LayerKind::Reduce { elems: 10 }.is_mm());
    }

    #[test]
    fn gemm_flops() {
        let k = LayerKind::Mm { m: 64, k: 4, n: 64 };
        assert_eq!(k.flops(), 2.0 * 64.0 * 4.0 * 64.0);
    }

    #[test]
    fn bytes_scale_with_format() {
        let k = LayerKind::Mm { m: 8, k: 8, n: 8 };
        assert_eq!(k.bytes(4), 2.0 * k.bytes(2));
    }
}
