//! The process-wide event bus: a bounded, ring-buffered fan-out of
//! structured [`Event`]s from the trainer, planner, and federation
//! layers to any number of live subscribers.
//!
//! Design constraints (in priority order):
//!
//! 1. **Zero-cost when nobody is watching.** [`Bus::publish`] starts
//!    with one relaxed atomic load of the subscriber count and returns
//!    immediately when it is zero — no lock, no allocation, no clone.
//!    Hot paths additionally guard event *construction* behind
//!    [`active`] so an unobserved training loop never formats a field.
//! 2. **A publisher never blocks on a slow consumer.** The ring is
//!    bounded ([`RING_CAPACITY`]); when full, the oldest event is
//!    dropped and subscribers learn how many they missed via
//!    [`Drained::dropped`] (computed from the monotone sequence
//!    numbers), so back-pressure flows to the dashboard, never into
//!    the training loop.
//! 3. **Observation never mutates.** Publishing touches no RNG and no
//!    training state; the `--actors 1` bit-identity tests in
//!    `tests/train.rs` run with a live subscriber attached to pin this.
//!
//! Events are plain `kind` + field-map records serialized through
//! [`util::json`](crate::util::json), so the same struct rides the SSE
//! wire, the `/snapshot` view, and the `/emit` ingest path.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

/// Bounded ring size: enough to replay a recent history to a freshly
/// attached dashboard without letting an abandoned stream grow the heap.
pub const RING_CAPACITY: usize = 1024;

/// One structured telemetry record. `seq` is assigned by the bus at
/// publish time and is monotone per bus, which is how subscribers
/// detect overflow drops.
#[derive(Clone, Debug)]
pub struct Event {
    pub seq: u64,
    /// Dotted taxonomy name, e.g. `train.episode` or `sweep.point`
    /// (the full taxonomy is tabulated in [`crate::obs`]).
    pub kind: String,
    pub fields: BTreeMap<String, Json>,
}

impl Event {
    pub fn new(kind: &str) -> Event {
        Event { seq: 0, kind: kind.to_string(), fields: BTreeMap::new() }
    }

    /// Attach an arbitrary JSON field (builder style).
    pub fn with(mut self, key: &str, value: Json) -> Event {
        self.fields.insert(key.to_string(), value);
        self
    }

    pub fn num(self, key: &str, value: f64) -> Event {
        self.with(key, Json::Num(value))
    }

    pub fn tag(self, key: &str, value: &str) -> Event {
        self.with(key, Json::Str(value.to_string()))
    }

    pub fn flag(self, key: &str, value: bool) -> Event {
        self.with(key, Json::Bool(value))
    }

    /// Flatten to one JSON object: the fields plus reserved `seq` and
    /// `kind` keys (which shadow any field of the same name).
    pub fn to_json(&self) -> Json {
        let mut obj = self.fields.clone();
        obj.insert("seq".to_string(), Json::Num(self.seq as f64));
        obj.insert("kind".to_string(), Json::Str(self.kind.clone()));
        Json::Obj(obj)
    }

    /// Parse an ingested object back into an event (`/emit` path).
    /// `seq` is ignored — the receiving bus assigns its own. Kinds are
    /// validated because they are echoed verbatim into SSE `event:`
    /// frame headers.
    pub fn from_json(v: &Json) -> Result<Event> {
        let obj = v.as_obj().ok_or_else(|| anyhow!("event must be a JSON object"))?;
        let kind = v
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("event is missing its `kind` field"))?;
        let tame = |c: char| c.is_ascii_alphanumeric() || matches!(c, '.' | '_' | '-');
        if kind.is_empty() || kind.len() > 64 || !kind.chars().all(tame) {
            return Err(anyhow!("event kind {kind:?} is not a dotted identifier"));
        }
        let mut fields = BTreeMap::new();
        for (key, value) in obj {
            if key != "kind" && key != "seq" {
                fields.insert(key.clone(), value.clone());
            }
        }
        Ok(Event { seq: 0, kind: kind.to_string(), fields })
    }
}

struct Ring {
    buf: VecDeque<Event>,
    /// Sequence number the next published event will get; the oldest
    /// retained event is therefore `next_seq - buf.len()`.
    next_seq: u64,
    capacity: usize,
}

impl Ring {
    fn oldest_seq(&self) -> u64 {
        self.next_seq - self.buf.len() as u64
    }
}

/// The bus itself. Cheap to share (`Arc`); one global instance serves
/// the whole process via [`global`].
pub struct Bus {
    subscribers: AtomicUsize,
    inner: Mutex<Ring>,
    wake: Condvar,
    /// Events accepted into the ring over the bus lifetime.
    published: AtomicU64,
    /// Events evicted unread by ring overflow — the fleet-wide view of
    /// the per-subscriber [`Drained::dropped`] gaps.
    dropped: AtomicU64,
}

/// A self-telemetry snapshot of one bus (the `obs.stats` payload and
/// the `stats` verb's `obs` section). The no-subscriber fast path is
/// deliberately uncounted so it stays a single atomic load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BusCounters {
    pub published: u64,
    pub dropped: u64,
    pub subscribers: usize,
}

impl Bus {
    pub fn new() -> Arc<Bus> {
        Bus::with_capacity(RING_CAPACITY)
    }

    /// Custom ring size — for tests that want to force overflow fast.
    pub fn with_capacity(capacity: usize) -> Arc<Bus> {
        Arc::new(Bus {
            subscribers: AtomicUsize::new(0),
            inner: Mutex::new(Ring {
                buf: VecDeque::with_capacity(capacity.max(1)),
                next_seq: 0,
                capacity: capacity.max(1),
            }),
            wake: Condvar::new(),
            published: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        })
    }

    /// The publish fast path hinges on this: a single relaxed load.
    pub fn has_subscribers(&self) -> bool {
        self.subscribers.load(Ordering::Relaxed) > 0
    }

    /// Publish one event. Returns immediately when no subscriber is
    /// attached; otherwise stamps a sequence number and pushes, evicting
    /// the oldest event if the ring is full. Never blocks on consumers.
    pub fn publish(&self, mut event: Event) {
        if self.subscribers.load(Ordering::Relaxed) == 0 {
            return;
        }
        {
            let mut ring = self.inner.lock().unwrap();
            event.seq = ring.next_seq;
            ring.next_seq += 1;
            if ring.buf.len() == ring.capacity {
                ring.buf.pop_front();
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
            ring.buf.push_back(event);
        }
        self.published.fetch_add(1, Ordering::Relaxed);
        self.wake.notify_all();
    }

    /// Lifetime publish/eviction counters plus the live subscriber
    /// count — the bus's own health telemetry.
    pub fn counters(&self) -> BusCounters {
        BusCounters {
            published: self.published.load(Ordering::Relaxed),
            dropped: self.dropped.load(Ordering::Relaxed),
            subscribers: self.subscribers.load(Ordering::Relaxed),
        }
    }

    /// Build the `obs.stats` self-telemetry event from the current
    /// counters (the daemon publishes one per `stats` verb).
    pub fn stats_event(&self) -> Event {
        let c = self.counters();
        Event::new("obs.stats")
            .num("published", c.published as f64)
            .num("dropped", c.dropped as f64)
            .num("subscribers", c.subscribers as f64)
    }

    /// Attach a subscriber cursor starting at "now" (no backlog).
    pub fn subscribe(self: &Arc<Bus>) -> Subscription {
        self.subscribers.fetch_add(1, Ordering::SeqCst);
        let next = self.inner.lock().unwrap().next_seq;
        Subscription { bus: Arc::clone(self), next }
    }

    /// Attach a subscriber that first replays everything still in the
    /// ring — the dashboard uses this so a fresh browser tab sees recent
    /// history, not just the live tail.
    pub fn subscribe_with_backlog(self: &Arc<Bus>) -> Subscription {
        self.subscribers.fetch_add(1, Ordering::SeqCst);
        let next = self.inner.lock().unwrap().oldest_seq();
        Subscription { bus: Arc::clone(self), next }
    }

    /// Copy out the retained ring (the `/snapshot` view): the sequence
    /// number the next event will get, plus every buffered event.
    pub fn snapshot(&self) -> (u64, Vec<Event>) {
        let ring = self.inner.lock().unwrap();
        (ring.next_seq, ring.buf.iter().cloned().collect())
    }
}

/// What one [`Subscription::poll`] returned: the events themselves plus
/// how many were evicted before this consumer got to them.
#[derive(Debug, Default)]
pub struct Drained {
    pub events: Vec<Event>,
    pub dropped: u64,
}

/// A consumer cursor into one bus. Dropping it decrements the
/// subscriber count — when the last one detaches, publishing collapses
/// back to the single-atomic-load no-op.
pub struct Subscription {
    bus: Arc<Bus>,
    next: u64,
}

impl Subscription {
    /// Non-blocking: take everything published since the last call.
    pub fn drain(&mut self) -> Drained {
        let bus = Arc::clone(&self.bus);
        let ring = bus.inner.lock().unwrap();
        self.collect(&ring)
    }

    /// Wait up to `wait` for at least one new event, then drain.
    /// Returns empty on timeout; never blocks past the deadline.
    pub fn poll(&mut self, wait: Duration) -> Drained {
        let bus = Arc::clone(&self.bus);
        let mut ring = bus.inner.lock().unwrap();
        if ring.next_seq <= self.next {
            let (guard, _timed_out) = bus.wake.wait_timeout(ring, wait).unwrap();
            ring = guard;
        }
        self.collect(&ring)
    }

    fn collect(&mut self, ring: &Ring) -> Drained {
        let oldest = ring.oldest_seq();
        let dropped = oldest.saturating_sub(self.next);
        let skip = self.next.saturating_sub(oldest) as usize;
        let events: Vec<Event> = ring.buf.iter().skip(skip).cloned().collect();
        self.next = ring.next_seq;
        Drained { events, dropped }
    }
}

impl Drop for Subscription {
    fn drop(&mut self) {
        self.bus.subscribers.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The process-wide bus every instrumented layer publishes into.
pub fn global() -> &'static Arc<Bus> {
    static GLOBAL: OnceLock<Arc<Bus>> = OnceLock::new();
    GLOBAL.get_or_init(Bus::new)
}

/// Is anyone listening to the global bus? Hot paths check this before
/// even constructing an event, so the unobserved cost is one atomic
/// load (and the observed cost is still bounded by the ring).
pub fn active() -> bool {
    global().has_subscribers()
}

/// Publish to the global bus (no-op without subscribers).
pub fn publish(event: Event) {
    global().publish(event);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_without_subscribers_is_dropped_and_cheap() {
        let bus = Bus::with_capacity(4);
        assert!(!bus.has_subscribers());
        bus.publish(Event::new("test.lost").num("i", 1.0));
        let mut sub = bus.subscribe();
        let drained = sub.drain();
        assert!(drained.events.is_empty());
        assert_eq!(drained.dropped, 0);
    }

    #[test]
    fn overflow_evicts_oldest_and_reports_the_gap() {
        let bus = Bus::with_capacity(4);
        let mut sub = bus.subscribe();
        for i in 0..10 {
            bus.publish(Event::new("test.tick").num("i", i as f64));
        }
        let drained = sub.drain();
        assert_eq!(drained.events.len(), 4, "ring keeps only the newest capacity events");
        assert_eq!(drained.dropped, 6);
        let seqs: Vec<u64> = drained.events.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
        // Fully drained: a second poll is empty with no new drops.
        let again = sub.drain();
        assert!(again.events.is_empty());
        assert_eq!(again.dropped, 0);
    }

    #[test]
    fn backlog_subscription_replays_the_ring() {
        let bus = Bus::with_capacity(8);
        let _pin = bus.subscribe(); // keep the ring recording
        bus.publish(Event::new("test.early").num("i", 0.0));
        bus.publish(Event::new("test.early").num("i", 1.0));
        let mut late = bus.subscribe_with_backlog();
        let drained = late.drain();
        assert_eq!(drained.events.len(), 2);
        assert_eq!(drained.dropped, 0);
    }

    #[test]
    fn counters_track_published_and_evicted() {
        let bus = Bus::with_capacity(4);
        // No subscriber: the fast path counts nothing.
        bus.publish(Event::new("test.lost"));
        assert_eq!(bus.counters(), BusCounters::default());
        let _sub = bus.subscribe();
        for i in 0..6 {
            bus.publish(Event::new("test.tick").num("i", i as f64));
        }
        let c = bus.counters();
        assert_eq!(c.published, 6);
        assert_eq!(c.dropped, 2, "6 published into a 4-slot ring evicts 2");
        assert_eq!(c.subscribers, 1);
        let stats = bus.stats_event();
        assert_eq!(stats.kind, "obs.stats");
        assert_eq!(stats.fields.get("published").and_then(Json::as_f64), Some(6.0));
        assert_eq!(stats.fields.get("dropped").and_then(Json::as_f64), Some(2.0));
    }

    #[test]
    fn events_round_trip_their_json_encoding() {
        let ev = Event::new("train.episode")
            .tag("combo", "dqn_cartpole")
            .num("reward", 123.5)
            .flag("done", true);
        let json = ev.to_json();
        assert_eq!(json.get("kind").and_then(Json::as_str), Some("train.episode"));
        let back = Event::from_json(&json).expect("round trip");
        assert_eq!(back.kind, ev.kind);
        assert_eq!(back.fields, ev.fields);
        // Hostile kinds are rejected before they can corrupt SSE frames.
        let bad = Json::parse("{\"kind\":\"evil\\nheader\"}").unwrap();
        assert!(Event::from_json(&bad).is_err());
        assert!(Event::from_json(&Json::parse("{\"x\":1}").unwrap()).is_err());
    }
}
