//! Cross-process event forwarding: producers (`apdrl train`, `apdrl
//! sweep`, `apdrl serve`) publish into their own process-local bus; a
//! [`Forwarder`] drains that bus on a background thread and POSTs the
//! batches to a dash's `/emit` ingest route, so one `apdrl dash` can
//! watch a whole fleet.
//!
//! Enabled by pointing [`ENV_DASH`] at the dash address (the CLI calls
//! [`Forwarder::from_env`] in every producer subcommand). Forwarding is
//! strictly best-effort — a dead or slow dash costs the producer
//! nothing beyond the bounded ring: batches that fail to POST are
//! dropped, never retried, and never block publishing.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use super::bus::{self, Event, Subscription};
use super::dash::ENV_DASH_TOKEN;
use crate::util::json::Json;

/// Producers forward their bus to the dash at this address; `apdrl
/// dash` itself also reads it as its default bind address, so one
/// exported variable wires up the whole workflow.
pub const ENV_DASH: &str = "APDRL_DASH";

/// How often the forwarding thread wakes to check for events/stop.
const FORWARD_POLL: Duration = Duration::from_millis(100);
/// Socket deadlines for one `/emit` POST round trip.
const POST_TIMEOUT: Duration = Duration::from_secs(2);

/// Handle to the background forwarding thread. Call
/// [`finish`](Forwarder::finish) before process exit so the tail of the
/// event stream (e.g. `train.done`) reaches the dash.
pub struct Forwarder {
    stop: Arc<AtomicBool>,
    handle: JoinHandle<()>,
}

impl Forwarder {
    /// Start forwarding the global bus to the dash ingest at `addr`.
    pub fn start(addr: &str, token: Option<String>) -> Forwarder {
        let stop = Arc::new(AtomicBool::new(false));
        let sub = bus::global().subscribe();
        let addr = addr.to_string();
        let stop_flag = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            forward_loop(sub, &addr, token.as_deref(), &stop_flag);
        });
        Forwarder { stop, handle }
    }

    /// Start from the environment: `APDRL_DASH` names the dash,
    /// `APDRL_DASH_TOKEN` rides along when set. `None` when unset —
    /// the common case, costing producers nothing.
    pub fn from_env() -> Option<Forwarder> {
        let addr = std::env::var(ENV_DASH).ok().filter(|v| !v.is_empty())?;
        let token = std::env::var(ENV_DASH_TOKEN).ok().filter(|v| !v.is_empty());
        Some(Forwarder::start(&addr, token))
    }

    /// Flush whatever is still buffered, then stop the thread.
    pub fn finish(self) {
        self.stop.store(true, Ordering::SeqCst);
        let _ = self.handle.join();
    }
}

fn forward_loop(mut sub: Subscription, addr: &str, token: Option<&str>, stop: &AtomicBool) {
    let mut conn: Option<EmitConn> = None;
    loop {
        let stopping = stop.load(Ordering::SeqCst);
        let drained = if stopping { sub.drain() } else { sub.poll(FORWARD_POLL) };
        if !drained.events.is_empty() {
            // One reconnect attempt per batch; a batch that still fails
            // is dropped (observability must never wedge a producer).
            if post_batch(&mut conn, addr, token, &drained.events).is_err() {
                conn = None;
                let _ = post_batch(&mut conn, addr, token, &drained.events);
            }
        }
        if stopping {
            return;
        }
    }
}

/// A kept-alive connection to the dash's `/emit` route.
struct EmitConn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl EmitConn {
    fn open(addr: &str) -> std::io::Result<EmitConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true).ok();
        stream.set_read_timeout(Some(POST_TIMEOUT))?;
        stream.set_write_timeout(Some(POST_TIMEOUT))?;
        Ok(EmitConn { reader: BufReader::new(stream.try_clone()?), writer: stream })
    }
}

fn post_batch(
    conn: &mut Option<EmitConn>,
    addr: &str,
    token: Option<&str>,
    events: &[Event],
) -> std::io::Result<()> {
    if conn.is_none() {
        *conn = Some(EmitConn::open(addr)?);
    }
    let live = conn.as_mut().expect("emit connection just opened");
    let body = {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("events".to_string(), Json::Arr(events.iter().map(Event::to_json).collect()));
        Json::Obj(obj).to_string()
    };
    let target = match token {
        Some(t) => format!("/emit?token={t}"),
        None => "/emit".to_string(),
    };
    let head = format!(
        "POST {target} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: keep-alive\r\n\r\n",
        body.len()
    );
    let result = (|| {
        live.writer.write_all(head.as_bytes())?;
        live.writer.write_all(body.as_bytes())?;
        live.writer.flush()?;
        // Read and discard the response so keep-alive framing stays in
        // sync: status line, headers, then content-length body bytes.
        let mut status = String::new();
        if live.reader.read_line(&mut status)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "dash closed the emit connection",
            ));
        }
        let mut length = 0usize;
        loop {
            let mut line = String::new();
            if live.reader.read_line(&mut line)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "dash closed mid-response",
                ));
            }
            let line = line.trim_end();
            if line.is_empty() {
                break;
            }
            if let Some((key, value)) = line.split_once(':') {
                if key.trim().eq_ignore_ascii_case("content-length") {
                    length = value.trim().parse().unwrap_or(0);
                }
            }
        }
        let mut sink = vec![0u8; length];
        std::io::Read::read_exact(&mut live.reader, &mut sink)?;
        Ok(())
    })();
    if result.is_err() {
        *conn = None;
    }
    result
}
