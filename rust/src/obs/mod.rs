//! Live observability for the planner/trainer fleet: a bounded,
//! lock-light [event bus](bus) every instrumented layer publishes into,
//! the [`apdrl dash` HTTP/SSE endpoint](dash) that streams it to
//! browsers and scripts, a [cross-process forwarder](forward) that
//! lets one dash watch many producer processes, and a
//! [kernel-level span tracer](trace) whose shape-keyed timings feed
//! the planner's self-calibrating cost model
//! ([`profile::calib`](crate::profile::calib)).
//!
//! # Event taxonomy
//!
//! | kind            | source                 | fields                                                        |
//! |-----------------|------------------------|---------------------------------------------------------------|
//! | `train.episode` | trainer                | combo, job, seed, lane, episode, reward, env_steps, actors    |
//! | `train.scale`   | trainer (FSM)          | combo, job, seed, step, from, to, overflow                    |
//! | `train.done`    | trainer                | combo, backend, job, seed, actors, episodes, env_steps, train_steps, overflows, steps_per_sec |
//! | `plan.cache`    | static phase           | combo, batch, quantized, hit, calibrated, calib_nodes         |
//! | `sweep.start`   | coordinator            | points, distinct                                              |
//! | `sweep.point`   | coordinator            | index, done, total, combo, batch, quantized, cache_hit, explored, solve_us |
//! | `sweep.done`    | coordinator            | points, wall_us                                               |
//! | `serve.request` | daemon                 | verb, ok, wall_us                                             |
//! | `fed.shard`     | federation client      | host, shard, points, wall_us                                  |
//! | `fed.down`      | federation client      | host, shard, error                                            |
//! | `fed.failover`  | federation client      | pending, survivors                                            |
//! | `obs.dropped`   | dash (per SSE client)  | dropped                                                       |
//! | `obs.stats`     | daemon (`stats` verb)  | published, dropped, subscribers                               |
//! | `trace.kernel`  | [`trace`] spans        | kernel, threads, m, k, n, work, calls, mean_ns, last_ns       |
//! | `job.spilled`   | scheduler journal      | job, env_steps                                                |
//! | `job.recovered` | scheduler boot replay  | job, combo, was, from_checkpoint                              |
//! | `job.resubmitted` | train client (gossip) | origin, to, job                                               |
//! | `calib.dropped` | calibration load       | path                                                          |
//!
//! The invariants the whole layer is built around — zero cost with no
//! subscriber, publishers never block, observation never perturbs
//! training — are documented (and tested) in [`bus`].

pub mod bus;
pub mod dash;
pub mod forward;
pub mod trace;

pub use bus::{active, global, publish, Bus, BusCounters, Drained, Event, Subscription};
pub use dash::{DashServer, DEFAULT_DASH_ADDR, ENV_DASH_TOKEN};
pub use forward::{Forwarder, ENV_DASH};
