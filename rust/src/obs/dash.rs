//! `apdrl dash` — the hand-rolled HTTP endpoint (std::net only) that
//! turns the event bus into a live dashboard.
//!
//! Routes:
//!
//! | route            | method | body                                        |
//! |------------------|--------|---------------------------------------------|
//! | `/`              | GET    | embedded single-file HTML client            |
//! | `/events`        | GET    | `text/event-stream` SSE: one frame per event|
//! | `/snapshot`      | GET    | JSON view of the retained ring              |
//! | `/emit`          | POST   | ingest `{"events":[…]}` from producers      |
//! | `/shutdown`      | any    | stop the dash (used by CI for clean exits)  |
//!
//! SSE frames are the classic three-line form the spec requires —
//! `event: <kind>`, `data: <one-line json>`, blank line — plus
//! `: ping` comment heartbeats so dead clients are detected. The dash
//! holds a pin subscription for its whole lifetime, which keeps the
//! ring recording (and `/snapshot` meaningful) even with no browser
//! attached.
//!
//! **Auth.** Loopback binds are open. Binding any non-loopback address
//! refuses to start unless a token is configured ([`ENV_DASH_TOKEN`] or
//! `--token`); with a token set, every request must present it as
//! `?token=…` or `Authorization: Bearer …` or it gets a 401. Tokens
//! must be URL-safe (they are compared verbatim, no percent-decoding).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use super::bus::{Bus, Event};
use crate::util::json::Json;

/// Token required for non-loopback dashes (and checked on every
/// request whenever it is set, loopback included).
pub const ENV_DASH_TOKEN: &str = "APDRL_DASH_TOKEN";

/// Where `apdrl dash` binds when neither `--addr` nor `APDRL_DASH`
/// says otherwise.
pub const DEFAULT_DASH_ADDR: &str = "127.0.0.1:7044";

/// Cadence of the accept loop's shutdown check and of the idle
/// keep-alive read poll.
const ACCEPT_POLL: Duration = Duration::from_millis(100);
/// Once a request line has arrived, the rest (headers + body) must
/// follow within this window or the connection is dropped.
const BODY_TIMEOUT: Duration = Duration::from_secs(5);
/// How long an SSE writer waits on the bus before re-checking shutdown.
const SSE_POLL: Duration = Duration::from_millis(250);
/// Comment-frame heartbeat interval on otherwise-quiet SSE streams.
const HEARTBEAT: Duration = Duration::from_secs(10);
/// `/emit` bodies larger than this are rejected outright.
const MAX_BODY: usize = 1 << 20;

/// The embedded client: reward curves, FSM transition log, sweep
/// progress bars, federation health — one file, no external assets.
const CLIENT_HTML: &str = include_str!("dash.html");

/// The dashboard server. Bind, then [`run`](DashServer::run) (blocking;
/// one thread per connection, all watching a shared shutdown flag).
pub struct DashServer {
    listener: TcpListener,
    bus: Arc<Bus>,
    token: Option<String>,
    shutdown: Arc<AtomicBool>,
}

impl DashServer {
    /// Bind `addr` and enforce the token policy: non-loopback binds
    /// without a token are refused before any byte is served.
    pub fn bind(addr: &str, bus: Arc<Bus>, token: Option<String>) -> Result<DashServer> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding the dash endpoint on {addr}"))?;
        let local = listener.local_addr().context("reading the dash local address")?;
        let token = token.filter(|t| !t.is_empty());
        if !local.ip().is_loopback() && token.is_none() {
            bail!(
                "refusing to serve the dashboard on non-loopback {local} without a token; \
                 set {ENV_DASH_TOKEN} or pass --token"
            );
        }
        Ok(DashServer { listener, bus, token, shutdown: Arc::new(AtomicBool::new(false)) })
    }

    pub fn local_addr(&self) -> Result<std::net::SocketAddr> {
        self.listener.local_addr().context("reading the dash local address")
    }

    /// Shared stop flag: store `true` (or hit `/shutdown`) and the
    /// accept loop plus every live SSE stream wind down within a poll.
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Serve until shut down. Holds a pin subscription so the ring
    /// keeps recording while the dash is up.
    pub fn run(self) -> Result<()> {
        let DashServer { listener, bus, token, shutdown } = self;
        let _pin = bus.subscribe();
        listener.set_nonblocking(true).context("making the dash listener non-blocking")?;
        let token = Arc::new(token);
        loop {
            if shutdown.load(Ordering::SeqCst) {
                return Ok(());
            }
            match listener.accept() {
                Ok((stream, _peer)) => {
                    let bus = Arc::clone(&bus);
                    let token = Arc::clone(&token);
                    let shutdown = Arc::clone(&shutdown);
                    std::thread::spawn(move || {
                        // Client-gone write errors are the normal way
                        // SSE streams end; nothing to report.
                        let _ = serve_conn(stream, &bus, (*token).as_deref(), &shutdown);
                    });
                }
                Err(_) => std::thread::sleep(ACCEPT_POLL),
            }
        }
    }
}

/// One parsed HTTP request (just enough of HTTP/1.1 for the dash).
struct HttpRequest {
    method: String,
    target: String,
    headers: Vec<(String, String)>,
    body: Vec<u8>,
}

impl HttpRequest {
    fn path(&self) -> &str {
        self.target.split('?').next().unwrap_or("")
    }

    fn query(&self, key: &str) -> Option<&str> {
        let q = self.target.splitn(2, '?').nth(1)?;
        q.split('&').find_map(|kv| {
            let mut it = kv.splitn(2, '=');
            (it.next()? == key).then(|| it.next().unwrap_or(""))
        })
    }

    fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    fn authorized(&self, token: Option<&str>) -> bool {
        let Some(token) = token else { return true };
        if self.query("token") == Some(token) {
            return true;
        }
        self.header("authorization")
            .and_then(|h| h.strip_prefix("Bearer "))
            .is_some_and(|bearer| bearer.trim() == token)
    }
}

/// Keep-alive request loop for one connection. The 100ms read timeout
/// doubles as the shutdown poll while idling between requests.
fn serve_conn(
    stream: TcpStream,
    bus: &Arc<Bus>,
    token: Option<&str>,
    shutdown: &AtomicBool,
) -> std::io::Result<()> {
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(ACCEPT_POLL))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let mut pending = String::new();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        match reader.read_line(&mut pending) {
            Ok(0) => return Ok(()),
            Ok(_) => {}
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::Interrupted
                ) =>
            {
                continue;
            }
            Err(e) => return Err(e),
        }
        let request_line = std::mem::take(&mut pending);
        if request_line.trim().is_empty() {
            continue;
        }
        // The request line is here; give headers + body a firmer
        // deadline, then fall back to the idle poll. Socket options are
        // shared with the reader's cloned handle.
        writer.set_read_timeout(Some(BODY_TIMEOUT))?;
        let request = read_rest(&mut reader, &request_line)?;
        writer.set_read_timeout(Some(ACCEPT_POLL))?;

        if !request.authorized(token) {
            let body = b"{\"ok\":false,\"error\":\"missing or bad token\"}";
            return write_response(&mut writer, 401, "application/json", body, false);
        }
        match (request.method.as_str(), request.path()) {
            ("GET", "/") | ("GET", "/index.html") => {
                return write_response(
                    &mut writer,
                    200,
                    "text/html; charset=utf-8",
                    CLIENT_HTML.as_bytes(),
                    false,
                );
            }
            ("GET", "/events") => return serve_sse(&mut writer, bus, shutdown),
            ("GET", "/snapshot") => {
                let body = snapshot_json(bus).to_string();
                return write_response(&mut writer, 200, "application/json", body.as_bytes(), false);
            }
            ("POST", "/emit") => {
                // Producers hold this connection open and POST batches;
                // keep-alive matters here, so stay in the loop.
                match ingest(bus, &request.body) {
                    Ok(n) => {
                        let body = format!("{{\"ok\":true,\"accepted\":{n}}}");
                        let body = body.as_bytes();
                        write_response(&mut writer, 200, "application/json", body, true)?;
                    }
                    Err(e) => {
                        let msg = Json::Str(format!("{e:#}"));
                        let body = format!("{{\"ok\":false,\"error\":{msg}}}");
                        let body = body.as_bytes();
                        write_response(&mut writer, 400, "application/json", body, true)?;
                    }
                }
            }
            (_, "/shutdown") => {
                shutdown.store(true, Ordering::SeqCst);
                let body = b"{\"ok\":true,\"stopping\":true}";
                return write_response(&mut writer, 200, "application/json", body, false);
            }
            _ => {
                let body = b"{\"ok\":false,\"error\":\"no such route\"}";
                return write_response(&mut writer, 404, "application/json", body, false);
            }
        }
    }
}

/// Finish reading one request whose request line is already in hand.
fn read_rest(
    reader: &mut BufReader<TcpStream>,
    request_line: &str,
) -> std::io::Result<HttpRequest> {
    let mut parts = request_line.split_whitespace();
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("/").to_string();
    let mut headers = Vec::new();
    loop {
        let mut line = String::new();
        if reader.read_line(&mut line)? == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-headers",
            ));
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((key, value)) = line.split_once(':') {
            headers.push((key.trim().to_string(), value.trim().to_string()));
        }
    }
    let length = headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-length"))
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .unwrap_or(0);
    if length > MAX_BODY {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "request body over the 1 MiB dash limit",
        ));
    }
    let mut body = vec![0u8; length];
    if length > 0 {
        reader.read_exact(&mut body)?;
    }
    Ok(HttpRequest { method, target, headers, body })
}

fn write_response(
    writer: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        _ => "Not Found",
    };
    let connection = if keep_alive { "keep-alive" } else { "close" };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nAccess-Control-Allow-Origin: *\r\nConnection: {connection}\r\n\r\n",
        body.len()
    );
    writer.write_all(head.as_bytes())?;
    writer.write_all(body)?;
    writer.flush()
}

/// Stream the bus over SSE until the client hangs up or the dash stops.
/// Subscribes (with backlog) *before* the response header goes out, so
/// anything published after the client sees headers is guaranteed to
/// reach it.
fn serve_sse(writer: &mut TcpStream, bus: &Arc<Bus>, shutdown: &AtomicBool) -> std::io::Result<()> {
    let mut sub = bus.subscribe_with_backlog();
    writer.set_write_timeout(Some(Duration::from_secs(10)))?;
    writer.write_all(
        b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\n\
          Access-Control-Allow-Origin: *\r\nConnection: close\r\n\r\n",
    )?;
    writer.write_all(b"retry: 2000\n\n")?;
    let mut last_write = Instant::now();
    loop {
        if shutdown.load(Ordering::SeqCst) {
            return Ok(());
        }
        let drained = sub.poll(SSE_POLL);
        if drained.dropped > 0 {
            let frame =
                format!("event: obs.dropped\ndata: {{\"dropped\":{}}}\n\n", drained.dropped);
            writer.write_all(frame.as_bytes())?;
        }
        for event in &drained.events {
            writer.write_all(frame_for(event).as_bytes())?;
        }
        if !drained.events.is_empty() || drained.dropped > 0 {
            writer.flush()?;
            last_write = Instant::now();
        } else if last_write.elapsed() >= HEARTBEAT {
            writer.write_all(b": ping\n\n")?;
            writer.flush()?;
            last_write = Instant::now();
        }
    }
}

/// The three-line SSE frame for one event. `Json`'s `Display` is a
/// strict single line (strings escaped, non-finite numbers as null), so
/// the `data:` field can never split across lines.
fn frame_for(event: &Event) -> String {
    format!("event: {}\ndata: {}\n\n", event.kind, event.to_json())
}

fn snapshot_json(bus: &Bus) -> Json {
    let (next_seq, events) = bus.snapshot();
    let mut obj = std::collections::BTreeMap::new();
    obj.insert("seq".to_string(), Json::Num(next_seq as f64));
    obj.insert("count".to_string(), Json::Num(events.len() as f64));
    obj.insert("events".to_string(), Json::Arr(events.iter().map(Event::to_json).collect()));
    Json::Obj(obj)
}

/// Parse an `/emit` body and publish its events. Accepts either
/// `{"events":[…]}` or a bare array.
fn ingest(bus: &Bus, body: &[u8]) -> Result<usize> {
    let text = std::str::from_utf8(body).map_err(|_| anyhow!("emit body must be UTF-8"))?;
    let root = Json::parse(text).map_err(|e| anyhow!("emit body: {e}"))?;
    let events = root
        .get("events")
        .and_then(Json::as_arr)
        .or_else(|| root.as_arr())
        .ok_or_else(|| anyhow!("emit body must be {{\"events\":[…]}} or a bare array"))?;
    let mut accepted = 0;
    for raw in events {
        bus.publish(Event::from_json(raw)?);
        accepted += 1;
    }
    Ok(accepted)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_auth_accepts_query_and_bearer_rejects_the_rest() {
        let req = |target: &str, headers: Vec<(&str, &str)>| HttpRequest {
            method: "GET".to_string(),
            target: target.to_string(),
            headers: headers
                .into_iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            body: Vec::new(),
        };
        let open = req("/events", vec![]);
        assert!(open.authorized(None));
        assert!(!open.authorized(Some("s3cret")));
        assert!(req("/events?token=s3cret", vec![]).authorized(Some("s3cret")));
        assert!(!req("/events?token=wrong", vec![]).authorized(Some("s3cret")));
        let bearer = req("/events", vec![("Authorization", "Bearer s3cret")]);
        assert!(bearer.authorized(Some("s3cret")));
        assert!(!req("/events", vec![("Authorization", "Bearer nope")]).authorized(Some("s3cret")));
        // Query parsing keeps the path and extra params straight.
        let q = req("/snapshot?a=1&token=t&b=2", vec![]);
        assert_eq!(q.path(), "/snapshot");
        assert_eq!(q.query("token"), Some("t"));
        assert_eq!(q.query("b"), Some("2"));
        assert_eq!(q.query("missing"), None);
    }

    #[test]
    fn sse_frames_are_the_three_line_form() {
        let mut ev = Event::new("train.episode").num("reward", 42.0);
        ev.seq = 7;
        let frame = frame_for(&ev);
        let mut lines = frame.lines();
        assert_eq!(lines.next(), Some("event: train.episode"));
        let data = lines.next().expect("data line");
        let json = Json::parse(data.strip_prefix("data: ").expect("data prefix")).expect("json");
        assert_eq!(json.get("reward").and_then(Json::as_f64), Some(42.0));
        assert_eq!(json.get("seq").and_then(Json::as_usize), Some(7));
        assert!(frame.ends_with("\n\n"));
    }

    #[test]
    fn ingest_publishes_both_body_shapes_and_rejects_garbage() {
        let bus = Bus::with_capacity(16);
        let mut sub = bus.subscribe();
        assert_eq!(ingest(&bus, br#"{"events":[{"kind":"a.b","x":1}]}"#).unwrap(), 1);
        assert_eq!(ingest(&bus, br#"[{"kind":"c.d"},{"kind":"e.f"}]"#).unwrap(), 2);
        assert!(ingest(&bus, b"not json").is_err());
        assert!(ingest(&bus, br#"{"events":[{"no_kind":1}]}"#).is_err());
        let drained = sub.drain();
        assert_eq!(drained.events.len(), 3);
        assert_eq!(drained.events[0].kind, "a.b");
    }
}
