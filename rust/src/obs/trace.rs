//! Kernel-level span tracing: shape-keyed wall-clock timings of the
//! executor's hot kernels, aggregated online so the planner can price
//! the CPU path from *measured* costs instead of the analytic model
//! (`profile::ps_model`).
//!
//! The layer copies the [`bus`](super::bus) discipline exactly:
//!
//! 1. **Zero-cost when disarmed.** [`span`] starts with one relaxed
//!    atomic load of the recorder count and returns `None` when it is
//!    zero — no clock read, no lock, no allocation. Instrumented
//!    kernels therefore cost one predictable branch when tracing is
//!    off, which the no-allocation test in `tests/trace_overhead.rs`
//!    and the `trace_disarmed_span_ns` entry in `bench_exec` pin.
//! 2. **Observation never mutates.** A span records wall time only —
//!    no RNG, no numeric state — so the kernel-equivalence and
//!    `--actors 1` bit-identity suites pass with tracing hot
//!    (`tests/calib.rs` runs them armed with a live bus subscriber).
//! 3. **Bounded telemetry.** When the obs bus has a subscriber,
//!    aggregated `trace.kernel` events are published on a
//!    power-of-two cadence per (kernel, bucket, threads) cell, so a
//!    million GEMM calls produce ~20 events, not a flooded ring.
//!
//! Arming: [`record`] returns an RAII [`Recorder`] guard (the
//! `apdrl calibrate` sweep uses this), and [`arm_from_env`] arms the
//! process permanently when `APDRL_TRACE` is set to anything but `0`.
//! Samples aggregate into per-(kernel × log2-work-bucket × threads)
//! cells that [`drain_aggregate`] hands to
//! [`profile::calib::CalibrationTable`](crate::profile::calib).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

use super::bus;

/// Set to any value but `0`/empty to arm tracing for the whole
/// process lifetime (see [`arm_from_env`]).
pub const ENV_TRACE: &str = "APDRL_TRACE";

/// The instrumented kernels. Names are stable identifiers: they key
/// the persisted calibration table and ride `trace.kernel` events.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Kernel {
    /// `Tensor::matmul_with` — blocked/parallel C = A·B.
    GemmNn,
    /// `Tensor::matmul_tn_with` — C = Aᵀ·B (backprop weight grads).
    GemmTn,
    /// `Tensor::matmul_nt_with` — C = A·Bᵀ (backprop input grads).
    GemmNt,
    /// Conv forward patch extraction.
    Im2col,
    /// Conv backward patch scatter-accumulate.
    Col2im,
    /// `quant::round_slice` f16/bf16 rounding (identity formats skip).
    RoundSlice,
    /// One full `Adam::step` over every parameter tensor.
    AdamStep,
    /// `BatchedEnv::step` — one lockstep step of every lane.
    EnvStep,
    /// One trainer collection round: act + env step + observe.
    Collect,
}

impl Kernel {
    pub const ALL: [Kernel; 9] = [
        Kernel::GemmNn,
        Kernel::GemmTn,
        Kernel::GemmNt,
        Kernel::Im2col,
        Kernel::Col2im,
        Kernel::RoundSlice,
        Kernel::AdamStep,
        Kernel::EnvStep,
        Kernel::Collect,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Kernel::GemmNn => "gemm_nn",
            Kernel::GemmTn => "gemm_tn",
            Kernel::GemmNt => "gemm_nt",
            Kernel::Im2col => "im2col",
            Kernel::Col2im => "col2im",
            Kernel::RoundSlice => "round_slice",
            Kernel::AdamStep => "adam_step",
            Kernel::EnvStep => "env_step",
            Kernel::Collect => "collect",
        }
    }

    pub fn parse(s: &str) -> Option<Kernel> {
        Kernel::ALL.into_iter().find(|k| k.name() == s)
    }
}

/// Scalar work estimate for a shape: the product of its non-trivial
/// dims — MACs for a GEMM `[m, k, n]`, element count for `[elems, 0, 0]`.
pub fn work_of(dims: [usize; 3]) -> u64 {
    dims.iter().map(|&d| d.max(1) as u64).product()
}

/// log2 bucket a work value falls into; shapes within a bucket share
/// one aggregation cell and the calibration table interpolates between
/// bucket means.
pub fn bucket_of(work: u64) -> u32 {
    63 - work.max(1).leading_zeros()
}

static RECORDERS: AtomicUsize = AtomicUsize::new(0);

/// Is any recorder armed? One relaxed load — the whole fast path.
#[inline]
pub fn active() -> bool {
    RECORDERS.load(Ordering::Relaxed) != 0
}

/// RAII arming guard: tracing records while at least one exists.
pub struct Recorder(());

/// Arm tracing; samples aggregate until the guard drops.
pub fn record() -> Recorder {
    RECORDERS.fetch_add(1, Ordering::SeqCst);
    Recorder(())
}

impl Drop for Recorder {
    fn drop(&mut self) {
        RECORDERS.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Arm tracing for the rest of the process when `APDRL_TRACE` is set
/// (to anything but `0`/empty). Idempotent; `main` calls it once so
/// any verb can run with tracing hot.
pub fn arm_from_env() {
    static ONCE: OnceLock<Option<Recorder>> = OnceLock::new();
    ONCE.get_or_init(|| {
        std::env::var(ENV_TRACE)
            .ok()
            .filter(|v| !v.is_empty() && v != "0")
            .map(|_| record())
    });
}

/// A live timing span; records into the aggregate when dropped.
pub struct Span {
    kernel: Kernel,
    dims: [usize; 3],
    threads: usize,
    start: Instant,
}

/// Open a span over one kernel invocation. Returns `None` (without
/// reading the clock) when no recorder is armed — callers bind it to
/// `let _span = ...;` so the drop at scope exit stamps the duration.
#[inline]
pub fn span(kernel: Kernel, dims: [usize; 3], threads: usize) -> Option<Span> {
    if RECORDERS.load(Ordering::Relaxed) == 0 {
        return None;
    }
    Some(Span { kernel, dims, threads, start: Instant::now() })
}

impl Drop for Span {
    fn drop(&mut self) {
        let ns = self.start.elapsed().as_nanos() as u64;
        record_sample(self.kernel, self.dims, self.threads, ns);
    }
}

#[derive(Clone, Copy, Default)]
struct Cell {
    count: u64,
    total_ns: f64,
    total_work: f64,
    min_ns: u64,
}

type AggKey = (Kernel, u32, usize);

fn agg() -> &'static Mutex<BTreeMap<AggKey, Cell>> {
    static AGG: OnceLock<Mutex<BTreeMap<AggKey, Cell>>> = OnceLock::new();
    AGG.get_or_init(|| Mutex::new(BTreeMap::new()))
}

fn record_sample(kernel: Kernel, dims: [usize; 3], threads: usize, ns: u64) {
    let work = work_of(dims);
    let key = (kernel, bucket_of(work), threads);
    let (count, mean_ns) = {
        let mut map = agg().lock().unwrap();
        let cell = map.entry(key).or_default();
        cell.count += 1;
        cell.total_ns += ns as f64;
        cell.total_work += work as f64;
        cell.min_ns = if cell.count == 1 { ns } else { cell.min_ns.min(ns) };
        (cell.count, cell.total_ns / cell.count as f64)
    };
    // Power-of-two cadence per cell: the first sample is visible
    // immediately and steady-state traffic decays logarithmically, so
    // tracing a hot GEMM cannot flood the 1024-event ring.
    if count & (count - 1) == 0 && bus::active() {
        bus::publish(
            bus::Event::new("trace.kernel")
                .tag("kernel", kernel.name())
                .num("threads", threads as f64)
                .num("m", dims[0] as f64)
                .num("k", dims[1] as f64)
                .num("n", dims[2] as f64)
                .num("work", work as f64)
                .num("calls", count as f64)
                .num("mean_ns", mean_ns)
                .num("last_ns", ns as f64),
        );
    }
}

/// One aggregated cell: every sample of `kernel` whose work fell in
/// `bucket`, run at `threads` pool width.
#[derive(Clone, Debug, PartialEq)]
pub struct AggRow {
    pub kernel: Kernel,
    pub threads: usize,
    pub bucket: u32,
    pub count: u64,
    pub mean_work: f64,
    pub mean_ns: f64,
    pub min_ns: u64,
}

fn rows_of(map: &BTreeMap<AggKey, Cell>) -> Vec<AggRow> {
    map.iter()
        .map(|(&(kernel, bucket, threads), cell)| AggRow {
            kernel,
            threads,
            bucket,
            count: cell.count,
            mean_work: cell.total_work / cell.count.max(1) as f64,
            mean_ns: cell.total_ns / cell.count.max(1) as f64,
            min_ns: cell.min_ns,
        })
        .collect()
}

/// Copy out the current aggregate without clearing it.
pub fn snapshot_aggregate() -> Vec<AggRow> {
    rows_of(&agg().lock().unwrap())
}

/// Take the aggregate and reset it — the calibrate sweep drains once
/// at the end so concurrent sweeps don't double-count.
pub fn drain_aggregate() -> Vec<AggRow> {
    let mut map = agg().lock().unwrap();
    let rows = rows_of(&map);
    map.clear();
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn span_is_none_when_disarmed() {
        // Other tests may have a recorder armed concurrently; only
        // assert the disarmed contract when nothing is armed.
        if !active() {
            assert!(span(Kernel::GemmNn, [8, 8, 8], 1).is_none());
        }
    }

    #[test]
    fn armed_spans_aggregate_by_kernel_bucket_threads() {
        let _rec = record();
        assert!(active());
        {
            let _a = span(Kernel::GemmTn, [16, 16, 16], 3);
            let _b = span(Kernel::GemmTn, [17, 16, 16], 3); // same log2 bucket
        }
        let rows = snapshot_aggregate();
        let cell = rows
            .iter()
            .find(|r| r.kernel == Kernel::GemmTn && r.threads == 3)
            .expect("aggregated cell");
        assert_eq!(cell.bucket, bucket_of(16 * 16 * 16));
        assert!(cell.count >= 2);
        assert!(cell.mean_work >= 4096.0);
    }

    #[test]
    fn kernel_names_round_trip() {
        for k in Kernel::ALL {
            assert_eq!(Kernel::parse(k.name()), Some(k));
        }
        assert_eq!(Kernel::parse("nope"), None);
    }

    #[test]
    fn work_and_buckets() {
        assert_eq!(work_of([4, 5, 6]), 120);
        assert_eq!(work_of([7, 0, 0]), 7);
        assert_eq!(bucket_of(1), 0);
        assert_eq!(bucket_of(1024), 10);
        assert_eq!(bucket_of(1536), 10);
        assert_eq!(bucket_of(2048), 11);
    }
}
