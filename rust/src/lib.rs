//! # AP-DRL — automatic task partitioning + hardware-aware quantization
//! for DRL training on a modeled AMD Versal ACAP.
//!
//! Reproduction of *"AP-DRL: A Synergistic Algorithm-Hardware Framework for
//! Automatic Task Partitioning of Deep Reinforcement Learning on Versal
//! ACAP"* as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: Versal ACAP performance model
//!   ([`hw`]), layer-level CDFG of the DRL training step ([`graph`]),
//!   DSE-based profiling ([`profile`]), ILP partitioning ([`partition`]),
//!   the hardware-aware quantization state machine ([`quant`]), the DRL
//!   runtime (environments [`envs`], agent coordination [`drl`]), the
//!   pure-Rust CPU execution backend ([`exec`]) and the experiment
//!   coordinator ([`coordinator`]).
//! * **L2/L1 (python/, build time only)** — JAX train/act steps calling
//!   Pallas mixed-precision GEMM kernels, AOT-lowered to
//!   `artifacts/*.hlo.txt` and executed from rust via PJRT ([`runtime`]).
//!
//! The real VEK280 testbed is substituted by an analytic performance model
//! calibrated to the paper's reported constants (see DESIGN.md
//! §Substitutions); numerics (quantization, convergence) are real and run
//! through the CPU executor by default, or the PJRT artifacts.
//!
//! ## The dynamic phase: one `Backend` API, two executors
//!
//! Training (the paper's dynamic phase, Fig 7 right) is served behind
//! [`exec::Backend`]: the agents in [`drl`] own all coordination
//! (exploration, replay/GAE, target schedules, the loss-scaling FSM)
//! and delegate network math to per-algorithm compute traits
//! ([`drl::compute`]), implemented twice:
//!
//! | backend | what executes | formats | availability |
//! |---------|---------------|---------|--------------|
//! | [`exec::CpuBackend`] | pure-Rust tensors ([`exec::tensor`]): cache-blocked/packed GEMM fanned out over the `APDRL_THREADS` worker pool ([`exec::pool`]), hand-written backprop, Adam with masters | routed per layer from the partition plan via [`exec::ExecPolicy`], bit-exact BF16/FP16 emulation at slice throughput ([`quant::formats::round_slice`]) | always (tier-1 CI trains through it) |
//! | `exec::PjrtBackend` | AOT-lowered XLA artifacts over PJRT | baked into the lowered computation (`fp32`/`mixed`/`bf16` modes) | `pjrt` feature |
//!
//! **Bit-exactness guarantee:** the CPU executor's blocked and
//! parallel GEMM kernels keep the per-output-element f32 accumulation
//! order of the naive references, and the vectorized rounding path is
//! bit-identical to the scalar one — so `APDRL_THREADS` (or
//! `apdrl train --threads N`) changes wall-clock only.  Rewards,
//! losses and loss-scale FSM transitions are bit-identical at any
//! thread count (asserted in `tests/kernels.rs` and `tests/train.rs`);
//! `cargo bench --bench bench_exec` tracks the speedups and writes
//! `BENCH_exec.json`.
//!
//! ## Batched collection: `BatchedEnv` and `--actors N`
//!
//! The acting/collection path is N-wide end to end: an
//! [`envs::BatchedEnv`] steps N independently-seeded env lanes in
//! lockstep (fan-out over the [`exec::pool`] worker pool, per-lane
//! auto-reset), the [`drl::Agent`] trait acts and observes over all
//! lanes at once (`&[f32]` of N × obs_dim in, `Vec<Action>` out), and
//! actor inference issues **one GEMM per layer for all N lanes**
//! instead of N batch-1 forwards.  [`drl::rollout::RolloutBuffer`] is
//! lane-aware (per-lane GAE over interleaved pushes) and replay
//! training cadence counts per-lane observations, so every algorithm
//! trains correctly at any width.  `apdrl train --actors N` (default 1)
//! selects the fleet width and reports env-steps/sec.
//!
//! **N = 1 bit-identity guarantee:** with `--actors 1` the batched
//! loop reproduces the historical scalar path bit-for-bit — same lane
//! RNG stream (lane 0's fork *is* the scalar fork), same rewards, same
//! loss-scale FSM transitions, same final weights.  Asserted against a
//! verbatim scalar reference loop in `tests/train.rs`, and the env half
//! (N lanes ≡ N independent scalar envs, auto-reset included) in
//! `tests/envs.rs` for every registry env.  `bench_exec` tracks
//! env-steps/sec at a 1/8/64 lane ladder under the `"actors"` key of
//! `BENCH_exec.json`.
//!
//! The CPU path makes the plan → training hand-off literal: an FP16
//! (PL) update node arms an FP32 master copy and the [`quant::LossScaler`]
//! FSM; a BF16 (AIE) node stores weights in BF16 with no master; PS
//! nodes stay FP32 — exactly Alg. 1 / Table II.
//!
//! ### `apdrl train` quickstart
//!
//! ```bash
//! # plan the static phase, fold the schedule into a precision policy,
//! # train on the CPU executor, and compare quantized vs FP32:
//! apdrl train --combo dqn-cartpole --steps 5000 --train-every 2 --quantized
//! # FP32 control only:
//! apdrl train --combo dqn-cartpole --steps 5000
//! # collect with an 8-lane env fleet (batched inference; same API,
//! # higher env-steps/sec — `--actors 1` is bit-identical to scalar):
//! apdrl train --combo dqn-cartpole --steps 5000 --actors 8
//! # plan remotely via APDRL_SERVER (daemon or federation), train locally:
//! APDRL_SERVER=host1:7040 apdrl train --combo ddpg-lunar --quantized
//! # or submit the whole run as a streaming daemon job (protocol v3):
//! # least-loaded host wins, frames stream back live, and if the
//! # serving host dies the newest checkpoint resumes on a survivor.
//! apdrl train --combo ddpg-lunar --remote host1:7040,host2:7040 --checkpoint-every 1000
//! apdrl jobs --remote host1:7040,host2:7040            # list; --cancel ID stops one
//! ```
//!
//! Reported per run: per-episode rewards, loss-scale FSM transitions
//! (grows and overflow backoffs), converged reward, collection
//! throughput (env-steps/sec), and — with `--quantized` — the
//! reward-error summary against the FP32 control (paper Table III).
//!
//! ## Feature flags
//!
//! * **`pjrt`** (default **off**) — compiles the PJRT execution layer:
//!   `runtime::{client, executor}`, the artifact compute impls
//!   (`drl::pjrt`, `drl::network`) and `exec::PjrtBackend`.  It needs
//!   the external `xla` bindings (not on crates.io; supply via a
//!   `[patch]`/path dependency) plus `make artifacts`.  Everything else —
//!   the performance model, profiling, the partitioning planner, the
//!   environments and the whole CPU training path — builds, tests and
//!   *trains* offline with `cargo build && cargo test`.
//!
//! ## The planning service: one `Planner` API, three backends
//!
//! The paper's static phase (DSE profiling → TAPCA → ILP) is served
//! behind one trait — [`coordinator::planner::Planner`], with
//! `plan(&PlanRequest)` and `plan_many(&[PlanRequest])` — and one
//! backend-agnostic result, [`coordinator::planner::PlanOutcome`]
//! (schedule times, assignment, per-node precision, throughput), tagged
//! with `Provenance::{Local, Remote, Federated}`.  Consumers pick a
//! backend in exactly one place (`server::select_planner`, driven by
//! `--remote` / `APDRL_SERVER`) and never match on backend-specific
//! types.  All backends return bit-identical plans for the same grid
//! (asserted in `tests/federation.rs`):
//!
//! | backend            | semantics                                                              | env vars |
//! |--------------------|------------------------------------------------------------------------|----------|
//! | `LocalPlanner`     | in-process `static_phase`/`plan_sweep`: concurrent cache-aware sweeps, parallel B&B inside a lone solve (never nested), duplicate points deduped by plan key | `APDRL_PLAN_CACHE`, `APDRL_PLAN_CACHE_MAX` |
//! | `RemotePlanner`    | one `apdrl serve` daemon over JSON-lines TCP; transparent reconnect-and-retry per idempotent call; rides the daemon's process-wide cache | `APDRL_SERVER=host:port` |
//! | `FederatedPlanner` | N daemons; `plan_many` sharded **by plan key** (cache-affine) on worker threads; failed shards retried on surviving hosts; results merged in request order | `APDRL_SERVER=h1:p,h2:p,…` |
//!
//! Underneath, the service keeps its earlier guarantees:
//!
//! * **Parallel exact solver** — `partition::ilp` fans the top of the
//!   branch-and-bound tree out over scoped threads sharing an atomic
//!   incumbent; `solve_ilp_sequential` is the single-threaded reference
//!   and both always return the same optimal makespan.  The fan-out is
//!   auto-tuned from per-solve telemetry ([`server::stats`]) and never
//!   changes the returned optimum.
//! * **Plan cache** — `partition::cache` memoizes solved plans keyed on
//!   `(algo, net shape, batch, obs/act dims, precision, platform
//!   fingerprint)`; repeated plans are O(1) with `explored == 0` and
//!   `cache_hit == true`.  The persisted file (`APDRL_PLAN_CACHE`) is
//!   schema-versioned and LRU-capped at `APDRL_PLAN_CACHE_MAX` entries
//!   (default 4096), with recency stamps surviving reloads.
//!
//! ## The planning server (`apdrl serve`)
//!
//! The [`server`] module runs the local backend as a long-lived daemon
//! so many processes/hosts share one planner and one plan cache.
//! `apdrl serve` listens on TCP (default `127.0.0.1:7040`) and speaks a
//! versioned JSON-lines protocol; `apdrl plan|sweep --remote <hosts>`
//! (or `APDRL_SERVER`) offloads planning to it — `<hosts>` is one
//! `host:port` or a comma-separated list, which federates.  One line
//! per request, one per response:
//!
//! ```text
//! → {"v":3,"verb":"plan","combo":"ddpg_lunar","batch":256,"quantized":true}
//! ← {"v":3,"ok":true,"plan":{"makespan_us":…,"schedule":[…],"cache_hit":false,…}}
//! → {"v":3,"verb":"sweep","combos":["dqn_cartpole","ddpg_lunar"],"batches":[64,256],"quantized":true}
//! ← {"v":3,"ok":true,"plans":[…]}
//! → {"v":3,"verb":"plan_many","points":[{"combo":"dqn_cartpole","batch":48,"quantized":true},…]}
//! ← {"v":3,"ok":true,"plans":[…]}
//! → {"v":3,"verb":"stats"}
//! ← {"v":3,"ok":true,"stats":{"requests":…,"cache":{"hits":…,"hit_rate":…},…}}
//! → {"v":3,"verb":"cache_flush"}
//! ← {"v":3,"ok":true,"flushed":12}
//! → {"v":3,"verb":"shutdown"}
//! ← {"v":3,"ok":true,"stopping":true}
//! ```
//!
//! Duplicate (combo, batch) pairs within one `sweep`/`plan_many`
//! request are deduped against the plan key server-side: repeats come
//! back as memoized copies (`explored == 0`) without re-profiling.
//! Schedule times survive the wire bit-for-bit (the JSON number writer
//! is shortest-round-trip), so any plan served from the shared cache is
//! *bit-identical* between remote and local callers — asserted in
//! `tests/server.rs`.  The optimal makespan is always identical; only a
//! *fresh* solo solve may pick a different co-optimal assignment than
//! an independent local solve when symmetric placements tie.
//!
//! ## Training as a service (protocol v3)
//!
//! Protocol v3 adds three verbs that make the daemon a multi-tenant
//! *training* service on top of the planning service: `train` submits a
//! job to the daemon's [`server::jobs::Scheduler`] (bounded
//! priority-then-FIFO queue, dedicated runner threads) and holds the
//! connection open while the daemon **streams** one frame line per
//! event — per-episode rewards, loss-scale FSM transitions, periodic
//! progress summaries, and checkpoints — before the final result line;
//! `jobs` lists every queued/running/finished job; `cancel` stops one
//! (queued jobs immediately, running jobs at the next round boundary):
//!
//! ```text
//! → {"v":3,"verb":"train","combo":"dqn_cartpole","seed":1,"max_env_steps":5000,"checkpoint_every":1000,…}
//! ← {"v":3,"ok":true,"frame":"episode","job":"job-1","episode":1,"reward":…,"env_steps":…}
//! ← {"v":3,"ok":true,"frame":"scale","job":"job-1","step":…,"from":…,"to":…}
//! ← {"v":3,"ok":true,"frame":"checkpoint","job":"job-1","env_steps":1000,"data":{…}}
//! ← {"v":3,"ok":true,"result":{"job":"job-1","status":"done","metrics":{…},…}}
//! → {"v":3,"verb":"jobs"}            ← {"v":3,"ok":true,"jobs":[…],"draining":false}
//! → {"v":3,"verb":"cancel","job":"job-1"}   ← {"v":3,"ok":true,"job":"job-1","phase":"running"}
//! ```
//!
//! Checkpoint frames carry a complete [`coordinator::Checkpoint`]:
//! weights (and FP32 masters), Adam moments, replay/rollout-free lane
//! RNG state, the loss-scale FSM, and the full metrics prefix — floats
//! as raw-bit hex, so a resumed job continues **bit-identically**, not
//! approximately (asserted per algorithm in `tests/train.rs`).  That
//! makes fail-over an ordinary client move: `apdrl train --combo …
//! --remote host1:7040,host2:7040` submits to the least-loaded host,
//! retains the newest streamed checkpoint, and — when the serving host
//! dies mid-stream or answers with its *draining* flag (graceful
//! shutdown drains running jobs to one final hand-off checkpoint) —
//! re-submits that checkpoint to a survivor, which replays the
//! remainder of the run bit-for-bit ([`server::RemoteTrainer`];
//! two-daemon kill covered in `tests/server.rs` and the CI smoke).
//! `apdrl jobs --remote <hosts> [--cancel ID]` is the matching
//! federation-wide listing/cancel CLI, and the `stats` verb reports
//! job lifecycle counters plus per-job wall-time percentiles.
//!
//! ### Durable jobs (`APDRL_JOB_DIR`)
//!
//! Point `APDRL_JOB_DIR` at a directory and `apdrl serve` journals
//! every job to disk ([`server::Journal`]): one schema-versioned JSON
//! file per job (`<dir>/<job-id>.json`, floats as raw-bit hex) holding
//! the submitted spec, the newest streamed checkpoint (spilled on the
//! job's `checkpoint_every` cadence), and the lifecycle phase.  All
//! writes are atomic (temp sibling + rename, [`util::fsio`]), so a
//! crash can tear nothing: at boot the daemon replays the journal —
//! running jobs re-queue with their spilled checkpoint as the resume
//! point and finish **bit-identically** (the CI restart smoke SIGKILLs
//! a daemon mid-job and `cmp`s the recovered reward log against an
//! uninterrupted control), queued jobs re-enter in priority order, and
//! terminal records compact away.  `apdrl jobs` flags replayed entries
//! as `recovered`, `stats` counts them, and `apdrl journal [--dir D]
//! [--job ID] [--rewards]` inspects the files offline — no daemon
//! needed.
//!
//! Queued jobs also survive losing their *host*: daemons gossip
//! lightweight digests of their queue on `jobs`/`stats` responses and
//! on every streamed checkpoint frame, and when the streaming client
//! ([`server::RemoteTrainer`]) marks a host dead it resubmits that
//! host's queued jobs to the survivors — exactly once, keyed by an
//! `origin` tag (`dead-host/job-id`) the receiving daemon treats as an
//! idempotency key.
//!
//! ## Observability (`apdrl dash`)
//!
//! Every long-running subsystem publishes structured events onto one
//! process-wide, bounded, lock-light bus ([`obs`]): the trainer
//! (`train.episode`, `train.scale` FSM transitions, `train.done`), the
//! planning pipeline (`plan.cache`, `sweep.start`/`sweep.point`/
//! `sweep.done`), the daemon (`serve.request`) and the federation
//! client (`fed.shard`, `fed.down`, `fed.failover`).  Publishing is
//! **zero-cost when nothing subscribes** — one relaxed atomic load —
//! and events only *observe* (no RNG, no training state), so an
//! attached dashboard can never perturb a run: the `--actors 1`
//! bit-identity tests in `tests/train.rs` hold with a live subscriber.
//!
//! `apdrl dash` serves the bus over plain HTTP (`std::net`, no
//! dependencies): `GET /events` is a `text/event-stream` SSE feed for
//! any number of concurrent subscribers, `GET /snapshot` a JSON view of
//! the retained ring, `GET /` an embedded single-file HTML dashboard
//! (reward curves, FSM transition log, sweep progress, federation
//! health — no external assets), and `POST /emit` the ingest endpoint
//! other processes push through.  The full event taxonomy is tabled in
//! the [`obs`] module docs.
//!
//! ```bash
//! apdrl dash --addr 127.0.0.1:7044          # hub + dashboard
//! APDRL_DASH=127.0.0.1:7044 apdrl train --combo dqn-cartpole  # forwards events
//! APDRL_DASH=127.0.0.1:7044 apdrl serve     # daemon events too
//! # then open http://127.0.0.1:7044/ in a browser
//! ```
//!
//! Setting `APDRL_DASH` in a producer process starts a background
//! forwarder that batches local bus events to the dash over `POST
//! /emit`; unset, nothing runs and nothing is paid.  Binding the dash
//! to a non-loopback address requires a shared secret in
//! `APDRL_DASH_TOKEN` (checked as `?token=` or `Authorization:
//! Bearer` on every request).
//!
//! ### Kernel tracing and the self-calibrating cost model
//!
//! The hot kernels (GEMM variants, im2col/col2im, `round_slice`, the
//! Adam step, env stepping, collection rounds) are instrumented with
//! [`obs::trace`] spans: shape-keyed wall-clock samples aggregated by
//! (kernel, log2-work bucket, thread count).  Like the bus, the span
//! entry point is **one relaxed atomic load when no recorder is armed**
//! — no clock read, no allocation — so instrumentation rides in every
//! build (`bench_exec` tracks the disarmed cost under the `"micro"`
//! key, and `tests/trace_overhead.rs` asserts zero allocations).
//! Spans record *time only*, never values, so tracing cannot perturb
//! bit-exactness: the 1-vs-N-thread and `--actors 1` identity suites
//! pass with tracing armed and a live bus subscriber attached.
//!
//! `apdrl calibrate` arms a recorder, sweeps the kernels across a
//! work ladder on 1-thread and pooled configurations, and saves a
//! [`profile::CalibrationTable`] (schema-versioned JSON, raw-bit hex
//! floats, so it round-trips bit-exactly).  Point `APDRL_CALIB` at the
//! file and the planner's PS cost model ([`profile::ps_model`]) prices
//! covered shapes from **measurements** (linear interpolation over
//! the table) instead of the analytic model, which remains the
//! cold-start fallback.  Every plan then reports its provenance:
//! `apdrl plan`/`profile` print per-step measured-vs-modeled error and
//! star the measured costs, `PlanOutcome` carries
//! `calib_steps`/`calib_err_pct`/`calib_fingerprint` (also on the v3
//! wire), the `stats` verb gains `obs` + `calibration` sections, and
//! the dash shows live `trace.kernel` rows.
//!
//! ```bash
//! apdrl calibrate --reps 5 --out calib.json   # measure this machine's kernels
//! export APDRL_CALIB=calib.json               # planner now prices measured costs
//! apdrl plan dqn_cartpole                     # "calibration: N/M steps measured, err …%"
//! APDRL_TRACE=1 apdrl train --combo dqn-cartpole --steps 2000  # live trace.kernel events
//! ```
//!
//! ### Environment variables
//!
//! | variable              | consumer          | meaning                              |
//! |-----------------------|-------------------|--------------------------------------|
//! | `APDRL_SERVER`        | clients           | daemon `host:port`, or a comma list (federation) |
//! | `APDRL_PLAN_CACHE`    | planner (both)    | JSON persistence path of the cache   |
//! | `APDRL_PLAN_CACHE_MAX`| planner (both)    | LRU entry cap of the cache (def 4096)|
//! | `APDRL_THREADS`       | CPU executor      | kernel worker-pool size (default: cores, capped at 8); bit-exact at any value |
//! | `APDRL_DASH`          | producers + dash  | dashboard `host:port`: producers forward events to it, `apdrl dash` binds it |
//! | `APDRL_DASH_TOKEN`    | producers + dash  | shared auth token; required for non-loopback dash binds |
//! | `APDRL_TRACE`         | any process       | set non-`0` to arm a kernel trace recorder at startup (spans publish `trace.kernel` bus events) |
//! | `APDRL_CALIB`         | planner (both)    | path to an `apdrl calibrate` table; PS costs of covered shapes come from measurements |
//! | `APDRL_JOB_DIR`       | daemon + `journal`| job-journal directory: specs/checkpoints/phases spill here atomically and replay at boot |

pub mod coordinator;
pub mod drl;
pub mod envs;
pub mod exec;
pub mod graph;
pub mod hw;
pub mod obs;
pub mod partition;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod util;

/// Microseconds — every latency in the analytic hardware model uses this
/// unit (the paper's Figs 4/6 span ns..ms; µs keeps f64 comfortable).
pub type Micros = f64;
