//! # AP-DRL — automatic task partitioning + hardware-aware quantization
//! for DRL training on a modeled AMD Versal ACAP.
//!
//! Reproduction of *"AP-DRL: A Synergistic Algorithm-Hardware Framework for
//! Automatic Task Partitioning of Deep Reinforcement Learning on Versal
//! ACAP"* as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: Versal ACAP performance model
//!   ([`hw`]), layer-level CDFG of the DRL training step ([`graph`]),
//!   DSE-based profiling ([`profile`]), ILP partitioning ([`partition`]),
//!   the hardware-aware quantization state machine ([`quant`]), the DRL
//!   runtime (environments [`envs`], agents [`drl`]) and the experiment
//!   coordinator ([`coordinator`]).
//! * **L2/L1 (python/, build time only)** — JAX train/act steps calling
//!   Pallas mixed-precision GEMM kernels, AOT-lowered to
//!   `artifacts/*.hlo.txt` and executed from rust via PJRT ([`runtime`]).
//!
//! The real VEK280 testbed is substituted by an analytic performance model
//! calibrated to the paper's reported constants (see DESIGN.md
//! §Substitutions); numerics (quantization, convergence) are real and run
//! through the PJRT artifacts.
//!
//! ## Feature flags
//!
//! * **`pjrt`** (default **off**) — compiles the PJRT execution layer:
//!   `runtime::{client, executor}`, the DRL agents
//!   (`drl::{dqn, ddpg, a2c, ppo, network}`) and `coordinator::trainer`.
//!   It needs the external `xla` bindings (not on crates.io; supply via a
//!   `[patch]`/path dependency) plus `make artifacts`.  Everything else —
//!   the performance model, profiling, the partitioning planner, the
//!   environments and the figure/bench machinery that does not train —
//!   builds and tests offline with `cargo build && cargo test`.
//!
//! ## The static-phase planning service
//!
//! The paper's static phase (DSE profiling → TAPCA → ILP) is served by
//! [`coordinator::static_phase`] as a memoized, batched planner:
//!
//! * **Parallel exact solver** — `partition::ilp` fans the top of the
//!   branch-and-bound tree out over scoped threads sharing an atomic
//!   incumbent; `solve_ilp_sequential` is the single-threaded reference
//!   and both always return the same optimal makespan.
//! * **Plan cache** — `partition::cache` memoizes solved plans keyed on
//!   `(algo, net shape, batch, obs/act dims, precision, platform
//!   fingerprint)`.  Repeated `static_phase` calls are O(1): they return
//!   the identical schedule with `solution.explored == 0` and
//!   `cache_hit == true`.  Set `APDRL_PLAN_CACHE=<path>` to persist the
//!   cache as JSON (via `util::json`) across processes; entries are
//!   re-validated against current profile shapes on every lookup.
//! * **Batched sweeps** — [`coordinator::plan_sweep`] /
//!   [`coordinator::plan_sweep_grid`] plan many (combo, batch, precision)
//!   points concurrently in request order; the `figures` binary, the
//!   benches and the examples drive their Table III/IV grids through it.

pub mod coordinator;
pub mod drl;
pub mod envs;
pub mod graph;
pub mod hw;
pub mod partition;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod util;

/// Microseconds — every latency in the analytic hardware model uses this
/// unit (the paper's Figs 4/6 span ns..ms; µs keeps f64 comfortable).
pub type Micros = f64;
