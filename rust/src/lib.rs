//! # AP-DRL — automatic task partitioning + hardware-aware quantization
//! for DRL training on a modeled AMD Versal ACAP.
//!
//! Reproduction of *"AP-DRL: A Synergistic Algorithm-Hardware Framework for
//! Automatic Task Partitioning of Deep Reinforcement Learning on Versal
//! ACAP"* as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: Versal ACAP performance model
//!   ([`hw`]), layer-level CDFG of the DRL training step ([`graph`]),
//!   DSE-based profiling ([`profile`]), ILP partitioning ([`partition`]),
//!   the hardware-aware quantization state machine ([`quant`]), the DRL
//!   runtime (environments [`envs`], agents [`drl`]) and the experiment
//!   coordinator ([`coordinator`]).
//! * **L2/L1 (python/, build time only)** — JAX train/act steps calling
//!   Pallas mixed-precision GEMM kernels, AOT-lowered to
//!   `artifacts/*.hlo.txt` and executed from rust via PJRT ([`runtime`]).
//!
//! The real VEK280 testbed is substituted by an analytic performance model
//! calibrated to the paper's reported constants (see DESIGN.md
//! §Substitutions); numerics (quantization, convergence) are real and run
//! through the PJRT artifacts.
//!
//! ## Feature flags
//!
//! * **`pjrt`** (default **off**) — compiles the PJRT execution layer:
//!   `runtime::{client, executor}`, the DRL agents
//!   (`drl::{dqn, ddpg, a2c, ppo, network}`) and `coordinator::trainer`.
//!   It needs the external `xla` bindings (not on crates.io; supply via a
//!   `[patch]`/path dependency) plus `make artifacts`.  Everything else —
//!   the performance model, profiling, the partitioning planner, the
//!   environments and the figure/bench machinery that does not train —
//!   builds and tests offline with `cargo build && cargo test`.
//!
//! ## The static-phase planning service
//!
//! The paper's static phase (DSE profiling → TAPCA → ILP) is served by
//! [`coordinator::static_phase`] as a memoized, batched planner:
//!
//! * **Parallel exact solver** — `partition::ilp` fans the top of the
//!   branch-and-bound tree out over scoped threads sharing an atomic
//!   incumbent; `solve_ilp_sequential` is the single-threaded reference
//!   and both always return the same optimal makespan.
//! * **Plan cache** — `partition::cache` memoizes solved plans keyed on
//!   `(algo, net shape, batch, obs/act dims, precision, platform
//!   fingerprint)`.  Repeated `static_phase` calls are O(1): they return
//!   the identical schedule with `solution.explored == 0` and
//!   `cache_hit == true`.  Set `APDRL_PLAN_CACHE=<path>` to persist the
//!   cache as JSON (via `util::json`) across processes; entries are
//!   re-validated against current profile shapes on every lookup.
//! * **Batched sweeps** — [`coordinator::plan_sweep`] /
//!   [`coordinator::plan_sweep_grid`] plan many (combo, batch, precision)
//!   points concurrently in request order; the `figures` binary, the
//!   benches and the examples drive their Table III/IV grids through it.
//! * **Cache bounds** — the persisted cache file is schema-versioned
//!   (old-format files drop to a cold start) and LRU-capped at
//!   `APDRL_PLAN_CACHE_MAX` entries (default 4096), so it no longer
//!   grows monotonically.
//! * **Adaptive solver fan-out** — the parallel B&B's prefix fan-out is
//!   tuned from per-solve telemetry ([`server::stats`]): small search
//!   trees get a shallow task split, big trees a deep one, with the
//!   fixed constant as the cold-start fallback.  Fan-out never changes
//!   the returned optimum.
//!
//! ## The planning server (`apdrl serve`)
//!
//! The [`server`] module runs that planning service as a long-lived
//! daemon so many processes/hosts share one planner and one plan cache.
//! `apdrl serve` listens on TCP (default `127.0.0.1:7040`) and speaks a
//! versioned JSON-lines protocol; `apdrl sweep --remote <addr>` (or the
//! `APDRL_SERVER` env var) offloads sweep grids to it.  One line per
//! request, one per response:
//!
//! ```text
//! → {"v":1,"verb":"plan","combo":"ddpg_lunar","batch":256,"quantized":true}
//! ← {"v":1,"ok":true,"plan":{"makespan_us":…,"schedule":[…],"cache_hit":false,…}}
//! → {"v":1,"verb":"sweep","combos":["dqn_cartpole","ddpg_lunar"],"batches":[64,256],"quantized":true}
//! ← {"v":1,"ok":true,"plans":[…]}
//! → {"v":1,"verb":"stats"}
//! ← {"v":1,"ok":true,"stats":{"requests":…,"cache":{"hits":…,"hit_rate":…},…}}
//! → {"v":1,"verb":"cache_flush"}
//! ← {"v":1,"ok":true,"flushed":12}
//! → {"v":1,"verb":"shutdown"}
//! ← {"v":1,"ok":true,"stopping":true}
//! ```
//!
//! Schedule times survive the wire bit-for-bit (the JSON number writer
//! is shortest-round-trip), so any plan served from the shared cache is
//! *bit-identical* between remote and local callers — asserted in
//! `tests/server.rs`.  The optimal makespan is always identical; only a
//! *fresh* solo solve may pick a different co-optimal assignment than
//! an independent local solve when symmetric placements tie.
//!
//! ### Environment variables
//!
//! | variable              | consumer          | meaning                              |
//! |-----------------------|-------------------|--------------------------------------|
//! | `APDRL_SERVER`        | clients           | default `host:port` of the daemon    |
//! | `APDRL_PLAN_CACHE`    | planner (both)    | JSON persistence path of the cache   |
//! | `APDRL_PLAN_CACHE_MAX`| planner (both)    | LRU entry cap of the cache (def 4096)|

pub mod coordinator;
pub mod drl;
pub mod envs;
pub mod graph;
pub mod hw;
pub mod partition;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod util;

/// Microseconds — every latency in the analytic hardware model uses this
/// unit (the paper's Figs 4/6 span ns..ms; µs keeps f64 comfortable).
pub type Micros = f64;
