//! # AP-DRL — automatic task partitioning + hardware-aware quantization
//! for DRL training on a modeled AMD Versal ACAP.
//!
//! Reproduction of *"AP-DRL: A Synergistic Algorithm-Hardware Framework for
//! Automatic Task Partitioning of Deep Reinforcement Learning on Versal
//! ACAP"* as a three-layer rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: Versal ACAP performance model
//!   ([`hw`]), layer-level CDFG of the DRL training step ([`graph`]),
//!   DSE-based profiling ([`profile`]), ILP partitioning ([`partition`]),
//!   the hardware-aware quantization state machine ([`quant`]), the DRL
//!   runtime (environments [`envs`], agents [`drl`]) and the experiment
//!   coordinator ([`coordinator`]).
//! * **L2/L1 (python/, build time only)** — JAX train/act steps calling
//!   Pallas mixed-precision GEMM kernels, AOT-lowered to
//!   `artifacts/*.hlo.txt` and executed from rust via PJRT ([`runtime`]).
//!
//! The real VEK280 testbed is substituted by an analytic performance model
//! calibrated to the paper's reported constants (see DESIGN.md
//! §Substitutions); numerics (quantization, convergence) are real and run
//! through the PJRT artifacts.

pub mod coordinator;
pub mod drl;
pub mod envs;
pub mod graph;
pub mod hw;
pub mod partition;
pub mod profile;
pub mod quant;
pub mod runtime;
pub mod util;

/// Microseconds — every latency in the analytic hardware model uses this
/// unit (the paper's Figs 4/6 span ns..ms; µs keeps f64 comfortable).
pub type Micros = f64;
