//! The planning server: AP-DRL's static phase as a long-lived network
//! service (`apdrl serve`).
//!
//! The static phase (DSE profiling → TAPCA → ILP partitioning) is the
//! expensive, cacheable half of the framework; PR 1 made it a memoized
//! in-process library, and this subsystem puts that library behind a
//! socket so *many processes and hosts* share one planner and one plan
//! cache:
//!
//! * [`daemon`] — the TCP daemon: accept loop + worker-thread pool, all
//!   connections sharing the process-wide `partition::cache`.
//! * [`protocol`] — the versioned JSON-lines request/response protocol
//!   (`plan`, `sweep`, `stats`, `cache_flush`, `shutdown`) and the
//!   [`RemotePlan`] payload type.
//! * [`client`] — the blocking [`RemotePlanner`], mirroring the local
//!   planning entry points over the wire; `apdrl sweep --remote <addr>`
//!   and the `remote_sweep` example drive grids through it.
//! * [`stats`] — daemon telemetry (request counters, solve wall time,
//!   queue depth) surfaced by the `stats` verb, plus the process-global
//!   solve telemetry that auto-tunes the parallel B&B fan-out in
//!   `partition::ilp`.
//!
//! Everything is `std::net` + `std::thread`: no async runtime, no
//! external dependencies, per the offline build contract.

pub mod client;
pub mod daemon;
pub mod protocol;
pub mod stats;

pub use client::{server_addr, RemotePlanner, ENV_ADDR};
pub use daemon::{serve, Server, DEFAULT_ADDR};
pub use protocol::{RemotePlan, RemoteScheduleEntry, PROTOCOL_VERSION};
pub use stats::ServerStats;
