//! The planning server: AP-DRL's static phase as a long-lived network
//! service (`apdrl serve`).
//!
//! The static phase (DSE profiling → TAPCA → ILP partitioning) is the
//! expensive, cacheable half of the framework; PR 1 made it a memoized
//! in-process library, and this subsystem puts that library behind a
//! socket so *many processes and hosts* share one planner and one plan
//! cache:
//!
//! * [`daemon`] — the TCP daemon: accept loop + worker-thread pool, all
//!   connections sharing the process-wide `partition::cache`; every verb
//!   is served through the in-process `Planner` backend.
//! * [`protocol`] — the versioned JSON-lines request/response protocol
//!   (`plan`, `sweep` — optionally streaming per-point progress lines —
//!   `plan_many`, `profile`, `stats`, `cache_flush`, `shutdown`, and
//!   the v3 training verbs `train` / `jobs` / `cancel`); plan payloads
//!   are serialized `coordinator::planner::PlanOutcome`s.
//! * [`jobs`] — the multi-tenant training-job [`Scheduler`] behind the
//!   `train` verb: bounded priority queue, runner-thread pool, per-job
//!   frame streams, cancel and graceful drain; training-as-a-service on
//!   top of the checkpoint format in `coordinator::checkpoint`.  With
//!   `APDRL_JOB_DIR` set, the scheduler journals every job to disk
//!   ([`jobs::journal`]) and the daemon replays the journal on boot —
//!   crash-safe, bit-identical restart recovery.
//! * [`client`] — the blocking [`RemotePlanner`]: the single-daemon
//!   remote implementation of the `Planner` trait, with transparent
//!   reconnect-and-retry; plus [`RemoteTrainer`], the federation-aware
//!   `train` client that follows checkpoint hand-offs across hosts.
//! * [`federation`] — [`FederatedPlanner`]: N daemons, `plan_many`
//!   sharded by plan key with fail-over onto surviving hosts; plus
//!   [`select_planner`], the CLI's one backend-choice point.
//! * [`stats`] — daemon telemetry (request counters, per-verb latency
//!   percentiles, solve wall time, queue depth, job-scheduler lifecycle
//!   counts and per-job wall-time percentiles) surfaced by the `stats`
//!   verb, plus the process-global solve telemetry that auto-tunes the
//!   parallel B&B fan-out in `partition::ilp`.
//!
//! The daemon and federation client also publish structured events
//! (`serve.request`, `fed.shard`, `fed.down`, `fed.failover`) onto the
//! process-wide [`crate::obs`] bus — free when nothing subscribes, live
//! on an `apdrl dash` dashboard when something does.
//!
//! Everything is `std::net` + `std::thread`: no async runtime, no
//! external dependencies, per the offline build contract.

pub mod client;
pub mod daemon;
pub mod federation;
pub mod jobs;
pub mod protocol;
pub mod stats;

pub use client::{server_addr, RemotePlanner, RemoteTrainer, TrainSubmission, ENV_ADDR};
pub use daemon::{serve, Server, DEFAULT_ADDR};
pub use federation::{parse_host_list, select_planner, FederatedPlanner};
pub use jobs::{JobSpec, Journal, Scheduler, SubmitOpts, ENV_JOB_DIR};
pub use protocol::PROTOCOL_VERSION;
pub use stats::ServerStats;
