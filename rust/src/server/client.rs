//! Blocking client for the planning daemon: [`RemotePlanner`] is the
//! remote backend of the [`Planner`] trait — one persistent connection
//! to one `apdrl serve` daemon, riding its process-wide plan cache.
//! Benches, examples and `apdrl plan|sweep --remote <addr>` drive whole
//! grids through it; `FederatedPlanner` composes several of these.
//!
//! [`RemoteTrainer`] is the training-side counterpart (protocol-v3
//! `train` / `jobs` / `cancel`): it submits a job to the least-loaded
//! host of a federation, streams the job's frames, and — because every
//! `checkpoint` frame carries a complete bit-exact snapshot — follows a
//! dying or draining host by re-submitting the newest checkpoint to a
//! survivor.  The job continues from the snapshot; only when every host
//! has failed does it error.  Hosts also gossip their queued-job
//! digests (on `stats` responses and checkpoint frames); when a host is
//! marked dead, its last-known queued jobs are re-submitted detached to
//! the survivors, exactly once per job (origin-tagged, idempotent
//! server-side).
//!
//! Addressing: pass an explicit `host:port`, or set the `APDRL_SERVER`
//! environment variable and use [`RemotePlanner::from_env`] /
//! [`server_addr`].
//!
//! The connection lives behind a `Mutex<Option<_>>`: verbs take `&self`
//! (the trait's contract), a dead socket is reconnected and retried once
//! per call (every verb is idempotent), and a planner whose last call
//! failed re-establishes the connection lazily on the next call instead
//! of staying dead — the client-side half of fail-over.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::planner::{PlanOutcome, PlanRequest, Planner, Provenance};
use crate::util::json::Json;

use super::federation::parse_host_list;
use super::protocol::{parse_response, plan_from_json, Request, WirePoint};

/// Environment variable naming the planning server — one `host:port`, or
/// a comma-separated list of them for a federated sweep.
pub const ENV_ADDR: &str = "APDRL_SERVER";

/// Resolve the server address spec: an explicit value wins (a bare
/// `--remote` flag arrives as the literal `"true"` and falls through),
/// then `APDRL_SERVER`, then a guiding error.  The result may be a
/// comma-separated host list; see `federation::parse_host_list`.
pub fn server_addr(explicit: Option<&str>) -> Result<String> {
    match explicit {
        Some(v) if !v.is_empty() && v != "true" => Ok(v.to_string()),
        _ => std::env::var(ENV_ADDR)
            .ok()
            .filter(|v| !v.is_empty())
            .ok_or_else(|| {
                anyhow!("no planning server address: pass --remote <host:port> or set {ENV_ADDR}")
            }),
    }
}

/// One live socket to the daemon (reader and writer halves).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to planning server at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn { reader, writer: stream })
    }

    /// Write one line, read one line.  `io::Result` so the caller can
    /// tell a dead socket from a server-side error response.
    fn transport(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        self.read_line()
    }

    /// Read one further response line (streaming verbs send several per
    /// request).  EOF mid-stream is an error, not an empty line.
    fn read_line(&mut self) -> std::io::Result<String> {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed by server",
            ));
        }
        Ok(buf)
    }
}

/// A blocking connection to one planning daemon.
pub struct RemotePlanner {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

impl RemotePlanner {
    /// Connect to `addr` (`host:port`).  The connection is established
    /// eagerly so an unreachable daemon is reported here, not on the
    /// first plan.
    pub fn connect(addr: &str) -> Result<RemotePlanner> {
        let conn = Conn::open(addr)?;
        Ok(RemotePlanner { addr: addr.to_string(), conn: Mutex::new(Some(conn)) })
    }

    /// Connect to the server named by `APDRL_SERVER`.
    pub fn from_env() -> Result<RemotePlanner> {
        RemotePlanner::connect(&server_addr(None)?)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/response round trip.  Transport failures (the daemon
    /// drops connections idle past its timeout, or died and came back)
    /// get one transparent reconnect-and-retry — every verb is
    /// idempotent — while protocol errors (`ok:false`) surface
    /// immediately without a retry.
    fn call(&self, req: &Request) -> Result<Json> {
        let line = req.to_line()?;
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            // A previous call failed and dropped the connection; this
            // call starts by re-establishing it.
            *guard = Some(Conn::open(&self.addr)?);
        }
        let first = guard.as_mut().expect("connection just ensured").transport(&line);
        let buf = match first {
            Ok(buf) => buf,
            Err(_) => {
                // Dead socket: drop it, reconnect once, retry the line.
                *guard = None;
                let mut conn = Conn::open(&self.addr).with_context(|| {
                    format!("reconnecting to planning server at {}", self.addr)
                })?;
                match conn.transport(&line) {
                    Ok(buf) => {
                        *guard = Some(conn);
                        buf
                    }
                    Err(e) => {
                        return Err(anyhow::Error::from(e).context(format!(
                            "planning server at {} dropped the connection twice",
                            self.addr
                        )));
                    }
                }
            }
        };
        parse_response(&buf)
    }

    /// Parse a `plans` array payload into outcomes tagged `Remote`.
    fn parse_plans(&self, resp: &Json, expect: usize) -> Result<Vec<PlanOutcome>> {
        let plans = resp
            .get("plans")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("sweep response missing `plans`"))?
            .iter()
            .map(|p| plan_from_json(p, Provenance::Remote { addr: self.addr.clone() }))
            .collect::<Result<Vec<_>>>()?;
        if plans.len() != expect {
            bail!(
                "planning server at {} returned {} plans for {} requests",
                self.addr,
                plans.len(),
                expect
            );
        }
        Ok(plans)
    }

    /// Remote single-point plan by registry name (the wire `plan` verb).
    pub fn plan_named(&self, combo: &str, batch: usize, quantized: bool) -> Result<PlanOutcome> {
        let resp = self.call(&Request::Plan {
            combo: combo.to_string(),
            batch,
            quantized,
        })?;
        plan_from_json(
            resp.get("plan").ok_or_else(|| anyhow!("plan response missing `plan`"))?,
            Provenance::Remote { addr: self.addr.clone() },
        )
    }

    /// Remote grid sweep (the wire `sweep` verb): plan `combos ×
    /// batches`, returned in combo-major request order like the local
    /// grid sweep.
    pub fn sweep(
        &self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
    ) -> Result<Vec<PlanOutcome>> {
        let resp = self.call(&Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
            stream: false,
        })?;
        self.parse_plans(&resp, combos.len() * batches.len())
    }

    /// Remote grid sweep with live progress: sets the protocol-v2
    /// `stream` flag, invokes `on_progress` for every per-point progress
    /// line the daemon pushes, and returns the final plans.  Against an
    /// older daemon (which ignores the flag) the first line is already
    /// the final response and `on_progress` never fires — callers get
    /// graceful degradation, not an error.
    pub fn sweep_stream(
        &self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
        on_progress: &mut dyn FnMut(&Json),
    ) -> Result<Vec<PlanOutcome>> {
        let req = Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
            stream: true,
        };
        let line = req.to_line()?;
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(Conn::open(&self.addr)?);
        }
        // One reconnect-and-retry on the opening exchange, mirroring
        // `call` — but once progress lines start flowing the stream is
        // not replayable, so mid-stream EOF surfaces as an error.
        let first = guard.as_mut().expect("connection just ensured").transport(&line);
        let mut buf = match first {
            Ok(buf) => buf,
            Err(_) => {
                *guard = None;
                let mut conn = Conn::open(&self.addr).with_context(|| {
                    format!("reconnecting to planning server at {}", self.addr)
                })?;
                let buf = conn.transport(&line).with_context(|| {
                    format!("planning server at {} dropped the connection twice", self.addr)
                })?;
                *guard = Some(conn);
                buf
            }
        };
        loop {
            let resp = parse_response(&buf)?;
            match resp.get("progress") {
                Some(point) => {
                    on_progress(point);
                    buf = guard
                        .as_mut()
                        .expect("streaming connection is live")
                        .read_line()
                        .with_context(|| {
                            format!(
                                "planning server at {} dropped the connection mid-sweep",
                                self.addr
                            )
                        })?;
                }
                None => return self.parse_plans(&resp, combos.len() * batches.len()),
            }
        }
    }

    /// Fetch the DSE candidate table for one combo/batch point (the
    /// protocol-v2 `profile` verb): per-node PL/AIE candidates with
    /// latency and resource figures, as the daemon's profiler sees them.
    pub fn profile(&self, combo: &str, batch: usize, quantized: bool) -> Result<Json> {
        let resp = self.call(&Request::Profile { combo: combo.to_string(), batch, quantized })?;
        resp.get("profile")
            .cloned()
            .ok_or_else(|| anyhow!("profile response missing `profile`"))
    }

    /// Fetch the daemon's telemetry object (the `stats` verb).
    pub fn stats(&self) -> Result<Json> {
        let resp = self.call(&Request::Stats)?;
        resp.get("stats").cloned().ok_or_else(|| anyhow!("stats response missing `stats`"))
    }

    /// Fetch the daemon's training-job listing (the protocol-v3 `jobs`
    /// verb): the job array plus the daemon's draining flag.
    pub fn jobs(&self) -> Result<(Json, bool)> {
        let resp = self.call(&Request::Jobs)?;
        let jobs =
            resp.get("jobs").cloned().ok_or_else(|| anyhow!("jobs response missing `jobs`"))?;
        let draining = resp.get("draining").and_then(Json::as_bool).unwrap_or(false);
        Ok((jobs, draining))
    }

    /// Cancel a training job (the protocol-v3 `cancel` verb); returns
    /// the phase the job was in when the daemon processed the cancel.
    pub fn cancel_job(&self, job: &str) -> Result<String> {
        let resp = self.call(&Request::Cancel { job: job.to_string() })?;
        Ok(resp
            .get("phase")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("cancel response missing `phase`"))?
            .to_string())
    }

    /// Drop every entry of the server's in-memory plan cache; returns
    /// how many were flushed.
    pub fn cache_flush(&self) -> Result<usize> {
        let resp = self.call(&Request::CacheFlush)?;
        resp.get("flushed")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("cache_flush response missing `flushed`"))
    }

    /// Ask the daemon to stop (acknowledged before it exits).  Consumes
    /// the client: the connection is closed server-side afterwards.
    pub fn shutdown(self) -> Result<()> {
        self.call(&Request::Shutdown).map(|_| ())
    }
}

/// Lower a [`PlanRequest`] onto the wire.  Combos travel by registry
/// name, so a customized `ComboConfig` is rejected here instead of
/// silently planning the registry variant daemon-side.
pub(super) fn wire_point(req: &PlanRequest) -> Result<WirePoint> {
    if !req.is_registry_exact() {
        bail!(
            "remote planning sends combos by name, and this request customizes \
             the {:?} config (changed net/dims); plan it with LocalPlanner",
            req.name()
        );
    }
    Ok(WirePoint {
        combo: req.name().to_string(),
        batch: req.batch,
        quantized: req.quantized,
    })
}

impl Planner for RemotePlanner {
    fn describe(&self) -> String {
        format!("remote {}", self.addr)
    }

    fn plan(&self, req: &PlanRequest) -> Result<PlanOutcome> {
        let point = wire_point(req)?;
        self.plan_named(&point.combo, point.batch, point.quantized)
    }

    fn plan_many(&self, reqs: &[PlanRequest]) -> Result<Vec<PlanOutcome>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let points = reqs.iter().map(wire_point).collect::<Result<Vec<_>>>()?;
        let resp = self.call(&Request::PlanMany { points })?;
        self.parse_plans(&resp, reqs.len())
    }
}

/// Parameters of one remote training job, as `apdrl train --remote`
/// lowers them onto the wire.  The `resume` checkpoint travels
/// separately: hand-off payloads are owned by [`RemoteTrainer::train`],
/// which re-submits the newest streamed checkpoint on fail-over.
#[derive(Clone, Debug)]
pub struct TrainSubmission {
    pub combo: String,
    pub seed: u64,
    pub actors: usize,
    pub max_env_steps: usize,
    pub max_episodes: usize,
    pub quantized: bool,
    /// Scheduler priority: higher runs first among queued jobs.
    pub priority: i64,
    /// Env steps between streamed checkpoint frames (0 = none — which
    /// also means a fail-over restarts training from scratch).
    pub checkpoint_every: u64,
    /// Env steps between streamed progress frames (0 = none).
    pub progress_every: u64,
}

impl TrainSubmission {
    fn request(&self, resume: Option<Json>) -> Request {
        self.request_opts(resume, false, None)
    }

    fn request_opts(&self, resume: Option<Json>, detach: bool, origin: Option<String>) -> Request {
        Request::Train {
            combo: self.combo.clone(),
            seed: self.seed,
            actors: self.actors,
            max_env_steps: self.max_env_steps,
            max_episodes: self.max_episodes,
            quantized: self.quantized,
            priority: self.priority,
            checkpoint_every: self.checkpoint_every,
            progress_every: self.progress_every,
            resume,
            detach,
            origin,
        }
    }
}

/// Federation-aware client of the protocol-v3 `train` verb (see the
/// module docs): least-loaded submission, frame streaming, checkpoint
/// hand-off across host deaths and drains.
pub struct RemoteTrainer {
    hosts: Vec<String>,
}

impl RemoteTrainer {
    /// Build over a host list (comma-separated specs accepted, deduped,
    /// order preserved).  Probed eagerly: a fully unreachable federation
    /// is reported here, a partially reachable one is fine — fail-over
    /// covers the rest.
    pub fn connect(hosts: &[String]) -> Result<RemoteTrainer> {
        let mut deduped: Vec<String> = Vec::new();
        for host in hosts.iter().flat_map(|spec| parse_host_list(spec)) {
            if !deduped.contains(&host) {
                deduped.push(host);
            }
        }
        if deduped.is_empty() {
            bail!("remote training needs at least one daemon address");
        }
        if !deduped.iter().any(|h| RemotePlanner::connect(h).is_ok()) {
            bail!(
                "none of the {} training hosts are reachable ({})",
                deduped.len(),
                deduped.join(", ")
            );
        }
        Ok(RemoteTrainer { hosts: deduped })
    }

    /// The (deduped) host list, in submission-preference order.
    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }

    pub fn describe(&self) -> String {
        match self.hosts.len() {
            1 => format!("remote {}", self.hosts[0]),
            n => format!("federated over {n} hosts ({})", self.hosts.join(", ")),
        }
    }

    /// Pick the least-loaded live host: queued + running jobs from each
    /// host's `stats` verb, skipping the `dead` ones.  Unreachable hosts
    /// are skipped for this pick but not marked dead — a daemon that was
    /// briefly down may be back by the next hand-off.  Each answering
    /// host's queued-job digest (gossiped on the stats response) is
    /// retained in `queued` — the last-known snapshot is what fails over
    /// when that host later dies.
    fn pick_host(&self, dead: &[bool], queued: &mut [Vec<Json>]) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (i, host) in self.hosts.iter().enumerate() {
            if dead[i] {
                continue;
            }
            let Ok(stats) = RemotePlanner::connect(host).and_then(|c| c.stats()) else {
                continue;
            };
            let jobs = stats.get("jobs");
            if let Some(Json::Arr(digest)) = jobs.and_then(|j| j.get("queued")) {
                queued[i] = digest.clone();
            }
            let field =
                |k: &str| jobs.and_then(|j| j.get(k)).and_then(Json::as_usize).unwrap_or(0) as u64;
            let load = field("queue_depth") + field("running");
            if best.map(|(b, _)| load < b).unwrap_or(true) {
                best = Some((load, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Fail a dead host's last-known queued jobs over to the survivors.
    /// Each digest entry is re-submitted detached, tagged with an
    /// `origin` key (the entry's own origin if it was itself a
    /// resubmission, else `dead-host/job-id`) — the client-side
    /// `resubmitted` set and the server-side origin idempotency together
    /// guarantee at-most-one live copy per original job.  Best-effort:
    /// an entry that no survivor accepts is dropped (the whole train
    /// call is about to error out of hosts anyway).
    fn fail_over_queue(
        &self,
        dead_hi: usize,
        dead: &[bool],
        queued: &[Vec<Json>],
        resubmitted: &mut HashSet<String>,
    ) {
        let dead_host = &self.hosts[dead_hi];
        for entry in &queued[dead_hi] {
            let Some(job) = entry.get("job").and_then(Json::as_str) else { continue };
            let origin = entry
                .get("origin")
                .and_then(Json::as_str)
                .map(str::to_string)
                .unwrap_or_else(|| format!("{dead_host}/{job}"));
            if resubmitted.contains(&origin) {
                continue;
            }
            let Some(req) = resubmit_request(entry, &origin) else { continue };
            for (i, host) in self.hosts.iter().enumerate() {
                if dead[i] || i == dead_hi {
                    continue;
                }
                if let Ok(new_id) = submit_detached(host, &req) {
                    resubmitted.insert(origin.clone());
                    if crate::obs::active() {
                        crate::obs::publish(
                            crate::obs::Event::new("job.resubmitted")
                                .tag("origin", &origin)
                                .tag("to", host)
                                .tag("job", &new_id),
                        );
                    }
                    break;
                }
            }
        }
    }

    /// Submit `sub` fire-and-forget to the least-loaded host: the daemon
    /// acks with the job id on one line and runs the job headless (no
    /// frame stream; with `APDRL_JOB_DIR` set the journal keeps the
    /// durable state).  Returns `(host, job_id)`.
    pub fn train_detached(&self, sub: &TrainSubmission) -> Result<(String, String)> {
        let dead = vec![false; self.hosts.len()];
        let mut queued = vec![Vec::new(); self.hosts.len()];
        let hi = self
            .pick_host(&dead, &mut queued)
            .ok_or_else(|| anyhow!("no training host reachable"))?;
        let host = self.hosts[hi].clone();
        let job = submit_detached(&host, &sub.request_opts(None, true, None))?;
        Ok((host, job))
    }

    /// Run one training job across the federation.  Every streamed frame
    /// is handed to `on_frame(serving_host, frame)` — episodes, scale
    /// transitions, progress, checkpoints — and the newest checkpoint
    /// frame's `data` is retained as the hand-off payload: when the
    /// serving host dies mid-stream or drains for shutdown, the job is
    /// re-submitted to the least-loaded survivor with `resume` set, and
    /// training continues from the snapshot.  Returns the final `result`
    /// payload from whichever host finished the job; errors only when
    /// every host has failed.
    pub fn train(
        &self,
        sub: &TrainSubmission,
        on_frame: &mut dyn FnMut(&str, &Json),
    ) -> Result<Json> {
        let mut resume: Option<Json> = None;
        let mut dead = vec![false; self.hosts.len()];
        let mut queued: Vec<Vec<Json>> = vec![Vec::new(); self.hosts.len()];
        let mut resubmitted: HashSet<String> = HashSet::new();
        let mut last_err: Option<anyhow::Error> = None;
        loop {
            let Some(hi) = self.pick_host(&dead, &mut queued) else {
                let n = self.hosts.len();
                return Err(last_err
                    .unwrap_or_else(|| anyhow!("no training host reachable"))
                    .context(format!("train: all {n} hosts failed or are draining")));
            };
            let host = &self.hosts[hi];
            match stream_train(host, sub, &mut resume, &mut queued[hi], on_frame) {
                Ok(Some(result)) => return Ok(result),
                // Graceful drain: this host is going away — hand off,
                // and fail its queued jobs over to the survivors too.
                Ok(None) => {
                    dead[hi] = true;
                    last_err = Some(anyhow!("training host {host} is draining"));
                    self.fail_over_queue(hi, &dead, &queued, &mut resubmitted);
                }
                Err(e) => {
                    dead[hi] = true;
                    last_err = Some(e);
                    self.fail_over_queue(hi, &dead, &queued, &mut resubmitted);
                }
            }
        }
    }

    /// The `jobs` listing of every reachable host: `(host, jobs array,
    /// draining flag)` per daemon.  Errors only when no host answered.
    pub fn jobs(&self) -> Result<Vec<(String, Json, bool)>> {
        let mut out = Vec::new();
        let mut last_err = None;
        for host in &self.hosts {
            match RemotePlanner::connect(host).and_then(|c| c.jobs()) {
                Ok((jobs, draining)) => out.push((host.clone(), jobs, draining)),
                Err(e) => last_err = Some(e),
            }
        }
        match (out.is_empty(), last_err) {
            (true, Some(e)) => Err(e.context("no training host answered `jobs`")),
            _ => Ok(out),
        }
    }

    /// Cancel `job` wherever it lives: each host is asked in turn until
    /// one recognizes the id.  Returns `(host, phase)` from that host.
    pub fn cancel(&self, job: &str) -> Result<(String, String)> {
        let mut last_err: Option<anyhow::Error> = None;
        for host in &self.hosts {
            match RemotePlanner::connect(host).and_then(|c| c.cancel_job(job)) {
                Ok(phase) => return Ok((host.clone(), phase)),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("no training hosts configured"))
            .context(format!("cancelling job {job:?}")))
    }
}

/// Submit `sub` to `host` and stream its frames.  `resume` is both the
/// input hand-off payload and the output: each streamed checkpoint
/// frame replaces it, so a mid-stream death loses at most one
/// checkpoint interval of work.  `Ok(None)` means the host drained the
/// job for shutdown (re-submit to a survivor); `Ok(Some(result))` is
/// the job's terminal payload — done, user-cancelled, or failed
/// server-side.
fn stream_train(
    host: &str,
    sub: &TrainSubmission,
    resume: &mut Option<Json>,
    queued: &mut Vec<Json>,
    on_frame: &mut dyn FnMut(&str, &Json),
) -> Result<Option<Json>> {
    let line = sub.request(resume.clone()).to_line()?;
    let mut conn = Conn::open(host)?;
    let mut buf =
        conn.transport(&line).with_context(|| format!("submitting train job to {host}"))?;
    loop {
        let resp = parse_response(&buf)?;
        match resp.get("frame").and_then(Json::as_str) {
            Some(kind) => {
                if kind == "checkpoint" {
                    if let Some(data) = resp.get("data") {
                        *resume = Some(data.clone());
                    }
                    // Gossip rides the checkpoint frames: retain the
                    // host's queued-job digest so its queue can fail
                    // over if this stream later dies.  Final (hand-off)
                    // frames are skipped deliberately — a draining host
                    // has just cancelled its queue, and rescuing those
                    // jobs needs the pre-drain snapshot.
                    let is_final = resp.get("final").and_then(Json::as_bool).unwrap_or(false);
                    if !is_final {
                        if let Some(Json::Arr(digest)) = resp.get("queued") {
                            *queued = digest.clone();
                        }
                    }
                }
                on_frame(host, &resp);
                buf = conn
                    .read_line()
                    .with_context(|| format!("training host {host} died mid-job"))?;
            }
            None => {
                let result = resp.get("result").cloned().ok_or_else(|| {
                    anyhow!("train response from {host} has neither `frame` nor `result`")
                })?;
                let status = result.get("status").and_then(Json::as_str).unwrap_or("");
                let draining = result.get("draining").and_then(Json::as_bool).unwrap_or(false);
                if draining && status == "cancelled" {
                    return Ok(None);
                }
                return Ok(Some(result));
            }
        }
    }
}

/// Lower one queued-job digest entry (see `Scheduler::queued_digest`)
/// back onto the wire as a detached, origin-tagged `train` request.
/// `None` when the entry is missing a required field — a foreign or
/// truncated digest is skipped, never submitted half-parsed.
fn resubmit_request(entry: &Json, origin: &str) -> Option<Request> {
    Some(Request::Train {
        combo: entry.get("combo").and_then(Json::as_str)?.to_string(),
        seed: entry.get("seed").and_then(Json::as_f64)? as u64,
        actors: entry.get("actors").and_then(Json::as_usize)?,
        max_env_steps: entry.get("max_env_steps").and_then(Json::as_usize)?,
        max_episodes: entry.get("max_episodes").and_then(Json::as_usize)?,
        quantized: entry.get("quantized").and_then(Json::as_bool).unwrap_or(false),
        priority: entry.get("priority").and_then(Json::as_f64).unwrap_or(0.0) as i64,
        checkpoint_every: entry.get("checkpoint_every").and_then(Json::as_f64).unwrap_or(0.0)
            as u64,
        progress_every: entry.get("progress_every").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        resume: None,
        detach: true,
        origin: Some(origin.to_string()),
    })
}

/// One-shot detached submission: send the request, read the single ack
/// line, return the job id the daemon assigned (or the one it already
/// held for this origin — submission is idempotent server-side).
fn submit_detached(host: &str, req: &Request) -> Result<String> {
    let line = req.to_line()?;
    let mut conn = Conn::open(host)?;
    let buf = conn
        .transport(&line)
        .with_context(|| format!("resubmitting queued job to {host}"))?;
    let resp = parse_response(&buf)?;
    resp.get("job")
        .and_then(Json::as_str)
        .map(str::to_string)
        .ok_or_else(|| anyhow!("detached train response from {host} missing `job`"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_resolution_prefers_explicit_then_env() {
        assert_eq!(server_addr(Some("10.0.0.1:7040")).unwrap(), "10.0.0.1:7040");
        // A bare `--remote` flag (value "true") must NOT be treated as a
        // hostname; without the env var set it is a guiding error.
        if std::env::var(ENV_ADDR).is_err() {
            let e = server_addr(Some("true")).unwrap_err();
            assert!(format!("{e}").contains(ENV_ADDR), "{e}");
            let e = server_addr(None).unwrap_err();
            assert!(format!("{e}").contains("--remote"), "{e}");
        }
    }

    #[test]
    fn connect_to_nowhere_reports_the_address() {
        // Port 1 on loopback is essentially never listening.
        let e = match RemotePlanner::connect("127.0.0.1:1") {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e:#}").contains("127.0.0.1:1"), "{e:#}");
    }

    #[test]
    fn train_submissions_lower_onto_the_wire_and_back() {
        let sub = TrainSubmission {
            combo: "dqn_cartpole".into(),
            seed: 11,
            actors: 2,
            max_env_steps: 4_000,
            max_episodes: 60,
            quantized: true,
            priority: 5,
            checkpoint_every: 500,
            progress_every: 250,
        };
        let req = sub.request(None);
        let line = req.to_line().unwrap();
        assert_eq!(Request::parse_line(&line).unwrap(), req);
        // A retained checkpoint payload rides the resume field verbatim.
        let resumed = sub.request(Some(Json::obj(vec![("ckpt_version", Json::Num(1.0))])));
        let line = resumed.to_line().unwrap();
        assert!(line.contains("ckpt_version"), "{line}");
        assert_eq!(Request::parse_line(&line).unwrap(), resumed);
    }

    #[test]
    fn unreachable_trainer_federation_is_reported_at_connect() {
        // Loopback port 1 is essentially never listening.
        let hosts = vec!["127.0.0.1:1".to_string()];
        let e = match RemoteTrainer::connect(&hosts) {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e}").contains("reachable"), "{e}");
        assert!(RemoteTrainer::connect(&[]).is_err());
    }

    #[test]
    fn customized_combos_cannot_be_lowered_onto_the_wire() {
        let named = PlanRequest::named("dqn_cartpole").unwrap();
        assert!(wire_point(&named).is_ok());
        let mut custom = crate::coordinator::combo("dqn_cartpole");
        custom.net = crate::graph::NetSpec::mlp(&[4, 512, 512, 2]);
        let e = wire_point(&PlanRequest::new(custom, 64, true)).unwrap_err();
        assert!(format!("{e}").contains("LocalPlanner"), "{e}");
    }
}
