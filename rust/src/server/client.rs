//! Blocking client for the planning daemon: [`RemotePlanner`] is the
//! remote backend of the [`Planner`] trait — one persistent connection
//! to one `apdrl serve` daemon, riding its process-wide plan cache.
//! Benches, examples and `apdrl plan|sweep --remote <addr>` drive whole
//! grids through it; `FederatedPlanner` composes several of these.
//!
//! Addressing: pass an explicit `host:port`, or set the `APDRL_SERVER`
//! environment variable and use [`RemotePlanner::from_env`] /
//! [`server_addr`].
//!
//! The connection lives behind a `Mutex<Option<_>>`: verbs take `&self`
//! (the trait's contract), a dead socket is reconnected and retried once
//! per call (every verb is idempotent), and a planner whose last call
//! failed re-establishes the connection lazily on the next call instead
//! of staying dead — the client-side half of fail-over.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::planner::{PlanOutcome, PlanRequest, Planner, Provenance};
use crate::util::json::Json;

use super::protocol::{parse_response, plan_from_json, Request, WirePoint};

/// Environment variable naming the planning server — one `host:port`, or
/// a comma-separated list of them for a federated sweep.
pub const ENV_ADDR: &str = "APDRL_SERVER";

/// Resolve the server address spec: an explicit value wins (a bare
/// `--remote` flag arrives as the literal `"true"` and falls through),
/// then `APDRL_SERVER`, then a guiding error.  The result may be a
/// comma-separated host list; see `federation::parse_host_list`.
pub fn server_addr(explicit: Option<&str>) -> Result<String> {
    match explicit {
        Some(v) if !v.is_empty() && v != "true" => Ok(v.to_string()),
        _ => std::env::var(ENV_ADDR)
            .ok()
            .filter(|v| !v.is_empty())
            .ok_or_else(|| {
                anyhow!("no planning server address: pass --remote <host:port> or set {ENV_ADDR}")
            }),
    }
}

/// One live socket to the daemon (reader and writer halves).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to planning server at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn { reader, writer: stream })
    }

    /// Write one line, read one line.  `io::Result` so the caller can
    /// tell a dead socket from a server-side error response.
    fn transport(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        self.read_line()
    }

    /// Read one further response line (streaming verbs send several per
    /// request).  EOF mid-stream is an error, not an empty line.
    fn read_line(&mut self) -> std::io::Result<String> {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed by server",
            ));
        }
        Ok(buf)
    }
}

/// A blocking connection to one planning daemon.
pub struct RemotePlanner {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

impl RemotePlanner {
    /// Connect to `addr` (`host:port`).  The connection is established
    /// eagerly so an unreachable daemon is reported here, not on the
    /// first plan.
    pub fn connect(addr: &str) -> Result<RemotePlanner> {
        let conn = Conn::open(addr)?;
        Ok(RemotePlanner { addr: addr.to_string(), conn: Mutex::new(Some(conn)) })
    }

    /// Connect to the server named by `APDRL_SERVER`.
    pub fn from_env() -> Result<RemotePlanner> {
        RemotePlanner::connect(&server_addr(None)?)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/response round trip.  Transport failures (the daemon
    /// drops connections idle past its timeout, or died and came back)
    /// get one transparent reconnect-and-retry — every verb is
    /// idempotent — while protocol errors (`ok:false`) surface
    /// immediately without a retry.
    fn call(&self, req: &Request) -> Result<Json> {
        let line = req.to_line()?;
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            // A previous call failed and dropped the connection; this
            // call starts by re-establishing it.
            *guard = Some(Conn::open(&self.addr)?);
        }
        let first = guard.as_mut().expect("connection just ensured").transport(&line);
        let buf = match first {
            Ok(buf) => buf,
            Err(_) => {
                // Dead socket: drop it, reconnect once, retry the line.
                *guard = None;
                let mut conn = Conn::open(&self.addr).with_context(|| {
                    format!("reconnecting to planning server at {}", self.addr)
                })?;
                match conn.transport(&line) {
                    Ok(buf) => {
                        *guard = Some(conn);
                        buf
                    }
                    Err(e) => {
                        return Err(anyhow::Error::from(e).context(format!(
                            "planning server at {} dropped the connection twice",
                            self.addr
                        )));
                    }
                }
            }
        };
        parse_response(&buf)
    }

    /// Parse a `plans` array payload into outcomes tagged `Remote`.
    fn parse_plans(&self, resp: &Json, expect: usize) -> Result<Vec<PlanOutcome>> {
        let plans = resp
            .get("plans")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("sweep response missing `plans`"))?
            .iter()
            .map(|p| plan_from_json(p, Provenance::Remote { addr: self.addr.clone() }))
            .collect::<Result<Vec<_>>>()?;
        if plans.len() != expect {
            bail!(
                "planning server at {} returned {} plans for {} requests",
                self.addr,
                plans.len(),
                expect
            );
        }
        Ok(plans)
    }

    /// Remote single-point plan by registry name (the wire `plan` verb).
    pub fn plan_named(&self, combo: &str, batch: usize, quantized: bool) -> Result<PlanOutcome> {
        let resp = self.call(&Request::Plan {
            combo: combo.to_string(),
            batch,
            quantized,
        })?;
        plan_from_json(
            resp.get("plan").ok_or_else(|| anyhow!("plan response missing `plan`"))?,
            Provenance::Remote { addr: self.addr.clone() },
        )
    }

    /// Remote grid sweep (the wire `sweep` verb): plan `combos ×
    /// batches`, returned in combo-major request order like the local
    /// grid sweep.
    pub fn sweep(
        &self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
    ) -> Result<Vec<PlanOutcome>> {
        let resp = self.call(&Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
            stream: false,
        })?;
        self.parse_plans(&resp, combos.len() * batches.len())
    }

    /// Remote grid sweep with live progress: sets the protocol-v2
    /// `stream` flag, invokes `on_progress` for every per-point progress
    /// line the daemon pushes, and returns the final plans.  Against an
    /// older daemon (which ignores the flag) the first line is already
    /// the final response and `on_progress` never fires — callers get
    /// graceful degradation, not an error.
    pub fn sweep_stream(
        &self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
        on_progress: &mut dyn FnMut(&Json),
    ) -> Result<Vec<PlanOutcome>> {
        let req = Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
            stream: true,
        };
        let line = req.to_line()?;
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(Conn::open(&self.addr)?);
        }
        // One reconnect-and-retry on the opening exchange, mirroring
        // `call` — but once progress lines start flowing the stream is
        // not replayable, so mid-stream EOF surfaces as an error.
        let first = guard.as_mut().expect("connection just ensured").transport(&line);
        let mut buf = match first {
            Ok(buf) => buf,
            Err(_) => {
                *guard = None;
                let mut conn = Conn::open(&self.addr).with_context(|| {
                    format!("reconnecting to planning server at {}", self.addr)
                })?;
                let buf = conn.transport(&line).with_context(|| {
                    format!("planning server at {} dropped the connection twice", self.addr)
                })?;
                *guard = Some(conn);
                buf
            }
        };
        loop {
            let resp = parse_response(&buf)?;
            match resp.get("progress") {
                Some(point) => {
                    on_progress(point);
                    buf = guard
                        .as_mut()
                        .expect("streaming connection is live")
                        .read_line()
                        .with_context(|| {
                            format!(
                                "planning server at {} dropped the connection mid-sweep",
                                self.addr
                            )
                        })?;
                }
                None => return self.parse_plans(&resp, combos.len() * batches.len()),
            }
        }
    }

    /// Fetch the DSE candidate table for one combo/batch point (the
    /// protocol-v2 `profile` verb): per-node PL/AIE candidates with
    /// latency and resource figures, as the daemon's profiler sees them.
    pub fn profile(&self, combo: &str, batch: usize, quantized: bool) -> Result<Json> {
        let resp = self.call(&Request::Profile { combo: combo.to_string(), batch, quantized })?;
        resp.get("profile")
            .cloned()
            .ok_or_else(|| anyhow!("profile response missing `profile`"))
    }

    /// Fetch the daemon's telemetry object (the `stats` verb).
    pub fn stats(&self) -> Result<Json> {
        let resp = self.call(&Request::Stats)?;
        resp.get("stats").cloned().ok_or_else(|| anyhow!("stats response missing `stats`"))
    }

    /// Drop every entry of the server's in-memory plan cache; returns
    /// how many were flushed.
    pub fn cache_flush(&self) -> Result<usize> {
        let resp = self.call(&Request::CacheFlush)?;
        resp.get("flushed")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("cache_flush response missing `flushed`"))
    }

    /// Ask the daemon to stop (acknowledged before it exits).  Consumes
    /// the client: the connection is closed server-side afterwards.
    pub fn shutdown(self) -> Result<()> {
        self.call(&Request::Shutdown).map(|_| ())
    }
}

/// Lower a [`PlanRequest`] onto the wire.  Combos travel by registry
/// name, so a customized `ComboConfig` is rejected here instead of
/// silently planning the registry variant daemon-side.
pub(super) fn wire_point(req: &PlanRequest) -> Result<WirePoint> {
    if !req.is_registry_exact() {
        bail!(
            "remote planning sends combos by name, and this request customizes \
             the {:?} config (changed net/dims); plan it with LocalPlanner",
            req.name()
        );
    }
    Ok(WirePoint {
        combo: req.name().to_string(),
        batch: req.batch,
        quantized: req.quantized,
    })
}

impl Planner for RemotePlanner {
    fn describe(&self) -> String {
        format!("remote {}", self.addr)
    }

    fn plan(&self, req: &PlanRequest) -> Result<PlanOutcome> {
        let point = wire_point(req)?;
        self.plan_named(&point.combo, point.batch, point.quantized)
    }

    fn plan_many(&self, reqs: &[PlanRequest]) -> Result<Vec<PlanOutcome>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let points = reqs.iter().map(wire_point).collect::<Result<Vec<_>>>()?;
        let resp = self.call(&Request::PlanMany { points })?;
        self.parse_plans(&resp, reqs.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_resolution_prefers_explicit_then_env() {
        assert_eq!(server_addr(Some("10.0.0.1:7040")).unwrap(), "10.0.0.1:7040");
        // A bare `--remote` flag (value "true") must NOT be treated as a
        // hostname; without the env var set it is a guiding error.
        if std::env::var(ENV_ADDR).is_err() {
            let e = server_addr(Some("true")).unwrap_err();
            assert!(format!("{e}").contains(ENV_ADDR), "{e}");
            let e = server_addr(None).unwrap_err();
            assert!(format!("{e}").contains("--remote"), "{e}");
        }
    }

    #[test]
    fn connect_to_nowhere_reports_the_address() {
        // Port 1 on loopback is essentially never listening.
        let e = match RemotePlanner::connect("127.0.0.1:1") {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e:#}").contains("127.0.0.1:1"), "{e:#}");
    }

    #[test]
    fn customized_combos_cannot_be_lowered_onto_the_wire() {
        let named = PlanRequest::named("dqn_cartpole").unwrap();
        assert!(wire_point(&named).is_ok());
        let mut custom = crate::coordinator::combo("dqn_cartpole");
        custom.net = crate::graph::NetSpec::mlp(&[4, 512, 512, 2]);
        let e = wire_point(&PlanRequest::new(custom, 64, true)).unwrap_err();
        assert!(format!("{e}").contains("LocalPlanner"), "{e}");
    }
}
