//! Blocking client for the planning daemon: [`RemotePlanner`] is the
//! remote backend of the [`Planner`] trait — one persistent connection
//! to one `apdrl serve` daemon, riding its process-wide plan cache.
//! Benches, examples and `apdrl plan|sweep --remote <addr>` drive whole
//! grids through it; `FederatedPlanner` composes several of these.
//!
//! [`RemoteTrainer`] is the training-side counterpart (protocol-v3
//! `train` / `jobs` / `cancel`): it submits a job to the least-loaded
//! host of a federation, streams the job's frames, and — because every
//! `checkpoint` frame carries a complete bit-exact snapshot — follows a
//! dying or draining host by re-submitting the newest checkpoint to a
//! survivor.  The job continues from the snapshot; only when every host
//! has failed does it error.
//!
//! Addressing: pass an explicit `host:port`, or set the `APDRL_SERVER`
//! environment variable and use [`RemotePlanner::from_env`] /
//! [`server_addr`].
//!
//! The connection lives behind a `Mutex<Option<_>>`: verbs take `&self`
//! (the trait's contract), a dead socket is reconnected and retried once
//! per call (every verb is idempotent), and a planner whose last call
//! failed re-establishes the connection lazily on the next call instead
//! of staying dead — the client-side half of fail-over.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Mutex;

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::planner::{PlanOutcome, PlanRequest, Planner, Provenance};
use crate::util::json::Json;

use super::federation::parse_host_list;
use super::protocol::{parse_response, plan_from_json, Request, WirePoint};

/// Environment variable naming the planning server — one `host:port`, or
/// a comma-separated list of them for a federated sweep.
pub const ENV_ADDR: &str = "APDRL_SERVER";

/// Resolve the server address spec: an explicit value wins (a bare
/// `--remote` flag arrives as the literal `"true"` and falls through),
/// then `APDRL_SERVER`, then a guiding error.  The result may be a
/// comma-separated host list; see `federation::parse_host_list`.
pub fn server_addr(explicit: Option<&str>) -> Result<String> {
    match explicit {
        Some(v) if !v.is_empty() && v != "true" => Ok(v.to_string()),
        _ => std::env::var(ENV_ADDR)
            .ok()
            .filter(|v| !v.is_empty())
            .ok_or_else(|| {
                anyhow!("no planning server address: pass --remote <host:port> or set {ENV_ADDR}")
            }),
    }
}

/// One live socket to the daemon (reader and writer halves).
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Conn {
    fn open(addr: &str) -> Result<Conn> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to planning server at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        Ok(Conn { reader, writer: stream })
    }

    /// Write one line, read one line.  `io::Result` so the caller can
    /// tell a dead socket from a server-side error response.
    fn transport(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        self.read_line()
    }

    /// Read one further response line (streaming verbs send several per
    /// request).  EOF mid-stream is an error, not an empty line.
    fn read_line(&mut self) -> std::io::Result<String> {
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed by server",
            ));
        }
        Ok(buf)
    }
}

/// A blocking connection to one planning daemon.
pub struct RemotePlanner {
    addr: String,
    conn: Mutex<Option<Conn>>,
}

impl RemotePlanner {
    /// Connect to `addr` (`host:port`).  The connection is established
    /// eagerly so an unreachable daemon is reported here, not on the
    /// first plan.
    pub fn connect(addr: &str) -> Result<RemotePlanner> {
        let conn = Conn::open(addr)?;
        Ok(RemotePlanner { addr: addr.to_string(), conn: Mutex::new(Some(conn)) })
    }

    /// Connect to the server named by `APDRL_SERVER`.
    pub fn from_env() -> Result<RemotePlanner> {
        RemotePlanner::connect(&server_addr(None)?)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/response round trip.  Transport failures (the daemon
    /// drops connections idle past its timeout, or died and came back)
    /// get one transparent reconnect-and-retry — every verb is
    /// idempotent — while protocol errors (`ok:false`) surface
    /// immediately without a retry.
    fn call(&self, req: &Request) -> Result<Json> {
        let line = req.to_line()?;
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            // A previous call failed and dropped the connection; this
            // call starts by re-establishing it.
            *guard = Some(Conn::open(&self.addr)?);
        }
        let first = guard.as_mut().expect("connection just ensured").transport(&line);
        let buf = match first {
            Ok(buf) => buf,
            Err(_) => {
                // Dead socket: drop it, reconnect once, retry the line.
                *guard = None;
                let mut conn = Conn::open(&self.addr).with_context(|| {
                    format!("reconnecting to planning server at {}", self.addr)
                })?;
                match conn.transport(&line) {
                    Ok(buf) => {
                        *guard = Some(conn);
                        buf
                    }
                    Err(e) => {
                        return Err(anyhow::Error::from(e).context(format!(
                            "planning server at {} dropped the connection twice",
                            self.addr
                        )));
                    }
                }
            }
        };
        parse_response(&buf)
    }

    /// Parse a `plans` array payload into outcomes tagged `Remote`.
    fn parse_plans(&self, resp: &Json, expect: usize) -> Result<Vec<PlanOutcome>> {
        let plans = resp
            .get("plans")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("sweep response missing `plans`"))?
            .iter()
            .map(|p| plan_from_json(p, Provenance::Remote { addr: self.addr.clone() }))
            .collect::<Result<Vec<_>>>()?;
        if plans.len() != expect {
            bail!(
                "planning server at {} returned {} plans for {} requests",
                self.addr,
                plans.len(),
                expect
            );
        }
        Ok(plans)
    }

    /// Remote single-point plan by registry name (the wire `plan` verb).
    pub fn plan_named(&self, combo: &str, batch: usize, quantized: bool) -> Result<PlanOutcome> {
        let resp = self.call(&Request::Plan {
            combo: combo.to_string(),
            batch,
            quantized,
        })?;
        plan_from_json(
            resp.get("plan").ok_or_else(|| anyhow!("plan response missing `plan`"))?,
            Provenance::Remote { addr: self.addr.clone() },
        )
    }

    /// Remote grid sweep (the wire `sweep` verb): plan `combos ×
    /// batches`, returned in combo-major request order like the local
    /// grid sweep.
    pub fn sweep(
        &self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
    ) -> Result<Vec<PlanOutcome>> {
        let resp = self.call(&Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
            stream: false,
        })?;
        self.parse_plans(&resp, combos.len() * batches.len())
    }

    /// Remote grid sweep with live progress: sets the protocol-v2
    /// `stream` flag, invokes `on_progress` for every per-point progress
    /// line the daemon pushes, and returns the final plans.  Against an
    /// older daemon (which ignores the flag) the first line is already
    /// the final response and `on_progress` never fires — callers get
    /// graceful degradation, not an error.
    pub fn sweep_stream(
        &self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
        on_progress: &mut dyn FnMut(&Json),
    ) -> Result<Vec<PlanOutcome>> {
        let req = Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
            stream: true,
        };
        let line = req.to_line()?;
        let mut guard = self.conn.lock().unwrap();
        if guard.is_none() {
            *guard = Some(Conn::open(&self.addr)?);
        }
        // One reconnect-and-retry on the opening exchange, mirroring
        // `call` — but once progress lines start flowing the stream is
        // not replayable, so mid-stream EOF surfaces as an error.
        let first = guard.as_mut().expect("connection just ensured").transport(&line);
        let mut buf = match first {
            Ok(buf) => buf,
            Err(_) => {
                *guard = None;
                let mut conn = Conn::open(&self.addr).with_context(|| {
                    format!("reconnecting to planning server at {}", self.addr)
                })?;
                let buf = conn.transport(&line).with_context(|| {
                    format!("planning server at {} dropped the connection twice", self.addr)
                })?;
                *guard = Some(conn);
                buf
            }
        };
        loop {
            let resp = parse_response(&buf)?;
            match resp.get("progress") {
                Some(point) => {
                    on_progress(point);
                    buf = guard
                        .as_mut()
                        .expect("streaming connection is live")
                        .read_line()
                        .with_context(|| {
                            format!(
                                "planning server at {} dropped the connection mid-sweep",
                                self.addr
                            )
                        })?;
                }
                None => return self.parse_plans(&resp, combos.len() * batches.len()),
            }
        }
    }

    /// Fetch the DSE candidate table for one combo/batch point (the
    /// protocol-v2 `profile` verb): per-node PL/AIE candidates with
    /// latency and resource figures, as the daemon's profiler sees them.
    pub fn profile(&self, combo: &str, batch: usize, quantized: bool) -> Result<Json> {
        let resp = self.call(&Request::Profile { combo: combo.to_string(), batch, quantized })?;
        resp.get("profile")
            .cloned()
            .ok_or_else(|| anyhow!("profile response missing `profile`"))
    }

    /// Fetch the daemon's telemetry object (the `stats` verb).
    pub fn stats(&self) -> Result<Json> {
        let resp = self.call(&Request::Stats)?;
        resp.get("stats").cloned().ok_or_else(|| anyhow!("stats response missing `stats`"))
    }

    /// Fetch the daemon's training-job listing (the protocol-v3 `jobs`
    /// verb): the job array plus the daemon's draining flag.
    pub fn jobs(&self) -> Result<(Json, bool)> {
        let resp = self.call(&Request::Jobs)?;
        let jobs =
            resp.get("jobs").cloned().ok_or_else(|| anyhow!("jobs response missing `jobs`"))?;
        let draining = resp.get("draining").and_then(Json::as_bool).unwrap_or(false);
        Ok((jobs, draining))
    }

    /// Cancel a training job (the protocol-v3 `cancel` verb); returns
    /// the phase the job was in when the daemon processed the cancel.
    pub fn cancel_job(&self, job: &str) -> Result<String> {
        let resp = self.call(&Request::Cancel { job: job.to_string() })?;
        Ok(resp
            .get("phase")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("cancel response missing `phase`"))?
            .to_string())
    }

    /// Drop every entry of the server's in-memory plan cache; returns
    /// how many were flushed.
    pub fn cache_flush(&self) -> Result<usize> {
        let resp = self.call(&Request::CacheFlush)?;
        resp.get("flushed")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("cache_flush response missing `flushed`"))
    }

    /// Ask the daemon to stop (acknowledged before it exits).  Consumes
    /// the client: the connection is closed server-side afterwards.
    pub fn shutdown(self) -> Result<()> {
        self.call(&Request::Shutdown).map(|_| ())
    }
}

/// Lower a [`PlanRequest`] onto the wire.  Combos travel by registry
/// name, so a customized `ComboConfig` is rejected here instead of
/// silently planning the registry variant daemon-side.
pub(super) fn wire_point(req: &PlanRequest) -> Result<WirePoint> {
    if !req.is_registry_exact() {
        bail!(
            "remote planning sends combos by name, and this request customizes \
             the {:?} config (changed net/dims); plan it with LocalPlanner",
            req.name()
        );
    }
    Ok(WirePoint {
        combo: req.name().to_string(),
        batch: req.batch,
        quantized: req.quantized,
    })
}

impl Planner for RemotePlanner {
    fn describe(&self) -> String {
        format!("remote {}", self.addr)
    }

    fn plan(&self, req: &PlanRequest) -> Result<PlanOutcome> {
        let point = wire_point(req)?;
        self.plan_named(&point.combo, point.batch, point.quantized)
    }

    fn plan_many(&self, reqs: &[PlanRequest]) -> Result<Vec<PlanOutcome>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        let points = reqs.iter().map(wire_point).collect::<Result<Vec<_>>>()?;
        let resp = self.call(&Request::PlanMany { points })?;
        self.parse_plans(&resp, reqs.len())
    }
}

/// Parameters of one remote training job, as `apdrl train --remote`
/// lowers them onto the wire.  The `resume` checkpoint travels
/// separately: hand-off payloads are owned by [`RemoteTrainer::train`],
/// which re-submits the newest streamed checkpoint on fail-over.
#[derive(Clone, Debug)]
pub struct TrainSubmission {
    pub combo: String,
    pub seed: u64,
    pub actors: usize,
    pub max_env_steps: usize,
    pub max_episodes: usize,
    pub quantized: bool,
    /// Scheduler priority: higher runs first among queued jobs.
    pub priority: i64,
    /// Env steps between streamed checkpoint frames (0 = none — which
    /// also means a fail-over restarts training from scratch).
    pub checkpoint_every: u64,
    /// Env steps between streamed progress frames (0 = none).
    pub progress_every: u64,
}

impl TrainSubmission {
    fn request(&self, resume: Option<Json>) -> Request {
        Request::Train {
            combo: self.combo.clone(),
            seed: self.seed,
            actors: self.actors,
            max_env_steps: self.max_env_steps,
            max_episodes: self.max_episodes,
            quantized: self.quantized,
            priority: self.priority,
            checkpoint_every: self.checkpoint_every,
            progress_every: self.progress_every,
            resume,
        }
    }
}

/// Federation-aware client of the protocol-v3 `train` verb (see the
/// module docs): least-loaded submission, frame streaming, checkpoint
/// hand-off across host deaths and drains.
pub struct RemoteTrainer {
    hosts: Vec<String>,
}

impl RemoteTrainer {
    /// Build over a host list (comma-separated specs accepted, deduped,
    /// order preserved).  Probed eagerly: a fully unreachable federation
    /// is reported here, a partially reachable one is fine — fail-over
    /// covers the rest.
    pub fn connect(hosts: &[String]) -> Result<RemoteTrainer> {
        let mut deduped: Vec<String> = Vec::new();
        for host in hosts.iter().flat_map(|spec| parse_host_list(spec)) {
            if !deduped.contains(&host) {
                deduped.push(host);
            }
        }
        if deduped.is_empty() {
            bail!("remote training needs at least one daemon address");
        }
        if !deduped.iter().any(|h| RemotePlanner::connect(h).is_ok()) {
            bail!(
                "none of the {} training hosts are reachable ({})",
                deduped.len(),
                deduped.join(", ")
            );
        }
        Ok(RemoteTrainer { hosts: deduped })
    }

    /// The (deduped) host list, in submission-preference order.
    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }

    pub fn describe(&self) -> String {
        match self.hosts.len() {
            1 => format!("remote {}", self.hosts[0]),
            n => format!("federated over {n} hosts ({})", self.hosts.join(", ")),
        }
    }

    /// Pick the least-loaded live host: queued + running jobs from each
    /// host's `stats` verb, skipping the `dead` ones.  Unreachable hosts
    /// are skipped for this pick but not marked dead — a daemon that was
    /// briefly down may be back by the next hand-off.
    fn pick_host(&self, dead: &[bool]) -> Option<usize> {
        let mut best: Option<(u64, usize)> = None;
        for (i, host) in self.hosts.iter().enumerate() {
            if dead[i] {
                continue;
            }
            let Ok(stats) = RemotePlanner::connect(host).and_then(|c| c.stats()) else {
                continue;
            };
            let jobs = stats.get("jobs");
            let field =
                |k: &str| jobs.and_then(|j| j.get(k)).and_then(Json::as_usize).unwrap_or(0) as u64;
            let load = field("queue_depth") + field("running");
            if best.map(|(b, _)| load < b).unwrap_or(true) {
                best = Some((load, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Run one training job across the federation.  Every streamed frame
    /// is handed to `on_frame(serving_host, frame)` — episodes, scale
    /// transitions, progress, checkpoints — and the newest checkpoint
    /// frame's `data` is retained as the hand-off payload: when the
    /// serving host dies mid-stream or drains for shutdown, the job is
    /// re-submitted to the least-loaded survivor with `resume` set, and
    /// training continues from the snapshot.  Returns the final `result`
    /// payload from whichever host finished the job; errors only when
    /// every host has failed.
    pub fn train(
        &self,
        sub: &TrainSubmission,
        on_frame: &mut dyn FnMut(&str, &Json),
    ) -> Result<Json> {
        let mut resume: Option<Json> = None;
        let mut dead = vec![false; self.hosts.len()];
        let mut last_err: Option<anyhow::Error> = None;
        loop {
            let Some(hi) = self.pick_host(&dead) else {
                let n = self.hosts.len();
                return Err(last_err
                    .unwrap_or_else(|| anyhow!("no training host reachable"))
                    .context(format!("train: all {n} hosts failed or are draining")));
            };
            let host = &self.hosts[hi];
            match stream_train(host, sub, &mut resume, on_frame) {
                Ok(Some(result)) => return Ok(result),
                // Graceful drain: this host is going away — hand off.
                Ok(None) => {
                    dead[hi] = true;
                    last_err = Some(anyhow!("training host {host} is draining"));
                }
                Err(e) => {
                    dead[hi] = true;
                    last_err = Some(e);
                }
            }
        }
    }

    /// The `jobs` listing of every reachable host: `(host, jobs array,
    /// draining flag)` per daemon.  Errors only when no host answered.
    pub fn jobs(&self) -> Result<Vec<(String, Json, bool)>> {
        let mut out = Vec::new();
        let mut last_err = None;
        for host in &self.hosts {
            match RemotePlanner::connect(host).and_then(|c| c.jobs()) {
                Ok((jobs, draining)) => out.push((host.clone(), jobs, draining)),
                Err(e) => last_err = Some(e),
            }
        }
        match (out.is_empty(), last_err) {
            (true, Some(e)) => Err(e.context("no training host answered `jobs`")),
            _ => Ok(out),
        }
    }

    /// Cancel `job` wherever it lives: each host is asked in turn until
    /// one recognizes the id.  Returns `(host, phase)` from that host.
    pub fn cancel(&self, job: &str) -> Result<(String, String)> {
        let mut last_err: Option<anyhow::Error> = None;
        for host in &self.hosts {
            match RemotePlanner::connect(host).and_then(|c| c.cancel_job(job)) {
                Ok(phase) => return Ok((host.clone(), phase)),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("no training hosts configured"))
            .context(format!("cancelling job {job:?}")))
    }
}

/// Submit `sub` to `host` and stream its frames.  `resume` is both the
/// input hand-off payload and the output: each streamed checkpoint
/// frame replaces it, so a mid-stream death loses at most one
/// checkpoint interval of work.  `Ok(None)` means the host drained the
/// job for shutdown (re-submit to a survivor); `Ok(Some(result))` is
/// the job's terminal payload — done, user-cancelled, or failed
/// server-side.
fn stream_train(
    host: &str,
    sub: &TrainSubmission,
    resume: &mut Option<Json>,
    on_frame: &mut dyn FnMut(&str, &Json),
) -> Result<Option<Json>> {
    let line = sub.request(resume.clone()).to_line()?;
    let mut conn = Conn::open(host)?;
    let mut buf =
        conn.transport(&line).with_context(|| format!("submitting train job to {host}"))?;
    loop {
        let resp = parse_response(&buf)?;
        match resp.get("frame").and_then(Json::as_str) {
            Some(kind) => {
                if kind == "checkpoint" {
                    if let Some(data) = resp.get("data") {
                        *resume = Some(data.clone());
                    }
                }
                on_frame(host, &resp);
                buf = conn
                    .read_line()
                    .with_context(|| format!("training host {host} died mid-job"))?;
            }
            None => {
                let result = resp.get("result").cloned().ok_or_else(|| {
                    anyhow!("train response from {host} has neither `frame` nor `result`")
                })?;
                let status = result.get("status").and_then(Json::as_str).unwrap_or("");
                let draining = result.get("draining").and_then(Json::as_bool).unwrap_or(false);
                if draining && status == "cancelled" {
                    return Ok(None);
                }
                return Ok(Some(result));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_resolution_prefers_explicit_then_env() {
        assert_eq!(server_addr(Some("10.0.0.1:7040")).unwrap(), "10.0.0.1:7040");
        // A bare `--remote` flag (value "true") must NOT be treated as a
        // hostname; without the env var set it is a guiding error.
        if std::env::var(ENV_ADDR).is_err() {
            let e = server_addr(Some("true")).unwrap_err();
            assert!(format!("{e}").contains(ENV_ADDR), "{e}");
            let e = server_addr(None).unwrap_err();
            assert!(format!("{e}").contains("--remote"), "{e}");
        }
    }

    #[test]
    fn connect_to_nowhere_reports_the_address() {
        // Port 1 on loopback is essentially never listening.
        let e = match RemotePlanner::connect("127.0.0.1:1") {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e:#}").contains("127.0.0.1:1"), "{e:#}");
    }

    #[test]
    fn train_submissions_lower_onto_the_wire_and_back() {
        let sub = TrainSubmission {
            combo: "dqn_cartpole".into(),
            seed: 11,
            actors: 2,
            max_env_steps: 4_000,
            max_episodes: 60,
            quantized: true,
            priority: 5,
            checkpoint_every: 500,
            progress_every: 250,
        };
        let req = sub.request(None);
        let line = req.to_line().unwrap();
        assert_eq!(Request::parse_line(&line).unwrap(), req);
        // A retained checkpoint payload rides the resume field verbatim.
        let resumed = sub.request(Some(Json::obj(vec![("ckpt_version", Json::Num(1.0))])));
        let line = resumed.to_line().unwrap();
        assert!(line.contains("ckpt_version"), "{line}");
        assert_eq!(Request::parse_line(&line).unwrap(), resumed);
    }

    #[test]
    fn unreachable_trainer_federation_is_reported_at_connect() {
        // Loopback port 1 is essentially never listening.
        let hosts = vec!["127.0.0.1:1".to_string()];
        let e = match RemoteTrainer::connect(&hosts) {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e}").contains("reachable"), "{e}");
        assert!(RemoteTrainer::connect(&[]).is_err());
    }

    #[test]
    fn customized_combos_cannot_be_lowered_onto_the_wire() {
        let named = PlanRequest::named("dqn_cartpole").unwrap();
        assert!(wire_point(&named).is_ok());
        let mut custom = crate::coordinator::combo("dqn_cartpole");
        custom.net = crate::graph::NetSpec::mlp(&[4, 512, 512, 2]);
        let e = wire_point(&PlanRequest::new(custom, 64, true)).unwrap_err();
        assert!(format!("{e}").contains("LocalPlanner"), "{e}");
    }
}
