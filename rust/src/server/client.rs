//! Blocking client for the planning daemon: [`RemotePlanner`] mirrors
//! the local planning entry points (`static_phase` → [`plan`],
//! `plan_sweep_grid` → [`sweep`]) over one persistent connection, so
//! benches, examples and the `apdrl sweep --remote` path can offload
//! whole grids to a shared daemon and ride its process-wide plan cache.
//!
//! Addressing: pass an explicit `host:port`, or set the `APDRL_SERVER`
//! environment variable and use [`RemotePlanner::from_env`] /
//! [`server_addr`].
//!
//! [`plan`]: RemotePlanner::plan
//! [`sweep`]: RemotePlanner::sweep

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

use super::protocol::{parse_response, RemotePlan, Request};

/// Environment variable naming the planning server (`host:port`).
pub const ENV_ADDR: &str = "APDRL_SERVER";

/// Resolve the server address: an explicit value wins (a bare `--remote`
/// flag arrives as the literal `"true"` and falls through), then
/// `APDRL_SERVER`, then a guiding error.
pub fn server_addr(explicit: Option<&str>) -> Result<String> {
    match explicit {
        Some(v) if !v.is_empty() && v != "true" => Ok(v.to_string()),
        _ => std::env::var(ENV_ADDR)
            .ok()
            .filter(|v| !v.is_empty())
            .ok_or_else(|| {
                anyhow!("no planning server address: pass --remote <host:port> or set {ENV_ADDR}")
            }),
    }
}

/// A blocking connection to one planning daemon.
pub struct RemotePlanner {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    addr: String,
}

impl RemotePlanner {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> Result<RemotePlanner> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to planning server at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone()?);
        Ok(RemotePlanner { reader, writer: stream, addr: addr.to_string() })
    }

    /// Connect to the server named by `APDRL_SERVER`.
    pub fn from_env() -> Result<RemotePlanner> {
        RemotePlanner::connect(&server_addr(None)?)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// One request/response round trip.  Transport failures (the daemon
    /// drops connections idle past its timeout) get one transparent
    /// reconnect-and-retry — every verb is idempotent — while protocol
    /// errors (`ok:false`) surface immediately without a retry.
    fn call(&mut self, req: &Request) -> Result<Json> {
        let line = req.to_line()?;
        let buf = match self.transport(&line) {
            Ok(buf) => buf,
            Err(_) => {
                let addr = self.addr.clone();
                *self = RemotePlanner::connect(&addr)?;
                self.transport(&line).with_context(|| {
                    format!("planning server at {addr} dropped the connection twice")
                })?
            }
        };
        parse_response(&buf)
    }

    /// Write one line, read one line.  `io::Result` so [`call`] can tell
    /// a dead socket from a server-side error response.
    ///
    /// [`call`]: RemotePlanner::call
    fn transport(&mut self, line: &str) -> std::io::Result<String> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut buf = String::new();
        let n = self.reader.read_line(&mut buf)?;
        if n == 0 {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed by server",
            ));
        }
        Ok(buf)
    }

    /// Remote `static_phase`: plan one (combo, batch, precision) point.
    pub fn plan(&mut self, combo: &str, batch: usize, quantized: bool) -> Result<RemotePlan> {
        let resp = self.call(&Request::Plan {
            combo: combo.to_string(),
            batch,
            quantized,
        })?;
        RemotePlan::from_json(
            resp.get("plan").ok_or_else(|| anyhow!("plan response missing `plan`"))?,
        )
    }

    /// Remote `plan_sweep_grid`: plan `combos × batches`, returned in
    /// combo-major request order like the local grid sweep.
    pub fn sweep(
        &mut self,
        combos: &[String],
        batches: &[usize],
        quantized: bool,
    ) -> Result<Vec<RemotePlan>> {
        let resp = self.call(&Request::Sweep {
            combos: combos.to_vec(),
            batches: batches.to_vec(),
            quantized,
        })?;
        resp.get("plans")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("sweep response missing `plans`"))?
            .iter()
            .map(RemotePlan::from_json)
            .collect()
    }

    /// Fetch the daemon's telemetry object (the `stats` verb).
    pub fn stats(&mut self) -> Result<Json> {
        let resp = self.call(&Request::Stats)?;
        resp.get("stats").cloned().ok_or_else(|| anyhow!("stats response missing `stats`"))
    }

    /// Drop every entry of the server's in-memory plan cache; returns
    /// how many were flushed.
    pub fn cache_flush(&mut self) -> Result<usize> {
        let resp = self.call(&Request::CacheFlush)?;
        resp.get("flushed")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("cache_flush response missing `flushed`"))
    }

    /// Ask the daemon to stop (acknowledged before it exits).  Consumes
    /// the client: the connection is closed server-side afterwards.
    pub fn shutdown(mut self) -> Result<()> {
        self.call(&Request::Shutdown).map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn address_resolution_prefers_explicit_then_env() {
        assert_eq!(server_addr(Some("10.0.0.1:7040")).unwrap(), "10.0.0.1:7040");
        // A bare `--remote` flag (value "true") must NOT be treated as a
        // hostname; without the env var set it is a guiding error.
        if std::env::var(ENV_ADDR).is_err() {
            let e = server_addr(Some("true")).unwrap_err();
            assert!(format!("{e}").contains(ENV_ADDR), "{e}");
            let e = server_addr(None).unwrap_err();
            assert!(format!("{e}").contains("--remote"), "{e}");
        }
    }

    #[test]
    fn connect_to_nowhere_reports_the_address() {
        // Port 1 on loopback is essentially never listening.
        let e = match RemotePlanner::connect("127.0.0.1:1") {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e:#}").contains("127.0.0.1:1"), "{e:#}");
    }
}
