//! Crash-safe job journal: one JSON file per job under `APDRL_JOB_DIR`.
//!
//! Every submission writes its spec; every streamed checkpoint frame
//! re-spills the newest [`Checkpoint`](crate::coordinator::Checkpoint)
//! (raw-bit-hex floats, exactly the wire format); terminal transitions
//! stamp the final phase while keeping that checkpoint.  All writes go
//! through [`fsio::atomic_write`](crate::util::fsio::atomic_write), so
//! a SIGKILL at any instant leaves either the previous complete record
//! or the new one — never a torn file.
//!
//! On boot the daemon replays the directory ([`Journal::load_all`]):
//! queued and running entries re-enter the scheduler (running ones
//! resume from their spilled checkpoint, bit-identically by the
//! trainer's resume guarantee), terminal entries are compacted away,
//! and unreadable files are skipped with a warning (a journal must
//! never stop the daemon from booting).

use std::collections::BTreeMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::coordinator::{Checkpoint, TrainLimits};
use crate::util::fsio::atomic_write;
use crate::util::json::Json;

use super::JobSpec;

/// Directory holding the per-job journal files; unset means jobs are
/// memory-only (pre-durability behavior).
pub const ENV_JOB_DIR: &str = "APDRL_JOB_DIR";

/// Journal record format version.  Readers drop other-schema files
/// wholesale (with a warning) rather than risk misparsing them.
pub const JOURNAL_VERSION: f64 = 1.0;

/// A journal entry read back at boot, ready to re-enter the scheduler.
pub struct RecoveredJob {
    pub id: String,
    /// Numeric suffix of `job-N`, so the scheduler can advance its id
    /// counter past every recovered job.
    pub seq: u64,
    /// Phase at crash time (`queued`/`running`/terminal names).
    pub phase: String,
    /// Origin tag a fail-over resubmission carried, if any.
    pub origin: Option<String>,
    /// The job's spec, with `resume` already pointing at the newest
    /// spilled checkpoint when one was journalled.
    pub spec: JobSpec,
}

impl RecoveredJob {
    pub fn terminal(&self) -> bool {
        matches!(self.phase.as_str(), "done" | "cancelled" | "failed")
    }
}

/// Handle on one journal directory.  All operations are best-effort:
/// persistence must never take down the scheduler, so I/O errors are
/// swallowed (writes) or surfaced as warnings (reads).
pub struct Journal {
    dir: PathBuf,
}

impl Journal {
    pub fn open(dir: impl Into<PathBuf>) -> Journal {
        let dir = dir.into();
        let _ = fs::create_dir_all(&dir);
        Journal { dir }
    }

    /// The journal named by `APDRL_JOB_DIR`, or `None` when unset.
    pub fn from_env() -> Option<Journal> {
        std::env::var(ENV_JOB_DIR)
            .ok()
            .filter(|d| !d.is_empty())
            .map(Journal::open)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    fn path(&self, id: &str) -> PathBuf {
        self.dir.join(format!("{id}.json"))
    }

    /// Write a fresh record for a just-submitted (or just-recovered)
    /// job.  A submission that carried a resume checkpoint spills it
    /// immediately — a crash before the first cadence checkpoint must
    /// not lose the hand-off state the client already gave up.
    pub fn record_submit(&self, id: &str, spec: &JobSpec, origin: Option<&str>, recovered: bool) {
        let mut root = BTreeMap::new();
        root.insert("schema".to_string(), Json::Num(JOURNAL_VERSION));
        root.insert("job".to_string(), Json::Str(id.to_string()));
        root.insert("phase".to_string(), Json::Str("queued".to_string()));
        root.insert("spec".to_string(), spec_to_json(spec));
        if let Some(origin) = origin {
            root.insert("origin".to_string(), Json::Str(origin.to_string()));
        }
        if recovered {
            root.insert("recovered".to_string(), Json::Bool(true));
        }
        if let Some(ckpt) = &spec.resume {
            root.insert("checkpoint".to_string(), ckpt.to_json());
        }
        self.write(id, Json::Obj(root));
    }

    /// Stamp a phase transition, preserving the rest of the record
    /// (spec, origin, newest checkpoint).
    pub fn record_phase(&self, id: &str, phase: &str, error: Option<&str>) {
        self.update(id, |root| {
            root.insert("phase".to_string(), Json::Str(phase.to_string()));
            if let Some(err) = error {
                root.insert("error".to_string(), Json::Str(err.to_string()));
            }
        });
    }

    /// Spill the newest streamed checkpoint (the frame's `data` field,
    /// already in wire format).
    pub fn record_checkpoint(&self, id: &str, data: &Json) {
        self.update(id, |root| {
            root.insert("phase".to_string(), Json::Str("running".to_string()));
            root.insert("checkpoint".to_string(), data.clone());
        });
    }

    /// Drop a job's record (terminal compaction / finished eviction).
    pub fn remove(&self, id: &str) {
        let _ = fs::remove_file(self.path(id));
    }

    /// Read every journal record in the directory, skipping (with a
    /// warning) anything torn, garbage, or from another schema.
    /// Temp siblings from interrupted atomic writes are dot-prefixed
    /// and skipped by the extension filter.
    pub fn load_all(&self) -> Vec<RecoveredJob> {
        let Ok(entries) = fs::read_dir(&self.dir) else { return Vec::new() };
        let mut jobs = Vec::new();
        let mut paths: Vec<PathBuf> = entries
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.extension().is_some_and(|x| x == "json")
                    && p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| !n.starts_with('.'))
            })
            .collect();
        paths.sort();
        for path in paths {
            match read_record(&path) {
                Some(job) => jobs.push(job),
                None => eprintln!(
                    "warning: job journal entry {} is torn or from another schema; skipping it",
                    path.display()
                ),
            }
        }
        jobs
    }

    /// Read-modify-write one record.  A missing or unreadable record is
    /// left alone: an update must never resurrect a compacted job.
    fn update(&self, id: &str, f: impl FnOnce(&mut BTreeMap<String, Json>)) {
        let path = self.path(id);
        let Ok(text) = fs::read_to_string(&path) else { return };
        let Ok(Json::Obj(mut root)) = Json::parse(&text) else { return };
        if root.get("schema").and_then(Json::as_f64) != Some(JOURNAL_VERSION) {
            return;
        }
        f(&mut root);
        self.write(id, Json::Obj(root));
    }

    fn write(&self, id: &str, root: Json) {
        if let Ok(line) = root.to_line() {
            let _ = atomic_write(&self.path(id), (line + "\n").as_bytes());
        }
    }
}

fn spec_to_json(spec: &JobSpec) -> Json {
    Json::obj(vec![
        ("combo", Json::Str(spec.combo.clone())),
        ("seed", Json::Num(spec.seed as f64)),
        ("actors", Json::Num(spec.actors as f64)),
        ("max_env_steps", Json::Num(spec.limits.max_env_steps as f64)),
        ("max_episodes", Json::Num(spec.limits.max_episodes as f64)),
        ("quantized", Json::Bool(spec.quantized)),
        ("priority", Json::Num(spec.priority as f64)),
        ("checkpoint_every", Json::Num(spec.checkpoint_every as f64)),
        ("progress_every", Json::Num(spec.progress_every as f64)),
    ])
}

/// Parse one record; `None` on anything unusable (torn JSON, wrong
/// schema, malformed spec, a checkpoint that fails its own validation).
fn read_record(path: &Path) -> Option<RecoveredJob> {
    let text = fs::read_to_string(path).ok()?;
    let root = Json::parse(&text).ok()?;
    if root.get("schema").and_then(Json::as_f64) != Some(JOURNAL_VERSION) {
        return None;
    }
    let id = root.get("job").and_then(Json::as_str)?.to_string();
    let seq = id.strip_prefix("job-").and_then(|s| s.parse::<u64>().ok())?;
    let phase = root.get("phase").and_then(Json::as_str)?.to_string();
    let spec = root.get("spec")?;
    let resume = match root.get("checkpoint") {
        Some(data) => Some(Checkpoint::from_json(data).ok()?),
        None => None,
    };
    let spec = JobSpec {
        combo: spec.get("combo").and_then(Json::as_str)?.to_string(),
        seed: spec.get("seed").and_then(Json::as_f64)? as u64,
        actors: spec.get("actors").and_then(Json::as_usize)?,
        limits: TrainLimits {
            max_env_steps: spec.get("max_env_steps").and_then(Json::as_f64)? as u64,
            max_episodes: spec.get("max_episodes").and_then(Json::as_usize)?,
        },
        quantized: spec.get("quantized").and_then(Json::as_bool)?,
        priority: spec.get("priority").and_then(Json::as_f64)? as i64,
        checkpoint_every: spec.get("checkpoint_every").and_then(Json::as_f64)? as u64,
        progress_every: spec.get("progress_every").and_then(Json::as_f64)? as u64,
        resume,
    };
    let origin = root.get("origin").and_then(Json::as_str).map(str::to_string);
    Some(RecoveredJob { id, seq, phase, origin, spec })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("apdrl_journal_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn spec() -> JobSpec {
        JobSpec {
            combo: "dqn_cartpole".into(),
            seed: 7,
            actors: 2,
            limits: TrainLimits { max_env_steps: 5_000, max_episodes: 40 },
            quantized: true,
            priority: 3,
            checkpoint_every: 250,
            progress_every: 0,
            resume: None,
        }
    }

    #[test]
    fn records_round_trip_spec_phase_and_origin() {
        let dir = scratch("roundtrip");
        let j = Journal::open(&dir);
        j.record_submit("job-4", &spec(), Some("h1/job-0"), false);
        j.record_phase("job-4", "running", None);
        let jobs = j.load_all();
        assert_eq!(jobs.len(), 1);
        let job = &jobs[0];
        assert_eq!(job.id, "job-4");
        assert_eq!(job.seq, 4);
        assert_eq!(job.phase, "running");
        assert_eq!(job.origin.as_deref(), Some("h1/job-0"));
        assert_eq!(job.spec.combo, "dqn_cartpole");
        assert_eq!(job.spec.seed, 7);
        assert_eq!(job.spec.limits.max_env_steps, 5_000);
        assert_eq!(job.spec.priority, 3);
        assert!(!job.terminal());
        j.record_phase("job-4", "failed", Some("boom"));
        assert!(j.load_all()[0].terminal());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_and_foreign_files_are_skipped_not_fatal() {
        let dir = scratch("torn");
        let j = Journal::open(&dir);
        j.record_submit("job-0", &spec(), None, false);
        // A torn half-write, plain garbage, a wrong-schema record, and a
        // leftover temp sibling from an interrupted atomic write.
        fs::write(dir.join("job-1.json"), "{\"schema\":1,\"job\":\"job-1\",\"ph").unwrap();
        fs::write(dir.join("job-2.json"), "not json at all").unwrap();
        fs::write(dir.join("job-3.json"), "{\"schema\":99,\"job\":\"job-3\"}").unwrap();
        fs::write(dir.join(".job-4.json.tmp.1.0"), "{}").unwrap();
        let jobs = j.load_all();
        assert_eq!(jobs.len(), 1, "only the intact record survives");
        assert_eq!(jobs[0].id, "job-0");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn updates_never_resurrect_a_removed_record() {
        let dir = scratch("compact");
        let j = Journal::open(&dir);
        j.record_submit("job-0", &spec(), None, false);
        j.remove("job-0");
        j.record_phase("job-0", "done", None);
        j.record_checkpoint("job-0", &Json::Null);
        assert!(j.load_all().is_empty());
        let _ = fs::remove_dir_all(&dir);
    }
}
