//! Multi-tenant training-job scheduler behind the daemon's protocol-v3
//! `train` verb.
//!
//! The daemon submits each `train` request here and holds the
//! connection open; a small pool of *runner* threads (separate from the
//! connection workers, so planning verbs stay responsive while jobs
//! train) claims queued jobs and executes them exactly the way `apdrl
//! train` runs locally — static-phase plan through the shared plan
//! cache, CPU backend from the plan, then
//! [`train_combo_job`] with the job hooks attached.  Frames flow to the
//! submitting connection through a per-job [`FrameQueue`].
//!
//! Scheduling is priority-then-FIFO over a bounded queue: among queued
//! jobs the highest `priority` wins, ties run in submission order, and
//! submissions beyond [`DEFAULT_MAX_QUEUE`] waiting jobs are rejected
//! synchronously (the client sees the error on its `train` line, not a
//! job that silently never starts).  Lifecycle is `queued → running →
//! done | cancelled | failed`; `cancel` stops a queued job immediately
//! and flips a running job's cooperative flag so the trainer stops at
//! the next round boundary — emitting a final checkpoint frame for
//! hand-off when the submitter asked for checkpoints.  [`drain`]
//! (graceful shutdown) rejects new submissions and pushes every live
//! job down the cancel path, so a killed daemon's clients all end with
//! a resumable checkpoint.
//!
//! When the daemon has an `APDRL_JOB_DIR`, the scheduler additionally
//! journals every job to disk ([`journal`]): spec at submission, the
//! newest streamed checkpoint on the job's `checkpoint_every` cadence,
//! and the terminal phase.  [`recover`](Scheduler::recover) replays
//! that journal at boot — running jobs re-queue with their spilled
//! checkpoint as the resume point (bit-identical by the trainer's
//! resume guarantee), queued jobs re-enter in priority order, terminal
//! records are compacted — so a SIGKILLed daemon picks its work back
//! up on restart.  Runner panics are caught and land the job in
//! `failed` with the panic message; every verb path takes the state
//! lock poison-tolerantly, so one bad job can never wedge the daemon.
//!
//! [`drain`]: Scheduler::drain

pub mod frames;
pub mod journal;

pub use frames::FrameQueue;
pub use journal::{Journal, RecoveredJob, ENV_JOB_DIR, JOURNAL_VERSION};

use std::collections::{BTreeMap, VecDeque};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, ensure, Result};

use crate::coordinator::{
    train_combo_job, try_combo, Checkpoint, JobOptions, LocalPlanner, PlanRequest, Planner,
    TrainLimits, TrainResult,
};
use crate::exec::CpuBackend;
use crate::util::json::Json;

use super::stats::ServerStats;

/// Default bound on jobs waiting in the queue (running jobs excluded).
pub const DEFAULT_MAX_QUEUE: usize = 32;

/// Runner threads the daemon spawns alongside its connection workers.
pub const DEFAULT_RUNNERS: usize = 2;

/// Terminal jobs kept for `jobs` listings before the oldest are evicted.
const FINISHED_RETAINED: usize = 64;

/// Idle-runner wakeup cadence (shutdown-flag poll while queue is empty).
const RUNNER_POLL: Duration = Duration::from_millis(100);

/// Test-only trapdoor: a job submitted with this seed panics inside its
/// runner, letting unit tests pin the catch-and-fail path without a
/// special-purpose combo.
#[cfg(test)]
pub(crate) const PANIC_INJECTION_SEED: u64 = 0xBAD_5EED;

/// Everything the scheduler needs to run one training job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub combo: String,
    pub seed: u64,
    pub actors: usize,
    pub limits: TrainLimits,
    pub quantized: bool,
    /// Higher runs first among queued jobs; ties run in submission order.
    pub priority: i64,
    /// Env steps between checkpoint frames (0 = none).
    pub checkpoint_every: u64,
    /// Env steps between progress frames (0 = none).
    pub progress_every: u64,
    /// Snapshot to resume from (a handed-off job from a dead host).
    pub resume: Option<Checkpoint>,
}

/// Submission metadata beyond the spec itself.
#[derive(Clone, Debug, Default)]
pub struct SubmitOpts {
    /// Provenance tag of a fail-over resubmission (`host/job-id` on the
    /// dead host).  Submissions are idempotent per origin: a duplicate
    /// returns the existing job instead of queueing a second copy, so
    /// gossip-driven fail-over lands exactly once.
    pub origin: Option<String>,
    /// Run headless: no connection will stream this job, so its frame
    /// queue drops pushes instead of accumulating them unboundedly.
    pub detached: bool,
}

/// Job lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    Queued,
    Running,
    Done,
    Cancelled,
    Failed,
}

impl JobPhase {
    pub fn name(self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Running => "running",
            JobPhase::Done => "done",
            JobPhase::Cancelled => "cancelled",
            JobPhase::Failed => "failed",
        }
    }
}

struct JobEntry {
    spec: JobSpec,
    phase: JobPhase,
    cancel: Arc<AtomicBool>,
    frames: Arc<FrameQueue>,
    /// Submission order: priority tiebreak and eviction order.
    seq: u64,
    wall_us: Option<u64>,
    error: Option<String>,
    /// Success payload fields for the final response line.
    result: Option<BTreeMap<String, Json>>,
    /// Fail-over provenance (`host/job-id` on the host that died).
    origin: Option<String>,
    /// Replayed from the journal at boot, vs submitted fresh.
    recovered: bool,
}

#[derive(Default)]
struct SchedState {
    jobs: BTreeMap<String, JobEntry>,
    /// Queued ids in submission order; picks scan for highest priority.
    queue: VecDeque<String>,
    next_id: u64,
    /// Terminal ids in finish order, for bounded retention.
    finished: VecDeque<String>,
}

/// What a runner takes off the queue: id, spec, cancel flag, sink.
type Claimed = (String, JobSpec, Arc<AtomicBool>, Arc<FrameQueue>);

/// The daemon's job scheduler (see the module docs).
pub struct Scheduler {
    max_queue: usize,
    state: Mutex<SchedState>,
    cond: Condvar,
    draining: AtomicBool,
    stats: Arc<ServerStats>,
    /// Disk spill under `APDRL_JOB_DIR`; `None` = memory-only jobs.
    journal: Option<Journal>,
}

impl Scheduler {
    pub fn new(max_queue: usize, stats: Arc<ServerStats>) -> Scheduler {
        Scheduler::with_journal(max_queue, stats, None)
    }

    /// A scheduler that journals every job under `journal`'s directory.
    /// Call [`recover`](Scheduler::recover) afterwards to replay
    /// whatever a previous process left behind.
    pub fn with_journal(
        max_queue: usize,
        stats: Arc<ServerStats>,
        journal: Option<Journal>,
    ) -> Scheduler {
        Scheduler {
            max_queue,
            state: Mutex::new(SchedState::default()),
            cond: Condvar::new(),
            draining: AtomicBool::new(false),
            stats,
            journal,
        }
    }

    /// The scheduler state lock, poison-tolerantly.  A runner that
    /// panics while holding the lock (caught panics re-raise on the
    /// unwind path) must not turn every later `submit`/`jobs`/`cancel`
    /// into a panic: the state is a plain bookkeeping map whose
    /// invariants hold between statements, so continuing with the
    /// inner guard is safe.
    fn locked(&self) -> MutexGuard<'_, SchedState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Submit one job.  Validates combo and resume checkpoint
    /// synchronously — the submitter gets the error on its own request
    /// line, never a job that fails on a runner it cannot see — and
    /// bounces when the daemon is draining or the queue is full.
    /// Returns the job id and the frame queue the runner will feed.
    pub fn submit(&self, spec: JobSpec) -> Result<(String, Arc<FrameQueue>)> {
        self.submit_opts(spec, SubmitOpts::default())
    }

    /// [`submit`](Scheduler::submit) with fail-over metadata: an origin
    /// tag (idempotency key) and/or headless (detached) execution.
    pub fn submit_opts(
        &self,
        spec: JobSpec,
        opts: SubmitOpts,
    ) -> Result<(String, Arc<FrameQueue>)> {
        if self.draining.load(Ordering::SeqCst) {
            self.stats.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            bail!("daemon is draining: new jobs are not accepted");
        }
        try_combo(&spec.combo)?;
        ensure!(spec.actors >= 1, "train: actors must be at least 1");
        if let Some(ckpt) = &spec.resume {
            ensure!(
                ckpt.combo == spec.combo,
                "resume checkpoint is for combo {}, job submits {}",
                ckpt.combo,
                spec.combo
            );
            ensure!(
                ckpt.seed == spec.seed && ckpt.actors == spec.actors,
                "resume checkpoint seed/actors {}/{} disagree with the job's {}/{}",
                ckpt.seed,
                ckpt.actors,
                spec.seed,
                spec.actors
            );
            ensure!(
                ckpt.quantized == spec.quantized,
                "resume checkpoint precision (quantized={}) disagrees with the job's ({})",
                ckpt.quantized,
                spec.quantized
            );
        }
        let mut state = self.locked();
        // Exactly-once fail-over: a resubmission whose origin is already
        // known (any phase) returns the existing job instead of queueing
        // a duplicate.
        if let Some(origin) = opts.origin.as_deref() {
            let existing = state
                .jobs
                .iter()
                .find(|(_, e)| e.origin.as_deref() == Some(origin))
                .map(|(id, e)| (id.clone(), Arc::clone(&e.frames)));
            if let Some(found) = existing {
                return Ok(found);
            }
        }
        if state.queue.len() >= self.max_queue {
            self.stats.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            bail!("job queue is full ({} waiting)", state.queue.len());
        }
        let seq = state.next_id;
        state.next_id += 1;
        let id = format!("job-{seq}");
        if let Some(journal) = &self.journal {
            journal.record_submit(&id, &spec, opts.origin.as_deref(), false);
        }
        let frames =
            Arc::new(if opts.detached { FrameQueue::detached() } else { FrameQueue::new() });
        state.jobs.insert(
            id.clone(),
            JobEntry {
                spec,
                phase: JobPhase::Queued,
                cancel: Arc::new(AtomicBool::new(false)),
                frames: Arc::clone(&frames),
                seq,
                wall_us: None,
                error: None,
                result: None,
                origin: opts.origin,
                recovered: false,
            },
        );
        state.queue.push_back(id.clone());
        self.stats.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
        drop(state);
        self.cond.notify_all();
        Ok((id, frames))
    }

    /// Replay the journal left by a previous process: live (queued or
    /// running) records re-enter the queue under their original ids —
    /// running ones resume from their spilled checkpoint — and terminal
    /// records are compacted away.  Recovered jobs run headless
    /// (detached frame queues: their submitting connections died with
    /// the old process).  Returns how many jobs re-entered.
    pub fn recover(&self) -> usize {
        let Some(journal) = &self.journal else { return 0 };
        let mut live = journal.load_all();
        live.retain(|job| {
            if job.terminal() {
                journal.remove(&job.id);
                return false;
            }
            true
        });
        // Original submission order; `pick` re-applies priority on top.
        live.sort_by_key(|j| j.seq);
        let mut state = self.locked();
        let mut count = 0u64;
        for job in live {
            state.next_id = state.next_id.max(job.seq + 1);
            if state.jobs.contains_key(&job.id) {
                continue;
            }
            let resumes = job.spec.resume.is_some();
            // Re-journal as queued so a second crash replays this entry
            // the same way (keeping the spilled checkpoint as `resume`).
            journal.record_submit(&job.id, &job.spec, job.origin.as_deref(), true);
            crate::obs::publish(
                crate::obs::Event::new("job.recovered")
                    .tag("job", &job.id)
                    .tag("combo", &job.spec.combo)
                    .tag("was", &job.phase)
                    .flag("from_checkpoint", resumes),
            );
            state.jobs.insert(
                job.id.clone(),
                JobEntry {
                    spec: job.spec,
                    phase: JobPhase::Queued,
                    cancel: Arc::new(AtomicBool::new(false)),
                    frames: Arc::new(FrameQueue::detached()),
                    seq: job.seq,
                    wall_us: None,
                    error: None,
                    result: None,
                    origin: job.origin,
                    recovered: true,
                },
            );
            state.queue.push_back(job.id);
            count += 1;
        }
        self.stats.jobs_submitted.fetch_add(count, Ordering::Relaxed);
        self.stats.jobs_recovered.fetch_add(count, Ordering::Relaxed);
        self.stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
        drop(state);
        self.cond.notify_all();
        count as usize
    }

    /// Cancel a job.  Queued jobs stop immediately; running jobs stop at
    /// the trainer's next round boundary (with a final checkpoint frame
    /// when the submitter asked for checkpoints).  Terminal jobs are a
    /// no-op.  Returns the phase name reported to the canceller.
    pub fn cancel(&self, id: &str) -> Result<&'static str> {
        let mut state = self.locked();
        let Some(entry) = state.jobs.get_mut(id) else {
            bail!("unknown job {id:?}");
        };
        match entry.phase {
            JobPhase::Queued => {
                entry.phase = JobPhase::Cancelled;
                entry.frames.close();
                state.queue.retain(|q| q != id);
                state.finished.push_back(id.to_string());
                self.stats.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                self.stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
                if let Some(journal) = &self.journal {
                    journal.record_phase(id, JobPhase::Cancelled.name(), None);
                }
                Self::evict_finished(&mut state, self.journal.as_ref());
                Ok(JobPhase::Cancelled.name())
            }
            JobPhase::Running => {
                entry.cancel.store(true, Ordering::SeqCst);
                Ok(JobPhase::Running.name())
            }
            phase => Ok(phase.name()),
        }
    }

    /// Graceful-shutdown drain: reject all new submissions, cancel every
    /// queued job outright and flip every running job's cancel flag so
    /// each trainer stops at its next round boundary, emitting a final
    /// checkpoint frame for hand-off before the daemon exits.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        let mut state = self.locked();
        let queued: Vec<String> = state.queue.drain(..).collect();
        for id in queued {
            if let Some(entry) = state.jobs.get_mut(&id) {
                entry.phase = JobPhase::Cancelled;
                entry.frames.close();
                self.stats.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
            }
            if let Some(journal) = &self.journal {
                journal.record_phase(&id, JobPhase::Cancelled.name(), None);
            }
            state.finished.push_back(id);
        }
        self.stats.job_queue_depth.store(0, Ordering::Relaxed);
        for entry in state.jobs.values() {
            if entry.phase == JobPhase::Running {
                entry.cancel.store(true, Ordering::SeqCst);
            }
        }
        Self::evict_finished(&mut state, self.journal.as_ref());
        drop(state);
        self.cond.notify_all();
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// The `jobs` verb payload: one entry per known job, newest first.
    pub fn jobs_json(&self) -> Json {
        let state = self.locked();
        let mut entries: Vec<(&String, &JobEntry)> = state.jobs.iter().collect();
        entries.sort_by_key(|(_, e)| std::cmp::Reverse(e.seq));
        let list = entries
            .into_iter()
            .map(|(id, e)| {
                let mut o = BTreeMap::new();
                o.insert("job".to_string(), Json::Str(id.clone()));
                o.insert("combo".to_string(), Json::Str(e.spec.combo.clone()));
                o.insert("seed".to_string(), Json::Num(e.spec.seed as f64));
                o.insert("actors".to_string(), Json::Num(e.spec.actors as f64));
                o.insert("quantized".to_string(), Json::Bool(e.spec.quantized));
                o.insert("priority".to_string(), Json::Num(e.spec.priority as f64));
                o.insert("phase".to_string(), Json::Str(e.phase.name().to_string()));
                if e.recovered {
                    o.insert("recovered".to_string(), Json::Bool(true));
                }
                if let Some(origin) = &e.origin {
                    o.insert("origin".to_string(), Json::Str(origin.clone()));
                }
                if let Some(us) = e.wall_us {
                    o.insert("wall_us".to_string(), Json::Num(us as f64));
                }
                if let Some(err) = &e.error {
                    o.insert("error".to_string(), Json::Str(err.clone()));
                }
                Json::Obj(o)
            })
            .collect();
        Json::Arr(list)
    }

    /// Lightweight digests of every *queued* job, in queue order — the
    /// gossip payload that rides `jobs`/`stats` responses and streamed
    /// checkpoint frames, giving clients enough to resubmit a dead
    /// host's queue to survivors (see `server::client::RemoteTrainer`).
    pub fn queued_digest(&self) -> Json {
        let state = self.locked();
        let list = state
            .queue
            .iter()
            .filter_map(|id| {
                let e = state.jobs.get(id)?;
                let mut o = BTreeMap::new();
                o.insert("job".to_string(), Json::Str(id.clone()));
                o.insert("combo".to_string(), Json::Str(e.spec.combo.clone()));
                o.insert("seed".to_string(), Json::Num(e.spec.seed as f64));
                o.insert("actors".to_string(), Json::Num(e.spec.actors as f64));
                o.insert(
                    "max_env_steps".to_string(),
                    Json::Num(e.spec.limits.max_env_steps as f64),
                );
                o.insert(
                    "max_episodes".to_string(),
                    Json::Num(e.spec.limits.max_episodes as f64),
                );
                o.insert("quantized".to_string(), Json::Bool(e.spec.quantized));
                o.insert("priority".to_string(), Json::Num(e.spec.priority as f64));
                o.insert(
                    "checkpoint_every".to_string(),
                    Json::Num(e.spec.checkpoint_every as f64),
                );
                o.insert(
                    "progress_every".to_string(),
                    Json::Num(e.spec.progress_every as f64),
                );
                if let Some(origin) = &e.origin {
                    o.insert("origin".to_string(), Json::Str(origin.clone()));
                }
                Some(Json::Obj(o))
            })
            .collect();
        Json::Arr(list)
    }

    /// The final-response payload for a job whose frame queue closed:
    /// terminal status, the cancelled flag, the runner's result fields
    /// (backend, threads, bit-exact metrics) or error, and the live
    /// draining flag so a handed-off client knows to resubmit elsewhere.
    pub fn final_result(&self, id: &str) -> Json {
        let state = self.locked();
        let mut body = BTreeMap::new();
        body.insert("job".to_string(), Json::Str(id.to_string()));
        match state.jobs.get(id) {
            Some(entry) => {
                body.insert("status".to_string(), Json::Str(entry.phase.name().to_string()));
                body.insert(
                    "cancelled".to_string(),
                    Json::Bool(entry.phase == JobPhase::Cancelled),
                );
                if let Some(err) = &entry.error {
                    body.insert("error".to_string(), Json::Str(err.clone()));
                }
                if let Some(result) = &entry.result {
                    for (k, v) in result {
                        body.insert(k.clone(), v.clone());
                    }
                }
            }
            None => {
                body.insert("status".to_string(), Json::Str("evicted".to_string()));
            }
        }
        body.insert("draining".to_string(), Json::Bool(self.draining()));
        Json::Obj(body)
    }

    /// One runner thread: claim the highest-priority queued job, train
    /// it, record the outcome, repeat.  Returns once `shutdown` is set
    /// and nothing is claimable (a drain cancels queued jobs first, so
    /// exit is prompt).
    pub fn run_runner(&self, shutdown: &AtomicBool) {
        loop {
            let claimed = {
                let mut state = self.locked();
                loop {
                    if let Some(id) = Self::pick(&state) {
                        break Some(Self::claim(&mut state, &id, &self.stats));
                    }
                    if shutdown.load(Ordering::SeqCst) {
                        break None;
                    }
                    let (s, _) = self
                        .cond
                        .wait_timeout(state, RUNNER_POLL)
                        .unwrap_or_else(PoisonError::into_inner);
                    state = s;
                }
            };
            let Some((id, spec, cancel, frames)) = claimed else { return };
            self.execute(id, spec, &cancel, &frames);
        }
    }

    /// Highest priority wins; among equals, lowest submission seq.
    fn pick(state: &SchedState) -> Option<String> {
        state
            .queue
            .iter()
            .filter_map(|id| state.jobs.get(id).map(|e| (id, e)))
            .max_by_key(|(_, e)| (e.spec.priority, std::cmp::Reverse(e.seq)))
            .map(|(id, _)| id.clone())
    }

    fn claim(state: &mut SchedState, id: &str, stats: &ServerStats) -> Claimed {
        state.queue.retain(|q| q != id);
        stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
        stats.jobs_running.fetch_add(1, Ordering::Relaxed);
        let entry = state.jobs.get_mut(id).expect("claimed job exists");
        entry.phase = JobPhase::Running;
        (
            id.to_string(),
            entry.spec.clone(),
            Arc::clone(&entry.cancel),
            Arc::clone(&entry.frames),
        )
    }

    fn execute(&self, id: String, spec: JobSpec, cancel: &AtomicBool, frames: &FrameQueue) {
        if let Some(journal) = &self.journal {
            journal.record_phase(&id, JobPhase::Running.name(), None);
        }
        let t0 = Instant::now();
        // A panic anywhere in the planning/training stack must land the
        // job in `failed` — not unwind through the runner loop and leave
        // the daemon one runner short (or, mid-lock, poisoned).
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            run_job(&id, &spec, cancel, frames, self.journal.as_ref())
        }))
        .unwrap_or_else(|payload| {
            Err(anyhow!("job runner panicked: {}", panic_message(payload.as_ref())))
        });
        let wall_us = t0.elapsed().as_micros() as u64;
        let mut state = self.locked();
        self.stats.jobs_running.fetch_sub(1, Ordering::Relaxed);
        self.stats.record_job_wall(wall_us);
        if let Some(entry) = state.jobs.get_mut(&id) {
            entry.wall_us = Some(wall_us);
            match outcome {
                Ok(result) => {
                    entry.phase =
                        if result.cancelled { JobPhase::Cancelled } else { JobPhase::Done };
                    entry.result = Some(result_body(&result));
                }
                Err(e) => {
                    entry.phase = JobPhase::Failed;
                    entry.error = Some(format!("{e:#}"));
                }
            }
            match entry.phase {
                JobPhase::Done => self.stats.jobs_completed.fetch_add(1, Ordering::Relaxed),
                JobPhase::Cancelled => {
                    self.stats.jobs_cancelled.fetch_add(1, Ordering::Relaxed)
                }
                _ => self.stats.jobs_failed.fetch_add(1, Ordering::Relaxed),
            };
            if let Some(journal) = &self.journal {
                // The final checkpoint frame (if any) was spilled by the
                // sink before the trainer returned, so this terminal
                // stamp rides alongside the job's complete final state.
                journal.record_phase(&id, entry.phase.name(), entry.error.as_deref());
            }
            entry.frames.close();
        }
        state.finished.push_back(id);
        Self::evict_finished(&mut state, self.journal.as_ref());
    }

    /// Keep the most recent [`FINISHED_RETAINED`] terminal jobs so a
    /// long-lived daemon's `jobs` listing (and journal directory) stays
    /// bounded.
    fn evict_finished(state: &mut SchedState, journal: Option<&Journal>) {
        while state.finished.len() > FINISHED_RETAINED {
            if let Some(old) = state.finished.pop_front() {
                if let Some(journal) = journal {
                    journal.remove(&old);
                }
                state.jobs.remove(&old);
            }
        }
    }
}

/// Human-readable panic payload (panics carry `&str` or `String` in
/// practice; anything else is reported opaquely).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one job exactly the way `apdrl train` runs locally: static-phase
/// plan (through the shared process-wide plan cache), CPU backend from
/// the plan, then the training loop with job hooks attached.  With a
/// journal, every checkpoint frame is spilled to disk on its way to the
/// frame queue (`job.spilled` on the obs bus), so a crash loses at most
/// one `checkpoint_every` window of progress.
fn run_job(
    id: &str,
    spec: &JobSpec,
    cancel: &AtomicBool,
    frames: &FrameQueue,
    journal: Option<&Journal>,
) -> Result<TrainResult> {
    #[cfg(test)]
    if spec.seed == PANIC_INJECTION_SEED {
        panic!("injected runner panic");
    }
    let c = try_combo(&spec.combo)?;
    let plan = LocalPlanner.plan(&PlanRequest::new(c.clone(), c.batch, spec.quantized))?;
    let mut backend = CpuBackend::from_outcome(&plan)?;
    let mut sink = |frame: &Json| {
        if frame.get("frame").and_then(Json::as_str) == Some("checkpoint") {
            if let (Some(journal), Some(data)) = (journal, frame.get("data")) {
                journal.record_checkpoint(id, data);
                crate::obs::publish(
                    crate::obs::Event::new("job.spilled").tag("job", id).num(
                        "env_steps",
                        frame.get("env_steps").and_then(Json::as_f64).unwrap_or(0.0),
                    ),
                );
            }
        }
        frames.push(frame.clone());
    };
    let opts = JobOptions {
        job_id: Some(id.to_string()),
        cancel: Some(cancel),
        checkpoint_every: spec.checkpoint_every,
        progress_every: spec.progress_every,
        sink: Some(&mut sink),
        resume: spec.resume.as_ref(),
        quantized: spec.quantized,
    };
    train_combo_job(&mut backend, &c, spec.seed, spec.limits, spec.actors, false, opts)
}

/// The success payload stored for the final response line.
fn result_body(result: &TrainResult) -> BTreeMap<String, Json> {
    let mut body = BTreeMap::new();
    body.insert("combo".to_string(), Json::Str(result.combo.clone()));
    body.insert("backend".to_string(), Json::Str(result.backend.clone()));
    body.insert("threads".to_string(), Json::Num(result.threads as f64));
    body.insert("actors".to_string(), Json::Num(result.actors as f64));
    body.insert("seed".to_string(), Json::Num(result.seed as f64));
    body.insert("metrics".to_string(), result.metrics.to_json());
    body
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(priority: i64) -> JobSpec {
        JobSpec {
            combo: "dqn_cartpole".into(),
            seed: 1,
            actors: 1,
            limits: TrainLimits { max_env_steps: 300, max_episodes: 8 },
            quantized: false,
            priority,
            checkpoint_every: 0,
            progress_every: 0,
            resume: None,
        }
    }

    fn scratch(tag: &str) -> std::path::PathBuf {
        let dir =
            std::env::temp_dir().join(format!("apdrl_sched_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn submissions_validate_synchronously() {
        let sched = Scheduler::new(4, Arc::new(ServerStats::new()));
        let mut bad = spec(0);
        bad.combo = "dqn_nonsense".into();
        assert!(sched.submit(bad).is_err());
        let mut mismatched = spec(0);
        mismatched.resume = Some(Checkpoint {
            combo: "a2c_invpend".into(),
            seed: 1,
            actors: 1,
            quantized: false,
            metrics: Default::default(),
            last_scale: None,
            ep_rewards: vec![0.0],
            rng_state: 1,
            rng_spare: None,
            fleet: Json::Null,
            agent: Json::Null,
        });
        let e = sched.submit(mismatched).unwrap_err();
        assert!(format!("{e}").contains("combo"), "{e}");
    }

    #[test]
    fn queue_is_bounded_and_priority_ordered() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(3, Arc::clone(&stats));
        let (_a, _) = sched.submit(spec(0)).unwrap();
        let (b, _) = sched.submit(spec(5)).unwrap();
        let (c, _) = sched.submit(spec(5)).unwrap();
        let e = sched.submit(spec(9)).unwrap_err();
        assert!(format!("{e}").contains("queue is full"), "{e}");
        assert_eq!(stats.jobs_rejected.load(Ordering::Relaxed), 1);
        assert_eq!(stats.job_queue_depth.load(Ordering::Relaxed), 3);
        // Highest priority first; FIFO among equals (b before c).
        let mut state = sched.state.lock().unwrap();
        let first = Scheduler::pick(&state).unwrap();
        assert_eq!(first, b);
        Scheduler::claim(&mut state, &first, &stats);
        assert_eq!(Scheduler::pick(&state).unwrap(), c);
    }

    #[test]
    fn cancelling_a_queued_job_closes_it_immediately() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let (id, frames) = sched.submit(spec(0)).unwrap();
        assert_eq!(sched.cancel(&id).unwrap(), "cancelled");
        assert!(frames.next().is_none());
        let body = sched.final_result(&id);
        assert_eq!(body.get("status").and_then(Json::as_str), Some("cancelled"));
        assert_eq!(body.get("cancelled").and_then(Json::as_bool), Some(true));
        assert_eq!(stats.jobs_cancelled.load(Ordering::Relaxed), 1);
        // Cancelling again is a no-op reporting the terminal phase.
        assert_eq!(sched.cancel(&id).unwrap(), "cancelled");
        assert!(sched.cancel("job-999").is_err());
    }

    #[test]
    fn drain_rejects_new_jobs_and_cancels_queued_ones() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let (id, frames) = sched.submit(spec(0)).unwrap();
        sched.drain();
        assert!(frames.next().is_none());
        let body = sched.final_result(&id);
        assert_eq!(body.get("status").and_then(Json::as_str), Some("cancelled"));
        assert_eq!(body.get("draining").and_then(Json::as_bool), Some(true));
        let e = sched.submit(spec(0)).unwrap_err();
        assert!(format!("{e}").contains("draining"), "{e}");
        assert_eq!(stats.jobs_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn jobs_run_stream_frames_and_reach_done() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let shutdown = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| sched.run_runner(&shutdown));
            let mut want = spec(0);
            want.checkpoint_every = 100;
            want.progress_every = 75;
            let (id, frames) = sched.submit(want).unwrap();
            let mut kinds = Vec::new();
            while let Some(f) = frames.next() {
                assert_eq!(f.get("job").and_then(Json::as_str), Some(id.as_str()));
                kinds.push(f.get("frame").and_then(Json::as_str).unwrap_or("?").to_string());
            }
            let body = sched.final_result(&id);
            assert_eq!(body.get("status").and_then(Json::as_str), Some("done"));
            assert_eq!(body.get("cancelled").and_then(Json::as_bool), Some(false));
            assert!(body.get("metrics").is_some());
            assert!(kinds.iter().any(|k| k == "episode"), "{kinds:?}");
            assert!(kinds.iter().any(|k| k == "checkpoint"), "{kinds:?}");
            assert!(kinds.iter().any(|k| k == "progress"), "{kinds:?}");
            let listing = sched.jobs_json();
            let arr = listing.as_arr().unwrap();
            assert_eq!(arr.len(), 1);
            assert_eq!(arr[0].get("phase").and_then(Json::as_str), Some("done"));
            assert!(arr[0].get("wall_us").is_some());
            shutdown.store(true, Ordering::SeqCst);
        });
        assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 1);
        assert_eq!(stats.jobs_running.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn a_panicking_job_lands_failed_without_wedging_the_scheduler() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let shutdown = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| sched.run_runner(&shutdown));
            let mut bomb = spec(0);
            bomb.seed = PANIC_INJECTION_SEED;
            let (id, frames) = sched.submit(bomb).unwrap();
            while frames.next().is_some() {}
            let body = sched.final_result(&id);
            assert_eq!(body.get("status").and_then(Json::as_str), Some("failed"));
            let err = body.get("error").and_then(Json::as_str).unwrap_or("");
            assert!(err.contains("injected runner panic"), "{err:?}");
            // The scheduler (and the same runner thread) must keep
            // working: a fresh job runs to completion afterwards.
            let (id2, frames2) = sched.submit(spec(0)).unwrap();
            while frames2.next().is_some() {}
            let body2 = sched.final_result(&id2);
            assert_eq!(body2.get("status").and_then(Json::as_str), Some("done"));
            shutdown.store(true, Ordering::SeqCst);
        });
        assert_eq!(stats.jobs_failed.load(Ordering::Relaxed), 1);
        assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 1);
        assert_eq!(stats.jobs_running.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn verb_paths_survive_a_poisoned_state_lock() {
        let sched = Scheduler::new(4, Arc::new(ServerStats::new()));
        let (id, _) = sched.submit(spec(0)).unwrap();
        // Poison the state mutex the way an uncaught runner panic under
        // the lock would.
        let _ = std::thread::scope(|s| {
            s.spawn(|| {
                let _guard = sched.state.lock().unwrap();
                panic!("poison the scheduler lock");
            })
            .join()
        });
        assert!(sched.state.lock().is_err(), "the lock really is poisoned");
        // Every verb path must keep working on the inner state.
        let (id2, _) = sched.submit(spec(1)).unwrap();
        assert_eq!(sched.jobs_json().as_arr().unwrap().len(), 2);
        assert_eq!(sched.cancel(&id).unwrap(), "cancelled");
        assert!(sched.queued_digest().as_arr().unwrap().len() == 1);
        assert!(sched.final_result(&id2).get("status").is_some());
        sched.drain();
        assert!(sched.submit(spec(0)).is_err());
    }

    #[test]
    fn origin_tagged_resubmissions_are_idempotent() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let opts = SubmitOpts { origin: Some("h1/job-9".into()), detached: true };
        let (a, _) = sched.submit_opts(spec(0), opts.clone()).unwrap();
        let (b, _) = sched.submit_opts(spec(0), opts).unwrap();
        assert_eq!(a, b, "same origin must land the same job");
        assert_eq!(stats.jobs_submitted.load(Ordering::Relaxed), 1);
        let digest = sched.queued_digest();
        let arr = digest.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("origin").and_then(Json::as_str), Some("h1/job-9"));
        assert_eq!(arr[0].get("max_env_steps").and_then(Json::as_usize), Some(300));
        let listing = sched.jobs_json();
        assert_eq!(
            listing.as_arr().unwrap()[0].get("origin").and_then(Json::as_str),
            Some("h1/job-9")
        );
    }

    #[test]
    fn journal_replay_requeues_live_jobs_and_compacts_terminal_ones() {
        let dir = scratch("replay");
        let stats = Arc::new(ServerStats::new());
        {
            let sched = Scheduler::with_journal(
                8,
                Arc::clone(&stats),
                Some(Journal::open(&dir)),
            );
            sched.submit(spec(0)).unwrap(); // job-0, stays queued
            sched.submit(spec(7)).unwrap(); // job-1, higher priority
            // Process "crashes" here: both jobs sit in the journal.
        }
        let journal = Journal::open(&dir);
        journal.record_phase("job-0", "running", None); // crashed mid-run
        let sched =
            Scheduler::with_journal(8, Arc::new(ServerStats::new()), Some(Journal::open(&dir)));
        assert_eq!(sched.recover(), 2);
        let listing = sched.jobs_json();
        let arr = listing.as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        for e in arr {
            assert_eq!(e.get("phase").and_then(Json::as_str), Some("queued"));
            assert_eq!(e.get("recovered").and_then(Json::as_bool), Some(true));
        }
        // Priority survives recovery: job-1 (priority 7) picks first,
        // and fresh submissions continue past the recovered ids.
        {
            let state = sched.locked();
            assert_eq!(Scheduler::pick(&state).as_deref(), Some("job-1"));
        }
        let (fresh, _) = sched.submit(spec(0)).unwrap();
        assert_eq!(fresh, "job-2");
        // Terminal records compact away on the next replay.
        let journal = Journal::open(&dir);
        journal.record_phase("job-0", "done", None);
        journal.record_phase("job-1", "cancelled", None);
        journal.record_phase("job-2", "failed", Some("x"));
        let sched2 =
            Scheduler::with_journal(8, Arc::new(ServerStats::new()), Some(Journal::open(&dir)));
        assert_eq!(sched2.recover(), 0);
        assert!(Journal::open(&dir).load_all().is_empty(), "terminal entries compacted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn recovered_running_jobs_resume_from_their_spilled_checkpoint() {
        let dir = scratch("resume");
        let stats = Arc::new(ServerStats::new());
        let shutdown = AtomicBool::new(false);
        // First life: run a checkpointing job to completion so the
        // journal holds a real final checkpoint, then rewind its phase
        // to "running" to simulate a crash just before the terminal
        // stamp landed.
        {
            let sched = Scheduler::with_journal(
                8,
                Arc::clone(&stats),
                Some(Journal::open(&dir)),
            );
            std::thread::scope(|s| {
                s.spawn(|| sched.run_runner(&shutdown));
                let mut want = spec(0);
                want.checkpoint_every = 100;
                let (_, frames) = sched.submit(want).unwrap();
                while frames.next().is_some() {}
                shutdown.store(true, Ordering::SeqCst);
            });
        }
        Journal::open(&dir).record_phase("job-0", "running", None);
        let sched =
            Scheduler::with_journal(8, Arc::new(ServerStats::new()), Some(Journal::open(&dir)));
        assert_eq!(sched.recover(), 1);
        let state = sched.locked();
        let entry = &state.jobs["job-0"];
        let ckpt = entry.spec.resume.as_ref().expect("recovered job carries its checkpoint");
        assert_eq!(ckpt.combo, "dqn_cartpole");
        assert!(!ckpt.ep_rewards.is_empty(), "checkpoint holds streamed reward history");
        drop(state);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
