//! Multi-tenant training-job scheduler behind the daemon's protocol-v3
//! `train` verb.
//!
//! The daemon submits each `train` request here and holds the
//! connection open; a small pool of *runner* threads (separate from the
//! connection workers, so planning verbs stay responsive while jobs
//! train) claims queued jobs and executes them exactly the way `apdrl
//! train` runs locally — static-phase plan through the shared plan
//! cache, CPU backend from the plan, then
//! [`train_combo_job`] with the job hooks attached.  Frames flow to the
//! submitting connection through a per-job [`FrameQueue`].
//!
//! Scheduling is priority-then-FIFO over a bounded queue: among queued
//! jobs the highest `priority` wins, ties run in submission order, and
//! submissions beyond [`DEFAULT_MAX_QUEUE`] waiting jobs are rejected
//! synchronously (the client sees the error on its `train` line, not a
//! job that silently never starts).  Lifecycle is `queued → running →
//! done | cancelled | failed`; `cancel` stops a queued job immediately
//! and flips a running job's cooperative flag so the trainer stops at
//! the next round boundary — emitting a final checkpoint frame for
//! hand-off when the submitter asked for checkpoints.  [`drain`]
//! (graceful shutdown) rejects new submissions and pushes every live
//! job down the cancel path, so a killed daemon's clients all end with
//! a resumable checkpoint.
//!
//! [`drain`]: Scheduler::drain

pub mod frames;

pub use frames::FrameQueue;

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Result};

use crate::coordinator::{
    train_combo_job, try_combo, Checkpoint, JobOptions, LocalPlanner, PlanRequest, Planner,
    TrainLimits, TrainResult,
};
use crate::exec::CpuBackend;
use crate::util::json::Json;

use super::stats::ServerStats;

/// Default bound on jobs waiting in the queue (running jobs excluded).
pub const DEFAULT_MAX_QUEUE: usize = 32;

/// Runner threads the daemon spawns alongside its connection workers.
pub const DEFAULT_RUNNERS: usize = 2;

/// Terminal jobs kept for `jobs` listings before the oldest are evicted.
const FINISHED_RETAINED: usize = 64;

/// Idle-runner wakeup cadence (shutdown-flag poll while queue is empty).
const RUNNER_POLL: Duration = Duration::from_millis(100);

/// Everything the scheduler needs to run one training job.
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub combo: String,
    pub seed: u64,
    pub actors: usize,
    pub limits: TrainLimits,
    pub quantized: bool,
    /// Higher runs first among queued jobs; ties run in submission order.
    pub priority: i64,
    /// Env steps between checkpoint frames (0 = none).
    pub checkpoint_every: u64,
    /// Env steps between progress frames (0 = none).
    pub progress_every: u64,
    /// Snapshot to resume from (a handed-off job from a dead host).
    pub resume: Option<Checkpoint>,
}

/// Job lifecycle phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobPhase {
    Queued,
    Running,
    Done,
    Cancelled,
    Failed,
}

impl JobPhase {
    pub fn name(self) -> &'static str {
        match self {
            JobPhase::Queued => "queued",
            JobPhase::Running => "running",
            JobPhase::Done => "done",
            JobPhase::Cancelled => "cancelled",
            JobPhase::Failed => "failed",
        }
    }
}

struct JobEntry {
    spec: JobSpec,
    phase: JobPhase,
    cancel: Arc<AtomicBool>,
    frames: Arc<FrameQueue>,
    /// Submission order: priority tiebreak and eviction order.
    seq: u64,
    wall_us: Option<u64>,
    error: Option<String>,
    /// Success payload fields for the final response line.
    result: Option<BTreeMap<String, Json>>,
}

#[derive(Default)]
struct SchedState {
    jobs: BTreeMap<String, JobEntry>,
    /// Queued ids in submission order; picks scan for highest priority.
    queue: VecDeque<String>,
    next_id: u64,
    /// Terminal ids in finish order, for bounded retention.
    finished: VecDeque<String>,
}

/// What a runner takes off the queue: id, spec, cancel flag, sink.
type Claimed = (String, JobSpec, Arc<AtomicBool>, Arc<FrameQueue>);

/// The daemon's job scheduler (see the module docs).
pub struct Scheduler {
    max_queue: usize,
    state: Mutex<SchedState>,
    cond: Condvar,
    draining: AtomicBool,
    stats: Arc<ServerStats>,
}

impl Scheduler {
    pub fn new(max_queue: usize, stats: Arc<ServerStats>) -> Scheduler {
        Scheduler {
            max_queue,
            state: Mutex::new(SchedState::default()),
            cond: Condvar::new(),
            draining: AtomicBool::new(false),
            stats,
        }
    }

    /// Submit one job.  Validates combo and resume checkpoint
    /// synchronously — the submitter gets the error on its own request
    /// line, never a job that fails on a runner it cannot see — and
    /// bounces when the daemon is draining or the queue is full.
    /// Returns the job id and the frame queue the runner will feed.
    pub fn submit(&self, spec: JobSpec) -> Result<(String, Arc<FrameQueue>)> {
        if self.draining.load(Ordering::SeqCst) {
            self.stats.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            bail!("daemon is draining: new jobs are not accepted");
        }
        try_combo(&spec.combo)?;
        ensure!(spec.actors >= 1, "train: actors must be at least 1");
        if let Some(ckpt) = &spec.resume {
            ensure!(
                ckpt.combo == spec.combo,
                "resume checkpoint is for combo {}, job submits {}",
                ckpt.combo,
                spec.combo
            );
            ensure!(
                ckpt.seed == spec.seed && ckpt.actors == spec.actors,
                "resume checkpoint seed/actors {}/{} disagree with the job's {}/{}",
                ckpt.seed,
                ckpt.actors,
                spec.seed,
                spec.actors
            );
            ensure!(
                ckpt.quantized == spec.quantized,
                "resume checkpoint precision (quantized={}) disagrees with the job's ({})",
                ckpt.quantized,
                spec.quantized
            );
        }
        let mut state = self.state.lock().unwrap();
        if state.queue.len() >= self.max_queue {
            self.stats.jobs_rejected.fetch_add(1, Ordering::Relaxed);
            bail!("job queue is full ({} waiting)", state.queue.len());
        }
        let seq = state.next_id;
        state.next_id += 1;
        let id = format!("job-{seq}");
        let frames = Arc::new(FrameQueue::new());
        state.jobs.insert(
            id.clone(),
            JobEntry {
                spec,
                phase: JobPhase::Queued,
                cancel: Arc::new(AtomicBool::new(false)),
                frames: Arc::clone(&frames),
                seq,
                wall_us: None,
                error: None,
                result: None,
            },
        );
        state.queue.push_back(id.clone());
        self.stats.jobs_submitted.fetch_add(1, Ordering::Relaxed);
        self.stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
        drop(state);
        self.cond.notify_all();
        Ok((id, frames))
    }

    /// Cancel a job.  Queued jobs stop immediately; running jobs stop at
    /// the trainer's next round boundary (with a final checkpoint frame
    /// when the submitter asked for checkpoints).  Terminal jobs are a
    /// no-op.  Returns the phase name reported to the canceller.
    pub fn cancel(&self, id: &str) -> Result<&'static str> {
        let mut state = self.state.lock().unwrap();
        let Some(entry) = state.jobs.get_mut(id) else {
            bail!("unknown job {id:?}");
        };
        match entry.phase {
            JobPhase::Queued => {
                entry.phase = JobPhase::Cancelled;
                entry.frames.close();
                state.queue.retain(|q| q != id);
                state.finished.push_back(id.to_string());
                self.stats.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
                self.stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
                Self::evict_finished(&mut state);
                Ok(JobPhase::Cancelled.name())
            }
            JobPhase::Running => {
                entry.cancel.store(true, Ordering::SeqCst);
                Ok(JobPhase::Running.name())
            }
            phase => Ok(phase.name()),
        }
    }

    /// Graceful-shutdown drain: reject all new submissions, cancel every
    /// queued job outright and flip every running job's cancel flag so
    /// each trainer stops at its next round boundary, emitting a final
    /// checkpoint frame for hand-off before the daemon exits.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
        let mut state = self.state.lock().unwrap();
        let queued: Vec<String> = state.queue.drain(..).collect();
        for id in queued {
            if let Some(entry) = state.jobs.get_mut(&id) {
                entry.phase = JobPhase::Cancelled;
                entry.frames.close();
                self.stats.jobs_cancelled.fetch_add(1, Ordering::Relaxed);
            }
            state.finished.push_back(id);
        }
        self.stats.job_queue_depth.store(0, Ordering::Relaxed);
        for entry in state.jobs.values() {
            if entry.phase == JobPhase::Running {
                entry.cancel.store(true, Ordering::SeqCst);
            }
        }
        Self::evict_finished(&mut state);
        drop(state);
        self.cond.notify_all();
    }

    pub fn draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// The `jobs` verb payload: one entry per known job, newest first.
    pub fn jobs_json(&self) -> Json {
        let state = self.state.lock().unwrap();
        let mut entries: Vec<(&String, &JobEntry)> = state.jobs.iter().collect();
        entries.sort_by_key(|(_, e)| std::cmp::Reverse(e.seq));
        let list = entries
            .into_iter()
            .map(|(id, e)| {
                let mut o = BTreeMap::new();
                o.insert("job".to_string(), Json::Str(id.clone()));
                o.insert("combo".to_string(), Json::Str(e.spec.combo.clone()));
                o.insert("seed".to_string(), Json::Num(e.spec.seed as f64));
                o.insert("actors".to_string(), Json::Num(e.spec.actors as f64));
                o.insert("quantized".to_string(), Json::Bool(e.spec.quantized));
                o.insert("priority".to_string(), Json::Num(e.spec.priority as f64));
                o.insert("phase".to_string(), Json::Str(e.phase.name().to_string()));
                if let Some(us) = e.wall_us {
                    o.insert("wall_us".to_string(), Json::Num(us as f64));
                }
                if let Some(err) = &e.error {
                    o.insert("error".to_string(), Json::Str(err.clone()));
                }
                Json::Obj(o)
            })
            .collect();
        Json::Arr(list)
    }

    /// The final-response payload for a job whose frame queue closed:
    /// terminal status, the cancelled flag, the runner's result fields
    /// (backend, threads, bit-exact metrics) or error, and the live
    /// draining flag so a handed-off client knows to resubmit elsewhere.
    pub fn final_result(&self, id: &str) -> Json {
        let state = self.state.lock().unwrap();
        let mut body = BTreeMap::new();
        body.insert("job".to_string(), Json::Str(id.to_string()));
        match state.jobs.get(id) {
            Some(entry) => {
                body.insert("status".to_string(), Json::Str(entry.phase.name().to_string()));
                body.insert(
                    "cancelled".to_string(),
                    Json::Bool(entry.phase == JobPhase::Cancelled),
                );
                if let Some(err) = &entry.error {
                    body.insert("error".to_string(), Json::Str(err.clone()));
                }
                if let Some(result) = &entry.result {
                    for (k, v) in result {
                        body.insert(k.clone(), v.clone());
                    }
                }
            }
            None => {
                body.insert("status".to_string(), Json::Str("evicted".to_string()));
            }
        }
        body.insert("draining".to_string(), Json::Bool(self.draining()));
        Json::Obj(body)
    }

    /// One runner thread: claim the highest-priority queued job, train
    /// it, record the outcome, repeat.  Returns once `shutdown` is set
    /// and nothing is claimable (a drain cancels queued jobs first, so
    /// exit is prompt).
    pub fn run_runner(&self, shutdown: &AtomicBool) {
        loop {
            let claimed = {
                let mut state = self.state.lock().unwrap();
                loop {
                    if let Some(id) = Self::pick(&state) {
                        break Some(Self::claim(&mut state, &id, &self.stats));
                    }
                    if shutdown.load(Ordering::SeqCst) {
                        break None;
                    }
                    let (s, _) = self.cond.wait_timeout(state, RUNNER_POLL).unwrap();
                    state = s;
                }
            };
            let Some((id, spec, cancel, frames)) = claimed else { return };
            self.execute(id, spec, &cancel, &frames);
        }
    }

    /// Highest priority wins; among equals, lowest submission seq.
    fn pick(state: &SchedState) -> Option<String> {
        state
            .queue
            .iter()
            .filter_map(|id| state.jobs.get(id).map(|e| (id, e)))
            .max_by_key(|(_, e)| (e.spec.priority, std::cmp::Reverse(e.seq)))
            .map(|(id, _)| id.clone())
    }

    fn claim(state: &mut SchedState, id: &str, stats: &ServerStats) -> Claimed {
        state.queue.retain(|q| q != id);
        stats.job_queue_depth.store(state.queue.len(), Ordering::Relaxed);
        stats.jobs_running.fetch_add(1, Ordering::Relaxed);
        let entry = state.jobs.get_mut(id).expect("claimed job exists");
        entry.phase = JobPhase::Running;
        (
            id.to_string(),
            entry.spec.clone(),
            Arc::clone(&entry.cancel),
            Arc::clone(&entry.frames),
        )
    }

    fn execute(&self, id: String, spec: JobSpec, cancel: &AtomicBool, frames: &FrameQueue) {
        let t0 = Instant::now();
        let outcome = run_job(&id, &spec, cancel, frames);
        let wall_us = t0.elapsed().as_micros() as u64;
        let mut state = self.state.lock().unwrap();
        self.stats.jobs_running.fetch_sub(1, Ordering::Relaxed);
        self.stats.record_job_wall(wall_us);
        if let Some(entry) = state.jobs.get_mut(&id) {
            entry.wall_us = Some(wall_us);
            match outcome {
                Ok(result) => {
                    entry.phase =
                        if result.cancelled { JobPhase::Cancelled } else { JobPhase::Done };
                    entry.result = Some(result_body(&result));
                }
                Err(e) => {
                    entry.phase = JobPhase::Failed;
                    entry.error = Some(format!("{e:#}"));
                }
            }
            match entry.phase {
                JobPhase::Done => self.stats.jobs_completed.fetch_add(1, Ordering::Relaxed),
                JobPhase::Cancelled => {
                    self.stats.jobs_cancelled.fetch_add(1, Ordering::Relaxed)
                }
                _ => self.stats.jobs_failed.fetch_add(1, Ordering::Relaxed),
            };
            entry.frames.close();
        }
        state.finished.push_back(id);
        Self::evict_finished(&mut state);
    }

    /// Keep the most recent [`FINISHED_RETAINED`] terminal jobs so a
    /// long-lived daemon's `jobs` listing stays bounded.
    fn evict_finished(state: &mut SchedState) {
        while state.finished.len() > FINISHED_RETAINED {
            if let Some(old) = state.finished.pop_front() {
                state.jobs.remove(&old);
            }
        }
    }
}

/// Run one job exactly the way `apdrl train` runs locally: static-phase
/// plan (through the shared process-wide plan cache), CPU backend from
/// the plan, then the training loop with job hooks attached.
fn run_job(
    id: &str,
    spec: &JobSpec,
    cancel: &AtomicBool,
    frames: &FrameQueue,
) -> Result<TrainResult> {
    let c = try_combo(&spec.combo)?;
    let plan = LocalPlanner.plan(&PlanRequest::new(c.clone(), c.batch, spec.quantized))?;
    let mut backend = CpuBackend::from_outcome(&plan)?;
    let mut sink = |frame: &Json| frames.push(frame.clone());
    let opts = JobOptions {
        job_id: Some(id.to_string()),
        cancel: Some(cancel),
        checkpoint_every: spec.checkpoint_every,
        progress_every: spec.progress_every,
        sink: Some(&mut sink),
        resume: spec.resume.as_ref(),
        quantized: spec.quantized,
    };
    train_combo_job(&mut backend, &c, spec.seed, spec.limits, spec.actors, false, opts)
}

/// The success payload stored for the final response line.
fn result_body(result: &TrainResult) -> BTreeMap<String, Json> {
    let mut body = BTreeMap::new();
    body.insert("combo".to_string(), Json::Str(result.combo.clone()));
    body.insert("backend".to_string(), Json::Str(result.backend.clone()));
    body.insert("threads".to_string(), Json::Num(result.threads as f64));
    body.insert("actors".to_string(), Json::Num(result.actors as f64));
    body.insert("seed".to_string(), Json::Num(result.seed as f64));
    body.insert("metrics".to_string(), result.metrics.to_json());
    body
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(priority: i64) -> JobSpec {
        JobSpec {
            combo: "dqn_cartpole".into(),
            seed: 1,
            actors: 1,
            limits: TrainLimits { max_env_steps: 300, max_episodes: 8 },
            quantized: false,
            priority,
            checkpoint_every: 0,
            progress_every: 0,
            resume: None,
        }
    }

    #[test]
    fn submissions_validate_synchronously() {
        let sched = Scheduler::new(4, Arc::new(ServerStats::new()));
        let mut bad = spec(0);
        bad.combo = "dqn_nonsense".into();
        assert!(sched.submit(bad).is_err());
        let mut mismatched = spec(0);
        mismatched.resume = Some(Checkpoint {
            combo: "a2c_invpend".into(),
            seed: 1,
            actors: 1,
            quantized: false,
            metrics: Default::default(),
            last_scale: None,
            ep_rewards: vec![0.0],
            rng_state: 1,
            rng_spare: None,
            fleet: Json::Null,
            agent: Json::Null,
        });
        let e = sched.submit(mismatched).unwrap_err();
        assert!(format!("{e}").contains("combo"), "{e}");
    }

    #[test]
    fn queue_is_bounded_and_priority_ordered() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(3, Arc::clone(&stats));
        let (_a, _) = sched.submit(spec(0)).unwrap();
        let (b, _) = sched.submit(spec(5)).unwrap();
        let (c, _) = sched.submit(spec(5)).unwrap();
        let e = sched.submit(spec(9)).unwrap_err();
        assert!(format!("{e}").contains("queue is full"), "{e}");
        assert_eq!(stats.jobs_rejected.load(Ordering::Relaxed), 1);
        assert_eq!(stats.job_queue_depth.load(Ordering::Relaxed), 3);
        // Highest priority first; FIFO among equals (b before c).
        let mut state = sched.state.lock().unwrap();
        let first = Scheduler::pick(&state).unwrap();
        assert_eq!(first, b);
        Scheduler::claim(&mut state, &first, &stats);
        assert_eq!(Scheduler::pick(&state).unwrap(), c);
    }

    #[test]
    fn cancelling_a_queued_job_closes_it_immediately() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let (id, frames) = sched.submit(spec(0)).unwrap();
        assert_eq!(sched.cancel(&id).unwrap(), "cancelled");
        assert!(frames.next().is_none());
        let body = sched.final_result(&id);
        assert_eq!(body.get("status").and_then(Json::as_str), Some("cancelled"));
        assert_eq!(body.get("cancelled").and_then(Json::as_bool), Some(true));
        assert_eq!(stats.jobs_cancelled.load(Ordering::Relaxed), 1);
        // Cancelling again is a no-op reporting the terminal phase.
        assert_eq!(sched.cancel(&id).unwrap(), "cancelled");
        assert!(sched.cancel("job-999").is_err());
    }

    #[test]
    fn drain_rejects_new_jobs_and_cancels_queued_ones() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let (id, frames) = sched.submit(spec(0)).unwrap();
        sched.drain();
        assert!(frames.next().is_none());
        let body = sched.final_result(&id);
        assert_eq!(body.get("status").and_then(Json::as_str), Some("cancelled"));
        assert_eq!(body.get("draining").and_then(Json::as_bool), Some(true));
        let e = sched.submit(spec(0)).unwrap_err();
        assert!(format!("{e}").contains("draining"), "{e}");
        assert_eq!(stats.jobs_rejected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn jobs_run_stream_frames_and_reach_done() {
        let stats = Arc::new(ServerStats::new());
        let sched = Scheduler::new(4, Arc::clone(&stats));
        let shutdown = AtomicBool::new(false);
        std::thread::scope(|s| {
            s.spawn(|| sched.run_runner(&shutdown));
            let mut want = spec(0);
            want.checkpoint_every = 100;
            want.progress_every = 75;
            let (id, frames) = sched.submit(want).unwrap();
            let mut kinds = Vec::new();
            while let Some(f) = frames.next() {
                assert_eq!(f.get("job").and_then(Json::as_str), Some(id.as_str()));
                kinds.push(f.get("frame").and_then(Json::as_str).unwrap_or("?").to_string());
            }
            let body = sched.final_result(&id);
            assert_eq!(body.get("status").and_then(Json::as_str), Some("done"));
            assert_eq!(body.get("cancelled").and_then(Json::as_bool), Some(false));
            assert!(body.get("metrics").is_some());
            assert!(kinds.iter().any(|k| k == "episode"), "{kinds:?}");
            assert!(kinds.iter().any(|k| k == "checkpoint"), "{kinds:?}");
            assert!(kinds.iter().any(|k| k == "progress"), "{kinds:?}");
            let listing = sched.jobs_json();
            let arr = listing.as_arr().unwrap();
            assert_eq!(arr.len(), 1);
            assert_eq!(arr[0].get("phase").and_then(Json::as_str), Some("done"));
            assert!(arr[0].get("wall_us").is_some());
            shutdown.store(true, Ordering::SeqCst);
        });
        assert_eq!(stats.jobs_completed.load(Ordering::Relaxed), 1);
        assert_eq!(stats.jobs_running.load(Ordering::Relaxed), 0);
    }
}
