//! Frame mailbox between a job's runner thread and the connection that
//! streams it.
//!
//! The trainer's sink pushes frames from the runner thread; the daemon's
//! streaming handler blocks on [`FrameQueue::next`] from the connection
//! worker and writes each frame as one protocol line.  Closing the queue
//! (job reached a terminal phase, or a queued job was cancelled before
//! running) wakes the reader with `None` — but only after every frame
//! pushed before the close has been drained, so a cancelled job's final
//! checkpoint frame always reaches the client.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};

use crate::util::json::Json;

/// A close-able FIFO of streamed training frames.
#[derive(Debug, Default)]
pub struct FrameQueue {
    inner: Mutex<Inner>,
    cond: Condvar,
}

#[derive(Debug, Default)]
struct Inner {
    frames: VecDeque<Json>,
    closed: bool,
    detached: bool,
}

impl FrameQueue {
    pub fn new() -> FrameQueue {
        FrameQueue::default()
    }

    /// A queue with no reader: every push is dropped on the floor.
    /// Detached and journal-recovered jobs run headless — without this,
    /// their frames would accumulate unboundedly with nobody draining.
    pub fn detached() -> FrameQueue {
        FrameQueue {
            inner: Mutex::new(Inner { detached: true, ..Inner::default() }),
            cond: Condvar::new(),
        }
    }

    /// Enqueue one frame (a no-op after close — a late frame from a
    /// racing producer is dropped rather than leaked into nowhere).
    pub fn push(&self, frame: Json) {
        let mut inner = self.inner.lock().unwrap();
        if !inner.closed && !inner.detached {
            inner.frames.push_back(frame);
            self.cond.notify_all();
        }
    }

    /// Blocking pop: the next frame, or `None` once the queue is closed
    /// *and* drained.
    pub fn next(&self) -> Option<Json> {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(f) = inner.frames.pop_front() {
                return Some(f);
            }
            if inner.closed {
                return None;
            }
            inner = self.cond.wait(inner).unwrap();
        }
    }

    /// Mark the stream complete, waking any blocked reader.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        self.cond.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frames_drain_in_order_then_none_after_close() {
        let q = FrameQueue::new();
        q.push(Json::Num(1.0));
        q.push(Json::Num(2.0));
        q.close();
        // Pushes after close are dropped, not queued.
        q.push(Json::Num(3.0));
        assert_eq!(q.next(), Some(Json::Num(1.0)));
        assert_eq!(q.next(), Some(Json::Num(2.0)));
        assert_eq!(q.next(), None);
        assert_eq!(q.next(), None);
    }

    #[test]
    fn close_wakes_a_blocked_reader() {
        let q = FrameQueue::new();
        std::thread::scope(|s| {
            let reader = s.spawn(|| q.next());
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.close();
            assert_eq!(reader.join().unwrap(), None);
        });
    }

    #[test]
    fn detached_queues_drop_every_push() {
        let q = FrameQueue::detached();
        q.push(Json::Num(1.0));
        q.push(Json::Num(2.0));
        q.close();
        assert_eq!(q.next(), None, "detached frames are never retained");
    }

    #[test]
    fn push_wakes_a_blocked_reader() {
        let q = FrameQueue::new();
        std::thread::scope(|s| {
            let reader = s.spawn(|| q.next());
            std::thread::sleep(std::time::Duration::from_millis(20));
            q.push(Json::Bool(true));
            assert_eq!(reader.join().unwrap(), Some(Json::Bool(true)));
        });
    }
}
