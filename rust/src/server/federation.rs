//! Federated planning: one [`Planner`] over *several* `apdrl serve`
//! daemons (the ROADMAP's multi-daemon federation item).
//!
//! [`FederatedPlanner`] takes N daemon addresses.  `plan_many` shards
//! the request list **by plan key** across the hosts — the same point
//! always lands on the same daemon within a host list, so every shard
//! rides its daemon's warm plan cache — and runs one worker thread per
//! shard.  A shard whose daemon fails (connection refused, died
//! mid-sweep, protocol error) marks its host dead; its unfinished
//! requests are re-sharded **round-robin across every surviving host**
//! (concurrent retry chunks, balanced to within one request), cascading
//! if a survivor dies mid-retry.  Only when *every* host has failed does
//! the sweep error.  Results merge back into request order, tagged
//! `Provenance::Federated { shard }` with the host index that actually
//! served them.
//!
//! Because all daemons run the same deterministic solver (and the plans
//! of one grid point never depend on another's), a federated sweep is
//! bit-identical to a local or single-remote one — asserted in
//! `tests/federation.rs`, including with one host down.
//!
//! [`select_planner`] is the one place the whole CLI picks a backend:
//! local by default, [`RemotePlanner`] for a single `--remote` host,
//! [`FederatedPlanner`] for a comma-separated host list.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::planner::{
    LocalPlanner, PlanOutcome, PlanRequest, Planner, Provenance,
};
use crate::obs;
use crate::partition::cache::PlanKey;

use super::client::{server_addr, wire_point, RemotePlanner, ENV_ADDR};

/// Split a `--remote` / `APDRL_SERVER` spec into its host list
/// (comma-separated, blanks ignored, order-preserving dedupe — the same
/// daemon listed twice must not be sharded twice).
pub fn parse_host_list(spec: &str) -> Vec<String> {
    let mut hosts: Vec<String> = Vec::new();
    for host in spec.split(',').map(str::trim).filter(|h| !h.is_empty()) {
        if !hosts.iter().any(|h| h == host) {
            hosts.push(host.to_string());
        }
    }
    hosts
}

/// The one backend-choice point: resolve the `--remote` flag (explicit
/// value, bare flag, or absent) against `APDRL_SERVER` and hand back the
/// matching [`Planner`].
///
/// * no flag, no env → [`LocalPlanner`];
/// * one `host:port` → [`RemotePlanner`] (connected eagerly);
/// * `host1:p,host2:p,...` → [`FederatedPlanner`] over the list.
pub fn select_planner(remote_flag: Option<&str>) -> Result<Box<dyn Planner>> {
    let spec = match remote_flag {
        // An explicit --remote value (a bare flag arrives as "true" and
        // defers to the environment, erroring helpfully if unset).
        Some(_) => Some(server_addr(remote_flag)?),
        // No flag: the env var alone also opts into remote planning —
        // the documented one-env-var workflow.
        None => std::env::var(ENV_ADDR).ok().filter(|v| !v.is_empty()),
    };
    match spec {
        None => Ok(Box::new(LocalPlanner)),
        Some(spec) => {
            let hosts = parse_host_list(&spec);
            match hosts.len() {
                0 => bail!("no usable host in planning server spec {spec:?}"),
                1 => Ok(Box::new(RemotePlanner::connect(&hosts[0])?)),
                _ => Ok(Box::new(FederatedPlanner::connect(&hosts)?)),
            }
        }
    }
}

/// FNV-1a over the plan-key string: a stable, dependency-free shard
/// hash (std's `DefaultHasher` would work today but documents no
/// stability guarantee).
fn shard_of(key: &PlanKey, hosts: usize) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_str().bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h % hosts as u64) as usize
}

/// A sharded, fail-over planning backend over N daemon addresses.
pub struct FederatedPlanner {
    hosts: Vec<String>,
}

impl FederatedPlanner {
    /// Build over `hosts` (deduped, order preserved).  Hosts are probed
    /// eagerly: a fully unreachable federation is reported here, while a
    /// *partially* reachable one is fine — fail-over covers the rest.
    pub fn connect(hosts: &[String]) -> Result<FederatedPlanner> {
        let mut deduped: Vec<String> = Vec::new();
        for host in hosts.iter().flat_map(|spec| parse_host_list(spec)) {
            if !deduped.iter().any(|h| *h == host) {
                deduped.push(host);
            }
        }
        if deduped.is_empty() {
            bail!("federated planner needs at least one daemon address");
        }
        if !deduped.iter().any(|h| RemotePlanner::connect(h).is_ok()) {
            bail!(
                "none of the {} federated planning hosts are reachable ({})",
                deduped.len(),
                deduped.join(", ")
            );
        }
        Ok(FederatedPlanner { hosts: deduped })
    }

    /// The (deduped) host list, in shard-index order.
    pub fn hosts(&self) -> &[String] {
        &self.hosts
    }

    /// Which shard (host index) `req` homes on — observability for
    /// operators and tests (fail-over may serve it elsewhere).
    pub fn shard_for(&self, req: &PlanRequest) -> usize {
        shard_of(&req.plan_key(), self.hosts.len())
    }
}

/// Reject requests no host could ever serve (zero batch, customized
/// non-registry combos) *before* dispatch, so a client-side validation
/// error surfaces directly instead of marking healthy daemons dead and
/// replaying a doomed batch against every host.
fn validate_for_wire(reqs: &[PlanRequest]) -> Result<()> {
    for req in reqs {
        if req.batch == 0 {
            bail!("plan: batch must be ≥ 1 (combo {})", req.name());
        }
        wire_point(req)?;
    }
    Ok(())
}

/// Plan `idxs` (indices into `reqs`) on `host`, writing outcomes tagged
/// with `shard` into `slots`.  All-or-nothing per call: on error the
/// caller re-dispatches whatever is still unfilled.
fn serve_shard(
    host: &str,
    shard: usize,
    idxs: &[usize],
    reqs: &[PlanRequest],
    slots: &[Mutex<Option<PlanOutcome>>],
) -> Result<()> {
    let t0 = Instant::now();
    let client = RemotePlanner::connect(host)?;
    let subset: Vec<PlanRequest> = idxs.iter().map(|&i| reqs[i].clone()).collect();
    let outcomes = client.plan_many(&subset)?;
    for (&i, mut outcome) in idxs.iter().zip(outcomes) {
        outcome.provenance = Provenance::Federated { shard };
        *slots[i].lock().unwrap() = Some(outcome);
    }
    if obs::active() {
        obs::publish(
            obs::Event::new("fed.shard")
                .tag("host", host)
                .num("shard", shard as f64)
                .num("points", idxs.len() as f64)
                .num("wall_us", t0.elapsed().as_micros() as f64),
        );
    }
    Ok(())
}

/// Publish a `fed.down` event for a host that just failed (connection
/// refused, died mid-sweep, protocol error).
fn publish_host_down(host: &str, shard: usize, err: &anyhow::Error) {
    if obs::active() {
        obs::publish(
            obs::Event::new("fed.down")
                .tag("host", host)
                .num("shard", shard as f64)
                .tag("error", &format!("{err:#}")),
        );
    }
}

impl Planner for FederatedPlanner {
    fn describe(&self) -> String {
        format!(
            "federated over {} hosts ({})",
            self.hosts.len(),
            self.hosts.join(", ")
        )
    }

    /// One point: its shard host first, then the others in order — the
    /// single-plan shape of the same fail-over the sweep path has.
    fn plan(&self, req: &PlanRequest) -> Result<PlanOutcome> {
        validate_for_wire(std::slice::from_ref(req))?;
        let n = self.hosts.len();
        let home = shard_of(&req.plan_key(), n);
        let mut last_err = None;
        for k in 0..n {
            let shard = (home + k) % n;
            match RemotePlanner::connect(&self.hosts[shard])
                .and_then(|client| client.plan(req))
            {
                Ok(mut outcome) => {
                    outcome.provenance = Provenance::Federated { shard };
                    return Ok(outcome);
                }
                Err(e) => {
                    publish_host_down(&self.hosts[shard], shard, &e);
                    last_err = Some(e);
                }
            }
        }
        Err(last_err
            .unwrap_or_else(|| anyhow!("federated planner has no hosts"))
            .context(format!("all {n} federated planning hosts failed")))
    }

    /// Shard by plan key, one worker thread per shard, merge in request
    /// order; failed shards retry on the surviving hosts.
    fn plan_many(&self, reqs: &[PlanRequest]) -> Result<Vec<PlanOutcome>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        validate_for_wire(reqs)?;
        let n = self.hosts.len();
        let mut shards: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, req) in reqs.iter().enumerate() {
            shards[shard_of(&req.plan_key(), n)].push(i);
        }
        let slots: Vec<Mutex<Option<PlanOutcome>>> =
            (0..reqs.len()).map(|_| Mutex::new(None)).collect();
        let alive: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(true)).collect();
        let first_error: Mutex<Option<anyhow::Error>> = Mutex::new(None);
        std::thread::scope(|s| {
            for (shard, idxs) in shards.iter().enumerate() {
                if idxs.is_empty() {
                    continue;
                }
                let (slots, alive, first_error) = (&slots, &alive, &first_error);
                let host = &self.hosts[shard];
                s.spawn(move || {
                    if let Err(e) = serve_shard(host, shard, idxs, reqs, slots) {
                        alive[shard].store(false, Ordering::SeqCst);
                        publish_host_down(host, shard, &e);
                        first_error.lock().unwrap().get_or_insert(e);
                    }
                });
            }
        });
        // Fail-over passes: everything the dead shards left unfilled is
        // re-sharded round-robin across *all* surviving hosts — a dead
        // daemon's load spreads evenly instead of one survivor absorbing
        // the whole remainder — and the retry chunks run concurrently.
        // A survivor that dies during a retry round is dropped and the
        // still-unserved remainder re-shards over whoever is left.
        let mut pending: Vec<usize> =
            (0..reqs.len()).filter(|&i| slots[i].lock().unwrap().is_none()).collect();
        let mut survivors: Vec<usize> =
            (0..n).filter(|&i| alive[i].load(Ordering::SeqCst)).collect();
        while !pending.is_empty() {
            if survivors.is_empty() {
                let err = first_error
                    .lock()
                    .unwrap()
                    .take()
                    .unwrap_or_else(|| anyhow!("federated sweep failed"));
                return Err(err.context(format!(
                    "federated sweep: {} of {} points unserved after trying all {} hosts",
                    pending.len(),
                    reqs.len(),
                    n
                )));
            }
            if obs::active() {
                obs::publish(
                    obs::Event::new("fed.failover")
                        .num("pending", pending.len() as f64)
                        .num("survivors", survivors.len() as f64),
                );
            }
            let mut chunks: Vec<Vec<usize>> = vec![Vec::new(); survivors.len()];
            for (pos, &req_idx) in pending.iter().enumerate() {
                chunks[pos % survivors.len()].push(req_idx);
            }
            std::thread::scope(|s| {
                for (ci, chunk) in chunks.iter().enumerate() {
                    if chunk.is_empty() {
                        continue;
                    }
                    let shard = survivors[ci];
                    let (slots, alive, first_error) = (&slots, &alive, &first_error);
                    let host = &self.hosts[shard];
                    s.spawn(move || {
                        if let Err(e) = serve_shard(host, shard, chunk, reqs, slots) {
                            alive[shard].store(false, Ordering::SeqCst);
                            publish_host_down(host, shard, &e);
                            first_error.lock().unwrap().get_or_insert(e);
                        }
                    });
                }
            });
            pending.retain(|&i| slots[i].lock().unwrap().is_none());
            survivors.retain(|&i| alive[i].load(Ordering::SeqCst));
        }
        let outcomes: Vec<PlanOutcome> = slots
            .into_iter()
            .map(|slot| slot.into_inner().unwrap().expect("every slot filled or errored"))
            .collect();
        Ok(outcomes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_lists_parse_trim_and_dedupe() {
        assert_eq!(
            parse_host_list("a:1, b:2 ,a:1,,c:3"),
            vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()]
        );
        assert!(parse_host_list(" , ").is_empty());
    }

    #[test]
    fn sharding_is_deterministic_and_in_range() {
        let reqs = [
            PlanRequest::named("dqn_cartpole").unwrap(),
            PlanRequest::named("ddpg_lunar").unwrap().with_batch(256),
            PlanRequest::named("a2c_invpend").unwrap().fp32(),
        ];
        for hosts in 1..=4usize {
            for req in &reqs {
                let s = shard_of(&req.plan_key(), hosts);
                assert!(s < hosts);
                assert_eq!(s, shard_of(&req.plan_key(), hosts), "must be stable");
            }
        }
        // One host ⇒ everything shards to it.
        assert!(reqs.iter().all(|r| shard_of(&r.plan_key(), 1) == 0));
    }

    #[test]
    fn unreachable_federation_is_reported_at_connect() {
        // Loopback port 1 is essentially never listening.
        let hosts = vec!["127.0.0.1:1".to_string()];
        let e = match FederatedPlanner::connect(&hosts) {
            Err(e) => e,
            Ok(_) => return, // something *is* listening; nothing to assert
        };
        assert!(format!("{e}").contains("reachable"), "{e}");
        assert!(FederatedPlanner::connect(&[]).is_err());
    }

    #[test]
    fn select_planner_defaults_local_without_flag_or_env() {
        if std::env::var(ENV_ADDR).is_ok() {
            return; // environment opts into remote; nothing to assert here
        }
        let planner = select_planner(None).expect("local backend needs no server");
        assert_eq!(planner.describe(), "local");
        // A bare --remote with no env var is a guiding error.
        let e = select_planner(Some("true")).unwrap_err();
        assert!(format!("{e}").contains(ENV_ADDR), "{e}");
    }
}
