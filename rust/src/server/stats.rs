//! Telemetry for the planning server *and* the solver it fronts.
//!
//! Two layers:
//!
//! * [`ServerStats`] — per-daemon counters (requests by verb, errors,
//!   plans served, solve wall time, queue depth, live connections),
//!   surfaced over the wire by the `stats` protocol verb together with
//!   the process-wide plan-cache counters.
//! * [`SolveTelemetry`] — a process-global record of every fresh ILP
//!   solve (count, explored nodes, wall time), fed by
//!   `partition::ilp::solve` itself.  Its running mean of explored
//!   nodes drives [`tasks_per_worker_hint`], the adaptive fan-out the
//!   parallel branch-and-bound uses instead of the fixed
//!   `TASKS_PER_WORKER` constant once enough solves have been observed.
//!
//! Everything is lock-free atomics: the counters sit on the solver hot
//! path and must never serialize concurrent workers.

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::partition::cache;
use crate::util::json::Json;

/// Minimum observed solves before the adaptive fan-out hint activates;
/// below this the solver keeps its fixed fallback constant.
const HINT_MIN_SOLVES: u64 = 4;

/// Per-verb latency reservoir depth: percentiles are computed over the
/// most recent this-many requests of each verb, so a long-lived daemon
/// reports current behaviour rather than its lifetime average.
const LATENCY_SAMPLES: usize = 512;

/// Per-daemon request counters.  All monotonic except `queue_depth`
/// (connections accepted but not yet picked up by a worker) and
/// `in_flight` (requests currently being serviced).
#[derive(Debug, Default)]
pub struct ServerStats {
    pub requests: AtomicU64,
    pub errors: AtomicU64,
    pub plan_requests: AtomicU64,
    pub sweep_requests: AtomicU64,
    pub stats_requests: AtomicU64,
    pub flush_requests: AtomicU64,
    /// Individual plans returned (a sweep of N points counts N).
    pub plans_served: AtomicU64,
    /// Of those, how many came out of the plan cache.
    pub plans_from_cache: AtomicU64,
    /// Wall time spent inside planning calls, µs (cache hits included —
    /// they are part of request latency).
    pub solve_us_total: AtomicU64,
    /// Slowest single planning request, µs.
    pub solve_us_max: AtomicU64,
    /// B&B nodes explored on behalf of remote requests.
    pub explored_total: AtomicU64,
    pub connections: AtomicU64,
    pub queue_depth: AtomicUsize,
    pub in_flight: AtomicUsize,
    /// Training jobs accepted by the scheduler (protocol v3 `train`).
    pub jobs_submitted: AtomicU64,
    /// Jobs that ran to their limits.
    pub jobs_completed: AtomicU64,
    /// Jobs stopped by `cancel` or a drain, whether queued or running.
    pub jobs_cancelled: AtomicU64,
    /// Jobs whose runner returned an error.
    pub jobs_failed: AtomicU64,
    /// Submissions bounced (queue full or daemon draining).
    pub jobs_rejected: AtomicU64,
    /// Jobs replayed from the `APDRL_JOB_DIR` journal at boot (each is
    /// also counted in `jobs_submitted`): recovered-vs-fresh provenance.
    pub jobs_recovered: AtomicU64,
    /// Jobs currently waiting in the scheduler queue.
    pub job_queue_depth: AtomicUsize,
    /// Jobs currently executing on a runner thread.
    pub jobs_running: AtomicUsize,
    /// Sliding window of request wall times per verb, µs — touched once
    /// per *request* (not per solve iteration), so a short critical
    /// section off the solver hot path is fine.
    verb_latency: Mutex<BTreeMap<String, VecDeque<u64>>>,
    /// Sliding window of per-job wall times, µs (one sample per job that
    /// reached a terminal phase — the `stats` verb turns it into p50/p90/
    /// p99 percentiles).
    job_wall_us: Mutex<VecDeque<u64>>,
}

impl ServerStats {
    pub fn new() -> ServerStats {
        ServerStats::default()
    }

    /// Record one serviced planning request covering `plans` plans, of
    /// which `cache_hits` were cache hits, exploring `explored` nodes in
    /// `wall_us` µs of wall time.
    pub fn record_request(&self, plans: u64, cache_hits: u64, explored: u64, wall_us: u64) {
        self.plans_served.fetch_add(plans, Ordering::Relaxed);
        self.plans_from_cache.fetch_add(cache_hits, Ordering::Relaxed);
        self.explored_total.fetch_add(explored, Ordering::Relaxed);
        self.solve_us_total.fetch_add(wall_us, Ordering::Relaxed);
        self.solve_us_max.fetch_max(wall_us, Ordering::Relaxed);
    }

    /// Record the end-to-end wall time of one request of `verb`, µs.
    /// Keeps the most recent [`LATENCY_SAMPLES`] per verb.
    pub fn record_latency(&self, verb: &str, wall_us: u64) {
        let mut map = self.verb_latency.lock().unwrap();
        let window = map.entry(verb.to_string()).or_default();
        if window.len() == LATENCY_SAMPLES {
            window.pop_front();
        }
        window.push_back(wall_us);
    }

    /// Record the wall time of one finished training job, µs.  Same
    /// sliding-window policy as [`ServerStats::record_latency`].
    pub fn record_job_wall(&self, wall_us: u64) {
        let mut window = self.job_wall_us.lock().unwrap();
        if window.len() == LATENCY_SAMPLES {
            window.pop_front();
        }
        window.push_back(wall_us);
    }

    /// Snapshot every counter — plus the process-wide plan-cache state
    /// and solver telemetry — as the JSON object the `stats` verb ships.
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        let num = |v: u64| Json::Num(v as f64);
        obj.insert("requests".into(), num(self.requests.load(Ordering::Relaxed)));
        obj.insert("errors".into(), num(self.errors.load(Ordering::Relaxed)));
        obj.insert("plan_requests".into(), num(self.plan_requests.load(Ordering::Relaxed)));
        obj.insert("sweep_requests".into(), num(self.sweep_requests.load(Ordering::Relaxed)));
        obj.insert("stats_requests".into(), num(self.stats_requests.load(Ordering::Relaxed)));
        obj.insert("flush_requests".into(), num(self.flush_requests.load(Ordering::Relaxed)));
        obj.insert("plans_served".into(), num(self.plans_served.load(Ordering::Relaxed)));
        obj.insert(
            "plans_from_cache".into(),
            num(self.plans_from_cache.load(Ordering::Relaxed)),
        );
        obj.insert("solve_us_total".into(), num(self.solve_us_total.load(Ordering::Relaxed)));
        obj.insert("solve_us_max".into(), num(self.solve_us_max.load(Ordering::Relaxed)));
        obj.insert("explored_total".into(), num(self.explored_total.load(Ordering::Relaxed)));
        obj.insert("connections".into(), num(self.connections.load(Ordering::Relaxed)));
        obj.insert(
            "queue_depth".into(),
            num(self.queue_depth.load(Ordering::Relaxed) as u64),
        );
        obj.insert("in_flight".into(), num(self.in_flight.load(Ordering::Relaxed) as u64));

        // Process-wide plan cache: every client shares it, so hit/miss
        // rates here are the fleet-level figure, not per-connection.
        let (len, hits, misses, evictions) = {
            let guard = cache::global().lock().unwrap();
            (guard.len() as u64, guard.hits, guard.misses, guard.evictions)
        };
        let mut c = std::collections::BTreeMap::new();
        c.insert("entries".into(), num(len));
        c.insert("hits".into(), num(hits));
        c.insert("misses".into(), num(misses));
        c.insert("evictions".into(), num(evictions));
        let rate = if hits + misses > 0 { hits as f64 / (hits + misses) as f64 } else { 0.0 };
        c.insert("hit_rate".into(), Json::Num(rate));
        obj.insert("cache".into(), Json::Obj(c));

        // Per-verb request latency percentiles over the recent window.
        let mut lat = std::collections::BTreeMap::new();
        for (verb, window) in self.verb_latency.lock().unwrap().iter() {
            let mut sorted: Vec<u64> = window.iter().copied().collect();
            sorted.sort_unstable();
            let mut v = std::collections::BTreeMap::new();
            v.insert("count".into(), num(sorted.len() as u64));
            v.insert("p50_us".into(), num(percentile(&sorted, 0.50)));
            v.insert("p90_us".into(), num(percentile(&sorted, 0.90)));
            v.insert("p99_us".into(), num(percentile(&sorted, 0.99)));
            v.insert("max_us".into(), num(*sorted.last().unwrap_or(&0)));
            lat.insert(verb.clone(), Json::Obj(v));
        }
        obj.insert("latency_us".into(), Json::Obj(lat));

        // Training-job scheduler: lifecycle counters plus per-job
        // wall-time percentiles over the recent window.
        let mut jobs = std::collections::BTreeMap::new();
        jobs.insert("submitted".into(), num(self.jobs_submitted.load(Ordering::Relaxed)));
        jobs.insert("completed".into(), num(self.jobs_completed.load(Ordering::Relaxed)));
        jobs.insert("cancelled".into(), num(self.jobs_cancelled.load(Ordering::Relaxed)));
        jobs.insert("failed".into(), num(self.jobs_failed.load(Ordering::Relaxed)));
        jobs.insert("rejected".into(), num(self.jobs_rejected.load(Ordering::Relaxed)));
        jobs.insert("recovered".into(), num(self.jobs_recovered.load(Ordering::Relaxed)));
        jobs.insert(
            "queue_depth".into(),
            num(self.job_queue_depth.load(Ordering::Relaxed) as u64),
        );
        jobs.insert("running".into(), num(self.jobs_running.load(Ordering::Relaxed) as u64));
        let mut sorted: Vec<u64> = self.job_wall_us.lock().unwrap().iter().copied().collect();
        sorted.sort_unstable();
        let mut w = std::collections::BTreeMap::new();
        w.insert("count".into(), num(sorted.len() as u64));
        w.insert("p50_us".into(), num(percentile(&sorted, 0.50)));
        w.insert("p90_us".into(), num(percentile(&sorted, 0.90)));
        w.insert("p99_us".into(), num(percentile(&sorted, 0.99)));
        w.insert("max_us".into(), num(*sorted.last().unwrap_or(&0)));
        jobs.insert("wall_us".into(), Json::Obj(w));
        obj.insert("jobs".into(), Json::Obj(jobs));

        // Observability bus self-telemetry: how many events this process
        // published / evicted and whether anyone is listening right now.
        let bc = crate::obs::global().counters();
        let mut o = std::collections::BTreeMap::new();
        o.insert("published".into(), num(bc.published));
        o.insert("dropped".into(), num(bc.dropped));
        o.insert("subscribers".into(), num(bc.subscribers as u64));
        obj.insert("obs".into(), Json::Obj(o));

        // Kernel-calibration provenance: which measured cost table (if
        // any) is pricing this daemon's PS latencies.
        obj.insert("calibration".into(), crate::profile::calib::provenance_json());

        // Solver telemetry (all solves in this process, remote or not).
        let t = telemetry();
        let mut s = std::collections::BTreeMap::new();
        s.insert("solves".into(), num(t.solves.load(Ordering::Relaxed)));
        s.insert("explored_total".into(), num(t.explored_total.load(Ordering::Relaxed)));
        s.insert("wall_us_total".into(), num(t.wall_us_total.load(Ordering::Relaxed)));
        s.insert(
            "tasks_per_worker_hint".into(),
            match tasks_per_worker_hint() {
                Some(n) => num(n as u64),
                None => Json::Null,
            },
        );
        obj.insert("solver".into(), Json::Obj(s));

        Json::Obj(obj)
    }
}

/// Nearest-rank percentile over an already-sorted sample, 0 when empty.
fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx]
}

/// Process-global solve telemetry, recorded by `partition::ilp::solve`
/// for every fresh (non-cached) branch-and-bound run.
#[derive(Debug, Default)]
pub struct SolveTelemetry {
    pub solves: AtomicU64,
    pub explored_total: AtomicU64,
    pub wall_us_total: AtomicU64,
}

pub fn telemetry() -> &'static SolveTelemetry {
    static GLOBAL: SolveTelemetry = SolveTelemetry {
        solves: AtomicU64::new(0),
        explored_total: AtomicU64::new(0),
        wall_us_total: AtomicU64::new(0),
    };
    &GLOBAL
}

/// Record one completed solve.
pub fn record_solve(explored: usize, wall: std::time::Duration) {
    let t = telemetry();
    t.solves.fetch_add(1, Ordering::Relaxed);
    t.explored_total.fetch_add(explored as u64, Ordering::Relaxed);
    t.wall_us_total.fetch_add(wall.as_micros() as u64, Ordering::Relaxed);
}

/// Adaptive prefix fan-out for the parallel B&B: how many prefix tasks
/// to cut per worker, judged from the mean explored-node count of the
/// solves seen so far in this process.
///
/// Small trees (the cartpole-class combos) finish in microseconds — the
/// queue-drain overhead of a deep fan-out outweighs any balancing, so
/// the hint shrinks.  Large trees (conv nets, big batches) leave
/// stragglers under a shallow fan-out, so the hint grows.  `None` until
/// [`HINT_MIN_SOLVES`] solves have been observed; the caller then falls
/// back to its fixed constant.  The hint only shapes work division —
/// both fan-outs are exact searches, so the returned plan is identical
/// either way (asserted in `partition::ilp` tests).
pub fn tasks_per_worker_hint() -> Option<usize> {
    let t = telemetry();
    hint_for(
        t.solves.load(Ordering::Relaxed),
        t.explored_total.load(Ordering::Relaxed),
    )
}

/// The pure band mapping behind [`tasks_per_worker_hint`].
fn hint_for(solves: u64, explored_total: u64) -> Option<usize> {
    if solves < HINT_MIN_SOLVES {
        return None;
    }
    Some(match explored_total / solves {
        0..=7_999 => 2,
        8_000..=79_999 => 4,
        _ => 8,
    })
}

/// Test-only: reset the process-global telemetry (tests share one
/// process; stale counts would couple them).
pub fn reset_telemetry_for_tests() {
    let t = telemetry();
    t.solves.store(0, Ordering::Relaxed);
    t.explored_total.store(0, Ordering::Relaxed);
    t.wall_us_total.store(0, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hint_needs_minimum_history_then_scales_with_tree_size() {
        // The pure mapping is tested directly: the process-global
        // counters race with every other test that solves an ILP.
        assert_eq!(hint_for(0, 0), None, "no history → fixed fallback");
        assert_eq!(hint_for(HINT_MIN_SOLVES - 1, 1 << 40), None, "below minimum history");
        let n = HINT_MIN_SOLVES;
        assert_eq!(hint_for(n, n * 1_000), Some(2), "tiny trees → shallow fan-out");
        assert_eq!(hint_for(n, n * 20_000), Some(4), "mid trees → the fixed default");
        assert_eq!(hint_for(n, n * 500_000), Some(8), "huge trees → deep fan-out");
    }

    #[test]
    fn server_stats_json_has_the_contract_fields() {
        let stats = ServerStats::new();
        stats.requests.fetch_add(3, Ordering::Relaxed);
        stats.record_request(2, 1, 4_000, 1_500);
        let j = stats.to_json();
        assert_eq!(j.get("requests").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("plans_served").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("plans_from_cache").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("solve_us_max").and_then(Json::as_usize), Some(1_500));
        assert!(j.get("cache").and_then(|c| c.get("hit_rate")).is_some());
        assert!(j.get("cache").and_then(|c| c.get("evictions")).is_some());
        assert!(j.get("solver").and_then(|s| s.get("solves")).is_some());
        let o = j.get("obs").expect("obs bus section");
        for key in ["published", "dropped", "subscribers"] {
            assert!(o.get(key).and_then(Json::as_usize).is_some(), "obs.{key}");
        }
        assert!(
            j.get("calibration").and_then(|c| c.get("present")).is_some(),
            "calibration provenance section"
        );
        let jobs = j.get("jobs").expect("jobs section");
        for key in ["submitted", "completed", "cancelled", "failed", "rejected", "recovered"] {
            assert_eq!(jobs.get(key).and_then(Json::as_usize), Some(0), "{key}");
        }
        assert_eq!(jobs.get("queue_depth").and_then(Json::as_usize), Some(0));
        assert_eq!(jobs.get("running").and_then(Json::as_usize), Some(0));
    }

    #[test]
    fn job_wall_times_report_windowed_percentiles() {
        let stats = ServerStats::new();
        for us in 1..=100u64 {
            stats.record_job_wall(us);
        }
        let j = stats.to_json();
        let w = j.get("jobs").and_then(|s| s.get("wall_us")).expect("wall window");
        assert_eq!(w.get("count").and_then(Json::as_usize), Some(100));
        assert_eq!(w.get("p50_us").and_then(Json::as_usize), Some(51));
        assert_eq!(w.get("p90_us").and_then(Json::as_usize), Some(90));
        assert_eq!(w.get("max_us").and_then(Json::as_usize), Some(100));
    }

    #[test]
    fn verb_latency_reports_windowed_percentiles() {
        let stats = ServerStats::new();
        // 1..=100 µs in order: p50 hits the middle, max the top.
        for us in 1..=100u64 {
            stats.record_latency("plan", us);
        }
        stats.record_latency("stats", 7);
        let j = stats.to_json();
        let plan = j.get("latency_us").and_then(|l| l.get("plan")).expect("plan window");
        assert_eq!(plan.get("count").and_then(Json::as_usize), Some(100));
        assert_eq!(plan.get("p50_us").and_then(Json::as_usize), Some(51));
        assert_eq!(plan.get("p99_us").and_then(Json::as_usize), Some(99));
        assert_eq!(plan.get("max_us").and_then(Json::as_usize), Some(100));
        let s = j.get("latency_us").and_then(|l| l.get("stats")).expect("stats window");
        assert_eq!(s.get("p50_us").and_then(Json::as_usize), Some(7));

        // The window slides: after LATENCY_SAMPLES more, old samples age out.
        for _ in 0..LATENCY_SAMPLES {
            stats.record_latency("plan", 1_000);
        }
        let j = stats.to_json();
        let plan = j.get("latency_us").and_then(|l| l.get("plan")).expect("plan window");
        assert_eq!(plan.get("count").and_then(Json::as_usize), Some(LATENCY_SAMPLES));
        assert_eq!(plan.get("p50_us").and_then(Json::as_usize), Some(1_000));
    }

    #[test]
    fn percentile_is_nearest_rank_and_total_on_singletons() {
        assert_eq!(percentile(&[], 0.5), 0);
        assert_eq!(percentile(&[42], 0.0), 42);
        assert_eq!(percentile(&[42], 0.99), 42);
        assert_eq!(percentile(&[10, 20, 30, 40], 0.5), 30);
    }
}
