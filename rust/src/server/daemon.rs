//! The long-lived planning daemon behind `apdrl serve`.
//!
//! A [`Server`] binds a TCP listener (`std::net` only — no async
//! runtime, no external deps) and services JSON-lines requests
//! ([`super::protocol`]) with a fixed pool of worker threads.
//! Scheduling is **per request, not per connection**: the accept loop
//! enqueues each connection on an `mpsc` channel, a worker dequeues it,
//! serves at most one request (polling reads with a short timeout so a
//! quiet connection never pins the worker), and re-enqueues it.  Open
//! connections round-robin through the pool, so a handful of persistent
//! sweep clients can never starve the control verbs (`stats`,
//! `shutdown`) out of the pool.  All planning goes through the
//! in-process [`Planner`] backend (`coordinator::planner::LocalPlanner`)
//! — the daemon *is* the local backend behind a socket — so every
//! connection shares the one process-wide [`crate::partition::cache`]:
//! a plan solved for any client is a cache hit for every later client,
//! which is the point of running the planner as a daemon instead of a
//! library.
//!
//! Protocol-v3 `train` requests do not run on the connection workers:
//! they are submitted to the in-process job [`Scheduler`], which
//! executes them on its own small pool of *runner* threads while the
//! submitting worker streams the job's frames back over the held-open
//! connection (`jobs` and `cancel` administer the same scheduler from
//! any connection).  The training *compute* therefore never occupies a
//! connection worker — though a streaming connection pins its worker
//! for the stream's duration, exactly like a streaming sweep, so size
//! `--workers` above the number of concurrent train clients.
//!
//! Shutdown is cooperative and *draining*: the `shutdown` verb first
//! drains the scheduler — new submissions are rejected, queued jobs are
//! cancelled, running jobs stop at their next round boundary and emit a
//! final checkpoint frame for hand-off — and is then acknowledged on
//! its own connection before the flag flips; the accept loop (a
//! nonblocking poll), the workers and the runners observe it within one
//! poll quantum and exit (queued connections are closed, streaming
//! connections finish their final `result` line first).
//!
//! With `APDRL_JOB_DIR` set, jobs are additionally *durable*: the
//! scheduler journals each job's spec and newest checkpoint to that
//! directory, and [`Server::bind`] replays the journal so a SIGKILLed
//! daemon resumes its jobs (bit-identically) on restart — see
//! [`super::jobs::journal`].  The daemon also gossips its queued-job
//! digests to clients (on `jobs`/`stats` responses and on every
//! streamed checkpoint frame), which is how `RemoteTrainer` fails a
//! dead host's queue over to survivors.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::coordinator::planner::{LocalPlanner, PlanOutcome, PlanRequest, Planner};
use crate::coordinator::{plan_sweep_progress, Checkpoint, TrainLimits};
use crate::obs;
use crate::util::json::Json;

use super::jobs::{Journal, JobSpec, Scheduler, SubmitOpts, DEFAULT_MAX_QUEUE, DEFAULT_RUNNERS};
use super::protocol::{
    error_response, frame_response, ok_response, plan_to_json, profile_payload,
    progress_response, Request, WirePoint,
};
use super::stats::ServerStats;

/// Default listen address of `apdrl serve` (loopback: the daemon trusts
/// its peers — exposing it wider is a deployment decision, not ours).
pub const DEFAULT_ADDR: &str = "127.0.0.1:7040";

/// Idle-connection cutoff: a connection with no complete request for
/// this long is dropped (well-behaved clients reconnect transparently —
/// `RemotePlanner` retries once on a dead socket).
const IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// Read-poll quantum: how long a worker waits on one connection for a
/// request (and on the queue for a connection) before moving on.  Short
/// enough that a quiet connection cannot monopolize a worker; data
/// arriving mid-poll is served immediately, so request latency is not
/// quantized by this.
const READ_POLL: Duration = Duration::from_millis(100);

/// A bound-but-not-yet-running planning server.
pub struct Server {
    listener: TcpListener,
    workers: usize,
    stats: Arc<ServerStats>,
    shutdown: Arc<AtomicBool>,
    scheduler: Arc<Scheduler>,
}

impl Server {
    /// Bind `addr` (use port 0 for an ephemeral port — tests do) with a
    /// pool of `workers` connection handlers (plus
    /// [`DEFAULT_RUNNERS`] training-job runners).
    pub fn bind(addr: &str, workers: usize) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding planning server on {addr}"))?;
        let stats = Arc::new(ServerStats::new());
        // Durable jobs: journal under APDRL_JOB_DIR (when set) and
        // replay whatever a previous — possibly SIGKILLed — process
        // left there before accepting new work.
        let scheduler =
            Scheduler::with_journal(DEFAULT_MAX_QUEUE, Arc::clone(&stats), Journal::from_env());
        let recovered = scheduler.recover();
        if recovered > 0 {
            eprintln!("recovered {recovered} job(s) from the journal");
        }
        Ok(Server {
            listener,
            workers: workers.max(1),
            scheduler: Arc::new(scheduler),
            stats,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The actual bound address (resolves ephemeral ports).
    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Shared handle to this daemon's counters (tests, embedders).
    pub fn stats(&self) -> Arc<ServerStats> {
        Arc::clone(&self.stats)
    }

    /// Run until a `shutdown` request arrives.  Blocks the calling
    /// thread; spawn it if you need to keep going (tests, the
    /// `remote_sweep` example).
    pub fn run(self) -> Result<()> {
        let Server { listener, workers, stats, shutdown, scheduler } = self;
        // Nonblocking accept, polled against the shutdown flag: no
        // blocked `accept()` to wake, so shutdown needs no self-connect
        // trick and cannot be lost to a failed wake-up.
        listener.set_nonblocking(true)?;
        let (tx, rx) = mpsc::channel::<Conn>();
        let rx = Mutex::new(rx);
        std::thread::scope(|s| {
            for _ in 0..DEFAULT_RUNNERS {
                let (scheduler, shutdown) = (&scheduler, &shutdown);
                s.spawn(move || scheduler.run_runner(shutdown));
            }
            for _ in 0..workers {
                let tx = tx.clone();
                let (rx, stats, shutdown, scheduler) = (&rx, &stats, &shutdown, &scheduler);
                s.spawn(move || loop {
                    if shutdown.load(Ordering::SeqCst) {
                        break;
                    }
                    // Hold the lock only for the dequeue; the timeout
                    // bounds it so the flag is re-checked regularly.
                    let next = rx.lock().unwrap().recv_timeout(READ_POLL);
                    let mut conn = match next {
                        Ok(conn) => conn,
                        Err(mpsc::RecvTimeoutError::Timeout) => continue,
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    };
                    stats.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    match service_one(&mut conn, stats, scheduler) {
                        Disposition::Requeue => {
                            stats.queue_depth.fetch_add(1, Ordering::Relaxed);
                            // A send error means the server is tearing
                            // down; the connection just closes.
                            let _ = tx.send(conn);
                        }
                        Disposition::Close => {}
                        Disposition::Shutdown => {
                            shutdown.store(true, Ordering::SeqCst);
                        }
                    }
                });
            }
            while !shutdown.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _peer)) => {
                        let Some(conn) = Conn::accept(stream) else { continue };
                        stats.connections.fetch_add(1, Ordering::Relaxed);
                        stats.queue_depth.fetch_add(1, Ordering::Relaxed);
                        if tx.send(conn).is_err() {
                            break;
                        }
                    }
                    // No pending connection (or a transient error):
                    // sleep one quantum and re-check the flag.
                    Err(_) => std::thread::sleep(READ_POLL),
                }
            }
            drop(tx); // workers also exit via the shutdown flag
        });
        Ok(())
    }
}

/// Convenience: bind + run in one call (what `apdrl serve` does).
pub fn serve(addr: &str, workers: usize) -> Result<()> {
    Server::bind(addr, workers)?.run()
}

/// One live client connection as it circulates through the worker pool.
struct Conn {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
    /// Partial request line carried across read polls (a slow writer's
    /// bytes arrive over several quanta; nothing is lost between them).
    pending: String,
    /// Last complete request, for the idle cutoff.
    last_activity: Instant,
}

impl Conn {
    fn accept(stream: TcpStream) -> Option<Conn> {
        // Some platforms let accepted sockets inherit the listener's
        // nonblocking mode; reads here must block (bounded by the
        // timeout below), so force it off.
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_nodelay(true);
        // Polling reads: see [`READ_POLL`].
        let _ = stream.set_read_timeout(Some(READ_POLL));
        let reader = BufReader::new(stream.try_clone().ok()?);
        Some(Conn {
            reader,
            writer: stream,
            pending: String::new(),
            last_activity: Instant::now(),
        })
    }
}

/// What to do with a connection after one service cycle.
enum Disposition {
    /// Still healthy: back into the queue for its next request.
    Requeue,
    /// EOF, I/O error, or idle past the cutoff: drop it.
    Close,
    /// It asked the daemon to stop (already acknowledged).
    Shutdown,
}

/// Serve at most one request from `conn`.  Errors are per-request: a
/// malformed line gets an error response and the connection lives on.
fn service_one(conn: &mut Conn, stats: &ServerStats, scheduler: &Scheduler) -> Disposition {
    match conn.reader.read_line(&mut conn.pending) {
        Ok(0) => Disposition::Close,
        Ok(_) => {
            // `read_line` returns only on '\n' or EOF, so this is a
            // complete request line.
            let line = std::mem::take(&mut conn.pending);
            conn.last_activity = Instant::now();
            if line.trim().is_empty() {
                return Disposition::Requeue;
            }
            stats.requests.fetch_add(1, Ordering::Relaxed);
            stats.in_flight.fetch_add(1, Ordering::Relaxed);
            let t0 = Instant::now();
            let parsed = Request::parse_line(&line);
            let verb = parsed.as_ref().map(Request::verb).unwrap_or("invalid");
            // Streaming verbs (sweeps with `stream:true`, every `train`)
            // write their own lines before the final response; every
            // other verb is one response line.
            let (response, stop) = match parsed {
                Ok(Request::Sweep { combos, batches, quantized, stream: true }) => {
                    stats.sweep_requests.fetch_add(1, Ordering::Relaxed);
                    let streamed = handle_sweep_streaming(
                        &mut conn.writer,
                        &combos,
                        &batches,
                        quantized,
                        stats,
                    );
                    let response = streamed.unwrap_or_else(|e| {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&format!("{e:#}"))
                    });
                    (response, false)
                }
                Ok(Request::Train {
                    combo,
                    seed,
                    actors,
                    max_env_steps,
                    max_episodes,
                    quantized,
                    priority,
                    checkpoint_every,
                    progress_every,
                    resume,
                    detach,
                    origin,
                }) => {
                    // The resume payload is opaque at the protocol layer;
                    // parse it here so a corrupt checkpoint is a
                    // synchronous error on the submitter's own line.
                    let parsed_resume = match resume {
                        None => Ok(None),
                        Some(v) => Checkpoint::from_json(&v).map(Some),
                    };
                    let streamed = parsed_resume.and_then(|resume| {
                        let spec = JobSpec {
                            combo,
                            seed,
                            actors,
                            limits: TrainLimits {
                                max_env_steps: max_env_steps as u64,
                                max_episodes,
                            },
                            quantized,
                            priority,
                            checkpoint_every,
                            progress_every,
                            resume,
                        };
                        let opts = SubmitOpts { origin, detached: detach };
                        if detach {
                            // Fire-and-forget: one ack line, no stream.
                            // Used by queue fail-over resubmissions and
                            // `train --detach`; frames are dropped and
                            // the journal keeps the durable state.
                            let (id, _frames) = scheduler.submit_opts(spec, opts)?;
                            let mut body = BTreeMap::new();
                            body.insert("job".to_string(), Json::Str(id));
                            body.insert("detached".to_string(), Json::Bool(true));
                            Ok(ok_response(body))
                        } else {
                            handle_train_streaming(&mut conn.writer, spec, opts, scheduler, stats)
                        }
                    });
                    let response = streamed.unwrap_or_else(|e| {
                        stats.errors.fetch_add(1, Ordering::Relaxed);
                        error_response(&format!("{e:#}"))
                    });
                    (response, false)
                }
                other => respond(other, stats, scheduler),
            };
            stats.in_flight.fetch_sub(1, Ordering::Relaxed);
            let wall_us = t0.elapsed().as_micros() as u64;
            stats.record_latency(verb, wall_us);
            if obs::active() {
                let ok = response.get("ok").and_then(Json::as_bool).unwrap_or(false);
                obs::publish(
                    obs::Event::new("serve.request")
                        .tag("verb", verb)
                        .flag("ok", ok)
                        .num("wall_us", wall_us as f64),
                );
            }
            let wire = response.to_line().unwrap_or_else(|e| {
                // Unreachable for well-formed plans (latencies are
                // finite by construction), but the daemon must never
                // crash or emit garbage framing over a degenerate value.
                stats.errors.fetch_add(1, Ordering::Relaxed);
                error_response(&format!("internal serialization error: {e}")).to_string()
            });
            let sent = conn
                .writer
                .write_all(wire.as_bytes())
                .and_then(|_| conn.writer.write_all(b"\n"))
                .and_then(|_| conn.writer.flush());
            match (sent, stop) {
                (Err(_), _) => Disposition::Close,
                (Ok(()), true) => Disposition::Shutdown,
                (Ok(()), false) => Disposition::Requeue,
            }
        }
        // Poll expired with no (complete) line: any bytes consumed so
        // far stay in `pending`; requeue unless the peer has been
        // silent past the idle cutoff.
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::Interrupted
            ) =>
        {
            if conn.last_activity.elapsed() > IDLE_TIMEOUT {
                Disposition::Close
            } else {
                Disposition::Requeue
            }
        }
        Err(_) => Disposition::Close,
    }
}

/// Dispatch one parsed request → (response, shutdown?).  Streaming
/// sweeps and `train` never get here — `service_one` intercepts them
/// because they need the connection's writer mid-request.
fn respond(parsed: Result<Request>, stats: &ServerStats, scheduler: &Scheduler) -> (Json, bool) {
    let req = match parsed {
        Ok(req) => req,
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            return (error_response(&format!("{e:#}")), false);
        }
    };
    let result = match req {
        Request::Plan { combo, batch, quantized } => {
            stats.plan_requests.fetch_add(1, Ordering::Relaxed);
            handle_plan(&combo, batch, quantized, stats)
        }
        Request::Sweep { combos, batches, quantized, stream: _ } => {
            stats.sweep_requests.fetch_add(1, Ordering::Relaxed);
            handle_sweep(&combos, &batches, quantized, stats)
        }
        Request::Profile { combo, batch, quantized } => handle_profile(&combo, batch, quantized),
        Request::PlanMany { points } => {
            // Batched like a sweep for the telemetry (it is one).
            stats.sweep_requests.fetch_add(1, Ordering::Relaxed);
            handle_plan_many(&points, stats)
        }
        Request::Stats => {
            stats.stats_requests.fetch_add(1, Ordering::Relaxed);
            // Mirror the bus's own counters onto the bus so a dashboard
            // watching the stream sees drop pressure without polling.
            if crate::obs::active() {
                crate::obs::publish(crate::obs::global().stats_event());
            }
            // Graft the queued-job digest into the jobs section: this is
            // the gossip channel `RemoteTrainer` harvests so a host's
            // queue can fail over when the host later dies.
            let mut stats_json = stats.to_json();
            if let Json::Obj(map) = &mut stats_json {
                if let Some(Json::Obj(jobs)) = map.get_mut("jobs") {
                    jobs.insert("queued".to_string(), scheduler.queued_digest());
                }
            }
            let mut body = BTreeMap::new();
            body.insert("stats".to_string(), stats_json);
            Ok(ok_response(body))
        }
        Request::CacheFlush => {
            stats.flush_requests.fetch_add(1, Ordering::Relaxed);
            let flushed = {
                let mut guard = crate::partition::cache::global().lock().unwrap();
                let n = guard.len();
                guard.clear();
                n
            };
            let mut body = BTreeMap::new();
            body.insert("flushed".to_string(), Json::Num(flushed as f64));
            Ok(ok_response(body))
        }
        Request::Jobs => {
            stats.stats_requests.fetch_add(1, Ordering::Relaxed);
            let mut body = BTreeMap::new();
            body.insert("jobs".to_string(), scheduler.jobs_json());
            body.insert("queued".to_string(), scheduler.queued_digest());
            body.insert("draining".to_string(), Json::Bool(scheduler.draining()));
            Ok(ok_response(body))
        }
        Request::Cancel { job } => scheduler.cancel(&job).map(|phase| {
            let mut body = BTreeMap::new();
            body.insert("job".to_string(), Json::Str(job.clone()));
            body.insert("phase".to_string(), Json::Str(phase.to_string()));
            ok_response(body)
        }),
        Request::Train { .. } => {
            // Intercepted in `service_one` (it needs the connection's
            // writer); reaching here is a bug, answered not panicked.
            Err(anyhow!("train requests must be streamed"))
        }
        Request::Shutdown => {
            // Graceful drain before the ack: reject new jobs, cancel
            // queued ones, and stop running ones at their next round
            // boundary (their streams finish with a final checkpoint
            // frame and a `result` line before the workers exit).
            scheduler.drain();
            let mut body = BTreeMap::new();
            body.insert("stopping".to_string(), Json::Bool(true));
            return (ok_response(body), true);
        }
    };
    match result {
        Ok(response) => (response, false),
        Err(e) => {
            stats.errors.fetch_add(1, Ordering::Relaxed);
            (error_response(&format!("{e:#}")), false)
        }
    }
}

fn handle_plan(combo: &str, batch: usize, quantized: bool, stats: &ServerStats) -> Result<Json> {
    if batch == 0 {
        bail!("plan: batch must be ≥ 1");
    }
    let req = PlanRequest::named(combo)?.with_batch(batch).with_quantized(quantized);
    let t0 = Instant::now();
    let outcome = LocalPlanner.plan(&req)?;
    stats.record_request(
        1,
        outcome.cache_hit as u64,
        outcome.explored as u64,
        t0.elapsed().as_micros() as u64,
    );
    let mut body = BTreeMap::new();
    body.insert("plan".to_string(), plan_to_json(&outcome));
    Ok(ok_response(body))
}

/// Serve a batch of requests through the in-process backend and wrap the
/// outcomes as a `plans[]` response.  Shared by the `sweep` (grid) and
/// `plan_many` (point-list) verbs; `plan_sweep` underneath dedupes
/// repeated plan keys within the batch, so duplicate (combo, batch)
/// pairs in one request cost one profile+solve and come back as
/// memoized copies (`explored == 0`).
fn serve_batch(reqs: &[PlanRequest], stats: &ServerStats) -> Result<Json> {
    let t0 = Instant::now();
    let outcomes = LocalPlanner.plan_many(reqs)?;
    let wall = t0.elapsed().as_micros() as u64;
    let hits = outcomes.iter().filter(|o| o.cache_hit).count() as u64;
    let explored: u64 = outcomes.iter().map(|o| o.explored as u64).sum();
    stats.record_request(outcomes.len() as u64, hits, explored, wall);
    let plans: Vec<Json> = outcomes.iter().map(plan_to_json).collect();
    let mut body = BTreeMap::new();
    body.insert("plans".to_string(), Json::Arr(plans));
    Ok(ok_response(body))
}

fn handle_sweep(
    combos: &[String],
    batches: &[usize],
    quantized: bool,
    stats: &ServerStats,
) -> Result<Json> {
    let reqs = PlanRequest::named_grid(combos, batches, quantized)?;
    serve_batch(&reqs, stats)
}

/// The `sweep` verb with `"stream":true`: one `progress` line per
/// completed grid point (completion order), then the usual `plans[]`
/// response as the final line.  Mid-stream write failures are swallowed
/// — the sweep finishes for the shared cache's sake, and the final
/// write in `service_one` fails the same way and closes the connection.
fn handle_sweep_streaming(
    writer: &mut TcpStream,
    combos: &[String],
    batches: &[usize],
    quantized: bool,
    stats: &ServerStats,
) -> Result<Json> {
    let reqs = PlanRequest::named_grid(combos, batches, quantized)?;
    let t0 = Instant::now();
    let sink = Mutex::new(&mut *writer);
    let plans = plan_sweep_progress(&reqs, &|point| {
        if let Ok(line) = progress_response(point).to_line() {
            let mut w = sink.lock().unwrap();
            let _ = w
                .write_all(line.as_bytes())
                .and_then(|_| w.write_all(b"\n"))
                .and_then(|_| w.flush());
        }
    });
    let wall = t0.elapsed().as_micros() as u64;
    let outcomes: Vec<PlanOutcome> =
        plans.iter().zip(&reqs).map(|(p, r)| PlanOutcome::from_static(p, r)).collect();
    let hits = outcomes.iter().filter(|o| o.cache_hit).count() as u64;
    let explored: u64 = outcomes.iter().map(|o| o.explored as u64).sum();
    stats.record_request(outcomes.len() as u64, hits, explored, wall);
    let wire_plans: Vec<Json> = outcomes.iter().map(plan_to_json).collect();
    let mut body = BTreeMap::new();
    body.insert("plans".to_string(), Json::Arr(wire_plans));
    Ok(ok_response(body))
}

/// The `train` verb: submit the job to the scheduler, then stream every
/// frame the runner emits as its own response line, ending with the
/// `result` final once the job reaches a terminal phase.  A submit
/// rejection (unknown combo, bad resume checkpoint, full queue,
/// draining daemon) surfaces as the one and only response line.  A
/// mid-stream write failure cancels the job — the client is gone, so
/// training on is wasted work — and keeps draining the queue so the
/// runner is never left feeding a dead stream.
fn handle_train_streaming(
    writer: &mut TcpStream,
    spec: JobSpec,
    opts: SubmitOpts,
    scheduler: &Scheduler,
    stats: &ServerStats,
) -> Result<Json> {
    let (id, frames) = scheduler.submit_opts(spec, opts)?;
    let mut client_gone = false;
    while let Some(frame) = frames.next() {
        if client_gone {
            continue;
        }
        // Checkpoint frames double as the gossip channel: each carries
        // the host's queued-job digest (computed at write time) so a
        // streaming client continuously knows what would be stranded if
        // this host died.  Once a drain begins the digest is omitted —
        // the queue was just cancelled *because the daemon is going
        // away*, and clients must keep their pre-drain snapshot to
        // rescue those jobs.  (Digest before the flag check: a drain
        // racing in between yields a skipped pre-drain digest, never an
        // attached post-drain one.)
        let frame = match frame {
            Json::Obj(mut map)
                if map.get("frame").and_then(Json::as_str) == Some("checkpoint") =>
            {
                let digest = scheduler.queued_digest();
                if !scheduler.draining() {
                    map.insert("queued".to_string(), digest);
                }
                Json::Obj(map)
            }
            other => other,
        };
        if let Ok(line) = frame_response(&frame).to_line() {
            let sent = writer
                .write_all(line.as_bytes())
                .and_then(|_| writer.write_all(b"\n"))
                .and_then(|_| writer.flush());
            if sent.is_err() {
                client_gone = true;
                stats.errors.fetch_add(1, Ordering::Relaxed);
                let _ = scheduler.cancel(&id);
            }
        }
    }
    let mut body = BTreeMap::new();
    body.insert("result".to_string(), scheduler.final_result(&id));
    Ok(ok_response(body))
}

fn handle_profile(combo: &str, batch: usize, quantized: bool) -> Result<Json> {
    let mut body = BTreeMap::new();
    body.insert("profile".to_string(), profile_payload(combo, batch, quantized)?);
    Ok(ok_response(body))
}

fn handle_plan_many(points: &[WirePoint], stats: &ServerStats) -> Result<Json> {
    let reqs: Vec<PlanRequest> = points
        .iter()
        .map(|p| {
            Ok(PlanRequest::named(&p.combo)?
                .with_batch(p.batch)
                .with_quantized(p.quantized))
        })
        .collect::<Result<_>>()?;
    serve_batch(&reqs, stats)
}
