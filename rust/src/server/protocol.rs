//! Versioned JSON-lines wire protocol of the planning server.
//!
//! Every request and every response is exactly one JSON object on one
//! line (`\n`-terminated), serialized with the strict
//! [`Json::to_line`] writer (non-finite numbers are a hard error, never
//! a silent `null`).  Requests carry the protocol version in `v`; a
//! mismatch is rejected before the verb is looked at, so old clients get
//! a diagnostic instead of a misparse.
//!
//! Verbs (see `lib.rs` for a worked example of each line):
//!
//! | verb          | request fields                         | response payload |
//! |---------------|----------------------------------------|------------------|
//! | `plan`        | `combo`, `batch`, `quantized`          | `plan`           |
//! | `sweep`       | `combos[]`, `batches[]`, `quantized`   | `plans[]`        |
//! | `stats`       | —                                      | `stats`          |
//! | `cache_flush` | —                                      | `flushed`        |
//! | `shutdown`    | —                                      | `stopping`       |
//!
//! Responses are `{"v":1,"ok":true,...payload}` or
//! `{"v":1,"ok":false,"error":"..."}`.  The plan payload carries the
//! full schedule with raw `f64` start/finish times; the serializer's
//! shortest-round-trip formatting makes the remote schedule
//! *bit-identical* to the in-process one (asserted in
//! `tests/server.rs`).

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::StaticPlan;
use crate::hw::Component;
use crate::util::json::Json;

/// Bump on any incompatible change to the request or response shapes.
pub const PROTOCOL_VERSION: u64 = 1;

/// One parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Plan { combo: String, batch: usize, quantized: bool },
    Sweep { combos: Vec<String>, batches: Vec<usize>, quantized: bool },
    Stats,
    CacheFlush,
    Shutdown,
}

/// Strict integer read: `Json::as_usize` truncates fractions and
/// saturates negatives, which would let a buggy peer's `"batch":63.7`
/// silently plan batch 63.  The wire accepts exact non-negative
/// integers only.
fn exact_usize(v: &Json) -> Option<usize> {
    let n = v.as_f64()?;
    (n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64).then_some(n as usize)
}

impl Request {
    /// Parse one wire line.  Version is checked before the verb so a
    /// future client talking to an old server fails loudly.
    pub fn parse_line(line: &str) -> Result<Request> {
        let root = Json::parse(line.trim())
            .map_err(|e| anyhow!("bad request: {e}"))?;
        let v = root
            .get("v")
            .and_then(exact_usize)
            .ok_or_else(|| anyhow!("bad request: missing protocol version field `v`"))?;
        if v as u64 != PROTOCOL_VERSION {
            bail!(
                "protocol version mismatch: peer speaks v{v}, server speaks v{PROTOCOL_VERSION}"
            );
        }
        let verb = root
            .get("verb")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("bad request: missing `verb`"))?;
        match verb {
            "plan" => {
                let combo = root
                    .get("combo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("plan: missing `combo`"))?
                    .to_string();
                let batch = root
                    .get("batch")
                    .and_then(exact_usize)
                    .ok_or_else(|| anyhow!("plan: missing or non-integer `batch`"))?;
                let quantized =
                    root.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                Ok(Request::Plan { combo, batch, quantized })
            }
            "sweep" => {
                let combos = root
                    .get("combos")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sweep: missing `combos`"))?
                    .iter()
                    .map(|c| {
                        c.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow!("sweep: `combos` must be strings"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let batches = root
                    .get("batches")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sweep: missing `batches`"))?
                    .iter()
                    .map(|b| {
                        exact_usize(b)
                            .filter(|&n| n > 0)
                            .ok_or_else(|| anyhow!("sweep: `batches` must be positive integers"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                if combos.is_empty() || batches.is_empty() {
                    bail!("sweep: empty grid");
                }
                let quantized =
                    root.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                Ok(Request::Sweep { combos, batches, quantized })
            }
            "stats" => Ok(Request::Stats),
            "cache_flush" => Ok(Request::CacheFlush),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown verb {other:?}"),
        }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> Result<String> {
        let mut obj = BTreeMap::new();
        obj.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
        match self {
            Request::Plan { combo, batch, quantized } => {
                obj.insert("verb".into(), Json::Str("plan".into()));
                obj.insert("combo".into(), Json::Str(combo.clone()));
                obj.insert("batch".into(), Json::Num(*batch as f64));
                obj.insert("quantized".into(), Json::Bool(*quantized));
            }
            Request::Sweep { combos, batches, quantized } => {
                obj.insert("verb".into(), Json::Str("sweep".into()));
                obj.insert(
                    "combos".into(),
                    Json::Arr(combos.iter().map(|c| Json::Str(c.clone())).collect()),
                );
                obj.insert(
                    "batches".into(),
                    Json::Arr(batches.iter().map(|&b| Json::Num(b as f64)).collect()),
                );
                obj.insert("quantized".into(), Json::Bool(*quantized));
            }
            Request::Stats => {
                obj.insert("verb".into(), Json::Str("stats".into()));
            }
            Request::CacheFlush => {
                obj.insert("verb".into(), Json::Str("cache_flush".into()));
            }
            Request::Shutdown => {
                obj.insert("verb".into(), Json::Str("shutdown".into()));
            }
        }
        Ok(Json::Obj(obj).to_line()?)
    }
}

/// `{"v":1,"ok":true}` extended with the payload fields of `body`.
pub fn ok_response(body: BTreeMap<String, Json>) -> Json {
    let mut obj = body;
    obj.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    obj.insert("ok".to_string(), Json::Bool(true));
    Json::Obj(obj)
}

/// `{"v":1,"ok":false,"error":"..."}`.
pub fn error_response(msg: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    obj.insert("ok".to_string(), Json::Bool(false));
    obj.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj)
}

/// Client side: parse a response line, turning `ok:false` into an error
/// carrying the server's message.
pub fn parse_response(line: &str) -> Result<Json> {
    let root =
        Json::parse(line.trim()).map_err(|e| anyhow!("bad response from server: {e}"))?;
    match root.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(root),
        Some(false) => {
            let msg = root
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unspecified server error");
            bail!("server error: {msg}")
        }
        None => bail!("bad response from server: missing `ok` field"),
    }
}

/// One scheduled node as shipped over the wire (mirrors
/// `partition::schedule::ScheduleEntry` plus display metadata).
#[derive(Clone, Debug, PartialEq)]
pub struct RemoteScheduleEntry {
    pub node: usize,
    pub name: String,
    pub component: String,
    pub format: String,
    pub start_us: f64,
    pub finish_us: f64,
}

/// The planning result a remote client receives: everything the CLI,
/// the benches and the figure harness read off a local
/// [`StaticPlan`], minus the problem internals (dag/profiles stay
/// server-side).
#[derive(Clone, Debug, PartialEq)]
pub struct RemotePlan {
    pub combo: String,
    pub batch: usize,
    pub quantized: bool,
    pub makespan_us: f64,
    pub comm_us: f64,
    pub sync_us: f64,
    pub ps_pl_us: f64,
    pub interface: String,
    pub aie_mm_nodes: usize,
    pub mm_nodes: usize,
    pub explored: usize,
    pub cache_hit: bool,
    /// `(component name, candidate)` per DAG node.
    pub assignment: Vec<(String, usize)>,
    pub schedule: Vec<RemoteScheduleEntry>,
}

impl RemotePlan {
    /// Per-training-step time: mirrors `StaticPlan::step_time_us`.
    pub fn step_time_us(&self) -> f64 {
        self.makespan_us + self.ps_pl_us
    }

    /// Training throughput (batches/second): mirrors
    /// `StaticPlan::throughput`.
    pub fn throughput(&self) -> f64 {
        1e6 / self.step_time_us()
    }

    /// Parse the `plan` payload object.
    pub fn from_json(plan: &Json) -> Result<RemotePlan> {
        let field = |k: &str| plan.get(k).ok_or_else(|| anyhow!("plan payload missing `{k}`"));
        let str_field = |k: &str| -> Result<String> {
            Ok(field(k)?
                .as_str()
                .ok_or_else(|| anyhow!("plan payload `{k}` must be a string"))?
                .to_string())
        };
        let num_field = |k: &str| -> Result<f64> {
            field(k)?.as_f64().ok_or_else(|| anyhow!("plan payload `{k}` must be a number"))
        };
        // Counts ride the same strict-integer rule as request fields: a
        // truncated `batch: 63.7` from a skewed peer must be an error,
        // not a silently different plan.
        let usize_field = |k: &str| -> Result<usize> {
            field(k).and_then(|v| {
                exact_usize(v)
                    .ok_or_else(|| anyhow!("plan payload `{k}` must be a non-negative integer"))
            })
        };
        let assignment = field("assignment")?
            .as_arr()
            .ok_or_else(|| anyhow!("plan payload `assignment` must be an array"))?
            .iter()
            .map(|pair| {
                let p = pair.as_arr().unwrap_or(&[]);
                match (p.first().and_then(Json::as_str), p.get(1).and_then(exact_usize)) {
                    // The name must be a real component, not just a string.
                    (Some(comp), Some(cand)) if Component::from_name(comp).is_some() => {
                        Ok((comp.to_string(), cand))
                    }
                    _ => Err(anyhow!("plan payload: malformed assignment pair")),
                }
            })
            .collect::<Result<Vec<_>>>()?;
        let schedule = field("schedule")?
            .as_arr()
            .ok_or_else(|| anyhow!("plan payload `schedule` must be an array"))?
            .iter()
            .map(|e| {
                let get_num = |k: &str| -> Result<f64> {
                    e.get(k)
                        .and_then(Json::as_f64)
                        .ok_or_else(|| anyhow!("schedule entry missing `{k}`"))
                };
                let get_str = |k: &str| -> Result<String> {
                    Ok(e.get(k)
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("schedule entry missing `{k}`"))?
                        .to_string())
                };
                Ok(RemoteScheduleEntry {
                    node: e
                        .get("node")
                        .and_then(exact_usize)
                        .ok_or_else(|| anyhow!("schedule entry missing `node`"))?,
                    name: get_str("name")?,
                    component: get_str("unit")?,
                    format: get_str("fmt")?,
                    start_us: get_num("start_us")?,
                    finish_us: get_num("finish_us")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(RemotePlan {
            combo: str_field("combo")?,
            batch: usize_field("batch")?,
            quantized: field("quantized")?
                .as_bool()
                .ok_or_else(|| anyhow!("plan payload `quantized` must be a bool"))?,
            makespan_us: num_field("makespan_us")?,
            comm_us: num_field("comm_us")?,
            sync_us: num_field("sync_us")?,
            ps_pl_us: num_field("ps_pl_us")?,
            interface: str_field("interface")?,
            aie_mm_nodes: usize_field("aie_mm_nodes")?,
            mm_nodes: usize_field("mm_nodes")?,
            explored: usize_field("explored")?,
            cache_hit: field("cache_hit")?
                .as_bool()
                .ok_or_else(|| anyhow!("plan payload `cache_hit` must be a bool"))?,
            assignment,
            schedule,
        })
    }
}

/// Serialize a solved [`StaticPlan`] into the wire `plan` payload.
pub fn plan_to_json(plan: &StaticPlan, combo: &str, batch: usize, quantized: bool) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("combo".to_string(), Json::Str(combo.to_string()));
    obj.insert("batch".to_string(), Json::Num(batch as f64));
    obj.insert("quantized".to_string(), Json::Bool(quantized));
    obj.insert("makespan_us".to_string(), Json::Num(plan.schedule.makespan_us));
    obj.insert("comm_us".to_string(), Json::Num(plan.schedule.comm_us));
    obj.insert("sync_us".to_string(), Json::Num(plan.schedule.sync_us));
    obj.insert("ps_pl_us".to_string(), Json::Num(plan.ps_pl_us));
    obj.insert("interface".to_string(), Json::Str(plan.interface.name().to_string()));
    obj.insert(
        "aie_mm_nodes".to_string(),
        Json::Num(plan.solution.aie_nodes(&plan.dag) as f64),
    );
    obj.insert("mm_nodes".to_string(), Json::Num(plan.dag.mm_nodes().len() as f64));
    obj.insert("explored".to_string(), Json::Num(plan.solution.explored as f64));
    obj.insert("cache_hit".to_string(), Json::Bool(plan.cache_hit));
    obj.insert(
        "assignment".to_string(),
        Json::Arr(
            plan.solution
                .assignment
                .iter()
                .map(|p| {
                    Json::Arr(vec![
                        Json::Str(p.component.name().to_string()),
                        Json::Num(p.candidate as f64),
                    ])
                })
                .collect(),
        ),
    );
    obj.insert(
        "schedule".to_string(),
        Json::Arr(
            plan.schedule
                .entries
                .iter()
                .map(|e| {
                    let mut entry = BTreeMap::new();
                    entry.insert("node".to_string(), Json::Num(e.node as f64));
                    entry.insert(
                        "name".to_string(),
                        Json::Str(plan.dag.nodes[e.node].name.clone()),
                    );
                    entry.insert("unit".to_string(), Json::Str(e.component.name().to_string()));
                    entry.insert(
                        "fmt".to_string(),
                        Json::Str(plan.policy.node_format[e.node].name().to_string()),
                    );
                    entry.insert("start_us".to_string(), Json::Num(e.start_us));
                    entry.insert("finish_us".to_string(), Json::Num(e.finish_us));
                    Json::Obj(entry)
                })
                .collect(),
        ),
    );
    Json::Obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requests_round_trip_the_wire() {
        let reqs = [
            Request::Plan { combo: "dqn_cartpole".into(), batch: 64, quantized: true },
            Request::Sweep {
                combos: vec!["a2c_invpend".into(), "ddpg_lunar".into()],
                batches: vec![64, 256],
                quantized: false,
            },
            Request::Stats,
            Request::CacheFlush,
            Request::Shutdown,
        ];
        for req in reqs {
            let line = req.to_line().unwrap();
            assert!(!line.contains('\n'), "wire lines must be one line");
            assert_eq!(Request::parse_line(&line).unwrap(), req);
        }
    }

    #[test]
    fn version_mismatch_is_rejected_before_the_verb() {
        let e = Request::parse_line(r#"{"v":99,"verb":"stats"}"#).unwrap_err();
        assert!(format!("{e}").contains("protocol version mismatch"), "{e}");
        let e = Request::parse_line(r#"{"verb":"stats"}"#).unwrap_err();
        assert!(format!("{e}").contains("missing protocol version"), "{e}");
    }

    #[test]
    fn wire_integers_must_be_exact() {
        // A fractional version or batch must be rejected, not truncated
        // into a request the peer never made.
        for bad in [
            r#"{"v":1.9,"verb":"stats"}"#,
            r#"{"v":-1,"verb":"stats"}"#,
            r#"{"v":1,"verb":"plan","combo":"dqn_cartpole","batch":63.7}"#,
            r#"{"v":1,"verb":"plan","combo":"dqn_cartpole","batch":-8}"#,
            r#"{"v":1,"verb":"sweep","combos":["dqn_cartpole"],"batches":[64.5]}"#,
        ] {
            assert!(Request::parse_line(bad).is_err(), "{bad} must not parse");
        }
        // Integral floats (JSON has no int type) are of course fine.
        assert!(Request::parse_line(r#"{"v":1.0,"verb":"stats"}"#).is_ok());
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        assert!(Request::parse_line("not json").is_err());
        let e = Request::parse_line(r#"{"v":1,"verb":"fly"}"#).unwrap_err();
        assert!(format!("{e}").contains("unknown verb"), "{e}");
        let e = Request::parse_line(r#"{"v":1,"verb":"plan","batch":64}"#).unwrap_err();
        assert!(format!("{e}").contains("missing `combo`"), "{e}");
        let e = Request::parse_line(r#"{"v":1,"verb":"sweep","combos":[],"batches":[]}"#)
            .unwrap_err();
        assert!(format!("{e}").contains("missing") || format!("{e}").contains("empty"), "{e}");
    }

    #[test]
    fn responses_carry_ok_and_errors() {
        let ok = ok_response(BTreeMap::new()).to_line().unwrap();
        assert!(parse_response(&ok).is_ok());
        let err = error_response("boom").to_line().unwrap();
        let e = parse_response(&err).unwrap_err();
        assert!(format!("{e}").contains("boom"), "{e}");
    }

    #[test]
    fn plan_payload_round_trips_bit_identically() {
        let c = crate::coordinator::combo("dqn_cartpole");
        let plan = crate::coordinator::static_phase(&c, 24, true);
        let wire = plan_to_json(&plan, c.name, 24, true).to_line().unwrap();
        let remote = RemotePlan::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(remote.makespan_us.to_bits(), plan.schedule.makespan_us.to_bits());
        assert_eq!(remote.schedule.len(), plan.schedule.entries.len());
        for (r, l) in remote.schedule.iter().zip(&plan.schedule.entries) {
            assert_eq!(r.node, l.node);
            assert_eq!(r.component, l.component.name());
            assert_eq!(r.start_us.to_bits(), l.start_us.to_bits());
            assert_eq!(r.finish_us.to_bits(), l.finish_us.to_bits());
        }
        assert_eq!(remote.assignment.len(), plan.solution.assignment.len());
        assert_eq!(remote.step_time_us().to_bits(), plan.step_time_us().to_bits());
    }
}
