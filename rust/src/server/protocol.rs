//! Versioned JSON-lines wire protocol of the planning server.
//!
//! Every request and every response is exactly one JSON object on one
//! line (`\n`-terminated), serialized with the strict
//! [`Json::to_line`] writer (non-finite numbers are a hard error, never
//! a silent `null`).  Requests carry the protocol version in `v`; a
//! mismatch is rejected before the verb is looked at, so old clients get
//! a diagnostic instead of a misparse.
//!
//! Verbs (see `lib.rs` for a worked example of each line):
//!
//! | verb          | request fields                         | response payload |
//! |---------------|----------------------------------------|------------------|
//! | `plan`        | `combo`, `batch`, `quantized`          | `plan`           |
//! | `sweep`       | `combos[]`, `batches[]`, `quantized`, optional `stream` | `plans[]` (after `progress` lines when streaming) |
//! | `plan_many`   | `points[]` of `{combo,batch,quantized}`| `plans[]`        |
//! | `profile`     | `combo`, `batch`, `quantized`          | `profile`        |
//! | `stats`       | —                                      | `stats`          |
//! | `cache_flush` | —                                      | `flushed`        |
//! | `shutdown`    | —                                      | `stopping`       |
//! | `train`       | `combo`, optional `seed`/`actors`/`max_env_steps`/`max_episodes`/`quantized`/`priority`/`checkpoint_every`/`progress_every`/`resume` | streamed `frame` lines, then `result` |
//! | `jobs`        | —                                      | `jobs[]`, `draining` |
//! | `cancel`      | `job`                                  | `job`, `phase`   |
//!
//! `sweep` is the cross-product grid form; `plan_many` carries an
//! arbitrary point list — it is how `Planner::plan_many` travels the
//! wire.  v2 added `plan_many` and the required `mm` flag on schedule
//! entries; the flag changed the *response* shape, so the version was
//! bumped and a new client talking to a v1 daemon gets a clean
//! version-mismatch error instead of a missing-field parse failure.
//!
//! Two later additions stayed within v2 because they are strictly
//! additive: `"stream":true` on `sweep` asks the daemon to write one
//! `{"v":3,"ok":true,"progress":{…}}` line per completed grid point
//! before the final `plans` line (an old daemon ignores the flag and
//! sends the final line only — a streaming client must treat the first
//! line *without* a `progress` key as the final response); and the
//! `profile` verb exposes the DSE candidate table (per-node PL/AIE
//! latency, resource and kLUT candidates plus the PS reference) that
//! [`profile_payload`] builds — an old daemon answers it with its
//! normal unknown-verb error.
//!
//! v3 adds training-as-a-service.  `train` submits a job to the
//! daemon's scheduler and holds the connection open while the runner
//! streams the trainer's frames hoisted into the response envelope via
//! [`frame_response`] —
//! `{"v":3,"ok":true,"frame":"episode"|"scale"|"progress"|"checkpoint",…}`
//! — until the final line, which carries `result` instead of `frame`
//! (that key is how clients tell the two apart).  `checkpoint` frames
//! embed a full [`Checkpoint`] under `data`, which is also what the
//! optional `resume` request field carries back on re-submission after
//! a host death.  `jobs` lists the scheduler's queue and `cancel`
//! flips a job's cancel flag.  The version was bumped (rather than
//! staying additive like streaming sweeps) because a `train` client
//! must *know* the daemon schedules jobs: a v2 daemon would accept the
//! connection, then answer with unknown-verb after the client already
//! committed to streaming, and a half-understood `resume` checkpoint
//! would silently restart training from scratch.
//!
//! Responses are `{"v":3,"ok":true,...payload}` or
//! `{"v":3,"ok":false,"error":"..."}`.  The plan payload is the
//! serialized form of [`PlanOutcome`] minus provenance (the *receiving*
//! side knows which backend it asked) and carries the full schedule with
//! raw `f64` start/finish times; the serializer's
//! shortest-round-trip formatting makes the remote schedule
//! *bit-identical* to the in-process one (asserted in
//! `tests/server.rs`).
//!
//! [`PlanOutcome`]: crate::coordinator::planner::PlanOutcome
//! [`Checkpoint`]: crate::coordinator::Checkpoint

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use crate::coordinator::planner::{PlanOutcome, PlanStep, Provenance};
use crate::hw::Component;
use crate::util::json::Json;

/// Bump on any incompatible change to the request or response shapes.
/// v2: `plan_many` verb; schedule entries carry a required `mm` flag.
/// v3: training-as-a-service — `train` (streamed `frame` lines before a
/// `result` final), `jobs`, and `cancel` verbs.
pub const PROTOCOL_VERSION: u64 = 3;

/// One point of a `plan_many` request as it travels the wire: combos go
/// by registry name (a customized `ComboConfig` cannot be expressed —
/// clients reject those before sending; see
/// `PlanRequest::is_registry_exact`).
#[derive(Clone, Debug, PartialEq)]
pub struct WirePoint {
    pub combo: String,
    pub batch: usize,
    pub quantized: bool,
}

/// One parsed client request.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Plan { combo: String, batch: usize, quantized: bool },
    Sweep { combos: Vec<String>, batches: Vec<usize>, quantized: bool, stream: bool },
    PlanMany { points: Vec<WirePoint> },
    Profile { combo: String, batch: usize, quantized: bool },
    Stats,
    CacheFlush,
    Shutdown,
    /// Submit a training job.  `resume` carries an opaque checkpoint
    /// object (validated by the scheduler at submit time, not here — the
    /// protocol layer does not depend on checkpoint internals).
    ///
    /// `detach` and `origin` are additive v3 fields (old daemons never
    /// see them — they are omitted when defaulted — and old clients
    /// never send them): `detach` asks for an immediate ack instead of
    /// a frame stream (the job runs headless), and `origin` tags a
    /// fail-over resubmission of a dead host's queued job so survivors
    /// can dedup it exactly-once.
    Train {
        combo: String,
        seed: u64,
        actors: usize,
        max_env_steps: usize,
        max_episodes: usize,
        quantized: bool,
        /// Scheduler priority: higher runs first among queued jobs.
        priority: i64,
        /// Emit a `checkpoint` frame every N env steps (0 = off).
        checkpoint_every: u64,
        /// Emit a `progress` frame every N env steps (0 = off).
        progress_every: u64,
        resume: Option<Json>,
        /// Submit-and-return: no frame streaming, the job runs headless.
        detach: bool,
        /// Fail-over idempotency key (`host/job-id` on the dead host).
        origin: Option<String>,
    },
    Jobs,
    Cancel { job: String },
}

/// Strict integer read: `Json::as_usize` truncates fractions and
/// saturates negatives, which would let a buggy peer's `"batch":63.7`
/// silently plan batch 63.  The wire accepts exact non-negative
/// integers only.
fn exact_usize(v: &Json) -> Option<usize> {
    let n = v.as_f64()?;
    (n >= 0.0 && n.fract() == 0.0 && n <= u32::MAX as f64).then_some(n as usize)
}

/// Strict wide read for seeds and step cadences: exact non-negative
/// integers up to 2^53 (the JSON-number exactness bound).
fn exact_u64(v: &Json) -> Option<u64> {
    let n = v.as_f64()?;
    (n >= 0.0 && n.fract() == 0.0 && n <= 9_007_199_254_740_992.0).then_some(n as u64)
}

/// Strict signed read for priorities.
fn exact_i64(v: &Json) -> Option<i64> {
    let n = v.as_f64()?;
    (n.fract() == 0.0 && n.abs() <= 9_007_199_254_740_992.0).then_some(n as i64)
}

impl Request {
    /// Parse one wire line.  Version is checked before the verb so a
    /// future client talking to an old server fails loudly.
    pub fn parse_line(line: &str) -> Result<Request> {
        let root = Json::parse(line.trim())
            .map_err(|e| anyhow!("bad request: {e}"))?;
        let v = root
            .get("v")
            .and_then(exact_usize)
            .ok_or_else(|| anyhow!("bad request: missing protocol version field `v`"))?;
        if v as u64 != PROTOCOL_VERSION {
            bail!(
                "protocol version mismatch: peer speaks v{v}, server speaks v{PROTOCOL_VERSION}"
            );
        }
        let verb = root
            .get("verb")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("bad request: missing `verb`"))?;
        match verb {
            "plan" => {
                let combo = root
                    .get("combo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("plan: missing `combo`"))?
                    .to_string();
                let batch = root
                    .get("batch")
                    .and_then(exact_usize)
                    .ok_or_else(|| anyhow!("plan: missing or non-integer `batch`"))?;
                let quantized =
                    root.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                Ok(Request::Plan { combo, batch, quantized })
            }
            "sweep" => {
                let combos = root
                    .get("combos")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sweep: missing `combos`"))?
                    .iter()
                    .map(|c| {
                        c.as_str()
                            .map(str::to_string)
                            .ok_or_else(|| anyhow!("sweep: `combos` must be strings"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                let batches = root
                    .get("batches")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("sweep: missing `batches`"))?
                    .iter()
                    .map(|b| {
                        exact_usize(b)
                            .filter(|&n| n > 0)
                            .ok_or_else(|| anyhow!("sweep: `batches` must be positive integers"))
                    })
                    .collect::<Result<Vec<_>>>()?;
                if combos.is_empty() || batches.is_empty() {
                    bail!("sweep: empty grid");
                }
                let quantized =
                    root.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                let stream = root.get("stream").and_then(Json::as_bool).unwrap_or(false);
                Ok(Request::Sweep { combos, batches, quantized, stream })
            }
            "profile" => {
                let combo = root
                    .get("combo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("profile: missing `combo`"))?
                    .to_string();
                let batch = root
                    .get("batch")
                    .and_then(exact_usize)
                    .filter(|&n| n > 0)
                    .ok_or_else(|| anyhow!("profile: `batch` must be a positive integer"))?;
                let quantized =
                    root.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                Ok(Request::Profile { combo, batch, quantized })
            }
            "plan_many" => {
                let points = root
                    .get("points")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("plan_many: missing `points`"))?
                    .iter()
                    .map(|p| {
                        let combo = p
                            .get("combo")
                            .and_then(Json::as_str)
                            .ok_or_else(|| anyhow!("plan_many: point missing `combo`"))?
                            .to_string();
                        let batch = p
                            .get("batch")
                            .and_then(exact_usize)
                            .filter(|&n| n > 0)
                            .ok_or_else(|| {
                                anyhow!("plan_many: point `batch` must be a positive integer")
                            })?;
                        let quantized =
                            p.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                        Ok(WirePoint { combo, batch, quantized })
                    })
                    .collect::<Result<Vec<_>>>()?;
                if points.is_empty() {
                    bail!("plan_many: empty points");
                }
                Ok(Request::PlanMany { points })
            }
            "train" => {
                let combo = root
                    .get("combo")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("train: missing `combo`"))?
                    .to_string();
                let opt_u64 = |k: &str, default: u64| match root.get(k) {
                    None => Ok(default),
                    Some(v) => exact_u64(v)
                        .ok_or_else(|| anyhow!("train: `{k}` must be a non-negative integer")),
                };
                let opt_pos = |k: &str, default: usize| match root.get(k) {
                    None => Ok(default),
                    Some(v) => exact_usize(v)
                        .filter(|&n| n > 0)
                        .ok_or_else(|| anyhow!("train: `{k}` must be a positive integer")),
                };
                let seed = opt_u64("seed", 1)?;
                let actors = opt_pos("actors", 1)?;
                let max_env_steps = opt_pos("max_env_steps", 8_000)?;
                let max_episodes = opt_pos("max_episodes", 300)?;
                let quantized =
                    root.get("quantized").and_then(Json::as_bool).unwrap_or(true);
                let priority = match root.get("priority") {
                    None => 0,
                    Some(v) => exact_i64(v)
                        .ok_or_else(|| anyhow!("train: `priority` must be an integer"))?,
                };
                let checkpoint_every = opt_u64("checkpoint_every", 0)?;
                let progress_every = opt_u64("progress_every", 0)?;
                let resume = match root.get("resume") {
                    None => None,
                    Some(v @ Json::Obj(_)) => Some(v.clone()),
                    Some(_) => bail!("train: `resume` must be a checkpoint object"),
                };
                let detach = root.get("detach").and_then(Json::as_bool).unwrap_or(false);
                let origin = match root.get("origin") {
                    None => None,
                    Some(Json::Str(s)) => Some(s.clone()),
                    Some(_) => bail!("train: `origin` must be a string"),
                };
                Ok(Request::Train {
                    combo,
                    seed,
                    actors,
                    max_env_steps,
                    max_episodes,
                    quantized,
                    priority,
                    checkpoint_every,
                    progress_every,
                    resume,
                    detach,
                    origin,
                })
            }
            "jobs" => Ok(Request::Jobs),
            "cancel" => {
                let job = root
                    .get("job")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("cancel: missing `job`"))?
                    .to_string();
                Ok(Request::Cancel { job })
            }
            "stats" => Ok(Request::Stats),
            "cache_flush" => Ok(Request::CacheFlush),
            "shutdown" => Ok(Request::Shutdown),
            other => bail!("unknown verb {other:?}"),
        }
    }

    /// Serialize to one wire line (no trailing newline).
    pub fn to_line(&self) -> Result<String> {
        let mut obj = BTreeMap::new();
        obj.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
        match self {
            Request::Plan { combo, batch, quantized } => {
                obj.insert("verb".into(), Json::Str("plan".into()));
                obj.insert("combo".into(), Json::Str(combo.clone()));
                obj.insert("batch".into(), Json::Num(*batch as f64));
                obj.insert("quantized".into(), Json::Bool(*quantized));
            }
            Request::Sweep { combos, batches, quantized, stream } => {
                obj.insert("verb".into(), Json::Str("sweep".into()));
                obj.insert(
                    "combos".into(),
                    Json::Arr(combos.iter().map(|c| Json::Str(c.clone())).collect()),
                );
                obj.insert(
                    "batches".into(),
                    Json::Arr(batches.iter().map(|&b| Json::Num(b as f64)).collect()),
                );
                obj.insert("quantized".into(), Json::Bool(*quantized));
                // Omitted when false so non-streaming lines are byte-
                // identical to what pre-streaming clients sent.
                if *stream {
                    obj.insert("stream".into(), Json::Bool(true));
                }
            }
            Request::PlanMany { points } => {
                obj.insert("verb".into(), Json::Str("plan_many".into()));
                obj.insert(
                    "points".into(),
                    Json::Arr(
                        points
                            .iter()
                            .map(|p| {
                                let mut point = BTreeMap::new();
                                point.insert("combo".to_string(), Json::Str(p.combo.clone()));
                                point.insert("batch".to_string(), Json::Num(p.batch as f64));
                                point.insert(
                                    "quantized".to_string(),
                                    Json::Bool(p.quantized),
                                );
                                Json::Obj(point)
                            })
                            .collect(),
                    ),
                );
            }
            Request::Profile { combo, batch, quantized } => {
                obj.insert("verb".into(), Json::Str("profile".into()));
                obj.insert("combo".into(), Json::Str(combo.clone()));
                obj.insert("batch".into(), Json::Num(*batch as f64));
                obj.insert("quantized".into(), Json::Bool(*quantized));
            }
            Request::Train {
                combo,
                seed,
                actors,
                max_env_steps,
                max_episodes,
                quantized,
                priority,
                checkpoint_every,
                progress_every,
                resume,
                detach,
                origin,
            } => {
                obj.insert("verb".into(), Json::Str("train".into()));
                obj.insert("combo".into(), Json::Str(combo.clone()));
                obj.insert("seed".into(), Json::Num(*seed as f64));
                obj.insert("actors".into(), Json::Num(*actors as f64));
                obj.insert("max_env_steps".into(), Json::Num(*max_env_steps as f64));
                obj.insert("max_episodes".into(), Json::Num(*max_episodes as f64));
                obj.insert("quantized".into(), Json::Bool(*quantized));
                obj.insert("priority".into(), Json::Num(*priority as f64));
                obj.insert("checkpoint_every".into(), Json::Num(*checkpoint_every as f64));
                obj.insert("progress_every".into(), Json::Num(*progress_every as f64));
                // Omitted when absent/defaulted: fresh attached
                // submissions are byte-identical to pre-durability
                // clients' lines, and a missing key is unambiguous.
                if let Some(ckpt) = resume {
                    obj.insert("resume".into(), ckpt.clone());
                }
                if *detach {
                    obj.insert("detach".into(), Json::Bool(true));
                }
                if let Some(origin) = origin {
                    obj.insert("origin".into(), Json::Str(origin.clone()));
                }
            }
            Request::Jobs => {
                obj.insert("verb".into(), Json::Str("jobs".into()));
            }
            Request::Cancel { job } => {
                obj.insert("verb".into(), Json::Str("cancel".into()));
                obj.insert("job".into(), Json::Str(job.clone()));
            }
            Request::Stats => {
                obj.insert("verb".into(), Json::Str("stats".into()));
            }
            Request::CacheFlush => {
                obj.insert("verb".into(), Json::Str("cache_flush".into()));
            }
            Request::Shutdown => {
                obj.insert("verb".into(), Json::Str("shutdown".into()));
            }
        }
        Ok(Json::Obj(obj).to_line()?)
    }

    /// The wire verb name — the key the daemon's per-verb latency
    /// reservoirs and `serve.request` events are tagged with.
    pub fn verb(&self) -> &'static str {
        match self {
            Request::Plan { .. } => "plan",
            Request::Sweep { .. } => "sweep",
            Request::PlanMany { .. } => "plan_many",
            Request::Profile { .. } => "profile",
            Request::Stats => "stats",
            Request::CacheFlush => "cache_flush",
            Request::Shutdown => "shutdown",
            Request::Train { .. } => "train",
            Request::Jobs => "jobs",
            Request::Cancel { .. } => "cancel",
        }
    }
}

/// `{"v":3,"ok":true}` extended with the payload fields of `body`.
pub fn ok_response(body: BTreeMap<String, Json>) -> Json {
    let mut obj = body;
    obj.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    obj.insert("ok".to_string(), Json::Bool(true));
    Json::Obj(obj)
}

/// One mid-stream line of a streaming sweep:
/// `{"v":3,"ok":true,"progress":{…}}`.  Clients distinguish these from
/// the final response by the presence of the `progress` key.
pub fn progress_response(point: &crate::coordinator::SweepPoint) -> Json {
    let mut p = BTreeMap::new();
    p.insert("index".to_string(), Json::Num(point.index as f64));
    p.insert("done".to_string(), Json::Num(point.done as f64));
    p.insert("total".to_string(), Json::Num(point.total as f64));
    p.insert("combo".to_string(), Json::Str(point.combo.clone()));
    p.insert("batch".to_string(), Json::Num(point.batch as f64));
    p.insert("quantized".to_string(), Json::Bool(point.quantized));
    p.insert("cache_hit".to_string(), Json::Bool(point.cache_hit));
    p.insert("explored".to_string(), Json::Num(point.explored as f64));
    p.insert("solve_us".to_string(), Json::Num(point.solve_us as f64));
    let mut obj = BTreeMap::new();
    obj.insert("progress".to_string(), Json::Obj(p));
    ok_response(obj)
}

/// One mid-stream line of a streaming `train` job: the trainer's frame
/// object (always a `Json::Obj` with a `frame` kind, a `job` id and the
/// kind-specific fields) hoisted into the response envelope —
/// `{"v":3,"ok":true,"frame":"episode",…}`.  Clients distinguish frames
/// from the final response by the presence of the `frame` key; the
/// final line carries `result` instead.
pub fn frame_response(frame: &Json) -> Json {
    let body = match frame {
        Json::Obj(map) => map.clone(),
        // Trainer frames are objects by construction; anything else
        // would be a bug, surfaced as a bare ok line rather than a hang.
        _ => BTreeMap::new(),
    };
    ok_response(body)
}

/// Build the `profile` verb's payload: run the DSE profiler for a
/// registry combo and serialize the full candidate table.  Shared by
/// the daemon and by `apdrl profile` running locally, so both sides of
/// the wire show the same shape.
pub fn profile_payload(combo: &str, batch: usize, quantized: bool) -> Result<Json> {
    let c = crate::coordinator::try_combo(combo)?;
    let platform = crate::hw::vek280();
    let spec = c.train_spec(batch);
    let dag = crate::graph::build_train_graph(&spec);
    let profiles = crate::profile::profile_dag(&dag, &platform, quantized);
    let candidates = |list: &[crate::profile::Candidate]| {
        Json::Arr(
            list.iter()
                .map(|cand| {
                    let mut obj = BTreeMap::new();
                    obj.insert("fmt".to_string(), Json::Str(cand.fmt.name().to_string()));
                    obj.insert("latency_us".to_string(), Json::Num(cand.latency_us));
                    obj.insert("resource".to_string(), Json::Num(cand.resource as f64));
                    obj.insert("kluts".to_string(), Json::Num(cand.kluts));
                    Json::Obj(obj)
                })
                .collect(),
        )
    };
    let nodes = Json::Arr(
        profiles
            .iter()
            .map(|p| {
                let mut obj = BTreeMap::new();
                obj.insert("node".to_string(), Json::Num(p.node as f64));
                obj.insert("name".to_string(), Json::Str(dag.nodes[p.node].name.clone()));
                obj.insert("ps_latency_us".to_string(), Json::Num(p.ps_latency_us));
                obj.insert("ps_modeled_us".to_string(), Json::Num(p.ps_modeled_us));
                obj.insert("ps_measured".to_string(), Json::Bool(p.ps_measured));
                obj.insert("pl".to_string(), candidates(&p.pl));
                obj.insert("aie".to_string(), candidates(&p.aie));
                Json::Obj(obj)
            })
            .collect(),
    );
    let mut profile = BTreeMap::new();
    profile.insert("combo".to_string(), Json::Str(c.name.to_string()));
    profile.insert("batch".to_string(), Json::Num(batch as f64));
    profile.insert("quantized".to_string(), Json::Bool(quantized));
    profile.insert(
        "platform".to_string(),
        Json::Str(crate::partition::platform_fingerprint(&platform)),
    );
    profile.insert("calibration".to_string(), crate::profile::calib::provenance_json());
    profile.insert("nodes".to_string(), nodes);
    Ok(Json::Obj(profile))
}

/// `{"v":3,"ok":false,"error":"..."}`.
pub fn error_response(msg: &str) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("v".to_string(), Json::Num(PROTOCOL_VERSION as f64));
    obj.insert("ok".to_string(), Json::Bool(false));
    obj.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj)
}

/// Client side: parse a response line, turning `ok:false` into an error
/// carrying the server's message.
pub fn parse_response(line: &str) -> Result<Json> {
    let root =
        Json::parse(line.trim()).map_err(|e| anyhow!("bad response from server: {e}"))?;
    match root.get("ok").and_then(Json::as_bool) {
        Some(true) => Ok(root),
        Some(false) => {
            let msg = root
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("unspecified server error");
            bail!("server error: {msg}")
        }
        None => bail!("bad response from server: missing `ok` field"),
    }
}

/// Serialize a [`PlanOutcome`] into the wire `plan` payload (the daemon
/// side; provenance is not shipped — the receiver tags results with its
/// own backend knowledge).
pub fn plan_to_json(outcome: &PlanOutcome) -> Json {
    let mut obj = BTreeMap::new();
    obj.insert("combo".to_string(), Json::Str(outcome.combo.clone()));
    obj.insert("batch".to_string(), Json::Num(outcome.batch as f64));
    obj.insert("quantized".to_string(), Json::Bool(outcome.quantized));
    obj.insert("makespan_us".to_string(), Json::Num(outcome.makespan_us));
    obj.insert("comm_us".to_string(), Json::Num(outcome.comm_us));
    obj.insert("sync_us".to_string(), Json::Num(outcome.sync_us));
    obj.insert("ps_pl_us".to_string(), Json::Num(outcome.ps_pl_us));
    obj.insert("interface".to_string(), Json::Str(outcome.interface.clone()));
    obj.insert("aie_mm_nodes".to_string(), Json::Num(outcome.aie_mm_nodes as f64));
    obj.insert("mm_nodes".to_string(), Json::Num(outcome.mm_nodes as f64));
    obj.insert("explored".to_string(), Json::Num(outcome.explored as f64));
    obj.insert("cache_hit".to_string(), Json::Bool(outcome.cache_hit));
    obj.insert("calib_steps".to_string(), Json::Num(outcome.calib_steps as f64));
    obj.insert("calib_err_pct".to_string(), Json::Num(outcome.calib_err_pct));
    obj.insert(
        "calib_fingerprint".to_string(),
        Json::Str(outcome.calib_fingerprint.clone()),
    );
    obj.insert(
        "assignment".to_string(),
        Json::Arr(
            outcome
                .assignment
                .iter()
                .map(|(comp, cand)| {
                    Json::Arr(vec![Json::Str(comp.clone()), Json::Num(*cand as f64)])
                })
                .collect(),
        ),
    );
    obj.insert(
        "schedule".to_string(),
        Json::Arr(
            outcome
                .schedule
                .iter()
                .map(|step| {
                    let mut entry = BTreeMap::new();
                    entry.insert("node".to_string(), Json::Num(step.node as f64));
                    entry.insert("name".to_string(), Json::Str(step.name.clone()));
                    entry.insert("unit".to_string(), Json::Str(step.component.clone()));
                    entry.insert("fmt".to_string(), Json::Str(step.format.clone()));
                    entry.insert("mm".to_string(), Json::Bool(step.mm));
                    entry.insert("start_us".to_string(), Json::Num(step.start_us));
                    entry.insert("finish_us".to_string(), Json::Num(step.finish_us));
                    entry.insert("cpu_us".to_string(), Json::Num(step.cpu_us));
                    entry.insert("modeled_us".to_string(), Json::Num(step.modeled_us));
                    entry.insert("measured".to_string(), Json::Bool(step.measured));
                    Json::Obj(entry)
                })
                .collect(),
        ),
    );
    Json::Obj(obj)
}

/// Parse the wire `plan` payload back into a [`PlanOutcome`], tagging it
/// with the caller-supplied provenance (the client knows which backend
/// it asked; the payload deliberately does not say).
pub fn plan_from_json(plan: &Json, provenance: Provenance) -> Result<PlanOutcome> {
    let field = |k: &str| plan.get(k).ok_or_else(|| anyhow!("plan payload missing `{k}`"));
    let str_field = |k: &str| -> Result<String> {
        Ok(field(k)?
            .as_str()
            .ok_or_else(|| anyhow!("plan payload `{k}` must be a string"))?
            .to_string())
    };
    let num_field = |k: &str| -> Result<f64> {
        field(k)?.as_f64().ok_or_else(|| anyhow!("plan payload `{k}` must be a number"))
    };
    let bool_field = |k: &str| -> Result<bool> {
        field(k)?.as_bool().ok_or_else(|| anyhow!("plan payload `{k}` must be a bool"))
    };
    // Counts ride the same strict-integer rule as request fields: a
    // truncated `batch: 63.7` from a skewed peer must be an error,
    // not a silently different plan.
    let usize_field = |k: &str| -> Result<usize> {
        field(k).and_then(|v| {
            exact_usize(v)
                .ok_or_else(|| anyhow!("plan payload `{k}` must be a non-negative integer"))
        })
    };
    let assignment = field("assignment")?
        .as_arr()
        .ok_or_else(|| anyhow!("plan payload `assignment` must be an array"))?
        .iter()
        .map(|pair| {
            let p = pair.as_arr().unwrap_or(&[]);
            match (p.first().and_then(Json::as_str), p.get(1).and_then(exact_usize)) {
                // The name must be a real component, not just a string.
                (Some(comp), Some(cand)) if Component::from_name(comp).is_some() => {
                    Ok((comp.to_string(), cand))
                }
                _ => Err(anyhow!("plan payload: malformed assignment pair")),
            }
        })
        .collect::<Result<Vec<_>>>()?;
    let schedule = field("schedule")?
        .as_arr()
        .ok_or_else(|| anyhow!("plan payload `schedule` must be an array"))?
        .iter()
        .map(|e| {
            let get_num = |k: &str| -> Result<f64> {
                e.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("schedule entry missing `{k}`"))
            };
            let get_str = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("schedule entry missing `{k}`"))?
                    .to_string())
            };
            // The calibration trio is optional for wire back-compat with
            // pre-calibration peers: fall back to the scheduled duration
            // and "not measured".
            let modeled_us = e
                .get("modeled_us")
                .and_then(Json::as_f64)
                .unwrap_or(get_num("finish_us")? - get_num("start_us")?);
            Ok(PlanStep {
                node: e
                    .get("node")
                    .and_then(exact_usize)
                    .ok_or_else(|| anyhow!("schedule entry missing `node`"))?,
                name: get_str("name")?,
                component: get_str("unit")?,
                format: get_str("fmt")?,
                mm: e
                    .get("mm")
                    .and_then(Json::as_bool)
                    .ok_or_else(|| anyhow!("schedule entry missing `mm`"))?,
                start_us: get_num("start_us")?,
                finish_us: get_num("finish_us")?,
                cpu_us: e.get("cpu_us").and_then(Json::as_f64).unwrap_or(modeled_us),
                modeled_us,
                measured: e.get("measured").and_then(Json::as_bool).unwrap_or(false),
            })
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(PlanOutcome {
        combo: str_field("combo")?,
        batch: usize_field("batch")?,
        quantized: bool_field("quantized")?,
        makespan_us: num_field("makespan_us")?,
        comm_us: num_field("comm_us")?,
        sync_us: num_field("sync_us")?,
        ps_pl_us: num_field("ps_pl_us")?,
        interface: str_field("interface")?,
        aie_mm_nodes: usize_field("aie_mm_nodes")?,
        mm_nodes: usize_field("mm_nodes")?,
        explored: usize_field("explored")?,
        cache_hit: bool_field("cache_hit")?,
        calib_steps: plan.get("calib_steps").and_then(exact_usize).unwrap_or(0),
        calib_err_pct: plan.get("calib_err_pct").and_then(Json::as_f64).unwrap_or(0.0),
        calib_fingerprint: plan
            .get("calib_fingerprint")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string(),
        assignment,
        schedule,
        provenance,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::planner::{LocalPlanner, PlanRequest, Planner};

    #[test]
    fn requests_round_trip_the_wire() {
        let reqs = [
            Request::Plan { combo: "dqn_cartpole".into(), batch: 64, quantized: true },
            Request::Sweep {
                combos: vec!["a2c_invpend".into(), "ddpg_lunar".into()],
                batches: vec![64, 256],
                quantized: false,
                stream: false,
            },
            Request::Sweep {
                combos: vec!["dqn_cartpole".into()],
                batches: vec![48],
                quantized: true,
                stream: true,
            },
            Request::Profile { combo: "ddpg_lunar".into(), batch: 128, quantized: true },
            Request::PlanMany {
                points: vec![
                    WirePoint { combo: "dqn_cartpole".into(), batch: 48, quantized: true },
                    WirePoint { combo: "ddpg_lunar".into(), batch: 256, quantized: false },
                ],
            },
            Request::Stats,
            Request::CacheFlush,
            Request::Shutdown,
            Request::Train {
                combo: "dqn_cartpole".into(),
                seed: 7,
                actors: 4,
                max_env_steps: 5_000,
                max_episodes: 120,
                quantized: false,
                priority: -3,
                checkpoint_every: 1_000,
                progress_every: 500,
                resume: Some(Json::obj(vec![("ckpt_version", Json::Num(1.0))])),
                detach: true,
                origin: Some("127.0.0.1:7040/job-2".into()),
            },
            Request::Jobs,
            Request::Cancel { job: "job-3".into() },
        ];
        for req in reqs {
            let line = req.to_line().unwrap();
            assert!(!line.contains('\n'), "wire lines must be one line");
            assert_eq!(Request::parse_line(&line).unwrap(), req);
        }
    }

    #[test]
    fn version_mismatch_is_rejected_before_the_verb() {
        let e = Request::parse_line(r#"{"v":99,"verb":"stats"}"#).unwrap_err();
        assert!(format!("{e}").contains("protocol version mismatch"), "{e}");
        let e = Request::parse_line(r#"{"verb":"stats"}"#).unwrap_err();
        assert!(format!("{e}").contains("missing protocol version"), "{e}");
    }

    #[test]
    fn wire_integers_must_be_exact() {
        // A fractional version or batch must be rejected, not truncated
        // into a request the peer never made.
        for bad in [
            r#"{"v":1.9,"verb":"stats"}"#,
            r#"{"v":-1,"verb":"stats"}"#,
            r#"{"v":3,"verb":"plan","combo":"dqn_cartpole","batch":63.7}"#,
            r#"{"v":3,"verb":"plan","combo":"dqn_cartpole","batch":-8}"#,
            r#"{"v":3,"verb":"sweep","combos":["dqn_cartpole"],"batches":[64.5]}"#,
            r#"{"v":3,"verb":"plan_many","points":[{"combo":"dqn_cartpole","batch":0}]}"#,
            r#"{"v":3,"verb":"plan_many","points":[{"combo":"dqn_cartpole","batch":8.5}]}"#,
        ] {
            assert!(Request::parse_line(bad).is_err(), "{bad} must not parse");
        }
        // Integral floats (JSON has no int type) are of course fine.
        assert!(Request::parse_line(r#"{"v":3.0,"verb":"stats"}"#).is_ok());
    }

    #[test]
    fn malformed_requests_error_cleanly() {
        assert!(Request::parse_line("not json").is_err());
        let e = Request::parse_line(r#"{"v":3,"verb":"fly"}"#).unwrap_err();
        assert!(format!("{e}").contains("unknown verb"), "{e}");
        let e = Request::parse_line(r#"{"v":3,"verb":"plan","batch":64}"#).unwrap_err();
        assert!(format!("{e}").contains("missing `combo`"), "{e}");
        let e = Request::parse_line(r#"{"v":3,"verb":"sweep","combos":[],"batches":[]}"#)
            .unwrap_err();
        assert!(format!("{e}").contains("missing") || format!("{e}").contains("empty"), "{e}");
        let e = Request::parse_line(r#"{"v":3,"verb":"plan_many","points":[]}"#).unwrap_err();
        assert!(format!("{e}").contains("empty points"), "{e}");
    }

    #[test]
    fn sweep_stream_flag_is_additive_and_profile_parses_strictly() {
        // A pre-streaming line (no `stream` key) parses as non-streaming,
        // and serializing it back omits the key — byte-compatible both ways.
        let legacy =
            r#"{"v":3,"verb":"sweep","combos":["dqn_cartpole"],"batches":[64],"quantized":true}"#;
        let req = Request::parse_line(legacy).unwrap();
        let Request::Sweep { stream, .. } = &req else { panic!("parsed as sweep") };
        assert!(!stream);
        assert!(!req.to_line().unwrap().contains("stream"));
        // Streaming form carries the flag.
        let line = Request::Sweep {
            combos: vec!["dqn_cartpole".into()],
            batches: vec![64],
            quantized: true,
            stream: true,
        }
        .to_line()
        .unwrap();
        assert!(line.contains("\"stream\":true"));
        // Profile rejects a zero batch like the other planning verbs.
        let e = Request::parse_line(r#"{"v":3,"verb":"profile","combo":"dqn_cartpole","batch":0}"#)
            .unwrap_err();
        assert!(format!("{e}").contains("positive integer"), "{e}");
        assert_eq!(
            Request::parse_line(r#"{"v":3,"verb":"profile","combo":"dqn_cartpole","batch":32}"#)
                .unwrap()
                .verb(),
            "profile"
        );
    }

    #[test]
    fn train_requests_default_sensibly_and_validate_strictly() {
        // A minimal submission gets the documented defaults.
        let min =
            Request::parse_line(r#"{"v":3,"verb":"train","combo":"dqn_cartpole"}"#).unwrap();
        let Request::Train {
            combo,
            seed,
            actors,
            max_env_steps,
            max_episodes,
            quantized,
            priority,
            checkpoint_every,
            progress_every,
            resume,
            detach,
            origin,
        } = &min
        else {
            panic!("parsed as train")
        };
        assert_eq!(combo, "dqn_cartpole");
        assert_eq!((*seed, *actors, *max_env_steps, *max_episodes), (1, 1, 8_000, 300));
        assert!(*quantized);
        assert_eq!(*priority, 0);
        assert_eq!((*checkpoint_every, *progress_every), (0, 0));
        assert!(resume.is_none());
        assert!(!detach, "pre-durability lines parse as attached submissions");
        assert!(origin.is_none());
        // A fresh attached submission never ships the optional keys: its
        // wire line is byte-identical to pre-durability clients'.
        let line = min.to_line().unwrap();
        assert!(!line.contains("resume"));
        assert!(!line.contains("detach"));
        assert!(!line.contains("origin"));
        assert_eq!(min.verb(), "train");
        // Strict field validation: no silent truncation, no scalar resume.
        for bad in [
            r#"{"v":3,"verb":"train"}"#,
            r#"{"v":3,"verb":"train","combo":"dqn_cartpole","actors":0}"#,
            r#"{"v":3,"verb":"train","combo":"dqn_cartpole","seed":1.5}"#,
            r#"{"v":3,"verb":"train","combo":"dqn_cartpole","priority":0.5}"#,
            r#"{"v":3,"verb":"train","combo":"dqn_cartpole","checkpoint_every":-5}"#,
            r#"{"v":3,"verb":"train","combo":"dqn_cartpole","resume":42}"#,
            r#"{"v":3,"verb":"train","combo":"dqn_cartpole","origin":7}"#,
            r#"{"v":3,"verb":"cancel"}"#,
        ] {
            assert!(Request::parse_line(bad).is_err(), "{bad} must not parse");
        }
        let c = Request::parse_line(r#"{"v":3,"verb":"cancel","job":"job-3"}"#).unwrap();
        assert_eq!(c, Request::Cancel { job: "job-3".into() });
        assert_eq!(c.verb(), "cancel");
        assert_eq!(Request::parse_line(r#"{"v":3,"verb":"jobs"}"#).unwrap(), Request::Jobs);
    }

    #[test]
    fn frame_lines_hoist_the_trainer_frame() {
        let frame = Json::obj(vec![
            ("frame", Json::Str("episode".into())),
            ("job", Json::Str("job-1".into())),
            ("reward", Json::Num(10.5)),
        ]);
        let line = frame_response(&frame).to_line().unwrap();
        let parsed = parse_response(&line).unwrap();
        assert_eq!(parsed.get("frame").and_then(Json::as_str), Some("episode"));
        assert_eq!(parsed.get("job").and_then(Json::as_str), Some("job-1"));
        assert_eq!(parsed.get("v").and_then(Json::as_f64), Some(3.0));
    }

    #[test]
    fn profile_payload_carries_the_candidate_table() {
        let payload = profile_payload("dqn_cartpole", 64, true).unwrap();
        let nodes = payload.get("nodes").and_then(Json::as_arr).expect("nodes array");
        assert!(!nodes.is_empty());
        for node in nodes {
            assert!(node.get("name").and_then(Json::as_str).is_some());
            assert!(node.get("ps_latency_us").and_then(Json::as_f64).is_some());
            let pl = node.get("pl").and_then(Json::as_arr).expect("pl candidates");
            assert!(!pl.is_empty(), "every node has at least one PL candidate");
            for cand in pl {
                assert!(cand.get("latency_us").and_then(Json::as_f64).unwrap() > 0.0);
                assert!(cand.get("fmt").and_then(Json::as_str).is_some());
            }
        }
        // Unknown combos surface the registry error, not a panic.
        assert!(profile_payload("dqn_nonsense", 64, true).is_err());
    }

    #[test]
    fn responses_carry_ok_and_errors() {
        let ok = ok_response(BTreeMap::new()).to_line().unwrap();
        assert!(parse_response(&ok).is_ok());
        let err = error_response("boom").to_line().unwrap();
        let e = parse_response(&err).unwrap_err();
        assert!(format!("{e}").contains("boom"), "{e}");
    }

    #[test]
    fn plan_payload_round_trips_bit_identically() {
        let req = PlanRequest::named("dqn_cartpole").unwrap().with_batch(24);
        let outcome = LocalPlanner.plan(&req).unwrap();
        let wire = plan_to_json(&outcome).to_line().unwrap();
        let remote = plan_from_json(
            &Json::parse(&wire).unwrap(),
            Provenance::Remote { addr: "test".into() },
        )
        .unwrap();
        assert_eq!(remote.makespan_us.to_bits(), outcome.makespan_us.to_bits());
        assert_eq!(remote.schedule.len(), outcome.schedule.len());
        for (r, l) in remote.schedule.iter().zip(&outcome.schedule) {
            assert_eq!(r.node, l.node);
            assert_eq!(r.component, l.component);
            assert_eq!(r.mm, l.mm);
            assert_eq!(r.start_us.to_bits(), l.start_us.to_bits());
            assert_eq!(r.finish_us.to_bits(), l.finish_us.to_bits());
        }
        assert_eq!(remote.assignment, outcome.assignment);
        assert_eq!(remote.step_time_us().to_bits(), outcome.step_time_us().to_bits());
        // Everything but provenance survives the wire unchanged.
        assert_eq!(remote.provenance, Provenance::Remote { addr: "test".into() });
        let mut relabeled = remote.clone();
        relabeled.provenance = outcome.provenance.clone();
        assert_eq!(relabeled, outcome);
    }
}
