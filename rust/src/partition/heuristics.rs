//! Heuristic partitioners: the greedy baseline and HEFT list scheduling.
//! Used (a) to seed the B&B incumbent, (b) as ablation baselines for the
//! partition-quality bench (DESIGN.md §3).

use crate::hw::Component;
use crate::Micros;

use super::model::{Assignment, Placement, Problem, Solution};
use super::schedule::evaluate;

/// Greedy: every node takes its standalone-fastest feasible placement
/// (ignores parallelism and communication entirely).
pub fn greedy(problem: &Problem) -> Solution {
    let n = problem.dag.len();
    let mut assignment: Assignment = Vec::with_capacity(n);
    for i in 0..n {
        // Shared-accelerator semantics: every candidate fits the pools
        // by construction, so greedy is the pure standalone argmin.
        let best = problem
            .options(i)
            .into_iter()
            .min_by(|a, b| {
                // total_cmp: a NaN latency sorts last instead of panicking.
                problem.latency(i, *a).total_cmp(&problem.latency(i, *b))
            })
            .expect("every node has a PL candidate");
        assignment.push(best);
    }
    let sched = evaluate(problem, &assignment);
    Solution { assignment, makespan_us: sched.makespan_us, explored: n }
}

/// HEFT: nodes in descending upward rank; each placed on the component
/// minimizing its earliest finish time under the incremental schedule.
pub fn heft(problem: &Problem) -> Solution {
    let dag = problem.dag;
    let n = dag.len();

    // Best-case latency per node for ranking (classic HEFT uses the mean
    // across processors, but our candidate sets include deliberately
    // tiny configs whose latencies would swamp the mean).
    let mean_lat: Vec<Micros> = (0..n).map(|i| problem.min_latency(i)).collect();

    // Upward rank: rank(i) = mean_lat(i) + max_{s ∈ succ} rank(s).
    let order = dag.topo_order();
    let mut rank = vec![0.0f64; n];
    for &i in order.iter().rev() {
        let succ_max =
            dag.succs[i].iter().map(|&s| rank[s]).fold(0.0, f64::max);
        rank[i] = mean_lat[i] + succ_max;
    }
    let mut by_rank: Vec<usize> = (0..n).collect();
    by_rank.sort_by(|&a, &b| rank[b].total_cmp(&rank[a]));

    // Incremental placement honoring precedence (process by rank, which
    // is a valid topological order for HEFT since rank(parent) >
    // rank(child) when latencies are positive).
    let mut finish = vec![0.0f64; n];
    let mut free: [Micros; 3] = [0.0; 3];
    let comp_idx = |c: Component| match c {
        Component::PS => 0,
        Component::PL => 1,
        Component::AIE => 2,
    };
    let mut assignment: Assignment =
        vec![Placement { component: Component::PL, candidate: 0 }; n];
    for &i in &by_rank {
        let mut best: Option<(Micros, Placement, Micros)> = None; // (eft, placement, start)
        for p in problem.options(i) {
            let mut ready = 0.0f64;
            for &pr in &dag.preds[i] {
                let bytes = dag.nodes[pr].out_elems as f64 * 2.0;
                let comm = problem.platform.comm.edge_cost(
                    assignment[pr].component,
                    p.component,
                    bytes,
                );
                ready = ready.max(finish[pr] + comm);
            }
            let start = ready.max(free[comp_idx(p.component)]);
            let eft = start + problem.latency(i, p);
            // total_cmp keeps a NaN EFT from sticking as the running best.
            if best.as_ref().map_or(true, |(b, _, _)| eft.total_cmp(b).is_lt()) {
                best = Some((eft, p, start));
            }
        }
        let (eft, p, _start) = best.expect("every node has at least one candidate");
        assignment[i] = p;
        finish[i] = eft;
        free[comp_idx(p.component)] = eft;
    }
    let sched = evaluate(problem, &assignment);
    Solution { assignment, makespan_us: sched.makespan_us, explored: n }
}

/// Hill-climbing refinement: repeatedly try every alternative placement
/// for every node (others fixed), keep any feasible improvement, until a
/// full sweep yields none.  Polishes HEFT seeds and capped-B&B incumbents
/// — a cheap stand-in for the ILP solver's final gap-closing on graphs
/// too large for exact search.
pub fn local_search(problem: &Problem, start: Solution) -> Solution {
    let n = problem.dag.len();
    let mut best = start;
    let mut improved = true;
    let mut explored = best.explored;
    while improved {
        improved = false;
        for i in 0..n {
            let current = best.assignment[i];
            for p in problem.options(i) {
                if p == current {
                    continue;
                }
                let mut trial = best.assignment.clone();
                trial[i] = p;
                if !problem.feasible(&trial) {
                    continue;
                }
                explored += 1;
                let m = evaluate(problem, &trial).makespan_us;
                if m + 1e-9 < best.makespan_us {
                    best = Solution { assignment: trial, makespan_us: m, explored };
                    improved = true;
                }
            }
        }
    }
    best.explored = explored;
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, NetSpec, TrainSpec};
    use crate::hw::vek280;
    use crate::profile::profile_dag;

    fn make(
        sizes: &[usize],
        batch: usize,
    ) -> (crate::graph::Dag, Vec<crate::profile::NodeProfile>, crate::hw::Platform) {
        let spec = TrainSpec {
            algo: Algo::Ddpg,
            net: NetSpec::mlp(sizes),
            batch,
            obs_dim: sizes[0],
            act_dim: *sizes.last().unwrap(),
        };
        let dag = build_train_graph(&spec);
        let platform = vek280();
        let profs = profile_dag(&dag, &platform, true);
        (dag, profs, platform)
    }

    #[test]
    fn both_heuristics_feasible() {
        let (dag, profs, platform) = make(&[8, 400, 300, 2], 256);
        let problem = Problem::new(&dag, &profs, &platform, true);
        for sol in [greedy(&problem), heft(&problem)] {
            assert!(problem.feasible(&sol.assignment));
            assert!(sol.makespan_us.is_finite() && sol.makespan_us > 0.0);
        }
    }

    #[test]
    fn heft_no_worse_than_greedy_on_ddpg() {
        // Not a theorem, but holds on the paper's workloads (HEFT models
        // parallelism + comm; greedy does not).
        let (dag, profs, platform) = make(&[8, 400, 300, 2], 1024);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let g = greedy(&problem);
        let h = heft(&problem);
        assert!(h.makespan_us <= g.makespan_us * 1.5, "HEFT {} vs greedy {}", h.makespan_us, g.makespan_us);
    }

    #[test]
    fn rank_order_respects_dependencies() {
        // Implicit check: heft() panics/asserts nothing and the schedule
        // evaluator validates via its own dependency test elsewhere; here
        // assert determinism.
        let (dag, profs, platform) = make(&[4, 64, 64, 1], 64);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let a = heft(&problem);
        let b = heft(&problem);
        assert_eq!(a.assignment, b.assignment);
    }
}
