//! ILP-based automatic task partitioning (paper §IV-C, Eq. 2–7).
//!
//! Decision: for every MM layer node, PL or AIE (non-MM nodes are pinned
//! to PL, §IV-A) *and* which DSE candidate config to use, minimizing the
//! training-step makespan under dependency (Eq. 5), completion (Eq. 3/6)
//! and resource-capacity (Eq. 7) constraints, with inter-component
//! communication charged on cut edges and master-weight sync charged by
//! the quantization policy.
//!
//! Solvers: exact branch-and-bound ([`ilp`]) — parallel prefix fan-out
//! over scoped threads with an atomically shared incumbent, optimality
//! cross-checked against exhaustive enumeration and the sequential
//! reference in tests — plus greedy and HEFT baselines ([`heuristics`])
//! used for the ablation benches.  Solved plans are memoized by
//! [`cache`] (keyed on algo/net/batch/precision/platform, optional JSON
//! persistence), which is what makes the static phase a cheap, reusable
//! planning service.

pub mod cache;
pub mod heuristics;
pub mod ilp;
pub mod model;
pub mod schedule;

pub use cache::{platform_fingerprint, PlanCache, PlanKey};
pub use ilp::{solve_ilp, solve_ilp_capped, solve_ilp_sequential};
pub use model::{Assignment, Placement, Problem, Solution};
pub use schedule::{evaluate, ScheduleEntry};
