//! ILP-based automatic task partitioning (paper §IV-C, Eq. 2–7).
//!
//! Decision: for every MM layer node, PL or AIE (non-MM nodes are pinned
//! to PL, §IV-A) *and* which DSE candidate config to use, minimizing the
//! training-step makespan under dependency (Eq. 5), completion (Eq. 3/6)
//! and resource-capacity (Eq. 7) constraints, with inter-component
//! communication charged on cut edges and master-weight sync charged by
//! the quantization policy.
//!
//! Solvers: exact branch-and-bound ([`ilp`]) with optimality
//! cross-checked against exhaustive enumeration in tests, plus greedy and
//! HEFT baselines ([`heuristics`]) used for the ablation benches.

pub mod heuristics;
pub mod ilp;
pub mod model;
pub mod schedule;

pub use ilp::solve_ilp;
pub use model::{Assignment, Placement, Problem, Solution};
pub use schedule::{evaluate, ScheduleEntry};
