//! Memoized plan store for the static phase (the "planning service"
//! backing `coordinator::pipeline`).
//!
//! The static phase (DSE profiling → TAPCA → ILP partitioning) is pure:
//! the same (algorithm, network shape, batch, precision mode, platform)
//! always produces the same optimal assignment.  Re-solving it for every
//! figure, bench and sweep point is the dominant offline cost, so solved
//! plans are cached under a [`PlanKey`] covering exactly the solver
//! inputs:
//!
//! `algo | net fingerprint | batch | obs/act dims | quantized | platform
//! fingerprint`
//!
//! A process-wide cache ([`global`]) makes repeated
//! `coordinator::static_phase` calls O(1) after the first solve.  Set the
//! `APDRL_PLAN_CACHE` environment variable to a file path to persist the
//! cache as JSON (via `util::json`) across runs; without it the global
//! cache is memory-only.  Cached entries are validated against the
//! current profile shapes on lookup, so a stale file from an older model
//! degrades to a miss, never a wrong plan.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use crate::graph::{NetSpec, TrainSpec};
use crate::hw::{Component, ComponentSpec, Platform};
use crate::profile::NodeProfile;
use crate::util::json::Json;

use super::model::{Assignment, Placement, Solution};

/// Bump whenever an analytic-model constant *outside* [`Platform`]
/// changes (pl_model/aie_model/ps_model pragma constants, master-sync
/// overheads, schedule semantics...).  Persisted plans from an older
/// model version then key apart instead of being served stale.
const MODEL_VERSION: u32 = 1;

/// Canonical cache key for one static-phase problem instance.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey(String);

impl PlanKey {
    /// Key for a training-step spec on a platform.  Everything the ILP's
    /// inputs depend on is folded in; nothing else is.
    pub fn new(spec: &TrainSpec, quantized: bool, platform: &Platform) -> PlanKey {
        PlanKey(format!(
            "{}|{}|bs{}|obs{}|act{}|{}|{}",
            spec.algo.name(),
            net_fingerprint(&spec.net),
            spec.batch,
            spec.obs_dim,
            spec.act_dim,
            if quantized { "quant" } else { "fp32" },
            platform_fingerprint(platform),
        ))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

/// Network-shape fingerprint (layer dims only — weights don't exist yet
/// at planning time).
fn net_fingerprint(net: &NetSpec) -> String {
    match net {
        NetSpec::Mlp { sizes } => {
            let dims: Vec<String> = sizes.iter().map(|d| d.to_string()).collect();
            format!("mlp:{}", dims.join("-"))
        }
        NetSpec::Conv { in_hw, in_ch, conv, fc } => {
            let convs: Vec<String> =
                conv.iter().map(|(c, k, s)| format!("{c}.{k}.{s}")).collect();
            let fcs: Vec<String> = fc.iter().map(|d| d.to_string()).collect();
            format!("conv:{in_hw}x{in_hw}x{in_ch};{};fc{}", convs.join(";"), fcs.join("-"))
        }
    }
}

/// Platform fingerprint: *every* constant the profiling and schedule
/// models read (component specs, link model, resource pools), prefixed
/// with [`MODEL_VERSION`].  Two platforms with equal fingerprints
/// produce identical profiles, so a changed model constant can never
/// serve a stale persisted plan.
fn platform_fingerprint(p: &Platform) -> String {
    format!(
        "v{MODEL_VERSION}|{}|ps[{}]pl[{}]aie[{}]|comm[{};{};{};{}]|pools[{};{};{};{};{}]",
        p.name,
        spec_fingerprint(&p.ps),
        spec_fingerprint(&p.pl),
        spec_fingerprint(&p.aie),
        p.comm.ps_pl_lat_us,
        p.comm.ps_pl_gbps,
        p.comm.pl_aie_lat_us,
        p.comm.pl_aie_gbps,
        p.pl_dsp,
        p.pl_kluts,
        p.pl_mem_mb,
        p.aie_tiles,
        p.aie_lanes_per_tile,
    )
}

fn spec_fingerprint(s: &ComponentSpec) -> String {
    format!(
        "c{};i{};l{};e{};m{};f{}/{}/{}",
        s.clock_mhz,
        s.init_us,
        s.max_mac_lanes,
        s.efficiency,
        s.mem_gbps,
        s.fmt_fp32,
        s.fmt_fp16,
        s.fmt_bf16
    )
}

/// One memoized solve result.  `explored` is deliberately not stored: a
/// cache hit reports `explored == 0`, which is also how callers can tell
/// a hit from a fresh solve.
#[derive(Clone, Debug, PartialEq)]
pub struct CachedPlan {
    pub assignment: Assignment,
    pub makespan_us: f64,
}

impl CachedPlan {
    fn to_solution(&self) -> Solution {
        Solution {
            assignment: self.assignment.clone(),
            makespan_us: self.makespan_us,
            explored: 0,
        }
    }
}

/// In-memory plan cache with optional JSON persistence.
#[derive(Debug, Default)]
pub struct PlanCache {
    entries: HashMap<String, CachedPlan>,
    path: Option<PathBuf>,
    pub hits: u64,
    pub misses: u64,
}

impl PlanCache {
    /// Memory-only cache.
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Cache backed by a JSON file: loads any valid existing content.
    /// Writes happen on [`save`](PlanCache::save) (merging with what is
    /// on disk — see there).  A missing or corrupt file is an empty
    /// cache, never an error.
    pub fn with_persistence(path: impl AsRef<Path>) -> PlanCache {
        let path = path.as_ref().to_path_buf();
        let mut cache = PlanCache { path: Some(path.clone()), ..PlanCache::default() };
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(root) = Json::parse(&text) {
                cache.absorb(&root);
            }
        }
        cache
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop every in-memory entry (hit/miss counters keep running).
    /// The backing file, if any, is untouched: persistence merges on
    /// save, so clearing memory (e.g. the benches forcing cold solves)
    /// can never destroy previously persisted plans.
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Look up a plan and validate it against the profiles the caller is
    /// about to schedule with.  Any shape mismatch (stale file, changed
    /// model) is a miss.
    pub fn lookup(&mut self, key: &PlanKey, profiles: &[NodeProfile]) -> Option<Solution> {
        let valid = self
            .entries
            .get(key.as_str())
            .filter(|plan| plan_is_valid(plan, profiles))
            .map(CachedPlan::to_solution);
        if valid.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        valid
    }

    /// Memoize a fresh solve in memory.  Persistence is a separate,
    /// explicit step ([`save`](PlanCache::save), or [`global_insert`]
    /// for the process-wide cache) so callers can keep disk I/O outside
    /// their locks.
    pub fn insert(&mut self, key: &PlanKey, solution: &Solution) {
        self.entries.insert(
            key.as_str().to_string(),
            CachedPlan {
                assignment: solution.assignment.clone(),
                makespan_us: solution.makespan_us,
            },
        );
    }

    /// Write the cache file (no-op for memory-only caches), merging the
    /// in-memory entries into whatever is currently on disk.
    pub fn save(&self) {
        if let Some(path) = &self.path {
            write_merged(path, self.entries.clone());
        }
    }

    /// Merge entries parsed from a cache file; malformed entries are
    /// skipped silently (forward/backward compatibility).
    fn absorb(&mut self, root: &Json) {
        if root.get("version").and_then(Json::as_f64) != Some(1.0) {
            return;
        }
        let Some(plans) = root.get("plans").and_then(Json::as_obj) else { return };
        for (key, entry) in plans {
            let Some(makespan_us) = entry.get("makespan_us").and_then(Json::as_f64) else {
                continue;
            };
            let Some(raw) = entry.get("assignment").and_then(Json::as_arr) else { continue };
            let mut assignment: Assignment = Vec::with_capacity(raw.len());
            let mut ok = true;
            for item in raw {
                let pair = item.as_arr().unwrap_or(&[]);
                let comp = pair
                    .first()
                    .and_then(Json::as_str)
                    .and_then(component_from_name);
                let cand = pair.get(1).and_then(Json::as_usize);
                match (comp, cand) {
                    (Some(component), Some(candidate)) => {
                        assignment.push(Placement { component, candidate });
                    }
                    _ => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok && makespan_us.is_finite() {
                self.entries.insert(key.clone(), CachedPlan { assignment, makespan_us });
            }
        }
    }
}

fn entries_to_json(entries: &HashMap<String, CachedPlan>) -> Json {
    let mut plans = std::collections::BTreeMap::new();
    for (key, plan) in entries {
        let assignment: Vec<Json> = plan
            .assignment
            .iter()
            .map(|p| {
                Json::Arr(vec![
                    Json::Str(p.component.name().to_string()),
                    Json::Num(p.candidate as f64),
                ])
            })
            .collect();
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("makespan_us".to_string(), Json::Num(plan.makespan_us));
        obj.insert("assignment".to_string(), Json::Arr(assignment));
        plans.insert(key.clone(), Json::Obj(obj));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("version".to_string(), Json::Num(1.0));
    root.insert("plans".to_string(), Json::Obj(plans));
    Json::Obj(root)
}

/// Merge `entries` into whatever is on disk at `path` (memory wins on
/// key conflicts) and write the union back.  Because saves merge, a
/// memory-side [`PlanCache::clear`] or a concurrent process can never
/// truncate previously persisted plans — a racing writer loses at most
/// its own last write.  Best-effort: an unwritable path must not take
/// down the planning service, the cache just stays memory-only.
fn write_merged(path: &Path, entries: HashMap<String, CachedPlan>) {
    let mut disk = PlanCache::default();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(root) = Json::parse(&text) {
            disk.absorb(&root);
        }
    }
    disk.entries.extend(entries);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    let _ = std::fs::write(path, entries_to_json(&disk.entries).to_string());
}

/// Insert into the process-wide cache and persist it (when
/// `APDRL_PLAN_CACHE` is set), with the disk I/O performed *outside*
/// the cache lock so concurrent sweep workers doing lookups never block
/// behind the filesystem.
pub fn global_insert(key: &PlanKey, solution: &Solution) {
    let snapshot = {
        let mut guard = global().lock().unwrap();
        guard.insert(key, solution);
        guard.path.clone().map(|path| (path, guard.entries.clone()))
    };
    if let Some((path, entries)) = snapshot {
        write_merged(&path, entries);
    }
}

/// A cached assignment is only usable if every placement indexes a
/// candidate that exists in the profiles being scheduled.
fn plan_is_valid(plan: &CachedPlan, profiles: &[NodeProfile]) -> bool {
    plan.assignment.len() == profiles.len()
        && plan.assignment.iter().zip(profiles).all(|(p, prof)| match p.component {
            Component::PL => p.candidate < prof.pl.len(),
            Component::AIE => p.candidate < prof.aie.len(),
            Component::PS => p.candidate == 0,
        })
}

fn component_from_name(name: &str) -> Option<Component> {
    match name {
        "PS" => Some(Component::PS),
        "PL" => Some(Component::PL),
        "AIE" => Some(Component::AIE),
        _ => None,
    }
}

/// The process-wide plan cache used by `coordinator::static_phase`.
/// File-backed iff `APDRL_PLAN_CACHE` names a path at first use.
pub fn global() -> &'static Mutex<PlanCache> {
    static GLOBAL: OnceLock<Mutex<PlanCache>> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let cache = match std::env::var("APDRL_PLAN_CACHE") {
            Ok(path) if !path.is_empty() => PlanCache::with_persistence(path),
            _ => PlanCache::new(),
        };
        Mutex::new(cache)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, TrainSpec};
    use crate::hw::vek280;
    use crate::partition::{solve_ilp, Problem};
    use crate::profile::profile_dag;

    fn spec(batch: usize) -> TrainSpec {
        TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(&[4, 16, 2]),
            batch,
            obs_dim: 4,
            act_dim: 2,
        }
    }

    fn solved(batch: usize) -> (PlanKey, Solution, Vec<NodeProfile>) {
        let platform = vek280();
        let s = spec(batch);
        let dag = build_train_graph(&s);
        let profiles = profile_dag(&dag, &platform, true);
        let problem = Problem::new(&dag, &profiles, &platform, true);
        let solution = solve_ilp(&problem);
        (PlanKey::new(&s, true, &platform), solution, profiles)
    }

    #[test]
    fn key_separates_problem_dimensions() {
        let p = vek280();
        let base = PlanKey::new(&spec(64), true, &p);
        assert_eq!(base, PlanKey::new(&spec(64), true, &p));
        assert_ne!(base, PlanKey::new(&spec(128), true, &p), "batch must key");
        assert_ne!(base, PlanKey::new(&spec(64), false, &p), "precision must key");
        let mut other = spec(64);
        other.net = NetSpec::mlp(&[4, 32, 2]);
        assert_ne!(base, PlanKey::new(&other, true, &p), "net shape must key");
        let mut fx = crate::hw::fixar_platform();
        fx.pl_dsp = p.pl_dsp; // same pools, different clocks
        assert_ne!(base, PlanKey::new(&spec(64), true, &fx), "platform must key");
    }

    #[test]
    fn hit_returns_identical_plan_with_zero_explored() {
        let (key, solution, profiles) = solved(32);
        let mut cache = PlanCache::new();
        assert!(cache.lookup(&key, &profiles).is_none());
        cache.insert(&key, &solution);
        let hit = cache.lookup(&key, &profiles).expect("must hit after insert");
        assert_eq!(hit.assignment, solution.assignment);
        assert_eq!(hit.makespan_us.to_bits(), solution.makespan_us.to_bits());
        assert_eq!(hit.explored, 0);
        assert_eq!((cache.hits, cache.misses), (1, 1));
    }

    #[test]
    fn stale_shapes_degrade_to_miss() {
        let (key, solution, mut profiles) = solved(32);
        let mut cache = PlanCache::new();
        cache.insert(&key, &solution);
        // candidate list shrank (model changed) → candidate index invalid
        for prof in profiles.iter_mut() {
            prof.pl.clear();
            prof.aie.clear();
        }
        assert!(cache.lookup(&key, &profiles).is_none());
        // wrong node count → invalid
        let (_, _, longer) = solved(64);
        let truncated = &longer[..longer.len() - 1];
        assert!(cache.lookup(&key, truncated).is_none());
    }

    #[test]
    fn persistence_round_trips_bit_identically() {
        let (key, solution, profiles) = solved(32);
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let path = dir.join("plans.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut cache = PlanCache::with_persistence(&path);
            cache.insert(&key, &solution);
            cache.save();
        }
        let mut reloaded = PlanCache::with_persistence(&path);
        assert_eq!(reloaded.len(), 1);
        let hit = reloaded.lookup(&key, &profiles).expect("persisted plan must hit");
        assert_eq!(hit.assignment, solution.assignment);
        assert_eq!(hit.makespan_us.to_bits(), solution.makespan_us.to_bits());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn saves_merge_with_disk_so_clear_loses_nothing() {
        let (key_a, sol_a, profiles) = solved(32);
        let (key_b, sol_b, _) = solved(64);
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let path = dir.join("merge.json");
        let _ = std::fs::remove_file(&path);
        let mut cache = PlanCache::with_persistence(&path);
        cache.insert(&key_a, &sol_a);
        cache.save();
        // Memory cleared (as the cold-solve benches do), then a new plan
        // saved: the file must end up with the union, not just B.
        cache.clear();
        cache.insert(&key_b, &sol_b);
        cache.save();
        let mut reloaded = PlanCache::with_persistence(&path);
        assert_eq!(reloaded.len(), 2, "merge-on-save must keep A and add B");
        assert!(reloaded.lookup(&key_a, &profiles).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_is_an_empty_cache() {
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("corrupt.json");
        std::fs::write(&path, "{not json").unwrap();
        let cache = PlanCache::with_persistence(&path);
        assert!(cache.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
