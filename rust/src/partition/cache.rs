//! Memoized plan store for the static phase (the "planning service"
//! backing `coordinator::pipeline`).
//!
//! The static phase (DSE profiling → TAPCA → ILP partitioning) is pure:
//! the same (algorithm, network shape, batch, precision mode, platform)
//! always produces the same optimal assignment.  Re-solving it for every
//! figure, bench and sweep point is the dominant offline cost, so solved
//! plans are cached under a [`PlanKey`] covering exactly the solver
//! inputs:
//!
//! `algo | net fingerprint | batch | obs/act dims | quantized | platform
//! fingerprint`
//!
//! A process-wide cache ([`global`]) makes repeated
//! `coordinator::static_phase` calls O(1) after the first solve.  Set the
//! `APDRL_PLAN_CACHE` environment variable to a file path to persist the
//! cache as JSON (via `util::json`) across runs; without it the global
//! cache is memory-only.  Cached entries are validated against the
//! current profile shapes on lookup, so a stale file from an older model
//! degrades to a miss, never a wrong plan.
//!
//! Two policies bound the cache (and with it the persisted file, which
//! previously grew monotonically):
//!
//! * **Schema versioning** — the file carries a `schema` field; a file
//!   written by a different schema version is dropped wholesale on load.
//! * **LRU cap** — at most `APDRL_PLAN_CACHE_MAX` entries (default
//!   4096) are retained; inserts and saves evict the least-recently-used
//!   plans first (recency stamps persist across reloads).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Mutex, OnceLock};

use crate::graph::{NetSpec, TrainSpec};
use crate::hw::{Component, ComponentSpec, Platform};
use crate::profile::NodeProfile;
use crate::util::json::Json;

use super::model::{Assignment, Placement, Solution};

/// Bump whenever an analytic-model constant *outside* [`Platform`]
/// changes (pl_model/aie_model/ps_model pragma constants, master-sync
/// overheads, schedule semantics...).  Persisted plans from an older
/// model version then key apart instead of being served stale.
const MODEL_VERSION: u32 = 1;

/// Version of the *persisted file format* (independent of
/// [`MODEL_VERSION`], which versions the analytic model inside the
/// keys).  Loading a file with a different schema drops every entry —
/// old-format caches degrade to a cold start, never a misparse.
/// v2 added per-entry recency stamps for the LRU cap.
const SCHEMA_VERSION: f64 = 2.0;

/// Default entry cap when `APDRL_PLAN_CACHE_MAX` is unset: generous
/// enough for every figure/bench grid in the repo, small enough that
/// the persisted JSON file stops growing monotonically.
const DEFAULT_MAX_ENTRIES: usize = 4096;

/// Entry cap from the environment (`APDRL_PLAN_CACHE_MAX`), falling
/// back to [`DEFAULT_MAX_ENTRIES`] when unset or unparsable.
fn env_limit() -> usize {
    limit_from(std::env::var("APDRL_PLAN_CACHE_MAX").ok().as_deref())
}

fn limit_from(raw: Option<&str>) -> usize {
    raw.and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(DEFAULT_MAX_ENTRIES)
}

/// Canonical cache key for one static-phase problem instance.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey(String);

impl PlanKey {
    /// Key for a training-step spec on a platform.  Everything the ILP's
    /// inputs depend on is folded in; nothing else is.  That includes
    /// the active calibration table (`APDRL_CALIB`): measured PS costs
    /// change the profiles, so calibrated and uncalibrated solves —
    /// and solves under different measurements — must key apart.
    pub fn new(spec: &TrainSpec, quantized: bool, platform: &Platform) -> PlanKey {
        let calib = match crate::profile::calib::active_fingerprint() {
            Some(fp) => format!("|calib:{fp}"),
            None => String::new(),
        };
        PlanKey(format!(
            "{}|{}|bs{}|obs{}|act{}|{}|{}{}",
            spec.algo.name(),
            net_fingerprint(&spec.net),
            spec.batch,
            spec.obs_dim,
            spec.act_dim,
            if quantized { "quant" } else { "fp32" },
            platform_fingerprint(platform),
            calib,
        ))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

/// Network-shape fingerprint (layer dims only — weights don't exist yet
/// at planning time).
fn net_fingerprint(net: &NetSpec) -> String {
    match net {
        NetSpec::Mlp { sizes } => {
            let dims: Vec<String> = sizes.iter().map(|d| d.to_string()).collect();
            format!("mlp:{}", dims.join("-"))
        }
        NetSpec::Conv { in_hw, in_ch, conv, fc } => {
            let convs: Vec<String> =
                conv.iter().map(|(c, k, s)| format!("{c}.{k}.{s}")).collect();
            let fcs: Vec<String> = fc.iter().map(|d| d.to_string()).collect();
            format!("conv:{in_hw}x{in_hw}x{in_ch};{};fc{}", convs.join(";"), fcs.join("-"))
        }
    }
}

/// Platform fingerprint: *every* constant the profiling and schedule
/// models read (component specs, link model, resource pools), prefixed
/// with [`MODEL_VERSION`].  Two platforms with equal fingerprints
/// produce identical profiles, so a changed model constant can never
/// serve a stale persisted plan.  Public because `apdrl profile` and
/// the `profile` verb state which platform they priced.
pub fn platform_fingerprint(p: &Platform) -> String {
    format!(
        "v{MODEL_VERSION}|{}|ps[{}]pl[{}]aie[{}]|comm[{};{};{};{}]|pools[{};{};{};{};{}]",
        p.name,
        spec_fingerprint(&p.ps),
        spec_fingerprint(&p.pl),
        spec_fingerprint(&p.aie),
        p.comm.ps_pl_lat_us,
        p.comm.ps_pl_gbps,
        p.comm.pl_aie_lat_us,
        p.comm.pl_aie_gbps,
        p.pl_dsp,
        p.pl_kluts,
        p.pl_mem_mb,
        p.aie_tiles,
        p.aie_lanes_per_tile,
    )
}

fn spec_fingerprint(s: &ComponentSpec) -> String {
    format!(
        "c{};i{};l{};e{};m{};f{}/{}/{}",
        s.clock_mhz,
        s.init_us,
        s.max_mac_lanes,
        s.efficiency,
        s.mem_gbps,
        s.fmt_fp32,
        s.fmt_fp16,
        s.fmt_bf16
    )
}

/// One memoized solve result.  `explored` is deliberately not stored: a
/// cache hit reports `explored == 0`, which is also how callers can tell
/// a hit from a fresh solve.
#[derive(Clone, Debug, PartialEq)]
pub struct CachedPlan {
    pub assignment: Assignment,
    pub makespan_us: f64,
}

impl CachedPlan {
    fn to_solution(&self) -> Solution {
        Solution {
            assignment: self.assignment.clone(),
            makespan_us: self.makespan_us,
            explored: 0,
        }
    }
}

/// One stored plan plus its recency stamp (logical clock ticks on
/// insert and on every hit; lowest stamp = least recently used).
#[derive(Clone, Debug)]
struct Entry {
    plan: CachedPlan,
    stamp: u64,
}

/// In-memory plan cache with optional JSON persistence and an LRU-ish
/// entry cap (`APDRL_PLAN_CACHE_MAX`, default 4096): when an insert
/// pushes the cache over its limit, the least-recently-used entries are
/// evicted, and saves cap the merged file the same way — the persisted
/// JSON no longer grows monotonically.
#[derive(Debug)]
pub struct PlanCache {
    entries: HashMap<String, Entry>,
    path: Option<PathBuf>,
    /// Logical recency clock; monotonically increasing per operation.
    clock: u64,
    /// Maximum retained entries (≥ 1).
    limit: usize,
    pub hits: u64,
    pub misses: u64,
    /// Entries dropped by the LRU cap over this cache's lifetime
    /// (inserts and loads; merge-on-save scratch caches don't count).
    pub evictions: u64,
}

impl Default for PlanCache {
    fn default() -> PlanCache {
        PlanCache {
            entries: HashMap::new(),
            path: None,
            clock: 0,
            limit: env_limit(),
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }
}

impl PlanCache {
    /// Memory-only cache (entry cap from `APDRL_PLAN_CACHE_MAX`).
    pub fn new() -> PlanCache {
        PlanCache::default()
    }

    /// Memory-only cache with an explicit entry cap (tests, embedders).
    pub fn with_limit(limit: usize) -> PlanCache {
        PlanCache { limit: limit.max(1), ..PlanCache::default() }
    }

    /// Cache backed by a JSON file: loads any valid existing content.
    /// Writes happen on [`save`](PlanCache::save) (merging with what is
    /// on disk — see there).  A missing or corrupt file is an empty
    /// cache, never an error, and a file written by an older schema
    /// version is dropped wholesale (cold start, never a misparse).
    pub fn with_persistence(path: impl AsRef<Path>) -> PlanCache {
        PlanCache::with_persistence_limited(path, env_limit())
    }

    /// [`with_persistence`](PlanCache::with_persistence) with an explicit
    /// entry cap instead of `APDRL_PLAN_CACHE_MAX` (tests, embedders —
    /// env vars are process-global and test runs are concurrent).
    pub fn with_persistence_limited(path: impl AsRef<Path>, limit: usize) -> PlanCache {
        let path = path.as_ref().to_path_buf();
        let mut cache = PlanCache {
            path: Some(path.clone()),
            limit: limit.max(1),
            ..PlanCache::default()
        };
        if let Ok(text) = std::fs::read_to_string(&path) {
            match Json::parse(&text) {
                Ok(root) if root.get("schema").and_then(Json::as_f64) == Some(SCHEMA_VERSION) => {
                    cache.absorb(&root);
                }
                // A readable file that is not a current-schema cache is
                // dropped wholesale (cold start) — but never silently:
                // losing every persisted plan deserves a signal.
                _ => eprintln!(
                    "warning: plan cache {} is unreadable or from another schema; starting cold",
                    path.display()
                ),
            }
        }
        cache
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Drop every in-memory entry (hit/miss counters keep running).
    /// The backing file, if any, is untouched: persistence merges on
    /// save, so clearing memory (e.g. the benches forcing cold solves)
    /// can never destroy previously persisted plans.
    pub fn clear(&mut self) {
        self.entries.clear();
    }

    /// Look up a plan and validate it against the profiles the caller is
    /// about to schedule with.  Any shape mismatch (stale file, changed
    /// model) is a miss.  A hit refreshes the entry's recency stamp.
    pub fn lookup(&mut self, key: &PlanKey, profiles: &[NodeProfile]) -> Option<Solution> {
        self.clock += 1;
        let clock = self.clock;
        let valid = self
            .entries
            .get_mut(key.as_str())
            .filter(|entry| plan_is_valid(&entry.plan, profiles))
            .map(|entry| {
                entry.stamp = clock;
                entry.plan.to_solution()
            });
        if valid.is_some() {
            self.hits += 1;
        } else {
            self.misses += 1;
        }
        valid
    }

    /// Memoize a fresh solve in memory, evicting the least-recently-used
    /// entries if this pushes the cache over its cap.  Persistence is a
    /// separate, explicit step ([`save`](PlanCache::save), or
    /// [`global_insert`] for the process-wide cache) so callers can keep
    /// disk I/O outside their locks.
    pub fn insert(&mut self, key: &PlanKey, solution: &Solution) {
        self.clock += 1;
        self.entries.insert(
            key.as_str().to_string(),
            Entry {
                plan: CachedPlan {
                    assignment: solution.assignment.clone(),
                    makespan_us: solution.makespan_us,
                },
                stamp: self.clock,
            },
        );
        self.evictions += evict_over_limit(&mut self.entries, self.limit) as u64;
    }

    /// Write the cache file (no-op for memory-only caches), merging the
    /// in-memory entries into whatever is currently on disk.
    pub fn save(&self) {
        if let Some(path) = &self.path {
            write_merged(path, self.entries.clone());
        }
    }

    /// Merge entries parsed from a cache file; malformed entries are
    /// skipped silently (forward/backward compatibility), and a file
    /// from a different [`SCHEMA_VERSION`] is dropped wholesale.  The
    /// load respects the entry cap (newest stamps win).
    fn absorb(&mut self, root: &Json) {
        if root.get("schema").and_then(Json::as_f64) != Some(SCHEMA_VERSION) {
            return;
        }
        let Some(plans) = root.get("plans").and_then(Json::as_obj) else { return };
        for (key, entry) in plans {
            let Some(makespan_us) = entry.get("makespan_us").and_then(Json::as_f64) else {
                continue;
            };
            let Some(raw) = entry.get("assignment").and_then(Json::as_arr) else { continue };
            // Clamp hostile/corrupt stamps: `as u64` saturates 1e300 to
            // u64::MAX, which would overflow the clock on the next tick
            // and (wrapping to 0 in release) make junk entries immortal
            // under LRU.  u32::MAX keeps ~2^64 ticks of headroom.
            let stamp = entry
                .get("stamp")
                .and_then(Json::as_f64)
                .filter(|s| s.is_finite() && *s >= 0.0)
                .map_or(0, |s| s.min(u32::MAX as f64) as u64);
            let mut assignment: Assignment = Vec::with_capacity(raw.len());
            let mut ok = true;
            for item in raw {
                let pair = item.as_arr().unwrap_or(&[]);
                let comp = pair
                    .first()
                    .and_then(Json::as_str)
                    .and_then(Component::from_name);
                let cand = pair.get(1).and_then(Json::as_usize);
                match (comp, cand) {
                    (Some(component), Some(candidate)) => {
                        assignment.push(Placement { component, candidate });
                    }
                    _ => {
                        ok = false;
                        break;
                    }
                }
            }
            if ok && makespan_us.is_finite() {
                self.clock = self.clock.max(stamp);
                self.entries.insert(
                    key.clone(),
                    Entry { plan: CachedPlan { assignment, makespan_us }, stamp },
                );
            }
        }
        self.evictions += evict_over_limit(&mut self.entries, self.limit) as u64;
    }
}

/// Drop least-recently-used entries until `entries` fits `limit`,
/// returning how many were dropped.  One sort + one retain — a
/// per-eviction min-scan would go quadratic when loading a file written
/// under a much larger cap.
fn evict_over_limit(entries: &mut HashMap<String, Entry>, limit: usize) -> usize {
    let limit = limit.max(1);
    let before = entries.len();
    if before <= limit {
        return 0;
    }
    let mut stamps: Vec<u64> = entries.values().map(|e| e.stamp).collect();
    stamps.sort_unstable_by(|a, b| b.cmp(a));
    let cutoff = stamps[limit - 1];
    // Stamps can tie (absorbed legacy entries default to 0): keep
    // everything strictly newer than the cutoff, then top up with
    // cutoff-stamped entries until the cap is exactly met.
    let mut slack = limit - stamps.iter().take_while(|&&s| s > cutoff).count();
    entries.retain(|_, e| {
        if e.stamp > cutoff {
            true
        } else if e.stamp == cutoff && slack > 0 {
            slack -= 1;
            true
        } else {
            false
        }
    });
    before - entries.len()
}

fn entries_to_json(entries: &HashMap<String, Entry>) -> Json {
    let mut plans = std::collections::BTreeMap::new();
    for (key, entry) in entries {
        let assignment: Vec<Json> = entry
            .plan
            .assignment
            .iter()
            .map(|p| {
                Json::Arr(vec![
                    Json::Str(p.component.name().to_string()),
                    Json::Num(p.candidate as f64),
                ])
            })
            .collect();
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("makespan_us".to_string(), Json::Num(entry.plan.makespan_us));
        obj.insert("assignment".to_string(), Json::Arr(assignment));
        obj.insert("stamp".to_string(), Json::Num(entry.stamp as f64));
        plans.insert(key.clone(), Json::Obj(obj));
    }
    let mut root = std::collections::BTreeMap::new();
    root.insert("schema".to_string(), Json::Num(SCHEMA_VERSION));
    root.insert("plans".to_string(), Json::Obj(plans));
    Json::Obj(root)
}

/// Merge `entries` into whatever is on disk at `path` (memory wins on
/// key conflicts) and write the union back, capped at the entry limit
/// (LRU evicted first).  Because saves merge, a memory-side
/// [`PlanCache::clear`] or a concurrent process can never truncate
/// previously persisted plans — a racing writer loses at most its own
/// last write.  Best-effort: an unwritable path must not take down the
/// planning service, the cache just stays memory-only.
fn write_merged(path: &Path, entries: HashMap<String, Entry>) {
    let mut disk = PlanCache::default();
    if let Ok(text) = std::fs::read_to_string(path) {
        if let Ok(root) = Json::parse(&text) {
            disk.absorb(&root);
        }
    }
    // Stamps are per-process logical clocks, so comparing this writer's
    // stamps against a foreign file's directly could evict the plans we
    // just computed in favor of another process's higher clock.
    // Re-stamp our entries above everything on disk (preserving their
    // relative recency) before applying the cap.
    let base = disk.entries.values().map(|e| e.stamp).max().unwrap_or(0);
    let mut fresh: Vec<(String, Entry)> = entries.into_iter().collect();
    fresh.sort_by_key(|(_, e)| e.stamp);
    for (i, (key, mut entry)) in fresh.into_iter().enumerate() {
        entry.stamp = base + 1 + i as u64;
        disk.entries.insert(key, entry);
    }
    let _ = evict_over_limit(&mut disk.entries, disk.limit);
    // Temp-sibling + rename: a crash mid-save must leave the previous
    // file intact, never a torn half-write that the schema check would
    // silently drop to a cold start (losing every persisted plan).
    let _ = crate::util::fsio::atomic_write(
        path,
        entries_to_json(&disk.entries).to_string().as_bytes(),
    );
}

/// Insert into the process-wide cache and persist it (when
/// `APDRL_PLAN_CACHE` is set), with the disk I/O performed *outside*
/// the cache lock so concurrent sweep workers doing lookups never block
/// behind the filesystem.
pub fn global_insert(key: &PlanKey, solution: &Solution) {
    let snapshot = {
        let mut guard = global().lock().unwrap();
        guard.insert(key, solution);
        guard.path.clone().map(|path| (path, guard.entries.clone()))
    };
    if let Some((path, entries)) = snapshot {
        write_merged(&path, entries);
    }
}

/// A cached assignment is only usable if every placement indexes a
/// candidate that exists in the profiles being scheduled.
fn plan_is_valid(plan: &CachedPlan, profiles: &[NodeProfile]) -> bool {
    plan.assignment.len() == profiles.len()
        && plan.assignment.iter().zip(profiles).all(|(p, prof)| match p.component {
            Component::PL => p.candidate < prof.pl.len(),
            Component::AIE => p.candidate < prof.aie.len(),
            Component::PS => p.candidate == 0,
        })
}

/// The process-wide plan cache used by `coordinator::static_phase`.
/// File-backed iff `APDRL_PLAN_CACHE` names a path at first use.
pub fn global() -> &'static Mutex<PlanCache> {
    static GLOBAL: OnceLock<Mutex<PlanCache>> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let cache = match std::env::var("APDRL_PLAN_CACHE") {
            Ok(path) if !path.is_empty() => PlanCache::with_persistence(path),
            _ => PlanCache::new(),
        };
        Mutex::new(cache)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, TrainSpec};
    use crate::hw::vek280;
    use crate::partition::{solve_ilp, Problem};
    use crate::profile::profile_dag;

    fn spec(batch: usize) -> TrainSpec {
        TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(&[4, 16, 2]),
            batch,
            obs_dim: 4,
            act_dim: 2,
        }
    }

    fn solved(batch: usize) -> (PlanKey, Solution, Vec<NodeProfile>) {
        let platform = vek280();
        let s = spec(batch);
        let dag = build_train_graph(&s);
        let profiles = profile_dag(&dag, &platform, true);
        let problem = Problem::new(&dag, &profiles, &platform, true);
        let solution = solve_ilp(&problem);
        (PlanKey::new(&s, true, &platform), solution, profiles)
    }

    #[test]
    fn key_separates_problem_dimensions() {
        let p = vek280();
        let base = PlanKey::new(&spec(64), true, &p);
        assert_eq!(base, PlanKey::new(&spec(64), true, &p));
        assert_ne!(base, PlanKey::new(&spec(128), true, &p), "batch must key");
        assert_ne!(base, PlanKey::new(&spec(64), false, &p), "precision must key");
        let mut other = spec(64);
        other.net = NetSpec::mlp(&[4, 32, 2]);
        assert_ne!(base, PlanKey::new(&other, true, &p), "net shape must key");
        let mut fx = crate::hw::fixar_platform();
        fx.pl_dsp = p.pl_dsp; // same pools, different clocks
        assert_ne!(base, PlanKey::new(&spec(64), true, &fx), "platform must key");
    }

    #[test]
    fn hit_returns_identical_plan_with_zero_explored() {
        let (key, solution, profiles) = solved(32);
        let mut cache = PlanCache::new();
        assert!(cache.lookup(&key, &profiles).is_none());
        cache.insert(&key, &solution);
        let hit = cache.lookup(&key, &profiles).expect("must hit after insert");
        assert_eq!(hit.assignment, solution.assignment);
        assert_eq!(hit.makespan_us.to_bits(), solution.makespan_us.to_bits());
        assert_eq!(hit.explored, 0);
        assert_eq!((cache.hits, cache.misses), (1, 1));
    }

    #[test]
    fn stale_shapes_degrade_to_miss() {
        let (key, solution, mut profiles) = solved(32);
        let mut cache = PlanCache::new();
        cache.insert(&key, &solution);
        // candidate list shrank (model changed) → candidate index invalid
        for prof in profiles.iter_mut() {
            prof.pl.clear();
            prof.aie.clear();
        }
        assert!(cache.lookup(&key, &profiles).is_none());
        // wrong node count → invalid
        let (_, _, longer) = solved(64);
        let truncated = &longer[..longer.len() - 1];
        assert!(cache.lookup(&key, truncated).is_none());
    }

    #[test]
    fn persistence_round_trips_bit_identically() {
        let (key, solution, profiles) = solved(32);
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let path = dir.join("plans.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut cache = PlanCache::with_persistence(&path);
            cache.insert(&key, &solution);
            cache.save();
        }
        let mut reloaded = PlanCache::with_persistence(&path);
        assert_eq!(reloaded.len(), 1);
        let hit = reloaded.lookup(&key, &profiles).expect("persisted plan must hit");
        assert_eq!(hit.assignment, solution.assignment);
        assert_eq!(hit.makespan_us.to_bits(), solution.makespan_us.to_bits());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn saves_merge_with_disk_so_clear_loses_nothing() {
        let (key_a, sol_a, profiles) = solved(32);
        let (key_b, sol_b, _) = solved(64);
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let path = dir.join("merge.json");
        let _ = std::fs::remove_file(&path);
        let mut cache = PlanCache::with_persistence(&path);
        cache.insert(&key_a, &sol_a);
        cache.save();
        // Memory cleared (as the cold-solve benches do), then a new plan
        // saved: the file must end up with the union, not just B.
        cache.clear();
        cache.insert(&key_b, &sol_b);
        cache.save();
        let mut reloaded = PlanCache::with_persistence(&path);
        assert_eq!(reloaded.len(), 2, "merge-on-save must keep A and add B");
        assert!(reloaded.lookup(&key_a, &profiles).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lru_cap_evicts_the_least_recently_used_plan() {
        let (key_a, sol_a, profiles_a) = solved(32);
        let (key_b, sol_b, _) = solved(48);
        let (key_c, sol_c, profiles_c) = solved(64);
        let mut cache = PlanCache::with_limit(2);
        cache.insert(&key_a, &sol_a);
        cache.insert(&key_b, &sol_b);
        // Touch A so B becomes the LRU entry, then overflow with C.
        assert!(cache.lookup(&key_a, &profiles_a).is_some());
        cache.insert(&key_c, &sol_c);
        assert_eq!(cache.len(), 2, "cap must hold");
        assert!(cache.lookup(&key_a, &profiles_a).is_some(), "recently used survives");
        assert!(cache.lookup(&key_c, &profiles_c).is_some(), "new entry survives");
        let (_, _, profiles_b) = solved(48);
        assert!(cache.lookup(&key_b, &profiles_b).is_none(), "LRU entry evicted");
    }

    #[test]
    fn eviction_counter_tracks_lru_drops() {
        let (key_a, sol_a, _) = solved(32);
        let (key_b, sol_b, _) = solved(48);
        let (key_c, sol_c, _) = solved(64);
        let mut cache = PlanCache::with_limit(2);
        cache.insert(&key_a, &sol_a);
        cache.insert(&key_b, &sol_b);
        assert_eq!(cache.evictions, 0, "under the cap nothing is evicted");
        cache.insert(&key_c, &sol_c);
        assert_eq!(cache.evictions, 1, "overflowing the cap evicts exactly one");
        // Re-inserting an existing key replaces in place: no eviction.
        cache.insert(&key_c, &sol_c);
        assert_eq!(cache.evictions, 1);
    }

    #[test]
    fn lru_eviction_survives_persist_and_reload() {
        // Fill past the cap with a recency pattern, persist, reload: the
        // entries missing from the reloaded cache must be exactly the
        // least-recently-used ones, and the persisted recency stamps
        // must keep ordering future evictions after the reload.
        let (key_a, sol_a, prof_a) = solved(8);
        let (key_b, sol_b, prof_b) = solved(16);
        let (key_c, sol_c, prof_c) = solved(24);
        let (key_d, sol_d, prof_d) = solved(32);
        let (key_e, sol_e, prof_e) = solved(40);
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let path = dir.join("lru_reload.json");
        let _ = std::fs::remove_file(&path);
        {
            let mut cache = PlanCache::with_persistence_limited(&path, 3);
            cache.insert(&key_a, &sol_a);
            cache.insert(&key_b, &sol_b);
            cache.insert(&key_c, &sol_c);
            // Touch A: recency is now B < C < A.
            assert!(cache.lookup(&key_a, &prof_a).is_some());
            // Overflow twice: B then C are the LRU victims.
            cache.insert(&key_d, &sol_d);
            cache.insert(&key_e, &sol_e);
            assert_eq!(cache.len(), 3);
            cache.save();
        }
        let mut reloaded = PlanCache::with_persistence_limited(&path, 3);
        assert_eq!(reloaded.len(), 3, "reload must carry exactly the capped set");
        assert!(reloaded.lookup(&key_a, &prof_a).is_some(), "touched entry survives");
        assert!(reloaded.lookup(&key_d, &prof_d).is_some());
        assert!(reloaded.lookup(&key_e, &prof_e).is_some());
        assert!(reloaded.lookup(&key_b, &prof_b).is_none(), "LRU entry B evicted");
        assert!(reloaded.lookup(&key_c, &prof_c).is_none(), "LRU entry C evicted");
        // Recency stamps persisted with the file: a *tighter* reload cap
        // evicts the on-disk LRU (A, untouched since before D and E).
        let mut tighter = PlanCache::with_persistence_limited(&path, 2);
        assert_eq!(tighter.len(), 2);
        assert!(tighter.lookup(&key_a, &prof_a).is_none(), "on-disk LRU evicted on load");
        assert!(tighter.lookup(&key_d, &prof_d).is_some());
        assert!(tighter.lookup(&key_e, &prof_e).is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn limit_parses_from_env_shape_with_fallback() {
        assert_eq!(limit_from(Some("2")), 2);
        assert_eq!(limit_from(Some(" 17 ")), 17);
        assert_eq!(limit_from(Some("0")), DEFAULT_MAX_ENTRIES, "0 is not a usable cap");
        assert_eq!(limit_from(Some("nope")), DEFAULT_MAX_ENTRIES);
        assert_eq!(limit_from(None), DEFAULT_MAX_ENTRIES);
    }

    #[test]
    fn old_schema_files_are_dropped_on_load() {
        // A v1-era file (pre-schema field, pre-stamps): entries must be
        // discarded wholesale, leaving a cold cache, not a misparse.
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("old_schema.json");
        std::fs::write(
            &path,
            r#"{"version":1,"plans":{"k":{"makespan_us":1.5,"assignment":[["PL",0]]}}}"#,
        )
        .unwrap();
        let cache = PlanCache::with_persistence(&path);
        assert!(cache.is_empty(), "old-schema entries must be dropped on load");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn persisted_file_carries_schema_and_stamps() {
        let (key, solution, _) = solved(32);
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let path = dir.join("schema.json");
        let _ = std::fs::remove_file(&path);
        let mut cache = PlanCache::with_persistence(&path);
        cache.insert(&key, &solution);
        cache.save();
        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.get("schema").and_then(Json::as_f64), Some(SCHEMA_VERSION));
        let plans = root.get("plans").and_then(Json::as_obj).unwrap();
        assert!(plans.values().all(|e| e.get("stamp").is_some()), "stamps must persist");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn corrupt_file_is_an_empty_cache() {
        let dir = std::env::temp_dir().join("apdrl_plan_cache_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("corrupt.json");
        std::fs::write(&path, "{not json").unwrap();
        let cache = PlanCache::with_persistence(&path);
        assert!(cache.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
