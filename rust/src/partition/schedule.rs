//! Event-driven schedule evaluator: the objective function of the ILP
//! (Eq. 3: T = max completion), made concrete.
//!
//! Each component executes serially (one kernel at a time — the paper's
//! per-component execution model); different components run in parallel.
//! Cross-component edges pay the `hw::comm` transfer cost, and in
//! quantized mode PL update nodes pay (partially overlapped)
//! master-weight synchronization — the ≥22 % effect of Table IV.

use crate::hw::Component;
use crate::quant::master::sync_overhead;
use crate::Micros;

use super::model::{Assignment, Problem};

/// One scheduled node (Fig 14's Gantt rows).
#[derive(Clone, Debug)]
pub struct ScheduleEntry {
    pub node: usize,
    pub component: Component,
    pub start_us: Micros,
    pub finish_us: Micros,
}

/// Full evaluation result.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub entries: Vec<ScheduleEntry>,
    pub makespan_us: Micros,
    /// Total time spent on cross-component transfers.
    pub comm_us: Micros,
    /// Total un-overlapped master-weight sync time.
    pub sync_us: Micros,
}

/// Evaluate `assignment` against `problem`, producing the schedule.
///
/// List scheduling in topological order with per-component availability;
/// node start = max(component free, preds' finish + edge comm).
pub fn evaluate(problem: &Problem, assignment: &Assignment) -> Schedule {
    let dag = problem.dag;
    assert_eq!(assignment.len(), dag.len());
    let order = dag.topo_order();
    let mut finish = vec![0.0f64; dag.len()];
    let mut free: [Micros; 3] = [0.0; 3];
    let comp_idx = |c: Component| match c {
        Component::PS => 0,
        Component::PL => 1,
        Component::AIE => 2,
    };
    let mut entries = Vec::with_capacity(dag.len());
    let mut comm_total = 0.0;
    let mut sync_total = 0.0;

    // Process in topo order, but pick the ready node with the earliest
    // possible start among those whose preds are done (list scheduling).
    let mut done = vec![false; dag.len()];
    let mut remaining: Vec<usize> = order.clone();
    while !remaining.is_empty() {
        // find ready nodes
        let mut best: Option<(usize, usize, Micros, Micros)> = None; // (pos, node, start, dur)
        for (pos, &i) in remaining.iter().enumerate() {
            if !dag.preds[i].iter().all(|&p| done[p]) {
                continue;
            }
            let place = assignment[i];
            let mut ready = 0.0f64;
            for &p in &dag.preds[i] {
                let pfmt = match assignment[p].component {
                    Component::PS => crate::hw::Format::Fp32,
                    c => {
                        if problem.quantized {
                            c.native_format()
                        } else {
                            crate::hw::Format::Fp32
                        }
                    }
                };
                let bytes = dag.nodes[p].out_elems as f64 * pfmt.bytes() as f64;
                let comm = problem.platform.comm.edge_cost(
                    assignment[p].component,
                    place.component,
                    bytes,
                );
                ready = ready.max(finish[p] + comm);
            }
            let start = ready.max(free[comp_idx(place.component)]);
            let mut dur = problem.latency(i, place);
            if problem.quantized {
                dur += sync_overhead(
                    &problem.platform.comm,
                    &dag.nodes[i],
                    place.component,
                    dur,
                    problem.platform.pl.init_us,
                );
            }
            match best {
                None => best = Some((pos, i, start, dur)),
                Some((_, _, s, _)) if start < s => best = Some((pos, i, start, dur)),
                _ => {}
            }
        }
        let (pos, i, start, dur) = best.expect("ready node must exist in a DAG");
        remaining.swap_remove(pos);
        done[i] = true;
        finish[i] = start + dur;
        free[comp_idx(assignment[i].component)] = finish[i];
        // accounting
        let place = assignment[i];
        for &p in &dag.preds[i] {
            let bytes = dag.nodes[p].out_elems as f64 * 2.0;
            comm_total +=
                problem.platform.comm.edge_cost(assignment[p].component, place.component, bytes);
        }
        if problem.quantized {
            sync_total += sync_overhead(
                &problem.platform.comm,
                &dag.nodes[i],
                place.component,
                problem.latency(i, place),
                problem.platform.pl.init_us,
            );
        }
        entries.push(ScheduleEntry {
            node: i,
            component: place.component,
            start_us: start,
            finish_us: finish[i],
        });
    }

    let makespan = finish.iter().cloned().fold(0.0, f64::max);
    Schedule { entries, makespan_us: makespan, comm_us: comm_total, sync_us: sync_total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, NetSpec, TrainSpec};
    use crate::hw::vek280;
    use crate::partition::model::Placement;
    use crate::profile::profile_dag;

    fn setup(batch: usize) -> (crate::graph::Dag, Vec<crate::profile::NodeProfile>, crate::hw::Platform) {
        let spec = TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(&[4, 64, 64, 2]),
            batch,
            obs_dim: 4,
            act_dim: 2,
        };
        let dag = build_train_graph(&spec);
        let platform = vek280();
        let profs = profile_dag(&dag, &platform, true);
        (dag, profs, platform)
    }

    fn all_pl(problem: &Problem) -> Assignment {
        (0..problem.dag.len())
            .map(|i| {
                // fastest PL candidate
                let best = problem.profiles[i]
                    .pl
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.latency_us.partial_cmp(&b.1.latency_us).unwrap())
                    .unwrap()
                    .0;
                Placement { component: crate::hw::Component::PL, candidate: best }
            })
            .collect()
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let (dag, profs, platform) = setup(64);
        let problem = Problem::new(&dag, &profs, &platform, false);
        let a = all_pl(&problem);
        let sched = evaluate(&problem, &a);
        let cp = dag.critical_path(|i| problem.latency(i, a[i]));
        assert!(sched.makespan_us >= cp - 1e-9, "{} < {}", sched.makespan_us, cp);
    }

    #[test]
    fn single_component_serializes() {
        let (dag, profs, platform) = setup(64);
        let problem = Problem::new(&dag, &profs, &platform, false);
        let a = all_pl(&problem);
        let sched = evaluate(&problem, &a);
        let total: f64 = (0..dag.len()).map(|i| problem.latency(i, a[i])).sum();
        // everything on one component → makespan == sum of latencies
        assert!((sched.makespan_us - total).abs() < 1e-6);
    }

    #[test]
    fn no_component_overlap() {
        let (dag, profs, platform) = setup(256);
        let problem = Problem::new(&dag, &profs, &platform, true);
        // split: MM nodes with even id on AIE
        let a: Assignment = (0..dag.len())
            .map(|i| {
                if dag.nodes[i].kind.is_mm() && i % 2 == 0 {
                    Placement { component: crate::hw::Component::AIE, candidate: 0 }
                } else {
                    Placement { component: crate::hw::Component::PL, candidate: 0 }
                }
            })
            .collect();
        let sched = evaluate(&problem, &a);
        // per component, intervals must not overlap
        for c in [crate::hw::Component::PL, crate::hw::Component::AIE] {
            let mut spans: Vec<(f64, f64)> = sched
                .entries
                .iter()
                .filter(|e| e.component == c)
                .map(|e| (e.start_us, e.finish_us))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                assert!(w[1].0 >= w[0].1 - 1e-9, "overlap on {c:?}: {w:?}");
            }
        }
    }

    #[test]
    fn deps_respected_with_comm() {
        let (dag, profs, platform) = setup(64);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let a = all_pl(&problem);
        let sched = evaluate(&problem, &a);
        let start: Vec<f64> = {
            let mut v = vec![0.0; dag.len()];
            for e in &sched.entries {
                v[e.node] = e.start_us;
            }
            v
        };
        let fin: Vec<f64> = {
            let mut v = vec![0.0; dag.len()];
            for e in &sched.entries {
                v[e.node] = e.finish_us;
            }
            v
        };
        for i in 0..dag.len() {
            for &p in &dag.preds[i] {
                assert!(start[i] >= fin[p] - 1e-9);
            }
        }
    }

    #[test]
    fn quantized_sync_increases_makespan() {
        let (dag, profs, platform) = setup(64);
        let pq = Problem::new(&dag, &profs, &platform, true);
        let pf = Problem::new(&dag, &profs, &platform, false);
        let a = all_pl(&pq);
        let sq = evaluate(&pq, &a);
        let sf = evaluate(&pf, &a);
        assert!(sq.sync_us > 0.0);
        assert!(sq.makespan_us >= sf.makespan_us * 0.5); // sanity, not strict
    }
}
