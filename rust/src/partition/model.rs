//! Problem/solution types for the partitioning ILP.

use crate::graph::Dag;
use crate::hw::{Component, Platform};
use crate::profile::NodeProfile;
use crate::Micros;

/// Where one node runs: component + index into that component's DSE
/// candidate list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Placement {
    pub component: Component,
    pub candidate: usize,
}

/// Full assignment: one placement per DAG node.
pub type Assignment = Vec<Placement>;

/// A partitioning problem instance.
pub struct Problem<'a> {
    pub dag: &'a Dag,
    pub profiles: &'a [NodeProfile],
    pub platform: &'a Platform,
    /// AP-DRL quantized mode: PL nodes pay master-weight sync (Table IV).
    pub quantized: bool,
}

impl<'a> Problem<'a> {
    pub fn new(
        dag: &'a Dag,
        profiles: &'a [NodeProfile],
        platform: &'a Platform,
        quantized: bool,
    ) -> Self {
        assert_eq!(dag.len(), profiles.len());
        Problem { dag, profiles, platform, quantized }
    }

    /// Latency of `node` under `placement`.
    pub fn latency(&self, node: usize, p: Placement) -> Micros {
        let prof = &self.profiles[node];
        match p.component {
            Component::PL => prof.pl[p.candidate].latency_us,
            Component::AIE => prof.aie[p.candidate].latency_us,
            Component::PS => prof.ps_latency_us,
        }
    }

    /// Resource draw of `node` under `placement` (DSPs or tiles).
    pub fn resource(&self, node: usize, p: Placement) -> usize {
        let prof = &self.profiles[node];
        match p.component {
            Component::PL => prof.pl[p.candidate].resource,
            Component::AIE => prof.aie[p.candidate].resource,
            Component::PS => 0,
        }
    }

    /// kLUT draw of `node` under `placement` (AIE kernels still consume
    /// PL-side data-mover LUTs — CHARM).
    pub fn kluts(&self, node: usize, p: Placement) -> f64 {
        let prof = &self.profiles[node];
        match p.component {
            Component::PL => prof.pl[p.candidate].kluts,
            Component::AIE => prof.aie[p.candidate].kluts,
            Component::PS => 0.0,
        }
    }

    /// All placements available for `node` (PL candidates, then AIE).
    pub fn options(&self, node: usize) -> Vec<Placement> {
        let prof = &self.profiles[node];
        let mut out: Vec<Placement> = (0..prof.pl.len())
            .map(|c| Placement { component: Component::PL, candidate: c })
            .collect();
        out.extend(
            (0..prof.aie.len()).map(|c| Placement { component: Component::AIE, candidate: c }),
        );
        out
    }

    /// Minimum possible latency of `node` over all placements.
    pub fn min_latency(&self, node: usize) -> Micros {
        self.options(node)
            .into_iter()
            .map(|p| self.latency(node, p))
            .fold(f64::INFINITY, f64::min)
    }

    /// Check Eq. 7 capacity feasibility of a full assignment under the
    /// shared-accelerator semantics: the PL engine must be as wide as the
    /// widest PL node config, the AIE allocation as large as the largest
    /// tile request (see `profile::profile_dag`).
    pub fn feasible(&self, assignment: &Assignment) -> bool {
        let (mut dsp, mut tiles) = (0usize, 0usize);
        let mut kluts = 0.0f64;
        for (i, p) in assignment.iter().enumerate() {
            let prof = &self.profiles[i];
            match p.component {
                Component::PL => {
                    dsp = dsp.max(prof.pl[p.candidate].resource);
                    kluts = kluts.max(prof.pl[p.candidate].kluts);
                }
                Component::AIE => {
                    tiles = tiles.max(prof.aie[p.candidate].resource);
                }
                Component::PS => {}
            }
        }
        dsp <= self.platform.pl_dsp
            && tiles <= self.platform.aie_tiles
            && kluts <= self.platform.pl_kluts
    }
}

/// Solver output.
#[derive(Clone, Debug)]
pub struct Solution {
    pub assignment: Assignment,
    pub makespan_us: Micros,
    /// Nodes the solver explored (B&B statistics for the ablation bench).
    pub explored: usize,
}

impl Solution {
    /// Count of MM nodes assigned to AIE (Fig 15's reported quantity).
    pub fn aie_nodes(&self, dag: &Dag) -> usize {
        self.assignment
            .iter()
            .enumerate()
            .filter(|(i, p)| dag.nodes[*i].kind.is_mm() && p.component == Component::AIE)
            .count()
    }
}
