//! Exact branch-and-bound solver for the partitioning ILP (Eq. 2–7).
//!
//! Variables: for every node, one placement among its DSE candidates
//! (x_ijc with Σ = 1, Eq. 4); non-MM nodes only have PL candidates
//! (§IV-A pinning).  Objective: the schedule evaluator's makespan
//! (Eq. 3/5/6 with explicit communication); constraint: Eq. 7 resource
//! capacities.
//!
//! Bounding: a node-order by descending FLOPs; at each partial
//! assignment, prune when
//!   LB = critical-path(assigned latencies ∪ min latencies) ≥ best,
//! or when the remaining minimum resource demand cannot fit.  For
//! paper-scale DAGs (≤ ~40 nodes, ≤ 6 options each) this closes in
//! milliseconds; `max_explored` caps pathological cases and falls back
//! to HEFT (never triggered by the Table III workloads — asserted in
//! benches).

use crate::Micros;

use super::heuristics::heft;
use super::model::{Assignment, Placement, Problem, Solution};
use super::schedule::evaluate;

/// Exploration cap before falling back to HEFT.
const DEFAULT_MAX_EXPLORED: usize = 300_000;

pub fn solve_ilp(problem: &Problem) -> Solution {
    solve_ilp_capped(problem, DEFAULT_MAX_EXPLORED)
}

pub fn solve_ilp_capped(problem: &Problem, max_explored: usize) -> Solution {
    let n = problem.dag.len();
    // Branch order: MM nodes by descending FLOPs first (they decide the
    // makespan), then non-MM nodes (PL-pinned, only config choice).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let (ma, mb) = (problem.dag.nodes[a].kind.is_mm(), problem.dag.nodes[b].kind.is_mm());
        mb.cmp(&ma).then(
            problem.dag.nodes[b]
                .flops()
                .partial_cmp(&problem.dag.nodes[a].flops())
                .unwrap(),
        )
    });

    // Seed incumbent with HEFT — gives the B&B a strong initial bound.
    let seed = heft(problem);
    let best_assignment = seed.assignment.clone();
    let best_makespan = seed.makespan_us;

    // Precompute per-node options and min latencies.  Under the
    // shared-accelerator semantics every candidate fits the resource
    // pools by construction (profiler filters), so capacity never prunes
    // and the search is the paper's pure binary x_ij.
    let options: Vec<Vec<Placement>> = (0..n).map(|i| problem.options(i)).collect();
    let min_lat: Vec<Micros> = (0..n).map(|i| problem.min_latency(i)).collect();

    struct Ctx<'p, 'a> {
        problem: &'p Problem<'a>,
        order: Vec<usize>,
        options: Vec<Vec<Placement>>,
        min_lat: Vec<Micros>,
        explored: usize,
        max_explored: usize,
        best_makespan: Micros,
        best_assignment: Assignment,
        aborted: bool,
    }

    impl<'p, 'a> Ctx<'p, 'a> {
        /// Critical-path lower bound with assigned latencies where fixed.
        fn lower_bound(&self, assignment: &[Option<Placement>]) -> Micros {
            self.problem.dag.critical_path(|i| match assignment[i] {
                Some(p) => self.problem.latency(i, p),
                None => self.min_lat[i],
            })
        }

        fn dfs(&mut self, depth: usize, assignment: &mut Vec<Option<Placement>>) {
            if self.aborted {
                return;
            }
            self.explored += 1;
            if self.explored > self.max_explored {
                self.aborted = true;
                return;
            }
            if depth == self.order.len() {
                let full: Assignment = assignment.iter().map(|p| p.unwrap()).collect();
                let sched = evaluate(self.problem, &full);
                if sched.makespan_us < self.best_makespan {
                    self.best_makespan = sched.makespan_us;
                    self.best_assignment = full;
                }
                return;
            }
            if self.lower_bound(assignment) >= self.best_makespan {
                return;
            }
            let node = self.order[depth];
            // Sort options by latency so good solutions are found early.
            let mut opts = self.options[node].clone();
            opts.sort_by(|a, b| {
                self.problem
                    .latency(node, *a)
                    .partial_cmp(&self.problem.latency(node, *b))
                    .unwrap()
            });
            for p in opts {
                assignment[node] = Some(p);
                self.dfs(depth + 1, assignment);
                assignment[node] = None;
            }
        }
    }

    let mut ctx = Ctx {
        problem,
        order,
        options,
        min_lat,
        explored: 0,
        max_explored,
        best_makespan,
        best_assignment,
        aborted: false,
    };
    let mut assignment: Vec<Option<Placement>> = vec![None; n];
    ctx.dfs(0, &mut assignment);

    let incumbent = Solution {
        assignment: ctx.best_assignment,
        makespan_us: ctx.best_makespan,
        explored: ctx.explored,
    };
    if ctx.aborted {
        // Search was capped: polish the incumbent with local search so
        // large graphs still end near-optimal (B&B alone may be stuck at
        // the HEFT seed).
        super::heuristics::local_search(problem, incumbent)
    } else {
        incumbent
    }
}

/// Exhaustive enumeration (tests only — cross-checks B&B optimality).
pub fn solve_exhaustive(problem: &Problem) -> Solution {
    let n = problem.dag.len();
    let options: Vec<Vec<Placement>> = (0..n).map(|i| problem.options(i)).collect();
    let mut best: Option<(Micros, Assignment)> = None;
    let mut counter = vec![0usize; n];
    let mut explored = 0usize;
    loop {
        let assignment: Assignment =
            (0..n).map(|i| options[i][counter[i]]).collect();
        if problem.feasible(&assignment) {
            explored += 1;
            let m = evaluate(problem, &assignment).makespan_us;
            if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
                best = Some((m, assignment));
            }
        }
        // increment mixed-radix counter
        let mut i = 0;
        loop {
            if i == n {
                let (m, a) = best.expect("no feasible assignment");
                return Solution { assignment: a, makespan_us: m, explored };
            }
            counter[i] += 1;
            if counter[i] < options[i].len() {
                break;
            }
            counter[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, NetSpec, TrainSpec};
    use crate::hw::vek280;
    use crate::profile::profile_dag;

    fn problem_for(
        sizes: &[usize],
        batch: usize,
    ) -> (crate::graph::Dag, Vec<crate::profile::NodeProfile>, crate::hw::Platform) {
        let spec = TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(sizes),
            batch,
            obs_dim: sizes[0],
            act_dim: *sizes.last().unwrap(),
        };
        let dag = build_train_graph(&spec);
        let platform = vek280();
        let profs = profile_dag(&dag, &platform, true);
        (dag, profs, platform)
    }

    #[test]
    fn bnb_matches_exhaustive_small() {
        // 2-layer MLP → small DAG, exhaustive is feasible.
        let (dag, profs, platform) = problem_for(&[4, 8, 2], 16);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let bnb = solve_ilp(&problem);
        let exact = solve_exhaustive(&problem);
        assert!(
            (bnb.makespan_us - exact.makespan_us).abs() < 1e-6,
            "B&B {} vs exhaustive {}",
            bnb.makespan_us,
            exact.makespan_us
        );
    }

    #[test]
    fn bnb_never_worse_than_heft() {
        for &(h, bs) in &[(64usize, 64usize), (400, 256), (400, 1024)] {
            let (dag, profs, platform) = problem_for(&[8, h, h, 2], bs);
            let problem = Problem::new(&dag, &profs, &platform, true);
            let bnb = solve_ilp(&problem);
            let h_sol = super::super::heuristics::heft(&problem);
            assert!(
                bnb.makespan_us <= h_sol.makespan_us + 1e-6,
                "B&B {} worse than HEFT {}",
                bnb.makespan_us,
                h_sol.makespan_us
            );
        }
    }

    #[test]
    fn solution_is_feasible() {
        let (dag, profs, platform) = problem_for(&[8, 400, 300, 2], 512);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let sol = solve_ilp(&problem);
        assert!(problem.feasible(&sol.assignment));
        assert_eq!(sol.assignment.len(), dag.len());
    }

    #[test]
    fn small_net_prefers_pl_large_prefers_aie() {
        // Fig 15 / §V-C: low-FLOPs nets stay on the PL; high-FLOPs MM
        // nodes migrate to the AIE.
        let (dag_s, profs_s, platform) = problem_for(&[4, 64, 64, 2], 64);
        let p_s = Problem::new(&dag_s, &profs_s, &platform, true);
        let sol_s = solve_ilp(&p_s);
        assert_eq!(sol_s.aie_nodes(&dag_s), 0, "tiny net should be all-PL");

        let (dag_l, profs_l, platform2) = problem_for(&[8, 4096, 3072, 2], 1024);
        let p_l = Problem::new(&dag_l, &profs_l, &platform2, true);
        let sol_l = solve_ilp(&p_l);
        assert!(
            sol_l.aie_nodes(&dag_l) >= 4,
            "big net should use the AIE, got {}",
            sol_l.aie_nodes(&dag_l)
        );
    }

    #[test]
    fn batch_size_monotonicity() {
        // Fig 15: more AIE nodes as batch size grows.
        let mut prev = 0usize;
        for &bs in &[64usize, 256, 1024] {
            let (dag, profs, platform) = problem_for(&[8, 400, 300, 2], bs);
            let problem = Problem::new(&dag, &profs, &platform, true);
            let sol = solve_ilp(&problem);
            let aie = sol.aie_nodes(&dag);
            assert!(aie >= prev, "AIE nodes decreased: {prev} -> {aie} at bs={bs}");
            prev = aie;
        }
        assert!(prev > 0, "largest batch should use the AIE");
    }
}
