//! Exact branch-and-bound solver for the partitioning ILP (Eq. 2–7).
//!
//! Variables: for every node, one placement among its DSE candidates
//! (x_ijc with Σ = 1, Eq. 4); non-MM nodes only have PL candidates
//! (§IV-A pinning).  Objective: the schedule evaluator's makespan
//! (Eq. 3/5/6 with explicit communication); constraint: Eq. 7 resource
//! capacities.
//!
//! Bounding: a node-order by descending FLOPs; at each partial
//! assignment, prune when
//!   LB = critical-path(assigned latencies ∪ min latencies) ≥ best,
//! or when the remaining minimum resource demand cannot fit.  For
//! paper-scale DAGs (≤ ~40 nodes, ≤ 6 options each) this closes in
//! milliseconds; `max_explored` caps pathological cases and falls back
//! to a `local_search`-polished incumbent (never triggered by the
//! Table III workloads — asserted in benches).
//!
//! **Parallel search** ([`solve_ilp`]): the top of the search tree is
//! expanded breadth-first into fixed placement *prefixes* (the root
//! node's options, then the next node's, … until there are a few tasks
//! per worker).  A scoped-thread worker pool drains the prefix queue,
//! each worker running the same sequential DFS below its fixed prefix.
//! Workers share one incumbent makespan encoded as an `AtomicU64`
//! (f64 bits), so a bound improvement found by any worker immediately
//! tightens everyone's pruning.  Both modes search exactly, so
//! [`solve_ilp`] and [`solve_ilp_sequential`] always agree on the
//! optimal makespan (asserted in tests over the Table III combos).

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};

use crate::Micros;

use super::heuristics::heft;
use super::model::{Assignment, Placement, Problem, Solution};
use super::schedule::evaluate;

/// Exploration cap before falling back to the polished incumbent.
const DEFAULT_MAX_EXPLORED: usize = 300_000;

/// Upper bound on worker threads (the DAGs are small; past this the
/// queue-drain overhead outweighs the extra cores).
const MAX_WORKERS: usize = 16;

/// Fixed fallback for prefix tasks generated per worker: enough that an
/// unlucky worker stuck with a dense subtree does not serialize the
/// whole solve.  Once the process has solve-time telemetry
/// (`server::stats`), [`tasks_per_worker`] adapts the fan-out to the
/// observed tree sizes instead; this constant remains the cold-start
/// value.
const TASKS_PER_WORKER: usize = 4;

/// Prefix fan-out per worker: the telemetry-tuned hint when enough
/// solves have been observed, the fixed constant otherwise.  Fan-out
/// only shapes work division between workers — every fan-out is an
/// exact search, so the returned makespan is identical either way
/// (asserted in `fanout_choice_never_changes_the_optimum`).
fn tasks_per_worker() -> usize {
    crate::server::stats::tasks_per_worker_hint().unwrap_or(TASKS_PER_WORKER)
}

/// Shared incumbent makespan: f64 bits in an `AtomicU64`.  Workers only
/// ever store makespans of *evaluated complete assignments*, so the
/// bound stays exact; `try_improve` is a CAS loop keeping the minimum.
struct SharedBound {
    bits: AtomicU64,
}

impl SharedBound {
    fn new(initial: Micros) -> Self {
        SharedBound { bits: AtomicU64::new(initial.to_bits()) }
    }

    fn get(&self) -> Micros {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }

    /// Lower the bound to `m` if it improves it; true when `m` won.
    fn try_improve(&self, m: Micros) -> bool {
        let mut cur = self.bits.load(Ordering::Relaxed);
        loop {
            if m >= f64::from_bits(cur) {
                return false;
            }
            match self.bits.compare_exchange_weak(
                cur,
                m.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(seen) => cur = seen,
            }
        }
    }
}

/// Immutable search context shared by all workers of one solve.
struct SearchCtx<'p, 'a> {
    problem: &'p Problem<'a>,
    /// Branch order (MM nodes by descending FLOPs first).
    order: Vec<usize>,
    /// Per-node placement options, pre-sorted by ascending latency so
    /// good solutions are found early.
    options: Vec<Vec<Placement>>,
    min_lat: Vec<Micros>,
    bound: SharedBound,
    explored: AtomicUsize,
    max_explored: usize,
    aborted: AtomicBool,
}

impl<'p, 'a> SearchCtx<'p, 'a> {
    /// Critical-path lower bound with assigned latencies where fixed.
    fn lower_bound(&self, assignment: &[Option<Placement>]) -> Micros {
        self.problem.dag.critical_path(|i| match assignment[i] {
            Some(p) => self.problem.latency(i, p),
            None => self.min_lat[i],
        })
    }

    /// Sequential DFS below a fixed prefix.  `best` is the calling
    /// worker's local optimum (assignments are only kept locally; the
    /// shared state carries just the scalar bound).
    fn dfs(
        &self,
        depth: usize,
        assignment: &mut Vec<Option<Placement>>,
        best: &mut Option<(Micros, Assignment)>,
    ) {
        if self.aborted.load(Ordering::Relaxed) {
            return;
        }
        let seen = self.explored.fetch_add(1, Ordering::Relaxed) + 1;
        if seen > self.max_explored {
            self.aborted.store(true, Ordering::Relaxed);
            return;
        }
        if depth == self.order.len() {
            let full: Assignment = assignment.iter().map(|p| p.unwrap()).collect();
            let m = evaluate(self.problem, &full).makespan_us;
            // A NaN makespan (degenerate profile) must never become the
            // incumbent: it would disable all pruning and win every
            // comparison by vacuous falsehood.
            if m.is_finite() {
                self.bound.try_improve(m);
                if best.as_ref().map_or(true, |(b, _)| m < *b) {
                    *best = Some((m, full));
                }
            }
            return;
        }
        if self.lower_bound(assignment) >= self.bound.get() {
            return;
        }
        let node = self.order[depth];
        for &p in &self.options[node] {
            assignment[node] = Some(p);
            self.dfs(depth + 1, assignment, best);
            assignment[node] = None;
        }
    }

    /// Run the DFS under one prefix of placements for `order[0..k]`.
    fn run_prefix(&self, prefix: &[Placement], best: &mut Option<(Micros, Assignment)>) {
        let mut assignment: Vec<Option<Placement>> = vec![None; self.problem.dag.len()];
        for (d, &p) in prefix.iter().enumerate() {
            assignment[self.order[d]] = Some(p);
        }
        self.dfs(prefix.len(), &mut assignment, best);
    }
}

pub fn solve_ilp(problem: &Problem) -> Solution {
    solve_ilp_capped(problem, DEFAULT_MAX_EXPLORED)
}

/// Parallel solve with an explicit exploration cap.
pub fn solve_ilp_capped(problem: &Problem, max_explored: usize) -> Solution {
    solve(problem, max_explored, worker_count())
}

/// Single-threaded solve — the reference the parallel path is tested
/// against (identical makespans) and a determinism escape hatch.
pub fn solve_ilp_sequential(problem: &Problem, max_explored: usize) -> Solution {
    solve(problem, max_explored, 1)
}

/// Default-cap solve with an explicit worker count.  The planning
/// service passes 1 from inside its own `plan_sweep` fan-out so the two
/// parallelism levels don't multiply into cores × B&B-workers threads.
pub fn solve_ilp_with_workers(problem: &Problem, workers: usize) -> Solution {
    solve(problem, DEFAULT_MAX_EXPLORED, workers.max(1))
}

fn worker_count() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(MAX_WORKERS)
}

fn solve(problem: &Problem, max_explored: usize, workers: usize) -> Solution {
    let t0 = std::time::Instant::now();
    let n = problem.dag.len();
    // Branch order: MM nodes by descending FLOPs first (they decide the
    // makespan), then non-MM nodes (PL-pinned, only config choice).
    // NaN-safe: total_cmp, not partial_cmp().unwrap() — a degenerate
    // profile latency must not panic the solver.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let (ma, mb) = (problem.dag.nodes[a].kind.is_mm(), problem.dag.nodes[b].kind.is_mm());
        mb.cmp(&ma)
            .then(problem.dag.nodes[b].flops().total_cmp(&problem.dag.nodes[a].flops()))
    });

    // Seed incumbent with HEFT — gives the B&B a strong initial bound
    // and guarantees the result is never worse than the heuristic.
    let seed = heft(problem);

    // Per-node options sorted by latency (shared-accelerator semantics:
    // every candidate fits the pools by construction, so capacity never
    // prunes and the search is the paper's pure binary x_ij).
    let options: Vec<Vec<Placement>> = (0..n)
        .map(|i| {
            let mut opts = problem.options(i);
            opts.sort_by(|a, b| {
                problem.latency(i, *a).total_cmp(&problem.latency(i, *b))
            });
            opts
        })
        .collect();
    let min_lat: Vec<Micros> = (0..n).map(|i| problem.min_latency(i)).collect();

    // The cap bounds wall time; parallel workers drain nodes
    // concurrently (and redundantly explore a little until the shared
    // bound tightens), so the node budget scales with the worker count
    // to keep its wall-time meaning stable across both modes.
    let workers = workers.max(1);
    let ctx = SearchCtx {
        problem,
        order,
        options,
        min_lat,
        bound: SharedBound::new(seed.makespan_us),
        explored: AtomicUsize::new(0),
        max_explored: max_explored.saturating_mul(workers),
        aborted: AtomicBool::new(false),
    };

    // Expand the top of the tree into prefix tasks (in option-sorted
    // order, so sequential mode explores exactly like a plain DFS).
    // The per-worker task count is tuned from solve telemetry.
    let prefixes = expand_prefixes(&ctx, workers * tasks_per_worker());

    let mut local_bests: Vec<Option<(Micros, Assignment)>> = Vec::new();
    if workers <= 1 || prefixes.len() <= 1 {
        let mut best = None;
        for prefix in &prefixes {
            ctx.run_prefix(prefix, &mut best);
        }
        local_bests.push(best);
    } else {
        let next = AtomicUsize::new(0);
        let threads = workers.min(prefixes.len());
        std::thread::scope(|s| {
            let handles: Vec<_> = (0..threads)
                .map(|_| {
                    s.spawn(|| {
                        let mut best = None;
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            match prefixes.get(i) {
                                Some(prefix) => ctx.run_prefix(prefix, &mut best),
                                None => break,
                            }
                        }
                        best
                    })
                })
                .collect();
            for h in handles {
                local_bests.push(h.join().expect("B&B worker panicked"));
            }
        });
    }

    // Global winner: best across workers, never worse than the seed.
    let mut best_makespan = seed.makespan_us;
    let mut best_assignment = seed.assignment;
    for found in local_bests.into_iter().flatten() {
        // `|| is_nan()` displaces a NaN HEFT seed with any finite result.
        if found.0 < best_makespan || best_makespan.is_nan() {
            best_makespan = found.0;
            best_assignment = found.1;
        }
    }

    let incumbent = Solution {
        assignment: best_assignment,
        makespan_us: best_makespan,
        explored: ctx.explored.load(Ordering::Relaxed),
    };
    // Feed the telemetry that tunes future fan-outs (and that the
    // planning server's `stats` verb reports).
    crate::server::stats::record_solve(incumbent.explored, t0.elapsed());
    if ctx.aborted.load(Ordering::Relaxed) {
        // Search was capped: polish the incumbent with local search so
        // large graphs still end near-optimal (B&B alone may be stuck at
        // the HEFT seed).
        super::heuristics::local_search(problem, incumbent)
    } else {
        incumbent
    }
}

/// Breadth-first expansion of the first few branch levels into fixed
/// placement prefixes (at least `target` of them, options permitting).
/// Each prefix becomes one worker task.
fn expand_prefixes(ctx: &SearchCtx, target: usize) -> Vec<Vec<Placement>> {
    let mut prefixes: Vec<Vec<Placement>> = vec![Vec::new()];
    let mut depth = 0;
    while prefixes.len() < target && depth < ctx.order.len() {
        let node = ctx.order[depth];
        if ctx.options[node].is_empty() {
            // No feasible placement: nothing below this level can be
            // completed; keep the (doomed) prefixes for the DFS to report.
            break;
        }
        let mut next = Vec::with_capacity(prefixes.len() * ctx.options[node].len());
        for prefix in &prefixes {
            for &p in &ctx.options[node] {
                let mut np = prefix.clone();
                np.push(p);
                next.push(np);
            }
        }
        prefixes = next;
        depth += 1;
    }
    prefixes
}

/// Exhaustive enumeration (tests only — cross-checks B&B optimality).
pub fn solve_exhaustive(problem: &Problem) -> Solution {
    let n = problem.dag.len();
    let options: Vec<Vec<Placement>> = (0..n).map(|i| problem.options(i)).collect();
    let mut best: Option<(Micros, Assignment)> = None;
    let mut counter = vec![0usize; n];
    let mut explored = 0usize;
    loop {
        let assignment: Assignment =
            (0..n).map(|i| options[i][counter[i]]).collect();
        if problem.feasible(&assignment) {
            explored += 1;
            let m = evaluate(problem, &assignment).makespan_us;
            if best.as_ref().map_or(true, |(bm, _)| m < *bm) {
                best = Some((m, assignment));
            }
        }
        // increment mixed-radix counter
        let mut i = 0;
        loop {
            if i == n {
                let (m, a) = best.expect("no feasible assignment");
                return Solution { assignment: a, makespan_us: m, explored };
            }
            counter[i] += 1;
            if counter[i] < options[i].len() {
                break;
            }
            counter[i] = 0;
            i += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, NetSpec, TrainSpec};
    use crate::hw::vek280;
    use crate::profile::profile_dag;

    fn problem_for(
        sizes: &[usize],
        batch: usize,
    ) -> (crate::graph::Dag, Vec<crate::profile::NodeProfile>, crate::hw::Platform) {
        let spec = TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(sizes),
            batch,
            obs_dim: sizes[0],
            act_dim: *sizes.last().unwrap(),
        };
        let dag = build_train_graph(&spec);
        let platform = vek280();
        let profs = profile_dag(&dag, &platform, true);
        (dag, profs, platform)
    }

    #[test]
    fn bnb_matches_exhaustive_small() {
        // 2-layer MLP → small DAG, exhaustive is feasible.
        let (dag, profs, platform) = problem_for(&[4, 8, 2], 16);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let bnb = solve_ilp(&problem);
        let exact = solve_exhaustive(&problem);
        assert!(
            (bnb.makespan_us - exact.makespan_us).abs() < 1e-6,
            "B&B {} vs exhaustive {}",
            bnb.makespan_us,
            exact.makespan_us
        );
    }

    #[test]
    fn bnb_never_worse_than_heft() {
        for &(h, bs) in &[(64usize, 64usize), (400, 256), (400, 1024)] {
            let (dag, profs, platform) = problem_for(&[8, h, h, 2], bs);
            let problem = Problem::new(&dag, &profs, &platform, true);
            let bnb = solve_ilp(&problem);
            let h_sol = super::super::heuristics::heft(&problem);
            assert!(
                bnb.makespan_us <= h_sol.makespan_us + 1e-6,
                "B&B {} worse than HEFT {}",
                bnb.makespan_us,
                h_sol.makespan_us
            );
        }
    }

    #[test]
    fn parallel_matches_sequential_on_table3_combos() {
        // The parallel prefix fan-out and the plain DFS are both exact
        // searches: equal optimal makespans, always.
        use crate::coordinator::config::combo;
        use crate::partition::Problem;
        for name in ["dqn_cartpole", "a2c_invpend", "ddpg_lunar", "ddpg_mntncar"] {
            let c = combo(name);
            let dag = build_train_graph(&c.train_spec(c.batch));
            let platform = vek280();
            let profs = profile_dag(&dag, &platform, true);
            let problem = Problem::new(&dag, &profs, &platform, true);
            // Generous cap: equality is only guaranteed when neither
            // search aborts (parallel workers can explore a few times
            // more nodes than the DFS before the shared bound tightens).
            let par = solve_ilp_capped(&problem, 2_000_000);
            let seq = solve_ilp_sequential(&problem, 2_000_000);
            assert!(
                (par.makespan_us - seq.makespan_us).abs() < 1e-9,
                "{name}: parallel {} vs sequential {}",
                par.makespan_us,
                seq.makespan_us
            );
        }
    }

    #[test]
    fn capped_search_falls_back_but_never_below_heft() {
        // Regression: with the exploration cap slammed shut the solver
        // must return the (local_search-polished) HEFT incumbent, never
        // anything worse.
        let (dag, profs, platform) = problem_for(&[8, 400, 300, 2], 512);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let heft_sol = super::super::heuristics::heft(&problem);
        for cap in [1usize, 5, 50, 500] {
            for sol in [
                solve_ilp_capped(&problem, cap),
                solve_ilp_sequential(&problem, cap),
            ] {
                assert!(
                    sol.makespan_us <= heft_sol.makespan_us + 1e-6,
                    "cap {cap}: {} worse than HEFT {}",
                    sol.makespan_us,
                    heft_sol.makespan_us
                );
                assert!(problem.feasible(&sol.assignment));
            }
        }
    }

    #[test]
    fn fanout_choice_never_changes_the_optimum() {
        // The telemetry-tuned fan-out only re-divides the exact search;
        // every band the tuner can pick must return the same optimum as
        // the sequential reference.
        let (dag, profs, platform) = problem_for(&[8, 400, 300, 2], 256);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let reference = solve_ilp_sequential(&problem, 2_000_000);
        crate::server::stats::reset_telemetry_for_tests();
        for explored_band in [1_000usize, 20_000, 500_000] {
            crate::server::stats::reset_telemetry_for_tests();
            for _ in 0..8 {
                crate::server::stats::record_solve(
                    explored_band,
                    std::time::Duration::from_micros(100),
                );
            }
            let tuned = solve_ilp_capped(&problem, 2_000_000);
            assert!(
                (tuned.makespan_us - reference.makespan_us).abs() < 1e-9,
                "fan-out band {explored_band}: {} vs {}",
                tuned.makespan_us,
                reference.makespan_us
            );
        }
        crate::server::stats::reset_telemetry_for_tests();
    }

    #[test]
    fn nan_latency_does_not_panic_the_solver() {
        // A degenerate profile (NaN latency on one candidate) used to
        // panic in the partial_cmp().unwrap() sorts; total_cmp orders it
        // deterministically instead.
        let (dag, mut profs, platform) = problem_for(&[4, 8, 2], 16);
        if let Some(c) = profs[0].pl.first_mut() {
            c.latency_us = f64::NAN;
        }
        let problem = Problem::new(&dag, &profs, &platform, true);
        let sol = solve_ilp(&problem);
        assert_eq!(sol.assignment.len(), dag.len());
    }

    #[test]
    fn solution_is_feasible() {
        let (dag, profs, platform) = problem_for(&[8, 400, 300, 2], 512);
        let problem = Problem::new(&dag, &profs, &platform, true);
        let sol = solve_ilp(&problem);
        assert!(problem.feasible(&sol.assignment));
        assert_eq!(sol.assignment.len(), dag.len());
    }

    #[test]
    fn small_net_prefers_pl_large_prefers_aie() {
        // Fig 15 / §V-C: low-FLOPs nets stay on the PL; high-FLOPs MM
        // nodes migrate to the AIE.
        let (dag_s, profs_s, platform) = problem_for(&[4, 64, 64, 2], 64);
        let p_s = Problem::new(&dag_s, &profs_s, &platform, true);
        let sol_s = solve_ilp(&p_s);
        assert_eq!(sol_s.aie_nodes(&dag_s), 0, "tiny net should be all-PL");

        let (dag_l, profs_l, platform2) = problem_for(&[8, 4096, 3072, 2], 1024);
        let p_l = Problem::new(&dag_l, &profs_l, &platform2, true);
        let sol_l = solve_ilp(&p_l);
        assert!(
            sol_l.aie_nodes(&dag_l) >= 4,
            "big net should use the AIE, got {}",
            sol_l.aie_nodes(&dag_l)
        );
    }

    #[test]
    fn batch_size_monotonicity() {
        // Fig 15: more AIE nodes as batch size grows.
        let mut prev = 0usize;
        for &bs in &[64usize, 256, 1024] {
            let (dag, profs, platform) = problem_for(&[8, 400, 300, 2], bs);
            let problem = Problem::new(&dag, &profs, &platform, true);
            let sol = solve_ilp(&problem);
            let aie = sol.aie_nodes(&dag);
            assert!(aie >= prev, "AIE nodes decreased: {prev} -> {aie} at bs={bs}");
            prev = aie;
        }
        assert!(prev > 0, "largest batch should use the AIE");
    }
}
