//! Bit-exact software emulation of the coordinated formats (paper Fig 3 /
//! Table II), mirroring `python/compile/kernels/quantize.py` so the
//! coordinator can reason about on-the-wire values without PJRT.
//!
//! Two independent implementations of each rounding:
//!
//! * the scalar reference path ([`bf16_round`], [`fp16_round`] via the
//!   explicit [`f32_to_f16`]/[`f16_to_f32`] codec) — readable,
//!   case-by-case, used element-wise;
//! * the [`round_slice`] fast path — branch-free bit manipulation on
//!   `u32` lanes, chunked so the compiler auto-vectorizes it.  This is
//!   what the executor's hot loops (`Tensor::round_to`, the per-layer
//!   format hooks, Adam's master-weight round-trips) run through.
//!
//! The two are pinned bit-identical by the exhaustive tests below (all
//! 65,536 binary16 patterns, the bf16 RNE reference sweep, and random
//! full-width bit patterns) — that equivalence is what lets the fast
//! path replace the scalar one without perturbing the loss-scale FSM.

use crate::hw::Format;

/// Round-to-nearest-even f32 -> bf16 -> f32 (AIE-ML storage format).
pub fn bf16_round(x: f32) -> f32 {
    if x.is_nan() {
        return x;
    }
    let bits = x.to_bits();
    let rounding_bias = ((bits >> 16) & 1).wrapping_add(0x7FFF);
    f32::from_bits(bits.wrapping_add(rounding_bias) & 0xFFFF_0000)
}

/// f32 -> IEEE binary16 -> f32 (PL/DSP compute format), RNE with
/// overflow→±inf and subnormal flushing handled by the conversion.
pub fn fp16_round(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

/// IEEE 754 binary16 encode (RNE).
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let frac = bits & 0x7F_FFFF;
    if exp == 0xFF {
        // inf / nan
        return sign | 0x7C00 | if frac != 0 { 0x200 } else { 0 };
    }
    // unbiased exponent
    let e = exp - 127;
    if e > 15 {
        return sign | 0x7C00; // overflow -> inf
    }
    if e >= -14 {
        // normal: 10-bit mantissa, RNE on the dropped 13 bits
        let mant = frac >> 13;
        let rest = frac & 0x1FFF;
        let half = 0x1000;
        let mut m = ((e + 15) as u32) << 10 | mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            m += 1; // may carry into exponent — that's correct rounding
        }
        return sign | m as u16;
    }
    if e >= -25 {
        // Subnormal.  e == -25 values can still round *up* to the
        // smallest subnormal 2⁻²⁴ (anything strictly above the 2⁻²⁵
        // midpoint does; the exact tie goes to even, i.e. zero) — an
        // earlier cut at -24 flushed that whole band to zero, which is
        // not round-to-nearest-even.
        let shift = (-14 - e) as u32; // 1..=11 additional shift
        let full = frac | 0x80_0000; // implicit leading 1
        let mant = full >> (13 + shift);
        let rest = full & ((1 << (13 + shift)) - 1);
        let half = 1u32 << (12 + shift);
        let mut m = mant;
        if rest > half || (rest == half && (mant & 1) == 1) {
            m += 1;
        }
        return sign | m as u16;
    }
    sign // underflow -> ±0
}

/// IEEE 754 binary16 decode.
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let frac = (h & 0x3FF) as u32;
    let bits = match (exp, frac) {
        (0, 0) => sign,
        (0, f) => {
            // subnormal: value = f · 2⁻²⁴; normalize into f32.
            let p = 31 - f.leading_zeros(); // MSB position of f
            let e = p + 103; // (p - 24) + 127
            let frac32 = ((f ^ (1 << p)) << (23 - p)) & 0x7F_FFFF;
            sign | (e << 23) | frac32
        }
        (0x1F, 0) => sign | 0x7F80_0000,
        (0x1F, f) => sign | 0x7F80_0000 | (f << 13) | 0x40_0000,
        (e, f) => sign | ((e + 127 - 15) << 23) | (f << 13),
    };
    f32::from_bits(bits)
}

/// Round a value into a coordinated format (identity for FP32/FX16 —
/// FIXAR's fixed-point rounding lives in the baseline model).
pub fn round_to(x: f32, fmt: Format) -> f32 {
    match fmt {
        Format::Fp32 | Format::Fx16 => x,
        Format::Bf16 => bf16_round(x),
        Format::Fp16 => fp16_round(x),
    }
}

// ------------------------------------------------------------------------
// Vectorized slice rounding: branch-free per-lane bit manipulation so
// the chunked loops below auto-vectorize.  Bit-identical to the scalar
// reference path — asserted exhaustively in the tests.

/// Branch-free select: `mask ? a : b` with an all-ones/all-zeros mask.
#[inline(always)]
fn lane_select(mask: u32, a: u32, b: u32) -> u32 {
    (a & mask) | (b & !mask)
}

/// All-ones when `cond`, else zero.
#[inline(always)]
fn lane_mask(cond: bool) -> u32 {
    (cond as u32).wrapping_neg()
}

/// One f32 bit pattern → the bit pattern of its nearest bf16 value
/// (RNE, NaN passthrough) — bit-identical to [`bf16_round`].
#[inline(always)]
fn bf16_round_bits(bits: u32) -> u32 {
    let bias = ((bits >> 16) & 1).wrapping_add(0x7FFF);
    let rounded = bits.wrapping_add(bias) & 0xFFFF_0000;
    // NaN (mag above the inf pattern) passes through unchanged: the
    // bias add could otherwise carry a payload into the exponent.
    let nan = lane_mask((bits & 0x7FFF_FFFF) > 0x7F80_0000);
    lane_select(nan, bits, rounded)
}

/// One f32 bit pattern → the bit pattern of `f16_to_f32(f32_to_f16(x))`
/// (RNE with overflow→±inf, subnormals, NaN canonicalized to the quiet
/// pattern) — bit-identical to [`fp16_round`], without the per-case
/// branches:
///
/// * normal range: add `0xFFF + lsb(bit 13)` below the 13 dropped
///   mantissa bits — the classic RNE-by-addition trick; the carry
///   walks into the exponent exactly like the scalar encoder's;
/// * overflow: any rounded magnitude ≥ 2¹⁶ selects ±inf;
/// * subnormals: `(|x| + 0.5) - 0.5` — the sum's ULP at exponent −1 is
///   2⁻²⁴ (one f16 subnormal step), so the f32 addition itself performs
///   the RNE quantization and the Sterbenz-exact subtraction recovers
///   the rounded value.
#[inline(always)]
fn fp16_round_bits(bits: u32) -> u32 {
    let sign = bits & 0x8000_0000;
    let mag = bits & 0x7FFF_FFFF;
    // Normal path (also maps inf → inf via the overflow select).
    let rounded = (mag + (0xFFF + ((mag >> 13) & 1))) & 0xFFFF_E000;
    let inf = lane_mask(rounded >= 0x4780_0000);
    let normal = lane_select(inf, 0x7F80_0000, rounded);
    // Subnormal path (computed unconditionally; NaN lanes are benign).
    let sub = ((f32::from_bits(mag) + 0.5) - 0.5).to_bits();
    let finite = lane_select(lane_mask(mag < 0x3880_0000), sub, normal);
    let nan = lane_mask(mag > 0x7F80_0000);
    sign | lane_select(nan, 0x7FC0_0000, finite)
}

/// In-place slice rounding into `fmt` — the fast path behind
/// [`crate::exec::Tensor::round_to`], the per-layer format hooks and
/// the optimizer's master-weight round-trips.  Identity for FP32/FX16;
/// otherwise bit-identical to mapping [`round_to`] over the slice
/// (including ±inf overflow surfacing and NaN handling), at vector
/// throughput: fixed-width chunks of branch-free lane ops plus a
/// scalar-shaped tail for unaligned lengths.
pub fn round_slice(xs: &mut [f32], fmt: Format) {
    match fmt {
        Format::Fp32 | Format::Fx16 => {}
        Format::Bf16 => round_lanes(xs, bf16_round_bits),
        Format::Fp16 => round_lanes(xs, fp16_round_bits),
    }
}

/// Apply a lane function over fixed-size chunks (vectorizable: the
/// chunk trip count is compile-time constant) plus the remainder.
#[inline]
fn round_lanes(xs: &mut [f32], lane: impl Fn(u32) -> u32 + Copy) {
    const LANES: usize = 16;
    let mut chunks = xs.chunks_exact_mut(LANES);
    for chunk in &mut chunks {
        for x in chunk.iter_mut() {
            *x = f32::from_bits(lane(x.to_bits()));
        }
    }
    for x in chunks.into_remainder() {
        *x = f32::from_bits(lane(x.to_bits()));
    }
}

/// Table II rows, used by the `figures table2` emitter and asserted in
/// tests.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FormatInfo {
    pub name: &'static str,
    pub sign_bits: u32,
    pub exp_bits: u32,
    pub frac_bits: u32,
    pub exp_min: i32,
    pub exp_max: i32,
    pub bytes: usize,
    pub needs_master_weight: bool,
    pub needs_loss_scaling: bool,
}

pub fn format_info(fmt: Format) -> FormatInfo {
    match fmt {
        Format::Fp16 => FormatInfo {
            name: "FP16",
            sign_bits: 1,
            exp_bits: 5,
            frac_bits: 10,
            exp_min: -14,
            exp_max: 15,
            bytes: 2,
            needs_master_weight: true,
            needs_loss_scaling: true,
        },
        Format::Fp32 => FormatInfo {
            name: "FP32",
            sign_bits: 1,
            exp_bits: 8,
            frac_bits: 23,
            exp_min: -126,
            exp_max: 127,
            bytes: 4,
            needs_master_weight: false,
            needs_loss_scaling: false,
        },
        Format::Bf16 => FormatInfo {
            name: "BF16",
            sign_bits: 1,
            exp_bits: 8,
            frac_bits: 7,
            exp_min: -126,
            exp_max: 127,
            bytes: 2,
            needs_master_weight: false,
            needs_loss_scaling: false,
        },
        Format::Fx16 => FormatInfo {
            name: "FX16",
            sign_bits: 1,
            exp_bits: 0,
            frac_bits: 15,
            exp_min: 0,
            exp_max: 0,
            bytes: 2,
            needs_master_weight: true,
            needs_loss_scaling: false,
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::forall;

    #[test]
    fn bf16_known_values() {
        assert_eq!(bf16_round(1.0), 1.0);
        assert_eq!(bf16_round(-2.5), -2.5);
        // 1.00390625 = 1 + 2^-8 rounds to 1.0 (ties-to-even on bit 16)
        assert_eq!(bf16_round(1.003_906_25), 1.0);
        // 1.01171875 = 1 + 3·2^-8 rounds up to 1 + 2^-7 + 2^-8? → nearest bf16
        let r = bf16_round(1.011_718_75);
        assert!((r == 1.007_812_5) || (r == 1.015_625));
    }

    #[test]
    fn bf16_preserves_exponent_range() {
        for &x in &[1e38f32, -1e38, 1e-38, -1e-38] {
            let r = bf16_round(x);
            assert!(r.is_finite() && r != 0.0, "{x} -> {r}");
        }
        assert!(bf16_round(f32::NAN).is_nan());
        assert_eq!(bf16_round(f32::INFINITY), f32::INFINITY);
    }

    #[test]
    fn fp16_narrow_range() {
        assert_eq!(fp16_round(1e6), f32::INFINITY);
        assert_eq!(fp16_round(-1e6), f32::NEG_INFINITY);
        assert_eq!(fp16_round(1e-9), 0.0);
        assert_eq!(fp16_round(65504.0), 65504.0); // max finite f16
        assert_eq!(fp16_round(65520.0), f32::INFINITY); // rounds up past max
    }

    #[test]
    fn fp16_exact_values() {
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1.5, 0.099975586] {
            assert_eq!(fp16_round(x), x, "{x} should be f16-representable");
        }
    }

    #[test]
    fn fp16_subnormals() {
        let min_sub = 5.960_464_5e-8; // 2^-24
        assert!((fp16_round(min_sub) - min_sub).abs() / min_sub < 1e-3);
        assert_eq!(fp16_round(min_sub / 3.0), 0.0);
    }

    #[test]
    fn fp16_roundtrip_idempotent_property() {
        forall(300, 0xF16, |rng| {
            let x = (rng.normal() * rng.uniform_in(1e-4, 1e4)) as f32;
            let once = fp16_round(x);
            let twice = fp16_round(once);
            assert!(
                once == twice || (once.is_nan() && twice.is_nan()),
                "not idempotent: {x} -> {once} -> {twice}"
            );
        });
    }

    #[test]
    fn bf16_idempotent_property() {
        forall(300, 0xBF16, |rng| {
            let x = (rng.normal() * rng.uniform_in(1e-30, 1e30)) as f32;
            let once = bf16_round(x);
            assert_eq!(bf16_round(once).to_bits(), once.to_bits());
        });
    }

    #[test]
    fn rounding_error_bounded_property() {
        forall(300, 0xE44, |rng| {
            let x = (rng.normal() * 100.0) as f32;
            if x == 0.0 {
                return;
            }
            // bf16: 8 fraction bits incl. implicit → rel err ≤ 2^-8
            assert!((bf16_round(x) - x).abs() / x.abs() <= 1.0 / 256.0 + 1e-7);
            // fp16 in normal range: rel err ≤ 2^-11
            if x.abs() > 1e-4 && x.abs() < 6e4 {
                assert!((fp16_round(x) - x).abs() / x.abs() <= 1.0 / 2048.0 + 1e-7);
            }
        });
    }

    /// Exhaustive codec check: every one of the 65,536 binary16 bit
    /// patterns must survive decode → encode bit-exactly — except NaNs,
    /// whose *class* is preserved (still NaN, same sign) while the
    /// payload canonicalizes to the quiet pattern.
    #[test]
    fn f16_all_65536_bit_patterns_roundtrip() {
        for b in 0..=u16::MAX {
            let f = f16_to_f32(b);
            let b2 = f32_to_f16(f);
            let exp = (b >> 10) & 0x1F;
            let frac = b & 0x3FF;
            if exp == 0x1F && frac != 0 {
                // NaN: class + sign preserved, payload canonicalized.
                assert!(f.is_nan(), "{b:#06x} must decode to NaN");
                assert_eq!(b2 & 0x8000, b & 0x8000, "{b:#06x}: NaN sign lost");
                assert_eq!((b2 >> 10) & 0x1F, 0x1F, "{b:#06x}: NaN exponent lost");
                assert_ne!(b2 & 0x3FF, 0, "{b:#06x}: NaN collapsed to infinity");
            } else {
                assert_eq!(b2, b, "{b:#06x} -> {f} -> {b2:#06x}");
            }
        }
    }

    /// Decoded binary16 values are exact in f32: re-rounding is identity
    /// and the decode agrees with the value formula 2^(e-15)·(1+m/1024).
    #[test]
    fn f16_decode_matches_value_formula() {
        for b in 0..=u16::MAX {
            let f = f16_to_f32(b);
            if f.is_nan() {
                continue;
            }
            let sign = if b & 0x8000 != 0 { -1.0f64 } else { 1.0 };
            let exp = ((b >> 10) & 0x1F) as i32;
            let frac = (b & 0x3FF) as f64;
            let expect = match exp {
                0 => sign * frac * 2.0f64.powi(-24),
                0x1F => sign * f64::INFINITY,
                e => sign * 2.0f64.powi(e - 15) * (1.0 + frac / 1024.0),
            };
            assert_eq!(f as f64, expect, "{b:#06x}");
        }
    }

    /// bf16 rounding is round-to-nearest-even: against a value-space
    /// reference (nearest of the two bracketing bf16 values, ties to the
    /// even mantissa), exhaustively over every exponent with the
    /// interesting low-bit patterns, plus random property coverage.
    #[test]
    fn bf16_rne_matches_nearest_even_reference() {
        fn check(x: f32) {
            if x.is_nan() {
                assert!(bf16_round(x).is_nan());
                return;
            }
            let r = bf16_round(x);
            if x.is_infinite() {
                assert_eq!(r, x);
                return;
            }
            assert_eq!(r.to_bits() & 0xFFFF, 0, "{x}: result not bf16-representable");
            // Bracketing bf16 neighbours: truncated magnitude and one
            // step outward (same sign); distances compared exactly in
            // f64 (both operands have ≤24-bit mantissas within one bf16
            // ULP of x, so the subtractions are exact).
            let t = x.to_bits() & 0xFFFF_0000;
            let c0 = f32::from_bits(t);
            let c1 = f32::from_bits(t.wrapping_add(0x1_0000));
            let xd = x as f64;
            if !c1.is_finite() {
                // Overflow boundary: the next step past the largest
                // finite bf16 is ±inf, whose zero mantissa is the even
                // side — so the exact midpoint and beyond round to inf,
                // anything below stays at the largest finite value.
                let max_bf16 = f32::from_bits(0x7F7F_0000) as f64;
                let half_ulp = 2.0f64.powi(119); // ulp at exponent 127 is 2^120
                if xd.abs() >= max_bf16 + half_ulp {
                    assert!(
                        r.is_infinite() && (r > 0.0) == (x > 0.0),
                        "{x}: must overflow to signed inf, got {r}"
                    );
                } else {
                    assert_eq!(r, c0, "{x}: premature overflow (got {r})");
                }
                return;
            }
            let rd = r as f64;
            let d = (rd - xd).abs();
            let d0 = (c0 as f64 - xd).abs();
            let d1 = (c1 as f64 - xd).abs();
            assert!(d <= d0 && d <= d1, "{x}: rounded {r} is not the nearest bf16");
            if d0 == d1 {
                // Exact tie: the kept mantissa LSB must be even.
                assert_eq!(r.to_bits() >> 16 & 1, 0, "{x}: tie must round to even mantissa");
            }
        }
        // Exhaustive over the upper half-word with structured low bits:
        // every sign/exponent/mantissa-high pattern × the rounding edges.
        for hi in 0..=u16::MAX {
            let base = (hi as u32) << 16;
            for lo in [0u32, 1, 0x7FFF, 0x8000, 0x8001, 0xFFFF] {
                check(f32::from_bits(base | lo));
            }
        }
        // And random full-width patterns.
        forall(2000, 0xB16E, |rng| {
            check(f32::from_bits(rng.next_u64() as u32));
        });
    }

    /// Exhaustive fast-path pin: every one of the 65,536 binary16 bit
    /// patterns, decoded to f32 and pushed through [`round_slice`],
    /// must agree bit-for-bit with the scalar [`round_to`] — including
    /// NaNs (both canonicalize identically) and with slice lengths that
    /// leave unaligned chunk tails.
    #[test]
    fn round_slice_fp16_matches_scalar_for_all_65536_patterns() {
        let decoded: Vec<f32> = (0..=u16::MAX).map(f16_to_f32).collect();
        // Lengths chosen to cover: full array, a 15-lane tail, a
        // sub-chunk slice, and single elements.
        for (off, len) in [(0usize, 65536usize), (1, 65535), (7, 4098), (13, 11), (65535, 1)] {
            let mut fast = decoded[off..off + len].to_vec();
            round_slice(&mut fast, Format::Fp16);
            for (i, (&got, &x)) in fast.iter().zip(&decoded[off..off + len]).enumerate() {
                let want = round_to(x, Format::Fp16);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "pattern {:#06x} (slice [{off}; {len}] idx {i}): {got} vs {want}",
                    off + i
                );
            }
        }
    }

    /// The bf16 fast path against the scalar RNE reference over the
    /// same structured sweep as `bf16_rne_matches_nearest_even_reference`
    /// (every upper half-word × the rounding-edge low bits).
    #[test]
    fn round_slice_bf16_matches_scalar_reference_sweep() {
        let mut vals = Vec::with_capacity(65536 * 6);
        for hi in 0..=u16::MAX {
            let base = (hi as u32) << 16;
            for lo in [0u32, 1, 0x7FFF, 0x8000, 0x8001, 0xFFFF] {
                vals.push(f32::from_bits(base | lo));
            }
        }
        let mut fast = vals.clone();
        round_slice(&mut fast, Format::Bf16);
        for (&got, &x) in fast.iter().zip(&vals) {
            let want = bf16_round(x);
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "bits {:#010x}: {got} vs {want}",
                x.to_bits()
            );
        }
    }

    /// Random full-width f32 bit patterns (normals, subnormals, ±inf,
    /// NaNs) through both formats: slice path == scalar path.
    #[test]
    fn round_slice_matches_scalar_on_random_bit_patterns() {
        let mut rng = crate::util::Rng::new(0x51);
        let vals: Vec<f32> = (0..20_000).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        for fmt in [Format::Fp16, Format::Bf16] {
            let mut fast = vals.clone();
            round_slice(&mut fast, fmt);
            for (&got, &x) in fast.iter().zip(&vals) {
                let want = round_to(x, fmt);
                assert_eq!(
                    got.to_bits(),
                    want.to_bits(),
                    "{fmt:?} bits {:#010x}: {got} vs {want}",
                    x.to_bits()
                );
            }
        }
    }

    #[test]
    fn round_slice_fp32_and_fx16_are_identity() {
        let vals = vec![1.0f32, -0.0, f32::NAN, f32::INFINITY, 3.1e-41, 65520.0];
        for fmt in [Format::Fp32, Format::Fx16] {
            let mut out = vals.clone();
            round_slice(&mut out, fmt);
            for (o, v) in out.iter().zip(&vals) {
                assert_eq!(o.to_bits(), v.to_bits());
            }
        }
    }

    /// Regression for the e = −25 band: values in (2⁻²⁵, 2⁻²⁴) must
    /// round RNE to the smallest subnormal 2⁻²⁴ (an earlier encoder
    /// flushed the whole band to zero); the exact 2⁻²⁵ midpoint ties
    /// to even (zero), and below it everything underflows.
    #[test]
    fn fp16_e25_subnormal_band_rounds_to_nearest_even() {
        let min_sub = 2.0f32.powi(-24);
        let midpoint = 2.0f32.powi(-25);
        assert_eq!(fp16_round(1.5 * midpoint), min_sub, "above midpoint rounds up");
        assert_eq!(fp16_round(-1.5 * midpoint), -min_sub);
        assert_eq!(fp16_round(midpoint), 0.0, "exact tie goes to even (zero)");
        assert_eq!(
            fp16_round(f32::from_bits(midpoint.to_bits() + 1)),
            min_sub,
            "one ULP above the tie rounds up"
        );
        assert_eq!(fp16_round(0.99 * midpoint), 0.0, "below midpoint underflows");
    }

    #[test]
    fn table2_rows() {
        let bf = format_info(Format::Bf16);
        let fp16 = format_info(Format::Fp16);
        let fp32 = format_info(Format::Fp32);
        // Paper Table II: exponent ranges
        assert_eq!((bf.exp_min, bf.exp_max), (fp32.exp_min, fp32.exp_max));
        assert_eq!((fp16.exp_min, fp16.exp_max), (-14, 15));
        // bit layouts (Fig 3)
        assert_eq!((fp16.sign_bits, fp16.exp_bits, fp16.frac_bits), (1, 5, 10));
        assert_eq!((fp32.sign_bits, fp32.exp_bits, fp32.frac_bits), (1, 8, 23));
        assert_eq!((bf.sign_bits, bf.exp_bits, bf.frac_bits), (1, 8, 7));
        // master weight / loss scaling rows
        assert!(fp16.needs_master_weight && fp16.needs_loss_scaling);
        assert!(!bf.needs_master_weight && !bf.needs_loss_scaling);
    }
}
