//! Dynamic loss scaling FSM (paper Fig 9): grows the scale after a run of
//! clean steps, halves it and skips the update on overflow.  The policy
//! lives here at L3; the per-step mechanics (scaled backprop, grad check,
//! conditional skip) are inside the lowered artifacts, which take the
//! scale as input and report `found_inf`.

use crate::util::json::{hex_f32s, Json, JsonError};

/// Dynamic loss scaler with the standard grow/backoff policy.
#[derive(Clone, Debug)]
pub struct LossScaler {
    scale: f32,
    growth_factor: f32,
    backoff_factor: f32,
    growth_interval: u32,
    clean_steps: u32,
    min_scale: f32,
    max_scale: f32,
    /// Statistics for reports.
    pub overflows: u64,
    pub updates_skipped: u64,
    pub steps: u64,
}

impl Default for LossScaler {
    fn default() -> Self {
        Self::new(65536.0, 2.0, 0.5, 200)
    }
}

impl LossScaler {
    pub fn new(init: f32, growth: f32, backoff: f32, interval: u32) -> Self {
        assert!(init > 0.0 && growth > 1.0 && backoff < 1.0 && backoff > 0.0);
        LossScaler {
            scale: init,
            growth_factor: growth,
            backoff_factor: backoff,
            growth_interval: interval,
            clean_steps: 0,
            min_scale: 1.0,
            max_scale: 2.0f32.powi(24),
            overflows: 0,
            updates_skipped: 0,
            steps: 0,
        }
    }

    /// A scaler pinned to 1.0 — used for pure-BF16/FP32 pipelines where
    /// no PL/FP16 node participates (paper Table II: BF16 needs no
    /// scaling).
    pub fn disabled() -> Self {
        let mut s = Self::new(1.0, 2.0, 0.5, u32::MAX);
        s.max_scale = 1.0;
        s
    }

    /// Scale to feed the next train-step artifact invocation.
    pub fn scale(&self) -> f32 {
        self.scale
    }

    /// Serialize the full FSM — scale, policy knobs and streak position —
    /// bit-exactly for checkpoints.  `from_json` reconstructs a scaler
    /// that continues the grow/backoff trajectory identically.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("scale", Json::Str(hex_f32s(&[self.scale]))),
            ("growth_factor", Json::Str(hex_f32s(&[self.growth_factor]))),
            ("backoff_factor", Json::Str(hex_f32s(&[self.backoff_factor]))),
            ("growth_interval", Json::Num(f64::from(self.growth_interval))),
            ("clean_steps", Json::Num(f64::from(self.clean_steps))),
            ("min_scale", Json::Str(hex_f32s(&[self.min_scale]))),
            ("max_scale", Json::Str(hex_f32s(&[self.max_scale]))),
            ("overflows", Json::Num(self.overflows as f64)),
            ("updates_skipped", Json::Num(self.updates_skipped as f64)),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    /// Rebuild a scaler from a [`LossScaler::to_json`] snapshot.
    pub fn from_json(v: &Json) -> Result<LossScaler, JsonError> {
        Ok(LossScaler {
            scale: v.req_f32_bits("scale")?,
            growth_factor: v.req_f32_bits("growth_factor")?,
            backoff_factor: v.req_f32_bits("backoff_factor")?,
            growth_interval: v.req_u64("growth_interval")? as u32,
            clean_steps: v.req_u64("clean_steps")? as u32,
            min_scale: v.req_f32_bits("min_scale")?,
            max_scale: v.req_f32_bits("max_scale")?,
            overflows: v.req_u64("overflows")?,
            updates_skipped: v.req_u64("updates_skipped")?,
            steps: v.req_u64("steps")?,
        })
    }

    /// Record a step outcome (the artifact's `found_inf` output);
    /// returns true if the optimizer update was applied.
    pub fn update(&mut self, found_inf: bool) -> bool {
        self.steps += 1;
        if found_inf {
            self.overflows += 1;
            self.updates_skipped += 1;
            self.scale = (self.scale * self.backoff_factor).max(self.min_scale);
            self.clean_steps = 0;
            false
        } else {
            self.clean_steps += 1;
            if self.clean_steps >= self.growth_interval {
                self.scale = (self.scale * self.growth_factor).min(self.max_scale);
                self.clean_steps = 0;
            }
            true
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proplite::forall;

    #[test]
    fn grows_after_interval() {
        let mut s = LossScaler::new(1024.0, 2.0, 0.5, 3);
        assert!(s.update(false));
        assert!(s.update(false));
        assert_eq!(s.scale(), 1024.0);
        s.update(false);
        assert_eq!(s.scale(), 2048.0);
    }

    #[test]
    fn backoff_on_overflow_and_skip() {
        let mut s = LossScaler::new(1024.0, 2.0, 0.5, 3);
        assert!(!s.update(true));
        assert_eq!(s.scale(), 512.0);
        assert_eq!(s.updates_skipped, 1);
    }

    #[test]
    fn overflow_resets_clean_streak() {
        let mut s = LossScaler::new(1024.0, 2.0, 0.5, 2);
        s.update(false);
        s.update(true); // streak resets, scale 512
        s.update(false);
        assert_eq!(s.scale(), 512.0); // only 1 clean step since overflow
        s.update(false);
        assert_eq!(s.scale(), 1024.0);
    }

    #[test]
    fn scale_bounded() {
        let mut s = LossScaler::new(2.0, 2.0, 0.5, 1);
        for _ in 0..100 {
            s.update(true);
        }
        assert!(s.scale() >= 1.0);
        for _ in 0..100 {
            s.update(false);
        }
        assert!(s.scale() <= 2.0f32.powi(24));
    }

    #[test]
    fn disabled_stays_at_one() {
        let mut s = LossScaler::disabled();
        for i in 0..1000 {
            s.update(i % 7 == 0);
        }
        assert_eq!(s.scale(), 1.0);
    }

    #[test]
    fn json_round_trip_continues_fsm_identically() {
        let mut s = LossScaler::new(1024.0, 2.0, 0.5, 3);
        for i in 0..17 {
            s.update(i % 5 == 0);
        }
        let mut restored = LossScaler::from_json(&s.to_json()).unwrap();
        for i in 0..50 {
            let inf = i % 7 == 0;
            assert_eq!(s.update(inf), restored.update(inf));
            assert_eq!(s.scale().to_bits(), restored.scale().to_bits());
        }
        assert_eq!(s.overflows, restored.overflows);
        assert_eq!(s.steps, restored.steps);
        // The disabled scaler round-trips too (u32::MAX interval).
        let d = LossScaler::disabled();
        let rd = LossScaler::from_json(&d.to_json()).unwrap();
        assert_eq!(rd.scale(), 1.0);
        assert_eq!(rd.growth_interval, u32::MAX);
        assert_eq!(rd.max_scale, 1.0);
    }

    #[test]
    fn reference_trace_property() {
        // FSM == straightforward reference simulation for random traces.
        forall(100, 0x5CA1E, |rng| {
            let interval = 1 + rng.below(5) as u32;
            let mut fsm = LossScaler::new(256.0, 2.0, 0.5, interval);
            let mut scale = 256.0f32;
            let mut clean = 0u32;
            for _ in 0..200 {
                let inf = rng.uniform() < 0.15;
                let applied = fsm.update(inf);
                assert_eq!(applied, !inf);
                if inf {
                    scale = (scale * 0.5).max(1.0);
                    clean = 0;
                } else {
                    clean += 1;
                    if clean >= interval {
                        scale = (scale * 2.0).min(2.0f32.powi(24));
                        clean = 0;
                    }
                }
                assert_eq!(fsm.scale(), scale);
            }
        });
    }
}
