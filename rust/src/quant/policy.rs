//! Partition result → per-layer precision assignment (paper Alg. 1).
//!
//! AIE nodes run BF16 end-to-end; PL nodes run FP16 with a
//! higher-precision master; PS nodes run FP32.  The policy also decides
//! whether the pipeline needs dynamic loss scaling at all (only if some
//! node runs FP16 — Table II).

use crate::graph::Dag;
use crate::hw::{Component, Format};
use crate::partition::model::Assignment;

/// Precision plan derived from a partitioning solution.
#[derive(Clone, Debug)]
pub struct PrecisionPolicy {
    /// Per-node compute format.
    pub node_format: Vec<Format>,
    /// Any FP16 node present → the LossScaler FSM must be armed.
    pub needs_loss_scaling: bool,
    /// Node ids that keep a master-weight backup (PL update nodes).
    pub master_backed_nodes: Vec<usize>,
}

impl PrecisionPolicy {
    /// Apply Alg. 1's format rule to a partition assignment.
    pub fn from_assignment(dag: &Dag, assignment: &Assignment, quantized: bool) -> Self {
        let node_format: Vec<Format> = assignment
            .iter()
            .map(|p| {
                if quantized {
                    p.component.native_format()
                } else {
                    Format::Fp32
                }
            })
            .collect();
        let needs_loss_scaling = node_format.iter().any(|&f| f == Format::Fp16);
        let master_backed_nodes = assignment
            .iter()
            .enumerate()
            .filter(|(i, p)| {
                quantized
                    && p.component == Component::PL
                    && dag.nodes[*i].weight_elems > 0
            })
            .map(|(i, _)| i)
            .collect();
        PrecisionPolicy { node_format, needs_loss_scaling, master_backed_nodes }
    }

    /// Which artifact precision mode this policy corresponds to: all-PS →
    /// "fp32"; mixes → "mixed"; all-AIE MM nodes → "bf16".
    pub fn artifact_mode(&self) -> &'static str {
        let any_fp16 = self.node_format.iter().any(|&f| f == Format::Fp16);
        let any_bf16 = self.node_format.iter().any(|&f| f == Format::Bf16);
        match (any_fp16, any_bf16) {
            (false, false) => "fp32",
            (false, true) => "bf16",
            _ => "mixed",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, NetSpec, TrainSpec};
    use crate::partition::model::Placement;

    fn dag() -> Dag {
        build_train_graph(&TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(&[4, 8, 2]),
            batch: 8,
            obs_dim: 4,
            act_dim: 2,
        })
    }

    fn uniform(dag: &Dag, c: Component) -> Assignment {
        (0..dag.len()).map(|_| Placement { component: c, candidate: 0 }).collect()
    }

    #[test]
    fn quantized_pl_needs_scaling_and_masters() {
        let d = dag();
        let a = uniform(&d, Component::PL);
        let p = PrecisionPolicy::from_assignment(&d, &a, true);
        assert!(p.needs_loss_scaling);
        assert!(!p.master_backed_nodes.is_empty());
        assert_eq!(p.artifact_mode(), "mixed");
        // master-backed nodes are exactly the weight-carrying ones
        for &i in &p.master_backed_nodes {
            assert!(d.nodes[i].weight_elems > 0);
        }
    }

    #[test]
    fn all_aie_needs_no_scaling() {
        let d = dag();
        let a = uniform(&d, Component::AIE);
        let p = PrecisionPolicy::from_assignment(&d, &a, true);
        assert!(!p.needs_loss_scaling);
        assert!(p.master_backed_nodes.is_empty());
        assert_eq!(p.artifact_mode(), "bf16");
    }

    #[test]
    fn non_quantized_is_fp32_everywhere() {
        let d = dag();
        let a = uniform(&d, Component::PL);
        let p = PrecisionPolicy::from_assignment(&d, &a, false);
        assert!(p.node_format.iter().all(|&f| f == Format::Fp32));
        assert!(!p.needs_loss_scaling);
        assert_eq!(p.artifact_mode(), "fp32");
    }
}
