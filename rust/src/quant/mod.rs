//! Hardware-aware quantization (paper §IV-D / Algorithm 1 / Figs 9–10).
//!
//! The per-step mixed-precision *dataflow* (scaled loss, grad check,
//! conditional skip) is compiled into the L2 artifacts; this module owns
//! the cross-step *coordination*:
//!
//! * [`formats`] — bit-exact f32↔bf16/f16 casts (mirrors the L1 kernels)
//!   and the Table II format metadata;
//! * [`loss_scale`] — the dynamic loss-scaling state machine driving the
//!   artifacts' `loss_scale` input from their `found_inf` output;
//! * [`master`] — master-weight backup bookkeeping + the sync-overhead
//!   model charged to PL nodes in the schedule (Table IV's ≥22 %);
//! * [`policy`] — partition result → per-layer precision assignment.

pub mod formats;
pub mod loss_scale;
pub mod master;
pub mod policy;

pub use formats::{bf16_round, fp16_round, FormatInfo};
pub use loss_scale::LossScaler;
pub use policy::PrecisionPolicy;
