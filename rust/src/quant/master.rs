//! Master-weight backup bookkeeping + synchronization-cost model
//! (paper Fig 10, Table IV).
//!
//! PL/FP16 nodes keep a higher-precision master copy (BF16 when the
//! neighbour is the AIE, FP32 when the PS — Fig 10's "FP32+FP16 for
//! nodes interfacing with PS, BF16+FP16 for AIE interactions").  The
//! master copy travels with the input stream and the FP16 result is
//! converted back before the master update, so every PL update node
//! moves 2× its weight volume across the link.  AP-DRL overlaps this
//! with compute; what cannot be hidden is the Table IV ≥22 % effect at
//! low FLOPs.

use crate::graph::layer::{Node, Phase};
use crate::hw::{CommModel, Component, Link};
use crate::Micros;

/// Fraction of the sync that dataflow streaming hides behind the node's
/// own compute (the rest is exposed).  At high FLOPs compute >> sync and
/// the whole transfer hides; at low FLOPs most of it is exposed.
const OVERLAP_FRACTION: f64 = 0.5;

/// Master-copy bytes per weight element: BF16 master (2 B) streamed in
/// + FP16→BF16 result streamed back (2 B).
const SYNC_BYTES_PER_ELEM: f64 = 4.0;

/// Per-update-node synchronization setup: stream handshake + format
/// conversion pipeline fill on both ends (paper Table IV: at low FLOPs
/// this makes the quantized run *slower* than FP32 — 0.78×).
const SYNC_SETUP_US: Micros = 20.0;

/// Extra latency charged to `node` when mapped to `component` in
/// quantized mode.  Only PL update nodes with weights pay (AIE keeps
/// weights resident in BF16 — Table II "no master backup"; PS is full
/// precision).
///
/// `compute_us` is the node's full latency; only its *compute* portion
/// (after the kernel-launch floor `launch_us`) can hide the stream.
pub fn sync_overhead(
    comm: &CommModel,
    node: &Node,
    component: Component,
    compute_us: Micros,
    launch_us: Micros,
) -> Micros {
    if component != Component::PL || node.phase != Phase::Update || node.weight_elems == 0 {
        return 0.0;
    }
    let bytes = node.weight_elems as f64 * SYNC_BYTES_PER_ELEM;
    let sync = SYNC_SETUP_US + comm.transfer_time(Link::PlAie, bytes);
    let overlappable = (compute_us - launch_us).max(0.0) * OVERLAP_FRACTION;
    (sync - overlappable).max(0.0)
}

/// Which master format a PL layer keeps, given its upstream/downstream
/// component (Fig 10).
pub fn master_format(neighbour: Component) -> crate::hw::Format {
    match neighbour {
        Component::PS => crate::hw::Format::Fp32,
        _ => crate::hw::Format::Bf16,
    }
}

/// Host-side master-weight store: the coordinator keeps the FP32 master
/// params (PS residency) and mirrors the quantized working copies, so
/// the reward-accounting code can inspect live weight ranges.
#[derive(Clone, Debug, Default)]
pub struct MasterStore {
    pub tensors: Vec<Vec<f32>>,
}

impl MasterStore {
    pub fn new(tensors: Vec<Vec<f32>>) -> Self {
        MasterStore { tensors }
    }

    pub fn total_elems(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Largest |w| across all tensors — the dynamic-range telemetry the
    /// paper's §V-B discussion references (wide distributions are more
    /// quantization-sensitive).
    pub fn max_abs(&self) -> f32 {
        self.tensors
            .iter()
            .flat_map(|t| t.iter())
            .fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::layer::LayerKind;
    use crate::hw::vek280;

    fn update_node(weights: usize) -> Node {
        Node {
            id: 0,
            name: "w/update".into(),
            phase: Phase::Update,
            kind: LayerKind::Elementwise { elems: weights },
            weight_elems: weights,
            out_elems: weights,
        }
    }

    #[test]
    fn only_pl_update_nodes_pay() {
        let p = vek280();
        let n = update_node(10_000);
        assert!(sync_overhead(&p.comm, &n, Component::PL, 1.0, 0.0) > 0.0);
        assert_eq!(sync_overhead(&p.comm, &n, Component::AIE, 1.0, 0.0), 0.0);
        assert_eq!(sync_overhead(&p.comm, &n, Component::PS, 1.0, 0.0), 0.0);
        let mut fwd = update_node(10_000);
        fwd.phase = Phase::Forward;
        assert_eq!(sync_overhead(&p.comm, &fwd, Component::PL, 1.0, 0.0), 0.0);
    }

    #[test]
    fn overlap_hides_sync_at_high_compute() {
        let p = vek280();
        let n = update_node(50_000);
        let exposed_small = sync_overhead(&p.comm, &n, Component::PL, 1.0, 0.0);
        let exposed_big = sync_overhead(&p.comm, &n, Component::PL, 1e6, 9.0);
        assert!(exposed_small > 0.0);
        assert_eq!(exposed_big, 0.0);
    }

    #[test]
    fn table4_low_flops_regime_sync_significant() {
        // (64,64) CartPole MLP: weights ≈ 4.6K elems, compute per update
        // node is a few µs → exposed sync must be a noticeable fraction
        // (paper: ≥22 % penalty on BF16 quantization at low FLOPs).
        let p = vek280();
        let n = update_node(64 * 64 + 64);
        let compute = 3.0; // µs, realistic for this node on PL
        let exposed = sync_overhead(&p.comm, &n, Component::PL, compute, 9.0);
        assert!(
            exposed / (compute + exposed) > 0.2,
            "exposed sync fraction too small: {}",
            exposed / (compute + exposed)
        );
    }

    #[test]
    fn master_format_follows_fig10() {
        assert_eq!(master_format(Component::PS), crate::hw::Format::Fp32);
        assert_eq!(master_format(Component::AIE), crate::hw::Format::Bf16);
        assert_eq!(master_format(Component::PL), crate::hw::Format::Bf16);
    }

    #[test]
    fn master_store_stats() {
        let s = MasterStore::new(vec![vec![1.0, -3.0], vec![0.5]]);
        assert_eq!(s.total_elems(), 3);
        assert_eq!(s.max_abs(), 3.0);
    }
}
