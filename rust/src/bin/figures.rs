//! `figures` — regenerate every table and figure of the paper's
//! evaluation (DESIGN.md §3 experiment index).  Outputs print to stdout
//! and are mirrored as TSV under reports/.
//!
//!   figures fig4            platform comparison (PS/PL/AIE × batch)
//!   figures fig5            PS train-step phase breakdown
//!   figures fig6            synthetic GEMM ladder on PL vs AIE
//!   figures fig8            DQN-Breakout per-layer FLOPs
//!   figures table1          PL DSE design-point counts
//!   figures table2          format comparison
//!   figures fig11 [--combo C] [--seeds N] [--steps N] [--full]
//!                           convergence: quantized vs fp32 (+ Table III
//!                           reward-error column) — runs real training
//!   figures table4          FP32-vs-BF16 training time across net sizes
//!   figures fig12           normalized total training time (3 systems)
//!   figures fig13           normalized training throughput
//!   figures fig14           DDPG-LunarCont operation-sequence Gantt
//!   figures fig15           DDPG-LunarCont partition vs batch size
//!   figures headline        max speedups vs the paper's 4.17× / 3.82×
//!   figures all             everything except fig11 (which trains)

use anyhow::{bail, Result};

use apdrl::coordinator::baselines::{aie_only_step_time, fixar_step_time};
#[cfg(feature = "pjrt")]
use apdrl::coordinator::metrics::reward_error_pct;
use apdrl::coordinator::report::{ascii_bars, ascii_table, write_tsv};
use apdrl::coordinator::{combo, LocalPlanner, PlanRequest, Planner};
#[cfg(feature = "pjrt")]
use apdrl::coordinator::{train_combo, TrainLimits};
use apdrl::server::select_planner;
use apdrl::graph::{build_train_graph, Phase};
use apdrl::hw::{vek280, Component, Format};
use apdrl::profile::dse::{explore_aie, explore_pl, partition_factors, unroll_factors};
use apdrl::profile::ps_model::ps_latency;
use apdrl::quant::formats::format_info;
#[cfg(feature = "pjrt")]
use apdrl::runtime::Runtime;

fn reports_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/reports"))
}

/// The planning backend for every Table III (registry-named) grid in
/// this binary: in-process by default, or whatever `APDRL_SERVER` names
/// (one daemon, or a comma-separated federation) — the figures are
/// identical either way, because remote plans are bit-identical to
/// local ones.  Table IV's resized nets are not registry combos and
/// always plan through [`LocalPlanner`].
fn planner() -> Result<Box<dyn Planner>> {
    select_planner(None)
}

#[cfg(feature = "pjrt")]
fn artifacts_dir() -> String {
    std::env::var("APDRL_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

/// Fig 4: log-normalized single-train-step time on PS/PL/AIE for the
/// three algo-env combos across batch sizes.
fn fig4() -> Result<()> {
    println!("== Fig 4: train-step time on PS / PL / AIE (modeled VEK280) ==");
    let platform = vek280();
    let combos = [
        ("dqn_cartpole", vec![32usize, 64, 128, 256]),
        ("ddpg_lunar", vec![64, 256, 1024]),
        ("dqn_breakout", vec![16, 32, 64]),
    ];
    let mut rows = Vec::new();
    for (name, batches) in &combos {
        let c = combo(name);
        for &bs in batches {
            let dag = build_train_graph(&c.train_spec(bs));
            let profiles = apdrl::profile::profile_dag(&dag, &platform, false);
            // Serial per-component totals (what Fig 4 measures: the whole
            // step on ONE component, fp32).
            let ps: f64 = profiles.iter().map(|p| p.ps_latency_us).sum();
            let pl: f64 = profiles
                .iter()
                .map(|p| p.pl.first().map(|c| c.latency_us).unwrap_or(0.0))
                .sum();
            let aie: f64 = profiles
                .iter()
                .map(|p| {
                    p.aie
                        .first()
                        .map(|c| c.latency_us)
                        // non-MM nodes run on the PL even in the AIE-only
                        // deployment (paper §IV-A)
                        .unwrap_or_else(|| p.pl.first().map(|c| c.latency_us).unwrap_or(0.0))
                })
                .sum();
            println!(
                "{name:16} bs={bs:<5} PS {:>12.1} µs   PL {:>11.1} µs   AIE {:>11.1} µs",
                ps, pl, aie
            );
            rows.push(vec![
                name.to_string(),
                bs.to_string(),
                format!("{ps:.2}"),
                format!("{pl:.2}"),
                format!("{aie:.2}"),
            ]);
        }
        let last = rows.last().unwrap().clone();
        let labels = vec!["PS".to_string(), "PL".to_string(), "AIE".to_string()];
        let vals = vec![
            last[2].parse::<f64>().unwrap(),
            last[3].parse::<f64>().unwrap(),
            last[4].parse::<f64>().unwrap(),
        ];
        println!("{}", ascii_bars(&format!("  log-scale, {name} @ largest bs"), &labels, &vals, true));
    }
    write_tsv(reports_dir().join("fig4.tsv"), &["combo", "batch", "ps_us", "pl_us", "aie_us"], &rows)?;
    println!("paper check: PL wins at low FLOPs; AIE wins at high FLOPs (crossover visible above)");
    Ok(())
}

/// Fig 5: PS execution-time breakdown per training phase.
fn fig5() -> Result<()> {
    println!("== Fig 5: PS train-step phase breakdown ==");
    let platform = vek280();
    let mut rows = Vec::new();
    for name in ["dqn_cartpole", "ddpg_lunar", "dqn_breakout"] {
        let c = combo(name);
        let dag = build_train_graph(&c.train_spec(c.batch));
        let mut per_phase = [0.0f64; 4];
        let mut total = 0.0;
        for node in &dag.nodes {
            let t = ps_latency(platform.spec(Component::PS), &node.kind, Format::Fp32);
            let idx = match node.phase {
                Phase::Forward => 0,
                Phase::Loss => 1,
                Phase::Backward => 2,
                Phase::Update => 3,
            };
            per_phase[idx] += t;
            total += t;
        }
        println!(
            "{name:16} fwd {:5.1}%  loss {:4.1}%  bwd {:5.1}%  update {:4.1}%   (total {:.1} µs)",
            100.0 * per_phase[0] / total,
            100.0 * per_phase[1] / total,
            100.0 * per_phase[2] / total,
            100.0 * per_phase[3] / total,
            total
        );
        rows.push(vec![
            name.to_string(),
            format!("{:.4}", per_phase[0] / total),
            format!("{:.4}", per_phase[1] / total),
            format!("{:.4}", per_phase[2] / total),
            format!("{:.4}", per_phase[3] / total),
        ]);
    }
    write_tsv(reports_dir().join("fig5.tsv"), &["combo", "forward", "loss", "backward", "update"], &rows)?;
    println!("paper check: forward + backward dominate across all three combos");
    Ok(())
}

/// Fig 6: synthetic n×n GEMM ladder on PL vs AIE.
fn fig6() -> Result<()> {
    println!("== Fig 6: synthetic GEMM on PL vs AIE (init | body) ==");
    let platform = vek280();
    let mut rows = Vec::new();
    for n in [64usize, 128, 256, 512, 1024, 2048] {
        let kind = apdrl::graph::LayerKind::Mm { m: n, k: n, n };
        let pl_best = explore_pl(platform.spec(Component::PL), &kind, Format::Fp16, platform.pl_dsp)
            .last()
            .map(|d| d.latency_us)
            .unwrap();
        let aie_best = explore_aie(
            platform.spec(Component::AIE),
            &kind,
            Format::Bf16,
            platform.aie_tiles,
            platform.aie_lanes_per_tile,
        )
        .last()
        .map(|d| d.latency_us)
        .unwrap();
        let pl_init = platform.pl.init_us.min(pl_best);
        let aie_init = platform.aie.init_us.min(aie_best);
        println!(
            "GEMM {n:<5} PL {pl_best:>10.1} µs (init {:4.1}%)   AIE {aie_best:>10.1} µs (init {:5.1}%)   PL/AIE = {:.2}",
            100.0 * pl_init / pl_best,
            100.0 * aie_init / aie_best,
            pl_best / aie_best
        );
        rows.push(vec![
            n.to_string(),
            format!("{pl_best:.2}"),
            format!("{:.4}", pl_init / pl_best),
            format!("{aie_best:.2}"),
            format!("{:.4}", aie_init / aie_best),
        ]);
    }
    write_tsv(
        reports_dir().join("fig6.tsv"),
        &["n", "pl_us", "pl_init_frac", "aie_us", "aie_init_frac"],
        &rows,
    )?;
    println!("paper check: AIE init dominates small GEMMs; large-GEMM PL/AIE ratio ≈ clock ratio (4.08)");
    Ok(())
}

/// Fig 8: DQN-Breakout per-layer FLOPs (fwd + bwd MM nodes).
fn fig8() -> Result<()> {
    println!("== Fig 8: DQN-Breakout per-layer FLOPs (batch=1 rows) ==");
    let c = combo("dqn_breakout");
    let dag = build_train_graph(&c.train_spec(1));
    let mut rows = Vec::new();
    let (mut labels, mut vals) = (Vec::new(), Vec::new());
    for node in dag.nodes.iter().filter(|n| n.kind.is_mm()) {
        rows.push(vec![node.name.clone(), format!("{:.3e}", node.flops())]);
        labels.push(node.name.clone());
        vals.push(node.flops());
    }
    println!("{}", ascii_bars("  per-MM-layer FLOPs (log scale)", &labels, &vals, true));
    let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = vals.iter().cloned().fold(0.0f64, f64::max);
    println!(
        "{} MM layers; min {:.2} KFLOPs, max {:.2} MFLOPs (paper: 15 layers, 4.10 K – 10.61 M)",
        vals.len(),
        min / 1e3,
        max / 1e6
    );
    write_tsv(reports_dir().join("fig8.tsv"), &["layer", "flops"], &rows)?;
    Ok(())
}

/// Table I: the DSE design-point counts.
fn table1() -> Result<()> {
    println!("== Table I: PL DSE design points ==");
    let lb = 4096usize;
    let rows = vec![
        vec!["Dataflow (DF)".to_string(), "Enable/Disable".to_string(), "2".to_string()],
        vec!["Function Pipeline (FP)".to_string(), "Enable/Disable".to_string(), "2".to_string()],
        vec!["Loop Pipeline (LP)".to_string(), "Enable/Disable".to_string(), "2".to_string()],
        vec![
            "Loop Unroll (LU)".to_string(),
            format!("factors up to LB={lb}"),
            unroll_factors(lb).len().to_string(),
        ],
        vec![
            "Array Partition (AP)".to_string(),
            "bounded by B_M/B_D (fp16)".to_string(),
            partition_factors(Format::Fp16).len().to_string(),
        ],
    ];
    println!("{}", ascii_table(&["Pragma", "Configurations", "#Design Points"], &rows));
    write_tsv(reports_dir().join("table1.tsv"), &["pragma", "configurations", "points"], &rows)?;
    Ok(())
}

/// Table II: FP16 / FP32 / BF16 comparison.
fn table2() -> Result<()> {
    println!("== Table II: format comparison ==");
    let rows: Vec<Vec<String>> = [Format::Fp16, Format::Fp32, Format::Bf16]
        .iter()
        .map(|&f| {
            let i = format_info(f);
            vec![
                i.name.to_string(),
                format!("(1, {}, {})", i.exp_bits, i.frac_bits),
                format!("[{}, {}]", i.exp_min, i.exp_max),
                i.bytes.to_string(),
                (if i.needs_master_weight { "Yes" } else { "No" }).to_string(),
                (if i.needs_loss_scaling { "Yes" } else { "No" }).to_string(),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(
            &["Format", "(S,E,F)", "Exp range", "Bytes", "Master wt?", "Loss scaling?"],
            &rows
        )
    );
    write_tsv(
        reports_dir().join("table2.tsv"),
        &["format", "sef", "exp_range", "bytes", "master", "scaling"],
        &rows,
    )?;
    Ok(())
}

/// Fig 11 + Table III reward-error column: real training, quantized vs
/// fp32, across seeds.  Needs the PJRT runtime (`pjrt` feature).
#[cfg(not(feature = "pjrt"))]
fn fig11(_args: &Args) -> Result<()> {
    bail!("fig11 trains through PJRT artifacts; rebuild with `--features pjrt` (needs the xla bindings + `make artifacts`)")
}

#[cfg(feature = "pjrt")]
fn fig11(args: &Args) -> Result<()> {
    let seeds = args.usize_flag("seeds", 3);
    let only: Option<&str> = args.flag("combo");
    let full = args.flag("full").is_some();
    let combos: Vec<&str> = match only {
        Some(c) => vec![c],
        None => vec!["dqn_cartpole", "a2c_invpend", "ddpg_mntncar", "ddpg_lunar"],
    };
    let mut runtime = Runtime::new(artifacts_dir())?;
    println!("== Fig 11 / Table III: convergence of quantized vs FP32 ({seeds} seeds) ==");
    let mut rows = Vec::new();
    for name in combos {
        // `--combo` is user input: report unknown names, don't abort.
        let c = apdrl::coordinator::try_combo(name)?;
        let default_steps: usize = if full { 120_000 } else { 15_000 };
        let limits = TrainLimits {
            max_env_steps: args.usize_flag("steps", default_steps) as u64,
            max_episodes: if full { 2_000 } else { 400 },
        };
        let mut fp32_rewards = Vec::new();
        let mut mixed_rewards = Vec::new();
        for seed in 1..=seeds as u64 {
            for mode in ["fp32", "mixed"] {
                let mut backend = apdrl::exec::PjrtBackend::new(&mut runtime, mode);
                let r = train_combo(&mut backend, &c, seed, limits, true)?;
                let conv = r.metrics.converged_reward(50);
                println!(
                    "  {name} [{mode}] seed {seed}: converged {conv:.2} ({} eps, {} train steps, {} overflows)",
                    r.metrics.episode_rewards.len(),
                    r.metrics.train_steps,
                    r.metrics.overflows
                );
                let curve: Vec<Vec<String>> = r
                    .metrics
                    .smoothed_rewards()
                    .iter()
                    .enumerate()
                    .map(|(i, v)| vec![i.to_string(), format!("{v:.3}")])
                    .collect();
                write_tsv(
                    reports_dir().join(format!("fig11_{name}_{mode}_s{seed}.tsv")),
                    &["episode", "reward_ma100"],
                    &curve,
                )?;
                if mode == "fp32" {
                    fp32_rewards.push(conv);
                } else {
                    mixed_rewards.push(conv);
                }
            }
        }
        let err = reward_error_pct(&fp32_rewards, &mixed_rewards);
        println!(
            "  -> {name}: fp32 {:.2} vs mixed {:.2} | reward error {err:.2}% (paper: {:.2}%)",
            apdrl::util::stats::mean(&fp32_rewards),
            apdrl::util::stats::mean(&mixed_rewards),
            c.paper_reward_error_pct
        );
        rows.push(vec![
            name.to_string(),
            format!("{:.3}", apdrl::util::stats::mean(&fp32_rewards)),
            format!("{:.3}", apdrl::util::stats::mean(&mixed_rewards)),
            format!("{err:.2}"),
            format!("{:.2}", c.paper_reward_error_pct),
        ]);
    }
    write_tsv(
        reports_dir().join("table3_reward_error.tsv"),
        &["combo", "fp32_reward", "mixed_reward", "error_pct", "paper_error_pct"],
        &rows,
    )?;
    Ok(())
}

/// Table IV: FP32 vs quantized training time across network sizes.
fn table4() -> Result<()> {
    println!("== Table IV: DQN-CartPole step time, FP32 vs AP-DRL quantized ==");
    let sizes: [(&str, Vec<usize>); 3] = [
        ("(64, 64)", vec![4, 64, 64, 2]),
        ("(400, 300)", vec![4, 400, 300, 2]),
        ("(4096, 3072)", vec![4, 4096, 3072, 2]),
    ];
    // One batched sweep plans all six (net, precision) points
    // concurrently.  These are *customized* combos (resized nets), not
    // registry names, so they always go through the in-process backend.
    let requests: Vec<PlanRequest> = sizes
        .iter()
        .flat_map(|(_, sizes_v)| {
            let mut c = combo("dqn_cartpole");
            c.net = apdrl::graph::NetSpec::mlp(sizes_v);
            [PlanRequest::new(c.clone(), 64, false), PlanRequest::new(c, 64, true)]
        })
        .collect();
    let plans = LocalPlanner.plan_many(&requests)?;
    let mut rows = Vec::new();
    for (i, (label, _)) in sizes.iter().enumerate() {
        let (fp32, quant) = (&plans[2 * i], &plans[2 * i + 1]);
        let speedup = fp32.step_time_us() / quant.step_time_us();
        println!(
            "{label:14} FP32 {:>12.1} µs   quantized {:>12.1} µs   speedup {speedup:.2}x   (sync exposed {:.1} µs)",
            fp32.step_time_us(),
            quant.step_time_us(),
            quant.sync_us
        );
        rows.push(vec![
            label.to_string(),
            format!("{:.2}", fp32.step_time_us()),
            format!("{:.2}", quant.step_time_us()),
            format!("{speedup:.3}"),
            format!("{:.2}", quant.sync_us),
        ]);
    }
    write_tsv(
        reports_dir().join("table4.tsv"),
        &["hidden", "fp32_us", "quant_us", "speedup", "sync_us"],
        &rows,
    )?;
    println!("paper check: 0.78x (sync-bound) -> 1.13x -> 2.98x with growing FLOPs");
    Ok(())
}

/// Fig 12/13 shared sweep: (combo, batch) × {AIE-only, FIXAR, AP-DRL}.
/// The AP-DRL column runs through the selected planning backend (one
/// batched, cache-aware `plan_many` over the whole grid).
fn speedup_matrix() -> Result<Vec<(String, usize, f64, f64, f64)>> {
    let grid: [(&str, [usize; 3]); 6] = [
        ("dqn_cartpole", [64, 128, 256]),
        ("a2c_invpend", [64, 128, 256]),
        ("ddpg_lunar", [256, 512, 1024]),
        ("ddpg_mntncar", [256, 512, 1024]),
        ("dqn_breakout", [16, 32, 64]),
        ("ppo_mspacman", [16, 32, 64]),
    ];
    let requests: Vec<PlanRequest> = grid
        .iter()
        .flat_map(|(name, batches)| {
            let c = combo(name);
            batches.iter().map(move |&bs| PlanRequest::new(c.clone(), bs, true))
        })
        .collect();
    let plans = planner()?.plan_many(&requests)?;
    Ok(requests
        .iter()
        .zip(&plans)
        .map(|(req, plan)| {
            let aie = aie_only_step_time(&req.combo, req.batch);
            let fixar = fixar_step_time(&req.combo, req.batch);
            (
                req.combo.name.to_string(),
                req.batch,
                aie,
                fixar,
                plan.makespan_us,
            )
        })
        .collect())
}

fn fig12_13() -> Result<()> {
    println!("== Fig 12/13: AIE-only vs FIXAR vs AP-DRL (per-step time, normalized) ==");
    let matrix = speedup_matrix()?;
    let mut rows12 = Vec::new();
    let mut rows13 = Vec::new();
    for (name, bs, aie, fixar, apdrl) in &matrix {
        let max = aie.max(*fixar).max(*apdrl);
        println!(
            "{name:16} bs={bs:<5} AIE-only {:>6.3}  FIXAR {:>6.3}  AP-DRL {:>6.3}   (AP-DRL vs FIXAR {:.2}x, vs AIE {:.2}x)",
            aie / max,
            fixar / max,
            apdrl / max,
            fixar / apdrl,
            aie / apdrl
        );
        rows12.push(vec![
            name.clone(),
            bs.to_string(),
            format!("{:.4}", aie / max),
            format!("{:.4}", fixar / max),
            format!("{:.4}", apdrl / max),
        ]);
        rows13.push(vec![
            name.clone(),
            bs.to_string(),
            format!("{:.4}", apdrl / aie),
            format!("{:.4}", apdrl / fixar),
            "1.0000".to_string(),
        ]);
    }
    write_tsv(
        reports_dir().join("fig12.tsv"),
        &["combo", "batch", "aie_only_norm", "fixar_norm", "apdrl_norm"],
        &rows12,
    )?;
    write_tsv(
        reports_dir().join("fig13.tsv"),
        &["combo", "batch", "aie_only_tput_rel", "fixar_tput_rel", "apdrl_tput_rel"],
        &rows13,
    )?;
    Ok(())
}

/// Fig 14: operation sequence (Gantt) of DDPG-LunarCont @ bs 256.
fn fig14() -> Result<()> {
    println!("== Fig 14: DDPG-LunarCont operation sequence (batch 256) ==");
    let req = PlanRequest::named("ddpg_lunar")?.with_batch(256);
    let plan = planner()?.plan(&req)?;
    let span = plan.makespan_us;
    let width = 60.0;
    let mut rows = Vec::new();
    for step in &plan.schedule {
        let pre = (((step.start_us / span) * width) as usize).min(60);
        let len = ((((step.finish_us - step.start_us) / span) * width).ceil() as usize)
            .max(1)
            .min(61 - pre);
        let ch = match step.component.as_str() {
            "PL" => '#',
            "AIE" => '%',
            _ => '.',
        };
        println!(
            "{:4} {:26} {:3} |{}{}|",
            step.node,
            step.name,
            step.component,
            " ".repeat(pre),
            ch.to_string().repeat(len)
        );
        rows.push(vec![
            step.name.clone(),
            step.component.clone(),
            format!("{:.2}", step.start_us),
            format!("{:.2}", step.finish_us),
        ]);
    }
    println!("makespan {:.1} µs (# PL  % AIE  . PS)", span);
    write_tsv(reports_dir().join("fig14.tsv"), &["node", "unit", "start_us", "finish_us"], &rows)?;
    Ok(())
}

/// Fig 15: DDPG-LunarCont partitioning vs batch size.
fn fig15() -> Result<()> {
    println!("== Fig 15: DDPG-LunarCont partition vs batch size ==");
    let c = combo("ddpg_lunar");
    let batches = [64usize, 128, 256, 512, 1024];
    let requests: Vec<PlanRequest> =
        batches.iter().map(|&bs| PlanRequest::new(c.clone(), bs, true)).collect();
    let plans = planner()?.plan_many(&requests)?;
    let mut rows = Vec::new();
    for (&bs, plan) in batches.iter().zip(&plans) {
        let names: Vec<String> = plan
            .schedule
            .iter()
            .filter(|step| step.mm && step.component == "AIE")
            .map(|step| step.name.clone())
            .collect();
        println!(
            "bs={bs:<6} AIE {}/{} MM nodes: {}",
            plan.aie_mm_nodes,
            plan.mm_nodes,
            names.join(", ")
        );
        rows.push(vec![
            bs.to_string(),
            plan.aie_mm_nodes.to_string(),
            plan.mm_nodes.to_string(),
            names.join(","),
        ]);
    }
    write_tsv(
        reports_dir().join("fig15.tsv"),
        &["batch", "aie_mm_nodes", "total_mm_nodes", "aie_layers"],
        &rows,
    )?;
    println!("paper check: AIE node count grows with batch size");
    Ok(())
}

/// Headline speedups (§V-C / abstract): extremes over the Fig 12 matrix.
fn headline() -> Result<()> {
    println!("== headline speedups ==");
    let matrix = speedup_matrix()?;
    let best_vs_fixar = matrix.iter().map(|(_, _, _, f, a)| f / a).fold(0.0f64, f64::max);
    let worst_vs_fixar =
        matrix.iter().map(|(_, _, _, f, a)| f / a).fold(f64::INFINITY, f64::min);
    let best_vs_aie = matrix.iter().map(|(_, _, ai, _, a)| ai / a).fold(0.0f64, f64::max);
    let worst_vs_aie =
        matrix.iter().map(|(_, _, ai, _, a)| ai / a).fold(f64::INFINITY, f64::min);
    println!("AP-DRL vs FIXAR (PL baseline): {worst_vs_fixar:.2}x - {best_vs_fixar:.2}x   (paper: 0.98x - 4.17x)");
    println!("AP-DRL vs AIE-only:            {worst_vs_aie:.2}x - {best_vs_aie:.2}x   (paper: 1.61x - 3.82x)");
    write_tsv(
        reports_dir().join("headline.tsv"),
        &["metric", "min", "max", "paper_min", "paper_max"],
        &[
            vec!["vs_fixar".to_string(), format!("{worst_vs_fixar:.3}"), format!("{best_vs_fixar:.3}"), "0.98".to_string(), "4.17".to_string()],
            vec!["vs_aie_only".to_string(), format!("{worst_vs_aie:.3}"), format!("{best_vs_aie:.3}"), "1.61".to_string(), "3.82".to_string()],
        ],
    )?;
    Ok(())
}

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = if it.peek().map_or(false, |v| !v.starts_with("--")) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn main() -> Result<()> {
    let args = Args::parse();
    let which = args.positional.first().map(String::as_str).unwrap_or("all");
    match which {
        "fig4" => fig4()?,
        "fig5" => fig5()?,
        "fig6" => fig6()?,
        "fig8" => fig8()?,
        "table1" => table1()?,
        "table2" => table2()?,
        "fig11" => fig11(&args)?,
        "table4" => table4()?,
        "fig12" | "fig13" => fig12_13()?,
        "fig14" => fig14()?,
        "fig15" => fig15()?,
        "headline" => headline()?,
        "all" => {
            fig4()?;
            fig5()?;
            fig6()?;
            fig8()?;
            table1()?;
            table2()?;
            table4()?;
            fig12_13()?;
            fig14()?;
            fig15()?;
            headline()?;
            println!("\n(fig11 runs real training; invoke `figures fig11` separately)");
        }
        other => bail!("unknown figure {other}"),
    }
    Ok(())
}
