//! Analytic Versal ACAP performance model — the substituted testbed
//! (DESIGN.md §Substitutions).
//!
//! The paper evaluates on VEK280 *hardware emulation*; every claim in its
//! evaluation is relative (who wins at which FLOPs, crossovers, speedup
//! factors).  This module reproduces the ratio structure those claims
//! depend on: per-component clocks, kernel-launch/initialization
//! overheads, parallel datapath widths, format multipliers and link
//! bandwidths, all taken from the paper's own constants (PL@245 MHz,
//! AIE@1 GHz, FIXAR@164 MHz, dual Cortex-A72 PS, 1312 DSPs, 304 AIE-ML
//! tiles) and Figures 4/6.

pub mod comm;
pub mod component;
pub mod platform;

pub use comm::{CommModel, Link};
pub use component::{Component, ComponentSpec, Format};
pub use platform::{fixar_platform, vek280, Platform};
