//! Per-component compute model: PS (Cortex-A72), PL (FPGA fabric + DSP)
//! and AIE-ML (AI engine array).

use crate::Micros;

/// The three Versal ACAP processing domains (paper Fig 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Component {
    /// Processing System: dual-core Cortex-A72, FP32 only.
    PS,
    /// Programmable Logic: fabric + DSP engines, native FP16/FP32.
    PL,
    /// AI Engine-ML array: native BF16 (FP32 emulated, slow).
    AIE,
}

impl Component {
    pub const ALL: [Component; 3] = [Component::PS, Component::PL, Component::AIE];

    pub fn name(self) -> &'static str {
        match self {
            Component::PS => "PS",
            Component::PL => "PL",
            Component::AIE => "AIE",
        }
    }

    /// Inverse of [`name`](Component::name): `None` for unknown names.
    /// Used by the plan-cache loader and the wire protocol, so the
    /// mapping lives here next to its forward direction.
    pub fn from_name(name: &str) -> Option<Component> {
        Component::ALL.into_iter().find(|c| c.name() == name)
    }

    /// Native compute format under AP-DRL's hardware-aware quantization
    /// (paper Alg. 1): PS=FP32, PL=FP16, AIE=BF16.
    pub fn native_format(self) -> Format {
        match self {
            Component::PS => Format::Fp32,
            Component::PL => Format::Fp16,
            Component::AIE => Format::Bf16,
        }
    }
}

/// Numeric formats coordinated by AP-DRL (paper Table II / Fig 3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Format {
    Fp32,
    Fp16,
    Bf16,
    /// FIXAR's 16-bit fixed point (baseline, paper §V-C).
    Fx16,
}

impl Format {
    pub fn bytes(self) -> usize {
        match self {
            Format::Fp32 => 4,
            Format::Fp16 | Format::Bf16 | Format::Fx16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Format::Fp32 => "FP32",
            Format::Fp16 => "FP16",
            Format::Bf16 => "BF16",
            Format::Fx16 => "FX16",
        }
    }

    pub const ALL: [Format; 4] = [Format::Fp32, Format::Fp16, Format::Bf16, Format::Fx16];

    /// Inverse of [`name`](Format::name): `None` for unknown names.  The
    /// CPU execution backend parses wire-schedule formats through this,
    /// so the mapping lives next to its forward direction.
    pub fn from_name(name: &str) -> Option<Format> {
        Format::ALL.into_iter().find(|f| f.name() == name)
    }
}

/// Static description of one processing unit.
#[derive(Clone, Debug)]
pub struct ComponentSpec {
    pub component: Component,
    /// Clock frequency in MHz (paper: PS≈1350, PL=245, AIE=1000).
    pub clock_mhz: f64,
    /// Kernel launch / graph initialization overhead in µs.  The paper's
    /// Fig 6 attributes AIE's low-FLOPs loss entirely to this term.
    pub init_us: Micros,
    /// Peak MAC lanes at the native format (DSP slices on PL, vector
    /// lanes across allocated tiles on AIE, NEON lanes on PS).
    pub max_mac_lanes: usize,
    /// Sustained fraction of peak after pipeline stalls/control (DSE
    /// configs move *within* this envelope).
    pub efficiency: f64,
    /// Local memory bandwidth in GB/s feeding the datapath (BRAM/URAM on
    /// PL, tile memory via PLIO on AIE, L2 on PS).
    pub mem_gbps: f64,
    /// Throughput multiplier per format relative to the native format.
    pub fmt_fp32: f64,
    pub fmt_fp16: f64,
    pub fmt_bf16: f64,
}

impl ComponentSpec {
    pub fn format_mult(&self, fmt: Format) -> f64 {
        match fmt {
            Format::Fp32 => self.fmt_fp32,
            Format::Fp16 => self.fmt_fp16,
            Format::Bf16 => self.fmt_bf16,
            // Fixed point maps onto the fp16 datapath width on PL/DSP.
            Format::Fx16 => self.fmt_fp16,
        }
    }

    /// Time for a GEMM-shaped op: `flops` total, `bytes` moved, using
    /// `lanes` MAC lanes (≤ max), `overlap` = dataflow pragma (compute
    /// and memory pipelined vs serialized).
    ///
    /// t_compute = flops / (2 · lanes · f_clk · eff · fmt_mult)
    /// t_mem     = bytes / BW
    /// t         = init + (overlap ? max : sum)
    pub fn gemm_time(
        &self,
        flops: f64,
        bytes: f64,
        lanes: usize,
        fmt: Format,
        overlap: bool,
    ) -> Micros {
        let lanes = lanes.min(self.max_mac_lanes).max(1) as f64;
        let rate = 2.0 * lanes * self.clock_mhz * 1e6 * self.efficiency * self.format_mult(fmt);
        let t_compute = flops / rate * 1e6;
        let t_mem = bytes / (self.mem_gbps * 1e9) * 1e6;
        let body = if overlap { t_compute.max(t_mem) } else { t_compute + t_mem };
        self.init_us + body
    }

    /// Time for an elementwise (non-MM) op of `elems` elements — bound by
    /// memory bandwidth plus a per-element ALU floor.
    pub fn elementwise_time(&self, elems: f64, fmt: Format) -> Micros {
        let bytes = elems * fmt.bytes() as f64 * 2.0; // read + write
        let t_mem = bytes / (self.mem_gbps * 1e9) * 1e6;
        let t_alu =
            elems / (self.max_mac_lanes as f64 * self.clock_mhz * 1e6 * self.efficiency) * 1e6;
        self.init_us + t_mem.max(t_alu)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform::vek280;

    #[test]
    fn native_formats_match_alg1() {
        assert_eq!(Component::PS.native_format(), Format::Fp32);
        assert_eq!(Component::PL.native_format(), Format::Fp16);
        assert_eq!(Component::AIE.native_format(), Format::Bf16);
    }

    #[test]
    fn format_bytes() {
        assert_eq!(Format::Fp32.bytes(), 4);
        assert_eq!(Format::Fp16.bytes(), 2);
        assert_eq!(Format::Bf16.bytes(), 2);
    }

    #[test]
    fn gemm_time_monotone_in_flops() {
        let pl = vek280().spec(Component::PL).clone();
        let t1 = pl.gemm_time(1e6, 1e4, 512, Format::Fp16, true);
        let t2 = pl.gemm_time(1e8, 1e5, 512, Format::Fp16, true);
        assert!(t2 > t1);
    }

    #[test]
    fn overlap_never_slower() {
        let pl = vek280().spec(Component::PL).clone();
        let on = pl.gemm_time(1e7, 1e6, 256, Format::Fp16, true);
        let off = pl.gemm_time(1e7, 1e6, 256, Format::Fp16, false);
        assert!(on <= off);
    }

    #[test]
    fn lanes_clamped_to_max() {
        let pl = vek280().spec(Component::PL).clone();
        let a = pl.gemm_time(1e8, 0.0, usize::MAX, Format::Fp16, true);
        let b = pl.gemm_time(1e8, 0.0, pl.max_mac_lanes, Format::Fp16, true);
        assert!((a - b).abs() < 1e-9);
    }

    #[test]
    fn aie_bf16_faster_than_aie_fp32() {
        let aie = vek280().spec(Component::AIE).clone();
        let bf = aie.gemm_time(1e9, 1e6, 1024, Format::Bf16, true);
        let fp = aie.gemm_time(1e9, 1e6, 1024, Format::Fp32, true);
        assert!(fp > 2.0 * bf, "AIE fp32 must be ≫ slower (emulated): {fp} vs {bf}");
    }
}
