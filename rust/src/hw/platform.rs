//! Platform catalogue: the VEK280 board the paper evaluates on, plus the
//! FIXAR baseline platform (CPU–FPGA @ 164 MHz, fixed point).

use super::comm::CommModel;
use super::component::{Component, ComponentSpec, Format};

/// A complete modeled board: three component specs + communication model
/// + total resource pools for the ILP's capacity constraints (Eq. 7).
#[derive(Clone, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub ps: ComponentSpec,
    pub pl: ComponentSpec,
    pub aie: ComponentSpec,
    pub comm: CommModel,
    /// PL resource pool (DSP slices) — paper: 1312 on VEK280.
    pub pl_dsp: usize,
    /// PL LUT pool (K LUTs) — paper: 520.7K.
    pub pl_kluts: f64,
    /// PL on-chip memory in Mb — paper: 113.4 Mb.
    pub pl_mem_mb: f64,
    /// AIE-ML tile count — paper: 304.
    pub aie_tiles: usize,
    /// MAC lanes contributed per allocated AIE-ML tile (native format).
    pub aie_lanes_per_tile: usize,
}

impl Platform {
    pub fn spec(&self, c: Component) -> &ComponentSpec {
        match c {
            Component::PS => &self.ps,
            Component::PL => &self.pl,
            Component::AIE => &self.aie,
        }
    }
}

/// The VEK280 evaluation platform (paper §V-A: dual-core Cortex-A72 APU,
/// 304 AIE-ML tiles, 1312 DSPs, 520.7K LUTs, 113.4 Mb PL memory; PL@245
/// MHz, AIE@1 GHz).
///
/// Calibration notes (DESIGN.md §Substitutions):
/// * AIE vs PL large-GEMM advantage ≈ clock ratio (1000/245 ≈ 4.08) at
///   matched spatial width — paper §III-A observes "similar ratio of
///   execution time between computation and memory access… inferior
///   performance due to its lower clock frequency".
/// * AIE kernel-launch overhead ≫ PL's — Fig 6's low-FLOPs regime.
/// * AIE FP32 is emulated (×0.25) while BF16 is native — Table IV's
///   2.98× large-net quantization speedup.
/// * PL FP16 is native; FP32 halves DSP throughput (×0.5).
pub fn vek280() -> Platform {
    Platform {
        name: "VEK280 (modeled)",
        ps: ComponentSpec {
            component: Component::PS,
            clock_mhz: 1350.0,
            init_us: 0.0, // host code, no kernel launch
            max_mac_lanes: 8, // 2 cores × 4-wide NEON FMA
            efficiency: 0.55,
            mem_gbps: 12.0,
            fmt_fp32: 1.0,
            fmt_fp16: 1.0,  // NEON fp16 ≈ fp32 FMA rate on A72
            fmt_bf16: 0.4,  // software-emulated bf16 on the PS
        },
        pl: ComponentSpec {
            component: Component::PL,
            clock_mhz: 245.0,
            init_us: 9.0, // XRT kernel start, short (paper Fig 6)
            max_mac_lanes: 1312, // one fp16 MAC per DSP58 slice
            efficiency: 0.60,
            mem_gbps: 85.0, // aggregated BRAM/URAM banks after partitioning
            fmt_fp32: 0.5,  // fp32 MAC costs two DSP slices
            fmt_fp16: 1.0,
            fmt_bf16: 0.9, // fabric bf16: fp16 datapath + exponent fixup LUTs
        },
        aie: ComponentSpec {
            component: Component::AIE,
            clock_mhz: 1000.0,
            init_us: 45.0, // per-kernel launch + stream reconfig (graph load amortized; Fig 6: dominant at low FLOPs)
            max_mac_lanes: 1312, // matched spatial width at CHARM's GEMM mapping
            efficiency: 0.60,
            mem_gbps: 340.0, // aggregate PLIO + tile-local memory streams
            fmt_fp32: 0.25,  // fp32 emulated over bf16 MACs
            fmt_fp16: 0.5,   // fp16 converted to bf16 path with fixups
            fmt_bf16: 1.0,   // native AIE-ML bf16
        },
        comm: CommModel {
            ps_pl_lat_us: 1.2,  // AXI + cache-coherency round trip
            ps_pl_gbps: 3.8,    // 128-bit AXI @ 245 MHz ≈ 3.9 GB/s
            pl_aie_lat_us: 0.5, // PLIO stream setup
            pl_aie_gbps: 7.6,   // two 64-bit PLIOs @ PL clock per stream group
        },
        pl_dsp: 1312,
        pl_kluts: 520.7,
        pl_mem_mb: 113.4,
        aie_tiles: 304,
        aie_lanes_per_tile: 4, // lanes the CHARM mapping sustains per tile (≈ PL width at 304 tiles)
    }
}

/// FIXAR (paper [27], §V-C baseline): CPU–FPGA platform at 164 MHz with
/// 16-bit fixed-point quantization-aware training and adaptive
/// parallelism.  Modeled as a PL-like fabric at the lower clock with the
/// fx16 (→fp16-width) datapath, plus the host CPU.
pub fn fixar_platform() -> Platform {
    let mut p = vek280();
    p.name = "FIXAR (modeled, CPU-FPGA @164 MHz)";
    p.pl.clock_mhz = 164.0;
    p.pl.init_us = 7.0;
    // FIXAR's adaptive parallelism keeps the fabric well utilized.
    p.pl.efficiency = 0.65;
    // AIE does not exist on FIXAR's platform; keep the spec but the
    // baseline scheduler never assigns nodes to it.
    p.aie.max_mac_lanes = 0;
    p
}

/// Format choice helpers shared by baselines.
pub fn fixar_format() -> Format {
    Format::Fx16
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper §III-A: at high FLOPs the optimized PL and AIE differ mainly
    /// by clock; at low FLOPs AIE loses on launch overhead (Fig 6).
    #[test]
    fn crossover_between_pl_and_aie() {
        let p = vek280();
        // Small GEMM (64³): PL must win.
        let flops_small = 2.0 * 64f64.powi(3);
        let bytes_small = 3.0 * 64.0 * 64.0 * 2.0;
        let t_pl =
            p.pl.gemm_time(flops_small, bytes_small, 1312, Format::Fp16, true);
        let t_aie =
            p.aie.gemm_time(flops_small, bytes_small, 1312, Format::Bf16, true);
        assert!(t_pl < t_aie, "low FLOPs: PL {t_pl} should beat AIE {t_aie}");

        // Large GEMM (2048³): AIE must win by roughly the clock ratio.
        let flops_big = 2.0 * 2048f64.powi(3);
        let bytes_big = 3.0 * 2048.0 * 2048.0 * 2.0;
        let t_pl = p.pl.gemm_time(flops_big, bytes_big, 1312, Format::Fp16, true);
        let t_aie = p.aie.gemm_time(flops_big, bytes_big, 1312, Format::Bf16, true);
        let ratio = t_pl / t_aie;
        assert!(
            (2.5..6.0).contains(&ratio),
            "high FLOPs: AIE advantage should be ≈ clock ratio, got {ratio}"
        );
    }

    #[test]
    fn ps_slower_than_pl_for_gemm() {
        let p = vek280();
        let flops = 2.0 * 256f64.powi(3);
        let bytes = 3.0 * 256.0 * 256.0 * 4.0;
        let t_ps = p.ps.gemm_time(flops, bytes, usize::MAX, Format::Fp32, false);
        let t_pl = p.pl.gemm_time(flops, bytes, 1312, Format::Fp32, true);
        assert!(t_ps > t_pl);
    }

    #[test]
    fn fixar_slower_clock() {
        let f = fixar_platform();
        assert!((f.pl.clock_mhz - 164.0).abs() < 1e-9);
        assert_eq!(f.aie.max_mac_lanes, 0);
    }

    #[test]
    fn resource_pools_match_table() {
        let p = vek280();
        assert_eq!(p.pl_dsp, 1312);
        assert_eq!(p.aie_tiles, 304);
        assert!((p.pl_kluts - 520.7).abs() < 1e-9);
        assert!((p.pl_mem_mb - 113.4).abs() < 1e-9);
    }
}
