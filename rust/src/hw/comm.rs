//! Inter-component communication model (paper Fig 2 / §II-B).
//!
//! PS↔PL: 128-bit AXI interfaces in several coherency configurations —
//! TAPCA (paper [13]) picks among them; see `profile::tapca`.
//! PL↔AIE: PLIO streams in the interface tiles (PL-clock wide side,
//! 1 GHz AIE side).  PS↔AIE traffic is routed through the PL (no direct
//! path on Versal AI Edge).

use crate::Micros;

use super::component::Component;

/// A directed transfer channel between two components.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Link {
    PsPl,
    PlAie,
    /// PS→AIE is PS→PL→AIE (and vice versa); modeled as both hops.
    PsAie,
}

impl Link {
    pub fn between(a: Component, b: Component) -> Option<Link> {
        use Component::*;
        match (a, b) {
            (PS, PL) | (PL, PS) => Some(Link::PsPl),
            (PL, AIE) | (AIE, PL) => Some(Link::PlAie),
            (PS, AIE) | (AIE, PS) => Some(Link::PsAie),
            _ => None,
        }
    }
}

/// Latency + bandwidth per link.  Values are the full-coherency AXI
/// numbers from the TAPCA paper scaled to VEK280 clocks, and PLIO
/// aggregate bandwidth for the interface-tile count CHARM allocates.
#[derive(Clone, Debug)]
pub struct CommModel {
    /// AXI PS↔PL: per-transfer latency (µs) + bandwidth (GB/s).
    pub ps_pl_lat_us: Micros,
    pub ps_pl_gbps: f64,
    /// PLIO PL↔AIE.
    pub pl_aie_lat_us: Micros,
    pub pl_aie_gbps: f64,
}

impl CommModel {
    /// Time to move `bytes` across `link`.
    pub fn transfer_time(&self, link: Link, bytes: f64) -> Micros {
        match link {
            Link::PsPl => self.ps_pl_lat_us + bytes / (self.ps_pl_gbps * 1e9) * 1e6,
            Link::PlAie => self.pl_aie_lat_us + bytes / (self.pl_aie_gbps * 1e9) * 1e6,
            Link::PsAie => {
                self.transfer_time(Link::PsPl, bytes) + self.transfer_time(Link::PlAie, bytes)
            }
        }
    }

    /// Edge cost between two (possibly equal) components.  Same-component
    /// edges are free: the data stays in local memory.
    pub fn edge_cost(&self, from: Component, to: Component, bytes: f64) -> Micros {
        match Link::between(from, to) {
            None => 0.0,
            Some(link) => self.transfer_time(link, bytes),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::platform::vek280;

    #[test]
    fn same_component_free() {
        let p = vek280();
        assert_eq!(p.comm.edge_cost(Component::PL, Component::PL, 1e6), 0.0);
    }

    #[test]
    fn ps_aie_is_two_hops() {
        let p = vek280();
        let direct =
            p.comm.transfer_time(Link::PsPl, 4096.0) + p.comm.transfer_time(Link::PlAie, 4096.0);
        assert!((p.comm.transfer_time(Link::PsAie, 4096.0) - direct).abs() < 1e-9);
    }

    #[test]
    fn latency_dominates_small_transfers() {
        let p = vek280();
        let t1 = p.comm.transfer_time(Link::PlAie, 64.0);
        let t2 = p.comm.transfer_time(Link::PlAie, 128.0);
        // Doubling tiny payloads barely changes the time (latency floor).
        assert!((t2 - t1) / t1 < 0.05);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let p = vek280();
        let t1 = p.comm.transfer_time(Link::PlAie, 1e8);
        let t2 = p.comm.transfer_time(Link::PlAie, 2e8);
        assert!(t2 / t1 > 1.9);
    }

    #[test]
    fn link_between() {
        assert_eq!(Link::between(Component::PS, Component::PS), None);
        assert_eq!(Link::between(Component::AIE, Component::PL), Some(Link::PlAie));
        assert_eq!(Link::between(Component::PS, Component::AIE), Some(Link::PsAie));
    }
}
