//! Self-built substrates that would normally come from crates.io (this
//! build is fully offline/vendored): RNG, JSON, statistics, a lightweight
//! property-testing harness and a micro-benchmark runner.

pub mod bench;
pub mod fsio;
pub mod json;
pub mod proplite;
pub mod rng;
pub mod stats;

pub use rng::Rng;
