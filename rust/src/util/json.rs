//! Minimal JSON parser *and writer* — enough to read
//! `artifacts/manifest.json`, persist the partition plan cache
//! (`partition::cache`) and write simple reports.  serde is not in the
//! vendored crate set, so this is one of the substrates we build
//! ourselves.  Serialization is the `Display` impl; `Json::parse(
//! &v.to_string())` round-trips every finite value.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Build an object from `(key, value)` pairs — sugar for decoders and
    /// checkpoint writers that would otherwise thread a `BTreeMap`.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Required-key lookup: like [`Json::get`] but a hard error when the
    /// key is absent, for decoding checkpoints/wire frames where a missing
    /// field means a corrupt or incompatible payload.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError { msg: format!("missing key {key:?}"), pos: 0 })
    }

    pub fn req_f64(&self, key: &str) -> Result<f64, JsonError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| JsonError { msg: format!("key {key:?} is not a number"), pos: 0 })
    }

    pub fn req_u64(&self, key: &str) -> Result<u64, JsonError> {
        let n = self.req_f64(key)?;
        if n < 0.0 || n.fract() != 0.0 || n > (1u64 << 53) as f64 {
            return Err(JsonError { msg: format!("key {key:?} is not a small u64"), pos: 0 });
        }
        Ok(n as u64)
    }

    pub fn req_str(&self, key: &str) -> Result<&str, JsonError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| JsonError { msg: format!("key {key:?} is not a string"), pos: 0 })
    }

    pub fn req_arr(&self, key: &str) -> Result<&[Json], JsonError> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| JsonError { msg: format!("key {key:?} is not an array"), pos: 0 })
    }

    /// Required key holding a bit-exact f32 (stored via [`hex_f32s`] of a
    /// one-element slice).
    pub fn req_f32_bits(&self, key: &str) -> Result<f32, JsonError> {
        let v = parse_hex_f32s(self.req_str(key)?)?;
        if v.len() != 1 {
            return Err(JsonError { msg: format!("key {key:?} is not a single f32"), pos: 0 });
        }
        Ok(v[0])
    }

    /// Required key holding a hex-encoded u64 (stored via [`hex_u64`]).
    pub fn req_u64_hex(&self, key: &str) -> Result<u64, JsonError> {
        parse_hex_u64(self.req_str(key)?)
    }

    /// Strict one-line serializer for wire protocols (the planning
    /// server's JSON-lines framing).  Unlike `Display` — which degrades
    /// non-finite numbers to `null` for best-effort report files — a
    /// NaN/Inf anywhere in the tree is a hard error here: a planner
    /// response silently swapping a latency for `null` would corrupt the
    /// remote side's schedule instead of failing the request.  The
    /// output never contains a raw newline (control characters are
    /// `\u`-escaped), so it frames safely as one line.
    pub fn to_line(&self) -> Result<String, JsonError> {
        self.reject_non_finite()?;
        Ok(self.to_string())
    }

    fn reject_non_finite(&self) -> Result<(), JsonError> {
        match self {
            Json::Num(n) if !n.is_finite() => Err(JsonError {
                msg: format!("non-finite number {n} has no JSON representation"),
                pos: 0,
            }),
            Json::Arr(items) => items.iter().try_for_each(Json::reject_non_finite),
            Json::Obj(map) => map.values().try_for_each(Json::reject_non_finite),
            _ => Ok(()),
        }
    }
}

/// Compact serializer (no insignificant whitespace).  Non-finite numbers
/// have no JSON representation and degrade to `null`; rust's default
/// `f64` formatting is shortest-round-trip, so parse ∘ to_string is the
/// identity on finite values.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if n.is_finite() => write!(f, "{n}"),
            Json::Num(_) => f.write_str("null"),
            Json::Str(s) => write!(f, "\"{}\"", escape(s)),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(map) => {
                f.write_str("{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "\"{}\":{v}", escape(k))?;
                }
                f.write_str("}")
            }
        }
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn literal(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {s}")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // multi-byte UTF-8: copy raw continuation bytes
                    let len = if c >= 0xF0 {
                        4
                    } else if c >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

// ---------------------------------------------------------------------------
// Bit-exact numeric codecs for checkpoints.
//
// Checkpoints must restore training state *bit-identically*: weights, Adam
// moments and RNG states cannot tolerate a decimal round-trip (NaN payloads
// and u64 > 2^53 would not survive `Json::Num`).  Dense float arrays are
// therefore carried as hex strings of their IEEE-754 bit patterns — 8 hex
// chars per f32, 16 per f64 — and u64 state words as 16-char hex strings.

/// Encode an f32 slice as a hex string (8 chars per element, big-endian bits).
pub fn hex_f32s(xs: &[f32]) -> String {
    let mut out = String::with_capacity(xs.len() * 8);
    for x in xs {
        out.push_str(&format!("{:08x}", x.to_bits()));
    }
    out
}

/// Decode a string produced by [`hex_f32s`].
pub fn parse_hex_f32s(s: &str) -> Result<Vec<f32>, JsonError> {
    if s.len() % 8 != 0 || !s.is_ascii() {
        return Err(JsonError { msg: "bad f32 hex array".into(), pos: 0 });
    }
    s.as_bytes()
        .chunks(8)
        .map(|c| {
            let chunk = std::str::from_utf8(c).expect("ascii checked above");
            u32::from_str_radix(chunk, 16)
                .map(f32::from_bits)
                .map_err(|_| JsonError { msg: format!("bad f32 hex {chunk:?}"), pos: 0 })
        })
        .collect()
}

/// Encode an f64 slice as a hex string (16 chars per element).
pub fn hex_f64s(xs: &[f64]) -> String {
    let mut out = String::with_capacity(xs.len() * 16);
    for x in xs {
        out.push_str(&format!("{:016x}", x.to_bits()));
    }
    out
}

/// Decode a string produced by [`hex_f64s`].
pub fn parse_hex_f64s(s: &str) -> Result<Vec<f64>, JsonError> {
    if s.len() % 16 != 0 || !s.is_ascii() {
        return Err(JsonError { msg: "bad f64 hex array".into(), pos: 0 });
    }
    s.as_bytes()
        .chunks(16)
        .map(|c| {
            let chunk = std::str::from_utf8(c).expect("ascii checked above");
            u64::from_str_radix(chunk, 16)
                .map(f64::from_bits)
                .map_err(|_| JsonError { msg: format!("bad f64 hex {chunk:?}"), pos: 0 })
        })
        .collect()
}

/// Encode a u64 (e.g. an RNG state word) losslessly as a hex string.
pub fn hex_u64(x: u64) -> String {
    format!("{x:016x}")
}

/// Decode a string produced by [`hex_u64`].
pub fn parse_hex_u64(s: &str) -> Result<u64, JsonError> {
    u64::from_str_radix(s, 16).map_err(|_| JsonError { msg: format!("bad u64 hex {s:?}"), pos: 0 })
}

/// Escape a string for JSON output (report writers).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_unicode_escape_and_utf8() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(Json::parse("\"é\"").unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"source_hash": "abc", "artifacts": {"x": {"file": "x.hlo.txt",
            "inputs": [{"shape": [64, 4], "dtype": "float32"}], "outputs": [],
            "meta": {"kind": "train", "batch": 64}}}}"#;
        let v = Json::parse(text).unwrap();
        let x = v.get("artifacts").unwrap().get("x").unwrap();
        assert_eq!(x.get("file").unwrap().as_str(), Some("x.hlo.txt"));
        let shape = x.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_usize(), Some(64));
    }

    #[test]
    fn escape_round_trips() {
        let s = "a\"b\\c\nd";
        let json = format!("\"{}\"", escape(s));
        assert_eq!(Json::parse(&json).unwrap(), Json::Str(s.into()));
    }

    #[test]
    fn serializer_round_trips() {
        let text = r#"{"a": [1, 2.5, {"b": "c\nd"}], "e": {}, "f": true, "g": null}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        // integral floats print without a trailing .0 and still parse
        assert_eq!(Json::Num(42.0).to_string(), "42");
        // non-finite degrades to null instead of emitting invalid JSON
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn escaped_strings_round_trip_the_wire() {
        // Every escape class the protocol can carry: quotes, backslashes,
        // path separators, control characters, tabs/newlines/CRs, unicode
        // (both raw UTF-8 and \u escapes) and the \u0000..\u001f band.
        let cases = [
            "plain",
            "quote\"inside",
            "back\\slash",
            "C:\\path\\to\\file",
            "line\nbreak\r\n",
            "tab\tand\u{8}backspace\u{c}formfeed",
            "unicode é ü 漢字 🦀",
            "\u{1}\u{2}\u{1f}",
            "",
        ];
        for s in cases {
            let v = Json::Str(s.to_string());
            let line = v.to_line().unwrap();
            assert!(!line.contains('\n'), "wire form must stay one line: {line:?}");
            assert_eq!(Json::parse(&line).unwrap(), v, "round trip failed for {s:?}");
        }
        // And nested inside object keys, where escaping also applies.
        let mut m = BTreeMap::new();
        m.insert("key\nwith\tescapes\"".to_string(), Json::Str("v\\".into()));
        let v = Json::Obj(m);
        assert_eq!(Json::parse(&v.to_line().unwrap()).unwrap(), v);
    }

    #[test]
    fn wire_serializer_rejects_non_finite_with_a_clear_error() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let e = Json::Num(bad).to_line().unwrap_err();
            assert!(
                format!("{e}").contains("non-finite"),
                "error must name the cause: {e}"
            );
        }
        // Deeply nested non-finite values are found too.
        let mut m = BTreeMap::new();
        m.insert("a".to_string(), Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NAN)]));
        assert!(Json::Obj(m).to_line().is_err());
        // Finite trees pass through identical to Display.
        let v = Json::parse(r#"{"x":[1,2.5,"s"],"y":null}"#).unwrap();
        assert_eq!(v.to_line().unwrap(), v.to_string());
    }

    #[test]
    fn parser_rejects_nan_and_infinity_tokens() {
        // JSON has no NaN/Infinity literals; they must not sneak in as
        // numbers from a buggy peer.
        for bad in ["NaN", "Infinity", "-Infinity", "[1,NaN]", "{\"x\":Infinity}"] {
            assert!(Json::parse(bad).is_err(), "{bad} must not parse");
        }
    }

    #[test]
    fn hex_codecs_are_bit_exact() {
        let f32s = [0.0f32, -0.0, 1.5, f32::NAN, f32::INFINITY, f32::MIN_POSITIVE, -123.456];
        let back = parse_hex_f32s(&hex_f32s(&f32s)).unwrap();
        for (a, b) in f32s.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let f64s = [0.0f64, -0.0, 1.5e-300, f64::NAN, f64::NEG_INFINITY];
        let back = parse_hex_f64s(&hex_f64s(&f64s)).unwrap();
        for (a, b) in f64s.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for x in [0u64, u64::MAX, 0x9E37_79B9_7F4A_7C15] {
            assert_eq!(parse_hex_u64(&hex_u64(x)).unwrap(), x);
        }
        assert!(parse_hex_f32s("zzzzzzzz").is_err());
        assert!(parse_hex_f32s("abc").is_err());
        assert!(parse_hex_u64("not hex").is_err());
    }

    #[test]
    fn serializer_precision_preserves_f64() {
        let x = 123.456789012345678_f64;
        let v = Json::Arr(vec![Json::Num(x)]);
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(back.as_arr().unwrap()[0].as_f64(), Some(x));
    }
}
