//! Crash-safe file persistence.
//!
//! Every persisted artifact in this crate (plan cache, calibration
//! table, job journal) is a single JSON document that readers validate
//! wholesale: a torn half-written file fails the schema check and
//! silently degrades to a cold start.  [`atomic_write`] closes that
//! window — the bytes land in a sibling temp file first, are fsynced,
//! and then `rename(2)` moves them over the live path.  On the same
//! filesystem the rename is atomic, so readers observe either the old
//! complete file or the new complete file, never a prefix.

use std::fs;
use std::io::{self, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Monotonic discriminator so concurrent writers in one process (e.g.
/// two runner threads journalling different jobs into the same
/// directory) never collide on a temp name.
static TEMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Sibling temp path for `path`: same directory (so the final rename
/// stays on one filesystem), dot-prefixed so directory scans skip it.
fn temp_sibling(path: &Path) -> PathBuf {
    let name = path.file_name().and_then(|n| n.to_str()).unwrap_or("file");
    let pid = std::process::id();
    let seq = TEMP_SEQ.fetch_add(1, Ordering::Relaxed);
    path.with_file_name(format!(".{name}.tmp.{pid}.{seq}"))
}

/// Write `contents` to `path` atomically: temp sibling + fsync +
/// rename.  Parent directories are created as needed.  On any error
/// the temp file is removed and the previous `path` contents (if any)
/// are left untouched.
pub fn atomic_write(path: &Path, contents: &[u8]) -> io::Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            fs::create_dir_all(parent)?;
        }
    }
    let tmp = temp_sibling(path);
    let result = (|| -> io::Result<()> {
        let mut f = fs::File::create(&tmp)?;
        f.write_all(contents)?;
        // Flush to stable storage before the rename publishes the file:
        // otherwise a power loss could leave a *renamed* but empty file,
        // which is exactly the torn state this helper exists to prevent.
        f.sync_all()?;
        fs::rename(&tmp, path)
    })();
    if result.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    result
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("apdrl_fsio_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn atomic_write_round_trips_and_creates_parents() {
        let dir = scratch_dir("roundtrip");
        let path = dir.join("nested/deeper/out.json");
        atomic_write(&path, b"{\"v\":1}").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"{\"v\":1}");
        // Overwrite in place: readers see old-complete or new-complete.
        atomic_write(&path, b"{\"v\":2}").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"{\"v\":2}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn atomic_write_leaves_no_temp_droppings() {
        let dir = scratch_dir("clean");
        let path = dir.join("out.json");
        atomic_write(&path, b"data").unwrap();
        let names: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        assert_eq!(names, vec!["out.json".to_string()]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_write_preserves_the_previous_file() {
        let dir = scratch_dir("preserve");
        let path = dir.join("out.json");
        atomic_write(&path, b"original").unwrap();
        // Simulate the interruption window: a temp sibling exists but the
        // rename never happened (writer died).  The live file is intact
        // and a later successful write still lands atomically.
        let stale = path.with_file_name(".out.json.tmp.dead.0");
        fs::write(&stale, b"torn-partial").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"original");
        atomic_write(&path, b"replacement").unwrap();
        assert_eq!(fs::read(&path).unwrap(), b"replacement");
        // Writing to a path whose parent is an existing *file* must fail
        // without disturbing anything.
        let blocked = path.join("child.json"); // out.json is a file, not a dir
        assert!(atomic_write(&blocked, b"x").is_err());
        assert_eq!(fs::read(&path).unwrap(), b"replacement");
        let _ = fs::remove_dir_all(&dir);
    }
}
