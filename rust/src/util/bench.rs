//! Micro-benchmark harness (criterion is not in the vendored crate set).
//!
//! Used by the `cargo bench` targets (`harness = false`): warms up, runs
//! timed iterations until a wall budget or iteration cap, and prints
//! median / mean / p95 per benchmark plus optional throughput.

use std::time::{Duration, Instant};

use super::stats;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub p95_ns: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10} iters   median {:>12}   mean {:>12}   p95 {:>12}",
            self.name,
            self.iters,
            fmt_ns(self.median_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.p95_ns)
        );
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark `f`, returning timing stats.  `budget` bounds total wall time.
pub fn bench<F: FnMut()>(name: &str, budget: Duration, mut f: F) -> BenchResult {
    // Warm-up: a few calls, also measures rough per-iter cost.
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_iters < 3 || (warm_start.elapsed() < budget / 10 && warm_iters < 1000) {
        f();
        warm_iters += 1;
    }
    let per_iter = warm_start.elapsed().as_nanos() as f64 / warm_iters as f64;
    // Aim for enough samples within the budget.
    let target_iters = ((budget.as_nanos() as f64 / per_iter.max(1.0)) as usize).clamp(5, 10_000);
    let mut samples = Vec::with_capacity(target_iters);
    let run_start = Instant::now();
    for _ in 0..target_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_nanos() as f64);
        if run_start.elapsed() > budget {
            break;
        }
    }
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        median_ns: stats::median(&samples),
        mean_ns: stats::mean(&samples),
        p95_ns: stats::percentile(&samples, 95.0),
    }
}

/// Convenience: bench and print with the default 2 s budget.
pub fn run<F: FnMut()>(name: &str, f: F) -> BenchResult {
    let r = bench(name, Duration::from_secs(2), f);
    r.print();
    r
}

/// `black_box` stand-in: prevent the optimizer from deleting a value.
#[inline]
pub fn observe<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("spin", Duration::from_millis(50), || {
            let mut s = 0u64;
            for i in 0..1000 {
                s = s.wrapping_add(observe(i));
            }
            observe(s);
        });
        assert!(r.iters >= 5);
        assert!(r.median_ns > 0.0);
        assert!(r.p95_ns >= r.median_ns);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).ends_with("ns"));
        assert!(fmt_ns(5e4).ends_with("µs"));
        assert!(fmt_ns(5e7).ends_with("ms"));
        assert!(fmt_ns(5e9).ends_with("s"));
    }
}
