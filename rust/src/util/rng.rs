//! Deterministic RNG: SplitMix64 core with uniform / normal / categorical
//! helpers.  Every stochastic component (envs, exploration, init) takes an
//! explicit seed so the 5-seed convergence runs of Fig 11 are reproducible.

/// SplitMix64 — tiny, fast, passes BigCrush as a 64-bit mixer; more than
/// enough statistical quality for DRL exploration noise.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
    /// Cached second Box-Muller output.
    spare_normal: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15), spare_normal: None }
    }

    /// Derive an independent stream (e.g. per-environment from a run seed).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xBF58_476D_1CE4_E5B9))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let (u1, u2) = (self.uniform().max(1e-300), self.uniform());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Snapshot the full generator state (SplitMix64 word + cached
    /// Box-Muller spare) for checkpointing.  `from_parts` restores a
    /// generator that continues the stream bit-identically.
    pub fn state_parts(&self) -> (u64, Option<f64>) {
        (self.state, self.spare_normal)
    }

    /// Rebuild a generator from a [`Rng::state_parts`] snapshot.
    pub fn from_parts(state: u64, spare_normal: Option<f64>) -> Self {
        Self { state, spare_normal }
    }

    /// He-uniform tensor init, mirroring `python/compile/nets.py::init_scale`.
    pub fn he_uniform(&mut self, n: usize, fan_in: usize) -> Vec<f32> {
        let lim = (6.0 / fan_in as f64).sqrt();
        (0..n).map(|_| self.uniform_in(-lim, lim) as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let (mut a, mut b) = (Rng::new(7), Rng::new(7));
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(1);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.uniform()).collect();
        assert!(xs.iter().all(|&x| (0.0..1.0).contains(&x)));
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(2);
        let xs: Vec<f64> = (0..40_000).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 3];
        for _ in 0..30_000 {
            counts[rng.categorical(&[1.0, 2.0, 1.0])] += 1;
        }
        let f1 = counts[1] as f64 / 30_000.0;
        assert!((f1 - 0.5).abs() < 0.02, "f1={f1}");
    }

    #[test]
    fn below_covers_range() {
        let mut rng = Rng::new(4);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[rng.below(5)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn fork_streams_differ() {
        let mut root = Rng::new(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn state_round_trip_continues_stream() {
        let mut rng = Rng::new(11);
        rng.normal(); // leave a cached spare in place
        let (state, spare) = rng.state_parts();
        let mut copy = Rng::from_parts(state, spare);
        for _ in 0..16 {
            assert_eq!(rng.normal().to_bits(), copy.normal().to_bits());
            assert_eq!(rng.next_u64(), copy.next_u64());
        }
    }

    #[test]
    fn he_uniform_bounds() {
        let mut rng = Rng::new(5);
        let v = rng.he_uniform(1000, 64);
        let lim = (6.0f64 / 64.0).sqrt() as f32;
        assert!(v.iter().all(|&x| x.abs() <= lim));
        assert_eq!(v.len(), 1000);
    }
}
