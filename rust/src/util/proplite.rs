//! Lightweight property-testing harness (proptest is not in the vendored
//! crate set).  Runs `N` deterministic random cases from a seed; on
//! failure reports the case index + seed so the exact case replays.
//!
//! ```no_run
//! # // no_run: doctest binaries lack the -Wl,-rpath for libxla's libstdc++
//! use apdrl::util::proplite::forall;
//! forall(100, 0xC0FFEE, |rng| {
//!     let x = rng.uniform_in(-1e3, 1e3);
//!     let y = x * 2.0;
//!     assert!((y / 2.0 - x).abs() < 1e-9);
//! });
//! ```

use super::rng::Rng;

/// Run `cases` random property checks.  Panics (re-raising the inner
/// assertion) with the failing case index and derived seed.
pub fn forall<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(cases: usize, seed: u64, prop: F) {
    for i in 0..cases {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(case_seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {i}/{cases} (seed {case_seed:#x}): {msg}");
        }
    }
}

/// Random subset sizes, vector helpers for property generators.
pub fn vec_f32(rng: &mut Rng, len: usize, lo: f32, hi: f32) -> Vec<f32> {
    (0..len).map(|_| rng.uniform_in(lo as f64, hi as f64) as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        forall(50, 1, |rng| {
            let v = vec_f32(rng, 8, -1.0, 1.0);
            assert_eq!(v.len(), 8);
            assert!(v.iter().all(|x| x.abs() <= 1.0));
        });
    }

    #[test]
    #[should_panic(expected = "property failed at case")]
    fn failing_property_reports_case() {
        forall(100, 2, |rng| {
            assert!(rng.uniform() < 0.9, "triggered");
        });
    }
}
