//! Small statistics helpers shared by metrics, reports and the bench
//! harness.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = (q / 100.0) * (v.len() - 1) as f64;
    let (lo, hi) = (pos.floor() as usize, pos.ceil() as usize);
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Moving average with window `w` (paper Fig 11 uses a 100-episode
/// sliding window over episodic rewards).
pub fn moving_average(xs: &[f64], w: usize) -> Vec<f64> {
    if w == 0 || xs.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::with_capacity(xs.len());
    let mut sum = 0.0;
    for (i, &x) in xs.iter().enumerate() {
        sum += x;
        if i >= w {
            sum -= xs[i - w];
        }
        out.push(sum / (i.min(w - 1) + 1) as f64);
    }
    out
}

/// Relative error |a - b| / max(|b|, eps) — the paper's "reward error %"
/// between quantized and fp32 converged rewards (Table III).
pub fn relative_error(a: f64, b: f64) -> f64 {
    (a - b).abs() / b.abs().max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert!((std_dev(&xs) - 1.118034).abs() < 1e-6);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn percentiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn moving_average_window() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let ma = moving_average(&xs, 2);
        assert_eq!(ma, vec![1.0, 1.5, 2.5, 3.5, 4.5]);
        let ma1 = moving_average(&xs, 100);
        assert!((ma1[4] - 3.0).abs() < 1e-12); // mean of all five
        assert!(moving_average(&[], 3).is_empty());
        assert!(moving_average(&xs, 0).is_empty());
    }

    #[test]
    fn rel_err() {
        assert!((relative_error(101.0, 100.0) - 0.01).abs() < 1e-12);
        assert!(relative_error(1.0, 0.0) > 1e9);
    }
}
