//! `apdrl` — leader CLI for the AP-DRL reproduction.
//!
//! Subcommands:
//!   plan  <combo> [--batch N] [--fp32] [--remote <hosts>]
//!                                             static phase: profile + ILP
//!   sweep [--fp32] [--remote <hosts>]         batched planning service:
//!         [--progress]                        all Table III combos × batches;
//!                                             --progress streams per-point
//!                                             lines (local or one daemon)
//!   profile <combo> [--batch N] [--fp32]      DSE candidate table per node
//!           [--remote <host>]                 (local, or the daemon's view)
//!   calibrate [--out PATH] [--reps N]         measure the hot kernels on
//!                                             this machine and write the
//!                                             calibration table the planner
//!                                             prices PS costs from
//!                                             (activate via APDRL_CALIB)
//!   serve [--addr A] [--workers N]            long-lived planning server
//!         --stop | --stats [--addr A]         remote-control a running one
//!                                             (APDRL_JOB_DIR makes its
//!                                             jobs durable across crashes)
//!   train --combo <algo-env> [--quantized] [--seed S] [--steps N]
//!         [--episodes N] [--threads N]        dynamic phase on the CPU
//!         [--actors N]                        executor: plan → precision
//!                                             policy → train
//!         --remote <hosts> [--priority P]     …or submit as a streaming
//!         [--checkpoint-every N]              job to the least-loaded
//!         [--progress-every N]                daemon (protocol v3), with
//!         [--detach]                          checkpoint hand-off to a
//!                                             survivor if a host dies;
//!                                             --detach submits and exits
//!   jobs  [--remote <hosts>] [--cancel ID]    list / cancel the daemons'
//!                                             training jobs
//!   journal [--dir D] [--job ID] [--rewards]  inspect a daemon's on-disk
//!                                             job journal (APDRL_JOB_DIR);
//!                                             --rewards prints the raw-bit
//!                                             hex reward log for bit-exact
//!                                             comparison
//!   dash  [--addr A] [--token T]              live observability hub: SSE
//!                                             event stream + HTML dashboard
//!   platform                                  PJRT + artifact info     (pjrt)
//!   list                                      known combos + artifacts
//!
//! Observability: `apdrl dash` binds an HTTP hub (default
//! `127.0.0.1:7044`, or `APDRL_DASH`); any `plan`/`sweep`/`serve`/`train`
//! process started with `APDRL_DASH=host:port` forwards its structured
//! events (episodes, FSM transitions, sweep progress, federation health)
//! there in the background — see the `apdrl::obs` module docs.
//! Non-loopback dash binds require `APDRL_DASH_TOKEN`.
//!
//! `plan` and `sweep` pick their *planning* backend in exactly one
//! place (`server::select_planner`): in-process by default, one daemon
//! for `--remote host:port`, a sharded fail-over federation for
//! `--remote host1:p,host2:p,...` — the `APDRL_SERVER` environment
//! variable (same single-host or comma-list shape) substitutes for the
//! flag.  The printed tables are identical whichever backend planned
//! them (remote plans are bit-identical to local ones).
//!
//! For `train`, an explicit `--remote <hosts>` goes further: the whole
//! run becomes a *job* submitted to the least-loaded daemon of the list
//! (protocol v3), which plans and trains server-side while streaming
//! episodes, loss-scale transitions and bit-exact checkpoints back over
//! the connection.  If the serving host dies or drains mid-job, the
//! client re-submits the newest checkpoint to a surviving host and the
//! run continues from the snapshot.  `apdrl jobs` lists (and
//! `--cancel <id>` stops) the jobs of every host.  Without the flag,
//! `train` runs in-process as before (`APDRL_SERVER` alone still
//! selects only the remote *planning* backend).
//!
//! `train` first plans the static phase, folds the solved schedule into
//! a per-layer precision policy, then runs the training loop on the
//! pure-Rust CPU executor (no PJRT needed).  `--quantized` selects
//! AP-DRL mixed precision and, unless `--no-compare`, reruns the same
//! seed in FP32 for the reward-error summary.  The executor's GEMM
//! kernels fan out over a worker pool sized by `--threads N` (or the
//! `APDRL_THREADS` env); thread count is a pure wall-clock knob — the
//! kernels are bit-exact across thread counts, so rewards and FSM
//! transitions do not change.  `--actors N` collects with an N-lane
//! env fleet stepped in lockstep (one batched inference per round);
//! `--actors 1` (the default) is bit-identical to the historical
//! scalar loop.  With the `pjrt` feature, `--backend pjrt [--mode M]`
//! trains over the artifacts instead.
//!
//! Figures/tables of the paper are regenerated by the `figures` binary.

use anyhow::{anyhow, bail, Result};

use apdrl::coordinator::metrics::{reward_error_pct, RunMetrics};
use apdrl::coordinator::report::ascii_table;
use apdrl::coordinator::{
    plan_sweep_progress, train_combo_actors, try_combo, PlanOutcome, PlanRequest, Planner,
    TrainLimits, TrainResult, COMBO_NAMES,
};
use apdrl::exec::{CpuBackend, ExecPolicy};
use apdrl::hw::Component;
use apdrl::obs::{DashServer, Forwarder, DEFAULT_DASH_ADDR, ENV_DASH, ENV_DASH_TOKEN};
#[cfg(feature = "pjrt")]
use apdrl::runtime::Runtime;
use apdrl::server::{
    parse_host_list, select_planner, server_addr, Journal, RemotePlanner, RemoteTrainer, Server,
    TrainSubmission, DEFAULT_ADDR, ENV_ADDR, ENV_JOB_DIR,
};
use apdrl::util::json::{hex_f64s, Json};

/// Tiny argv parser (clap is not in the vendored crate set).
pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::HashMap<String, String>,
}

impl Args {
    pub fn parse() -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::HashMap::new();
        let mut it = std::env::args().skip(1).peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = if it.peek().map_or(false, |v| !v.starts_with("--")) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

fn cmd_plan(args: &Args) -> Result<()> {
    let fwd = Forwarder::from_env();
    let name = args.positional.get(1).map(String::as_str).unwrap_or("dqn_cartpole");
    let base = PlanRequest::named(name)?;
    let batch = args.usize_flag("batch", base.batch);
    let req = base.with_batch(batch).with_quantized(args.flag("fp32").is_none());
    let planner = select_planner(args.flag("remote"))?;
    let plan = planner.plan(&req)?;
    println!(
        "== static phase: {} (batch {}, {}) [{}] ==",
        plan.combo,
        plan.batch,
        if plan.quantized { "AP-DRL mixed precision" } else { "FP32 control" },
        plan.provenance
    );
    let rows: Vec<Vec<String>> = plan
        .schedule
        .iter()
        .map(|step| {
            // Per-step modeled-vs-measured: the CPU cost the planner
            // priced the node at (starred when it came from the
            // calibration table) against the analytic model's prediction.
            let err = if step.measured && step.modeled_us > 0.0 {
                format!(
                    "{:.0}%",
                    (step.cpu_us - step.modeled_us).abs() / step.modeled_us * 100.0
                )
            } else {
                "-".into()
            };
            vec![
                step.name.clone(),
                step.component.clone(),
                step.format.clone(),
                format!("{:.1}", step.start_us),
                format!("{:.1}", step.finish_us - step.start_us),
                format!("{:.1}{}", step.cpu_us, if step.measured { "*" } else { "" }),
                err,
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(
            &["node", "unit", "fmt", "start µs", "dur µs", "cpu µs", "err"],
            &rows
        )
    );
    println!(
        "makespan {:.1} µs | comm {:.1} µs | sync {:.1} µs | PS-PL {} {:.1} µs",
        plan.makespan_us, plan.comm_us, plan.sync_us, plan.interface, plan.ps_pl_us
    );
    if plan.calib_steps > 0 {
        println!(
            "calibration: {}/{} steps' CPU costs measured (table {}, * marks them), \
             modeled-vs-measured error {:.1}%",
            plan.calib_steps,
            plan.schedule.len(),
            plan.calib_fingerprint,
            plan.calib_err_pct
        );
    }
    println!(
        "MM nodes on AIE: {}/{} | ILP explored {}{} | step {:.1} µs | {:.1} steps/s",
        plan.aie_mm_nodes,
        plan.mm_nodes,
        plan.explored,
        if plan.cache_hit { " (plan cache hit)" } else { "" },
        plan.step_time_us(),
        plan.throughput()
    );
    if let Some(f) = fwd {
        f.finish();
    }
    Ok(())
}

/// Batched planning service over the Table III timing combos: every
/// combo at a small batch ladder, planned concurrently, cache-aware.
/// The backend comes from `select_planner` (`--remote` / `APDRL_SERVER`,
/// single host or comma-separated federation list); the table is
/// identical whichever backend planned it, so there is exactly one row
/// formatter.
fn cmd_sweep(args: &Args) -> Result<()> {
    use apdrl::coordinator::config::TIMING_COMBO_NAMES;
    let fwd = Forwarder::from_env();
    let quantized = args.flag("fp32").is_none();
    let names: Vec<String> = TIMING_COMBO_NAMES.iter().map(|s| s.to_string()).collect();
    let batches = [64usize, 256];
    let t0 = std::time::Instant::now();
    let (describe, plans) = if args.flag("progress").is_some() {
        sweep_with_progress(args, &names, &batches, quantized)?
    } else {
        let reqs = PlanRequest::named_grid(&names, &batches, quantized)?;
        let planner = select_planner(args.flag("remote"))?;
        (planner.describe(), planner.plan_many(&reqs)?)
    };
    let wall = t0.elapsed();
    let table: Vec<Vec<String>> = plans.iter().map(sweep_row).collect();
    println!(
        "== plan sweep [{describe}]: {} combos × {:?} batches, {} ({} plans in {:.0} ms) ==",
        names.len(),
        batches,
        if quantized { "mixed precision" } else { "FP32" },
        table.len(),
        wall.as_secs_f64() * 1e3
    );
    println!(
        "{}",
        ascii_table(
            &["combo", "batch", "makespan µs", "AIE MM", "steps/s", "explored"],
            &table
        )
    );
    if let Some(f) = fwd {
        f.finish();
    }
    Ok(())
}

/// One streamed sweep-progress line (same shape for the local and the
/// remote source, so the output never depends on where planning ran).
fn progress_line(
    done: usize,
    total: usize,
    combo: &str,
    batch: usize,
    cache_hit: bool,
    explored: usize,
    solve_us: u64,
) -> String {
    format!(
        "[{done}/{total}] {combo} batch {batch}: {}",
        if cache_hit {
            "plan cache hit".to_string()
        } else {
            format!("solved in {solve_us} µs ({explored} nodes explored)")
        }
    )
}

/// `apdrl sweep --progress`: stream one line per grid point as it
/// lands.  Locally this taps `plan_sweep_progress` directly; against a
/// single `--remote` daemon it rides the protocol-v2 streaming sweep
/// (an old daemon without streaming degrades to the final table only).
/// Federated host lists have no streaming path yet and are rejected.
fn sweep_with_progress(
    args: &Args,
    names: &[String],
    batches: &[usize],
    quantized: bool,
) -> Result<(String, Vec<PlanOutcome>)> {
    let spec = match args.flag("remote") {
        Some(_) => Some(server_addr(args.flag("remote"))?),
        None => std::env::var(ENV_ADDR).ok().filter(|v| !v.is_empty()),
    };
    match spec {
        None => {
            let reqs = PlanRequest::named_grid(names, batches, quantized)?;
            let static_plans = plan_sweep_progress(&reqs, &|p| {
                eprintln!(
                    "  {}",
                    progress_line(
                        p.done, p.total, &p.combo, p.batch, p.cache_hit, p.explored, p.solve_us
                    )
                );
            });
            let outcomes = static_plans
                .iter()
                .zip(&reqs)
                .map(|(p, r)| PlanOutcome::from_static(p, r))
                .collect();
            Ok(("local".to_string(), outcomes))
        }
        Some(spec) => {
            let hosts = parse_host_list(&spec);
            if hosts.len() != 1 {
                bail!(
                    "sweep --progress streams from one daemon, but {spec:?} names {} hosts; \
                     drop --progress for federated sweeps",
                    hosts.len()
                );
            }
            let client = RemotePlanner::connect(&hosts[0])?;
            let mut on_progress = |p: &Json| {
                let g = |k: &str| p.get(k).and_then(Json::as_usize).unwrap_or(0);
                eprintln!(
                    "  {}",
                    progress_line(
                        g("done"),
                        g("total"),
                        p.get("combo").and_then(Json::as_str).unwrap_or("?"),
                        g("batch"),
                        p.get("cache_hit").and_then(Json::as_bool).unwrap_or(false),
                        g("explored"),
                        g("solve_us") as u64,
                    )
                );
            };
            let outcomes = client.sweep_stream(names, batches, quantized, &mut on_progress)?;
            Ok((format!("remote {}", client.addr()), outcomes))
        }
    }
}

/// `apdrl profile`: the DSE candidate table behind the planner — every
/// per-node PL/AIE (format, latency, resource) candidate the ILP chooses
/// from, locally or as a single daemon sees it (the protocol-v2
/// `profile` verb).
fn cmd_profile(args: &Args) -> Result<()> {
    let name = args
        .flag("combo")
        .or_else(|| args.positional.get(1).map(String::as_str))
        .unwrap_or("dqn_cartpole");
    let c = try_combo(name)?;
    let batch = args.usize_flag("batch", c.batch);
    let quantized = args.flag("fp32").is_none();
    let spec = match args.flag("remote") {
        Some(_) => Some(server_addr(args.flag("remote"))?),
        None => std::env::var(ENV_ADDR).ok().filter(|v| !v.is_empty()),
    };
    let (source, payload) = match spec {
        None => (
            "local".to_string(),
            apdrl::server::protocol::profile_payload(c.name, batch, quantized)?,
        ),
        Some(spec) => {
            let hosts = parse_host_list(&spec);
            if hosts.len() != 1 {
                bail!(
                    "profile queries one daemon, but {spec:?} names {} hosts",
                    hosts.len()
                );
            }
            let client = RemotePlanner::connect(&hosts[0])?;
            let payload = client.profile(c.name, batch, quantized)?;
            (format!("remote {}", client.addr()), payload)
        }
    };
    // Which hardware fingerprint and format mode the candidates were
    // priced under — without this the table is ambiguous whenever the
    // caller's --fp32 choice differs from another shell's default.
    println!(
        "== DSE profile [{source}]: {} batch {batch} | format mode: {} | platform {} ==",
        c.name,
        if quantized { "mixed precision (quantized)" } else { "FP32 control" },
        payload.get("platform").and_then(Json::as_str).unwrap_or("?")
    );
    let calib = payload.get("calibration");
    let calibrated = calib
        .and_then(|c| c.get("present"))
        .and_then(Json::as_bool)
        .unwrap_or(false);
    if let Some(cal) = calib.filter(|_| calibrated) {
        println!(
            "calibration table {} ({} entries, {} points) — PS costs are measured where covered",
            cal.get("fingerprint").and_then(Json::as_str).unwrap_or("?"),
            cal.get("entries").and_then(Json::as_usize).unwrap_or(0),
            cal.get("points").and_then(Json::as_usize).unwrap_or(0)
        );
    }
    let nodes = payload.get("nodes").and_then(Json::as_arr).unwrap_or(&[]);
    let mut rows: Vec<Vec<String>> = Vec::new();
    let mut measured_nodes = 0usize;
    for n in nodes {
        let node = n.get("name").and_then(Json::as_str).unwrap_or("?").to_string();
        let ps = n.get("ps_latency_us").and_then(Json::as_f64).unwrap_or(0.0);
        let model = n.get("ps_modeled_us").and_then(Json::as_f64).unwrap_or(ps);
        let measured = n.get("ps_measured").and_then(Json::as_bool).unwrap_or(false);
        let err = if measured && model > 0.0 {
            measured_nodes += 1;
            format!("{:.0}%", (ps - model).abs() / model * 100.0)
        } else {
            "-".into()
        };
        rows.push(vec![
            node.clone(),
            "PS".into(),
            "FP32".into(),
            format!("{ps:.1}{}", if measured { "*" } else { "" }),
            format!("{model:.1}"),
            err,
            "-".into(),
        ]);
        for (unit, key) in [("PL", "pl"), ("AIE", "aie")] {
            for cand in n.get(key).and_then(Json::as_arr).unwrap_or(&[]) {
                rows.push(vec![
                    node.clone(),
                    unit.to_string(),
                    cand.get("fmt").and_then(Json::as_str).unwrap_or("?").to_string(),
                    format!("{:.1}", cand.get("latency_us").and_then(Json::as_f64).unwrap_or(0.0)),
                    "-".into(),
                    "-".into(),
                    format!("{:.0}", cand.get("resource").and_then(Json::as_f64).unwrap_or(0.0)),
                ]);
            }
        }
    }
    println!(
        "{}",
        ascii_table(
            &["node", "unit", "fmt", "latency µs", "model µs", "err", "resource"],
            &rows
        )
    );
    println!(
        "{} nodes, {} candidate rows{}",
        nodes.len(),
        rows.len(),
        if measured_nodes > 0 {
            format!(" ({measured_nodes} PS costs measured — * marks them)")
        } else {
            String::new()
        }
    );
    Ok(())
}

/// `apdrl calibrate`: measure the executor's hot kernels on *this*
/// machine and persist the calibration table that
/// `profile::ps_latency` prices the planner's PS costs from.  The
/// sweep arms the trace layer, drives each instrumented kernel over a
/// work ladder (GEMM sizes, rounding lengths, real train steps for
/// conv/Adam shapes, batched env collection), then aggregates the
/// recorded spans into one `CalibrationTable`.  Activate the result
/// with `APDRL_CALIB=<path>` — `plan`, `profile` and `serve` then
/// optimize measured, not modeled, makespan.
fn cmd_calibrate(args: &Args) -> Result<()> {
    use apdrl::coordinator::config::{combo, ComboConfig};
    use apdrl::drl::replay::{ReplayBuffer, StoredAction};
    use apdrl::exec::{CpuDqn, Pool, Tensor};
    use apdrl::graph::{Algo, NetSpec};
    use apdrl::obs::trace;
    use apdrl::profile::{CalibrationTable, ENV_CALIB};
    use apdrl::util::bench::observe;
    use apdrl::util::Rng;

    let reps = args.usize_flag("reps", 5);
    let out = args
        .flag("out")
        .map(str::to_string)
        .or_else(|| std::env::var(ENV_CALIB).ok().filter(|p| !p.is_empty()))
        .unwrap_or_else(|| "calibration.json".to_string());
    let rec = trace::record();
    // Start from a clean aggregate: an APDRL_TRACE'd process may have
    // recorded spans already, and this sweep should stand alone.
    let _ = trace::drain_aggregate();

    let par_pool = Pool::global();
    let seq_pool = std::sync::Arc::new(Pool::new(1));
    println!(
        "== apdrl calibrate: kernel sweep ({reps} reps/shape, 1 and {} threads) ==",
        par_pool.threads()
    );
    let mut rng = Rng::new(0xCA11B);
    let rand_t = |rng: &mut Rng, r: usize, c: usize| {
        Tensor::from_vec((0..r * c).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect(), &[r, c])
    };

    // GEMM ladder (nn/tn/nt) over Table-I-style sizes, both pool widths.
    for &s in &[16usize, 32, 64, 128, 256] {
        let a = rand_t(&mut rng, s, s);
        let b = rand_t(&mut rng, s, s);
        for pool in [&seq_pool, &par_pool] {
            for _ in 0..reps {
                observe(a.matmul_with(&b, pool));
                observe(a.matmul_tn_with(&b, pool));
                observe(a.matmul_nt_with(&b, pool));
            }
        }
    }
    println!("  gemm ladder done");

    // round_slice ladder: the per-element CPU-touch proxy that prices
    // elementwise/reduce nodes.
    for &elems in &[1usize << 10, 1 << 13, 1 << 16, 1 << 19] {
        let mut t = rand_t(&mut rng, elems, 1);
        for _ in 0..reps {
            t.round_to(apdrl::hw::Format::Bf16);
        }
    }
    println!("  round_slice ladder done");

    // Real train steps: one MLP and one conv model, so im2col/col2im,
    // Adam and the backprop GEMMs calibrate at the shapes the executor
    // actually runs (mirrors the bench_exec setup).
    let bs = 64usize;
    let mlp = combo("dqn_cartpole");
    let conv = ComboConfig {
        name: "dqn_pixel_calib",
        algo: Algo::Dqn,
        env: "mspacman_mini",
        net: NetSpec::Conv { in_hw: 12, in_ch: 4, conv: vec![(8, 4, 2)], fc: vec![128, 9] },
        batch: bs,
        obs_dim: 12 * 12 * 4,
        act_dim: 9,
        paper_flops_per_row: 0.0,
        paper_reward_error_pct: 0.0,
    };
    for c in [&mlp, &conv] {
        let mut fill_rng = Rng::new(0xF111);
        let mut rb = ReplayBuffer::new(bs * 2, c.obs_dim);
        for _ in 0..bs * 2 {
            let o: Vec<f32> =
                (0..c.obs_dim).map(|_| fill_rng.uniform_in(-1.0, 1.0) as f32).collect();
            let o2: Vec<f32> =
                (0..c.obs_dim).map(|_| fill_rng.uniform_in(-1.0, 1.0) as f32).collect();
            rb.push(&o, StoredAction::Discrete(fill_rng.below(c.act_dim) as i32), 1.0, &o2, false);
        }
        let batch = rb.sample(bs, &mut fill_rng);
        for pool in [&seq_pool, &par_pool] {
            let mut model = CpuDqn::new_pooled(c, &ExecPolicy::fp32(), 11, pool.clone());
            for _ in 0..reps {
                observe(model.train(&batch, 1.0)?);
            }
        }
    }
    println!("  train-step rounds done");

    // Short end-to-end runs: env stepping and the full collection round
    // (act + step + observe) at a couple of fleet widths.
    for actors in [1usize, 8] {
        let mut backend = CpuBackend::fp32();
        let limits = TrainLimits { max_env_steps: 512, max_episodes: 1_000_000 };
        train_combo_actors(&mut backend, &mlp, 1, limits, actors, false)?;
    }
    println!("  collection rounds done");

    let rows = trace::drain_aggregate();
    drop(rec);
    let table = CalibrationTable::from_rows(&rows);
    let srows: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.kernel.name().to_string(),
                r.threads.to_string(),
                format!("2^{}", r.bucket),
                r.count.to_string(),
                format!("{:.0}", r.mean_work),
                format!("{:.2}", r.mean_ns / 1000.0),
                format!("{:.2}", r.min_ns as f64 / 1000.0),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(
            &["kernel", "thr", "bucket", "calls", "mean work", "mean µs", "min µs"],
            &srows
        )
    );
    table.save(std::path::Path::new(&out))?;
    println!(
        "wrote {} entries / {} points to {out} (fingerprint {})",
        table.entries(),
        table.points(),
        table.fingerprint()
    );
    println!("activate with: export {ENV_CALIB}={out}");
    Ok(())
}

/// `apdrl dash`: the observability hub — an HTTP server over the
/// process-wide event bus, fed by any `apdrl` process started with
/// `APDRL_DASH=host:port`.  Serves the embedded HTML dashboard at `/`,
/// a `text/event-stream` feed at `/events`, a JSON ring snapshot at
/// `/snapshot`, and accepts forwarded events on `POST /emit`.
fn cmd_dash(args: &Args) -> Result<()> {
    let addr = args
        .flag("addr")
        .map(str::to_string)
        .or_else(|| std::env::var(ENV_DASH).ok().filter(|v| !v.is_empty()))
        .unwrap_or_else(|| DEFAULT_DASH_ADDR.to_string());
    let token = args
        .flag("token")
        .map(str::to_string)
        .or_else(|| std::env::var(ENV_DASH_TOKEN).ok())
        .filter(|t| !t.is_empty());
    let server = DashServer::bind(&addr, std::sync::Arc::clone(apdrl::obs::global()), token)?;
    let at = server.local_addr()?;
    eprintln!(
        "apdrl dash: dashboard at http://{at}/ (SSE /events, JSON /snapshot); \
         point producers at APDRL_DASH={at}"
    );
    server.run()
}

/// One `apdrl sweep` table row from the backend-agnostic outcome —
/// every backend feeds the same formatter, so the tables cannot diverge.
fn sweep_row(plan: &PlanOutcome) -> Vec<String> {
    vec![
        plan.combo.clone(),
        plan.batch.to_string(),
        format!("{:.1}", plan.makespan_us),
        format!("{}/{}", plan.aie_mm_nodes, plan.mm_nodes),
        format!("{:.0}", plan.throughput()),
        if plan.cache_hit { "hit".to_string() } else { plan.explored.to_string() },
    ]
}

/// The daemon's address for both binding and remote control: `--addr`,
/// then `APDRL_SERVER` (so the one-env-var workflow points daemon and
/// clients at the same place), then the loopback default.  A daemon is
/// one address — a comma-separated federation list is a client-side
/// concept and is rejected here with a pointer to the right flag.
fn serve_addr(args: &Args) -> Result<String> {
    let addr = server_addr(args.flag("addr")).unwrap_or_else(|_| DEFAULT_ADDR.to_string());
    if addr.contains(',') {
        bail!(
            "serve takes one address, but {addr:?} names several; federation is \
             client-side — run one `apdrl serve` per host and point clients at \
             `--remote {addr}`"
        );
    }
    Ok(addr)
}

/// Run the long-lived planning daemon — or remote-control a running one
/// (`--stop`, `--stats`).
fn cmd_serve(args: &Args) -> Result<()> {
    let addr = serve_addr(args)?;
    if args.flag("stop").is_some() {
        RemotePlanner::connect(&addr)?.shutdown()?;
        println!("sent shutdown to planning server at {addr}");
        return Ok(());
    }
    if args.flag("stats").is_some() {
        let stats = RemotePlanner::connect(&addr)?.stats()?;
        println!("{stats}");
        return Ok(());
    }
    let workers = args.usize_flag(
        "workers",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4),
    );
    let fwd = Forwarder::from_env();
    let server = Server::bind(&addr, workers)?;
    eprintln!(
        "apdrl serve: listening on {} ({workers} workers, protocol v{}); \
         stop with `apdrl serve --stop --addr {}`",
        server.local_addr()?,
        apdrl::server::PROTOCOL_VERSION,
        server.local_addr()?
    );
    let out = server.run();
    if let Some(f) = fwd {
        f.finish();
    }
    out
}

/// One training run's report block.
fn report_train(result: &apdrl::coordinator::TrainResult) {
    let m = &result.metrics;
    println!(
        "{} [{}{}{}] seed {}: {} episodes, {} env steps, {} train steps, {} overflows, \
         {:.1}s ({:.0} env-steps/s)",
        result.combo,
        result.backend,
        if result.threads > 1 { format!(", {} threads", result.threads) } else { String::new() },
        if result.actors > 1 { format!(", {} actors", result.actors) } else { String::new() },
        result.seed,
        m.episode_rewards.len(),
        m.env_steps,
        m.train_steps,
        m.overflows,
        m.wallclock_s,
        m.env_steps_per_sec()
    );
    // Per-episode rewards, compact: every episode for short runs, the
    // tail for long ones.
    let n = m.episode_rewards.len();
    let shown = n.min(12);
    let tail: Vec<String> = m.episode_rewards[n - shown..]
        .iter()
        .enumerate()
        .map(|(i, r)| format!("ep{} {:.0}", n - shown + i + 1, r))
        .collect();
    if n > 0 {
        println!("  episode rewards{}: {}", if n > shown { " (tail)" } else { "" }, tail.join(" | "));
    }
    // A disabled scaler pins 1.0; only report the FSM when it is armed
    // (scale off 1.0 or actual transitions recorded).
    if !m.scale_transitions.is_empty() || (m.final_loss_scale != 0.0 && m.final_loss_scale != 1.0)
    {
        let head: Vec<String> = m
            .scale_transitions
            .iter()
            .take(8)
            .map(|(step, from, to)| {
                format!("@{step} {from:.0}->{to:.0}{}", if to < from { " (overflow)" } else { "" })
            })
            .collect();
        println!(
            "  loss-scale FSM: {} transitions [{}{}], final scale {:.0}",
            m.scale_transitions.len(),
            head.join(", "),
            if m.scale_transitions.len() > 8 { ", …" } else { "" },
            m.final_loss_scale
        );
    }
    println!("  converged reward (last 50 ep): {:.2}", m.converged_reward(50));
}

/// `apdrl train`: plan the static phase (local or `--remote`), fold the
/// schedule into a precision policy, and run the dynamic phase on the
/// CPU executor.
fn cmd_train(args: &Args) -> Result<()> {
    let name = args
        .flag("combo")
        .or_else(|| args.positional.get(1).map(String::as_str))
        .unwrap_or("dqn_cartpole");
    let c = try_combo(name)?;
    let quantized = args.flag("quantized").is_some();
    let seed = args.usize_flag("seed", 1) as u64;
    let actors = args.usize_flag("actors", 1);
    let limits = TrainLimits {
        max_env_steps: args.usize_flag("steps", 8_000) as u64,
        max_episodes: args.usize_flag("episodes", 300),
    };
    #[cfg(feature = "pjrt")]
    if args.flag("backend") == Some("pjrt") {
        return cmd_train_pjrt(args, &c, seed, limits, actors);
    }
    if let Some(backend) = args.flag("backend") {
        if backend != "cpu" {
            bail!("unknown train backend {backend} (cpu{})", if cfg!(feature = "pjrt") { ", pjrt" } else { "; pjrt needs --features pjrt" });
        }
    }
    // Training-as-a-service: an explicit --remote submits the run as a
    // streaming job to the daemon federation instead of training here
    // (planning happens daemon-side, through the same shared plan
    // cache).  APDRL_SERVER alone keeps the old meaning — remote
    // *planning*, local training.
    if args.flag("remote").is_some() {
        return cmd_train_remote(args, c.name, seed, actors, quantized, limits);
    }

    // Static phase first: the plan decides the per-layer formats.
    let fwd = Forwarder::from_env();
    let planner = select_planner(args.flag("remote"))?;
    let req = PlanRequest::new(c.clone(), c.batch, quantized);
    let plan = planner.plan(&req)?;
    println!(
        "== static phase [{}]: {} batch {} {} | makespan {:.1} µs | {}/{} MM nodes on AIE ==",
        plan.provenance,
        plan.combo,
        plan.batch,
        if quantized { "mixed precision" } else { "FP32 control" },
        plan.makespan_us,
        plan.aie_mm_nodes,
        plan.mm_nodes
    );
    let mut backend = tuned_backend(CpuBackend::from_outcome(&plan)?, args);
    // The executor must route exactly the plan's formats: cross-check
    // every plan routing entry against the networks the executor builds
    // before spending the training budget.
    apdrl::exec::verify_routing(&c, &plan)?;
    let expected = ExecPolicy::from_outcome(&plan)?;
    println!(
        "== dynamic phase [{}]: plan routing verified over {} layer entries, loss scaling {} ==",
        backend.describe(),
        expected.layer_count(),
        if expected.needs_loss_scaling { "armed" } else { "off" }
    );
    let result = train_combo_actors(&mut backend, &c, seed, limits, actors, true)?;
    report_train(&result);

    if quantized && args.flag("no-compare").is_none() {
        // FP32 control, same seed and budget: the Table III reward-error
        // summary for this run.
        let fp32_plan = planner.plan(&req.clone().fp32())?;
        let mut fp32_backend = tuned_backend(CpuBackend::from_outcome(&fp32_plan)?, args);
        let control = train_combo_actors(&mut fp32_backend, &c, seed, limits, actors, true)?;
        report_train(&control);
        let q = result.metrics.converged_reward(50);
        let f = control.metrics.converged_reward(50);
        println!(
            "quantized vs FP32 (seed {seed}): {:.2} vs {:.2} | reward error {:.2}% (paper Table III: {:.2}%)",
            q,
            f,
            reward_error_pct(&[f], &[q]),
            c.paper_reward_error_pct
        );
    }
    if let Some(f) = fwd {
        f.finish();
    }
    Ok(())
}

/// `apdrl train --remote host1,host2,...`: training-as-a-service.  The
/// job goes to the least-loaded daemon of the list; its frames stream
/// back live, and the newest checkpoint frame doubles as the hand-off
/// payload when a host dies or drains (see `server::RemoteTrainer`).
fn cmd_train_remote(
    args: &Args,
    combo: &str,
    seed: u64,
    actors: usize,
    quantized: bool,
    limits: TrainLimits,
) -> Result<()> {
    let spec = server_addr(args.flag("remote"))?;
    let trainer = RemoteTrainer::connect(&parse_host_list(&spec))?;
    let sub = TrainSubmission {
        combo: combo.to_string(),
        seed,
        actors,
        max_env_steps: limits.max_env_steps as usize,
        max_episodes: limits.max_episodes,
        quantized,
        priority: args.flag("priority").and_then(|v| v.parse().ok()).unwrap_or(0),
        checkpoint_every: args
            .flag("checkpoint-every")
            .and_then(|v| v.parse().ok())
            .unwrap_or(1_000),
        progress_every: args.flag("progress-every").and_then(|v| v.parse().ok()).unwrap_or(0),
    };
    // Fire-and-forget: submit to the least-loaded host and exit; the
    // daemon runs the job headless (track it with `apdrl jobs`, durable
    // under APDRL_JOB_DIR server-side).
    if args.flag("detach").is_some() {
        let (host, job) = trainer.train_detached(&sub)?;
        println!("submitted {} as {job} on {host} (detached)", sub.combo);
        return Ok(());
    }
    println!(
        "== remote training [{}]: {} seed {seed}, {}, checkpoint every {} env steps ==",
        trainer.describe(),
        sub.combo,
        if quantized { "mixed precision" } else { "FP32 control" },
        sub.checkpoint_every
    );
    let mut on_frame = |host: &str, frame: &Json| {
        let f = |k: &str| frame.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        match frame.get("frame").and_then(Json::as_str) {
            Some("episode") => {
                let n = f("episode") as usize;
                if n % 25 == 0 {
                    println!(
                        "  [{host}] ep {n}: reward {:.0} (steps {})",
                        f("reward"),
                        f("env_steps") as u64
                    );
                }
            }
            Some("scale") => println!(
                "  [{host}] loss scale @{}: {:.0} -> {:.0}",
                f("step") as u64,
                f("from"),
                f("to")
            ),
            Some("progress") => println!(
                "  [{host}] progress: {} env steps, {} episodes, avg25 {:.1}",
                f("env_steps") as u64,
                f("episodes") as usize,
                f("reward_avg25")
            ),
            Some("checkpoint") => println!(
                "  [{host}] checkpoint @{} env steps{}",
                f("env_steps") as u64,
                if frame.get("final").and_then(Json::as_bool).unwrap_or(false) {
                    " (final)"
                } else {
                    ""
                }
            ),
            _ => {}
        }
    };
    let result = trainer.train(&sub, &mut on_frame)?;
    let job = result.get("job").and_then(Json::as_str).unwrap_or("?").to_string();
    let status = result.get("status").and_then(Json::as_str).unwrap_or("?");
    if let Some(err) = result.get("error").and_then(Json::as_str) {
        bail!("remote job {job} {status}: {err}");
    }
    match result.get("metrics") {
        Some(m) => {
            // The final payload carries the run's bit-exact metrics:
            // rehydrate them into the standard local report block.
            let s = |k: &str| result.get(k).and_then(Json::as_str).unwrap_or("?").to_string();
            let n = |k: &str| result.get(k).and_then(Json::as_usize).unwrap_or(0);
            let cancelled = result.get("cancelled").and_then(Json::as_bool).unwrap_or(false);
            report_train(&TrainResult {
                metrics: RunMetrics::from_json(m)?,
                combo: s("combo"),
                backend: format!("{} via {job}", s("backend")),
                threads: n("threads"),
                actors: n("actors"),
                seed,
                cancelled,
            });
            if cancelled {
                println!("  (job {job} was cancelled; metrics cover the completed prefix)");
            }
        }
        None => println!("remote job {job}: {status}"),
    }
    Ok(())
}

/// `apdrl jobs`: list — or `--cancel <id>` — the training jobs of every
/// daemon named by `--remote` / `APDRL_SERVER`.
fn cmd_jobs(args: &Args) -> Result<()> {
    let spec = server_addr(args.flag("remote"))?;
    let trainer = RemoteTrainer::connect(&parse_host_list(&spec))?;
    if let Some(job) = args.flag("cancel") {
        let (host, phase) = trainer.cancel(job)?;
        println!("cancelled {job} on {host} (was {phase})");
        return Ok(());
    }
    let mut rows: Vec<Vec<String>> = Vec::new();
    for (host, jobs, draining) in trainer.jobs()? {
        let label = if draining { format!("{host} (draining)") } else { host.clone() };
        for j in jobs.as_arr().unwrap_or(&[]) {
            let s = |k: &str| j.get(k).and_then(Json::as_str).unwrap_or("-").to_string();
            let f = |k: &str| j.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            // Provenance: journal-replayed after a restart, failed over
            // from a dead host (origin tag), or a fresh submission.
            let recovered = j.get("recovered").and_then(Json::as_bool).unwrap_or(false);
            let src = match (recovered, j.get("origin").and_then(Json::as_str)) {
                (true, Some(o)) => format!("recovered {o}"),
                (true, None) => "recovered".to_string(),
                (false, Some(o)) => o.to_string(),
                (false, None) => "fresh".to_string(),
            };
            rows.push(vec![
                label.clone(),
                s("job"),
                s("combo"),
                format!("{}", f("seed") as u64),
                s("phase"),
                format!("{}", f("priority") as i64),
                src,
                j.get("wall_us")
                    .and_then(Json::as_f64)
                    .map(|us| format!("{:.2}", us / 1e6))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
    }
    if rows.is_empty() {
        println!("no jobs on {}", trainer.describe());
    } else {
        println!(
            "{}",
            ascii_table(&["host", "job", "combo", "seed", "phase", "prio", "src", "wall s"], &rows)
        );
    }
    Ok(())
}

/// `apdrl journal`: inspect a daemon's durable job journal on disk —
/// offline, straight from the files, no daemon needed.  Lists every
/// record under `--dir` (or `APDRL_JOB_DIR`); with `--job ID` prints
/// that record's newest spilled checkpoint, and `--rewards` narrows it
/// to the raw-bit hex reward log — the line the CI restart smoke
/// compares bit-for-bit against an uninterrupted control run.
fn cmd_journal(args: &Args) -> Result<()> {
    let dir = match args.flag("dir") {
        Some(d) => d.to_string(),
        None => std::env::var(ENV_JOB_DIR).ok().filter(|v| !v.is_empty()).ok_or_else(|| {
            anyhow!("no journal directory: pass --dir <path> or set {ENV_JOB_DIR}")
        })?,
    };
    let journal = Journal::open(&dir);
    let records = journal.load_all();
    if let Some(id) = args.flag("job") {
        let rec = records
            .iter()
            .find(|r| r.id == id)
            .ok_or_else(|| anyhow!("no journal record for {id} under {dir}"))?;
        let ckpt = rec.spec.resume.as_ref().ok_or_else(|| {
            anyhow!("journal record {id} has no spilled checkpoint yet (phase {})", rec.phase)
        })?;
        if args.flag("rewards").is_some() {
            // Raw-bit hex of the per-episode reward log: two runs are
            // bit-identical iff these lines are byte-identical.
            println!("{}", hex_f64s(&ckpt.metrics.episode_rewards));
        } else {
            println!("{}", ckpt.to_json());
        }
        return Ok(());
    }
    if records.is_empty() {
        println!("no journal records under {dir}");
        return Ok(());
    }
    let rows: Vec<Vec<String>> = records
        .iter()
        .map(|r| {
            vec![
                r.id.clone(),
                r.phase.clone(),
                r.spec.combo.clone(),
                format!("{}", r.spec.seed),
                r.spec
                    .resume
                    .as_ref()
                    .map(|c| format!("{}", c.metrics.env_steps))
                    .unwrap_or_else(|| "-".into()),
                r.origin.clone().unwrap_or_else(|| "-".into()),
            ]
        })
        .collect();
    println!(
        "{}",
        ascii_table(&["job", "phase", "combo", "seed", "ckpt steps", "origin"], &rows)
    );
    Ok(())
}

/// Apply the optional schedule-tuning flags to a CPU backend:
/// `--batch N` (batch / rollout horizon, all algorithms),
/// `--train-every N` / `--warmup N` (off-policy DQN/DDPG cadence only —
/// on-policy agents train once per rollout and ignore them), and
/// `--threads N` (kernel pool size, overriding `APDRL_THREADS`; results
/// are bit-identical at any setting, only wall-clock moves).
fn tuned_backend(mut backend: CpuBackend, args: &Args) -> CpuBackend {
    if let Some(n) = args.flag("train-every").and_then(|v| v.parse().ok()) {
        backend = backend.with_train_every(n);
    }
    if let Some(n) = args.flag("warmup").and_then(|v| v.parse().ok()) {
        backend = backend.with_warmup(n);
    }
    if let Some(n) = args.flag("batch").and_then(|v| v.parse().ok()) {
        backend = backend.with_batch(n);
    }
    if let Some(n) = args.flag("threads").and_then(|v| v.parse().ok()) {
        backend = backend.with_pool(std::sync::Arc::new(apdrl::exec::Pool::new(n)));
    }
    backend
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(
    args: &Args,
    c: &apdrl::coordinator::ComboConfig,
    seed: u64,
    limits: TrainLimits,
    actors: usize,
) -> Result<()> {
    let mode = args.flag("mode").unwrap_or("mixed");
    let mut runtime = Runtime::new(artifacts_dir())?;
    eprintln!("platform: {}", runtime.platform());
    let mut backend = apdrl::exec::PjrtBackend::new(&mut runtime, mode);
    let result = train_combo_actors(&mut backend, c, seed, limits, actors, true)?;
    report_train(&result);
    Ok(())
}

#[cfg(feature = "pjrt")]
fn cmd_platform() -> Result<()> {
    let mut runtime = Runtime::new(artifacts_dir())?;
    println!("PJRT: {}", runtime.platform());
    println!("artifacts: {}", runtime.manifest().artifacts.len());
    let exe = runtime.load("gemm_64_fp32")?;
    println!(
        "smoke artifact gemm_64_fp32: {} inputs, {} outputs",
        exe.spec().inputs.len(),
        exe.spec().outputs.len()
    );
    print_hw_table();
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_platform() -> Result<()> {
    println!("PJRT: disabled at build time (rebuild with `--features pjrt`)");
    print_hw_table();
    Ok(())
}

fn print_hw_table() {
    let hw = apdrl::hw::vek280();
    for comp in Component::ALL {
        let s = hw.spec(comp);
        println!(
            "{}: {:.0} MHz, init {:.0} µs, {} lanes, native {}",
            comp.name(),
            s.clock_mhz,
            s.init_us,
            s.max_mac_lanes,
            comp.native_format().name()
        );
    }
}

#[cfg(feature = "pjrt")]
pub fn artifacts_dir() -> String {
    std::env::var("APDRL_ARTIFACTS")
        .unwrap_or_else(|_| format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")))
}

fn main() -> Result<()> {
    let args = Args::parse();
    // APDRL_TRACE arms kernel tracing for any verb (near-zero cost when
    // unset — see apdrl::obs::trace).
    apdrl::obs::trace::arm_from_env();
    match args.positional.first().map(String::as_str) {
        Some("plan") => cmd_plan(&args),
        Some("sweep") => cmd_sweep(&args),
        Some("profile") => cmd_profile(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve") => cmd_serve(&args),
        Some("train") => cmd_train(&args),
        Some("jobs") => cmd_jobs(&args),
        Some("journal") => cmd_journal(&args),
        Some("dash") => cmd_dash(&args),
        Some("platform") => cmd_platform(),
        Some("list") | None => {
            println!("combos: {}", COMBO_NAMES.join(", "));
            println!(
                "usage: apdrl <plan|sweep|profile|calibrate|serve|train|jobs|journal|dash|platform|list> \
                 [combo] [--flags]"
            );
            Ok(())
        }
        Some(other) => bail!("unknown subcommand {other}"),
    }
}
