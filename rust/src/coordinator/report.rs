//! Report emitters: TSV series + ASCII tables/charts for the figure
//! harness, written under `reports/`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::Result;

/// Write a TSV file with a header row.
pub fn write_tsv(path: impl AsRef<Path>, header: &[&str], rows: &[Vec<String>]) -> Result<()> {
    let mut out = String::new();
    out.push_str(&header.join("\t"));
    out.push('\n');
    for row in rows {
        out.push_str(&row.join("\t"));
        out.push('\n');
    }
    if let Some(dir) = path.as_ref().parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, out)?;
    Ok(())
}

/// Render a fixed-width ASCII table.
pub fn ascii_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let sep = |out: &mut String| {
        for w in &widths {
            let _ = write!(out, "+{}", "-".repeat(w + 2));
        }
        out.push_str("+\n");
    };
    sep(&mut out);
    for (i, h) in header.iter().enumerate() {
        let _ = write!(out, "| {:w$} ", h, w = widths[i]);
    }
    out.push_str("|\n");
    sep(&mut out);
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            let _ = write!(out, "| {:w$} ", cell, w = widths[i]);
        }
        out.push_str("|\n");
    }
    sep(&mut out);
    out
}

/// Simple horizontal bar chart (log or linear) for figure-style series.
pub fn ascii_bars(title: &str, labels: &[String], values: &[f64], log: bool) -> String {
    let mut out = format!("{title}\n");
    let transformed: Vec<f64> = values
        .iter()
        .map(|&v| if log { (v.max(1e-12)).log10() } else { v })
        .collect();
    let lo = transformed.iter().cloned().fold(f64::INFINITY, f64::min).min(0.0);
    let hi = transformed.iter().cloned().fold(f64::NEG_INFINITY, f64::max).max(lo + 1e-9);
    let width = 48.0;
    let label_w = labels.iter().map(|l| l.len()).max().unwrap_or(0);
    for (label, (&v, &t)) in labels.iter().zip(values.iter().zip(&transformed)) {
        let frac = ((t - lo) / (hi - lo)).clamp(0.0, 1.0);
        let bar = "#".repeat((frac * width) as usize + 1);
        let _ = writeln!(out, "  {label:label_w$} | {bar:<49} {v:.4}");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tsv_roundtrip() {
        let dir = std::env::temp_dir().join("apdrl_test_reports");
        let path = dir.join("t.tsv");
        write_tsv(&path, &["a", "b"], &[vec!["1".into(), "2".into()]]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a\tb\n1\t2\n");
    }

    #[test]
    fn table_aligns() {
        let t = ascii_table(&["name", "v"], &[vec!["x".into(), "1.5".into()]]);
        assert!(t.contains("| name |"));
        assert!(t.lines().count() >= 5);
    }

    #[test]
    fn bars_render() {
        let s = ascii_bars(
            "demo",
            &["a".into(), "bb".into()],
            &[1.0, 10.0],
            true,
        );
        assert!(s.contains("demo"));
        assert!(s.lines().count() == 3);
    }
}
