//! Training checkpoints (protocol v3's hand-off currency): the complete
//! bit-exact state of a mid-run training job, serialized through
//! [`crate::util::json`].
//!
//! A checkpoint captures four layers, all with raw-bit float encoding so
//! a restore continues the *identical* trajectory (asserted per-combo in
//! `tests/train.rs`):
//!
//! 1. **Job identity** — combo, seed, actor count, quantized flag.  A
//!    resume must rebuild a structurally identical backend before the
//!    state can be poured back in; mismatches are reported errors.
//! 2. **Trainer bookkeeping** — [`RunMetrics`] (reward/loss histories,
//!    FSM transitions, accumulated wall-clock), the last seen loss
//!    scale, per-lane in-flight episode rewards, and the master RNG.
//! 3. **Env fleet** — per lane: env dynamics, RNG stream, current obs
//!    ([`crate::envs::BatchedEnv::save_state`]).
//! 4. **Agent** — weights + FP32 masters, Adam moments, replay/rollout
//!    buffers, loss-scale FSM, cadence counters
//!    ([`crate::drl::Agent::save_state`]).

use anyhow::{anyhow, ensure, Result};

use crate::util::json::{hex_f64s, hex_u64, parse_hex_f64s, Json};

use super::metrics::RunMetrics;

/// Format version; bump on incompatible schema changes.
pub const CHECKPOINT_VERSION: u64 = 1;

/// One resumable training snapshot (see the module docs for layout).
#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub combo: String,
    pub seed: u64,
    pub actors: usize,
    /// Whether the run executes a quantized plan (vs the FP32 control) —
    /// the resuming host must rebuild the same precision routing.
    pub quantized: bool,
    /// Metrics accumulated up to the snapshot; `wallclock_s` is the
    /// wall-clock consumed so far and keeps accumulating across resumes.
    pub metrics: RunMetrics,
    /// Loss scale fed to the most recent train step, if any (drives
    /// transition detection across the resume boundary).
    pub last_scale: Option<f32>,
    /// Per-lane in-flight (unfinished-episode) reward accumulators.
    pub ep_rewards: Vec<f64>,
    /// Master trainer RNG (exploration + sampling stream).
    pub rng_state: u64,
    pub rng_spare: Option<f64>,
    /// [`crate::envs::BatchedEnv::save_state`] payload.
    pub fleet: Json,
    /// [`crate::drl::Agent::save_state`] payload.
    pub agent: Json,
}

impl Checkpoint {
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("ckpt_version", Json::Num(CHECKPOINT_VERSION as f64)),
            ("combo", Json::Str(self.combo.clone())),
            ("seed", Json::Num(self.seed as f64)),
            ("actors", Json::Num(self.actors as f64)),
            ("quantized", Json::Bool(self.quantized)),
            ("metrics", self.metrics.to_json()),
            ("ep_rewards", Json::Str(hex_f64s(&self.ep_rewards))),
            ("rng", Json::Str(hex_u64(self.rng_state))),
            ("fleet", self.fleet.clone()),
            ("agent", self.agent.clone()),
        ];
        if let Some(s) = self.last_scale {
            pairs.push(("last_scale", Json::Str(crate::util::json::hex_f32s(&[s]))));
        }
        if let Some(sp) = self.rng_spare {
            pairs.push(("rng_spare", Json::Str(hex_f64s(&[sp]))));
        }
        Json::obj(pairs)
    }

    pub fn from_json(v: &Json) -> Result<Checkpoint> {
        let version = v.req_u64("ckpt_version")?;
        ensure!(
            version == CHECKPOINT_VERSION,
            "checkpoint version {version} not supported (this build reads {CHECKPOINT_VERSION})"
        );
        let last_scale = match v.get("last_scale") {
            None => None,
            Some(_) => Some(v.req_f32_bits("last_scale")?),
        };
        let rng_spare = match v.get("rng_spare") {
            None => None,
            Some(j) => {
                let s = j.as_str().ok_or_else(|| anyhow!("checkpoint: bad rng_spare"))?;
                let d = parse_hex_f64s(s)?;
                ensure!(d.len() == 1, "checkpoint: bad rng_spare length");
                Some(d[0])
            }
        };
        Ok(Checkpoint {
            combo: v.req_str("combo")?.to_string(),
            seed: v.req_u64("seed")?,
            actors: v.req_u64("actors")? as usize,
            quantized: v
                .req("quantized")?
                .as_bool()
                .ok_or_else(|| anyhow!("checkpoint: bad quantized flag"))?,
            metrics: RunMetrics::from_json(v.req("metrics")?)?,
            last_scale,
            ep_rewards: parse_hex_f64s(v.req_str("ep_rewards")?)?,
            rng_state: v.req_u64_hex("rng")?,
            rng_spare,
            fleet: v.req("fleet")?.clone(),
            agent: v.req("agent")?.clone(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            combo: "dqn_cartpole".into(),
            seed: 7,
            actors: 2,
            quantized: true,
            metrics: RunMetrics {
                episode_rewards: vec![12.0, 9.5],
                losses: vec![0.7],
                env_steps: 42,
                train_steps: 3,
                overflows: 1,
                wallclock_s: 0.25,
                scale_transitions: vec![(10, 1024.0, 512.0)],
                final_loss_scale: 512.0,
            },
            last_scale: Some(512.0),
            ep_rewards: vec![3.0, -1.5],
            rng_state: 0xDEAD_BEEF_0BAD_F00D,
            rng_spare: Some(-0.125),
            fleet: Json::Arr(vec![Json::Str("lane".into())]),
            agent: Json::obj(vec![("k", Json::Num(1.0))]),
        }
    }

    #[test]
    fn round_trips_through_wire_text() {
        let c = sample();
        // Through actual serialized text, as the daemon streams it.
        let line = c.to_json().to_line().unwrap();
        let r = Checkpoint::from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(r.combo, c.combo);
        assert_eq!(r.seed, c.seed);
        assert_eq!(r.actors, c.actors);
        assert_eq!(r.quantized, c.quantized);
        assert_eq!(r.metrics.env_steps, c.metrics.env_steps);
        assert_eq!(r.metrics.scale_transitions, c.metrics.scale_transitions);
        assert_eq!(r.last_scale.unwrap().to_bits(), 512.0f32.to_bits());
        assert_eq!(r.ep_rewards[1].to_bits(), (-1.5f64).to_bits());
        assert_eq!(r.rng_state, c.rng_state);
        assert_eq!(r.rng_spare.unwrap().to_bits(), (-0.125f64).to_bits());
        assert_eq!(r.fleet, c.fleet);
        assert_eq!(r.agent, c.agent);
    }

    #[test]
    fn optional_fields_default_to_none() {
        let mut c = sample();
        c.last_scale = None;
        c.rng_spare = None;
        let r = Checkpoint::from_json(&c.to_json()).unwrap();
        assert!(r.last_scale.is_none());
        assert!(r.rng_spare.is_none());
    }

    #[test]
    fn future_versions_are_rejected() {
        let mut v = sample().to_json();
        if let Json::Obj(map) = &mut v {
            map.insert("ckpt_version".into(), Json::Num(99.0));
        }
        assert!(Checkpoint::from_json(&v).is_err());
    }
}
