//! **The one planning API.**  Every way of running the paper's static
//! phase — in-process, against one `apdrl serve` daemon, or sharded
//! across a federation of daemons — implements the [`Planner`] trait and
//! returns the same backend-agnostic [`PlanOutcome`], so consumers (the
//! CLI, the figure harness, the examples, library users) pick a backend
//! in exactly one place and never match on backend-specific result
//! types.
//!
//! * [`PlanRequest`] — the builder-style description of one planning
//!   point (a Table III combo by name or a custom [`ComboConfig`], a
//!   batch size, a precision mode).  It is shared verbatim by the
//!   in-process sweep engine (`pipeline::plan_sweep`), the wire protocol
//!   (`server::protocol`), and the federation layer.
//! * [`PlanOutcome`] — schedule times, assignment, precision policy per
//!   node and derived throughput, tagged with [`Provenance`] saying
//!   which backend produced it (and whether it was a cache hit / which
//!   federation shard served it).
//! * [`LocalPlanner`] — the in-process backend: wraps
//!   [`static_phase`]/[`plan_sweep`], preserving their two-level
//!   parallelism (concurrent sweep workers, parallel B&B inside a lone
//!   solve) and plan-cache memoization.
//!
//! The remote backends live next to their transport:
//! `server::client::RemotePlanner` (one daemon) and
//! `server::federation::FederatedPlanner` (N daemons, sharded by plan
//! key with fail-over).  `server::federation::select_planner` is the
//! single backend-choice point used by every CLI entry.

use anyhow::{bail, Result};

use crate::hw::vek280;
use crate::partition::cache::PlanKey;

use super::config::{try_combo, ComboConfig};
use super::pipeline::{plan_sweep, static_phase, StaticPlan};

/// Which backend produced a [`PlanOutcome`], and what it knows about how.
#[derive(Clone, Debug, PartialEq)]
pub enum Provenance {
    /// Planned in-process; `cache_hit` mirrors the plan-cache outcome.
    Local { cache_hit: bool },
    /// Planned by the daemon at `addr` (whose *own* cache state is in
    /// [`PlanOutcome::cache_hit`]).
    Remote { addr: String },
    /// Planned by federation shard `shard` (index into the host list the
    /// `FederatedPlanner` was built with, possibly after fail-over).
    Federated { shard: usize },
}

impl std::fmt::Display for Provenance {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Provenance::Local { cache_hit: true } => write!(f, "local (plan cache hit)"),
            Provenance::Local { cache_hit: false } => write!(f, "local"),
            Provenance::Remote { addr } => write!(f, "remote {addr}"),
            Provenance::Federated { shard } => write!(f, "federated shard {shard}"),
        }
    }
}

/// One scheduled node of a solved plan: everything the Gantt/figure/CLI
/// renderers read, with component and precision format by *name* so the
/// value survives the wire unchanged.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanStep {
    pub node: usize,
    pub name: String,
    /// Component name (`"PS"` / `"PL"` / `"AIE"`).
    pub component: String,
    /// Precision format name (`"FP32"` / `"FP16"` / `"BF16"`).
    pub format: String,
    /// True for matrix-multiply nodes — the partitionable kind whose
    /// PL/AIE placement the paper's figures report.
    pub mm: bool,
    pub start_us: f64,
    pub finish_us: f64,
    /// The CPU (PS) cost the planner priced this node at — measured,
    /// when the active calibration table covers the shape, analytic
    /// otherwise.  This is the executor-side reality check every step
    /// carries regardless of where the ILP placed it.
    pub cpu_us: f64,
    /// What the analytic PS cost model predicts for the same node; the
    /// per-step modeled-vs-measured error is `cpu_us` against this.
    pub modeled_us: f64,
    /// True when `cpu_us` came from kernel measurements
    /// (`APDRL_CALIB`) rather than the analytic model.
    pub measured: bool,
}

/// The backend-agnostic result of planning one (combo, batch, precision)
/// point: the summary every consumer reads off a solved static phase,
/// without the solver internals (DAG, profiles) that stay backend-side.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanOutcome {
    pub combo: String,
    pub batch: usize,
    pub quantized: bool,
    pub makespan_us: f64,
    pub comm_us: f64,
    pub sync_us: f64,
    /// Per-step PS–PL pipeline time over the TAPCA-selected interface.
    pub ps_pl_us: f64,
    /// Name of the selected PS–PL interface.
    pub interface: String,
    /// MM nodes placed on the AIE (of `mm_nodes` total).
    pub aie_mm_nodes: usize,
    pub mm_nodes: usize,
    /// B&B nodes explored by the solve (0 for a memoized plan).
    pub explored: usize,
    /// True when the serving backend's plan cache supplied the plan.
    pub cache_hit: bool,
    /// `(component name, DSE candidate index)` per DAG node.
    pub assignment: Vec<(String, usize)>,
    pub schedule: Vec<PlanStep>,
    /// Schedule steps whose node's CPU cost was priced from kernel
    /// measurements (0 on a cold start — the analytic-model fallback).
    pub calib_steps: usize,
    /// Total modeled-vs-measured CPU latency error over the measured
    /// steps, in percent of the modeled total (0 when none).
    pub calib_err_pct: f64,
    /// Fingerprint of the calibration table the plan priced against
    /// (empty on cold start) — the plan's measurement provenance.
    pub calib_fingerprint: String,
    pub provenance: Provenance,
}

impl PlanOutcome {
    /// Full per-training-step time: partitioned train-stage makespan +
    /// the PS–PL pipeline (mirrors `StaticPlan::step_time_us`).
    pub fn step_time_us(&self) -> f64 {
        self.makespan_us + self.ps_pl_us
    }

    /// Training throughput (batches/second).
    pub fn throughput(&self) -> f64 {
        1e6 / self.step_time_us()
    }

    /// Fold a locally solved [`StaticPlan`] into the backend-agnostic
    /// summary, with `Local` provenance.  This is the *only* place a
    /// `StaticPlan` is read field-by-field outside the coordinator, so
    /// local and remote consumers cannot drift apart.
    pub fn from_static(plan: &StaticPlan, req: &PlanRequest) -> PlanOutcome {
        let mut calib_steps = 0usize;
        let mut measured_sum = 0.0f64;
        let mut modeled_sum = 0.0f64;
        let schedule = plan
            .schedule
            .entries
            .iter()
            .map(|e| {
                let node = &plan.dag.nodes[e.node];
                let prof = &plan.profiles[e.node];
                if prof.ps_measured {
                    calib_steps += 1;
                    measured_sum += prof.ps_latency_us;
                    modeled_sum += prof.ps_modeled_us;
                }
                PlanStep {
                    node: e.node,
                    name: node.name.clone(),
                    component: e.component.name().to_string(),
                    format: plan.policy.node_format[e.node].name().to_string(),
                    mm: node.kind.is_mm(),
                    start_us: e.start_us,
                    finish_us: e.finish_us,
                    cpu_us: prof.ps_latency_us,
                    modeled_us: prof.ps_modeled_us,
                    measured: prof.ps_measured,
                }
            })
            .collect();
        let calib_err_pct = if modeled_sum > 0.0 {
            (measured_sum - modeled_sum).abs() / modeled_sum * 100.0
        } else {
            0.0
        };
        let assignment = plan
            .solution
            .assignment
            .iter()
            .map(|p| (p.component.name().to_string(), p.candidate))
            .collect();
        PlanOutcome {
            combo: req.combo.name.to_string(),
            batch: req.batch,
            quantized: req.quantized,
            makespan_us: plan.schedule.makespan_us,
            comm_us: plan.schedule.comm_us,
            sync_us: plan.schedule.sync_us,
            ps_pl_us: plan.ps_pl_us,
            interface: plan.interface.name().to_string(),
            aie_mm_nodes: plan.solution.aie_nodes(&plan.dag),
            mm_nodes: plan.dag.mm_nodes().len(),
            explored: plan.solution.explored,
            cache_hit: plan.cache_hit,
            assignment,
            schedule,
            calib_steps,
            calib_err_pct,
            calib_fingerprint: crate::profile::calib::active_fingerprint().unwrap_or_default(),
            provenance: Provenance::Local { cache_hit: plan.cache_hit },
        }
    }
}

/// One point of a planning sweep — the single request type shared by the
/// in-process engine, the CLI, the wire protocol and the federation
/// layer.  Build it from a registry name ([`PlanRequest::named`]) or a
/// (possibly customized) [`ComboConfig`] ([`PlanRequest::new`]), then
/// refine with the `with_*` builders.
#[derive(Clone, Debug)]
pub struct PlanRequest {
    pub combo: ComboConfig,
    pub batch: usize,
    pub quantized: bool,
}

impl PlanRequest {
    /// Request for an explicit combo configuration (which may be a
    /// customized variant of a registry combo, e.g. Table IV's resized
    /// nets — those plan locally only; see [`is_registry_exact`]).
    ///
    /// [`is_registry_exact`]: PlanRequest::is_registry_exact
    pub fn new(combo: ComboConfig, batch: usize, quantized: bool) -> PlanRequest {
        PlanRequest { combo, batch, quantized }
    }

    /// Request for a Table III combo by registry name, at its default
    /// batch size, in AP-DRL mixed precision.  Unknown names are a
    /// reported error (CLI and wire input route through this).
    pub fn named(name: &str) -> Result<PlanRequest> {
        let combo = try_combo(name)?;
        let batch = combo.batch;
        Ok(PlanRequest { combo, batch, quantized: true })
    }

    /// Override the batch size.
    pub fn with_batch(mut self, batch: usize) -> PlanRequest {
        self.batch = batch;
        self
    }

    /// Select AP-DRL mixed precision (`true`) or the FP32 control.
    pub fn with_quantized(mut self, quantized: bool) -> PlanRequest {
        self.quantized = quantized;
        self
    }

    /// The FP32 control mode (`with_quantized(false)` spelled for CLIs).
    pub fn fp32(self) -> PlanRequest {
        self.with_quantized(false)
    }

    /// The combo's registry name.
    pub fn name(&self) -> &str {
        self.combo.name
    }

    /// The plan-cache key of this request on the modeled platform — also
    /// the federation shard key, so one point always lands on the same
    /// daemon (and its warm cache) within a host list.
    pub fn plan_key(&self) -> PlanKey {
        PlanKey::new(&self.combo.train_spec(self.batch), self.quantized, &vek280())
    }

    /// True when this request is exactly the registry combo of its name —
    /// i.e. expressible over the wire by name alone.  A customized
    /// `ComboConfig` (changed net shape, dims, algo) keys differently
    /// and must be planned locally; remote backends reject it instead of
    /// silently planning the registry variant.
    pub fn is_registry_exact(&self) -> bool {
        try_combo(self.combo.name).map_or(false, |registry| {
            let platform = vek280();
            PlanKey::new(&registry.train_spec(self.batch), self.quantized, &platform)
                == PlanKey::new(&self.combo.train_spec(self.batch), self.quantized, &platform)
        })
    }

    /// Cross-product grid of named combos at every batch size, combo-major
    /// (the `apdrl sweep` / daemon `sweep` grid shape).
    pub fn named_grid(
        names: &[String],
        batches: &[usize],
        quantized: bool,
    ) -> Result<Vec<PlanRequest>> {
        let combos: Vec<ComboConfig> =
            names.iter().map(|n| try_combo(n)).collect::<Result<_>>()?;
        Ok(combos
            .iter()
            .flat_map(|c| {
                batches
                    .iter()
                    .map(move |&bs| PlanRequest::new(c.clone(), bs, quantized))
            })
            .collect())
    }
}

/// A planning backend.  All three implementations return identical
/// optimal makespans and assignments for the same request grid (the
/// plans ride one shared deterministic solver and cache); they differ
/// only in *where* the solving happens and what [`Provenance`] tags the
/// results.
pub trait Planner {
    /// Human-readable backend tag for tables and logs (`"local"`,
    /// `"remote 10.0.0.1:7040"`, `"federated over 3 hosts"`).
    fn describe(&self) -> String;

    /// Plan one point.
    fn plan(&self, req: &PlanRequest) -> Result<PlanOutcome>;

    /// Plan every request, results in request order.  Backends override
    /// this to batch (one wire round trip, a concurrent sweep, a sharded
    /// fan-out); the default just loops.
    fn plan_many(&self, reqs: &[PlanRequest]) -> Result<Vec<PlanOutcome>> {
        reqs.iter().map(|r| self.plan(r)).collect()
    }
}

/// The in-process backend: `static_phase` for one point, the concurrent
/// cache-aware `plan_sweep` for many.  A lone solve parallelizes its
/// branch-and-bound internally; inside a sweep the per-solve pool is not
/// nested (the sweep workers are the parallelism) — exactly the
/// semantics library callers had before the trait existed.
pub struct LocalPlanner;

impl Planner for LocalPlanner {
    fn describe(&self) -> String {
        "local".to_string()
    }

    fn plan(&self, req: &PlanRequest) -> Result<PlanOutcome> {
        if req.batch == 0 {
            bail!("plan: batch must be ≥ 1");
        }
        let plan = static_phase(&req.combo, req.batch, req.quantized);
        Ok(PlanOutcome::from_static(&plan, req))
    }

    fn plan_many(&self, reqs: &[PlanRequest]) -> Result<Vec<PlanOutcome>> {
        if let Some(bad) = reqs.iter().find(|r| r.batch == 0) {
            bail!("plan: batch must be ≥ 1 (combo {})", bad.name());
        }
        let plans = plan_sweep(reqs);
        Ok(plans
            .iter()
            .zip(reqs)
            .map(|(plan, req)| PlanOutcome::from_static(plan, req))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::combo;

    #[test]
    fn request_builder_defaults_and_overrides() {
        let req = PlanRequest::named("ddpg_lunar").unwrap();
        assert_eq!(req.name(), "ddpg_lunar");
        assert_eq!(req.batch, combo("ddpg_lunar").batch);
        assert!(req.quantized);
        let req = req.with_batch(512).fp32();
        assert_eq!(req.batch, 512);
        assert!(!req.quantized);
        assert!(PlanRequest::named("dqn_tetris").is_err());
    }

    #[test]
    fn registry_exactness_detects_customized_combos() {
        let named = PlanRequest::named("dqn_cartpole").unwrap();
        assert!(named.is_registry_exact());
        assert!(named.clone().with_batch(96).is_registry_exact());
        let mut custom = combo("dqn_cartpole");
        custom.net = crate::graph::NetSpec::mlp(&[4, 4096, 3072, 2]);
        let custom = PlanRequest::new(custom, 64, true);
        assert!(!custom.is_registry_exact(), "a resized net is not the registry combo");
    }

    #[test]
    fn named_grid_is_combo_major_and_rejects_unknowns() {
        let names = vec!["dqn_cartpole".to_string(), "a2c_invpend".to_string()];
        let grid = PlanRequest::named_grid(&names, &[32, 64], false).unwrap();
        assert_eq!(grid.len(), 4);
        assert_eq!(grid[0].name(), "dqn_cartpole");
        assert_eq!(grid[1].name(), "dqn_cartpole");
        assert_eq!((grid[0].batch, grid[1].batch), (32, 64));
        assert_eq!(grid[3].name(), "a2c_invpend");
        assert!(grid.iter().all(|r| !r.quantized));
        assert!(PlanRequest::named_grid(&["nope".to_string()], &[32], true).is_err());
    }

    #[test]
    fn local_planner_outcome_mirrors_static_phase() {
        let req = PlanRequest::named("dqn_cartpole").unwrap().with_batch(56);
        let outcome = LocalPlanner.plan(&req).unwrap();
        let plan = static_phase(&req.combo, req.batch, req.quantized);
        assert_eq!(outcome.combo, "dqn_cartpole");
        assert_eq!(outcome.batch, 56);
        assert_eq!(outcome.makespan_us.to_bits(), plan.schedule.makespan_us.to_bits());
        assert_eq!(outcome.schedule.len(), plan.schedule.entries.len());
        assert_eq!(outcome.assignment.len(), plan.solution.assignment.len());
        assert_eq!(outcome.aie_mm_nodes, plan.solution.aie_nodes(&plan.dag));
        assert_eq!(outcome.mm_nodes, plan.dag.mm_nodes().len());
        assert_eq!(outcome.step_time_us().to_bits(), plan.step_time_us().to_bits());
        assert!(matches!(outcome.provenance, Provenance::Local { .. }));
        // The mm flag marks exactly the dag's MM nodes.
        let mm_steps = outcome.schedule.iter().filter(|s| s.mm).count();
        assert_eq!(mm_steps, outcome.mm_nodes);
    }

    #[test]
    fn local_plan_many_matches_solo_plans_in_order() {
        let reqs = vec![
            PlanRequest::named("dqn_cartpole").unwrap().with_batch(44),
            PlanRequest::named("a2c_invpend").unwrap().with_batch(44),
        ];
        let many = LocalPlanner.plan_many(&reqs).unwrap();
        assert_eq!(many.len(), 2);
        for (req, outcome) in reqs.iter().zip(&many) {
            let solo = LocalPlanner.plan(req).unwrap();
            assert_eq!(outcome.combo, solo.combo);
            assert_eq!(outcome.makespan_us.to_bits(), solo.makespan_us.to_bits());
            assert_eq!(outcome.assignment, solo.assignment);
        }
    }

    #[test]
    fn zero_batch_is_rejected_not_planned() {
        let req = PlanRequest::named("dqn_cartpole").unwrap().with_batch(0);
        assert!(LocalPlanner.plan(&req).is_err());
        assert!(LocalPlanner.plan_many(std::slice::from_ref(&req)).is_err());
    }

    #[test]
    fn provenance_labels_read_well() {
        assert_eq!(Provenance::Local { cache_hit: false }.to_string(), "local");
        assert_eq!(
            Provenance::Local { cache_hit: true }.to_string(),
            "local (plan cache hit)"
        );
        assert_eq!(
            Provenance::Remote { addr: "h:1".into() }.to_string(),
            "remote h:1"
        );
        assert_eq!(Provenance::Federated { shard: 2 }.to_string(), "federated shard 2");
    }
}
