//! Experiment configurations (paper Table III), mirroring
//! `python/compile/combos.py` — the artifact names are derived from
//! these, so the two must stay in sync (checked by an integration test).

use anyhow::{anyhow, Result};

use crate::envs::{self, Env};
use crate::graph::{Algo, NetSpec, TrainSpec};

/// One environment-algorithm combination.
#[derive(Clone, Debug)]
pub struct ComboConfig {
    pub name: &'static str,
    pub algo: Algo,
    pub env: &'static str,
    pub net: NetSpec,
    pub batch: usize,
    pub obs_dim: usize,
    pub act_dim: usize,
    /// Table III "Train FLOPs (Per Batch Size)" — the paper's reported
    /// per-row figure, asserted against our graph builder in tests.
    pub paper_flops_per_row: f64,
    /// Table III reward error (%) — reproduction target for Fig 11.
    pub paper_reward_error_pct: f64,
}

pub const COMBO_NAMES: [&str; 6] = [
    "dqn_cartpole",
    "a2c_invpend",
    "ddpg_lunar",
    "ddpg_mntncar",
    "dqn_breakout_mini",
    "ppo_mspacman_mini",
];

/// Full-shape Atari combos (Table III exact): used by the *timing*
/// figures only (hw model; no artifacts at 84×84 scale).
pub const TIMING_COMBO_NAMES: [&str; 6] = [
    "dqn_cartpole",
    "a2c_invpend",
    "ddpg_lunar",
    "ddpg_mntncar",
    "dqn_breakout",
    "ppo_mspacman",
];

/// Parse a combo name into its configuration.  Unknown names are a
/// reported error, not an abort — CLI front-ends (`apdrl`, `figures`)
/// route user input through this.  Dashes normalize to the registry's
/// underscores, so `dqn-cartpole` and `dqn_cartpole` are the same combo.
pub fn try_combo(name: &str) -> Result<ComboConfig> {
    let canon = name.replace('-', "_");
    let cfg = match canon.as_str() {
        "dqn_cartpole" => ComboConfig {
            name: "dqn_cartpole",
            algo: Algo::Dqn,
            env: "cartpole",
            net: NetSpec::mlp(&[4, 64, 64, 2]),
            batch: 64,
            obs_dim: 4,
            act_dim: 2,
            paper_flops_per_row: 28.04e3,
            paper_reward_error_pct: 1.60,
        },
        "a2c_invpend" => ComboConfig {
            name: "a2c_invpend",
            algo: Algo::A2c,
            env: "invpendulum",
            net: NetSpec::mlp(&[4, 64, 64, 1]),
            batch: 64,
            obs_dim: 4,
            act_dim: 1,
            paper_flops_per_row: 2.31e3,
            paper_reward_error_pct: 4.81,
        },
        "ddpg_lunar" => ComboConfig {
            name: "ddpg_lunar",
            algo: Algo::Ddpg,
            env: "lunarcont",
            net: NetSpec::mlp(&[8, 400, 300, 2]),
            batch: 64,
            obs_dim: 8,
            act_dim: 2,
            paper_flops_per_row: 2.25e6,
            paper_reward_error_pct: 1.73,
        },
        "ddpg_mntncar" => ComboConfig {
            name: "ddpg_mntncar",
            algo: Algo::Ddpg,
            env: "mntncarcont",
            net: NetSpec::mlp(&[2, 400, 300, 1]),
            batch: 64,
            obs_dim: 2,
            act_dim: 1,
            paper_flops_per_row: 2.19e6,
            paper_reward_error_pct: 1.12,
        },
        // mini pixel combos: artifacts exist, convergence runs use these
        "dqn_breakout_mini" => ComboConfig {
            name: "dqn_breakout_mini",
            algo: Algo::Dqn,
            env: "breakout_mini",
            net: NetSpec::Conv {
                in_hw: 12,
                in_ch: 4,
                conv: vec![(8, 4, 2), (16, 3, 1)],
                fc: vec![128, 4],
            },
            batch: 32,
            obs_dim: 12 * 12 * 4,
            act_dim: 4,
            paper_flops_per_row: 68.17e6, // full-shape figure (Table III)
            paper_reward_error_pct: 1.25,
        },
        "ppo_mspacman_mini" => ComboConfig {
            name: "ppo_mspacman_mini",
            algo: Algo::Ppo,
            env: "mspacman_mini",
            net: NetSpec::Conv {
                in_hw: 12,
                in_ch: 4,
                conv: vec![(8, 4, 2), (16, 3, 1)],
                fc: vec![128, 9],
            },
            batch: 64,
            obs_dim: 12 * 12 * 4,
            act_dim: 9,
            paper_flops_per_row: 106.23e6,
            paper_reward_error_pct: 1.13,
        },
        // full-shape Atari combos (timing figures only)
        "dqn_breakout" => ComboConfig {
            name: "dqn_breakout",
            algo: Algo::Dqn,
            env: "breakout_full",
            net: NetSpec::Conv {
                in_hw: 84,
                in_ch: 4,
                conv: vec![(32, 8, 4), (64, 4, 2), (64, 3, 1)],
                fc: vec![512, 4],
            },
            batch: 32,
            obs_dim: 84 * 84 * 4,
            act_dim: 4,
            paper_flops_per_row: 68.17e6,
            paper_reward_error_pct: 1.25,
        },
        "ppo_mspacman" => ComboConfig {
            name: "ppo_mspacman",
            algo: Algo::Ppo,
            env: "mspacman_full",
            net: NetSpec::Conv {
                in_hw: 84,
                in_ch: 4,
                conv: vec![(32, 8, 4), (64, 4, 2), (64, 3, 1)],
                fc: vec![512, 9],
            },
            batch: 32,
            obs_dim: 84 * 84 * 4,
            act_dim: 9,
            paper_flops_per_row: 106.23e6,
            paper_reward_error_pct: 1.13,
        },
        other => {
            return Err(anyhow!(
                "unknown combo {other} (known: {})",
                COMBO_NAMES.join(", ")
            ))
        }
    };
    Ok(cfg)
}

/// Infallible lookup for the statically known Table III names — tests,
/// benches and figure code use this; invalid names are a programmer
/// error here, so it panics with the parser's message.
pub fn combo(name: &str) -> ComboConfig {
    try_combo(name).unwrap_or_else(|e| panic!("{e}"))
}

impl ComboConfig {
    /// Training-step CDFG spec at batch size `bs`.
    pub fn train_spec(&self, bs: usize) -> TrainSpec {
        TrainSpec {
            algo: self.algo,
            net: self.net.clone(),
            batch: bs,
            obs_dim: self.obs_dim,
            act_dim: self.act_dim,
        }
    }

    /// Instantiate the environment, reporting unknown names as an error.
    pub fn try_make_env(&self) -> Result<Box<dyn Env>> {
        Ok(match self.env {
            "cartpole" => Box::new(envs::CartPole::new()) as Box<dyn Env>,
            "invpendulum" => Box::new(envs::InvertedPendulum::new()),
            "lunarcont" => Box::new(envs::LunarLanderCont::new()),
            "mntncarcont" => Box::new(envs::MountainCarCont::new()),
            "breakout_mini" => Box::new(envs::MiniBreakout::mini()),
            "mspacman_mini" => Box::new(envs::MiniMsPacman::mini()),
            "breakout_full" => Box::new(envs::MiniBreakout::full()),
            "mspacman_full" => Box::new(envs::MiniMsPacman::full()),
            other => return Err(anyhow!("combo {}: unknown env {other}", self.name)),
        })
    }

    /// Instantiate the environment (infallible for the Table III combos,
    /// whose env names are statically valid).
    pub fn make_env(&self) -> Box<dyn Env> {
        self.try_make_env().unwrap_or_else(|e| panic!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dashed_names_normalize_to_registry_combos() {
        let c = try_combo("dqn-cartpole").unwrap();
        assert_eq!(c.name, "dqn_cartpole");
        let c = try_combo("ppo-mspacman-mini").unwrap();
        assert_eq!(c.name, "ppo_mspacman_mini");
    }

    #[test]
    fn unknown_names_error_instead_of_aborting() {
        let e = try_combo("dqn_tetris").unwrap_err();
        assert!(format!("{e}").contains("unknown combo dqn_tetris"), "{e}");
        assert!(format!("{e}").contains("dqn_cartpole"), "should list known combos: {e}");
        let mut c = combo("dqn_cartpole");
        c.env = "no_such_env";
        // match, not unwrap_err: Box<dyn Env> has no Debug impl
        let e = match c.try_make_env() {
            Err(e) => e,
            Ok(_) => panic!("bad env name must not construct"),
        };
        assert!(format!("{e}").contains("unknown env no_such_env"), "{e}");
    }

    #[test]
    fn all_combos_construct() {
        for name in COMBO_NAMES.iter().chain(TIMING_COMBO_NAMES.iter()) {
            let c = combo(name);
            let env = c.make_env();
            assert_eq!(env.obs_dim(), c.obs_dim, "{name}");
            assert_eq!(env.action_dim(), c.act_dim, "{name}");
            let dag = crate::graph::build_train_graph(&c.train_spec(c.batch));
            assert!(!dag.is_empty());
        }
    }

    /// Table III FLOPs: our builder's fwd+bwd per-row MM FLOPs must be
    /// within 2× of the paper's reported figure (accounting conventions
    /// differ — see graph::flops tests).
    #[test]
    fn table3_flops_order_of_magnitude() {
        for name in ["dqn_cartpole", "ddpg_lunar", "ddpg_mntncar", "dqn_breakout", "ppo_mspacman"] {
            let c = combo(name);
            let dag = crate::graph::build_train_graph(&c.train_spec(c.batch));
            let per_row: f64 = dag
                .nodes
                .iter()
                .filter(|n| n.kind.is_mm())
                .map(|n| n.flops())
                .sum::<f64>()
                / c.batch as f64;
            let ratio = per_row / c.paper_flops_per_row;
            assert!(
                (0.4..6.0).contains(&ratio),
                "{name}: per-row {per_row:.3e} vs paper {:.3e} (ratio {ratio:.2})",
                c.paper_flops_per_row
            );
        }
    }
}
