//! Training metrics: episodic-reward tracking (Fig 11's 100-episode
//! moving average), reward-error computation (Table III) and loss-scale
//! telemetry.

use crate::util::json::{hex_f32s, hex_f64s, parse_hex_f32s, parse_hex_f64s, Json, JsonError};
use crate::util::stats;

/// Accumulated telemetry for one training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub episode_rewards: Vec<f64>,
    pub losses: Vec<f64>,
    pub env_steps: u64,
    pub train_steps: u64,
    pub overflows: u64,
    pub wallclock_s: f64,
    /// Loss-scale FSM transitions: `(env step, from, to)` — grows after
    /// clean-step streaks, halvings on overflow (paper Fig 9).  Scales
    /// are the values *fed to* consecutive train steps, so the very
    /// first backoff is included.
    pub scale_transitions: Vec<(u64, f32, f32)>,
    /// Scale fed to the most recent train step (0 before any).
    pub final_loss_scale: f32,
}

impl RunMetrics {
    /// Paper Fig 11's smoothing: 100-episode sliding-window average.
    pub fn smoothed_rewards(&self) -> Vec<f64> {
        stats::moving_average(&self.episode_rewards, 100)
    }

    /// Collection throughput: environment steps per wall-clock second
    /// (0 before `wallclock_s` is stamped).  The figure `--actors N`
    /// exists to move.
    pub fn env_steps_per_sec(&self) -> f64 {
        if self.wallclock_s > 0.0 {
            self.env_steps as f64 / self.wallclock_s
        } else {
            0.0
        }
    }

    /// Converged reward = mean of the last `tail` episodes (the value the
    /// paper compares between quantized and FP32 runs).
    pub fn converged_reward(&self, tail: usize) -> f64 {
        if self.episode_rewards.is_empty() {
            return 0.0;
        }
        let n = self.episode_rewards.len();
        let start = n.saturating_sub(tail);
        stats::mean(&self.episode_rewards[start..])
    }

    /// Serialize bit-exactly for checkpoints: reward/loss histories as
    /// hex f64 bits, scale transitions with their f32 bits, counters as
    /// plain numbers (shortest-round-trip f64 is exact for u64 < 2^53).
    pub fn to_json(&self) -> Json {
        let transitions = self
            .scale_transitions
            .iter()
            .map(|(step, from, to)| {
                Json::obj(vec![
                    ("step", Json::Num(*step as f64)),
                    ("scales", Json::Str(hex_f32s(&[*from, *to]))),
                ])
            })
            .collect();
        Json::obj(vec![
            ("episode_rewards", Json::Str(hex_f64s(&self.episode_rewards))),
            ("losses", Json::Str(hex_f64s(&self.losses))),
            ("env_steps", Json::Num(self.env_steps as f64)),
            ("train_steps", Json::Num(self.train_steps as f64)),
            ("overflows", Json::Num(self.overflows as f64)),
            ("wallclock_s", Json::Str(hex_f64s(&[self.wallclock_s]))),
            ("scale_transitions", Json::Arr(transitions)),
            ("final_loss_scale", Json::Str(hex_f32s(&[self.final_loss_scale]))),
        ])
    }

    /// Rebuild metrics from a [`RunMetrics::to_json`] snapshot.
    pub fn from_json(v: &Json) -> Result<RunMetrics, JsonError> {
        let bad = |msg: &str| JsonError { msg: msg.into(), pos: 0 };
        let scale_transitions = v
            .req_arr("scale_transitions")?
            .iter()
            .map(|t| {
                let scales = parse_hex_f32s(t.req_str("scales")?)?;
                if scales.len() != 2 {
                    return Err(bad("metrics: bad scale transition"));
                }
                Ok((t.req_u64("step")?, scales[0], scales[1]))
            })
            .collect::<Result<Vec<_>, JsonError>>()?;
        let wallclock = parse_hex_f64s(v.req_str("wallclock_s")?)?;
        if wallclock.len() != 1 {
            return Err(bad("metrics: bad wallclock"));
        }
        Ok(RunMetrics {
            episode_rewards: parse_hex_f64s(v.req_str("episode_rewards")?)?,
            losses: parse_hex_f64s(v.req_str("losses")?)?,
            env_steps: v.req_u64("env_steps")?,
            train_steps: v.req_u64("train_steps")?,
            overflows: v.req_u64("overflows")?,
            wallclock_s: wallclock[0],
            scale_transitions,
            final_loss_scale: v.req_f32_bits("final_loss_scale")?,
        })
    }
}

/// Table III reward error (%): |quantized − fp32| / |fp32| over converged
/// rewards, averaged across seeds.
pub fn reward_error_pct(fp32_rewards: &[f64], quant_rewards: &[f64]) -> f64 {
    let f = stats::mean(fp32_rewards);
    let q = stats::mean(quant_rewards);
    stats::relative_error(q, f) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converged_reward_tail() {
        let m = RunMetrics {
            episode_rewards: vec![0.0, 0.0, 10.0, 10.0],
            ..Default::default()
        };
        assert_eq!(m.converged_reward(2), 10.0);
        assert_eq!(m.converged_reward(100), 5.0);
        assert_eq!(RunMetrics::default().converged_reward(5), 0.0);
    }

    #[test]
    fn env_steps_per_sec_guards_zero_wallclock() {
        let mut m = RunMetrics::default();
        assert_eq!(m.env_steps_per_sec(), 0.0);
        m.env_steps = 500;
        m.wallclock_s = 2.0;
        assert_eq!(m.env_steps_per_sec(), 250.0);
    }

    #[test]
    fn reward_error_pct_basic() {
        assert!((reward_error_pct(&[100.0], &[98.0]) - 2.0).abs() < 1e-9);
        assert!((reward_error_pct(&[100.0, 100.0], &[101.0, 101.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn json_round_trip_is_bit_exact() {
        let m = RunMetrics {
            episode_rewards: vec![1.5, -2.25, 0.1],
            losses: vec![0.33, 0.31],
            env_steps: 1234,
            train_steps: 567,
            overflows: 2,
            wallclock_s: 3.125,
            scale_transitions: vec![(100, 1024.0, 512.0), (200, 512.0, 1024.0)],
            final_loss_scale: 1024.0,
        };
        let r = RunMetrics::from_json(&m.to_json()).unwrap();
        let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<u64>>();
        assert_eq!(bits(&r.episode_rewards), bits(&m.episode_rewards));
        assert_eq!(bits(&r.losses), bits(&m.losses));
        assert_eq!(r.env_steps, m.env_steps);
        assert_eq!(r.train_steps, m.train_steps);
        assert_eq!(r.overflows, m.overflows);
        assert_eq!(r.wallclock_s.to_bits(), m.wallclock_s.to_bits());
        assert_eq!(r.scale_transitions, m.scale_transitions);
        assert_eq!(r.final_loss_scale.to_bits(), m.final_loss_scale.to_bits());
        // Empty metrics round-trip too (fresh-run checkpoint at step 0).
        let e = RunMetrics::from_json(&RunMetrics::default().to_json()).unwrap();
        assert!(e.episode_rewards.is_empty() && e.losses.is_empty());
    }

    #[test]
    fn smoothing_window() {
        let m = RunMetrics {
            episode_rewards: (0..200).map(|i| i as f64).collect(),
            ..Default::default()
        };
        let s = m.smoothed_rewards();
        assert_eq!(s.len(), 200);
        assert!((s[199] - 149.5).abs() < 1e-9); // mean of 100..199
    }
}
