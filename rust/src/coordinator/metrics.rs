//! Training metrics: episodic-reward tracking (Fig 11's 100-episode
//! moving average), reward-error computation (Table III) and loss-scale
//! telemetry.

use crate::util::stats;

/// Accumulated telemetry for one training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub episode_rewards: Vec<f64>,
    pub losses: Vec<f64>,
    pub env_steps: u64,
    pub train_steps: u64,
    pub overflows: u64,
    pub wallclock_s: f64,
    /// Loss-scale FSM transitions: `(env step, from, to)` — grows after
    /// clean-step streaks, halvings on overflow (paper Fig 9).  Scales
    /// are the values *fed to* consecutive train steps, so the very
    /// first backoff is included.
    pub scale_transitions: Vec<(u64, f32, f32)>,
    /// Scale fed to the most recent train step (0 before any).
    pub final_loss_scale: f32,
}

impl RunMetrics {
    /// Paper Fig 11's smoothing: 100-episode sliding-window average.
    pub fn smoothed_rewards(&self) -> Vec<f64> {
        stats::moving_average(&self.episode_rewards, 100)
    }

    /// Collection throughput: environment steps per wall-clock second
    /// (0 before `wallclock_s` is stamped).  The figure `--actors N`
    /// exists to move.
    pub fn env_steps_per_sec(&self) -> f64 {
        if self.wallclock_s > 0.0 {
            self.env_steps as f64 / self.wallclock_s
        } else {
            0.0
        }
    }

    /// Converged reward = mean of the last `tail` episodes (the value the
    /// paper compares between quantized and FP32 runs).
    pub fn converged_reward(&self, tail: usize) -> f64 {
        if self.episode_rewards.is_empty() {
            return 0.0;
        }
        let n = self.episode_rewards.len();
        let start = n.saturating_sub(tail);
        stats::mean(&self.episode_rewards[start..])
    }
}

/// Table III reward error (%): |quantized − fp32| / |fp32| over converged
/// rewards, averaged across seeds.
pub fn reward_error_pct(fp32_rewards: &[f64], quant_rewards: &[f64]) -> f64 {
    let f = stats::mean(fp32_rewards);
    let q = stats::mean(quant_rewards);
    stats::relative_error(q, f) * 100.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converged_reward_tail() {
        let m = RunMetrics {
            episode_rewards: vec![0.0, 0.0, 10.0, 10.0],
            ..Default::default()
        };
        assert_eq!(m.converged_reward(2), 10.0);
        assert_eq!(m.converged_reward(100), 5.0);
        assert_eq!(RunMetrics::default().converged_reward(5), 0.0);
    }

    #[test]
    fn env_steps_per_sec_guards_zero_wallclock() {
        let mut m = RunMetrics::default();
        assert_eq!(m.env_steps_per_sec(), 0.0);
        m.env_steps = 500;
        m.wallclock_s = 2.0;
        assert_eq!(m.env_steps_per_sec(), 250.0);
    }

    #[test]
    fn reward_error_pct_basic() {
        assert!((reward_error_pct(&[100.0], &[98.0]) - 2.0).abs() < 1e-9);
        assert!((reward_error_pct(&[100.0, 100.0], &[101.0, 101.0]) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn smoothing_window() {
        let m = RunMetrics {
            episode_rewards: (0..200).map(|i| i as f64).collect(),
            ..Default::default()
        };
        let s = m.smoothed_rewards();
        assert_eq!(s.len(), 200);
        assert!((s[199] - 149.5).abs() < 1e-9); // mean of 100..199
    }
}
