//! The AP-DRL coordinator (L3 proper): experiment configs (Table III),
//! the static phase (build → profile → partition, paper Fig 7 left), the
//! dynamic phase (env/train loop over PJRT artifacts with the
//! quantization FSM, Fig 7 right), baseline timing models (AIE-only,
//! FIXAR) and report emission.

pub mod baselines;
pub mod config;
pub mod metrics;
pub mod pipeline;
pub mod report;
pub mod trainer;

pub use config::{combo, ComboConfig, COMBO_NAMES};
pub use pipeline::{static_phase, StaticPlan};
pub use trainer::{train_combo, TrainLimits, TrainResult};
