//! The AP-DRL coordinator (L3 proper): experiment configs (Table III),
//! the static phase (build → profile → partition, paper Fig 7 left) — a
//! cached, batched planning service (`static_phase` / `plan_sweep`)
//! behind the backend-agnostic [`planner::Planner`] trait — and the
//! dynamic phase (env/train loop with the quantization FSM, Fig 7
//! right) behind the execution [`crate::exec::Backend`] trait, plus
//! baseline timing models (AIE-only, FIXAR) and report emission.

pub mod baselines;
pub mod checkpoint;
pub mod config;
pub mod metrics;
pub mod pipeline;
pub mod planner;
pub mod report;
pub mod trainer;

pub use checkpoint::{Checkpoint, CHECKPOINT_VERSION};
pub use config::{combo, try_combo, ComboConfig, COMBO_NAMES};
pub use pipeline::{
    plan_sweep, plan_sweep_grid, plan_sweep_progress, static_phase, StaticPlan, SweepPoint,
};
pub use planner::{LocalPlanner, PlanOutcome, PlanRequest, PlanStep, Planner, Provenance};
pub use trainer::{
    train_combo, train_combo_actors, train_combo_job, JobOptions, TrainLimits, TrainResult,
};
