//! Baseline timing models (paper §V-C): AIE-only (CHARM-optimized FP32)
//! and FIXAR (CPU–FPGA @164 MHz, 16-bit fixed point, quantization-aware
//! training), evaluated on the same CDFG + schedule machinery as AP-DRL.

use crate::graph::build_train_graph;
use crate::hw::{fixar_platform, vek280, Component};
use crate::partition::model::{Assignment, Placement, Problem};
use crate::partition::evaluate;
use crate::profile::profile_dag;
use crate::Micros;

use super::config::ComboConfig;

/// AIE-only (paper baseline 1): every MM node on the AIE in FP32
/// (CHARM-optimized), non-MM nodes on the PL in FP32, no quantization.
pub fn aie_only_step_time(combo: &ComboConfig, bs: usize) -> Micros {
    let platform = vek280();
    let dag = build_train_graph(&combo.train_spec(bs));
    let profiles = profile_dag(&dag, &platform, false);
    let problem = Problem::new(&dag, &profiles, &platform, false);
    let assignment: Assignment = (0..dag.len())
        .map(|i| {
            if profiles[i].aie.is_empty() {
                Placement { component: Component::PL, candidate: 0 }
            } else {
                Placement { component: Component::AIE, candidate: 0 }
            }
        })
        .collect();
    evaluate(&problem, &assignment).makespan_us
}

/// FIXAR (paper baseline 2, [27]): everything on the 164 MHz fabric with
/// fx16 quantization-aware training (no master-weight sync — fixed point
/// trains in-place), CPU host loop.
pub fn fixar_step_time(combo: &ComboConfig, bs: usize) -> Micros {
    let platform = fixar_platform();
    let dag = build_train_graph(&combo.train_spec(bs));
    // FIXAR's fabric computes in fixed point; our PL fx16 path maps onto
    // the fp16 datapath width.  Profile quantized=true (fp16 widths) but
    // evaluate without AP-DRL's master-weight sync (quantized=false in
    // the Problem => no sync overhead; fixed-point QAT needs none).
    let profiles = profile_dag(&dag, &platform, true);
    let problem = Problem::new(&dag, &profiles, &platform, false);
    let assignment: Assignment = (0..dag.len())
        .map(|_| Placement { component: Component::PL, candidate: 0 })
        .collect();
    evaluate(&problem, &assignment).makespan_us
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::combo;
    use crate::coordinator::pipeline::static_phase;

    /// §V-C bullet 1: AIE-only loses to FIXAR at low FLOPs (launch
    /// overhead), wins at high FLOPs (clock).
    #[test]
    fn aie_vs_fixar_crossover() {
        let low = combo("dqn_cartpole");
        let t_aie = aie_only_step_time(&low, 64);
        let t_fix = fixar_step_time(&low, 64);
        assert!(t_aie > t_fix, "low FLOPs: AIE-only {t_aie} should lose to FIXAR {t_fix}");

        let high = combo("dqn_breakout");
        let t_aie = aie_only_step_time(&high, 128);
        let t_fix = fixar_step_time(&high, 128);
        assert!(t_aie < t_fix, "high FLOPs: AIE-only {t_aie} should beat FIXAR {t_fix}");
    }

    /// §V-C bullet 3: AP-DRL beats AIE-only across the board
    /// (1.61×–3.82× in the paper).
    #[test]
    fn apdrl_beats_aie_only_everywhere() {
        for name in ["dqn_cartpole", "ddpg_lunar", "dqn_breakout"] {
            let c = combo(name);
            let plan = static_phase(&c, c.batch, true);
            let t_aie = aie_only_step_time(&c, c.batch);
            let ratio = t_aie / plan.schedule.makespan_us;
            assert!(
                ratio > 1.0,
                "{name}: AP-DRL {} should beat AIE-only {t_aie}",
                plan.schedule.makespan_us
            );
            assert!(ratio < 50.0, "{name}: speedup {ratio} implausibly large");
        }
    }

    /// §V-C bullet 2: AP-DRL's advantage over FIXAR grows with FLOPs
    /// (0.98× → 4.17× in the paper).
    #[test]
    fn apdrl_vs_fixar_grows_with_flops() {
        let low = combo("dqn_cartpole");
        let plan_low = static_phase(&low, 64, true);
        let r_low = fixar_step_time(&low, 64) / plan_low.schedule.makespan_us;

        let high = combo("dqn_breakout");
        let plan_high = static_phase(&high, 128, true);
        let r_high = fixar_step_time(&high, 128) / plan_high.schedule.makespan_us;

        assert!(r_high > r_low, "speedup should grow: low {r_low} high {r_high}");
        assert!(r_high > 1.5, "high-FLOPs speedup too small: {r_high}");
    }
}
