//! Static phase (paper Fig 7, left column), served as a **planning
//! service**: build the layer CDFG, profile it per component (DSE),
//! select the PS–PL interface (TAPCA), solve the partitioning ILP and
//! derive the precision policy.
//!
//! Two service properties on top of the paper's flow:
//!
//! * **Memoization** — solved plans are cached in
//!   [`crate::partition::cache`] keyed on (algo, net shape, batch,
//!   precision, platform fingerprint).  A repeated [`static_phase`] call
//!   for the same key skips the ILP entirely: it returns the identical
//!   schedule with `solution.explored == 0` and `cache_hit == true`.
//!   Set `APDRL_PLAN_CACHE=<path>` to persist plans as JSON across runs.
//! * **Batched sweeps** — [`plan_sweep`] / [`plan_sweep_grid`] drive many
//!   (combo, batch) points concurrently over scoped threads, deduping
//!   repeated points by plan key (duplicates become memoized clones of
//!   the first occurrence, skipping even the DSE profiling).  A lone
//!   `static_phase` call
//!   parallelizes its branch-and-bound internally; inside a sweep the
//!   solves run sequentially so the two parallelism levels don't
//!   multiply.  This is how the figure harness, the benches and the
//!   examples regenerate Table III/IV-scale grids.

use std::cell::Cell;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::graph::{build_train_graph, Dag};
use crate::obs;
use crate::hw::{vek280, Platform};
use crate::partition::cache::{self, PlanKey};
use crate::partition::schedule::Schedule;
use crate::partition::{evaluate, solve_ilp, Solution};
use crate::profile::tapca::{select_interface, DrlTraffic, PsPlInterface};
use crate::profile::{profile_dag, NodeProfile};
use crate::quant::PrecisionPolicy;
use crate::Micros;

use super::config::ComboConfig;
use super::planner::PlanRequest;

/// Everything the dynamic phase needs, decided before deployment.
#[derive(Clone)]
pub struct StaticPlan {
    pub dag: Dag,
    pub profiles: Vec<NodeProfile>,
    pub platform: Platform,
    pub solution: Solution,
    pub schedule: Schedule,
    pub policy: PrecisionPolicy,
    pub interface: PsPlInterface,
    /// Per-step PS–PL pipeline time (inference I/O + batch + model sync)
    /// over the selected interface.
    pub ps_pl_us: Micros,
    /// True when the partitioning came from the plan cache instead of a
    /// fresh ILP solve (in which case `solution.explored == 0`).
    pub cache_hit: bool,
}

/// Run the static phase for `combo` at batch size `bs`, consulting the
/// process-wide plan cache.
/// `quantized` selects AP-DRL's mixed-precision mode vs the FP32 control.
pub fn static_phase(combo: &ComboConfig, bs: usize, quantized: bool) -> StaticPlan {
    let platform = vek280();
    let spec = combo.train_spec(bs);
    let dag = build_train_graph(&spec);
    let profiles = profile_dag(&dag, &platform, quantized);
    let problem = crate::partition::Problem::new(&dag, &profiles, &platform, quantized);

    let key = PlanKey::new(&spec, quantized, &platform);
    let cached = cache::global().lock().unwrap().lookup(&key, &profiles);
    if obs::active() {
        // How many node profiles were priced from kernel measurements
        // (calibration table) rather than the analytic cost model.
        let calib_nodes = profiles.iter().filter(|p| p.ps_measured).count();
        obs::publish(
            obs::Event::new("plan.cache")
                .tag("combo", combo.name)
                .num("batch", bs as f64)
                .flag("quantized", quantized)
                .flag("hit", cached.is_some())
                .flag("calibrated", calib_nodes > 0)
                .num("calib_nodes", calib_nodes as f64),
        );
    }
    let (solution, schedule, cache_hit) = match cached {
        Some(solution) => {
            let schedule = evaluate(&problem, &solution.assignment);
            // Defense in depth: if the schedule evaluator disagrees with
            // the memoized makespan (a model constant changed without
            // moving the platform fingerprint), fall back to a fresh
            // solve instead of serving a stale plan.
            let tol = 1e-6 * schedule.makespan_us.abs().max(1.0);
            if (schedule.makespan_us - solution.makespan_us).abs() <= tol {
                (solution, schedule, true)
            } else {
                solve_and_memoize(&problem, &key)
            }
        }
        None => solve_and_memoize(&problem, &key),
    };

    let policy = PrecisionPolicy::from_assignment(&dag, &solution.assignment, quantized);

    // TAPCA: PS–PL traffic of the Inference → Buffer → Batch → Model
    // pipeline (paper Fig 10).
    let elem_bytes = 4.0; // PS side is always fp32
    let weights = combo.net.weight_elems() as f64;
    let traffic = DrlTraffic {
        infer_bytes: (combo.obs_dim + combo.act_dim) as f64 * elem_bytes,
        infer_transfers: 1.0,
        batch_bytes: bs as f64 * (2.0 * combo.obs_dim as f64 + combo.act_dim as f64 + 2.0) * elem_bytes,
        // The model is accelerator-resident; the PS master copy is only
        // refreshed periodically (checkpoint cadence ~1/100 steps), so
        // the per-step charge is amortized.
        model_bytes: weights * elem_bytes / 100.0,
    };
    let (interface, ps_pl_us) = select_interface(&traffic);

    StaticPlan {
        dag,
        profiles,
        platform,
        solution,
        schedule,
        policy,
        interface,
        ps_pl_us,
        cache_hit,
    }
}

thread_local! {
    /// Set for the lifetime of a `plan_sweep` worker thread: the sweep
    /// already saturates the cores with one solve per worker, so nested
    /// solves run single-threaded instead of spawning their own pools.
    static IN_SWEEP: Cell<bool> = Cell::new(false);
}

fn solve_and_memoize(
    problem: &crate::partition::Problem,
    key: &PlanKey,
) -> (Solution, Schedule, bool) {
    let solution = if IN_SWEEP.with(Cell::get) {
        crate::partition::ilp::solve_ilp_with_workers(problem, 1)
    } else {
        solve_ilp(problem)
    };
    // insert + persist with the disk I/O outside the cache lock.
    cache::global_insert(key, &solution);
    let schedule = evaluate(problem, &solution.assignment);
    (solution, schedule, false)
}

/// Plan every request concurrently; results come back in request order.
/// Duplicate points within one sweep are planned once: the copies are
/// filled by cloning the first occurrence's plan (marked as memoized —
/// `cache_hit == true`, `explored == 0`) *without* re-running the DSE
/// profiling, so a sweep with repeated (combo, batch) pairs costs one
/// profile+solve per distinct plan key.  Each worker solves
/// sequentially — the sweep itself is the parallelism, so the per-solve
/// B&B pool is not nested inside it.  Separate overlapping sweeps are
/// not strictly deduplicated, but share the global plan cache.
pub fn plan_sweep(requests: &[PlanRequest]) -> Vec<StaticPlan> {
    plan_sweep_progress(requests, &|_| {})
}

/// One completed point of a sweep, as handed to a progress observer the
/// moment it resolves (completion order, not request order — `index`
/// says where it lands in the request slice, `done`/`total` drive
/// progress bars).
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub index: usize,
    /// Points completed so far, this one included.
    pub done: usize,
    pub total: usize,
    pub combo: String,
    pub batch: usize,
    pub quantized: bool,
    pub cache_hit: bool,
    pub explored: usize,
    /// Wall time of this point's static phase (0 for deduped copies).
    pub solve_us: u64,
}

/// [`plan_sweep`] with a live progress observer: `progress` fires once
/// per point — deduped duplicates included, so `done` always reaches
/// `total` — from whichever worker finished it.  The same completions
/// go to the event bus as `sweep.start`/`sweep.point`/`sweep.done`,
/// which the daemon's streaming sweep mode and `apdrl dash` render as
/// progress bars.
pub fn plan_sweep_progress(
    requests: &[PlanRequest],
    progress: &(dyn Fn(&SweepPoint) + Sync),
) -> Vec<StaticPlan> {
    let n = requests.len();
    if n == 0 {
        return Vec::new();
    }
    // First occurrence of each distinct plan key does the solving.
    let platform = vek280();
    let keys: Vec<PlanKey> = requests
        .iter()
        .map(|r| PlanKey::new(&r.combo.train_spec(r.batch), r.quantized, &platform))
        .collect();
    let mut first_of: HashMap<PlanKey, usize> = HashMap::new();
    let mut unique: Vec<usize> = Vec::new();
    for (i, key) in keys.iter().enumerate() {
        if !first_of.contains_key(key) {
            first_of.insert(key.clone(), i);
            unique.push(i);
        }
    }
    let t_sweep = Instant::now();
    if obs::active() {
        obs::publish(
            obs::Event::new("sweep.start")
                .num("points", n as f64)
                .num("distinct", unique.len() as f64),
        );
    }
    let done = AtomicUsize::new(0);
    let report = |i: usize, plan: &StaticPlan, solve_us: u64| {
        let req = &requests[i];
        let point = SweepPoint {
            index: i,
            done: done.fetch_add(1, Ordering::SeqCst) + 1,
            total: n,
            combo: req.combo.name.to_string(),
            batch: req.batch,
            quantized: req.quantized,
            cache_hit: plan.cache_hit,
            explored: plan.solution.explored,
            solve_us,
        };
        if obs::active() {
            obs::publish(
                obs::Event::new("sweep.point")
                    .tag("combo", &point.combo)
                    .num("index", point.index as f64)
                    .num("done", point.done as f64)
                    .num("total", point.total as f64)
                    .num("batch", point.batch as f64)
                    .flag("quantized", point.quantized)
                    .flag("cache_hit", point.cache_hit)
                    .num("explored", point.explored as f64)
                    .num("solve_us", point.solve_us as f64),
            );
        }
        progress(&point);
    };
    let workers = std::thread::available_parallelism()
        .map(|c| c.get())
        .unwrap_or(1)
        .min(unique.len())
        .max(1);
    let slots: Vec<Mutex<Option<StaticPlan>>> = (0..n).map(|_| Mutex::new(None)).collect();
    if workers == 1 {
        // Serial path (one distinct point, or one core): no worker pool,
        // so the lone solve keeps its internal B&B parallelism.
        for &i in &unique {
            let req = &requests[i];
            let t0 = Instant::now();
            let plan = static_phase(&req.combo, req.batch, req.quantized);
            report(i, &plan, t0.elapsed().as_micros() as u64);
            *slots[i].lock().unwrap() = Some(plan);
        }
    } else {
        let next = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    IN_SWEEP.with(|flag| flag.set(true));
                    loop {
                        let j = next.fetch_add(1, Ordering::Relaxed);
                        let Some(&i) = unique.get(j) else { break };
                        let req = &requests[i];
                        let t0 = Instant::now();
                        let plan = static_phase(&req.combo, req.batch, req.quantized);
                        report(i, &plan, t0.elapsed().as_micros() as u64);
                        *slots[i].lock().unwrap() = Some(plan);
                    }
                });
            }
        });
    }
    let mut plans: Vec<Option<StaticPlan>> =
        slots.into_iter().map(|slot| slot.into_inner().unwrap()).collect();
    for i in 0..n {
        if plans[i].is_none() {
            let j = first_of[&keys[i]];
            let mut copy = plans[j]
                .as_ref()
                .expect("first occurrence of every key is planned")
                .clone();
            // The copy is a memoized duplicate, whatever the original was.
            copy.solution.explored = 0;
            copy.cache_hit = true;
            report(i, &copy, 0);
            plans[i] = Some(copy);
        }
    }
    if obs::active() {
        obs::publish(
            obs::Event::new("sweep.done")
                .num("points", n as f64)
                .num("wall_us", t_sweep.elapsed().as_micros() as f64),
        );
    }
    plans.into_iter().map(|p| p.unwrap()).collect()
}

/// Convenience cross-product sweep: every combo at every batch size, in
/// row-major (combo-outer) order.
pub fn plan_sweep_grid(
    combos: &[ComboConfig],
    batches: &[usize],
    quantized: bool,
) -> Vec<StaticPlan> {
    let requests: Vec<PlanRequest> = combos
        .iter()
        .flat_map(|c| batches.iter().map(move |&bs| PlanRequest::new(c.clone(), bs, quantized)))
        .collect();
    plan_sweep(&requests)
}

impl StaticPlan {
    /// Full per-training-step time on the modeled platform: the
    /// partitioned train-stage makespan + the PS–PL pipeline (Fig 12's
    /// "total training time within one timestep").
    pub fn step_time_us(&self) -> Micros {
        self.schedule.makespan_us + self.ps_pl_us
    }

    /// Training throughput (batches/second), Fig 13's metric.
    pub fn throughput(&self) -> f64 {
        1e6 / self.step_time_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::combo;
    use crate::hw::Component;

    #[test]
    fn cartpole_plan_is_all_pl() {
        // Fig 15 / §V-C: tiny nets stay on the PL.
        let plan = static_phase(&combo("dqn_cartpole"), 64, true);
        assert_eq!(plan.solution.aie_nodes(&plan.dag), 0);
        assert!(plan.policy.needs_loss_scaling);
        assert!(plan.step_time_us() > 0.0);
    }

    #[test]
    fn breakout_plan_uses_aie() {
        // High-FLOPs conv nodes must migrate to the AIE.
        let plan = static_phase(&combo("dqn_breakout"), 32, true);
        assert!(
            plan.solution.aie_nodes(&plan.dag) >= 3,
            "got {}",
            plan.solution.aie_nodes(&plan.dag)
        );
    }

    #[test]
    fn quantized_never_slower_at_high_flops() {
        // Table IV large net: BF16/AIE quantization must speed up the
        // step substantially.
        let c = combo("ddpg_lunar");
        let q = static_phase(&c, 1024, true);
        let f = static_phase(&c, 1024, false);
        assert!(
            q.step_time_us() < f.step_time_us(),
            "quantized {} vs fp32 {}",
            q.step_time_us(),
            f.step_time_us()
        );
    }

    #[test]
    fn schedule_components_match_policy() {
        let plan = static_phase(&combo("ddpg_lunar"), 512, true);
        for e in &plan.schedule.entries {
            let fmt = plan.policy.node_format[e.node];
            match e.component {
                Component::PL => assert_eq!(fmt, crate::hw::Format::Fp16),
                Component::AIE => assert_eq!(fmt, crate::hw::Format::Bf16),
                Component::PS => assert_eq!(fmt, crate::hw::Format::Fp32),
            }
        }
    }

    #[test]
    fn repeated_static_phase_hits_the_plan_cache() {
        // The acceptance contract of the planning service: the second
        // solve for the same (combo, batch, quantized) key reports zero
        // explored nodes + the cache-hit flag, with an identical plan.
        let c = combo("ddpg_mntncar");
        let first = static_phase(&c, 96, true);
        let second = static_phase(&c, 96, true);
        assert!(second.cache_hit, "second solve must come from the cache");
        assert_eq!(second.solution.explored, 0, "cache hits skip the ILP search");
        assert_eq!(second.solution.assignment, first.solution.assignment);
        assert_eq!(
            second.solution.makespan_us.to_bits(),
            first.solution.makespan_us.to_bits(),
            "cached plan must be bit-identical to the fresh solve"
        );
        assert_eq!(second.schedule.entries.len(), first.schedule.entries.len());
        for (a, b) in second.schedule.entries.iter().zip(&first.schedule.entries) {
            assert_eq!(a.node, b.node);
            assert_eq!(a.component, b.component);
            assert_eq!(a.start_us.to_bits(), b.start_us.to_bits());
            assert_eq!(a.finish_us.to_bits(), b.finish_us.to_bits());
        }
        assert_eq!(second.step_time_us().to_bits(), first.step_time_us().to_bits());
    }

    #[test]
    fn plan_sweep_matches_individual_solves_in_order() {
        let combos = [combo("dqn_cartpole"), combo("a2c_invpend")];
        let batches = [48usize, 80];
        let swept = plan_sweep_grid(&combos, &batches, true);
        assert_eq!(swept.len(), combos.len() * batches.len());
        for (i, plan) in swept.iter().enumerate() {
            let c = &combos[i / batches.len()];
            let bs = batches[i % batches.len()];
            let solo = static_phase(c, bs, true);
            assert_eq!(
                plan.solution.makespan_us.to_bits(),
                solo.solution.makespan_us.to_bits(),
                "{} bs={bs}: sweep and solo plans disagree",
                c.name
            );
            assert_eq!(plan.solution.assignment, solo.solution.assignment);
        }
    }

    #[test]
    fn empty_sweep_is_empty() {
        assert!(plan_sweep(&[]).is_empty());
    }

    #[test]
    fn duplicate_sweep_points_are_memoized_copies_not_replans() {
        // Same (combo, batch, precision) three times in one sweep: one
        // profile+solve, two clones marked as memoized.
        let reqs = vec![
            PlanRequest::new(combo("a2c_invpend"), 88, true),
            PlanRequest::new(combo("a2c_invpend"), 88, true),
            PlanRequest::new(combo("dqn_cartpole"), 88, true),
            PlanRequest::new(combo("a2c_invpend"), 88, true),
        ];
        let plans = plan_sweep(&reqs);
        assert_eq!(plans.len(), 4);
        for dup in [&plans[1], &plans[3]] {
            assert!(dup.cache_hit, "duplicate points must be memoized");
            assert_eq!(dup.solution.explored, 0, "duplicates must not re-search");
            assert_eq!(dup.solution.assignment, plans[0].solution.assignment);
            assert_eq!(
                dup.solution.makespan_us.to_bits(),
                plans[0].solution.makespan_us.to_bits()
            );
            assert_eq!(
                dup.step_time_us().to_bits(),
                plans[0].step_time_us().to_bits()
            );
        }
        // The interleaved distinct point is its own plan.
        assert_ne!(
            plans[2].solution.makespan_us.to_bits(),
            plans[0].solution.makespan_us.to_bits()
        );
    }

    #[test]
    fn sweep_progress_reports_every_point_once_including_duplicates() {
        let reqs = vec![
            PlanRequest::new(combo("a2c_invpend"), 72, true),
            PlanRequest::new(combo("a2c_invpend"), 72, true),
            PlanRequest::new(combo("dqn_cartpole"), 72, true),
        ];
        let seen: Mutex<Vec<SweepPoint>> = Mutex::new(Vec::new());
        let plans = plan_sweep_progress(&reqs, &|p| seen.lock().unwrap().push(p.clone()));
        assert_eq!(plans.len(), 3);
        let seen = seen.into_inner().unwrap();
        assert_eq!(seen.len(), 3, "one progress report per point, duplicates included");
        let mut indices: Vec<usize> = seen.iter().map(|p| p.index).collect();
        indices.sort_unstable();
        assert_eq!(indices, vec![0, 1, 2]);
        assert!(seen.iter().any(|p| p.done == 3 && p.total == 3), "done must reach total");
        // The duplicate point arrives as a memoized copy with no solve time.
        let dup = seen.iter().find(|p| p.index == 1).expect("index 1 reported");
        assert!(dup.cache_hit);
        assert_eq!(dup.solve_us, 0);
        assert_eq!(dup.explored, 0);
    }
}
