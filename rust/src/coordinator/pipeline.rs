//! Static phase (paper Fig 7, left column): build the layer CDFG,
//! profile it per component (DSE), select the PS–PL interface (TAPCA),
//! solve the partitioning ILP and derive the precision policy.

use crate::graph::{build_train_graph, Dag};
use crate::hw::{vek280, Platform};
use crate::partition::{evaluate, solve_ilp, Solution};
use crate::partition::schedule::Schedule;
use crate::profile::tapca::{select_interface, DrlTraffic, PsPlInterface};
use crate::profile::{profile_dag, NodeProfile};
use crate::quant::PrecisionPolicy;
use crate::Micros;

use super::config::ComboConfig;

/// Everything the dynamic phase needs, decided before deployment.
pub struct StaticPlan {
    pub dag: Dag,
    pub profiles: Vec<NodeProfile>,
    pub platform: Platform,
    pub solution: Solution,
    pub schedule: Schedule,
    pub policy: PrecisionPolicy,
    pub interface: PsPlInterface,
    /// Per-step PS–PL pipeline time (inference I/O + batch + model sync)
    /// over the selected interface.
    pub ps_pl_us: Micros,
}

/// Run the static phase for `combo` at batch size `bs`.
/// `quantized` selects AP-DRL's mixed-precision mode vs the FP32 control.
pub fn static_phase(combo: &ComboConfig, bs: usize, quantized: bool) -> StaticPlan {
    let platform = vek280();
    let dag = build_train_graph(&combo.train_spec(bs));
    let profiles = profile_dag(&dag, &platform, quantized);
    let problem = crate::partition::Problem::new(&dag, &profiles, &platform, quantized);
    let solution = solve_ilp(&problem);
    let schedule = evaluate(&problem, &solution.assignment);
    let policy = PrecisionPolicy::from_assignment(&dag, &solution.assignment, quantized);

    // TAPCA: PS–PL traffic of the Inference → Buffer → Batch → Model
    // pipeline (paper Fig 10).
    let elem_bytes = 4.0; // PS side is always fp32
    let weights = combo.net.weight_elems() as f64;
    let traffic = DrlTraffic {
        infer_bytes: (combo.obs_dim + combo.act_dim) as f64 * elem_bytes,
        infer_transfers: 1.0,
        batch_bytes: bs as f64 * (2.0 * combo.obs_dim as f64 + combo.act_dim as f64 + 2.0) * elem_bytes,
        // The model is accelerator-resident; the PS master copy is only
        // refreshed periodically (checkpoint cadence ~1/100 steps), so
        // the per-step charge is amortized.
        model_bytes: weights * elem_bytes / 100.0,
    };
    let (interface, ps_pl_us) = select_interface(&traffic);

    StaticPlan { dag, profiles, platform, solution, schedule, policy, interface, ps_pl_us }
}

impl StaticPlan {
    /// Full per-training-step time on the modeled platform: the
    /// partitioned train-stage makespan + the PS–PL pipeline (Fig 12's
    /// "total training time within one timestep").
    pub fn step_time_us(&self) -> Micros {
        self.schedule.makespan_us + self.ps_pl_us
    }

    /// Training throughput (batches/second), Fig 13's metric.
    pub fn throughput(&self) -> f64 {
        1e6 / self.step_time_us()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::config::combo;
    use crate::hw::Component;

    #[test]
    fn cartpole_plan_is_all_pl() {
        // Fig 15 / §V-C: tiny nets stay on the PL.
        let plan = static_phase(&combo("dqn_cartpole"), 64, true);
        assert_eq!(plan.solution.aie_nodes(&plan.dag), 0);
        assert!(plan.policy.needs_loss_scaling);
        assert!(plan.step_time_us() > 0.0);
    }

    #[test]
    fn breakout_plan_uses_aie() {
        // High-FLOPs conv nodes must migrate to the AIE.
        let plan = static_phase(&combo("dqn_breakout"), 32, true);
        assert!(
            plan.solution.aie_nodes(&plan.dag) >= 3,
            "got {}",
            plan.solution.aie_nodes(&plan.dag)
        );
    }

    #[test]
    fn quantized_never_slower_at_high_flops() {
        // Table IV large net: BF16/AIE quantization must speed up the
        // step substantially.
        let c = combo("ddpg_lunar");
        let q = static_phase(&c, 1024, true);
        let f = static_phase(&c, 1024, false);
        assert!(
            q.step_time_us() < f.step_time_us(),
            "quantized {} vs fp32 {}",
            q.step_time_us(),
            f.step_time_us()
        );
    }

    #[test]
    fn schedule_components_match_policy() {
        let plan = static_phase(&combo("ddpg_lunar"), 512, true);
        for e in &plan.schedule.entries {
            let fmt = plan.policy.node_format[e.node];
            match e.component {
                Component::PL => assert_eq!(fmt, crate::hw::Format::Fp16),
                Component::AIE => assert_eq!(fmt, crate::hw::Format::Bf16),
                Component::PS => assert_eq!(fmt, crate::hw::Format::Fp32),
            }
        }
    }
}
