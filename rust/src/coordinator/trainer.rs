//! Dynamic phase (paper Fig 7, right column): the Inference →
//! Environment Step → Train loop, fully in rust, with network compute on
//! PJRT artifacts and the hardware-aware quantization FSM live.

use std::time::Instant;

use anyhow::Result;

use crate::drl::a2c::{A2cAgent, A2cConfig};
use crate::drl::ddpg::{DdpgAgent, DdpgConfig};
use crate::drl::dqn::{DqnAgent, DqnConfig};
use crate::drl::ppo::{PpoAgent, PpoConfig};
use crate::drl::Agent;
use crate::graph::Algo;
use crate::runtime::Runtime;
use crate::util::Rng;

use super::config::ComboConfig;
use super::metrics::RunMetrics;

/// Run-length limits (scaled for this 1-core testbed; `--full` in the
/// figures harness restores larger budgets).
#[derive(Clone, Copy, Debug)]
pub struct TrainLimits {
    pub max_env_steps: u64,
    pub max_episodes: usize,
}

impl Default for TrainLimits {
    fn default() -> Self {
        TrainLimits { max_env_steps: 20_000, max_episodes: 300 }
    }
}

/// Result of one seeded training run.
pub struct TrainResult {
    pub metrics: RunMetrics,
    pub combo: String,
    pub mode: String,
    pub seed: u64,
}

fn make_agent(
    runtime: &mut Runtime,
    combo: &ComboConfig,
    mode: &str,
    seed: u64,
) -> Result<Box<dyn Agent>> {
    Ok(match combo.algo {
        Algo::Dqn => {
            let obs_shape = match &combo.net {
                crate::graph::NetSpec::Mlp { .. } => vec![combo.obs_dim],
                crate::graph::NetSpec::Conv { in_hw, in_ch, .. } => vec![*in_hw, *in_hw, *in_ch],
            };
            Box::new(DqnAgent::new(
                runtime,
                combo.name,
                mode,
                DqnConfig::for_combo(combo.batch, obs_shape, combo.act_dim),
                seed,
            )?)
        }
        Algo::Ddpg => Box::new(DdpgAgent::new(
            runtime,
            combo.name,
            mode,
            DdpgConfig::for_combo(combo.batch, combo.obs_dim, combo.act_dim),
            seed,
        )?),
        Algo::A2c => Box::new(A2cAgent::new(
            runtime,
            combo.name,
            mode,
            A2cConfig::for_combo(combo.batch, combo.obs_dim, combo.act_dim),
            seed,
        )?),
        Algo::Ppo => {
            let obs_shape = match &combo.net {
                crate::graph::NetSpec::Mlp { .. } => vec![combo.obs_dim],
                crate::graph::NetSpec::Conv { in_hw, in_ch, .. } => vec![*in_hw, *in_hw, *in_ch],
            };
            Box::new(PpoAgent::new(
                runtime,
                combo.name,
                mode,
                PpoConfig::for_combo(combo.batch, obs_shape, combo.act_dim),
                seed,
            )?)
        }
    })
}

/// Train `combo` in `mode` ("fp32" | "mixed" | "bf16") for one seed.
pub fn train_combo(
    runtime: &mut Runtime,
    combo: &ComboConfig,
    mode: &str,
    seed: u64,
    limits: TrainLimits,
    verbose: bool,
) -> Result<TrainResult> {
    let t0 = Instant::now();
    let mut agent = make_agent(runtime, combo, mode, seed)?;
    let mut env = combo.make_env();
    let mut rng = Rng::new(seed);
    let mut env_rng = rng.fork(0xE74);
    let mut metrics = RunMetrics::default();

    let mut obs = env.reset(&mut env_rng);
    let mut ep_reward = 0.0f64;
    while metrics.env_steps < limits.max_env_steps
        && metrics.episode_rewards.len() < limits.max_episodes
    {
        let action = agent.act(&obs, &mut rng)?;
        let tr = env.step(&action, &mut env_rng);
        if let Some(stats) =
            agent.observe(&obs, &action, tr.reward as f32, &tr.obs, tr.done, &mut rng)?
        {
            metrics.losses.push(stats.loss as f64);
            if stats.found_inf {
                metrics.overflows += 1;
            }
        }
        ep_reward += tr.reward;
        metrics.env_steps += 1;
        if tr.done {
            metrics.episode_rewards.push(ep_reward);
            if verbose && metrics.episode_rewards.len() % 25 == 0 {
                let n = metrics.episode_rewards.len();
                let recent = metrics.converged_reward(25);
                eprintln!(
                    "  [{}/{} seed {seed}] ep {n}: avg25 {recent:.1} (steps {})",
                    combo.name, mode, metrics.env_steps
                );
            }
            ep_reward = 0.0;
            obs = env.reset(&mut env_rng);
        } else {
            obs = tr.obs;
        }
    }
    metrics.train_steps = agent.train_steps();
    metrics.wallclock_s = t0.elapsed().as_secs_f64();
    Ok(TrainResult { metrics, combo: combo.name.into(), mode: mode.into(), seed })
}
