//! Dynamic phase (paper Fig 7, right column): the Inference →
//! Environment Step → Train loop, fully in rust, with network compute on
//! an execution [`Backend`] — the pure-Rust CPU executor by default, the
//! PJRT artifacts under the `pjrt` feature — and the hardware-aware
//! quantization FSM live.
//!
//! Collection is N-wide: [`train_combo_actors`] drives a
//! [`BatchedEnv`] fleet of `actors` lanes in lockstep, so actor
//! inference issues one GEMM per layer for all lanes at once.  At
//! `actors == 1` the loop is bit-identical to the historical scalar
//! path — same RNG stream, same rewards, same loss-scale FSM
//! transitions, same final weights (proved in `tests/train.rs`).

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::drl::Agent;
use crate::envs::{lane_rngs, BatchedEnv, Env};
use crate::exec::{Backend, Pool};
use crate::obs;
use crate::util::json::Json;
use crate::util::Rng;

use super::checkpoint::Checkpoint;
use super::config::ComboConfig;
use super::metrics::RunMetrics;

/// Run-length limits (scaled for this small testbed; `--full` in the
/// figures harness restores larger budgets).
#[derive(Clone, Copy, Debug)]
pub struct TrainLimits {
    pub max_env_steps: u64,
    pub max_episodes: usize,
}

impl Default for TrainLimits {
    fn default() -> Self {
        TrainLimits { max_env_steps: 20_000, max_episodes: 300 }
    }
}

/// Result of one seeded training run.
pub struct TrainResult {
    pub metrics: RunMetrics,
    pub combo: String,
    /// Which execution backend (and precision) produced the run.
    pub backend: String,
    /// Kernel threads the backend computed with (`APDRL_THREADS` /
    /// `--threads`).  Reporting only: the CPU executor's kernels are
    /// bit-exact across thread counts, so two runs differing only here
    /// produce identical rewards and FSM logs (tests/train.rs).
    pub threads: usize,
    /// Env lanes collected in lockstep (`--actors`); 1 is the scalar
    /// path.
    pub actors: usize,
    pub seed: u64,
    /// True when a [`JobOptions::cancel`] flag stopped the run before
    /// its limits — the metrics cover the completed prefix.
    pub cancelled: bool,
}

/// Per-job hooks for [`train_combo_job`] — streaming frame sink,
/// cooperative cancel, checkpoint cadence and resume payload.
/// `Default` is the plain local run: no frames, no checkpoints, never
/// cancelled — bit-identical to the historical loop.
#[derive(Default)]
pub struct JobOptions<'a> {
    /// Job id tagged onto every `train.*` obs event and streamed frame;
    /// non-scheduled runs default to `local/<combo>/<seed>`.
    pub job_id: Option<String>,
    /// Cooperative cancellation/drain flag, checked once per round; when
    /// set the loop stops at the next round boundary and (with a sink
    /// attached) emits a final checkpoint frame for hand-off.
    pub cancel: Option<&'a AtomicBool>,
    /// Env steps between checkpoint frames (0 disables periodic
    /// checkpoints; a final one is still emitted when a sink is
    /// attached and this is non-zero).
    pub checkpoint_every: u64,
    /// Env steps between progress frames (0 disables them).
    pub progress_every: u64,
    /// Streaming sink: called in-loop with JSON frames
    /// (`episode` / `scale` / `progress` / `checkpoint`).
    pub sink: Option<&'a mut dyn FnMut(&Json)>,
    /// Snapshot to resume from (validated against combo/seed/actors).
    pub resume: Option<&'a Checkpoint>,
    /// Precision identity stamped into emitted checkpoints so the
    /// resuming host rebuilds the same routing.
    pub quantized: bool,
}

/// Assemble a [`Checkpoint`] from the live loop state at a round
/// boundary (every float captured by raw bits).
#[allow(clippy::too_many_arguments)]
fn snapshot(
    combo: &ComboConfig,
    seed: u64,
    actors: usize,
    quantized: bool,
    agent: &dyn Agent,
    fleet: &BatchedEnv,
    rng: &Rng,
    metrics: &RunMetrics,
    last_scale: Option<f32>,
    ep_rewards: &[f64],
    wallclock_s: f64,
) -> Result<Checkpoint> {
    let (rng_state, rng_spare) = rng.state_parts();
    let mut m = metrics.clone();
    m.train_steps = agent.train_steps();
    m.wallclock_s = wallclock_s;
    Ok(Checkpoint {
        combo: combo.name.to_string(),
        seed,
        actors,
        quantized,
        metrics: m,
        last_scale,
        ep_rewards: ep_rewards.to_vec(),
        rng_state,
        rng_spare,
        fleet: fleet.save_state(),
        agent: agent.save_state()?,
    })
}

/// Push one frame into the optional sink.
fn emit(sink: &mut Option<&mut dyn FnMut(&Json)>, frame: Json) {
    if let Some(s) = sink {
        s(&frame);
    }
}

/// Render a `train.episode` event as the verbose progress line.  Kept
/// as a view over the event fields (not a parallel format string) so
/// the eprintln output and the dashboard can never drift apart.
fn episode_line(event: &obs::Event, avg25: f64) -> String {
    let f = |key: &str| event.fields.get(key).and_then(Json::as_f64).unwrap_or(0.0);
    format!(
        "lane {} ep {}: reward {:.0}, avg25 {avg25:.1} (steps {})",
        f("lane") as usize,
        f("episode") as usize,
        f("reward"),
        f("env_steps") as usize
    )
}

/// Train `combo` on `backend` for one seed — the scalar (`actors == 1`)
/// entry point kept for existing call sites.
pub fn train_combo(
    backend: &mut dyn Backend,
    combo: &ComboConfig,
    seed: u64,
    limits: TrainLimits,
    verbose: bool,
) -> Result<TrainResult> {
    train_combo_actors(backend, combo, seed, limits, 1, verbose)
}

/// Train `combo` on `backend` for one seed with an `actors`-lane env
/// fleet.  Lane RNG streams fork off the master seed (lane 0 is the
/// scalar path's stream), episode bookkeeping is per lane, and training
/// cadence follows per-lane observation counts inside the agents — so
/// `actors == 1` reproduces the scalar run bit-for-bit while larger
/// fleets amortize inference over one batched forward per round.
pub fn train_combo_actors(
    backend: &mut dyn Backend,
    combo: &ComboConfig,
    seed: u64,
    limits: TrainLimits,
    actors: usize,
    verbose: bool,
) -> Result<TrainResult> {
    train_combo_job(backend, combo, seed, limits, actors, verbose, JobOptions::default())
}

/// [`train_combo_actors`] with job hooks: streaming frames, cooperative
/// cancel, periodic bit-exact checkpoints and checkpoint resume.  With
/// default [`JobOptions`] this *is* `train_combo_actors` — same RNG
/// stream, same rewards, same FSM transitions, same final weights.
pub fn train_combo_job(
    backend: &mut dyn Backend,
    combo: &ComboConfig,
    seed: u64,
    limits: TrainLimits,
    actors: usize,
    verbose: bool,
    mut opts: JobOptions<'_>,
) -> Result<TrainResult> {
    ensure!(actors >= 1, "--actors must be at least 1");
    let t0 = Instant::now();
    let mut agent = backend.make_agent(combo, seed)?;
    if verbose && backend.threads() > 1 {
        eprintln!(
            "  [{} seed {seed}] kernels on {} threads (bit-exact vs 1)",
            combo.name,
            backend.threads()
        );
    }
    let mut rng = Rng::new(seed);
    let envs = (0..actors)
        .map(|_| combo.try_make_env())
        .collect::<Result<Vec<Box<dyn Env>>>>()?;
    let rngs = lane_rngs(&mut rng, 0xE74, actors);
    let mut fleet = BatchedEnv::new(envs, rngs, Pool::global())?;
    ensure!(
        fleet.is_discrete() == combo.algo.discrete_actions(),
        "combo {}: {} emits {} actions but env {:?} has a {} action space",
        combo.name,
        combo.algo.name(),
        if combo.algo.discrete_actions() { "discrete" } else { "continuous" },
        combo.env,
        if fleet.is_discrete() { "discrete" } else { "continuous" }
    );
    let d = fleet.obs_dim();
    let mut metrics = RunMetrics::default();
    let mut last_scale: Option<f32> = None;

    let mut prev_obs = vec![0.0f32; actors * d];
    let mut rew_f32 = vec![0.0f32; actors];
    let mut ep_rewards = vec![0.0f64; actors];
    let mut stats_buf = Vec::new();
    let job = opts.job_id.clone().unwrap_or_else(|| format!("local/{}/{seed}", combo.name));

    // Wall-clock accumulated by earlier segments of a resumed job.
    let mut wallclock_base = 0.0;
    if let Some(ckpt) = opts.resume {
        ensure!(
            ckpt.combo == combo.name,
            "checkpoint is for combo {}, job runs {}",
            ckpt.combo,
            combo.name
        );
        ensure!(
            ckpt.seed == seed && ckpt.actors == actors,
            "checkpoint seed/actors {}/{} disagree with the job's {seed}/{actors}",
            ckpt.seed,
            ckpt.actors
        );
        ensure!(
            ckpt.ep_rewards.len() == actors,
            "checkpoint carries {} lane accumulators for {actors} lanes",
            ckpt.ep_rewards.len()
        );
        agent.restore_state(&ckpt.agent)?;
        fleet.restore_state(&ckpt.fleet)?;
        rng = Rng::from_parts(ckpt.rng_state, ckpt.rng_spare);
        metrics = ckpt.metrics.clone();
        wallclock_base = metrics.wallclock_s;
        metrics.wallclock_s = 0.0;
        last_scale = ckpt.last_scale;
        ep_rewards.copy_from_slice(&ckpt.ep_rewards);
    }

    let cadence_after = |steps: u64, every: u64| {
        if every > 0 {
            (steps / every + 1) * every
        } else {
            u64::MAX
        }
    };
    let mut next_ckpt = cadence_after(metrics.env_steps, opts.checkpoint_every);
    let mut next_progress = cadence_after(metrics.env_steps, opts.progress_every);
    let mut cancelled = false;
    while metrics.env_steps < limits.max_env_steps
        && metrics.episode_rewards.len() < limits.max_episodes
    {
        if opts.cancel.map(|c| c.load(Ordering::Relaxed)).unwrap_or(false) {
            cancelled = true;
            break;
        }
        // Round boundaries are the only legal snapshot points: the
        // agents' act caches are drained and all transition buffers
        // consumed, so the checkpoint closes over complete state.
        if metrics.env_steps >= next_ckpt {
            next_ckpt = cadence_after(metrics.env_steps, opts.checkpoint_every);
            let ckpt = snapshot(
                combo,
                seed,
                actors,
                opts.quantized,
                agent.as_ref(),
                &fleet,
                &rng,
                &metrics,
                last_scale,
                &ep_rewards,
                wallclock_base + t0.elapsed().as_secs_f64(),
            )?;
            emit(
                &mut opts.sink,
                Json::obj(vec![
                    ("frame", Json::Str("checkpoint".into())),
                    ("job", Json::Str(job.clone())),
                    ("env_steps", Json::Num(metrics.env_steps as f64)),
                    ("data", ckpt.to_json()),
                ]),
            );
        }
        if metrics.env_steps >= next_progress {
            next_progress = cadence_after(metrics.env_steps, opts.progress_every);
            emit(
                &mut opts.sink,
                Json::obj(vec![
                    ("frame", Json::Str("progress".into())),
                    ("job", Json::Str(job.clone())),
                    ("env_steps", Json::Num(metrics.env_steps as f64)),
                    ("episodes", Json::Num(metrics.episode_rewards.len() as f64)),
                    ("train_steps", Json::Num(agent.train_steps() as f64)),
                    ("reward_avg25", Json::Num(metrics.converged_reward(25))),
                ]),
            );
        }
        // All of this round's train steps log against the pre-round env
        // step count — at `actors == 1` that is exactly the scalar
        // path's pre-increment recording.
        let step_at = metrics.env_steps;
        let collect_span = obs::trace::span(
            obs::trace::Kernel::Collect,
            [actors, 0, 0],
            Pool::global().threads(),
        );
        prev_obs.copy_from_slice(fleet.obs());
        let actions = agent.act(&prev_obs, actors, &mut rng)?;
        fleet.step(&actions)?;
        for (r, &raw) in rew_f32.iter_mut().zip(fleet.rewards()) {
            *r = raw as f32;
        }
        stats_buf.clear();
        agent.observe(
            &prev_obs,
            &actions,
            &rew_f32,
            fleet.next_obs(),
            fleet.dones(),
            &mut rng,
            &mut stats_buf,
        )?;
        drop(collect_span);
        for stats in &stats_buf {
            metrics.losses.push(stats.loss as f64);
            if stats.found_inf {
                metrics.overflows += 1;
            }
            // Record every loss-scale FSM transition (grow or backoff).
            if let Some(prev) = last_scale {
                if prev != stats.loss_scale {
                    metrics.scale_transitions.push((step_at, prev, stats.loss_scale));
                    if obs::active() {
                        obs::publish(
                            obs::Event::new("train.scale")
                                .tag("combo", combo.name)
                                .tag("job", &job)
                                .num("seed", seed as f64)
                                .num("step", step_at as f64)
                                .num("from", prev as f64)
                                .num("to", stats.loss_scale as f64)
                                .flag("overflow", stats.loss_scale < prev),
                        );
                    }
                    emit(
                        &mut opts.sink,
                        Json::obj(vec![
                            ("frame", Json::Str("scale".into())),
                            ("job", Json::Str(job.clone())),
                            ("step", Json::Num(step_at as f64)),
                            ("from", Json::Num(f64::from(prev))),
                            ("to", Json::Num(f64::from(stats.loss_scale))),
                        ]),
                    );
                }
            }
            last_scale = Some(stats.loss_scale);
            metrics.final_loss_scale = stats.loss_scale;
        }
        for l in 0..actors {
            ep_rewards[l] += fleet.rewards()[l];
            metrics.env_steps += 1;
            if fleet.dones()[l] {
                metrics.episode_rewards.push(ep_rewards[l]);
                let n = metrics.episode_rewards.len();
                // Verbose lines are a *rendering* of the same event the
                // bus carries, so `--actors N` logs name their lane and
                // can never disagree with what a dashboard shows.  The
                // quiet, unobserved path pays one atomic load here.
                if verbose || obs::active() {
                    let event = obs::Event::new("train.episode")
                        .tag("combo", combo.name)
                        .tag("job", &job)
                        .num("seed", seed as f64)
                        .num("lane", l as f64)
                        .num("episode", n as f64)
                        .num("reward", ep_rewards[l])
                        .num("env_steps", metrics.env_steps as f64)
                        .num("actors", actors as f64);
                    if verbose && n % 25 == 0 {
                        eprintln!(
                            "  [{}/{} seed {seed}] {}",
                            combo.name,
                            backend.describe(),
                            episode_line(&event, metrics.converged_reward(25))
                        );
                    }
                    obs::publish(event);
                }
                emit(
                    &mut opts.sink,
                    Json::obj(vec![
                        ("frame", Json::Str("episode".into())),
                        ("job", Json::Str(job.clone())),
                        ("lane", Json::Num(l as f64)),
                        ("episode", Json::Num(n as f64)),
                        ("reward", Json::Num(ep_rewards[l])),
                        ("env_steps", Json::Num(metrics.env_steps as f64)),
                    ]),
                );
                ep_rewards[l] = 0.0;
            }
        }
    }
    metrics.train_steps = agent.train_steps();
    metrics.wallclock_s = wallclock_base + t0.elapsed().as_secs_f64();
    // Final checkpoint frame: a drain (cancel) hands the job off from
    // here; a natural finish leaves a resume-to-extend point.
    if opts.sink.is_some() && opts.checkpoint_every > 0 {
        let ckpt = snapshot(
            combo,
            seed,
            actors,
            opts.quantized,
            agent.as_ref(),
            &fleet,
            &rng,
            &metrics,
            last_scale,
            &ep_rewards,
            metrics.wallclock_s,
        )?;
        emit(
            &mut opts.sink,
            Json::obj(vec![
                ("frame", Json::Str("checkpoint".into())),
                ("job", Json::Str(job.clone())),
                ("env_steps", Json::Num(metrics.env_steps as f64)),
                ("final", Json::Bool(true)),
                ("data", ckpt.to_json()),
            ]),
        );
    }
    if obs::active() {
        obs::publish(
            obs::Event::new("train.done")
                .tag("combo", combo.name)
                .tag("backend", &backend.describe())
                .tag("job", &job)
                .num("seed", seed as f64)
                .num("actors", actors as f64)
                .num("episodes", metrics.episode_rewards.len() as f64)
                .num("env_steps", metrics.env_steps as f64)
                .num("train_steps", metrics.train_steps as f64)
                .num("overflows", metrics.overflows as f64)
                .num("steps_per_sec", metrics.env_steps_per_sec())
                .flag("cancelled", cancelled),
        );
    }
    Ok(TrainResult {
        metrics,
        combo: combo.name.into(),
        backend: backend.describe(),
        threads: backend.threads(),
        actors,
        seed,
        cancelled,
    })
}
