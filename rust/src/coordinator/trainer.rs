//! Dynamic phase (paper Fig 7, right column): the Inference →
//! Environment Step → Train loop, fully in rust, with network compute on
//! an execution [`Backend`] — the pure-Rust CPU executor by default, the
//! PJRT artifacts under the `pjrt` feature — and the hardware-aware
//! quantization FSM live.

use std::time::Instant;

use anyhow::Result;

use crate::exec::Backend;
use crate::util::Rng;

use super::config::ComboConfig;
use super::metrics::RunMetrics;

/// Run-length limits (scaled for this small testbed; `--full` in the
/// figures harness restores larger budgets).
#[derive(Clone, Copy, Debug)]
pub struct TrainLimits {
    pub max_env_steps: u64,
    pub max_episodes: usize,
}

impl Default for TrainLimits {
    fn default() -> Self {
        TrainLimits { max_env_steps: 20_000, max_episodes: 300 }
    }
}

/// Result of one seeded training run.
pub struct TrainResult {
    pub metrics: RunMetrics,
    pub combo: String,
    /// Which execution backend (and precision) produced the run.
    pub backend: String,
    /// Kernel threads the backend computed with (`APDRL_THREADS` /
    /// `--threads`).  Reporting only: the CPU executor's kernels are
    /// bit-exact across thread counts, so two runs differing only here
    /// produce identical rewards and FSM logs (tests/train.rs).
    pub threads: usize,
    pub seed: u64,
}

/// Train `combo` on `backend` for one seed.
pub fn train_combo(
    backend: &mut dyn Backend,
    combo: &ComboConfig,
    seed: u64,
    limits: TrainLimits,
    verbose: bool,
) -> Result<TrainResult> {
    let t0 = Instant::now();
    let mut agent = backend.make_agent(combo, seed)?;
    if verbose && backend.threads() > 1 {
        eprintln!(
            "  [{} seed {seed}] kernels on {} threads (bit-exact vs 1)",
            combo.name,
            backend.threads()
        );
    }
    let mut env = combo.try_make_env()?;
    let mut rng = Rng::new(seed);
    let mut env_rng = rng.fork(0xE74);
    let mut metrics = RunMetrics::default();
    let mut last_scale: Option<f32> = None;

    let mut obs = env.reset(&mut env_rng);
    let mut ep_reward = 0.0f64;
    while metrics.env_steps < limits.max_env_steps
        && metrics.episode_rewards.len() < limits.max_episodes
    {
        let action = agent.act(&obs, &mut rng)?;
        let tr = env.step(&action, &mut env_rng);
        if let Some(stats) =
            agent.observe(&obs, &action, tr.reward as f32, &tr.obs, tr.done, &mut rng)?
        {
            metrics.losses.push(stats.loss as f64);
            if stats.found_inf {
                metrics.overflows += 1;
            }
            // Record every loss-scale FSM transition (grow or backoff).
            if let Some(prev) = last_scale {
                if prev != stats.loss_scale {
                    metrics.scale_transitions.push((metrics.env_steps, prev, stats.loss_scale));
                }
            }
            last_scale = Some(stats.loss_scale);
            metrics.final_loss_scale = stats.loss_scale;
        }
        ep_reward += tr.reward;
        metrics.env_steps += 1;
        if tr.done {
            metrics.episode_rewards.push(ep_reward);
            if verbose && metrics.episode_rewards.len() % 25 == 0 {
                let n = metrics.episode_rewards.len();
                let recent = metrics.converged_reward(25);
                eprintln!(
                    "  [{}/{} seed {seed}] ep {n}: avg25 {recent:.1} (steps {})",
                    combo.name,
                    backend.describe(),
                    metrics.env_steps
                );
            }
            ep_reward = 0.0;
            obs = env.reset(&mut env_rng);
        } else {
            obs = tr.obs;
        }
    }
    metrics.train_steps = agent.train_steps();
    metrics.wallclock_s = t0.elapsed().as_secs_f64();
    Ok(TrainResult {
        metrics,
        combo: combo.name.into(),
        backend: backend.describe(),
        threads: backend.threads(),
        seed,
    })
}
