//! TAPCA-substitute (paper [13]): select the PS–PL shared-memory
//! interface for the Inference → Experience Buffer → Sampled Training
//! Data → Updated Model pipeline (paper Fig 7/10).
//!
//! The real TAPCA explores cache-coherency configurations on the
//! CPU–FPGA SoC; the table below models the four architectures its paper
//! compares, with the qualitative ordering: coherent paths cut latency
//! for small, frequent transfers; the non-coherent OCM path has the
//! highest streaming bandwidth for bulk transfers.

use crate::Micros;

/// PS–PL shared-memory architectures TAPCA selects among.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PsPlInterface {
    /// Non-coherent OCM + DMA bursts.
    OcmDma,
    /// IO-coherent via the last-level cache.
    LlcCoherent,
    /// IO-coherent snooping into PS L1.
    L1Coherent,
    /// Full coherency with a PL-side cache.
    PlCacheFull,
}

impl PsPlInterface {
    pub const ALL: [PsPlInterface; 4] = [
        PsPlInterface::OcmDma,
        PsPlInterface::LlcCoherent,
        PsPlInterface::L1Coherent,
        PsPlInterface::PlCacheFull,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PsPlInterface::OcmDma => "OCM+DMA",
            PsPlInterface::LlcCoherent => "LLC-coherent",
            PsPlInterface::L1Coherent => "L1-coherent",
            PsPlInterface::PlCacheFull => "PL-cache full coherency",
        }
    }

    /// (per-transfer latency µs, bandwidth GB/s).
    pub fn profile(self) -> (Micros, f64) {
        match self {
            PsPlInterface::OcmDma => (3.0, 3.8),      // DMA setup heavy, best BW
            PsPlInterface::LlcCoherent => (1.2, 3.2), // coherent, some snoop cost
            PsPlInterface::L1Coherent => (0.6, 1.8),  // lowest latency, narrow
            PsPlInterface::PlCacheFull => (0.9, 2.8), // PL cache hit path
        }
    }

    /// Time to move `transfers` messages of `bytes` each.
    pub fn time(self, bytes: f64, transfers: f64) -> Micros {
        let (lat, gbps) = self.profile();
        transfers * (lat + bytes / (gbps * 1e9) * 1e6)
    }
}

/// The DRL PS–PL traffic pattern TAPCA optimizes (paper Fig 10): per
/// timestep, inference I/O (small, frequent) + sampled batch (bulk) +
/// updated model writeback (bulk).
#[derive(Clone, Copy, Debug)]
pub struct DrlTraffic {
    /// Bytes per inference exchange (state down + action up).
    pub infer_bytes: f64,
    /// Inference exchanges per training step.
    pub infer_transfers: f64,
    /// Bytes of one sampled training batch.
    pub batch_bytes: f64,
    /// Bytes of the updated-model sync back to the PS master copy.
    pub model_bytes: f64,
}

/// Pick the interface minimizing total per-step PS–PL time.
pub fn select_interface(t: &DrlTraffic) -> (PsPlInterface, Micros) {
    PsPlInterface::ALL
        .iter()
        .map(|&i| {
            let cost = i.time(t.infer_bytes, t.infer_transfers)
                + i.time(t.batch_bytes, 1.0)
                + i.time(t.model_bytes, 1.0);
            (i, cost)
        })
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_frequent_prefers_low_latency() {
        let t = DrlTraffic {
            infer_bytes: 64.0,
            infer_transfers: 64.0,
            batch_bytes: 1024.0,
            model_bytes: 1024.0,
        };
        let (iface, _) = select_interface(&t);
        assert_eq!(iface, PsPlInterface::L1Coherent);
    }

    #[test]
    fn bulk_prefers_bandwidth() {
        let t = DrlTraffic {
            infer_bytes: 64.0,
            infer_transfers: 1.0,
            batch_bytes: 64e6,
            model_bytes: 16e6,
        };
        let (iface, _) = select_interface(&t);
        assert_eq!(iface, PsPlInterface::OcmDma);
    }

    #[test]
    fn time_additive_in_transfers() {
        let i = PsPlInterface::LlcCoherent;
        assert!((i.time(100.0, 4.0) - 4.0 * i.time(100.0, 1.0)).abs() < 1e-9);
    }

    #[test]
    fn all_interfaces_distinct_profiles() {
        let profs: Vec<_> = PsPlInterface::ALL.iter().map(|i| i.profile()).collect();
        for a in 0..profs.len() {
            for b in a + 1..profs.len() {
                assert_ne!(profs[a], profs[b]);
            }
        }
    }
}
