//! DSE-based performance profiling (paper §IV-B).
//!
//! The paper drives two external DSE frameworks — COMBA for the PL,
//! CHARM for the AIE — plus TAPCA for PS–PL shared-memory selection.
//! These are substituted by analytic models exposing the same design
//! spaces (Table I pragmas for the PL; tile allocation for the AIE;
//! interface selection for TAPCA) over the `hw` component models.

pub mod aie_model;
pub mod calib;
pub mod dse;
pub mod pl_model;
pub mod ps_model;
pub mod profiler;
pub mod tapca;

pub use calib::{CalibPoint, CalibrationTable, ENV_CALIB};
pub use dse::{pareto, DesignPoint};
pub use profiler::{profile_dag, Candidate, NodeProfile};
