//! CHARM-substitute: analytic model for MM nodes on the AIE-ML array.
//!
//! CHARM's design space is the tile allocation + the PL-side data movers;
//! the model exposes tile count as the knob and charges the (large)
//! kernel-launch/graph-initialization overhead the paper's Fig 6
//! identifies as the low-FLOPs bottleneck.  BF16 support added per paper
//! §IV-B ("We add the BF16 support in CHARM").

use crate::graph::layer::LayerKind;
use crate::hw::{ComponentSpec, Format};
use crate::Micros;

/// One AIE mapping: how many AIE-ML tiles the node occupies.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct AieConfig {
    pub tiles: usize,
    /// MAC lanes each tile sustains for this mapping.
    pub lanes_per_tile: usize,
}

impl AieConfig {
    pub fn lanes(&self) -> usize {
        (self.tiles * self.lanes_per_tile).max(1)
    }

    /// Latency of an MM or weight-update (elementwise) node on the
    /// allocated tiles.  Activation non-MM nodes are not AIE candidates
    /// (paper §IV-A pins them to the PL), but AIE-resident layers update
    /// their weights *on the AIE in BF16* (paper Alg. 1), so elementwise
    /// shapes are supported via the vector datapath.
    pub fn latency(&self, spec: &ComponentSpec, kind: &LayerKind, fmt: Format) -> Micros {
        if let LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } = *kind {
            let usable = (self.lanes() as f64).min(elems as f64);
            let rate = usable * spec.clock_mhz * 1e6 * spec.efficiency * spec.format_mult(fmt);
            let t_compute = elems as f64 / rate * 1e6;
            let bytes = kind.bytes(fmt.bytes());
            let frac = (self.tiles as f64 / 304.0).min(1.0);
            let bw = spec.mem_gbps * (0.25 + 0.75 * frac);
            let t_mem = bytes / (bw * 1e9) * 1e6;
            return spec.init_us + t_compute.max(t_mem);
        }
        let LayerKind::Mm { m, k, n } = *kind else { unreachable!() };
        let macs = m as f64 * k as f64 * n as f64;
        // Output-stationary spatial mapping: usable lanes bounded by the
        // output tile parallelism, like the PL model.
        let usable = (self.lanes() as f64).min((m * n) as f64);
        let rate = usable * spec.clock_mhz * 1e6 * spec.efficiency * spec.format_mult(fmt);
        let t_compute = macs / rate * 1e6;
        // PLIO bandwidth grows with interface share until the array-wide
        // aggregate saturates.
        let frac = (self.tiles as f64 / 304.0).min(1.0);
        let bw = spec.mem_gbps * (0.25 + 0.75 * frac);
        let bytes = kind.bytes(fmt.bytes());
        let t_mem = bytes / (bw * 1e9) * 1e6;
        // AIE graphs always stream (double-buffered tile memory):
        // compute/memory overlap, plus the big launch overhead.
        spec.init_us + t_compute.max(t_mem)
    }
}

/// Tile-allocation candidates CHARM would sweep for one node.
pub fn tile_candidates(max_tiles: usize) -> Vec<usize> {
    let mut v = Vec::new();
    let mut t = 4;
    while t <= max_tiles {
        v.push(t);
        t *= 2;
    }
    if v.last() != Some(&max_tiles) && max_tiles >= 4 {
        v.push(max_tiles);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{vek280, Component};

    fn spec() -> ComponentSpec {
        vek280().spec(Component::AIE).clone()
    }

    #[test]
    fn more_tiles_faster_on_big_gemm() {
        let kind = LayerKind::Mm { m: 1024, k: 1024, n: 1024 };
        let small = AieConfig { tiles: 8, lanes_per_tile: 8 };
        let big = AieConfig { tiles: 128, lanes_per_tile: 8 };
        assert!(big.latency(&spec(), &kind, Format::Bf16) < small.latency(&spec(), &kind, Format::Bf16));
    }

    #[test]
    fn init_dominates_small_gemm() {
        let kind = LayerKind::Mm { m: 16, k: 16, n: 16 };
        let cfg = AieConfig { tiles: 32, lanes_per_tile: 8 };
        let t = cfg.latency(&spec(), &kind, Format::Bf16);
        let s = spec();
        assert!(t < s.init_us * 1.1, "init should dominate: {t} vs {}", s.init_us);
        assert!(t >= s.init_us);
    }

    #[test]
    fn bf16_beats_fp32_on_aie() {
        let kind = LayerKind::Mm { m: 2048, k: 2048, n: 2048 };
        let cfg = AieConfig { tiles: 164, lanes_per_tile: 8 };
        let bf = cfg.latency(&spec(), &kind, Format::Bf16);
        let fp = cfg.latency(&spec(), &kind, Format::Fp32);
        // Table IV: 2175.12/729.91 ≈ 2.98× for the (4096,3072) net.
        let ratio = fp / bf;
        assert!((2.0..4.5).contains(&ratio), "fp32/bf16 ratio {ratio}");
    }

    #[test]
    fn elementwise_supported_for_updates() {
        // AIE-resident layers update weights on the AIE (paper Alg. 1).
        let cfg = AieConfig { tiles: 8, lanes_per_tile: 8 };
        let t = cfg.latency(&spec(), &LayerKind::Elementwise { elems: 100_000 }, Format::Bf16);
        assert!(t > spec().init_us);
    }

    #[test]
    fn tile_candidates_cover_range() {
        let c = tile_candidates(304);
        assert_eq!(c.first(), Some(&4));
        assert_eq!(c.last(), Some(&304));
        assert!(c.windows(2).all(|w| w[0] < w[1]));
        assert!(tile_candidates(3).is_empty());
    }
}
