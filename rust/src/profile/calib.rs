//! The self-calibrating cost model: a persisted table of *measured*
//! kernel timings (from [`obs::trace`](crate::obs::trace)) that
//! [`ps_latency`](super::ps_model::ps_latency) consults before falling
//! back to the analytic PS model, so solved plans optimize real — not
//! modeled — makespan on the machine that will execute them.
//!
//! The table is keyed kernel × shape × thread count: per
//! `(kernel, threads)` it holds calibration points `(work, ns)` — one
//! per log2 work bucket the trace aggregate observed — sorted by work.
//! Lookups interpolate linearly between bracketing points and scale
//! proportionally just past the measured range; a shape more than one
//! bucket outside the measured range is *not covered* and the caller
//! falls back to the analytic model (cold start).
//!
//! Persistence mirrors `partition::cache`: a schema-versioned JSON
//! object under the path named by [`ENV_CALIB`], floats stored as
//! raw-bit hex so a round trip is bit-exact, and a wrong-schema file
//! dropped wholesale (never misparsed) back to cold start. The global
//! accessor re-reads `APDRL_CALIB` per call site and reloads when the
//! value changes, so tests (and long-lived daemons) can swap tables
//! without restarting the process.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, Mutex, OnceLock};

use anyhow::{anyhow, Result};

use crate::graph::LayerKind;
use crate::obs::trace::{AggRow, Kernel};
use crate::util::json::{hex_f64s, parse_hex_f64s, Json};
use crate::Micros;

/// Path of the persisted calibration table; unset means cold start
/// (pure analytic model).
pub const ENV_CALIB: &str = "APDRL_CALIB";

/// File format version. Bumped whenever the serialized layout or the
/// meaning of a point changes; readers drop other-schema files
/// wholesale rather than risk misparsing them.
pub const SCHEMA_VERSION: f64 = 1.0;

/// A shape more than this factor outside the measured work range is
/// not covered — the analytic model prices it instead.
const COVERAGE_MARGIN: f64 = 2.0;

/// One measured point: `count` samples with mean work `work` took a
/// mean of `ns` nanoseconds per call.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CalibPoint {
    pub work: f64,
    pub ns: f64,
    pub count: u64,
}

/// Measured kernel costs keyed `(kernel name, threads)`, each holding
/// its calibration points sorted by ascending work.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CalibrationTable {
    entries: BTreeMap<(String, usize), Vec<CalibPoint>>,
}

impl CalibrationTable {
    pub fn new() -> CalibrationTable {
        CalibrationTable::default()
    }

    /// Build a table from a drained trace aggregate: one point per
    /// (kernel, threads, bucket) cell.
    pub fn from_rows(rows: &[AggRow]) -> CalibrationTable {
        let mut table = CalibrationTable::new();
        for row in rows {
            table.insert_point(
                row.kernel.name(),
                row.threads,
                CalibPoint { work: row.mean_work, ns: row.mean_ns, count: row.count },
            );
        }
        table
    }

    /// Insert one point, keeping the entry sorted by work.
    pub fn insert_point(&mut self, kernel: &str, threads: usize, point: CalibPoint) {
        let points = self.entries.entry((kernel.to_string(), threads)).or_default();
        let at = points.partition_point(|p| p.work < point.work);
        points.insert(at, point);
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Number of `(kernel, threads)` entries.
    pub fn entries(&self) -> usize {
        self.entries.len()
    }

    /// Total calibration points across all entries.
    pub fn points(&self) -> usize {
        self.entries.values().map(Vec::len).sum()
    }

    /// Iterate `(kernel name, threads, points)` in key order.
    pub fn iter(&self) -> impl Iterator<Item = (&str, usize, &[CalibPoint])> {
        self.entries.iter().map(|((k, t), v)| (k.as_str(), *t, v.as_slice()))
    }

    /// Measured cost in microseconds for `work` units of `kernel` at
    /// `threads`, or `None` when no entry covers the shape. Threads
    /// fall back to the nearest measured width for the kernel (the
    /// pool the table was calibrated on rarely matches exactly).
    pub fn lookup_us(&self, kernel: Kernel, threads: usize, work: f64) -> Option<Micros> {
        let name = kernel.name();
        let points = self
            .entries
            .iter()
            .filter(|((k, _), _)| k == name)
            .min_by_key(|((_, t), _)| (t.abs_diff(threads), *t))
            .map(|(_, points)| points)?;
        let first = points.first()?;
        let last = points.last()?;
        if work < first.work / COVERAGE_MARGIN || work > last.work * COVERAGE_MARGIN {
            return None;
        }
        let ns = if work <= first.work {
            // Just below the measured range: scale proportionally.
            first.ns * work / first.work.max(1.0)
        } else if work >= last.work {
            last.ns * work / last.work.max(1.0)
        } else {
            let hi = points.partition_point(|p| p.work <= work);
            let (a, b) = (points[hi - 1], points[hi]);
            let t = (work - a.work) / (b.work - a.work);
            a.ns + t * (b.ns - a.ns)
        };
        Some(ns / 1000.0)
    }

    /// Stable identity of the measurements: FNV-1a over every key and
    /// the raw bits of every point. Folded into plan-cache keys so
    /// calibrated and uncalibrated plans never collide.
    pub fn fingerprint(&self) -> String {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |bytes: &[u8]| {
            for &b in bytes {
                h ^= b as u64;
                h = h.wrapping_mul(0x100_0000_01b3);
            }
        };
        for ((kernel, threads), points) in &self.entries {
            eat(kernel.as_bytes());
            eat(&(*threads as u64).to_le_bytes());
            for p in points {
                eat(&p.work.to_bits().to_le_bytes());
                eat(&p.ns.to_bits().to_le_bytes());
                eat(&p.count.to_le_bytes());
            }
        }
        format!("{h:016x}")
    }

    /// Serialize: schema header plus one object per `(kernel, threads)`
    /// entry, floats as raw-bit hex (see `persistence_round_trips` in
    /// `tests/calib.rs`).
    pub fn to_json(&self) -> Json {
        let entries: Vec<Json> = self
            .entries
            .iter()
            .map(|((kernel, threads), points)| {
                let work: Vec<f64> = points.iter().map(|p| p.work).collect();
                let ns: Vec<f64> = points.iter().map(|p| p.ns).collect();
                let count: Vec<Json> =
                    points.iter().map(|p| Json::Num(p.count as f64)).collect();
                Json::obj(vec![
                    ("kernel", Json::Str(kernel.clone())),
                    ("threads", Json::Num(*threads as f64)),
                    ("work", Json::Str(hex_f64s(&work))),
                    ("ns", Json::Str(hex_f64s(&ns))),
                    ("count", Json::Arr(count)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::Num(SCHEMA_VERSION)),
            ("entries", Json::Arr(entries)),
        ])
    }

    /// Parse a persisted table. `None` when the schema does not match
    /// (stale files drop to cold start, like the plan cache); within a
    /// current-schema file, malformed entries are skipped.
    pub fn from_json(root: &Json) -> Option<CalibrationTable> {
        if root.get("schema").and_then(Json::as_f64) != Some(SCHEMA_VERSION) {
            return None;
        }
        let mut table = CalibrationTable::new();
        for entry in root.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let Some(kernel) = entry.get("kernel").and_then(Json::as_str) else { continue };
            let Some(threads) = entry.get("threads").and_then(Json::as_usize) else { continue };
            let work = entry
                .get("work")
                .and_then(Json::as_str)
                .and_then(|s| parse_hex_f64s(s).ok());
            let ns = entry
                .get("ns")
                .and_then(Json::as_str)
                .and_then(|s| parse_hex_f64s(s).ok());
            let (Some(work), Some(ns)) = (work, ns) else { continue };
            let counts = entry.get("count").and_then(Json::as_arr).unwrap_or(&[]);
            if work.len() != ns.len() {
                continue;
            }
            for (i, (&w, &t)) in work.iter().zip(&ns).enumerate() {
                let count = counts.get(i).and_then(Json::as_f64).unwrap_or(1.0) as u64;
                table.insert_point(kernel, threads, CalibPoint { work: w, ns: t, count });
            }
        }
        Some(table)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        let line = self.to_json().to_line().map_err(|e| anyhow!("{e}"))?;
        // Temp-sibling + rename (util::fsio): a crash mid-save must not
        // tear the live table — a torn file would silently revert the
        // planner to the analytic model.
        crate::util::fsio::atomic_write(path, (line + "\n").as_bytes())
            .map_err(|e| anyhow!("writing calibration table {}: {e}", path.display()))
    }

    /// Best-effort load: any failure (missing file, parse error, stale
    /// schema) is a cold start, never an error.  A file that *exists*
    /// but cannot be used is surfaced — stderr warning plus a
    /// `calib.dropped` obs event — because dropping it silently reverts
    /// the planner to the analytic model with no signal.
    pub fn load(path: &Path) -> Option<CalibrationTable> {
        let text = std::fs::read_to_string(path).ok()?;
        let table = Json::parse(&text).ok().and_then(|j| CalibrationTable::from_json(&j));
        if table.is_none() {
            eprintln!(
                "warning: calibration table {} is corrupt or from another schema; \
                 falling back to the analytic model",
                path.display()
            );
            crate::obs::publish(
                crate::obs::Event::new("calib.dropped")
                    .tag("path", &path.display().to_string()),
            );
        }
        table
    }
}

struct GlobalCalib {
    /// The `APDRL_CALIB` value the cached table was loaded from.
    source: Option<String>,
    table: Option<Arc<CalibrationTable>>,
}

fn global() -> &'static Mutex<GlobalCalib> {
    static GLOBAL: OnceLock<Mutex<GlobalCalib>> = OnceLock::new();
    GLOBAL.get_or_init(|| Mutex::new(GlobalCalib { source: None, table: None }))
}

/// Run `f` against the process-wide calibration table (or `None` when
/// `APDRL_CALIB` is unset / unloadable). The env value is re-checked
/// on every call — lookups only happen on the cold profiling path, and
/// it makes with-vs-without-calibration behavior testable in-process.
pub fn with_global<R>(f: impl FnOnce(Option<&CalibrationTable>) -> R) -> R {
    let env = std::env::var(ENV_CALIB).ok().filter(|p| !p.is_empty());
    let table = {
        let mut g = global().lock().unwrap();
        if env != g.source {
            let loaded = env.as_deref().and_then(|p| CalibrationTable::load(Path::new(p)));
            g.table = loaded.map(Arc::new);
            g.source = env;
        }
        g.table.clone()
    };
    f(table.as_deref())
}

/// Fingerprint of the active table, or `None` on cold start. Folded
/// into `PlanKey` so calibrated plans key apart in the plan cache.
pub fn active_fingerprint() -> Option<String> {
    with_global(|t| t.map(CalibrationTable::fingerprint))
}

/// Measured PS-side cost for one graph node, when the active table
/// covers its shape: `Mm` prices as a `gemm_nn` of `m·k·n` MACs,
/// elementwise/reduce nodes as a per-element CPU touch (the
/// `round_slice` entry is the measured proxy for streaming `elems`
/// floats through the core).
pub fn measured_ps_latency(kind: &LayerKind) -> Option<Micros> {
    let threads = crate::exec::pool::Pool::global().threads();
    let (kernel, work, threads) = match *kind {
        LayerKind::Mm { m, k, n } => (Kernel::GemmNn, (m * k * n) as f64, threads),
        LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } => {
            (Kernel::RoundSlice, elems as f64, 1)
        }
    };
    with_global(|t| t.and_then(|t| t.lookup_us(kernel, threads, work)))
}

/// Wire/stats provenance: is a table active, where from, its
/// fingerprint and size. Rides the `profile` and `stats` verbs.
pub fn provenance_json() -> Json {
    let source = std::env::var(ENV_CALIB).ok().filter(|p| !p.is_empty());
    with_global(|t| match t {
        Some(t) => Json::obj(vec![
            ("present", Json::Bool(true)),
            ("source", Json::Str(source.unwrap_or_default())),
            ("fingerprint", Json::Str(t.fingerprint())),
            ("entries", Json::Num(t.entries() as f64)),
            ("points", Json::Num(t.points() as f64)),
        ]),
        None => Json::obj(vec![("present", Json::Bool(false))]),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_point_table() -> CalibrationTable {
        let mut t = CalibrationTable::new();
        t.insert_point("gemm_nn", 4, CalibPoint { work: 1000.0, ns: 2000.0, count: 10 });
        t.insert_point("gemm_nn", 4, CalibPoint { work: 9000.0, ns: 10_000.0, count: 10 });
        t
    }

    #[test]
    fn lookup_interpolates_between_points() {
        let t = two_point_table();
        // Midpoint of work → midpoint of ns: 5000 work → 6000 ns = 6 µs.
        let us = t.lookup_us(Kernel::GemmNn, 4, 5000.0).unwrap();
        assert!((us - 6.0).abs() < 1e-9, "{us}");
        // Exact endpoints.
        assert!((t.lookup_us(Kernel::GemmNn, 4, 1000.0).unwrap() - 2.0).abs() < 1e-9);
        assert!((t.lookup_us(Kernel::GemmNn, 4, 9000.0).unwrap() - 10.0).abs() < 1e-9);
    }

    #[test]
    fn lookup_scales_at_the_margins_and_refuses_beyond() {
        let t = two_point_table();
        // Half the smallest point is still covered, proportionally.
        let us = t.lookup_us(Kernel::GemmNn, 4, 500.0).unwrap();
        assert!((us - 1.0).abs() < 1e-9, "{us}");
        // Twice the largest point likewise.
        let us = t.lookup_us(Kernel::GemmNn, 4, 18_000.0).unwrap();
        assert!((us - 20.0).abs() < 1e-9, "{us}");
        // Beyond the margin: not covered → analytic fallback.
        assert!(t.lookup_us(Kernel::GemmNn, 4, 400.0).is_none());
        assert!(t.lookup_us(Kernel::GemmNn, 4, 50_000.0).is_none());
        // Unmeasured kernel: never covered.
        assert!(t.lookup_us(Kernel::Im2col, 4, 5000.0).is_none());
    }

    #[test]
    fn lookup_falls_back_to_nearest_thread_width() {
        let t = two_point_table(); // only threads=4 measured
        assert!(t.lookup_us(Kernel::GemmNn, 1, 5000.0).is_some());
        assert!(t.lookup_us(Kernel::GemmNn, 64, 5000.0).is_some());
    }

    #[test]
    fn fingerprint_tracks_content() {
        let a = two_point_table();
        let mut b = two_point_table();
        assert_eq!(a.fingerprint(), b.fingerprint());
        b.insert_point("adam_step", 1, CalibPoint { work: 8.0, ns: 9.0, count: 1 });
        assert_ne!(a.fingerprint(), b.fingerprint());
    }

    #[test]
    fn json_round_trip_is_bit_exact() {
        let mut t = two_point_table();
        // Deliberately awkward bits: subnormal-ish and non-representable
        // decimals survive only via the hex path.
        t.insert_point(
            "round_slice",
            1,
            CalibPoint { work: 0.1 + 0.2, ns: f64::from_bits(0x0000_0000_0000_0001), count: 3 },
        );
        let back = CalibrationTable::from_json(&t.to_json()).expect("same schema");
        assert_eq!(back, t);
        for ((k, th), points) in &t.entries {
            let b = &back.entries[&(k.clone(), *th)];
            for (p, q) in points.iter().zip(b) {
                assert_eq!(p.work.to_bits(), q.work.to_bits());
                assert_eq!(p.ns.to_bits(), q.ns.to_bits());
            }
        }
    }

    #[test]
    fn stale_schema_is_a_cold_start() {
        let json = Json::parse("{\"schema\":0.5,\"entries\":[]}").unwrap();
        assert!(CalibrationTable::from_json(&json).is_none());
        let json = Json::parse("{\"entries\":[]}").unwrap();
        assert!(CalibrationTable::from_json(&json).is_none());
    }
}
