//! PS (Cortex-A72) execution model: the software baseline of Fig 4/5 and
//! the component running env step / buffer / coordination in AP-DRL.

use crate::graph::layer::LayerKind;
use crate::hw::{ComponentSpec, Format};
use crate::Micros;

/// Per-node framework overhead on the PS (loop dispatch, cache warmup).
const PS_NODE_OVERHEAD_US: Micros = 0.8;

/// Latency of any node on the PS. When the process has a calibration
/// table (`APDRL_CALIB`, see [`super::calib`]) whose measurements
/// cover the shape, the *measured* cost is returned and the analytic
/// model below is only the cold-start fallback — this is the single
/// seam through which the planner starts optimizing real makespan.
pub fn ps_latency(spec: &ComponentSpec, kind: &LayerKind, fmt: Format) -> Micros {
    if let Some(us) = super::calib::measured_ps_latency(kind) {
        return us;
    }
    ps_latency_analytic(spec, kind, fmt)
}

/// The pure analytic PS model (paper Fig 4/5's software row), never
/// consulting calibration — the profiler prices both so plans can
/// report modeled-vs-measured error.
pub fn ps_latency_analytic(spec: &ComponentSpec, kind: &LayerKind, fmt: Format) -> Micros {
    match *kind {
        LayerKind::Mm { .. } => {
            let bytes = kind.bytes(fmt.bytes());
            PS_NODE_OVERHEAD_US
                + spec.gemm_time(kind.flops(), bytes, spec.max_mac_lanes, fmt, false)
        }
        LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } => {
            PS_NODE_OVERHEAD_US + spec.elementwise_time(elems as f64, fmt)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{vek280, Component};

    #[test]
    fn gemm_scales_with_flops() {
        let spec = vek280().spec(Component::PS).clone();
        let t1 = ps_latency(&spec, &LayerKind::Mm { m: 64, k: 64, n: 64 }, Format::Fp32);
        let t2 = ps_latency(&spec, &LayerKind::Mm { m: 256, k: 256, n: 256 }, Format::Fp32);
        assert!(t2 > 10.0 * t1);
    }

    #[test]
    fn overhead_floor() {
        let spec = vek280().spec(Component::PS).clone();
        let t = ps_latency(&spec, &LayerKind::Elementwise { elems: 1 }, Format::Fp32);
        assert!(t >= PS_NODE_OVERHEAD_US);
    }
}
