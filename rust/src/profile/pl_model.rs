//! COMBA-substitute: analytic HLS latency/resource model for MM and
//! elementwise nodes on the PL, configured by the Table I pragmas.

use crate::graph::layer::LayerKind;
use crate::hw::{ComponentSpec, Format};
use crate::Micros;

/// One HLS pragma configuration (paper Table I).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PlConfig {
    /// Dataflow: overlap memory streaming with compute.
    pub dataflow: bool,
    /// Function pipeline: overlaps successive kernel invocations,
    /// amortizing part of the launch overhead.
    pub func_pipeline: bool,
    /// Loop pipeline: II=1 inner loop vs full body latency per iteration.
    pub loop_pipeline: bool,
    /// Loop unroll factor (MAC lanes requested).
    pub unroll: usize,
    /// Array partition factor (memory banks feeding the lanes).
    pub array_partition: usize,
}

/// Memory ports per partitioned bank (dual-port BRAM).
const PORTS_PER_BANK: usize = 2;
/// Loop body latency when not pipelined (add+mul+load/store chain).
const BODY_LATENCY: f64 = 6.0;
/// Pipeline fill depth (cycles) for a pipelined MM kernel.
const PIPE_DEPTH: f64 = 24.0;

/// Resource usage of a config for a given format.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlResources {
    pub dsp: usize,
    pub kluts: f64,
    pub bram_mb: f64,
}

impl PlConfig {
    /// Effective MAC lanes: unroll bounded by what the partitioned
    /// memory can feed (COMBA's port-constraint analysis).
    pub fn effective_lanes(&self) -> usize {
        self.unroll.min(self.array_partition * PORTS_PER_BANK).max(1)
    }

    /// Estimated latency for `kind` on the PL in `fmt`.
    pub fn latency(&self, spec: &ComponentSpec, kind: &LayerKind, fmt: Format) -> Micros {
        let lanes = self.effective_lanes();
        let init = spec.init_us * if self.func_pipeline { 0.4 } else { 1.0 };
        match *kind {
            LayerKind::Mm { m, k, n } => {
                let macs = m as f64 * k as f64 * n as f64;
                let ii = if self.loop_pipeline { 1.0 } else { BODY_LATENCY };
                // Output-stationary parallelism: can't use more lanes
                // than output elements being produced concurrently.
                let usable = (lanes as f64).min((m * n) as f64);
                let cycles = macs * ii / (usable * spec.format_mult(fmt))
                    / spec.efficiency
                    + PIPE_DEPTH
                    + k as f64;
                let t_compute = cycles / (spec.clock_mhz * 1e6) * 1e6;
                let bytes = kind.bytes(fmt.bytes());
                let t_mem = bytes / (spec.mem_gbps * 1e9) * 1e6;
                init + if self.dataflow { t_compute.max(t_mem) } else { t_compute + t_mem }
            }
            LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } => {
                let ii = if self.loop_pipeline { 1.0 } else { BODY_LATENCY };
                let usable = (lanes as f64).min(elems as f64);
                let cycles = elems as f64 * ii / usable / spec.efficiency + PIPE_DEPTH;
                let t_compute = cycles / (spec.clock_mhz * 1e6) * 1e6;
                let bytes = kind.bytes(fmt.bytes());
                let t_mem = bytes / (spec.mem_gbps * 1e9) * 1e6;
                init + if self.dataflow { t_compute.max(t_mem) } else { t_compute + t_mem }
            }
        }
    }

    /// Resource estimate (COMBA's resource model, simplified): DSPs scale
    /// with lanes (×2 for fp32 MACs), LUT with lanes + control, BRAM with
    /// partition banks.
    pub fn resources(&self, fmt: Format) -> PlResources {
        let lanes = self.effective_lanes();
        let dsp_per_lane = if fmt == Format::Fp32 { 2 } else { 1 };
        PlResources {
            dsp: lanes * dsp_per_lane,
            // ~120 LUTs of control/steering per MAC lane (the DSP slice
            // does the arithmetic) + kernel scaffolding.
            kluts: 1.5 + 0.12 * lanes as f64 + if self.dataflow { 2.0 } else { 0.0 },
            bram_mb: 0.05 + 0.03 * self.array_partition as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{vek280, Component};

    fn mm() -> LayerKind {
        LayerKind::Mm { m: 256, k: 256, n: 256 }
    }

    fn base() -> PlConfig {
        PlConfig {
            dataflow: false,
            func_pipeline: false,
            loop_pipeline: true,
            unroll: 64,
            array_partition: 32,
        }
    }

    #[test]
    fn loop_pipeline_helps() {
        let spec = vek280().spec(Component::PL).clone();
        let lp = base();
        let nolp = PlConfig { loop_pipeline: false, ..base() };
        assert!(lp.latency(&spec, &mm(), Format::Fp16) < nolp.latency(&spec, &mm(), Format::Fp16));
    }

    #[test]
    fn unroll_bounded_by_partition_ports() {
        let c = PlConfig { unroll: 512, array_partition: 4, ..base() };
        assert_eq!(c.effective_lanes(), 8);
    }

    #[test]
    fn more_unroll_faster_but_costlier() {
        let spec = vek280().spec(Component::PL).clone();
        let small = PlConfig { unroll: 8, array_partition: 8, ..base() };
        let big = PlConfig { unroll: 256, array_partition: 128, ..base() };
        assert!(big.latency(&spec, &mm(), Format::Fp16) < small.latency(&spec, &mm(), Format::Fp16));
        assert!(big.resources(Format::Fp16).dsp > small.resources(Format::Fp16).dsp);
    }

    #[test]
    fn fp32_doubles_dsp() {
        let c = base();
        assert_eq!(c.resources(Format::Fp32).dsp, 2 * c.resources(Format::Fp16).dsp);
    }

    #[test]
    fn dataflow_overlap_never_slower() {
        let spec = vek280().spec(Component::PL).clone();
        let df = PlConfig { dataflow: true, ..base() };
        assert!(df.latency(&spec, &mm(), Format::Fp16) <= base().latency(&spec, &mm(), Format::Fp16));
    }

    #[test]
    fn func_pipeline_cuts_init() {
        let spec = vek280().spec(Component::PL).clone();
        let tiny = LayerKind::Mm { m: 4, k: 4, n: 4 };
        let fp = PlConfig { func_pipeline: true, ..base() };
        assert!(fp.latency(&spec, &tiny, Format::Fp16) < base().latency(&spec, &tiny, Format::Fp16));
    }
}
