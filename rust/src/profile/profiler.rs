//! Per-node profiling (paper §IV-B): run the PL/AIE DSE for every layer
//! node of a training DAG and keep a small Pareto candidate set per
//! component — the `t_ij` / `a_ij` inputs of the ILP (§IV-C).

use crate::graph::Dag;
use crate::hw::{Component, Format, Platform};
use crate::Micros;

use super::calib;
use super::dse::{explore_aie, explore_pl};
use super::ps_model::ps_latency_analytic;

/// One (component, config) execution option for a node.
#[derive(Clone, Debug)]
pub struct Candidate {
    pub component: Component,
    pub fmt: Format,
    pub latency_us: Micros,
    /// Resource draw: DSP slices (PL) or tiles (AIE); 0 on PS.
    pub resource: usize,
    pub kluts: f64,
}

/// Profiling result for one node.
#[derive(Clone, Debug)]
pub struct NodeProfile {
    pub node: usize,
    /// PL candidates (every node has at least one — non-MM are pinned
    /// here).
    pub pl: Vec<Candidate>,
    /// AIE candidates (empty for non-MM nodes, per §IV-A).
    pub aie: Vec<Candidate>,
    /// Reference latency on the PS (Fig 4's software row). Measured —
    /// from the active calibration table — when it covers the shape,
    /// else the analytic model (`ps_measured` says which).
    pub ps_latency_us: Micros,
    /// What the analytic PS model predicts, always; with `ps_latency_us`
    /// this is the per-node modeled-vs-measured comparison plans report.
    pub ps_modeled_us: Micros,
    /// True when `ps_latency_us` came from calibration measurements.
    pub ps_measured: bool,
    /// Outgoing-edge payload in elements (activation tensor).
    pub out_elems: usize,
    /// Master-weight volume updated at this node (elements).
    pub weight_elems: usize,
}

/// Formats used per component: AP-DRL quantized mode follows Alg. 1
/// (PL=FP16, AIE=BF16); fp32 mode profiles everything in FP32.
pub fn component_format(c: Component, quantized: bool) -> Format {
    if quantized {
        c.native_format()
    } else {
        Format::Fp32
    }
}

/// Best frontier point within a resource budget (frontier is sorted by
/// ascending resource / descending latency).
fn best_within<C: Clone>(
    front: &[super::dse::DesignPoint<C>],
    budget: usize,
) -> Option<super::dse::DesignPoint<C>> {
    front.iter().rev().find(|d| d.resource <= budget).cloned()
}

/// Profile every node of `dag` on `platform`.
///
/// **Shared-accelerator semantics** (DESIGN.md §Substitutions): COMBA
/// builds one optimized kernel per op class and CHARM *composes* a small
/// number of shared GEMM accelerators that all AIE-assigned layers reuse
/// in sequence — per-layer kernels do not spatially coexist one-per-node.
/// Each node therefore gets its *best* config on each component (the DSE
/// winner under the full resource pool), and Eq. 7's capacity constraint
/// binds the shared engines (max over assigned nodes), not their sum.
/// The partitioning decision is then the paper's pure binary x_ij over
/// {PL, AIE} (Eq. 4).
pub fn profile_dag(dag: &Dag, platform: &Platform, quantized: bool) -> Vec<NodeProfile> {
    let pl_fmt = component_format(Component::PL, quantized);
    let aie_fmt = component_format(Component::AIE, quantized);
    let ps_fmt = Format::Fp32; // the PS always runs fp32 (paper Alg. 1)
    dag.nodes
        .iter()
        .map(|node| {
            let pl_front =
                explore_pl(platform.spec(Component::PL), &node.kind, pl_fmt, platform.pl_dsp);
            // DSE winner = fastest point of the Pareto frontier.
            let pl = best_within(&pl_front, platform.pl_dsp)
                .into_iter()
                .map(|d| Candidate {
                    component: Component::PL,
                    fmt: pl_fmt,
                    latency_us: d.latency_us,
                    resource: d.resource,
                    kluts: d.kluts,
                })
                .collect();
            // MM nodes are PL/AIE-decidable (Eq. 4); update nodes may
            // also live on the AIE (Alg. 1: AIE layers update weights in
            // BF16 on-array, no master sync).  Activation non-MM nodes
            // stay PL-pinned (§IV-A).
            let aie_eligible =
                node.kind.is_mm() || node.phase == crate::graph::Phase::Update;
            let aie = if aie_eligible {
                let front = explore_aie(
                    platform.spec(Component::AIE),
                    &node.kind,
                    aie_fmt,
                    platform.aie_tiles,
                    platform.aie_lanes_per_tile,
                );
                best_within(&front, platform.aie_tiles)
                    .into_iter()
                    .map(|d| Candidate {
                        component: Component::AIE,
                        fmt: aie_fmt,
                        latency_us: d.latency_us,
                        resource: d.resource,
                        kluts: d.kluts,
                    })
                    .collect()
            } else {
                Vec::new()
            };
            let ps_modeled_us =
                ps_latency_analytic(platform.spec(Component::PS), &node.kind, ps_fmt);
            let measured = calib::measured_ps_latency(&node.kind);
            NodeProfile {
                node: node.id,
                pl,
                aie,
                ps_latency_us: measured.unwrap_or(ps_modeled_us),
                ps_modeled_us,
                ps_measured: measured.is_some(),
                out_elems: node.out_elems,
                weight_elems: node.weight_elems,
            }
        })
        .collect()
}

impl NodeProfile {
    /// Fastest candidate on a component (None if not a candidate there).
    pub fn best_on(&self, c: Component) -> Option<&Candidate> {
        let list = match c {
            Component::PL => &self.pl,
            Component::AIE => &self.aie,
            Component::PS => return None,
        };
        list.iter().min_by(|a, b| a.latency_us.partial_cmp(&b.latency_us).unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{build_train_graph, Algo, NetSpec, TrainSpec};
    use crate::hw::vek280;

    fn profiles(batch: usize) -> (Dag, Vec<NodeProfile>) {
        let spec = TrainSpec {
            algo: Algo::Dqn,
            net: NetSpec::mlp(&[4, 64, 64, 2]),
            batch,
            obs_dim: 4,
            act_dim: 2,
        };
        let dag = build_train_graph(&spec);
        let platform = vek280();
        let profs = profile_dag(&dag, &platform, true);
        (dag, profs)
    }

    #[test]
    fn every_node_has_pl_candidate() {
        let (dag, profs) = profiles(64);
        assert_eq!(profs.len(), dag.len());
        for p in &profs {
            assert!(!p.pl.is_empty(), "node {} has no PL candidate", p.node);
        }
    }

    #[test]
    fn aie_candidates_for_mm_and_update_nodes_only() {
        // MM nodes (Eq. 4) and weight updates (Alg. 1: AIE layers update
        // in BF16 on-array) are AIE-eligible; activations/losses are
        // PL-pinned (§IV-A).
        let (dag, profs) = profiles(64);
        for p in &profs {
            let n = &dag.nodes[p.node];
            let expected = n.kind.is_mm() || n.phase == crate::graph::Phase::Update;
            assert_eq!(!p.aie.is_empty(), expected, "node {} ({})", p.node, n.name);
        }
    }

    #[test]
    fn small_layers_prefer_pl() {
        // CartPole's tiny layers: best PL < best AIE (launch overhead).
        let (dag, profs) = profiles(64);
        for p in &profs {
            if dag.nodes[p.node].kind.is_mm() {
                let pl = p.best_on(Component::PL).unwrap().latency_us;
                let aie = p.best_on(Component::AIE).unwrap().latency_us;
                assert!(pl < aie, "node {}: PL {pl} vs AIE {aie}", dag.nodes[p.node].name);
            }
        }
    }

    #[test]
    fn candidate_count_bounded() {
        let (_, profs) = profiles(256);
        for p in &profs {
            assert!(p.pl.len() <= 4 && p.aie.len() <= 10);
        }
    }

    #[test]
    fn quantized_formats_follow_alg1() {
        assert_eq!(component_format(Component::PL, true), Format::Fp16);
        assert_eq!(component_format(Component::AIE, true), Format::Bf16);
        assert_eq!(component_format(Component::PL, false), Format::Fp32);
    }
}
