//! Design-space exploration over the Table I pragma space (PL) and the
//! tile allocations (AIE), returning latency/resource Pareto frontiers.

use crate::graph::layer::LayerKind;
use crate::hw::{ComponentSpec, Format};
use crate::Micros;

use super::aie_model::{tile_candidates, AieConfig};
use super::pl_model::PlConfig;

/// One explored point: latency + scalar resource cost (DSPs on PL, tiles
/// on AIE) + the config that produced it.
#[derive(Clone, Debug)]
pub struct DesignPoint<C> {
    pub latency_us: Micros,
    pub resource: usize,
    pub kluts: f64,
    pub config: C,
}

/// Pareto frontier: minimal latency for each resource level (and vice
/// versa), sorted by ascending resource.
pub fn pareto<C: Clone>(mut points: Vec<DesignPoint<C>>) -> Vec<DesignPoint<C>> {
    points.sort_by(|a, b| {
        a.resource
            .cmp(&b.resource)
            .then(a.latency_us.partial_cmp(&b.latency_us).unwrap())
    });
    let mut out: Vec<DesignPoint<C>> = Vec::new();
    let mut best = f64::INFINITY;
    for p in points {
        if p.latency_us < best {
            best = p.latency_us;
            out.push(p);
        }
    }
    out
}

/// Table I: the loop-unroll factors sampled in exponential progression up
/// to the loop bound LB (⌈log₂ LB⌉ points).
pub fn unroll_factors(loop_bound: usize) -> Vec<usize> {
    let mut v = vec![1usize];
    let mut u = 2;
    while u <= loop_bound {
        v.push(u);
        u *= 2;
    }
    v
}

/// Table I: array-partition factors bounded by the interface bitwidth,
/// ⌊B_M / B_D⌋ + 1 points (B_M = 128-bit AXI, B_D = format width).
pub fn partition_factors(fmt: Format) -> Vec<usize> {
    let bm = 128usize;
    let bd = fmt.bytes() * 8;
    // factors 2^i up to bm/bd, plus 1 — |points| = bm/bd + 1 in the
    // paper's notation (they count the identity partition too).
    let maxf = bm / bd;
    let mut v = vec![1usize];
    let mut f = 2;
    while f <= maxf {
        v.push(f);
        f *= 2;
    }
    v
}

/// Full Table-I sweep for one node on the PL.  Returns the Pareto
/// frontier over (latency, DSP usage).
pub fn explore_pl(
    spec: &ComponentSpec,
    kind: &LayerKind,
    fmt: Format,
    max_dsp: usize,
) -> Vec<DesignPoint<PlConfig>> {
    let loop_bound = match *kind {
        LayerKind::Mm { k, n, .. } => (k * n).min(4096),
        LayerKind::Elementwise { elems } | LayerKind::Reduce { elems } => elems.min(4096),
    };
    // Scale partition factors with lanes: banks = partition factor ×
    // base interface factor (wider unrolls need multi-bank arrays).
    let mut points = Vec::new();
    for &df in &[false, true] {
        for &fp in &[false, true] {
            for &lp in &[false, true] {
                for &lu in &unroll_factors(loop_bound) {
                    for &ap_base in &partition_factors(fmt) {
                        // Banks needed to feed `lu` lanes come in units
                        // of the interface factor.
                        let ap = ap_base * ((lu / 2).max(1)).min(656);
                        let cfg = PlConfig {
                            dataflow: df,
                            func_pipeline: fp,
                            loop_pipeline: lp,
                            unroll: lu.min(spec.max_mac_lanes),
                            array_partition: ap,
                        };
                        let res = cfg.resources(fmt);
                        if res.dsp > max_dsp {
                            continue;
                        }
                        points.push(DesignPoint {
                            latency_us: cfg.latency(spec, kind, fmt),
                            resource: res.dsp,
                            kluts: res.kluts,
                            config: cfg,
                        });
                    }
                }
            }
        }
    }
    pareto(points)
}

/// CHARM-substitute sweep for one MM node on the AIE.
pub fn explore_aie(
    spec: &ComponentSpec,
    kind: &LayerKind,
    fmt: Format,
    max_tiles: usize,
    lanes_per_tile: usize,
) -> Vec<DesignPoint<AieConfig>> {
    let mut points = Vec::new();
    for tiles in tile_candidates(max_tiles) {
        let cfg = AieConfig { tiles, lanes_per_tile };
        points.push(DesignPoint {
            latency_us: cfg.latency(spec, kind, fmt),
            resource: tiles,
            kluts: 3.0, // PL-side data movers per AIE kernel (CHARM)
            config: cfg,
        });
    }
    pareto(points)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::{vek280, Component};
    use crate::util::proplite::forall;

    #[test]
    fn unroll_factors_log2() {
        assert_eq!(unroll_factors(8), vec![1, 2, 4, 8]);
        assert_eq!(unroll_factors(1), vec![1]);
        assert_eq!(unroll_factors(9).len(), 4); // 1,2,4,8
    }

    #[test]
    fn partition_factors_bounded_by_interface() {
        // fp16: 128/16 = 8 → 1,2,4,8
        assert_eq!(partition_factors(Format::Fp16), vec![1, 2, 4, 8]);
        // fp32: 128/32 = 4 → 1,2,4
        assert_eq!(partition_factors(Format::Fp32), vec![1, 2, 4]);
    }

    #[test]
    fn pareto_is_strictly_improving() {
        let p = vek280();
        let kind = LayerKind::Mm { m: 256, k: 128, n: 128 };
        let front = explore_pl(p.spec(Component::PL), &kind, Format::Fp16, p.pl_dsp);
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].resource > w[0].resource);
            assert!(w[1].latency_us < w[0].latency_us);
        }
    }

    #[test]
    fn aie_frontier_nonempty_and_sorted() {
        let p = vek280();
        let kind = LayerKind::Mm { m: 512, k: 512, n: 512 };
        let front = explore_aie(
            p.spec(Component::AIE),
            &kind,
            Format::Bf16,
            p.aie_tiles,
            p.aie_lanes_per_tile,
        );
        assert!(!front.is_empty());
        for w in front.windows(2) {
            assert!(w[1].latency_us < w[0].latency_us);
        }
    }

    #[test]
    fn pareto_property_no_dominated_points() {
        forall(50, 0xDEE5E, |rng| {
            let pts: Vec<DesignPoint<()>> = (0..20)
                .map(|_| DesignPoint {
                    latency_us: rng.uniform_in(1.0, 100.0),
                    resource: rng.below(64),
                    kluts: 0.0,
                    config: (),
                })
                .collect();
            let front = pareto(pts.clone());
            // every original point is dominated-or-equal by some frontier point
            for p in &pts {
                assert!(
                    front
                        .iter()
                        .any(|f| f.resource <= p.resource && f.latency_us <= p.latency_us),
                    "point ({}, {}) not covered",
                    p.resource,
                    p.latency_us
                );
            }
        });
    }

    #[test]
    fn bigger_dsp_budget_never_hurts() {
        let p = vek280();
        let kind = LayerKind::Mm { m: 512, k: 256, n: 256 };
        let small = explore_pl(p.spec(Component::PL), &kind, Format::Fp16, 64);
        let big = explore_pl(p.spec(Component::PL), &kind, Format::Fp16, p.pl_dsp);
        let best_small = small.iter().map(|d| d.latency_us).fold(f64::INFINITY, f64::min);
        let best_big = big.iter().map(|d| d.latency_us).fold(f64::INFINITY, f64::min);
        assert!(best_big <= best_small);
    }
}
