//! CartPole-v1 dynamics (Barto, Sutton & Anderson 1983; Gym constants).

use anyhow::{ensure, Result};

use crate::util::json::{hex_f64s, parse_hex_f64s, Json};
use crate::util::Rng;

use super::{Action, Env, Transition};

const GRAVITY: f64 = 9.8;
const MASS_CART: f64 = 1.0;
const MASS_POLE: f64 = 0.1;
const TOTAL_MASS: f64 = MASS_CART + MASS_POLE;
const LENGTH: f64 = 0.5; // half pole length
const POLE_MASS_LENGTH: f64 = MASS_POLE * LENGTH;
const FORCE_MAG: f64 = 10.0;
const TAU: f64 = 0.02;
const THETA_LIMIT: f64 = 12.0 * std::f64::consts::PI / 180.0;
const X_LIMIT: f64 = 2.4;

/// Classic cart-pole balancing task; discrete {left, right} actions,
/// +1 reward per surviving step, 500-step cap (v1).
#[derive(Clone, Debug, Default)]
pub struct CartPole {
    x: f64,
    x_dot: f64,
    theta: f64,
    theta_dot: f64,
    steps: usize,
}

impl CartPole {
    pub fn new() -> Self {
        Self::default()
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.x as f32, self.x_dot as f32, self.theta as f32, self.theta_dot as f32]
    }
}

impl Env for CartPole {
    fn obs_dim(&self) -> usize {
        4
    }

    fn action_dim(&self) -> usize {
        2
    }

    fn is_discrete(&self) -> bool {
        true
    }

    fn max_steps(&self) -> usize {
        500
    }

    fn reset(&mut self, rng: &mut Rng) -> Vec<f32> {
        self.x = rng.uniform_in(-0.05, 0.05);
        self.x_dot = rng.uniform_in(-0.05, 0.05);
        self.theta = rng.uniform_in(-0.05, 0.05);
        self.theta_dot = rng.uniform_in(-0.05, 0.05);
        self.steps = 0;
        self.obs()
    }

    fn step(&mut self, action: &Action, _rng: &mut Rng) -> Transition {
        let force = if action.discrete() == 1 { FORCE_MAG } else { -FORCE_MAG };
        let (sin_t, cos_t) = self.theta.sin_cos();
        let temp =
            (force + POLE_MASS_LENGTH * self.theta_dot * self.theta_dot * sin_t) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin_t - cos_t * temp)
            / (LENGTH * (4.0 / 3.0 - MASS_POLE * cos_t * cos_t / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LENGTH * theta_acc * cos_t / TOTAL_MASS;
        // Euler integration (Gym semantics).
        self.x += TAU * self.x_dot;
        self.x_dot += TAU * x_acc;
        self.theta += TAU * self.theta_dot;
        self.theta_dot += TAU * theta_acc;
        self.steps += 1;
        let failed = self.x.abs() > X_LIMIT || self.theta.abs() > THETA_LIMIT;
        let truncated = self.steps >= self.max_steps();
        Transition { obs: self.obs(), reward: 1.0, done: failed || truncated }
    }

    fn save_state(&self) -> Json {
        Json::obj(vec![
            ("phase", Json::Str(hex_f64s(&[self.x, self.x_dot, self.theta, self.theta_dot]))),
            ("steps", Json::Num(self.steps as f64)),
        ])
    }

    fn restore_state(&mut self, state: &Json) -> Result<()> {
        let p = parse_hex_f64s(state.req_str("phase")?)?;
        ensure!(p.len() == 4, "cartpole state: expected 4 phase values, got {}", p.len());
        self.x = p[0];
        self.x_dot = p[1];
        self.theta = p[2];
        self.theta_dot = p[3];
        self.steps = state.req_u64("steps")? as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::contract_check;

    #[test]
    fn contract() {
        contract_check(&mut CartPole::new(), 42);
    }

    #[test]
    fn random_policy_fails_quickly() {
        let mut env = CartPole::new();
        let mut rng = Rng::new(7);
        let mut lengths = Vec::new();
        for _ in 0..20 {
            env.reset(&mut rng);
            let mut n = 0;
            loop {
                let t = env.step(&Action::Discrete(rng.below(2)), &mut rng);
                n += 1;
                if t.done {
                    break;
                }
            }
            lengths.push(n as f64);
        }
        let mean = crate::util::stats::mean(&lengths);
        assert!((8.0..80.0).contains(&mean), "random policy mean length {mean}");
    }

    #[test]
    fn balanced_policy_survives_longer() {
        // Push in the direction the pole leans: a crude but better policy.
        let mut env = CartPole::new();
        let mut rng = Rng::new(8);
        let mut total = 0usize;
        for _ in 0..10 {
            let mut obs = env.reset(&mut rng);
            loop {
                let a = if obs[2] > 0.0 { 1 } else { 0 };
                let t = env.step(&Action::Discrete(a), &mut rng);
                obs = t.obs;
                total += 1;
                if t.done {
                    break;
                }
            }
        }
        assert!(total / 10 > 25, "lean-following policy too weak: {}", total / 10);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = CartPole::new();
            let mut rng = Rng::new(seed);
            env.reset(&mut rng);
            let mut v = Vec::new();
            for i in 0..20 {
                v.extend(env.step(&Action::Discrete(i % 2), &mut rng).obs);
            }
            v
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }
}
